//! Replay the Facebook Hadoop 2010 workload (paper §7.8, Fig. 12).
//!
//! Uses the real SWIM TSV when `traces/FB-2010_samples_24_times_1hr_0.tsv`
//! is present, otherwise the synthetic stand-in matched to the published
//! statistics (24 443 jobs, mean 76.1 GiB, max 85.2 TiB — DESIGN.md §4).
//! Service speed is normalized for load 0.9 exactly as in the paper,
//! then MST is reported against the exact-information SRPT optimum for
//! a sweep of error levels.
//!
//! ```sh
//! cargo run --release --example hadoop_replay
//! ```

use psbs::figures::{exact_copy, run_mst};
use psbs::workload::traces;

fn main() {
    let path = "traces/FB-2010_samples_24_times_1hr_0.tsv";
    let recs = match traces::load_file(path, "swim") {
        Ok(r) if !r.is_empty() => {
            println!("replaying real trace {path} ({} jobs)", r.len());
            r
        }
        _ => {
            let r = traces::synth_trace(&traces::FACEBOOK, 42);
            println!(
                "real trace not found; using the synthetic stand-in ({} jobs, mean {:.1} GiB)",
                r.len(),
                r.iter().map(|x| x.bytes).sum::<f64>() / r.len() as f64 / traces::GIB
            );
            r
        }
    };

    // Job size CCDF tail span (Fig. 11's headline feature).
    let ccdf = traces::ccdf(&recs, 20);
    let (max_over_mean, _) = ccdf.last().unwrap();
    println!("size tail spans {:.1} decades above the mean\n", max_over_mean.log10());

    println!(
        "{:<6} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "sigma", "psbs", "fspe", "srpte", "ps", "las"
    );
    for sigma in [0.125, 0.25, 0.5, 1.0, 2.0, 4.0] {
        let jobs = traces::to_jobs(&recs, 0.9, sigma, 7);
        let opt = run_mst("srpt", &exact_copy(&jobs));
        let row: Vec<f64> = ["psbs", "fspe", "srpte", "ps", "las"]
            .iter()
            .map(|p| run_mst(p, &jobs) / opt)
            .collect();
        println!(
            "{:<6} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3}",
            sigma, row[0], row[1], row[2], row[3], row[4]
        );
    }
    println!("\n(values are MST / optimal; the paper's Fig. 12 shape: PSBS stays");
    println!(" near 1 and below PS for sigma < 2, SRPTE/FSPE degrade with error)");
}
