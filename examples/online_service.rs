//! The "practical" deployment shape (paper §8): PSBS running *online*
//! inside a leader thread, fed by concurrent clients over channels,
//! measuring real wall-clock latency and throughput.
//!
//! Three client threads submit jobs with noisy size estimates and
//! different weights; the service schedules them with PSBS over a
//! simulated machine and reports per-class latency.
//!
//! ```sh
//! cargo run --release --example online_service
//! ```

use psbs::coordinator::{Service, ServiceConfig};
use psbs::workload::dists::{Dist, LogNormal, Weibull};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let speed = 50_000.0; // service units per second
    let svc = Arc::new(Service::start(ServiceConfig { policy: "psbs".into(), speed }));

    // Three tenants: weights 4 (interactive), 2 (batch), 1 (background).
    let tenants = [("interactive", 4.0, 60), ("batch", 2.0, 60), ("background", 1.0, 60)];
    let mut handles = Vec::new();
    for (ti, &(name, weight, njobs)) in tenants.iter().enumerate() {
        let svc = Arc::clone(&svc);
        handles.push(std::thread::spawn(move || {
            let mut rng = psbs::util::rng::Rng::new(100 + ti as u64);
            let sizes = Weibull::with_mean(0.5, speed * 0.01); // ~10 ms mean
            let err = LogNormal::error_model(0.5);
            let mut rxs = Vec::new();
            for _ in 0..njobs {
                let size = sizes.sample(&mut rng).max(1.0);
                let est = (size * err.sample(&mut rng)).max(1.0);
                rxs.push(svc.submit(size, est, weight));
                std::thread::sleep(Duration::from_millis(2));
            }
            let mut lat = Vec::new();
            let mut slow = Vec::new();
            for rx in rxs {
                let info = rx.recv_timeout(Duration::from_secs(60)).expect("completion");
                lat.push(info.latency.as_secs_f64() * 1e3);
                slow.push(info.slowdown);
            }
            (name, weight, lat, slow)
        }));
    }

    println!(
        "{:<14} {:>7} {:>12} {:>12} {:>12}",
        "tenant", "weight", "mean ms", "p99 ms", "mean slowdn"
    );
    for h in handles {
        let (name, weight, lat, slow) = h.join().unwrap();
        println!(
            "{:<14} {:>7} {:>12.2} {:>12.2} {:>12.2}",
            name,
            weight,
            psbs::stats::mean(&lat),
            psbs::stats::quantile(&lat, 0.99),
            psbs::stats::mean(&slow),
        );
    }

    let svc = Arc::try_unwrap(svc).ok().expect("all clients joined");
    let stats = svc.shutdown();
    println!(
        "\nservice: {} jobs completed in {:.2} s  ({:.1} jobs/s, mean latency {:.2} ms)",
        stats.completed,
        stats.wall_s,
        stats.throughput(),
        stats.mean_latency_s * 1e3
    );
}
