//! End-to-end validation driver: exercises **all layers** of the stack
//! on a real small workload and prints the paper's headline metric.
//!
//! The pipeline this runs:
//!   1. **Runtime + L1/L2**: load the AOT artifacts (`artifacts/*.hlo.txt`,
//!      compiled from the JAX graphs and Pallas kernels by
//!      `make artifacts`) on the PJRT CPU client;
//!   2. **Workload generation through the `workload` artifact**: rust
//!      supplies uniforms, the compiled Weibull inverse-CDF + log-normal
//!      Box–Muller kernels produce job sizes and error multipliers;
//!   3. **L3 coordinator**: simulate the scheduler zoo over that
//!      workload (Table-1 defaults);
//!   4. **Analytics through the `analytics` artifact**: slowdowns,
//!      conditional-slowdown classes and the ECDF are computed by the
//!      compiled one-hot-matmul binning kernel, cross-checked against
//!      the pure-rust metrics;
//!   5. Report the Fig. 5/6 headline: PSBS ≈ optimal while SRPTE/FSPE
//!      degrade, and everything agrees between the compiled and native
//!      paths.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_repro
//! ```

use psbs::figures::{exact_copy, run_mst};
use psbs::runtime::Runtime;
use psbs::sim::Job;
use psbs::util::rng::Rng;
use psbs::workload::dists::Weibull;
use psbs::{metrics, sched, sim};

/// Dependency-free `ensure!` stand-in (`anyhow` is unavailable in the
/// offline build environment).
macro_rules! ensure {
    ($cond:expr, $($msg:tt)+) => {
        if !$cond {
            return Err(format!($($msg)+).into());
        }
    };
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // ---- 1. load artifacts --------------------------------------------
    let rt = match Runtime::try_default() {
        Some(rt) => rt,
        None => {
            eprintln!("artifacts/ missing — run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!(
        "loaded AOT artifacts (batch {}, {} bins, {} thresholds)",
        rt.manifest.batch, rt.manifest.num_bins, rt.manifest.num_thresholds
    );

    // ---- 2. generate the workload through the compiled graph ----------
    let njobs = 10_000;
    let (shape, sigma, load, timeshape) = (0.25, 0.5, 0.9, 1.0);
    let rng = Rng::new(42);
    let scale = 1.0 / psbs::stats::gamma(1.0 + 1.0 / shape);
    let (sizes, mults) =
        rt.gen_weibull_lognormal(&mut rng.substream(1), njobs, shape, scale, sigma)?;
    // Arrival gaps from the same artifact (sigma 0 => multipliers unused).
    let gap_scale = Weibull::with_mean(timeshape, 1.0 / load).scale;
    let (gaps, _) =
        rt.gen_weibull_lognormal(&mut rng.substream(2), njobs, timeshape, gap_scale, 0.0)?;
    let mut t = 0.0;
    let jobs: Vec<Job> = (0..njobs)
        .map(|i| {
            t += gaps[i];
            let size = sizes[i].max(1e-9);
            Job { id: i as u32, arrival: t, size, est: (size * mults[i]).max(1e-9), weight: 1.0 }
        })
        .collect();
    let total: f64 = jobs.iter().map(|j| j.size).sum();
    println!(
        "generated {njobs} jobs via the compiled Weibull/log-normal kernels \
         (total work {total:.0}, empirical load {:.3})",
        total / t
    );

    // ---- 3. run the zoo ------------------------------------------------
    let opt = run_mst("srpt", &exact_copy(&jobs));
    println!("\noptimal MST (SRPT, exact sizes): {opt:.3}\n");
    println!("{:<10} {:>10} {:>12}", "policy", "MST/opt", "frac>100");
    let mut psbs_ratio = f64::NAN;
    let mut fspe_ratio = f64::NAN;
    for policy in ["psbs", "fspe+ps", "fspe", "srpte", "ps", "las", "fifo"] {
        let mut s = sched::by_name(policy).unwrap();
        let res = sim::run(s.as_mut(), &jobs);
        let ratio = res.mst(&jobs) / opt;
        let slow = res.slowdowns(&jobs);
        println!(
            "{:<10} {:>10.3} {:>12.4}",
            policy,
            ratio,
            metrics::frac_above(&slow, 100.0)
        );
        if policy == "psbs" {
            psbs_ratio = ratio;
        }
        if policy == "fspe" {
            fspe_ratio = ratio;
        }

        // ---- 4. analytics through the compiled graph ------------------
        if policy == "psbs" {
            let sizes: Vec<f64> = jobs.iter().map(|j| j.size).collect();
            let sojourns: Vec<f64> = jobs
                .iter()
                .map(|j| res.completion[j.id as usize] - j.arrival)
                .collect();
            let idx = metrics::bin_indices(&jobs, rt.manifest.num_bins);
            let thr = metrics::log_thresholds(rt.manifest.num_thresholds, 3.0);
            let out = rt.analyze(&sizes, &sojourns, &idx, &thr)?;
            let rust_mst = res.mst(&jobs);
            let hlo_mst = out.mst();
            ensure!(
                (rust_mst - hlo_mst).abs() / rust_mst < 1e-3,
                "compiled vs native MST mismatch: {hlo_mst} vs {rust_mst}"
            );
            println!(
                "           (analytics artifact agrees: MST {hlo_mst:.3} vs native {rust_mst:.3})"
            );
        }
    }

    // ---- 5. the reproduction check -------------------------------------
    println!();
    ensure!(
        psbs_ratio < fspe_ratio,
        "expected PSBS ({psbs_ratio:.2}) below FSPE ({fspe_ratio:.2}) at shape 0.25"
    );
    println!(
        "headline reproduced: PSBS at {psbs_ratio:.2}x optimal vs FSPE at {fspe_ratio:.2}x \
         on the heavy-tailed default workload — record in EXPERIMENTS.md"
    );
    Ok(())
}
