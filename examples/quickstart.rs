//! Quickstart: generate one Table-1 workload, run the scheduler zoo,
//! compare mean sojourn times.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use psbs::{metrics, sched, sim, workload};

fn main() {
    // The paper's defaults (Table 1): Weibull(0.25) sizes, sigma = 0.5
    // log-normal size-estimation error, load 0.9, 10 000 jobs.
    let cfg = workload::SynthConfig::default().with_njobs(5_000);
    let jobs = workload::synthesize(&cfg, 42);
    println!(
        "workload: {} jobs, total work {:.1}, span {:.1}",
        jobs.len(),
        jobs.iter().map(|j| j.size).sum::<f64>(),
        jobs.last().unwrap().arrival
    );

    println!("\n{:<12} {:>10} {:>12} {:>14}", "policy", "MST", "p99 slowdown", "frac>100 slow");
    for policy in ["fifo", "ps", "las", "srpte", "fspe", "fspe+ps", "psbs"] {
        let mut s = sched::by_name(policy).unwrap();
        let res = sim::run(s.as_mut(), &jobs);
        let slow = res.slowdowns(&jobs);
        println!(
            "{:<12} {:>10.3} {:>12.2} {:>14.4}",
            policy,
            res.mst(&jobs),
            psbs::stats::quantile(&slow, 0.99),
            metrics::frac_above(&slow, 100.0),
        );
    }

    // The reproduction headline: with estimation errors on a
    // heavy-tailed workload, PSBS tracks the (exact-information) SRPT
    // optimum while plain SRPTE/FSPE blow up.
    let exact: Vec<_> = jobs.iter().map(|j| psbs::sim::Job { est: j.size, ..*j }).collect();
    let mut srpt = sched::by_name("srpt").unwrap();
    let opt = sim::run(srpt.as_mut(), &exact).mst(&exact);
    let mut psbs_s = sched::by_name("psbs").unwrap();
    let psbs_mst = sim::run(psbs_s.as_mut(), &jobs).mst(&jobs);
    println!("\noptimal MST (SRPT, exact sizes): {opt:.3}");
    println!("PSBS / optimal = {:.3}", psbs_mst / opt);
}
