//! Job weights (paper §7.6, Fig. 9): five weight classes w = 1/c^beta,
//! PSBS vs DPS per-class mean sojourn time as beta sweeps 0 → 2.
//!
//! The paper's claims to reproduce:
//! * PSBS outperforms DPS in every class, for every beta;
//! * raising beta improves high-weight classes at the expense of
//!   low-weight ones;
//! * at beta = 2 the best class is already near the optimal MST of 1.
//!
//! ```sh
//! cargo run --release --example weighted_classes
//! ```

use psbs::workload::{synthetic::weight_class, SynthConfig};
use psbs::{sched, sim, stats, workload};

fn main() {
    let reps = 3;
    for shape in [0.25, 4.0] {
        println!("== shape {shape} ==");
        println!(
            "{:<6} {:<6} {:>12} {:>12} {:>8}",
            "beta", "class", "psbs MST", "dps MST", "ratio"
        );
        for beta in [0.0, 1.0, 2.0] {
            let cfg = SynthConfig::default().with_shape(shape).with_beta(beta).with_njobs(5_000);
            let mut psbs_mst = vec![Vec::new(); 5];
            let mut dps_mst = vec![Vec::new(); 5];
            for r in 0..reps {
                let jobs = workload::synthesize(&cfg, 42 + r * 7919);
                for (policy, acc) in [("psbs", &mut psbs_mst), ("dps", &mut dps_mst)] {
                    let mut s = sched::by_name(policy).unwrap();
                    let res = sim::run(s.as_mut(), &jobs);
                    let soj = res.sojourns(&jobs);
                    let mut sums = [0.0; 5];
                    let mut counts = [0usize; 5];
                    for (j, s) in jobs.iter().zip(&soj) {
                        let c = weight_class(j.weight, beta) - 1;
                        sums[c] += s;
                        counts[c] += 1;
                    }
                    for c in 0..5 {
                        if counts[c] > 0 {
                            acc[c].push(sums[c] / counts[c] as f64);
                        }
                    }
                }
            }
            for c in 0..5 {
                let p = stats::mean(&psbs_mst[c]);
                let d = stats::mean(&dps_mst[c]);
                println!(
                    "{:<6} {:<6} {:>12.3} {:>12.3} {:>8.3}",
                    beta,
                    c + 1,
                    p,
                    d,
                    p / d
                );
                if beta == 0.0 {
                    break; // uniform weights: all classes identical
                }
            }
        }
        println!();
    }
    println!("(ratio < 1 everywhere reproduces Fig. 9: PSBS beats DPS per class)");
}
