//! Multi-server PSBS (the HFSP [15] deployment shape): k unit-rate
//! servers behind a dispatcher, offered load 0.9·k, heavy-tailed sizes
//! with sigma = 0.5 estimation errors.
//!
//! Compares dispatch policies (least-estimated-work vs round-robin vs
//! random) and shows that size-based routing composes with size-based
//! per-server scheduling — and inherits the same robustness to
//! estimate errors that PSBS gives a single server.
//!
//! ```sh
//! cargo run --release --example cluster_sim
//! ```

use psbs::coordinator::{Cluster, Dispatch};
use psbs::workload::SynthConfig;
use psbs::{sim, stats, workload};

fn main() {
    let reps = 5;
    println!(
        "{:<4} {:>12} {:>12} {:>12}   {:>18}",
        "k", "least-work", "round-robin", "random", "(MST, psbs servers)"
    );
    for k in [1usize, 2, 4, 8, 16] {
        let cfg = SynthConfig::default()
            .with_load(0.9 * k as f64) // keep per-server load at 0.9
            .with_njobs(10_000);
        let mut cols = Vec::new();
        for dispatch in [Dispatch::LeastWork, Dispatch::RoundRobin, Dispatch::Random] {
            let mut msts = Vec::new();
            for r in 0..reps {
                let jobs = workload::synthesize(&cfg, 42 + r * 7919);
                let mut c = Cluster::new("psbs", k, dispatch, 7).unwrap();
                msts.push(sim::run(&mut c, &jobs).mst(&jobs));
            }
            cols.push(stats::mean(&msts));
        }
        println!(
            "{:<4} {:>12.3} {:>12.3} {:>12.3}",
            k, cols[0], cols[1], cols[2]
        );
    }

    println!("\nper-server policy comparison at k = 4 (least-work dispatch):");
    println!("{:<10} {:>10}", "policy", "MST");
    let cfg = SynthConfig::default().with_load(3.6).with_njobs(10_000);
    for policy in ["psbs", "fspe", "srpte", "ps", "las"] {
        let mut msts = Vec::new();
        for r in 0..reps {
            let jobs = workload::synthesize(&cfg, 42 + r * 7919);
            let mut c = Cluster::new(policy, 4, Dispatch::LeastWork, 7).unwrap();
            msts.push(sim::run(&mut c, &jobs).mst(&jobs));
        }
        println!("{:<10} {:>10.3}", policy, stats::mean(&msts));
    }
    println!("\n(PSBS keeps its single-server advantage inside a cluster)");
}
