//! Clock abstraction: what the event loop does *between* events.
//!
//! The streaming engine (`engine::stream_inner`) merges two event
//! streams — arrivals and scheduler-internal events — and advances
//! simulation state from one timestamp to the next.  In a simulation
//! that advance is free: virtual time jumps.  In a live service
//! (`psbs serve`) the same loop must *wait* for the wall clock to
//! reach each event, stay alive while both streams are momentarily
//! dry (more work may still arrive over the wire), and give the
//! service layer a hook to apply control requests (kills, stats,
//! shutdown) between steps.
//!
//! [`Clock`] captures exactly those four degrees of freedom, each with
//! a default that is the simulation behavior:
//!
//! * [`Clock::wait_until`] — block until it is time to process the
//!   event at `t` (default: don't — virtual time is free).  A live
//!   clock may return [`Wait::Interrupted`] to tell the engine to
//!   re-plan because the world changed while it slept (a new arrival
//!   or control request landed).
//! * [`Clock::wait_idle`] — both streams are dry; park until there is
//!   a reason to continue, or report that the run is over (default:
//!   it is over).
//! * [`Clock::live`] — whether the arrival source is open-ended
//!   (default: no).  A live engine must not stop just because
//!   everything delivered so far has completed.
//! * [`Clock::on_step`] — a between-steps hook with mutable access to
//!   the scheduler and the engine's [`JobStore`], where a service
//!   applies control requests (the kill path routes through
//!   [`Scheduler::cancel`] here); returning `false` aborts the run
//!   (default: keep going, touch nothing).
//!
//! [`VirtualClock`] implements the trait with *only* the defaults and
//! the engine is generic over the clock type, so the classic
//! simulation entry points monomorphize to exactly the pre-clock loop
//! — bit-identically, pinned by `rust/tests/streaming.rs` across the
//! whole policy zoo.  [`WallClock`] adds real-time pacing (with a
//! `--speedup` fast-forward factor) and is the pacing core of the
//! `psbs serve` session clock.

use super::store::JobStore;
use super::Scheduler;
use std::time::{Duration, Instant};

/// Outcome of a [`Clock::wait_until`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Wait {
    /// The wait ran to completion: process the event as planned.
    Elapsed,
    /// The world changed while waiting (new arrival, control request):
    /// the engine must re-merge the event streams before advancing.
    Interrupted,
}

/// What the event loop does between events — see the module docs.
/// Every method defaults to the virtual-time behavior; implement only
/// what a live deployment needs.
pub trait Clock {
    /// Block until the event at simulation time `t` should be
    /// processed.  Return [`Wait::Interrupted`] if the merge inputs
    /// may have changed (the engine loops back to re-plan instead of
    /// advancing).
    fn wait_until(&mut self, _t: f64) -> Wait {
        Wait::Elapsed
    }

    /// Both event streams are dry.  Return `true` to re-check (more
    /// work arrived or may still arrive), `false` to end the run.
    fn wait_idle(&mut self) -> bool {
        false
    }

    /// `true` when the arrival source is open-ended: the engine then
    /// keeps running after all delivered jobs complete instead of
    /// treating a momentarily-dry source as the end of the workload.
    fn live(&self) -> bool {
        false
    }

    /// Between-steps service hook, called once per loop iteration
    /// before the event streams are merged.  `now` is the engine's
    /// current simulation time; a live clock applies control requests
    /// here (kills via [`Scheduler::cancel`] + the store's state
    /// ledger).  Return `false` to abort the run immediately.
    fn on_step(&mut self, _now: f64, _sched: &mut dyn Scheduler, _store: &mut JobStore) -> bool {
        true
    }
}

/// Virtual time: all defaults, zero behavior — the simulation clock.
/// The engine monomorphized over `VirtualClock` is bit-identical to
/// the pre-clock engine (there is nothing to diverge: every hook
/// compiles to a constant).
#[derive(Debug, Default, Clone, Copy)]
pub struct VirtualClock;

impl Clock for VirtualClock {}

/// Wall-clock pacing: simulation time mapped affinely onto real time,
/// `speedup` simulated seconds per wall second (`f64::INFINITY` = no
/// pacing, run as fast as possible).
///
/// The origin is lazy: the first [`WallClock::remaining`] call pins
/// (wall now ↔ that event's simulation time), so a trace whose first
/// arrival is at t=10⁶ starts immediately instead of sleeping for
/// eleven virtual days.  Used directly as a [`Clock`] it paces a
/// closed workload (replay in real time); the `psbs serve` session
/// clock embeds one for pacing and layers interruptible waiting and
/// control handling on top.
#[derive(Debug, Clone, Copy)]
pub struct WallClock {
    speedup: f64,
    /// (wall origin, simulation origin), pinned at the first wait.
    origin: Option<(Instant, f64)>,
}

impl WallClock {
    /// `speedup` must be positive (`INFINITY` allowed: no pacing).
    pub fn new(speedup: f64) -> WallClock {
        assert!(speedup > 0.0, "speedup must be positive, got {speedup}");
        WallClock { speedup, origin: None }
    }

    /// How much longer the wall clock says to wait before processing
    /// the event at simulation time `t` — `None` when it is already
    /// due (or pacing is off).  Pins the pacing origin on first call.
    pub fn remaining(&mut self, t: f64) -> Option<Duration> {
        if !self.speedup.is_finite() {
            return None;
        }
        let (wall0, sim0) = *self.origin.get_or_insert_with(|| (Instant::now(), t));
        let dt = (t - sim0) / self.speedup;
        if !(dt > 0.0) || !dt.is_finite() {
            return None; // first event, past-due event, or degenerate dt
        }
        let due = wall0 + Duration::from_secs_f64(dt);
        due.checked_duration_since(Instant::now()).filter(|d| !d.is_zero())
    }
}

impl Clock for WallClock {
    fn wait_until(&mut self, t: f64) -> Wait {
        if let Some(d) = self.remaining(t) {
            std::thread::sleep(d);
        }
        Wait::Elapsed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_is_all_defaults() {
        let mut c = VirtualClock;
        assert_eq!(c.wait_until(123.0), Wait::Elapsed);
        assert!(!c.wait_idle());
        assert!(!c.live());
    }

    #[test]
    fn wall_clock_first_event_is_immediate() {
        let mut c = WallClock::new(1.0);
        // Even a huge first timestamp: the origin pins to it.
        assert_eq!(c.remaining(1.0e6), None);
        // And past-due events after the origin never wait.
        assert_eq!(c.remaining(1.0e6), None);
    }

    #[test]
    fn wall_clock_paces_relative_to_origin() {
        let mut c = WallClock::new(1000.0); // 1000 sim-seconds per wall-second
        assert_eq!(c.remaining(0.0), None);
        let d = c.remaining(100.0).expect("future event must wait");
        assert!(d <= Duration::from_millis(100), "100 sim-s at 1000x is <= 0.1 wall-s, got {d:?}");
    }

    #[test]
    fn infinite_speedup_never_waits() {
        let mut c = WallClock::new(f64::INFINITY);
        assert_eq!(c.remaining(0.0), None);
        assert_eq!(c.remaining(1.0e9), None);
        assert_eq!(c.wait_until(1.0e9), Wait::Elapsed);
    }

    #[test]
    #[should_panic(expected = "speedup must be positive")]
    fn zero_speedup_rejected() {
        WallClock::new(0.0);
    }
}
