//! Streaming arrival sources and completion sinks.
//!
//! [`JobSource`] is the engine-facing shape of a workload that does
//! *not* need to be materialized: a peekable, arrival-ordered stream
//! of jobs.  [`crate::sim::engine::run_streaming`] pulls jobs from a
//! source one burst at a time and pushes completions into a
//! [`CompletionSink`], so steady-state runs of 10⁷+ jobs hold only
//! O(active + late) state — the scheduler's own bookkeeping plus
//! whatever the sink retains (an [`crate::metrics::OnlineMetrics`]
//! accumulator is O(active); the materialized adapters' recorder is
//! O(total) by design, because `SimResult` is).
//!
//! Contract (same as `job::validate`, enforced by construction here
//! and checked by the materialized adapters): arrivals non-decreasing,
//! ids the dense indices 0..n in arrival order, sizes / estimates /
//! weights positive.  Schedulers (dense-indexed heaps, cluster
//! placement tables) rely on dense ids just as the materialized path
//! does.

use super::job::{Completion, Job};

/// An arrival-ordered stream of jobs with a peekable next-arrival
/// time.  `peek_arrival` must be idempotent and consistent with the
/// job a subsequent `next_job` returns.
pub trait JobSource {
    /// Arrival time of the next job, without consuming it.
    fn peek_arrival(&mut self) -> Option<f64>;
    /// Consume and return the next job.
    fn next_job(&mut self) -> Option<Job>;
}

/// Stream over a borrowed, already-materialized workload — the bridge
/// that lets the classic `run(sched, &jobs)` path ride the streaming
/// loop bit-identically.
pub struct SliceSource<'a> {
    jobs: &'a [Job],
    next: usize,
}

impl<'a> SliceSource<'a> {
    pub fn new(jobs: &'a [Job]) -> Self {
        SliceSource { jobs, next: 0 }
    }
}

impl JobSource for SliceSource<'_> {
    fn peek_arrival(&mut self) -> Option<f64> {
        self.jobs.get(self.next).map(|j| j.arrival)
    }
    fn next_job(&mut self) -> Option<Job> {
        let j = self.jobs.get(self.next).copied();
        if j.is_some() {
            self.next += 1;
        }
        j
    }
}

/// Stream over an owned workload (e.g. one repetition's synthesized
/// jobs handed to a metric evaluator that outlives the borrow).
pub struct VecSource {
    jobs: Vec<Job>,
    next: usize,
}

impl VecSource {
    pub fn new(jobs: Vec<Job>) -> Self {
        VecSource { jobs, next: 0 }
    }
}

impl JobSource for VecSource {
    fn peek_arrival(&mut self) -> Option<f64> {
        self.jobs.get(self.next).map(|j| j.arrival)
    }
    fn next_job(&mut self) -> Option<Job> {
        let j = self.jobs.get(self.next).copied();
        if j.is_some() {
            self.next += 1;
        }
        j
    }
}

/// Receives the engine's arrival and completion events as they happen.
/// `on_arrival` fires just before the scheduler sees the job (so a
/// sink can record arrival/size for later sojourn computation);
/// `on_completion` fires once per real completion with the
/// completion's own time (not the event-merge time — the same instant
/// the materialized path records).
pub trait CompletionSink {
    fn on_arrival(&mut self, _now: f64, _job: &Job) {}
    fn on_completion(&mut self, time: f64, c: &Completion);
}

/// Sink that ignores everything — for throughput benches where only
/// the engine + scheduler cost is of interest.
pub struct NullSink;

impl CompletionSink for NullSink {
    fn on_completion(&mut self, _time: f64, _c: &Completion) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_source_streams_in_order() {
        let jobs = vec![Job::exact(0, 0.0, 1.0), Job::exact(1, 2.0, 1.0)];
        let mut s = SliceSource::new(&jobs);
        assert_eq!(s.peek_arrival(), Some(0.0));
        assert_eq!(s.peek_arrival(), Some(0.0), "peek is idempotent");
        assert_eq!(s.next_job().unwrap().id, 0);
        assert_eq!(s.peek_arrival(), Some(2.0));
        assert_eq!(s.next_job().unwrap().id, 1);
        assert_eq!(s.peek_arrival(), None);
        assert!(s.next_job().is_none());
    }

    #[test]
    fn vec_source_matches_slice_source() {
        let jobs = vec![Job::exact(0, 0.5, 1.0), Job::exact(1, 0.5, 2.0)];
        let mut v = VecSource::new(jobs.clone());
        let mut s = SliceSource::new(&jobs);
        loop {
            assert_eq!(v.peek_arrival(), s.peek_arrival());
            match (v.next_job(), s.next_job()) {
                (None, None) => break,
                (a, b) => assert_eq!(a, b),
            }
        }
    }
}
