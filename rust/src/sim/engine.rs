//! The event loop: merge the (time-sorted) arrival stream with the
//! scheduler's internal event stream.
//!
//! Invariants the loop maintains:
//! * state is advanced monotonically — `advance(now, t)` is only called
//!   with `now <= t <=` the scheduler's own `next_event`;
//! * at equal timestamps, internal events (completions) are processed
//!   before arrivals, matching the paper's simulator semantics (a job
//!   finishing exactly when another arrives does not see it);
//! * the loop terminates: every internal event either completes a job
//!   or strictly reduces pending internal work;
//! * every arrival at one timestamp is delivered as a single
//!   [`Scheduler::on_arrival_batch`] burst (default body: the per-id
//!   loop), so the dynamic-dispatch cost is per burst, not per job —
//!   and each job's fields live once, in the engine-owned [`JobStore`],
//!   whose completed prefix is retired to keep memory O(active).

use super::clock::{Clock, VirtualClock, Wait};
use super::job::{Completion, Job};
use super::source::{CompletionSink, JobSource, NullSink, SliceSource};
use super::store::JobStore;
use super::Scheduler;

/// Outcome of one simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Completion time per job id (same indexing as the workload).
    pub completion: Vec<f64>,
    /// Number of internal scheduler events processed (profiling).
    pub events: u64,
}

impl SimResult {
    /// Sojourn times (completion - arrival), paired with the workload.
    pub fn sojourns(&self, jobs: &[Job]) -> Vec<f64> {
        jobs.iter().map(|j| self.completion[j.id as usize] - j.arrival).collect()
    }

    /// Mean sojourn time (MST), the paper's headline metric.
    pub fn mst(&self, jobs: &[Job]) -> f64 {
        self.sojourns(jobs).iter().sum::<f64>() / jobs.len().max(1) as f64
    }

    /// Per-job slowdowns (sojourn / true size).
    pub fn slowdowns(&self, jobs: &[Job]) -> Vec<f64> {
        jobs.iter().map(|j| j.slowdown(self.completion[j.id as usize])).collect()
    }

    /// Number of jobs that actually completed (lost jobs from
    /// [`run_to_drain`] keep `NaN` completion times).
    pub fn completed(&self) -> usize {
        self.completion.iter().filter(|c| c.is_finite()).count()
    }

    /// Mean sojourn over *completed* jobs only — the survivor MST of a
    /// fault run.  Identical to [`SimResult::mst`] (same summation
    /// order) when nothing was lost.
    pub fn mst_completed(&self, jobs: &[Job]) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for j in jobs {
            let c = self.completion[j.id as usize];
            if c.is_finite() {
                sum += c - j.arrival;
                n += 1;
            }
        }
        sum / n.max(1) as f64
    }
}

/// Run `sched` over `jobs` (sorted by arrival; see `job::validate`).
pub fn run(sched: &mut dyn Scheduler, jobs: &[Job]) -> SimResult {
    run_inner(sched, jobs, &mut NullSink, true)
}

/// Like [`run`], but tolerant of jobs that never complete: fault
/// injection can drop a job after exhausting its retries, so the loop
/// simply ends when both event streams dry up and lost jobs keep `NaN`
/// completion times.  Fault-free schedulers behave exactly as under
/// [`run`] — the stepping code is shared.
pub fn run_to_drain(sched: &mut dyn Scheduler, jobs: &[Job]) -> SimResult {
    run_inner(sched, jobs, &mut NullSink, false)
}

/// Like [`run`], forwarding every arrival and completion to `sink` as
/// it happens — [`CompletionSink`] is the single completion-consumption
/// API (the former closure-observer adapter folded into it).  The
/// returned [`SimResult`] is bit-identical to [`run`]'s.
pub fn run_with_sink(
    sched: &mut dyn Scheduler,
    jobs: &[Job],
    sink: &mut dyn CompletionSink,
) -> SimResult {
    run_inner(sched, jobs, sink, true)
}

/// Counters from one streaming run (there is no per-job `completion`
/// vector — that is the whole point; per-job outcomes flow through the
/// sink as they happen).
#[derive(Debug, Clone, Copy, Default)]
pub struct StreamStats {
    /// Jobs pulled from the source and delivered to the scheduler.
    pub delivered: u64,
    /// Real completions observed.
    pub completed: u64,
    /// Internal scheduler events processed (profiling — same counter
    /// as [`SimResult::events`], bit-identical on the same workload).
    pub events: u64,
}

/// Run `sched` over a streaming arrival `source`, pushing every
/// completion into `sink`.  Memory is O(active + late) plus whatever
/// the sink keeps: the engine-owned [`JobStore`] retires its completed
/// prefix as the run progresses.  On a materialized workload this loop
/// is *the same loop* as [`run`] — `run`/`run_to_drain`/
/// [`run_with_sink`] are thin adapters over it (a [`SliceSource`] plus
/// a completion-recording sink), so the two paths cannot drift apart.
pub fn run_streaming(
    sched: &mut dyn Scheduler,
    source: &mut dyn JobSource,
    sink: &mut dyn CompletionSink,
) -> StreamStats {
    stream_inner(sched, source, sink, &mut VirtualClock, true)
}

/// Streaming analogue of [`run_to_drain`]: tolerates jobs that never
/// complete (fault injection), ending when both event streams dry up.
pub fn run_streaming_to_drain(
    sched: &mut dyn Scheduler,
    source: &mut dyn JobSource,
    sink: &mut dyn CompletionSink,
) -> StreamStats {
    stream_inner(sched, source, sink, &mut VirtualClock, false)
}

/// The clock-generic streaming entry point: [`run_streaming_to_drain`]
/// with an explicit [`Clock`] deciding what happens *between* events —
/// real-time pacing, idle parking, control handling (see
/// [`crate::sim::clock`]).  With a [`VirtualClock`] this is exactly
/// `run_streaming` (`require_all = true`) / `run_streaming_to_drain`
/// (`require_all = false`), bit for bit — pinned across the policy zoo
/// by `rust/tests/streaming.rs`.  `psbs serve` drives this with a
/// live, wall-paced clock.
pub fn run_streaming_clocked(
    sched: &mut dyn Scheduler,
    source: &mut dyn JobSource,
    sink: &mut dyn CompletionSink,
    clock: &mut dyn Clock,
    require_all: bool,
) -> StreamStats {
    stream_inner(sched, source, sink, clock, require_all)
}

/// The one event loop.  Generic (not `dyn`) over source, sink and
/// clock so the materialized adapters monomorphize to exactly the
/// direct code they replaced ([`VirtualClock`]'s hooks are constants,
/// so the classic paths compile to the pre-clock loop bit-identically);
/// the public streaming entry points instantiate it with trait
/// objects.
///
/// The loop owns the [`JobStore`]: jobs are pushed as the source
/// yields them, every arrival at one timestamp is handed to the
/// scheduler as a single `on_arrival_batch` burst, completions flip
/// the store's state ledger, and the completed prefix is retired so a
/// 10^6-job streaming run holds O(active) rows.
fn stream_inner<S, K, C>(
    sched: &mut dyn Scheduler,
    source: &mut S,
    sink: &mut K,
    clock: &mut C,
    require_all: bool,
) -> StreamStats
where
    S: JobSource + ?Sized,
    K: CompletionSink + ?Sized,
    C: Clock + ?Sized,
{
    let mut store = JobStore::new();
    let mut done: Vec<Completion> = Vec::with_capacity(16);
    let mut now = 0.0_f64;
    let mut events: u64 = 0;
    let mut delivered: u64 = 0;
    let mut completed: u64 = 0;

    loop {
        // Service hook: a live clock applies control requests (kills,
        // stats, shutdown) here, between steps, with the scheduler and
        // store coherent at `now`.
        if !clock.on_step(now, sched, &mut store) {
            break;
        }
        let next_arrival = source.peek_arrival();
        let next_internal = sched.next_event(now);

        let (t, is_arrival) = match (next_arrival, next_internal) {
            // Both streams dry: over for a closed workload; a live
            // clock parks here until more work arrives over the wire.
            (None, None) => {
                if clock.wait_idle() {
                    continue;
                } else {
                    break;
                }
            }
            (Some(a), None) => (a, true),
            (None, Some(e)) => (e, false),
            // Completions first at ties.
            (Some(a), Some(e)) => {
                if e <= a {
                    (e, false)
                } else {
                    (a, true)
                }
            }
        };
        // Guard against schedulers that report a past event (would
        // otherwise livelock): clamp to `now`.
        let t = t.max(now);

        // Pacing point: a wall clock blocks here until the event is
        // due.  An interrupted wait means the merge inputs changed
        // (new arrival or control request landed while sleeping) —
        // re-plan from the top instead of advancing to a stale `t`.
        if let Wait::Interrupted = clock.wait_until(t) {
            continue;
        }

        done.clear();
        sched.advance(now, t, &store, &mut done);
        for c in &done {
            completed += 1;
            store.mark_completed(c.id);
            // The completion's own time, not the event-merge time `t`:
            // schedulers may report completions that landed strictly
            // inside [now, t] (chained sub-EPS completions, composite
            // schedulers crossing several internal events) — the sink
            // must see the same instant the recorded results use.
            sink.on_completion(c.time, c);
        }
        if !done.is_empty() {
            store.retire();
        }

        now = t;
        if is_arrival {
            // Pull every arrival at exactly this time into the store,
            // then deliver the whole burst in ONE scheduler call.
            let first = store.next_id();
            while matches!(source.peek_arrival(), Some(a) if a <= now) {
                let job = source.next_job().expect("peeked an arrival but the source is empty");
                sink.on_arrival(now, &job);
                store.push(&job);
                delivered += 1;
            }
            sched.on_arrival_batch(now, first..store.next_id(), &store);
        } else {
            events += 1;
            // An internal event with no completion must still make
            // progress (e.g. LAS regroup, virtual completion); the
            // scheduler's next_event must eventually advance. A cheap
            // sanity check: we cannot process more internal events than
            // a generous bound without completing anything.  Fault
            // injection legitimately multiplies events (crashes,
            // recoveries, retries, speculation deadlines), so the
            // drain-mode bound is far looser.
            debug_assert!(
                events < if require_all { 64 } else { 4096 } * (delivered + 4) * 4,
                "internal event storm: {} events, {} completed",
                events,
                completed
            );
        }

        // Equivalent to the classic `completed == jobs.len() &&
        // next_job == jobs.len()`: the source is dry exactly when all
        // n jobs were delivered, and then completed == delivered ⟺
        // completed == n.  A live source is never "dry", only
        // momentarily empty — the `live()` check both keeps the run
        // going and short-circuits ahead of a peek that may block.
        if completed == delivered && !clock.live() && source.peek_arrival().is_none() {
            break;
        }
    }

    if require_all {
        debug_assert_eq!(completed, delivered, "not all jobs completed");
    }
    StreamStats { delivered, completed, events }
}

/// Sink backing the materialized adapters: records each completion
/// time into the dense per-id vector and forwards both callbacks to
/// the caller's sink.
struct Recorder<'a> {
    completion: &'a mut [f64],
    inner: &'a mut dyn CompletionSink,
}

impl CompletionSink for Recorder<'_> {
    fn on_arrival(&mut self, now: f64, job: &Job) {
        self.inner.on_arrival(now, job);
    }

    fn on_completion(&mut self, time: f64, c: &Completion) {
        debug_assert!(self.completion[c.id as usize].is_nan(), "job {} completed twice", c.id);
        self.completion[c.id as usize] = c.time;
        self.inner.on_completion(time, c);
    }
}

fn run_inner(
    sched: &mut dyn Scheduler,
    jobs: &[Job],
    sink: &mut dyn CompletionSink,
    require_all: bool,
) -> SimResult {
    // The recorder indexes `completion[c.id]` and the slice source
    // walks `jobs` as a time-ordered stream: ids that aren't the dense
    // indices 0..n or out-of-order arrivals would silently corrupt
    // results (wrong slots overwritten, arrivals delivered at the
    // wrong times).  Fail fast in debug builds via the shared
    // workload validator.
    #[cfg(debug_assertions)]
    super::job::validate(jobs);

    let mut completion = vec![f64::NAN; jobs.len()];
    let mut source = SliceSource::new(jobs);
    let mut rec = Recorder { completion: &mut completion, inner: sink };
    let stats = stream_inner(sched, &mut source, &mut rec, &mut VirtualClock, require_all);
    if require_all {
        debug_assert_eq!(stats.completed as usize, jobs.len(), "not all jobs completed");
    }
    SimResult { completion, events: stats.events }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::store::JobId;

    /// Trivial serial FIFO used to test the engine contract itself.
    struct SerialFifo {
        queue: std::collections::VecDeque<(u32, f64)>,
    }

    impl Scheduler for SerialFifo {
        fn name(&self) -> &'static str {
            "test-fifo"
        }
        fn on_arrival(&mut self, _now: f64, id: JobId, store: &JobStore) {
            self.queue.push_back((id, store.size(id)));
        }
        fn next_event(&self, now: f64) -> Option<f64> {
            self.queue.front().map(|(_, rem)| now + rem)
        }
        fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
            let mut dt = t - now;
            while let Some((id, rem)) = self.queue.front_mut() {
                if *rem <= dt + crate::util::EPS {
                    dt -= *rem;
                    let id = *id;
                    self.queue.pop_front();
                    done.push(Completion { id, time: t - dt });
                } else {
                    *rem -= dt;
                    break;
                }
            }
        }
        fn active(&self) -> usize {
            self.queue.len()
        }
    }

    #[test]
    fn engine_runs_serial_fifo() {
        let jobs = vec![
            Job::exact(0, 0.0, 2.0),
            Job::exact(1, 1.0, 1.0),
            Job::exact(2, 10.0, 3.0),
        ];
        let mut s = SerialFifo { queue: Default::default() };
        let r = run(&mut s, &jobs);
        assert_eq!(r.completion, vec![2.0, 3.0, 13.0]);
        assert!((r.mst(&jobs) - (2.0 + 2.0 + 3.0) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn engine_handles_simultaneous_arrivals() {
        let jobs = vec![
            Job::exact(0, 1.0, 1.0),
            Job::exact(1, 1.0, 1.0),
            Job::exact(2, 1.0, 1.0),
        ];
        let mut s = SerialFifo { queue: Default::default() };
        let r = run(&mut s, &jobs);
        assert_eq!(r.completion, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn engine_idle_gap_then_arrival() {
        let jobs = vec![Job::exact(0, 0.0, 1.0), Job::exact(1, 100.0, 1.0)];
        let mut s = SerialFifo { queue: Default::default() };
        let r = run(&mut s, &jobs);
        assert_eq!(r.completion, vec![1.0, 101.0]);
    }

    /// The unsorted-input failure mode is caught upfront (debug
    /// builds), not silently folded into corrupted completion times.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "must be sorted")]
    fn engine_rejects_unsorted_arrivals() {
        let jobs = vec![Job::exact(0, 1.0, 1.0), Job::exact(1, 0.5, 1.0)];
        let mut s = SerialFifo { queue: Default::default() };
        run(&mut s, &jobs);
    }

    /// Ids must be the dense indices 0..n: `completion[c.id]` indexing
    /// would otherwise write the wrong slots (or panic late, mid-run).
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "dense indices")]
    fn engine_rejects_non_dense_ids() {
        let jobs = vec![Job::exact(0, 0.0, 1.0), Job::exact(5, 1.0, 1.0)];
        let mut s = SerialFifo { queue: Default::default() };
        run(&mut s, &jobs);
    }

    /// Counting sink for the sink-forwarding adapters.
    struct CountSink {
        arrivals: usize,
        completions: usize,
    }

    impl CompletionSink for CountSink {
        fn on_arrival(&mut self, _now: f64, _job: &Job) {
            self.arrivals += 1;
        }
        fn on_completion(&mut self, _time: f64, _c: &Completion) {
            self.completions += 1;
        }
    }

    #[test]
    fn sink_sees_every_arrival_and_completion() {
        let jobs: Vec<Job> = (0..10).map(|i| Job::exact(i, i as f64 * 0.1, 0.5)).collect();
        let mut s = SerialFifo { queue: Default::default() };
        let mut sink = CountSink { arrivals: 0, completions: 0 };
        let r = run_with_sink(&mut s, &jobs, &mut sink);
        assert_eq!(sink.arrivals, 10);
        assert_eq!(sink.completions, 10);
        assert_eq!(r.completed(), 10);
    }

    /// `run_with_sink` is `run` plus a tap: identical results, bitwise.
    #[test]
    fn run_with_sink_matches_run_bitwise() {
        let jobs: Vec<Job> = (0..50).map(|i| Job::exact(i, i as f64 * 0.3, 1.7)).collect();
        let mut a = SerialFifo { queue: Default::default() };
        let want = run(&mut a, &jobs);
        let mut b = SerialFifo { queue: Default::default() };
        let mut sink = CountSink { arrivals: 0, completions: 0 };
        let got = run_with_sink(&mut b, &jobs, &mut sink);
        assert_eq!(want.events, got.events);
        let wb: Vec<u64> = want.completion.iter().map(|c| c.to_bits()).collect();
        let gb: Vec<u64> = got.completion.iter().map(|c| c.to_bits()).collect();
        assert_eq!(wb, gb);
    }

    /// A FIFO that batches: `next_event` reports only the time its
    /// whole backlog drains, and `advance` emits each completion at its
    /// true (mid-interval) instant — the composite-scheduler shape
    /// (e.g. `Cluster`) where a single engine step crosses several
    /// internal completions.
    struct BatchingFifo {
        queue: std::collections::VecDeque<(u32, f64)>,
    }

    impl Scheduler for BatchingFifo {
        fn name(&self) -> &'static str {
            "test-batching-fifo"
        }
        fn on_arrival(&mut self, _now: f64, id: JobId, store: &JobStore) {
            self.queue.push_back((id, store.size(id)));
        }
        fn next_event(&self, now: f64) -> Option<f64> {
            if self.queue.is_empty() {
                return None;
            }
            Some(now + self.queue.iter().map(|(_, r)| r).sum::<f64>())
        }
        fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
            let mut dt = t - now;
            let mut at = now;
            while let Some((id, rem)) = self.queue.front_mut() {
                if *rem <= dt + crate::util::EPS {
                    dt -= *rem;
                    at += *rem;
                    let id = *id;
                    self.queue.pop_front();
                    done.push(Completion { id, time: at });
                } else {
                    *rem -= dt;
                    break;
                }
            }
        }
        fn active(&self) -> usize {
            self.queue.len()
        }
    }

    /// Recording sink used by the completion-time pin below.
    struct TimesSink {
        observed: Vec<(f64, u32, f64)>,
    }

    impl CompletionSink for TimesSink {
        fn on_completion(&mut self, time: f64, c: &Completion) {
            self.observed.push((time, c.id, c.time));
        }
    }

    /// The sink must receive each completion's own `c.time`, not the
    /// event-merge time `t` — they differ when a completion lands
    /// mid-interval (this pins the PR-6 engine bugfix).
    #[test]
    fn sink_gets_completion_time_not_merge_time() {
        let jobs = vec![
            Job::exact(0, 0.0, 1.0),
            Job::exact(1, 0.0, 2.0),
            Job::exact(2, 0.0, 3.0),
        ];
        let mut s = BatchingFifo { queue: Default::default() };
        let mut sink = TimesSink { observed: Vec::new() };
        let r = run_with_sink(&mut s, &jobs, &mut sink);
        // Completions land at 1, 3, 6 inside ONE engine step ending at 6.
        assert_eq!(r.completion, vec![1.0, 3.0, 6.0]);
        assert_eq!(sink.observed.len(), 3);
        for (time, id, ctime) in sink.observed {
            assert_eq!(
                time, ctime,
                "sink for job {id} got merge time {time}, completion time {ctime}"
            );
        }
    }

    /// Collects completions for streaming-vs-materialized comparisons.
    struct CollectSink {
        seen: Vec<(u32, f64)>,
    }

    impl crate::sim::source::CompletionSink for CollectSink {
        fn on_completion(&mut self, _time: f64, c: &Completion) {
            self.seen.push((c.id, c.time));
        }
    }

    /// `run_streaming` over a slice source is the same loop as `run`:
    /// identical completions (bitwise), identical event counter.
    #[test]
    fn streaming_matches_run_bitwise() {
        let jobs = vec![
            Job::exact(0, 0.0, 2.0),
            Job::exact(1, 1.0, 1.0),
            Job::exact(2, 1.0, 0.5),
            Job::exact(3, 10.0, 3.0),
        ];
        let mut a = SerialFifo { queue: Default::default() };
        let r = run(&mut a, &jobs);

        let mut b = SerialFifo { queue: Default::default() };
        let mut src = SliceSource::new(&jobs);
        let mut sink = CollectSink { seen: Vec::new() };
        let stats = run_streaming(&mut b, &mut src, &mut sink);

        assert_eq!(stats.delivered, jobs.len() as u64);
        assert_eq!(stats.completed, jobs.len() as u64);
        assert_eq!(stats.events, r.events);
        for (id, time) in sink.seen {
            assert_eq!(r.completion[id as usize].to_bits(), time.to_bits());
        }
    }

    /// A discipline that *counts* how it is called: the engine must
    /// coalesce every same-instant arrival group into exactly one
    /// batch call, and the default batch body must deliver per id in
    /// order.
    struct BatchProbe {
        inner: SerialFifo,
        batches: Vec<usize>,
        per_id: Vec<u32>,
    }

    impl Scheduler for BatchProbe {
        fn name(&self) -> &'static str {
            "batch-probe"
        }
        fn on_arrival(&mut self, now: f64, id: JobId, store: &JobStore) {
            self.per_id.push(id);
            self.inner.on_arrival(now, id, store);
        }
        fn on_arrival_batch(&mut self, now: f64, ids: std::ops::Range<JobId>, store: &JobStore) {
            self.batches.push(ids.len());
            for id in ids {
                self.on_arrival(now, id, store);
            }
        }
        fn next_event(&self, now: f64) -> Option<f64> {
            self.inner.next_event(now)
        }
        fn advance(&mut self, now: f64, t: f64, store: &JobStore, done: &mut Vec<Completion>) {
            self.inner.advance(now, t, store, done)
        }
        fn active(&self) -> usize {
            self.inner.active()
        }
    }

    #[test]
    fn same_instant_arrivals_coalesce_into_one_batch() {
        // Bursts of 3 at t=0, 2 at t=5 (while work is still pending),
        // 1 at t=100 (after an idle gap).
        let jobs = vec![
            Job::exact(0, 0.0, 4.0),
            Job::exact(1, 0.0, 4.0),
            Job::exact(2, 0.0, 4.0),
            Job::exact(3, 5.0, 1.0),
            Job::exact(4, 5.0, 1.0),
            Job::exact(5, 100.0, 1.0),
        ];
        let mut s = BatchProbe {
            inner: SerialFifo { queue: Default::default() },
            batches: Vec::new(),
            per_id: Vec::new(),
        };
        let r = run(&mut s, &jobs);
        assert_eq!(s.batches, vec![3, 2, 1], "one batch call per same-instant group");
        assert_eq!(s.per_id, vec![0, 1, 2, 3, 4, 5], "default body delivers in id order");
        assert_eq!(r.completed(), 6);
    }
}
