//! Struct-of-arrays job store — the shared job-field memory behind the
//! engine, every discipline in [`crate::sched`] and the coordinator
//! layer.
//!
//! Job ids are already dense (the workload validator enforces it), so
//! instead of copying five-field [`Job`] structs into every layer, the
//! engine owns one [`JobStore`] of parallel columns (`arrival`, `size`,
//! `est`, `weight` plus the engine-owned `attained`/`state` ledger) and
//! schedulers borrow it: [`crate::sim::Scheduler::on_arrival`] receives
//! `(id, &JobStore)` and reads exactly the fields it keys its heaps on,
//! straight from the SoA slices.  Completed work leaves the store via
//! prefix retirement + compaction, which is what keeps the streaming
//! engine's memory O(active) on million-job runs.
//!
//! Two access disciplines share the type:
//!
//! * **Engine stores** (the event loop, `Service`) push ids densely
//!   from 0 and retire any non-`Active` prefix ([`JobStore::retire`]) —
//!   an id is never delivered twice, so a completed *or* cancelled row
//!   can be reclaimed.
//! * **Overlay stores** (the `est(...)` estimator wrapper) see an
//!   arbitrary subsequence of the global id space (per-server inside a
//!   cluster) and may legitimately see an id *again* (crash
//!   re-dispatch).  They write through [`JobStore::upsert`] (gap rows
//!   are inert `Cancelled` placeholders) and reclaim only completed
//!   prefixes ([`JobStore::retire_completed`]) — a completed id can
//!   never return, so compaction below `base` is always safe.

use super::job::Job;
use super::Scheduler;

/// Dense job identifier: a row index into the [`JobStore`] columns
/// (the same value as [`Job::id`]).
pub type JobId = u32;

/// Lifecycle of a stored job.  Owned by whoever owns the store (the
/// engine, `Service`, an estimator overlay) — schedulers only read it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Delivered and not yet finished.
    Active,
    /// Really completed; `attained` is finalized to the full size.
    Completed,
    /// Killed/cancelled before completing (also the inert placeholder
    /// state of overlay gap rows).
    Cancelled,
}

/// The struct-of-arrays job table.  See the module docs for the two
/// access disciplines (dense engine stores vs sparse overlays).
#[derive(Debug, Default)]
pub struct JobStore {
    /// Id of column row 0; rows below `base` were compacted away.
    base: u32,
    /// Leading rows `< head` are retired but not yet compacted.
    head: usize,
    arrival: Vec<f64>,
    size: Vec<f64>,
    est: Vec<f64>,
    weight: Vec<f64>,
    /// Engine-owned attained-service ledger, finalized at completion
    /// granularity (`mark_completed` sets it to the full size; the
    /// fine-grained within-run attained lives in each discipline).
    attained: Vec<f64>,
    state: Vec<JobState>,
}

/// Compact once the retired prefix is both non-trivial and at least
/// half the table — amortized O(1) per retired row.
const COMPACT_MIN: usize = 32;

impl JobStore {
    pub fn new() -> JobStore {
        JobStore::default()
    }

    /// Bulk-load a materialized workload (dense ids from 0, as
    /// `job::validate` enforces).
    pub fn of(jobs: &[Job]) -> JobStore {
        let mut s = JobStore::new();
        for j in jobs {
            s.push(j);
        }
        s
    }

    /// The next dense id ([`JobStore::push`] requires exactly this id).
    #[inline]
    pub fn next_id(&self) -> JobId {
        self.base + self.state.len() as u32
    }

    /// Rows currently held (retired-but-uncompacted included).
    #[inline]
    pub fn rows(&self) -> usize {
        self.state.len()
    }

    #[inline]
    fn idx(&self, id: JobId) -> usize {
        debug_assert!(
            id >= self.base && id < self.next_id(),
            "job {id} outside store rows {}..{}",
            self.base,
            self.next_id()
        );
        (id - self.base) as usize
    }

    /// Append the next dense row.  Panics if `job.id` is not the next
    /// dense id — the same "job ids must be dense indices" contract the
    /// workload validator enforces up front.
    pub fn push(&mut self, job: &Job) -> JobId {
        assert_eq!(
            job.id,
            self.next_id(),
            "job ids must be dense indices (expected {}, got {})",
            self.next_id(),
            job.id
        );
        self.arrival.push(job.arrival);
        self.size.push(job.size);
        self.est.push(job.est);
        self.weight.push(job.weight);
        self.attained.push(0.0);
        self.state.push(JobState::Active);
        job.id
    }

    /// Insert or overwrite a row by id (overlay stores: sparse id
    /// subsequences, crash re-dispatch re-arrivals).  Gap rows are
    /// filled with inert `Cancelled` placeholders that are never
    /// retired by [`JobStore::retire_completed`] and never read by an
    /// inner scheduler (inners only see ids delivered through the
    /// overlay).
    pub fn upsert(&mut self, job: &Job) {
        assert!(
            job.id >= self.base,
            "job {} re-arrived below store base {} (compacted row)",
            job.id,
            self.base
        );
        let i = (job.id - self.base) as usize;
        while self.state.len() <= i {
            self.arrival.push(0.0);
            self.size.push(1.0);
            self.est.push(1.0);
            self.weight.push(1.0);
            self.attained.push(0.0);
            self.state.push(JobState::Cancelled);
        }
        self.arrival[i] = job.arrival;
        self.size[i] = job.size;
        self.est[i] = job.est;
        self.weight[i] = job.weight;
        self.attained[i] = 0.0;
        self.state[i] = JobState::Active;
    }

    #[inline]
    pub fn arrival(&self, id: JobId) -> f64 {
        self.arrival[self.idx(id)]
    }

    #[inline]
    pub fn size(&self, id: JobId) -> f64 {
        self.size[self.idx(id)]
    }

    #[inline]
    pub fn est(&self, id: JobId) -> f64 {
        self.est[self.idx(id)]
    }

    #[inline]
    pub fn weight(&self, id: JobId) -> f64 {
        self.weight[self.idx(id)]
    }

    #[inline]
    pub fn attained(&self, id: JobId) -> f64 {
        self.attained[self.idx(id)]
    }

    #[inline]
    pub fn state(&self, id: JobId) -> JobState {
        self.state[self.idx(id)]
    }

    /// Whether `id` names a live (delivered, not yet completed or
    /// cancelled) row.  Unlike [`JobStore::state`], this is total over
    /// the whole id space: ids below `base` (compacted away — they
    /// were necessarily non-`Active`) and ids not yet pushed are
    /// simply `false`, never a panic.  The `psbs serve` kill path
    /// validates untrusted wire ids with this before touching the row.
    #[inline]
    pub fn is_active(&self, id: JobId) -> bool {
        id >= self.base && id < self.next_id() && self.state[(id - self.base) as usize] == JobState::Active
    }

    /// Reassemble the flat [`Job`] for one row (compatibility edges:
    /// sinks, tests).
    pub fn job(&self, id: JobId) -> Job {
        let i = self.idx(id);
        Job {
            id,
            arrival: self.arrival[i],
            size: self.size[i],
            est: self.est[i],
            weight: self.weight[i],
        }
    }

    /// Overwrite one row's size estimate (estimator overlays).
    pub fn set_est(&mut self, id: JobId, est: f64) {
        let i = self.idx(id);
        self.est[i] = est;
    }

    /// Online estimate refinement entry point: overwrite one row's
    /// estimate, clamped so a delivered estimate can never fall below
    /// the attained service already recorded for that row (attained
    /// service is a hard lower bound on true size — arXiv:1403.5996).
    /// Returns the estimate actually stored.  Callers write the store
    /// *before* notifying the scheduler via
    /// [`Scheduler::on_estimate_update`], so the discipline re-keys off
    /// the already-clamped column.
    pub fn update_est(&mut self, id: JobId, est: f64) -> f64 {
        let i = self.idx(id);
        let clamped = est.max(self.attained[i]).max(1e-12);
        self.est[i] = clamped;
        clamped
    }

    /// Record a real completion: state `Completed`, attained finalized
    /// to the full size.
    pub fn mark_completed(&mut self, id: JobId) {
        let i = self.idx(id);
        debug_assert_eq!(self.state[i], JobState::Active, "job {id} completed twice");
        self.attained[i] = self.size[i];
        self.state[i] = JobState::Completed;
    }

    /// Record a kill/cancel (the job never completes).
    pub fn mark_cancelled(&mut self, id: JobId) {
        let i = self.idx(id);
        debug_assert_ne!(self.state[i], JobState::Completed, "cancelling completed job {id}");
        self.state[i] = JobState::Cancelled;
    }

    /// Engine-store retirement: reclaim every leading non-`Active` row
    /// (ids are never delivered twice, so completed *and* cancelled
    /// rows are both dead).  O(active) memory on streaming runs.
    pub fn retire(&mut self) {
        while self.head < self.state.len() && self.state[self.head] != JobState::Active {
            self.head += 1;
        }
        self.maybe_compact();
    }

    /// Overlay-store retirement: reclaim only leading `Completed` rows.
    /// A completed id can never re-arrive, so compacting below `base`
    /// stays safe even under crash re-dispatch; cancelled rows (and gap
    /// placeholders) conservatively pin the prefix.
    pub fn retire_completed(&mut self) {
        while self.head < self.state.len() && self.state[self.head] == JobState::Completed {
            self.head += 1;
        }
        self.maybe_compact();
    }

    fn maybe_compact(&mut self) {
        if self.head > COMPACT_MIN && self.head * 2 >= self.state.len() {
            self.arrival.drain(..self.head);
            self.size.drain(..self.head);
            self.est.drain(..self.head);
            self.weight.drain(..self.head);
            self.attained.drain(..self.head);
            self.state.drain(..self.head);
            self.base += self.head as u32;
            self.head = 0;
        }
    }

    /// Push `job` and deliver it to `sched` in one call — the
    /// unit-test/bench convenience mirroring the old
    /// `on_arrival(now, &job)` shape.
    pub fn deliver(&mut self, sched: &mut dyn Scheduler, now: f64, job: &Job) {
        let id = self.push(job);
        sched.on_arrival(now, id, self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_reads_back_all_columns() {
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 2.0, est: 1.5, weight: 2.0 },
            Job { id: 1, arrival: 1.0, size: 3.0, est: 3.0, weight: 1.0 },
        ];
        let st = JobStore::of(&jobs);
        assert_eq!(st.next_id(), 2);
        for j in &jobs {
            assert_eq!(st.arrival(j.id).to_bits(), j.arrival.to_bits());
            assert_eq!(st.size(j.id).to_bits(), j.size.to_bits());
            assert_eq!(st.est(j.id).to_bits(), j.est.to_bits());
            assert_eq!(st.weight(j.id).to_bits(), j.weight.to_bits());
            assert_eq!(st.state(j.id), JobState::Active);
            assert_eq!(st.attained(j.id), 0.0);
            assert_eq!(st.job(j.id), *j);
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "dense indices")]
    fn push_rejects_non_dense_ids() {
        let mut st = JobStore::new();
        st.push(&Job::exact(0, 0.0, 1.0));
        st.push(&Job::exact(5, 1.0, 1.0));
    }

    #[test]
    fn completion_finalizes_attained() {
        let mut st = JobStore::of(&[Job::exact(0, 0.0, 4.0)]);
        st.mark_completed(0);
        assert_eq!(st.state(0), JobState::Completed);
        assert_eq!(st.attained(0), 4.0);
    }

    /// Retirement compacts completed prefixes away and keeps reads on
    /// the surviving rows valid (the O(active) streaming claim at the
    /// store level).
    #[test]
    fn retire_compacts_completed_prefix() {
        let mut st = JobStore::new();
        for i in 0..200u32 {
            st.push(&Job::exact(i, i as f64, 1.0));
        }
        for i in 0..150u32 {
            st.mark_completed(i);
        }
        st.retire();
        assert!(st.rows() <= 50, "prefix must compact: {} rows", st.rows());
        assert_eq!(st.next_id(), 200, "ids keep counting past compaction");
        assert_eq!(st.size(180), 1.0);
        assert_eq!(st.arrival(199), 199.0);
        // New pushes continue densely.
        st.push(&Job::exact(200, 300.0, 2.0));
        assert_eq!(st.size(200), 2.0);
    }

    #[test]
    fn retire_stops_at_first_active_row() {
        let mut st = JobStore::of(&[
            Job::exact(0, 0.0, 1.0),
            Job::exact(1, 0.0, 1.0),
            Job::exact(2, 0.0, 1.0),
        ]);
        st.mark_completed(0);
        st.mark_cancelled(2); // non-prefix: must not retire
        st.retire();
        assert_eq!(st.state(1), JobState::Active);
        assert_eq!(st.state(2), JobState::Cancelled);
        assert_eq!(st.rows(), 3, "small prefixes stay uncompacted");
    }

    /// Overlay discipline: sparse upserts gap-fill, re-upsert of a
    /// cancelled (crash re-dispatch) row reactivates it, and
    /// `retire_completed` never reclaims past a non-completed row.
    #[test]
    fn upsert_gap_fills_and_reactivates() {
        let mut st = JobStore::new();
        st.upsert(&Job { id: 3, arrival: 1.0, size: 5.0, est: 4.0, weight: 1.0 });
        assert_eq!(st.state(0), JobState::Cancelled, "gap rows are inert");
        assert_eq!(st.state(3), JobState::Active);
        assert_eq!(st.est(3), 4.0);
        st.mark_cancelled(3);
        st.upsert(&Job { id: 3, arrival: 2.0, size: 5.0, est: 6.5, weight: 1.0 });
        assert_eq!(st.state(3), JobState::Active, "re-dispatch reactivates");
        assert_eq!(st.est(3), 6.5, "re-dispatch overwrites the estimate");
        st.retire_completed();
        assert_eq!(st.rows(), 4, "gap rows pin the prefix");
    }

    /// `is_active` must stay total (no panic, no wrap) across the whole
    /// id space — compacted, live, finished and never-seen ids alike.
    #[test]
    fn is_active_is_total_over_the_id_space() {
        let mut st = JobStore::new();
        for i in 0..200u32 {
            st.push(&Job::exact(i, i as f64, 1.0));
        }
        for i in 0..150u32 {
            st.mark_completed(i);
        }
        st.retire(); // compacts: base moves past the completed prefix
        assert!(!st.is_active(0), "compacted id");
        assert!(!st.is_active(149), "compacted id");
        assert!(st.is_active(150), "live row");
        assert!(st.is_active(199), "live row");
        assert!(!st.is_active(200), "not yet pushed");
        assert!(!st.is_active(u32::MAX), "way out of range");
        st.mark_cancelled(150);
        assert!(!st.is_active(150), "cancelled row");
    }

    #[test]
    fn set_est_only_touches_the_estimate() {
        let mut st = JobStore::of(&[Job { id: 0, arrival: 0.0, size: 2.0, est: 2.0, weight: 3.0 }]);
        st.set_est(0, 9.0);
        assert_eq!(st.est(0), 9.0);
        assert_eq!(st.size(0), 2.0);
        assert_eq!(st.weight(0), 3.0);
    }

    /// `update_est` clamps to attained service (the monotone lower
    /// bound): before any service it only floors at 1e-12, after
    /// completion (attained = size) no update can drop below the size.
    #[test]
    fn update_est_clamps_to_attained() {
        let mut st = JobStore::of(&[Job::exact(0, 0.0, 4.0), Job::exact(1, 0.0, 2.0)]);
        assert_eq!(st.update_est(0, 7.0), 7.0);
        assert_eq!(st.est(0), 7.0);
        assert_eq!(st.update_est(0, -3.0), 1e-12, "floor applies with zero attained");
        st.mark_completed(1); // attained finalized to 2.0
        assert_eq!(st.update_est(1, 0.5), 2.0, "attained is a hard lower bound");
        assert_eq!(st.est(1), 2.0);
        assert_eq!(st.update_est(1, 9.0), 9.0, "raising past attained is free");
    }
}
