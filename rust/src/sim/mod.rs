//! Discrete-event simulation core.
//!
//! * [`Job`] / [`Completion`] — the workload unit and its outcome.
//! * [`JobStore`] — the struct-of-arrays job table (dense ids →
//!   parallel `arrival`/`size`/`est`/`weight` columns plus the
//!   engine-owned `attained`/`state` ledger) shared by the engine,
//!   every scheduler and the coordinator layer.
//! * [`Scheduler`] — the event-driven discipline interface implemented
//!   by every policy in [`crate::sched`]; arrivals are delivered as
//!   `(id, &JobStore)` so disciplines key their heaps straight off the
//!   SoA columns instead of copying `Job`s.
//! * [`engine`] — the event loop merging the arrival stream with each
//!   scheduler's internal event stream; same-timestamp arrival bursts
//!   are coalesced into one [`Scheduler::on_arrival_batch`] call.
//! * [`clock`] — what the loop does *between* events: [`VirtualClock`]
//!   (free virtual time — the simulation, bit-identical to the
//!   pre-clock engine) vs [`WallClock`]-paced live deployments
//!   (`psbs serve`), via [`engine::run_streaming_clocked`].
//! * [`smallstep`] — an independent fixed-step integrator over
//!   allocation functions ω(i,t), used purely as a cross-validation
//!   oracle for the event-driven implementations.

pub mod clock;
pub mod engine;
pub mod job;
pub mod smallstep;
pub mod source;
pub mod store;

pub use clock::{Clock, VirtualClock, Wait, WallClock};
pub use engine::{
    run, run_streaming, run_streaming_clocked, run_streaming_to_drain, run_to_drain,
    run_with_sink, SimResult, StreamStats,
};
pub use job::{Completion, Job};
pub use source::{CompletionSink, JobSource, NullSink, SliceSource, VecSource};
pub use store::{JobId, JobState, JobStore};

/// An event-driven scheduling discipline.
///
/// The engine drives implementations through three calls:
///
/// 1. [`Scheduler::on_arrival`] — job `id` is released at time `now`
///    (the engine has already advanced state to `now`); the job's
///    fields live in the borrowed [`JobStore`].  Same-instant arrival
///    bursts arrive as one [`Scheduler::on_arrival_batch`] call whose
///    default body is the per-id loop, so batching is an engine-side
///    optimization no discipline is forced to implement.
/// 2. [`Scheduler::next_event`] — earliest *future* time (> `now`) at
///    which the scheduler's internal state changes discontinuously
///    (a real completion, a virtual completion, a service-group
///    regroup, a late transition), assuming no further arrivals.
/// 3. [`Scheduler::advance`] — integrate state forward from `now` to
///    `t` (with `t` no later than `next_event`), appending any real
///    completions that occur in `(now, t]`.  The store is borrowed
///    here too: composite schedulers (cluster re-dispatch, speculative
///    copies) read job fields for decisions made mid-advance.
///
/// Store contract: a discipline may read any column of any id it has
/// been delivered and not yet completed/cancelled; it must copy what
/// it needs to outlive that window (the engine retires completed rows
/// to keep streaming memory O(active)).  Work conservation, preemption
/// rules and tie-breaking are entirely the implementation's business;
/// the engine only merges event streams.
///
/// Real-time contract (`psbs serve`): the same three calls drive a
/// *live* deployment through [`engine::run_streaming_clocked`], where
/// `now` advances under wall-clock pacing and arrivals come off a
/// socket instead of a trace.  Nothing changes semantically for a
/// discipline, but two latent assumptions become load-bearing:
///
/// * a job may be delivered with `store.arrival(id) < now` (it crossed
///   the wire late) — disciplines must key off `now` and the store
///   columns, never assume `on_arrival`'s `now` equals the stamped
///   arrival time (none of the zoo does; the engine has always clamped
///   past-due events to `now`);
/// * [`Scheduler::cancel`] may be called between any two engine steps
///   (a live kill request), not just at arrival instants — state must
///   be coherent whenever `advance` returns, which the PR 5 cancel
///   churn tests already pin.
///
/// All calls stay on one thread: the engine never shares a scheduler
/// across threads, so implementations need no synchronization.
pub trait Scheduler {
    /// Discipline name (used in reports and CSV headers).
    fn name(&self) -> &'static str;

    /// Job `id` arrives; its fields are `store` columns.  State has
    /// already been advanced to `now`.
    fn on_arrival(&mut self, now: f64, id: JobId, store: &JobStore);

    /// A dense burst of same-instant arrivals, `ids` in arrival (= id)
    /// order.  The engine coalesces every arrival at one timestamp
    /// into a single call; the default body is the one-by-one loop
    /// (monomorphized per discipline, so the per-job calls are static
    /// dispatch — the virtual-dispatch cost is paid once per burst,
    /// not once per job).  Overriders must deliver in the same order.
    fn on_arrival_batch(&mut self, now: f64, ids: std::ops::Range<JobId>, store: &JobStore) {
        for id in ids {
            self.on_arrival(now, id, store);
        }
    }

    /// Earliest future internal event, or `None` if the scheduler is
    /// idle (no pending real work *and* no pending internal events).
    fn next_event(&self, now: f64) -> Option<f64>;

    /// Advance internal state from `now` to `t >= now`, pushing real
    /// completions (with their exact completion times) onto `done`.
    fn advance(&mut self, now: f64, t: f64, store: &JobStore, done: &mut Vec<Completion>);

    /// Number of jobs released but not yet really completed.
    fn active(&self) -> usize;

    /// Cancel (kill) a pending job: remove it from all bookkeeping
    /// without completing it.  Returns `true` if the job was found and
    /// removed; the default implementation reports the discipline does
    /// not support cancellation.  This is the "additional bookkeeping
    /// ... to handle jobs that complete even when they are not
    /// scheduled (e.g. ... after being killed)" of paper §5.2.2.
    fn cancel(&mut self, _now: f64, _id: u32) -> bool {
        false
    }

    /// The store's estimate for live job `id` changed (online
    /// refinement, a `psbs serve` `update` request).  The caller has
    /// already written the new value through [`JobStore::update_est`]
    /// (clamped ≥ attained service) *before* this call, so the store
    /// column is the source of truth here.  Returns `true` if the
    /// discipline re-keyed the job, `false` if it does not support
    /// estimate updates.
    ///
    /// The default is the universally correct PR 5 path: cancel the job
    /// (O(log n) for the whole zoo) and re-admit it at `now` as a fresh
    /// arrival, which re-reads the est column.  Disciplines whose keys
    /// depend on the estimate override this with a cheaper in-place
    /// re-key **only when bitwise-equal to cancel + re-admit**
    /// (pinned by `rust/tests/online_est.rs`); est-oblivious
    /// disciplines (fifo, ps, las, ...) must keep this default — for
    /// them a no-op would *not* match cancel + re-admit, which legally
    /// moves the job's queue position / resets its attained ledger.
    fn on_estimate_update(&mut self, now: f64, id: JobId, store: &JobStore) -> bool {
        if self.cancel(now, id) {
            self.on_arrival(now, id, store);
            true
        } else {
            false
        }
    }

    /// Fault-side accounting for composite schedulers that inject
    /// failures (crashes, retries, speculative copies — see
    /// [`crate::coordinator::faults`]); `None` for ordinary
    /// disciplines and for fault-free deployments.
    fn fault_stats(&self) -> Option<crate::coordinator::faults::FaultStats> {
        None
    }
}
