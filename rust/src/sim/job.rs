//! The workload unit: a job with true size, estimated size and weight.

/// One job in the single-server preemptive model (§3 of the paper:
/// `1|r_i; pmtn|...`).  Sizes are in service-time units (service rate
/// normalized to 1); `est` is what the scheduler sees, `size` is what
/// the server actually has to do.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Job {
    /// Dense id: index into the workload's job vector.
    pub id: u32,
    /// Release time r_i.
    pub arrival: f64,
    /// True size s_i (> 0).
    pub size: f64,
    /// Estimated size s_hat_i (> 0) — equals `size` for exact-info runs.
    pub est: f64,
    /// Weight w_i (> 0); 1.0 unless the experiment differentiates jobs
    /// (paper §7.6).
    pub weight: f64,
}

impl Job {
    /// Unweighted, exactly-estimated job.
    pub fn exact(id: u32, arrival: f64, size: f64) -> Job {
        Job { id, arrival, size, est: size, weight: 1.0 }
    }

    /// Job with an estimation error multiplier (`est = size * mult`).
    pub fn estimated(id: u32, arrival: f64, size: f64, mult: f64) -> Job {
        Job { id, arrival, size, est: size * mult, weight: 1.0 }
    }

    /// Paper's slowdown for a given completion time.
    pub fn slowdown(&self, completion: f64) -> f64 {
        (completion - self.arrival) / self.size
    }
}

/// A real (not virtual) job completion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Completion {
    pub id: u32,
    pub time: f64,
}

/// Validate a workload: sorted arrivals, positive sizes/weights.
/// Panics with a description on the first violation (workload
/// generators are required to uphold this; traces are sanitized on
/// parse).
pub fn validate(jobs: &[Job]) {
    let mut last = f64::NEG_INFINITY;
    for (i, j) in jobs.iter().enumerate() {
        assert_eq!(j.id as usize, i, "job ids must be dense indices");
        assert!(j.arrival >= last, "arrivals must be sorted (job {i})");
        assert!(j.size > 0.0, "job {i} has non-positive size");
        assert!(j.est > 0.0, "job {i} has non-positive estimate");
        assert!(j.weight > 0.0, "job {i} has non-positive weight");
        last = j.arrival;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slowdown_definition() {
        let j = Job::exact(0, 10.0, 2.0);
        assert_eq!(j.slowdown(14.0), 2.0); // waited 4, size 2
        assert_eq!(j.slowdown(12.0), 1.0); // optimal
    }

    #[test]
    fn estimated_multiplier() {
        let j = Job::estimated(0, 0.0, 4.0, 0.5);
        assert_eq!(j.est, 2.0);
        assert_eq!(j.size, 4.0);
    }

    #[test]
    fn validate_accepts_good_workload() {
        validate(&[
            Job::exact(0, 0.0, 1.0),
            Job::exact(1, 0.5, 2.0),
            Job::exact(2, 0.5, 3.0),
        ]);
    }

    #[test]
    #[should_panic(expected = "sorted")]
    fn validate_rejects_unsorted() {
        validate(&[Job::exact(0, 1.0, 1.0), Job::exact(1, 0.5, 1.0)]);
    }

    #[test]
    #[should_panic(expected = "non-positive size")]
    fn validate_rejects_zero_size() {
        validate(&[Job::exact(0, 0.0, 0.0)]);
    }
}
