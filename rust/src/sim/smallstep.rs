//! Fixed-step reference simulator — the cross-validation oracle.
//!
//! Every discipline is re-expressed here *directly from its paper
//! definition* as an allocation function ω(i, t) over per-job state
//! (attained service, virtual remaining), integrated with a small time
//! step.  The implementations share nothing with the event-driven
//! schedulers in [`crate::sched`], so agreement between the two (see
//! `rust/tests/crossval.rs`) validates the event-driven bookkeeping —
//! heaps, virtual lag, late sets — against the definitions.
//!
//! Accuracy is O(dt); tests use small workloads and compare completion
//! times with a tolerance of a few dt.  This module is **test-only
//! machinery** (never on the measurement path).

use super::job::Job;

/// Disciplines the oracle can integrate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fifo,
    Ps,
    Dps,
    Las,
    /// SRPT over estimates (exact when est == size); a late serving job
    /// (estimated remaining <= 0) is never preempted (§4.2).
    Srpte,
    /// SRPTE, but all late jobs + the best non-late job share via PS (§5.1).
    SrptePs,
    /// SRPTE, but eligible jobs are scheduled via LAS (§5.1).
    SrpteLas,
    /// FSP over estimates: serve jobs in virtual (PS-emulated)
    /// completion order; late jobs (virtually done, really pending)
    /// run serially and block (§4.2).
    Fspe,
    /// FSPE with PS among late jobs (§5.1).
    FspePs,
    /// FSPE with LAS among late jobs (§5.1).
    FspeLas,
    /// PSBS: FSPE+PS generalized with weights — the virtual system is
    /// DPS and late jobs share in proportion to weight (§5.2).
    Psbs,
}

struct St {
    arrival: f64,
    size: f64,
    est: f64,
    weight: f64,
    attained: f64,
    /// Remaining *estimated* work in the virtual system (FSP family).
    virt_rem: f64,
    /// Order in which the job completed virtually (usize::MAX if not yet).
    virt_order: usize,
    done_at: f64,
}

const TOL: f64 = 1e-12;

/// Integrate `policy` over `jobs` with step `dt`; returns completion
/// times by job id.
pub fn simulate(policy: Policy, jobs: &[Job], dt: f64) -> Vec<f64> {
    let mut st: Vec<St> = jobs
        .iter()
        .map(|j| St {
            arrival: j.arrival,
            size: j.size,
            est: j.est,
            weight: j.weight,
            attained: 0.0,
            virt_rem: j.est,
            virt_order: usize::MAX,
            done_at: f64::NAN,
        })
        .collect();

    let uses_virtual = matches!(
        policy,
        Policy::Fspe | Policy::FspePs | Policy::FspeLas | Policy::Psbs
    );
    let mut virt_seq = 0usize;
    let mut t = 0.0_f64;
    let mut remaining = jobs.len();
    let mut alloc: Vec<f64> = vec![0.0; jobs.len()];
    // Hard stop so a buggy policy cannot spin forever: total work is
    // bounded by sum of sizes + last arrival.
    let t_max = jobs.iter().map(|j| j.size).sum::<f64>()
        + jobs.last().map(|j| j.arrival).unwrap_or(0.0)
        + 1.0;

    while remaining > 0 {
        assert!(t < t_max + 1.0, "smallstep exceeded work bound (policy bug)");
        let pending: Vec<usize> = (0..st.len())
            .filter(|&i| st[i].arrival <= t + TOL && st[i].done_at.is_nan())
            .collect();

        // --- virtual system step (FSP family) --------------------------
        if uses_virtual {
            let vpend: Vec<usize> = (0..st.len())
                .filter(|&i| st[i].arrival <= t + TOL && st[i].virt_order == usize::MAX)
                .collect();
            let wsum: f64 = vpend.iter().map(|&i| st[i].weight).sum();
            if wsum > 0.0 {
                for &i in &vpend {
                    st[i].virt_rem -= st[i].weight / wsum * dt;
                }
                // Virtual completions, in deterministic (virt_rem/w, id)
                // order when several cross zero in the same step.
                let mut crossed: Vec<usize> = vpend
                    .iter()
                    .cloned()
                    .filter(|&i| st[i].virt_rem <= TOL)
                    .collect();
                crossed.sort_by(|&a, &b| {
                    (st[a].virt_rem / st[a].weight)
                        .partial_cmp(&(st[b].virt_rem / st[b].weight))
                        .unwrap()
                        .then(a.cmp(&b))
                });
                for i in crossed {
                    st[i].virt_order = virt_seq;
                    virt_seq += 1;
                }
            }
        }

        // --- real allocation -------------------------------------------
        for a in alloc.iter_mut() {
            *a = 0.0;
        }
        if !pending.is_empty() {
            match policy {
                Policy::Fifo => {
                    let i = *pending
                        .iter()
                        .min_by(|&&a, &&b| {
                            st[a].arrival.partial_cmp(&st[b].arrival).unwrap().then(a.cmp(&b))
                        })
                        .unwrap();
                    alloc[i] = 1.0;
                }
                Policy::Ps => {
                    let share = 1.0 / pending.len() as f64;
                    for &i in &pending {
                        alloc[i] = share;
                    }
                }
                Policy::Dps => {
                    let wsum: f64 = pending.iter().map(|&i| st[i].weight).sum();
                    for &i in &pending {
                        alloc[i] = st[i].weight / wsum;
                    }
                }
                Policy::Las => las_alloc(&st, &pending, &mut alloc),
                Policy::Srpte => {
                    let i = srpte_top(&st, &pending);
                    alloc[i] = 1.0;
                }
                Policy::SrptePs | Policy::SrpteLas => {
                    let mut eligible: Vec<usize> = pending
                        .iter()
                        .cloned()
                        .filter(|&i| st[i].est - st[i].attained <= TOL)
                        .collect();
                    // plus the highest-priority non-late job, if any
                    let non_late: Vec<usize> = pending
                        .iter()
                        .cloned()
                        .filter(|&i| st[i].est - st[i].attained > TOL)
                        .collect();
                    if !non_late.is_empty() {
                        eligible.push(srpte_top(&st, &non_late));
                    }
                    if policy == Policy::SrptePs {
                        let share = 1.0 / eligible.len() as f64;
                        for &i in &eligible {
                            alloc[i] = share;
                        }
                    } else {
                        las_alloc(&st, &eligible, &mut alloc);
                    }
                }
                Policy::Fspe | Policy::FspePs | Policy::FspeLas | Policy::Psbs => {
                    let late: Vec<usize> = pending
                        .iter()
                        .cloned()
                        .filter(|&i| st[i].virt_order != usize::MAX)
                        .collect();
                    if late.is_empty() {
                        // Serve the job that completes earliest in the
                        // virtual system: min virt_rem / weight (== g_i
                        // order), ties by id.
                        let i = *pending
                            .iter()
                            .min_by(|&&a, &&b| {
                                (st[a].virt_rem / st[a].weight)
                                    .partial_cmp(&(st[b].virt_rem / st[b].weight))
                                    .unwrap()
                                    .then(a.cmp(&b))
                            })
                            .unwrap();
                        alloc[i] = 1.0;
                    } else {
                        match policy {
                            Policy::Fspe => {
                                // Serial: earliest virtual completion first.
                                let i = *late
                                    .iter()
                                    .min_by_key(|&&i| st[i].virt_order)
                                    .unwrap();
                                alloc[i] = 1.0;
                            }
                            Policy::FspePs => {
                                let share = 1.0 / late.len() as f64;
                                for &i in &late {
                                    alloc[i] = share;
                                }
                            }
                            Policy::FspeLas => las_alloc(&st, &late, &mut alloc),
                            Policy::Psbs => {
                                let wsum: f64 = late.iter().map(|&i| st[i].weight).sum();
                                for &i in &late {
                                    alloc[i] = st[i].weight / wsum;
                                }
                            }
                            _ => unreachable!(),
                        }
                    }
                }
            }

            // Integrate and detect completions (sub-step interpolation).
            for &i in &pending {
                if alloc[i] <= 0.0 {
                    continue;
                }
                let need = st[i].size - st[i].attained;
                let got = alloc[i] * dt;
                if need <= got + TOL {
                    st[i].attained = st[i].size;
                    st[i].done_at = t + need / alloc[i];
                    remaining -= 1;
                } else {
                    st[i].attained += got;
                }
            }
        }

        t += dt;
    }

    st.iter().map(|s| s.done_at).collect()
}

/// LAS among `set`: equal shares for the argmin-attained group.
fn las_alloc(st: &[St], set: &[usize], alloc: &mut [f64]) {
    let min_att = set
        .iter()
        .map(|&i| st[i].attained)
        .fold(f64::INFINITY, f64::min);
    let group: Vec<usize> = set
        .iter()
        .cloned()
        .filter(|&i| st[i].attained <= min_att + 1e-9)
        .collect();
    let share = 1.0 / group.len() as f64;
    for &i in &group {
        alloc[i] = share;
    }
}

/// SRPTE serving choice among `set`: minimum estimated remaining, with
/// late jobs (negative remaining) sorting first — which encodes the
/// "late jobs cannot be preempted" rule of §4.2.
fn srpte_top(st: &[St], set: &[usize]) -> usize {
    *set.iter()
        .min_by(|&&a, &&b| {
            let ka = st[a].est - st[a].attained;
            let kb = st[b].est - st[b].attained;
            ka.partial_cmp(&kb).unwrap().then(st[a].arrival.partial_cmp(&st[b].arrival).unwrap()).then(a.cmp(&b))
        })
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jobs3() -> Vec<Job> {
        vec![
            Job::exact(0, 0.0, 3.0),
            Job::exact(1, 1.0, 1.0),
            Job::exact(2, 1.0, 2.0),
        ]
    }

    #[test]
    fn fifo_matches_hand_computation() {
        let c = simulate(Policy::Fifo, &jobs3(), 1e-4);
        assert!((c[0] - 3.0).abs() < 1e-3);
        assert!((c[1] - 4.0).abs() < 1e-3);
        assert!((c[2] - 6.0).abs() < 1e-3);
    }

    #[test]
    fn srpt_matches_hand_computation() {
        // t=1: rem(0)=2; serve job1 (1), then job2 (2), then job0.
        let c = simulate(Policy::Srpte, &jobs3(), 1e-4);
        assert!((c[1] - 2.0).abs() < 1e-3, "{c:?}");
        assert!((c[2] - 4.0).abs() < 1e-3, "{c:?}");
        assert!((c[0] - 6.0).abs() < 1e-3, "{c:?}");
    }

    #[test]
    fn ps_two_equal_jobs() {
        let jobs = vec![Job::exact(0, 0.0, 1.0), Job::exact(1, 0.0, 1.0)];
        let c = simulate(Policy::Ps, &jobs, 1e-4);
        assert!((c[0] - 2.0).abs() < 1e-3);
        assert!((c[1] - 2.0).abs() < 1e-3);
    }

    #[test]
    fn dps_weighted_shares() {
        // weights 2:1 over equal sizes 1: job0 completes at 1.5
        // (rates 2/3, 1/3); then job1 alone: 1.5 + (1 - 0.5) = 2.0.
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 1.0, est: 1.0, weight: 2.0 },
            Job { id: 1, arrival: 0.0, size: 1.0, est: 1.0, weight: 1.0 },
        ];
        let c = simulate(Policy::Dps, &jobs, 1e-4);
        assert!((c[0] - 1.5).abs() < 1e-3, "{c:?}");
        assert!((c[1] - 2.0).abs() < 1e-3, "{c:?}");
    }

    #[test]
    fn las_serves_youngest() {
        // Job0 size 2 from t=0. Job1 size 1 arrives t=1 with attained 0
        // < job0's 1, so LAS serves job1 exclusively until parity.
        let jobs = vec![Job::exact(0, 0.0, 2.0), Job::exact(1, 1.0, 1.0)];
        let c = simulate(Policy::Las, &jobs, 1e-4);
        // job1 runs alone [1,2] and completes at 2; job0 resumes, completes at 3.
        assert!((c[1] - 2.0).abs() < 1e-3, "{c:?}");
        assert!((c[0] - 3.0).abs() < 1e-3, "{c:?}");
    }

    #[test]
    fn fsp_serial_order_matches_paper_fig2_prefix() {
        // Paper Fig. 2 jobs: sizes 10, 5, 2 at t = 0, 3, 5.
        // FSP real schedule: J1 [0,3), J2 [3,5), J3 [5,7)->done,
        // J2 resumes [7,10)->done, J1 [10,17)->done.
        let jobs = vec![
            Job::exact(0, 0.0, 10.0),
            Job::exact(1, 3.0, 5.0),
            Job::exact(2, 5.0, 2.0),
        ];
        let c = simulate(Policy::Fspe, &jobs, 1e-3);
        assert!((c[2] - 7.0).abs() < 1e-2, "{c:?}");
        assert!((c[1] - 10.0).abs() < 1e-2, "{c:?}");
        assert!((c[0] - 17.0).abs() < 1e-2, "{c:?}");
    }

    #[test]
    fn srpte_late_job_blocks() {
        // Job0 size 4 but estimated 1: becomes late at t=1 and cannot
        // be preempted by job1 (size 1, arrives t=2). Job0 completes at
        // 4, job1 at 5. (Under exact SRPT job1 would preempt.)
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 4.0, est: 1.0, weight: 1.0 },
            Job::exact(1, 2.0, 1.0),
        ];
        let c = simulate(Policy::Srpte, &jobs, 1e-4);
        assert!((c[0] - 4.0).abs() < 1e-3, "{c:?}");
        assert!((c[1] - 5.0).abs() < 1e-3, "{c:?}");
    }

    #[test]
    fn srpte_ps_unblocks_small_jobs() {
        // Same workload: under SRPTE+PS the late job shares with job1:
        // from t=2 both at rate 1/2. Job1 needs 1 unit -> done at 4;
        // job0 has 2 left at t=2, gets 1 by t=4, runs alone after -> 5.
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 4.0, est: 1.0, weight: 1.0 },
            Job::exact(1, 2.0, 1.0),
        ];
        let c = simulate(Policy::SrptePs, &jobs, 1e-4);
        assert!((c[1] - 4.0).abs() < 1e-3, "{c:?}");
        assert!((c[0] - 5.0).abs() < 1e-3, "{c:?}");
    }

    #[test]
    fn psbs_equals_fspe_ps_with_unit_weights() {
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 5.0, est: 2.0, weight: 1.0 },
            Job::exact(1, 1.0, 1.0),
            Job { id: 2, arrival: 2.0, size: 3.0, est: 4.0, weight: 1.0 },
        ];
        let a = simulate(Policy::Psbs, &jobs, 1e-4);
        let b = simulate(Policy::FspePs, &jobs, 1e-4);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6, "{a:?} vs {b:?}");
        }
    }
}
