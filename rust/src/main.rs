//! `psbs` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//! * `simulate`  — run one policy over one synthetic workload, print MST
//!   and slowdown statistics;
//! * `sweep`     — regenerate the paper's figures (`--fig N` or all),
//!   writing CSVs into `results/`;
//! * `replay`    — replay a trace file (SWIM TSV, squid log, or the
//!   CSV-like `arrival,size[,weight][,estimate]` format) through a
//!   policy at a normalized load;
//! * `serve`     — run the scheduler as a live service: jobs arrive over
//!   a line protocol (stdin or TCP), dispatch is wall-clock paced, and
//!   online metrics stream out (see `psbs::serve`);
//! * `gen-trace` — write a synthetic stand-in trace (Facebook/IRCache
//!   statistics) in SWIM TSV form;
//! * `scenario`  — export the built-in figure scenarios as `.toml`
//!   files (`psbs scenario export fig6`) and validate a directory of
//!   scenario files (`psbs scenario validate`: render/parse round-trip
//!   plus a tiny smoke run — what the CI `scenario-validate` job
//!   gates on); `psbs sweep --scenario` runs any such file;
//! * `dominance` — empirical check of the §3 theorem on random
//!   workloads (Pri_S vs PS/DPS, PSBS vs DPS).

use psbs::figures::{self, Ctx};
use psbs::scenario::{AxisParam, PolicySpec, Reference, Scenario};
use psbs::sched;
use psbs::sim::{self, Job};
use psbs::util::cli::Args;
use psbs::workload::{self, traces, SizeDist, SynthConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match Args::parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    // Structured errors exit with a per-variant code (see
    // `psbs::Error::exit_code`); 2 stays reserved for usage errors.
    let code: Result<(), psbs::Error> = match parsed.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&parsed).map_err(Into::into),
        Some("sweep") => cmd_sweep(&parsed),
        Some("replay") => cmd_replay(&parsed),
        Some("serve") => cmd_serve(&parsed),
        Some("gen-trace") => cmd_gen_trace(&parsed).map_err(Into::into),
        Some("scenario") => cmd_scenario(&parsed).map_err(Into::into),
        Some("dominance") => cmd_dominance(&parsed).map_err(Into::into),
        Some("estimate") => cmd_estimate(&parsed).map_err(Into::into),
        Some("policies") => parsed
            .check_unknown()
            .map(|()| {
                for p in sched::ALL_POLICIES {
                    println!("{p}");
                }
            })
            .map_err(Into::into),
        Some(other) => Err(psbs::Error::msg(format!("unknown subcommand: {other}\n{USAGE}"))),
        None => Err(psbs::Error::msg(USAGE)),
    };
    if let Err(e) = code {
        eprintln!("error: {e}");
        std::process::exit(e.exit_code());
    }
}

const USAGE: &str = "\
usage: psbs <subcommand> [options]
  simulate   --policy P --shape S --sigma E --load L --njobs N --seed K [--weights-beta B] [--pareto ALPHA] [--timeshape T]
  sweep      [--fig N] [--reps R] [--njobs N] [--seed K] [--out DIR] [--svg] [--converge] [--threads T] [--no-share]
             [--scenario FILE.toml]
             [--policies P1,P2,... [--axis PARAM[=V1,V2,...]]... [--reference opt|ps|none]]
             (--threads defaults to the machine's available parallelism; 1 = exact serial path — results are bit-identical either
              way, as is the shared-workload planner vs --no-share; --scenario runs a scenario file (see scenarios/README.md) —
              the file's reps/converge overrides apply unless the same flag is given explicitly here;
              --policies sweeps a custom policy set — composed specs like cluster(k=4,dispatch=leastwork,inner=psbs) work anywhere
              a bare policy name does; --axis repeats for multi-axis cross-product grids, PARAM in
              shape|sigma|load|timeshape|njobs|beta|alpha, values optional — e.g. --axis sigma=0.25,0.5,1 --axis load=0.7,0.9)
  replay     --trace FILE --format swim|squid|csv|bin [--policy P] [--sigma E] [--load L] [--seed K] [--njobs N]
             (csv = the scenario-layer trace format: arrival,size[,weight][,estimate] — see scenarios/README.md;
              bin = a .psbt binary trace cache (write one with gen-trace --format bin) — replayed through the
              streaming engine with O(active)-memory online metrics, sized for million-job runs)
  serve      (--stdin | --listen ADDR:PORT) [--policy P] [--speedup X] [--queue N] [--stats-every N]
             (live service: submit rows `arrival,size[,weight][,estimate]` plus `kill <id>` / `stats` / `drain` /
              `shutdown` verbs arrive on stdin or one TCP connection; dispatch is wall-clock paced at X simulated
              seconds per wall second (inf = as fast as possible); responses are `done`/`stats`/`killed`/`err`
              lines — see scenarios/README.md for the protocol grammar and backpressure rules)
  gen-trace  --stats facebook|ircache --out FILE [--seed K] [--format swim|csv|bin] [--njobs N]
             (csv = the scenario-layer arrival,size format; bin = the .psbt binary trace cache; --njobs scales
              the synthetic trace, stretching its duration so the arrival rate stays at the published level)
  scenario   export <figN|all> [--dir scenarios] [--njobs N]  (dump built-in figure scenarios as .toml files)
  scenario   validate [--dir scenarios] [--njobs N] [--reps R] [--threads T]
             (round-trip every *.toml in --dir through render/parse and smoke-run it at a tiny --njobs;
              non-zero exit on any failure — the CI scenario-validate gate)
  dominance  [--cases N] [--njobs J] [--seed K]
  estimate   [--shape S] [--njobs N] [--seed K] (compare job-size estimators)
  policies   (list scheduling disciplines)";

/// Build a SynthConfig from common CLI flags.
fn synth_cfg(a: &Args) -> Result<SynthConfig, String> {
    let mut cfg = SynthConfig::default()
        .with_shape(a.get_f64("shape", 0.25)?)
        .with_sigma(a.get_f64("sigma", 0.5)?)
        .with_load(a.get_f64("load", 0.9)?)
        .with_timeshape(a.get_f64("timeshape", 1.0)?)
        .with_njobs(a.get_u64("njobs", 10_000)? as usize)
        .with_beta(a.get_f64("weights-beta", 0.0)?);
    if let Some(alpha) = a.get_opt("pareto") {
        let alpha: f64 = alpha.parse().map_err(|_| "--pareto: not a number".to_string())?;
        cfg.size_dist = SizeDist::Pareto { alpha };
    }
    Ok(cfg)
}

fn cmd_simulate(a: &Args) -> Result<(), String> {
    let policy = a.get("policy", "psbs");
    let seed = a.get_u64("seed", 42)?;
    let reps = a.get_u64("reps", 1)?;
    let cfg = synth_cfg(a)?;
    a.check_unknown()?;

    let mut msts = Vec::new();
    let mut all_slow = Vec::new();
    for r in 0..reps {
        let jobs = workload::synthesize(&cfg, seed.wrapping_add(r * 7919));
        let mut s = sched::by_name(&policy).ok_or_else(|| format!("unknown policy {policy}"))?;
        let t0 = std::time::Instant::now();
        let res = sim::run(s.as_mut(), &jobs);
        let dt = t0.elapsed();
        msts.push(res.mst(&jobs));
        all_slow.extend(res.slowdowns(&jobs));
        println!(
            "rep {r}: policy={policy} njobs={} mst={:.4} events={} wall={:.1?}",
            jobs.len(),
            msts.last().unwrap(),
            res.events,
            dt
        );
    }
    let mean_mst = psbs::stats::mean(&msts);
    all_slow.sort_by(|x, y| x.partial_cmp(y).unwrap());
    println!("---");
    println!("mean MST              {mean_mst:.4}");
    println!("median slowdown       {:.4}", psbs::stats::quantile_sorted(&all_slow, 0.5));
    println!("p99 slowdown          {:.4}", psbs::stats::quantile_sorted(&all_slow, 0.99));
    println!("max slowdown          {:.4}", all_slow.last().copied().unwrap_or(f64::NAN));
    match psbs::metrics::frac_above(&all_slow, 100.0) {
        Some(f) => println!("frac slowdown > 100   {f:.4}"),
        None => println!("frac slowdown > 100   n/a (no completions)"),
    }
    Ok(())
}

/// Parse one `--axis` occurrence: `name` (default grid) or
/// `name=v1,v2,...` (explicit value list).
fn parse_axis_arg(s: &str) -> Result<(String, AxisParam, Vec<f64>), String> {
    let (name, vals) = match s.split_once('=') {
        None => (s, None),
        Some((name, vals)) => (name, Some(vals)),
    };
    let name = name.trim();
    let param = AxisParam::parse(name).ok_or_else(|| format!("unknown --axis {name}"))?;
    let values: Vec<f64> = match vals {
        Some(vals) => {
            let mut out = Vec::new();
            for v in vals.split(',').map(str::trim).filter(|v| !v.is_empty()) {
                out.push(v.parse().map_err(|_| format!("--axis {name}: not a number: {v}"))?);
            }
            if out.is_empty() {
                return Err(format!("--axis {name}: empty value list"));
            }
            out
        }
        // Each axis gets a default grid in its own natural units (the
        // fractional shape/sigma GRID would be nonsense for njobs or
        // load).
        None => match param {
            AxisParam::Shape | AxisParam::Sigma | AxisParam::Timeshape | AxisParam::Alpha => {
                figures::GRID.to_vec()
            }
            AxisParam::Load => vec![0.5, 0.7, 0.9, 0.95, 0.999],
            AxisParam::Njobs => vec![1_000.0, 10_000.0, 100_000.0],
            AxisParam::Beta => vec![0.0, 0.5, 1.0, 2.0],
        },
    };
    Ok((name.to_string(), param, values))
}

fn cmd_sweep(a: &Args) -> Result<(), psbs::Error> {
    let fig = a.get_opt("fig").map(|f| f.parse::<u64>().map_err(|_| "--fig: integer")).transpose()?;
    let svg = a.get_bool("svg")?;
    let scenario_path = a.get_opt("scenario");
    let njobs_opt = a.get_opt("njobs");
    let policies = a.get_list("policies");
    let axis_args = a.get_multi("axis");
    let reference_opt = a.get_opt("reference");
    if policies.is_none() && (!axis_args.is_empty() || reference_opt.is_some()) {
        return Err("--axis/--reference only apply to a --policies sweep".into());
    }
    if scenario_path.is_some() && (fig.is_some() || policies.is_some()) {
        return Err("--scenario is exclusive with --fig/--policies".into());
    }
    if policies.is_some() && fig.is_some() {
        return Err("--fig is exclusive with a --policies sweep".into());
    }
    let reference = reference_opt.unwrap_or_else(|| "opt".to_string());
    let ctx = Ctx {
        reps: a.get_u64("reps", 5)?,
        njobs: a.get_u64("njobs", 10_000)? as usize,
        seed: a.get_u64("seed", 42)?,
        out_dir: a.get("out", "results"),
        converge: a.get_bool("converge")?,
        threads: a
            .get_u64("threads", psbs::util::pool::available_threads() as u64)?
            .max(1) as usize,
        share: !a.get_bool("no-share")?,
    };
    a.check_unknown()?;
    println!(
        "# sweep executor: {} worker thread(s), {} workloads",
        ctx.threads,
        if ctx.share { "planner-shared" } else { "per-cell" }
    );

    // A scenario file: the whole experiment lives in the .toml; only
    // execution knobs (--reps/--seed/--threads/...) come from the CLI,
    // plus an explicit --njobs rescale when given.  The file's own
    // reps/converge overrides apply unless the matching CLI flag was
    // given explicitly — a file pinning `reps = 30` must not silently
    // run at the CLI default 5, and `--reps 2` on the command line
    // must still win for quick looks.
    if let Some(path) = scenario_path {
        let mut sc = Scenario::load(&path)?;
        if njobs_opt.is_some() {
            sc = sc.with_njobs(ctx.njobs);
        }
        let mut p = sc.sweep_params(ctx.params());
        if a.has("reps") {
            p.reps = ctx.reps;
        }
        if a.has("converge") {
            p.converge = ctx.converge;
        }
        let t0 = std::time::Instant::now();
        for t in sc.tables(p, ctx.threads, ctx.share) {
            emit_table(&t, &ctx, svg)?;
            warn_on_dropped_kills(&t);
        }
        println!("# scenario {} done in {:.1?}\n", sc.name, t0.elapsed());
        return Ok(());
    }

    // Custom scenario sweep: a user-declared policy set (composed
    // specs welcome) over one or more grid axes (cross-product),
    // through the same planner as the paper figures.
    if let Some(policies) = policies {
        let mut sc = Scenario::new("custom_sweep", SynthConfig::default().with_njobs(ctx.njobs));
        let axes: Vec<(String, AxisParam, Vec<f64>)> = if axis_args.is_empty() {
            vec![parse_axis_arg("sigma")?]
        } else {
            axis_args.iter().map(|s| parse_axis_arg(s)).collect::<Result<_, _>>()?
        };
        for (name, param, values) in &axes {
            sc = sc.axis(name.clone(), *param, values);
        }
        for p in &policies {
            let spec = PolicySpec::parse(p)?;
            sc = sc.policy_as(spec.to_string(), spec);
        }
        match reference.as_str() {
            "opt" => sc = sc.vs(Reference::OptSrpt),
            "ps" => sc = sc.vs(Reference::Ps),
            "none" => {}
            other => return Err(format!("unknown --reference {other} (opt|ps|none)").into()),
        }
        sc.validate()?;
        let t0 = std::time::Instant::now();
        let t = sc.table(ctx.params(), ctx.threads, ctx.share);
        emit_table(&t, &ctx, svg)?;
        println!("# custom sweep done in {:.1?}\n", t0.elapsed());
        return Ok(());
    }

    let figs: Vec<u64> = match fig {
        Some(f) => vec![f],
        None => figures::ALL_FIGS.to_vec(),
    };
    for f in figs {
        let t0 = std::time::Instant::now();
        let tables = figures::by_number(&ctx, f).ok_or_else(|| format!("no figure {f}"))?;
        for t in &tables {
            emit_table(t, &ctx, svg)?;
        }
        println!("# fig {f} done in {:.1?}\n", t0.elapsed());
    }
    Ok(())
}

/// `psbs scenario export <figN|all>` — dump the built-in figure
/// scenarios as canonical `.toml` files (the committed `scenarios/`
/// directory is exactly this output at the default scale).
/// `psbs scenario validate` — round-trip + smoke-run a directory of
/// scenario files.
fn cmd_scenario(a: &Args) -> Result<(), String> {
    let action = a.positional(0).ok_or_else(|| format!("missing action\n{USAGE}"))?;
    match action.as_str() {
        "export" => cmd_scenario_export(a),
        "validate" => cmd_scenario_validate(a),
        other => {
            Err(format!("unknown scenario action `{other}` (expected `export` or `validate`)"))
        }
    }
}

fn cmd_scenario_export(a: &Args) -> Result<(), String> {
    let what = a
        .positional(1)
        .ok_or_else(|| format!("scenario export: which figure? (figN or all)\n{USAGE}"))?;
    let dir = a.get("dir", "scenarios");
    let njobs = a.get_u64("njobs", 10_000)? as usize;
    a.check_unknown()?;

    let figs: Vec<u64> = if what == "all" {
        figures::EXPORTED_FIGS.to_vec()
    } else {
        let n: u64 = what
            .strip_prefix("fig")
            .unwrap_or(&what)
            .parse()
            .map_err(|_| format!("scenario export: expected figN or all, got `{what}`"))?;
        if !figures::EXPORTED_FIGS.contains(&n) {
            return Err(format!(
                "fig {n} is not scenario-shaped; exportable: {:?}",
                figures::EXPORTED_FIGS
            ));
        }
        vec![n]
    };
    std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
    for fig in figs {
        for (fname, toml) in figures::export_files(fig, njobs).unwrap() {
            let path = format!("{dir}/{fname}");
            std::fs::write(&path, &toml).map_err(|e| format!("writing {path}: {e}"))?;
            println!("wrote {path}");
        }
    }
    Ok(())
}

/// `psbs scenario validate [--dir D] [--njobs N] [--reps R]
/// [--threads T]` — for every `*.toml` in the directory: (1) parse,
/// render the canonical form, re-parse and require the result to be
/// identical and the render a byte-exact fixpoint (schema and renderer
/// cannot drift apart on committed files); (2) smoke-run the scenario
/// through the shared planner at a tiny `--njobs` budget and require
/// well-formed, finite tables.  Non-zero exit on any failure — this is
/// exactly what the blocking CI `scenario-validate` job runs, so a
/// schema change or a broken scenario file fails the PR, not the user.
fn cmd_scenario_validate(a: &Args) -> Result<(), String> {
    let dir = a.get("dir", "scenarios");
    let njobs = a.get_u64("njobs", 150)? as usize;
    let reps = a.get_u64("reps", 1)?;
    let threads = a
        .get_u64("threads", psbs::util::pool::available_threads() as u64)?
        .max(1) as usize;
    a.check_unknown()?;

    let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(&dir)
        .map_err(|e| format!("reading {dir}: {e}"))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map_or(false, |x| x == "toml"))
        .collect();
    files.sort();
    if files.is_empty() {
        return Err(format!("{dir}: no scenario (*.toml) files to validate"));
    }

    let base = std::path::Path::new(&dir);
    let mut failures: Vec<String> = Vec::new();
    for path in &files {
        let shown = path.display();
        match validate_scenario_file(path, base, njobs, reps, threads) {
            Ok(ntables) => println!("ok   {shown}: round-trip + smoke ({ntables} table(s))"),
            Err(e) => {
                eprintln!("FAIL {shown}: {e}");
                failures.push(shown.to_string());
            }
        }
    }
    if failures.is_empty() {
        println!("validated {} scenario file(s) in {dir}", files.len());
        Ok(())
    } else {
        Err(format!(
            "{} of {} scenario file(s) failed validation: {}",
            failures.len(),
            files.len(),
            failures.join(", ")
        ))
    }
}

fn validate_scenario_file(
    path: &std::path::Path,
    base: &std::path::Path,
    njobs: usize,
    reps: u64,
    threads: usize,
) -> Result<usize, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading: {e}"))?;
    let sc = Scenario::parse_toml_in(&text, Some(base))?;
    // Round-trip: the canonical render must re-parse to the same
    // scenario and be a byte-exact fixpoint.
    let rendered = sc.to_toml();
    let back = Scenario::parse_toml_in(&rendered, Some(base))
        .map_err(|e| format!("canonical render failed to re-parse: {e}"))?;
    if back != sc {
        return Err("render/parse round-trip drifted from the original scenario".into());
    }
    if back.to_toml() != rendered {
        return Err("canonical render is not byte-identical under re-render".into());
    }
    // Smoke run: tiny but real — through the same planner a full sweep
    // uses.  File reps/converge overrides are deliberately ignored
    // here; the smoke budget must stay bounded no matter what a
    // scenario pins for its production runs.
    let smoke = sc.with_njobs(njobs);
    let p = psbs::scenario::SweepParams { reps, seed: 42, converge: false };
    let tables = smoke.tables(p, threads, true);
    if tables.is_empty() {
        return Err("smoke run produced no tables".into());
    }
    for t in &tables {
        if t.rows.is_empty() {
            return Err(format!("smoke run: table {} has no rows", t.name));
        }
        for row in &t.rows {
            if row.len() != t.header.len() {
                return Err(format!("smoke run: table {} has a ragged row", t.name));
            }
            if !row[0].is_finite() {
                return Err(format!("smoke run: table {} has a non-finite x value", t.name));
            }
        }
    }
    Ok(tables.len())
}

/// Fault scenarios emit a `{name}_fault_counters` companion table (one
/// row per policy, columns per `scenario::FAULT_COUNTER_COLUMNS`).  A
/// non-zero `kills_rejected`/`kills_unsupported` total means a
/// discipline mishandled a crash-path cancellation — loud warning, not
/// a silent CSV column.
fn warn_on_dropped_kills(t: &figures::Table) {
    if !t.name.ends_with("_fault_counters") {
        return;
    }
    for col in ["kills_rejected", "kills_unsupported"] {
        let Some(ci) = t.header.iter().position(|h| h == col) else { continue };
        let total: f64 = t.rows.iter().map(|r| r[ci]).sum();
        if total > 0.0 {
            eprintln!(
                "warning: {} {col} kill(s) across the sweep (table {}) — \
                 a discipline refused or missed crash-path cancellations",
                total, t.name
            );
        }
    }
}

/// What `emit_table` prints for a table — `None` for a
/// `{name}_fault_counters` companion whose counters are all zero
/// (column 0 is the policy index, not a counter).  A fault-free sweep
/// used to dump an all-zero counter table per scenario; the CSV is
/// still written either way, so nothing is lost from `results/`.
fn table_stdout(t: &figures::Table) -> Option<String> {
    let all_zero = t.name.ends_with("_fault_counters")
        && t.rows.iter().all(|r| r.iter().skip(1).all(|v| *v == 0.0));
    if all_zero {
        None
    } else {
        Some(t.render())
    }
}

fn emit_table(t: &figures::Table, ctx: &Ctx, svg: bool) -> Result<(), String> {
    if let Some(text) = table_stdout(t) {
        println!("{text}");
    }
    let path = t.write_csv(&ctx.out_dir).map_err(|e| e.to_string())?;
    println!("wrote {path}");
    if svg {
        let opts = figures::plot::PlotOpts::default();
        let path = figures::plot::write_svg(t, &ctx.out_dir, &opts).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_replay(a: &Args) -> Result<(), psbs::Error> {
    let trace = a.get_opt("trace").ok_or("missing --trace FILE")?;
    let format = a.get("format", "swim");
    let policy = a.get("policy", "psbs");
    let sigma = a.get_f64("sigma", 0.5)?;
    let load = a.get_f64("load", 0.9)?;
    let seed = a.get_u64("seed", 42)?;
    let njobs = match a.get_opt("njobs") {
        None => usize::MAX,
        Some(n) => n.parse::<usize>().map_err(|_| "--njobs: integer".to_string())?,
    };
    a.check_unknown()?;

    // A binary trace cache replays through the streaming engine: rows
    // decode straight from the fixed-width file, jobs exist only while
    // in flight, and the metrics fold online — memory stays O(active)
    // for million-job caches.
    if format == "bin" {
        return replay_streaming(&trace, &policy, njobs, load, sigma, seed);
    }

    // The scenario-layer CSV format parses with hard errors and
    // carries optional weight/estimate columns; SWIM/squid keep their
    // lenient skip-malformed-rows behavior (real logs are dirty).
    let jobs = if format == "csv" {
        psbs::workload::trace_file::TraceFile::load(&trace)?.to_jobs(njobs, load, sigma, seed)
    } else {
        let recs = traces::load_file(&trace, &format).map_err(|e| e.to_string())?;
        if recs.is_empty() {
            return Err("trace has no usable records".into());
        }
        traces::to_jobs(&recs, load, sigma, seed)
    };
    let mut s = sched::by_name(&policy).ok_or_else(|| format!("unknown policy {policy}"))?;
    let t0 = std::time::Instant::now();
    let res = sim::run(s.as_mut(), &jobs);
    let wall = t0.elapsed();
    let slow = res.slowdowns(&jobs);
    println!(
        "trace={} jobs={} policy={policy} sigma={sigma} load={load}",
        trace,
        jobs.len()
    );
    println!("MST                 {:.4}", res.mst(&jobs));
    println!("median slowdown     {:.4}", psbs::stats::quantile(&slow, 0.5));
    println!("p99 slowdown        {:.4}", psbs::stats::quantile(&slow, 0.99));
    match psbs::metrics::frac_above(&slow, 100.0) {
        Some(f) => println!("frac slowdown > 100 {f:.4}"),
        None => println!("frac slowdown > 100 n/a (no completions)"),
    }
    println!("sim wall time       {wall:.1?} ({:.0} jobs/s)", jobs.len() as f64 / wall.as_secs_f64());
    Ok(())
}

/// `psbs replay --format bin`: stream a `.psbt` binary trace cache
/// through [`sim::run_streaming`] with an
/// [`psbs::metrics::OnlineMetrics`] sink — no job vector, no
/// completion vector, no slowdown vector.  This is the bounded-memory
/// replay the tier-1 `streaming-smoke` gate runs at 10⁶ jobs.
fn replay_streaming(
    trace: &str,
    policy: &str,
    njobs: usize,
    load: f64,
    sigma: f64,
    seed: u64,
) -> Result<(), psbs::Error> {
    use psbs::metrics::OnlineMetrics;
    use psbs::workload::cache::CacheReader;
    use psbs::workload::trace_file::TraceJobSource;

    let reader = CacheReader::open(trace)?;
    let mut source =
        TraceJobSource::new(reader, njobs, load, sigma, seed).map_err(|e| e.with_path(trace))?;
    let mut s = sched::by_name(policy).ok_or_else(|| format!("unknown policy {policy}"))?;
    let mut m = OnlineMetrics::new().with_quantiles(&[0.5, 0.99]);
    let t0 = std::time::Instant::now();
    let stats = sim::run_streaming(s.as_mut(), &mut source, &mut m);
    let wall = t0.elapsed();
    println!(
        "trace={trace} jobs={} policy={policy} sigma={sigma} load={load} (streamed cache)",
        stats.delivered
    );
    println!("MST                 {:.4}", m.mst().unwrap_or(f64::NAN));
    println!("median slowdown     {:.4}", m.quantile(0.5).unwrap_or(f64::NAN));
    println!("p99 slowdown        {:.4}", m.quantile(0.99).unwrap_or(f64::NAN));
    match m.frac_above() {
        Some(f) => println!("frac slowdown > 100 {f:.4}"),
        None => println!("frac slowdown > 100 n/a (no completions)"),
    }
    println!(
        "sim wall time       {wall:.1?} ({:.0} jobs/s)",
        stats.delivered as f64 / wall.as_secs_f64()
    );
    Ok(())
}

/// `psbs serve` — one live session over stdin or one TCP connection;
/// protocol, pacing and backpressure live in [`psbs::serve`].
fn cmd_serve(a: &Args) -> Result<(), psbs::Error> {
    let policy = a.get("policy", "psbs");
    let use_stdin = a.get_bool("stdin")?;
    let listen = a.get_opt("listen");
    // f64::from_str accepts "inf", so `--speedup inf` just works.
    let speedup = a.get_f64("speedup", 1.0)?;
    let queue = a.get_u64("queue", 1024)? as usize;
    let stats_every = a.get_u64("stats-every", 0)?;
    a.check_unknown()?;

    let cfg = psbs::serve::ServeConfig { policy, speedup, queue, stats_every };
    let summary = match (use_stdin, listen) {
        (true, None) => psbs::serve::serve_stdin(&cfg)?,
        (false, Some(addr)) => psbs::serve::serve_listen(&addr, &cfg)?,
        _ => {
            return Err(psbs::Error::msg(format!(
                "serve: exactly one of --stdin or --listen ADDR:PORT is required\n{USAGE}"
            )))
        }
    };
    // Protocol lines went to the transport; the operator summary goes
    // to stderr so piping stdout stays machine-clean.
    eprintln!(
        "psbs serve: session over: delivered={} completed={} killed={}{}",
        summary.delivered,
        summary.completed,
        summary.killed,
        if summary.aborted { " (shutdown)" } else { "" }
    );
    Ok(())
}

fn cmd_gen_trace(a: &Args) -> Result<(), String> {
    let stats_name = a.get("stats", "facebook");
    let out = a.get_opt("out").ok_or("missing --out FILE")?;
    let seed = a.get_u64("seed", 42)?;
    let format = a.get("format", "swim");
    let njobs = match a.get_opt("njobs") {
        None => None,
        Some(n) => Some(n.parse::<usize>().map_err(|_| "--njobs: integer".to_string())?),
    };
    a.check_unknown()?;
    let mut stats = *traces::TraceName::from_name(&stats_name)
        .ok_or_else(|| format!("unknown stats preset: {stats_name}"))?
        .stats();
    if let Some(n) = njobs {
        if n == 0 {
            return Err("--njobs must be >= 1".into());
        }
        // Stretch the duration proportionally so the synthetic arrival
        // rate (and thus the offered load at replay) stays at the
        // published level instead of compressing N jobs into the
        // original span.
        stats.duration_s *= n as f64 / stats.jobs.max(1) as f64;
        stats.jobs = n;
    }
    let recs = traces::synth_trace(&stats, seed);
    match format.as_str() {
        "swim" => traces::write_swim(&recs, &out).map_err(|e| e.to_string())?,
        // The scenario-layer CSV trace format (arrival,size) — what
        // `replay --format csv` and `kind = "trace"` scenario files
        // read back.
        "csv" => {
            use std::io::Write;
            let f = std::fs::File::create(&out).map_err(|e| format!("creating {out}: {e}"))?;
            let mut w = std::io::BufWriter::new(f);
            writeln!(w, "arrival,size").map_err(|e| format!("writing {out}: {e}"))?;
            for r in &recs {
                writeln!(w, "{},{}", r.submit, r.bytes)
                    .map_err(|e| format!("writing {out}: {e}"))?;
            }
            w.flush().map_err(|e| format!("writing {out}: {e}"))?;
        }
        // The binary trace cache — what `replay --format bin` streams.
        "bin" => {
            use psbs::workload::cache::write_cache;
            use psbs::workload::trace_file::TraceRow;
            write_cache(
                &out,
                recs.iter().map(|r| TraceRow {
                    arrival: r.submit,
                    size: r.bytes,
                    weight: 1.0,
                    est: None,
                }),
            )?;
        }
        other => return Err(format!("unknown --format {other} (swim|csv|bin)")),
    }
    println!("wrote {} records to {out} ({format})", recs.len());
    Ok(())
}

fn cmd_dominance(a: &Args) -> Result<(), String> {
    let cases = a.get_u64("cases", 50)?;
    let njobs = a.get_u64("njobs", 200)? as usize;
    let seed = a.get_u64("seed", 42)?;
    a.check_unknown()?;

    use psbs::sched::pri::Pri;
    let mut worst: f64 = 0.0;
    for c in 0..cases {
        let cfg = SynthConfig::default().with_njobs(njobs).with_sigma(0.0).with_beta(
            if c % 2 == 0 { 0.0 } else { 1.0 },
        );
        let jobs: Vec<Job> = workload::synthesize(&cfg, seed.wrapping_add(c));
        let base_name = if c % 2 == 0 { "ps" } else { "dps" };
        let mut base = sched::by_name(base_name).unwrap();
        let base_res = sim::run(base.as_mut(), &jobs);
        let mut pri = Pri::from_completions(&base_res.completion);
        let pri_res = sim::run(&mut pri, &jobs);
        for i in 0..jobs.len() {
            let lateness = pri_res.completion[i] - base_res.completion[i];
            worst = worst.max(lateness);
            if lateness > 1e-6 {
                return Err(format!(
                    "dominance violated: case {c} job {i} pri {} vs {base_name} {}",
                    pri_res.completion[i], base_res.completion[i]
                ));
            }
        }
        // PSBS (exact sizes) must dominate DPS as well (§3/§5.2).
        let mut psbs = sched::by_name("psbs").unwrap();
        let psbs_res = sim::run(psbs.as_mut(), &jobs);
        let mut dps = sched::by_name("dps").unwrap();
        let dps_res = sim::run(dps.as_mut(), &jobs);
        for i in 0..jobs.len() {
            let lateness = psbs_res.completion[i] - dps_res.completion[i];
            worst = worst.max(lateness);
            if lateness > 1e-6 {
                return Err(format!(
                    "PSBS-vs-DPS dominance violated: case {c} job {i}: {} vs {}",
                    psbs_res.completion[i], dps_res.completion[i]
                ));
            }
        }
    }
    println!("dominance holds on {cases} random workloads (worst lateness {worst:.2e})");
    Ok(())
}

/// Compare the practical estimators of §2.2 (oracle, HFSP-style
/// sampling, size-class, log-normal reference) on one workload:
/// a-posteriori quality (§6.3's correlation) and the resulting PSBS /
/// SRPTE mean sojourn times against the exact-information optimum.
fn cmd_estimate(a: &Args) -> Result<(), String> {
    use psbs::estimate::{self, Estimator};
    use psbs::figures::{exact_copy, run_mst, Reference};
    let shape = a.get_f64("shape", 0.25)?;
    let njobs = a.get_u64("njobs", 10_000)? as usize;
    let seed = a.get_u64("seed", 42)?;
    a.check_unknown()?;

    let cfg = SynthConfig::default().with_shape(shape).with_sigma(0.0).with_njobs(njobs);
    let base = workload::synthesize(&cfg, seed);
    let opt = Reference::OptSrpt.mst(&exact_copy(&base));

    let estimators: Vec<(&str, Box<dyn Estimator>)> = vec![
        ("oracle", Box::new(estimate::OracleEstimator)),
        ("sample-1%", Box::new(estimate::SamplingEstimator::new(0.01, 0.5))),
        ("sample-5%", Box::new(estimate::SamplingEstimator::new(0.05, 0.5))),
        ("sample-25%", Box::new(estimate::SamplingEstimator::new(0.25, 0.5))),
        ("size-class", Box::new(estimate::ClassEstimator)),
        ("lognorm-0.5", Box::new(estimate::LogNormalNoise::new(0.5))),
        ("lognorm-2.0", Box::new(estimate::LogNormalNoise::new(2.0))),
    ];
    println!(
        "{:<12} {:>9} {:>7} {:>7} {:>10} {:>10}",
        "estimator", "log-sigma", "corr", "under%", "psbs/opt", "srpte/opt"
    );
    for (name, est) in estimators {
        let jobs = estimate::apply(&base, est.as_ref(), seed ^ 0xE5);
        let q = estimate::measure(&jobs);
        println!(
            "{:<12} {:>9.3} {:>7.3} {:>7.1} {:>10.3} {:>10.3}",
            name,
            q.log_sigma,
            q.correlation,
            q.frac_under * 100.0,
            run_mst("psbs", &jobs) / opt,
            run_mst("srpte", &jobs) / opt,
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counters(name: &str, rows: &[Vec<f64>]) -> figures::Table {
        let mut t = figures::Table::new(
            name,
            vec!["policy".into(), "crashes".into(), "restarts".into(), "lost".into()],
        );
        for r in rows {
            t.push(r.clone());
        }
        t
    }

    #[test]
    fn all_zero_fault_counter_tables_are_suppressed_on_stdout() {
        // Column 0 is the policy index, not a counter — a nonzero
        // index alone must not force the table out.
        let quiet = counters("fig6_fault_counters", &[vec![0.0, 0.0, 0.0, 0.0], vec![3.0, 0.0, 0.0, 0.0]]);
        assert_eq!(table_stdout(&quiet), None);

        let noisy = counters("fig6_fault_counters", &[vec![0.0, 0.0, 0.0, 0.0], vec![1.0, 0.0, 2.0, 0.0]]);
        assert_eq!(table_stdout(&noisy), Some(noisy.render()));

        // Non-counter tables always print, even when all-zero.
        let plain = counters("fig6_mst", &[vec![0.0, 0.0, 0.0, 0.0]]);
        assert_eq!(table_stdout(&plain), Some(plain.render()));

        // An empty counter table is vacuously all-zero: suppressed.
        let empty = counters("fig2_fault_counters", &[]);
        assert_eq!(table_stdout(&empty), None);
    }
}
