//! Streaming quantile estimation — the P² algorithm (Jain & Chlamtac,
//! CACM 1985).
//!
//! The online service reports latency percentiles without retaining
//! per-job samples: P² tracks one quantile with five markers updated
//! in O(1) per observation, using piecewise-parabolic interpolation.
//! Accuracy is ample for operational metrics (≈1% of the true quantile
//! for unimodal distributions); exact quantiles remain available
//! offline via [`crate::stats::quantile`] where samples are retained.

/// One P² estimator tracking quantile `q` (0 < q < 1).
#[derive(Debug, Clone)]
pub struct P2Quantile {
    q: f64,
    /// Marker heights (estimates of the 5 tracked quantile positions).
    heights: [f64; 5],
    /// Actual marker positions (1-based observation ranks).
    pos: [f64; 5],
    /// Desired marker positions.
    desired: [f64; 5],
    /// Desired position increments per observation.
    inc: [f64; 5],
    /// Observations seen so far (first 5 are stored raw).
    n: usize,
}

impl P2Quantile {
    pub fn new(q: f64) -> Self {
        assert!(q > 0.0 && q < 1.0, "quantile must be in (0,1)");
        P2Quantile {
            q,
            heights: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            desired: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            inc: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
            n: 0,
        }
    }

    /// Number of observations consumed.
    pub fn count(&self) -> usize {
        self.n
    }

    /// Feed one observation.
    pub fn observe(&mut self, x: f64) {
        if self.n < 5 {
            self.heights[self.n] = x;
            self.n += 1;
            if self.n == 5 {
                self.heights.sort_by(|a, b| a.partial_cmp(b).unwrap());
            }
            return;
        }
        self.n += 1;

        // Locate the cell k with heights[k] <= x < heights[k+1] and
        // bump the extremes if x falls outside.
        let k = if x < self.heights[0] {
            self.heights[0] = x;
            0
        } else if x >= self.heights[4] {
            self.heights[4] = x;
            3
        } else {
            let mut k = 0;
            while k < 3 && x >= self.heights[k + 1] {
                k += 1;
            }
            k
        };

        for p in self.pos.iter_mut().skip(k + 1) {
            *p += 1.0;
        }
        for (d, i) in self.desired.iter_mut().zip(self.inc) {
            *d += i;
        }

        // Adjust the three interior markers.
        for i in 1..4 {
            let d = self.desired[i] - self.pos[i];
            let right = self.pos[i + 1] - self.pos[i];
            let left = self.pos[i - 1] - self.pos[i];
            if (d >= 1.0 && right > 1.0) || (d <= -1.0 && left < -1.0) {
                let d = d.signum();
                let cand = self.parabolic(i, d);
                let new_h = if self.heights[i - 1] < cand && cand < self.heights[i + 1] {
                    cand
                } else {
                    self.linear(i, d)
                };
                self.heights[i] = new_h;
                self.pos[i] += d;
            }
        }
    }

    /// Piecewise-parabolic (P²) height prediction for marker `i`
    /// moving by `d` (±1).
    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (hm, h, hp) = (self.heights[i - 1], self.heights[i], self.heights[i + 1]);
        let (pm, p, pp) = (self.pos[i - 1], self.pos[i], self.pos[i + 1]);
        h + d / (pp - pm)
            * ((p - pm + d) * (hp - h) / (pp - p) + (pp - p - d) * (h - hm) / (p - pm))
    }

    /// Linear fallback when the parabola would break monotonicity.
    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.heights[i]
            + d * (self.heights[j] - self.heights[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current estimate of the tracked quantile.
    pub fn value(&self) -> f64 {
        match self.n {
            0 => f64::NAN,
            n if n < 5 => {
                // Exact small-sample quantile over the raw buffer.
                let mut v: Vec<f64> = self.heights[..n].to_vec();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                super::quantile_sorted(&v, self.q)
            }
            _ => self.heights[2],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tracks_median_of_uniform() {
        let mut p = P2Quantile::new(0.5);
        let mut rng = Rng::new(1);
        for _ in 0..100_000 {
            p.observe(rng.u01());
        }
        assert!((p.value() - 0.5).abs() < 0.01, "median {}", p.value());
    }

    #[test]
    fn tracks_p99_of_exponential() {
        // Exp(1): p99 = -ln(0.01) = 4.605.
        let mut p = P2Quantile::new(0.99);
        let mut rng = Rng::new(2);
        for _ in 0..200_000 {
            p.observe(-rng.u01_open_left().ln());
        }
        let want = -(0.01f64).ln();
        assert!(
            (p.value() - want).abs() / want < 0.05,
            "p99 {} want {want}",
            p.value()
        );
    }

    #[test]
    fn small_samples_are_exact() {
        let mut p = P2Quantile::new(0.5);
        for x in [3.0, 1.0, 2.0] {
            p.observe(x);
        }
        assert_eq!(p.value(), 2.0);
        assert_eq!(p.count(), 3);
        assert!(P2Quantile::new(0.5).value().is_nan());
    }

    #[test]
    fn heavy_tail_quantile_reasonable() {
        // LogNormal(0, 2): median = 1 — a hard case for sketches.
        let mut p = P2Quantile::new(0.5);
        let mut rng = Rng::new(3);
        for _ in 0..200_000 {
            p.observe((2.0 * rng.normal()).exp());
        }
        assert!((p.value() - 1.0).abs() < 0.1, "median {}", p.value());
    }

    #[test]
    fn matches_exact_quantile_on_retained_samples() {
        let mut rng = Rng::new(4);
        let xs: Vec<f64> = (0..50_000).map(|_| rng.u01().powi(3) * 100.0).collect();
        for q in [0.1, 0.5, 0.9, 0.99] {
            let mut p = P2Quantile::new(q);
            for &x in &xs {
                p.observe(x);
            }
            let exact = crate::stats::quantile(&xs, q);
            let err = (p.value() - exact).abs() / exact.abs().max(1e-9);
            assert!(err < 0.08, "q={q}: sketch {} exact {exact}", p.value());
        }
    }

    #[test]
    #[should_panic(expected = "quantile must be in (0,1)")]
    fn rejects_degenerate_quantile() {
        P2Quantile::new(1.0);
    }
}
