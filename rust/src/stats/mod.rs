//! Summary statistics: means, quantiles, 95% confidence intervals and
//! the paper's repetition-control rule (§6.3: repeat until the CI is
//! within 5% of the estimate); [`quantile::P2Quantile`] for streaming
//! percentiles in the online service.

pub mod quantile;
pub use quantile::P2Quantile;

/// Neumaier-compensated running sum: `add`/`sub` churn accumulates
/// O(eps) total error instead of O(n·eps).  Backs the `w_l`/`w_v`
/// weight sums that feed DPS rate denominators on every event
/// ([`crate::sched`]'s late-set engine) and the long-horizon MST /
/// mean-slowdown accumulators of [`crate::metrics::OnlineMetrics`],
/// where a 10⁷-job naive sum would drift.
#[derive(Debug, Clone, Copy, Default)]
pub struct CompensatedSum {
    sum: f64,
    comp: f64,
}

impl CompensatedSum {
    pub fn new() -> CompensatedSum {
        CompensatedSum::default()
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        let t = self.sum + x;
        // Neumaier's branch: compensate with whichever operand was
        // large enough to have absorbed the other's low bits.
        if self.sum.abs() >= x.abs() {
            self.comp += (self.sum - t) + x;
        } else {
            self.comp += (x - t) + self.sum;
        }
        self.sum = t;
    }

    #[inline]
    pub fn sub(&mut self, x: f64) {
        self.add(-x);
    }

    #[inline]
    pub fn value(&self) -> f64 {
        self.sum + self.comp
    }

    pub fn reset(&mut self) {
        *self = CompensatedSum::default();
    }
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Unbiased sample standard deviation (0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n as f64 - 1.0)).sqrt()
}

/// Half-width of the 95% confidence interval for the mean
/// (normal approximation; the paper's runs use n >= 30).
pub fn ci95_half_width(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::INFINITY;
    }
    1.96 * stddev(xs) / (xs.len() as f64).sqrt()
}

/// Paper §6.3 stopping rule: true once the 95% CI half-width is within
/// `frac` (default 0.05) of the estimated mean and n >= `min_reps`.
pub fn converged(xs: &[f64], frac: f64, min_reps: usize) -> bool {
    xs.len() >= min_reps && ci95_half_width(xs) <= frac * mean(xs).abs()
}

/// Quantile via linear interpolation over a *sorted* slice, q in [0,1].
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Quantile of an unsorted slice (copies + sorts).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

/// Running mean/CI accumulator for repetition loops.
#[derive(Debug, Default, Clone)]
pub struct Repetitions {
    pub values: Vec<f64>,
}

impl Repetitions {
    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }
    pub fn mean(&self) -> f64 {
        mean(&self.values)
    }
    pub fn ci95(&self) -> f64 {
        ci95_half_width(&self.values)
    }
    pub fn n(&self) -> usize {
        self.values.len()
    }
    /// §6.3 rule with the paper's 5% threshold.
    pub fn converged(&self, min_reps: usize) -> bool {
        converged(&self.values, 0.05, min_reps)
    }
}

/// ln Γ(x) via the Lanczos approximation (g = 7, n = 9); |err| < 1e-13
/// over the range we use (x >= 1, since x = 1 + 1/shape).
pub fn ln_gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = C[0];
    let t = x + G + 0.5;
    for (i, &c) in C.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Γ(x).
pub fn gamma(x: f64) -> f64 {
    ln_gamma(x).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compensated_sum_survives_churn() {
        // 1e16 + many small adds/subs: a naive f64 sum loses every
        // small term; the compensated value keeps them.
        let mut s = CompensatedSum::new();
        s.add(1e16);
        for _ in 0..1000 {
            s.add(1.0);
            s.sub(1.0);
        }
        s.add(1.0);
        s.sub(1e16);
        assert_eq!(s.value(), 1.0);
        s.reset();
        assert_eq!(s.value(), 0.0);
    }

    #[test]
    fn mean_and_stddev() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.2909944487358056).abs() < 1e-12);
    }

    #[test]
    fn gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(1/2)=sqrt(pi), Γ(9)=40320
        assert!((gamma(1.0) - 1.0).abs() < 1e-10);
        assert!((gamma(2.0) - 1.0).abs() < 1e-10);
        assert!((gamma(5.0) - 24.0).abs() < 1e-8);
        assert!((gamma(0.5) - std::f64::consts::PI.sqrt()).abs() < 1e-10);
        assert!((gamma(9.0) - 40320.0).abs() < 1e-5);
    }

    #[test]
    fn weibull_unit_mean_scale() {
        // mean = scale * Γ(1 + 1/k); for k = 0.25: Γ(5) = 24.
        let k: f64 = 0.25;
        let scale = 1.0 / gamma(1.0 + 1.0 / k);
        assert!((scale - 1.0 / 24.0).abs() < 1e-10);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn ci_and_convergence() {
        let tight: Vec<f64> = (0..100).map(|i| 10.0 + 0.01 * (i % 2) as f64).collect();
        assert!(converged(&tight, 0.05, 30));
        let loose = vec![1.0, 100.0, 2.0];
        assert!(!converged(&loose, 0.05, 30));
        assert_eq!(ci95_half_width(&[1.0]), f64::INFINITY);
    }
}
