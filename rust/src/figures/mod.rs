//! Figure regeneration harness: one function per figure/table of the
//! paper's evaluation (§7, appendix A.2), each producing [`Table`]s
//! that print the same rows/series the paper plots and land in
//! `results/*.csv`.
//!
//! Absolute numbers differ from the paper's (different RNG, reduced
//! repetition counts unless `--reps`/`--paper-scale` raise them); the
//! *shapes* — who wins, by what factor, where crossovers sit — are the
//! reproduction targets, recorded in EXPERIMENTS.md.
//!
//! ## Parallel sweep execution
//!
//! Every figure describes its work as a flat list of independent cells
//! — [`SweepCell`]s for plain MST/ratio grids, ad-hoc `(index, rep)`
//! items for pooled-population figures — and evaluates it through
//! [`crate::util::pool::par_map`] with `Ctx::threads` workers.  Each
//! cell derives its repetition seeds independently
//! (`seed + r * 7919`), and results are reassembled in cell order, so
//! parallel output is **bit-identical** to the serial path
//! (`threads == 1`); `tests::parallel_sweep_is_bit_identical` pins
//! this down across thread counts.

pub mod plot;
pub mod tables;

use crate::metrics;
use crate::runtime::Runtime;
use crate::sched;
use crate::sim::{self, Job};
use crate::stats::Repetitions;
use crate::util::pool;
use crate::workload::traces;
use crate::workload::{SizeDist, SynthConfig};
pub use tables::Table;

/// Shared sweep context.
pub struct Ctx {
    /// Repetitions per data point (paper: >= 30; default here: 5).
    pub reps: u64,
    /// Override Table-1 njobs (smaller = faster sweeps).
    pub njobs: usize,
    /// Base seed.
    pub seed: u64,
    /// Output directory for CSVs.
    pub out_dir: String,
    /// AOT analytics/workload runtime (None => pure-rust fallback).
    pub runtime: Option<Runtime>,
    /// Keep repeating past `reps` (up to 10x) until the 95% CI is
    /// within 5% of the mean (§6.3) — slow; off by default.
    pub converge: bool,
    /// Worker threads for grid evaluation (1 = the exact serial path;
    /// results are bit-identical either way).
    pub threads: usize,
}

impl Default for Ctx {
    fn default() -> Self {
        Ctx {
            reps: 5,
            njobs: 10_000,
            seed: 42,
            out_dir: "results".to_string(),
            runtime: None,
            converge: false,
            threads: 1,
        }
    }
}

/// The grid used for shape/sigma sweeps (paper: 0.125 .. 4, log-spaced).
pub const GRID: [f64; 6] = [0.125, 0.25, 0.5, 1.0, 2.0, 4.0];

/// Scalar sweep parameters, detached from [`Ctx`] so worker threads
/// never touch the (non-`Sync`) runtime handle.
#[derive(Debug, Clone, Copy)]
pub struct SweepParams {
    pub reps: u64,
    pub seed: u64,
    pub converge: bool,
}

/// One cell of a sweep grid: one (policy, workload-config) data point,
/// evaluated over seeded repetitions.  Figures build flat
/// `Vec<SweepCell>` grids and hand them to [`Ctx::eval_grid`].
#[derive(Debug, Clone, Copy)]
pub struct SweepCell {
    pub policy: &'static str,
    pub cfg: SynthConfig,
    /// `Some(r)` => mean of per-seed MST ratios against `r`;
    /// `None` => mean raw MST.
    pub reference: Option<Reference>,
}

impl SweepCell {
    /// A ratio cell (the common case).
    pub fn ratio(policy: &'static str, reference: Reference, cfg: SynthConfig) -> SweepCell {
        SweepCell { policy, cfg, reference: Some(reference) }
    }

    /// Evaluate this cell: a pure function of (cell, params), safe to
    /// run on any worker.
    pub fn eval(&self, p: SweepParams) -> f64 {
        match self.reference {
            None => mst_mean(p, self.policy, &self.cfg),
            Some(r) => mst_ratio_mean(p, self.policy, r, &self.cfg),
        }
    }
}

/// Mean MST of `policy` over repetitions of `cfg`.
fn mst_mean(p: SweepParams, policy: &str, cfg: &SynthConfig) -> f64 {
    let mut reps = Repetitions::default();
    let max = if p.converge { p.reps * 10 } else { p.reps };
    for r in 0..max {
        let jobs = crate::workload::synthesize(cfg, p.seed.wrapping_add(r * 7919));
        reps.push(run_mst(policy, &jobs));
        if r + 1 >= p.reps && (!p.converge || reps.converged(p.reps as usize)) {
            break;
        }
    }
    reps.mean()
}

/// Mean of MST ratios policy/reference, paired per seed (paired ratios
/// suppress the enormous per-workload variance of heavy-tailed sizes —
/// the reason the paper needs thousands of repetitions for raw
/// averages).
fn mst_ratio_mean(p: SweepParams, policy: &str, reference: Reference, cfg: &SynthConfig) -> f64 {
    let mut reps = Repetitions::default();
    let max = if p.converge { p.reps * 10 } else { p.reps };
    for r in 0..max {
        let jobs = crate::workload::synthesize(cfg, p.seed.wrapping_add(r * 7919));
        let a = run_mst(policy, &jobs);
        let q = reference.mst(&jobs);
        reps.push(a / q);
        if r + 1 >= p.reps && (!p.converge || reps.converged(p.reps as usize)) {
            break;
        }
    }
    reps.mean()
}

impl Ctx {
    fn cfg(&self) -> SynthConfig {
        SynthConfig::default().with_njobs(self.njobs)
    }

    /// The worker-safe scalar slice of this context.
    pub fn params(&self) -> SweepParams {
        SweepParams { reps: self.reps, seed: self.seed, converge: self.converge }
    }

    /// Mean MST of `policy` over repetitions of `cfg`.
    pub fn mst(&self, policy: &str, cfg: &SynthConfig) -> f64 {
        mst_mean(self.params(), policy, cfg)
    }

    /// Mean of MST ratios policy/reference, paired per seed.
    pub fn mst_ratio(&self, policy: &str, reference: Reference, cfg: &SynthConfig) -> f64 {
        mst_ratio_mean(self.params(), policy, reference, cfg)
    }

    /// Evaluate a flat sweep grid on the work pool; results come back
    /// in cell order regardless of thread count.
    pub fn eval_grid(&self, cells: &[SweepCell]) -> Vec<f64> {
        let p = self.params();
        pool::par_map(self.threads, cells, move |c| c.eval(p))
    }

    /// Parallel map over arbitrary independent work items (figures
    /// whose cells aren't plain MST points: pooled slowdowns, trace
    /// replays, per-rep dual-policy runs).  Deterministic: results in
    /// item order.
    pub fn par_runs<T, R>(&self, items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R>
    where
        T: Sync,
        R: Send,
    {
        pool::par_map(self.threads, items, f)
    }
}

/// Normalization baseline for MST ratios.
#[derive(Debug, Clone, Copy)]
pub enum Reference {
    /// PS on the same workload (Fig. 3, Fig. 15).
    Ps,
    /// Optimal MST: SRPT with *exact* sizes (Figs. 5, 6, 10, 12-14).
    OptSrpt,
}

impl Reference {
    pub fn mst(&self, jobs: &[Job]) -> f64 {
        match self {
            Reference::Ps => run_mst("ps", jobs),
            Reference::OptSrpt => run_mst("srpt", &exact_copy(jobs)),
        }
    }
}

/// The same workload with perfect size information.
pub fn exact_copy(jobs: &[Job]) -> Vec<Job> {
    jobs.iter().map(|j| Job { est: j.size, ..*j }).collect()
}

/// Run one policy over one workload; returns MST.
pub fn run_mst(policy: &str, jobs: &[Job]) -> f64 {
    let mut s = sched::by_name(policy).unwrap_or_else(|| panic!("unknown policy {policy}"));
    sim::run(s.as_mut(), jobs).mst(jobs)
}

/// Run one policy; returns per-job slowdowns.
pub fn run_slowdowns(policy: &str, jobs: &[Job]) -> Vec<f64> {
    let mut s = sched::by_name(policy).unwrap_or_else(|| panic!("unknown policy {policy}"));
    sim::run(s.as_mut(), jobs).slowdowns(jobs)
}

/// Flat (x-major, policy-minor) ratio grid over `xs`, one row per x.
/// The shared shape of Figs. 5, 6, 10, 14 and friends.
fn ratio_rows(
    ctx: &Ctx,
    xs: &[f64],
    policies: &[&'static str],
    reference: Reference,
    cfg_of: impl Fn(f64) -> SynthConfig,
    table: &mut Table,
) {
    let mut cells = Vec::with_capacity(xs.len() * policies.len());
    for &x in xs {
        let cfg = cfg_of(x);
        for &p in policies {
            cells.push(SweepCell::ratio(p, reference, cfg));
        }
    }
    let vals = ctx.eval_grid(&cells);
    let mut it = vals.into_iter();
    for &x in xs {
        let mut row = vec![x];
        row.extend((&mut it).take(policies.len()));
        table.push(row);
    }
}

// --------------------------------------------------------------------
// Fig. 3 — MST against PS over the sigma x shape grid, 6 policies.
// --------------------------------------------------------------------
pub fn fig3(ctx: &Ctx) -> Vec<Table> {
    let policies = ["srpte", "srpte+ps", "srpte+las", "fspe", "fspe+ps", "fspe+las"];
    let mut t = Table::new(
        "fig3_mst_vs_ps",
        ["shape", "sigma"].iter().chain(policies.iter()).map(|s| s.to_string()).collect(),
    );
    let mut cells = Vec::with_capacity(GRID.len() * GRID.len() * policies.len());
    for &shape in &GRID {
        for &sigma in &GRID {
            let cfg = ctx.cfg().with_shape(shape).with_sigma(sigma);
            for &p in &policies {
                cells.push(SweepCell::ratio(p, Reference::Ps, cfg));
            }
        }
    }
    let vals = ctx.eval_grid(&cells);
    let mut it = vals.into_iter();
    for &shape in &GRID {
        for &sigma in &GRID {
            let mut row = vec![shape, sigma];
            row.extend((&mut it).take(policies.len()));
            t.push(row);
        }
    }
    vec![t]
}

// --------------------------------------------------------------------
// Fig. 4 — per-job slowdown ECDF of the §5.1 proposals vs PS.
// --------------------------------------------------------------------
pub fn fig4(ctx: &Ctx) -> Vec<Table> {
    let policies = ["ps", "srpte+ps", "srpte+las", "fspe+ps", "fspe+las"];
    let thresholds = metrics::log_thresholds(128, 3.0);
    let seed = ctx.seed;
    let mut out = Vec::new();
    for &shape in &[0.5, 0.25, 0.125] {
        let mut t = Table::new(
            format!("fig4_slowdown_ecdf_shape{shape}"),
            ["slowdown"].iter().map(|s| s.to_string()).chain(policies.iter().map(|s| s.to_string())).collect(),
        );
        let cfg = ctx.cfg().with_shape(shape);
        // Reps run in parallel, one policy at a time (the fig7 shape):
        // rep order inside each policy matches the serial loop, so the
        // pooled ECDFs are bit-identical, and peak memory stays at one
        // policy's pooled population as in the serial path.  The paper
        // pools runs too.
        let rep_items: Vec<u64> = (0..ctx.reps).collect();
        let mut ecdfs: Vec<Vec<f64>> = Vec::new();
        for &policy in &policies {
            let runs = ctx.par_runs(&rep_items, |&r| {
                let jobs = crate::workload::synthesize(&cfg, seed.wrapping_add(r * 7919));
                run_slowdowns(policy, &jobs)
            });
            let mut pooled = Vec::new();
            for slow in runs {
                pooled.extend(slow);
            }
            ecdfs.push(metrics::slowdown_ecdf(&pooled, &thresholds));
        }
        for (i, &thr) in thresholds.iter().enumerate() {
            let mut row = vec![thr];
            row.extend(ecdfs.iter().map(|e| e[i]));
            t.push(row);
        }
        out.push(t);
    }
    out
}

// --------------------------------------------------------------------
// Fig. 5 — MST / optimal vs shape, all policies (sigma = 0.5).
// --------------------------------------------------------------------
pub fn fig5(ctx: &Ctx) -> Vec<Table> {
    let policies = ["psbs", "srpte", "fspe", "ps", "las", "fifo"];
    let mut t = Table::new(
        "fig5_mst_vs_shape",
        ["shape"].iter().map(|s| s.to_string()).chain(policies.iter().map(|s| s.to_string())).collect(),
    );
    let base = ctx.cfg();
    ratio_rows(ctx, &GRID, &policies, Reference::OptSrpt, |shape| base.with_shape(shape), &mut t);
    vec![t]
}

// --------------------------------------------------------------------
// Fig. 6 — MST / optimal vs sigma for three heavy-tailed shapes.
// --------------------------------------------------------------------
pub fn fig6(ctx: &Ctx) -> Vec<Table> {
    let policies = ["psbs", "srpte", "fspe", "ps", "las"];
    let mut out = Vec::new();
    for &shape in &[0.5, 0.25, 0.125] {
        let mut t = Table::new(
            format!("fig6_mst_vs_sigma_shape{shape}"),
            ["sigma"].iter().map(|s| s.to_string()).chain(policies.iter().map(|s| s.to_string())).collect(),
        );
        let base = ctx.cfg().with_shape(shape);
        ratio_rows(ctx, &GRID, &policies, Reference::OptSrpt, |sigma| base.with_sigma(sigma), &mut t);
        out.push(t);
    }
    out
}

// --------------------------------------------------------------------
// Fig. 7 — mean conditional slowdown vs job size (100 classes).
// --------------------------------------------------------------------
pub fn fig7(ctx: &Ctx) -> Vec<Table> {
    let policies = ["fifo", "srpte", "fspe", "ps", "las", "psbs"];
    let cfg = ctx.cfg();
    let seed = ctx.seed;
    let mut t = Table::new(
        "fig7_conditional_slowdown",
        ["size"].iter().map(|s| s.to_string()).chain(policies.iter().map(|s| s.to_string())).collect(),
    );
    // One pooled population across reps, analyzed per policy.  Reps
    // run in parallel but one policy is materialized at a time: the
    // cells return full (jobs, slowdowns) populations, so batching all
    // policies at once would multiply peak memory by the policy count
    // versus the serial path.  Pooling stays in the serial order.
    let rep_items: Vec<u64> = (0..ctx.reps).collect();
    let mut per_policy: Vec<Vec<(f64, f64)>> = Vec::new();
    for &policy in &policies {
        let runs = ctx.par_runs(&rep_items, |&r| {
            let jobs = crate::workload::synthesize(&cfg, seed.wrapping_add(r * 7919));
            let mut s = sched::by_name(policy).unwrap();
            let res = sim::run(s.as_mut(), &jobs);
            let slow = res.slowdowns(&jobs);
            (jobs, slow)
        });
        let mut jobs_all: Vec<Job> = Vec::new();
        let mut slow_all: Vec<f64> = Vec::new();
        for (jobs, slow) in runs {
            slow_all.extend(slow);
            jobs_all.extend(jobs);
        }
        per_policy.push(conditional_via_runtime(ctx, &jobs_all, &slow_all));
    }
    let bins = per_policy[0].len();
    for b in 0..bins {
        // Mean size per class is policy-independent (same workloads).
        let mut row = vec![per_policy[0][b].0];
        for pp in &per_policy {
            row.push(pp.get(b).map(|x| x.1).unwrap_or(f64::NAN));
        }
        t.push(row);
    }
    vec![t]
}

/// Conditional slowdown through the analytics artifact when loaded
/// (production path), pure rust otherwise.  Returns (mean size, mean
/// slowdown) per equal-count class.  Always runs on the main thread —
/// the runtime handle never crosses into the pool.
fn conditional_via_runtime(ctx: &Ctx, jobs: &[Job], slowdowns: &[f64]) -> Vec<(f64, f64)> {
    let rust_way = metrics::conditional_slowdown(jobs, slowdowns, metrics::COND_BINS);
    match &ctx.runtime {
        None => rust_way,
        Some(rt) => {
            // The artifact computes slowdown = sojourn/size itself; feed
            // sojourn = slowdown * size so both paths share inputs.
            let sizes: Vec<f64> = jobs.iter().map(|j| j.size).collect();
            let sojourns: Vec<f64> =
                jobs.iter().zip(slowdowns).map(|(j, s)| j.size * s).collect();
            let idx = metrics::bin_indices(jobs, metrics::COND_BINS);
            let thr = metrics::log_thresholds(rt.manifest.num_thresholds, 3.0);
            match rt.analyze(&sizes, &sojourns, &idx, &thr) {
                Ok(out) => {
                    let means = out.conditional_slowdown();
                    // Pair with the rust-side mean sizes (the artifact
                    // aggregates slowdowns; sizes come from the same
                    // equal-count classes).
                    rust_way
                        .iter()
                        .zip(means)
                        .map(|(&(sz, _), m)| (sz, m))
                        .collect()
                }
                Err(e) => {
                    eprintln!("warning: analytics artifact failed ({e:#}); using rust fallback");
                    rust_way
                }
            }
        }
    }
}

// --------------------------------------------------------------------
// Fig. 8 — per-job slowdown CDF, defaults, + tail zoom numbers.
// --------------------------------------------------------------------
pub fn fig8(ctx: &Ctx) -> Vec<Table> {
    let policies = ["fifo", "srpte", "fspe", "ps", "las", "psbs"];
    let thresholds = metrics::log_thresholds(128, 4.0);
    let cfg = ctx.cfg();
    let seed = ctx.seed;
    let mut t = Table::new(
        "fig8_perjob_slowdown_cdf",
        ["slowdown"].iter().map(|s| s.to_string()).chain(policies.iter().map(|s| s.to_string())).collect(),
    );
    let mut tails = Table::new(
        "fig8_tail_above_100",
        vec!["policy_idx".to_string(), "frac_above_100".to_string()],
    );
    // Per-policy batches of parallel reps, as in fig4/fig7: flat peak
    // memory, serial pooling order.
    let rep_items: Vec<u64> = (0..ctx.reps).collect();
    let mut ecdfs = Vec::new();
    for (pi, &policy) in policies.iter().enumerate() {
        let runs = ctx.par_runs(&rep_items, |&r| {
            let jobs = crate::workload::synthesize(&cfg, seed.wrapping_add(r * 7919));
            run_slowdowns(policy, &jobs)
        });
        let mut pooled = Vec::new();
        for slow in runs {
            pooled.extend(slow);
        }
        tails.push(vec![pi as f64, metrics::frac_above(&pooled, 100.0)]);
        ecdfs.push(metrics::slowdown_ecdf(&pooled, &thresholds));
    }
    for (i, &thr) in thresholds.iter().enumerate() {
        let mut row = vec![thr];
        row.extend(ecdfs.iter().map(|e| e[i]));
        t.push(row);
    }
    vec![t, tails]
}

// --------------------------------------------------------------------
// Fig. 9 — weighted classes: PSBS vs DPS, beta in {0,1,2}.
// --------------------------------------------------------------------
pub fn fig9(ctx: &Ctx) -> Vec<Table> {
    let seed = ctx.seed;
    let mut out = Vec::new();
    for &shape in &[0.25, 4.0] {
        let mut t = Table::new(
            format!("fig9_weights_shape{shape}"),
            vec![
                "beta".into(),
                "class".into(),
                "psbs_mst".into(),
                "dps_mst".into(),
            ],
        );
        for &beta in &[0.0, 1.0, 2.0] {
            let cfg = ctx.cfg().with_shape(shape).with_beta(beta);
            // One work item per repetition: both policies run on the
            // shared workload inside the cell, and the per-class means
            // are reduced *inside* the cell too (identical arithmetic
            // to the serial path), so each rep returns ~10 floats
            // instead of its full job/sojourn vectors — peak memory
            // stays flat in --reps.
            let rep_items: Vec<u64> = (0..ctx.reps).collect();
            let runs = ctx.par_runs(&rep_items, |&r| {
                let jobs = crate::workload::synthesize(&cfg, seed.wrapping_add(r * 7919));
                let mut class_means = [[None::<f64>; 5]; 2];
                for (pi, policy) in ["psbs", "dps"].into_iter().enumerate() {
                    let mut sch = sched::by_name(policy).unwrap();
                    let soj = sim::run(sch.as_mut(), &jobs).sojourns(&jobs);
                    for class in 1..=5usize {
                        let vals: Vec<f64> = jobs
                            .iter()
                            .zip(&soj)
                            .filter(|(j, _)| {
                                crate::workload::synthetic::weight_class(j.weight, beta)
                                    == class
                            })
                            .map(|(_, &s)| s)
                            .collect();
                        if !vals.is_empty() {
                            class_means[pi][class - 1] = Some(crate::stats::mean(&vals));
                        }
                    }
                }
                class_means
            });
            // Per-class MST accumulators over reps (serial order).
            let mut acc: Vec<(Repetitions, Repetitions)> =
                (0..5).map(|_| Default::default()).collect();
            for class_means in runs {
                for (pi, means) in class_means.iter().enumerate() {
                    for class in 1..=5usize {
                        if let Some(m) = means[class - 1] {
                            if pi == 0 {
                                acc[class - 1].0.push(m);
                            } else {
                                acc[class - 1].1.push(m);
                            }
                        }
                    }
                }
            }
            for class in 1..=5usize {
                t.push(vec![
                    beta,
                    class as f64,
                    acc[class - 1].0.mean(),
                    acc[class - 1].1.mean(),
                ]);
            }
        }
        out.push(t);
    }
    out
}

// --------------------------------------------------------------------
// Fig. 10 — Pareto job sizes, alpha in {2, 1}.
// --------------------------------------------------------------------
pub fn fig10(ctx: &Ctx) -> Vec<Table> {
    let policies = ["psbs", "srpte", "fspe", "ps", "las"];
    let mut out = Vec::new();
    for &alpha in &[2.0, 1.0] {
        let mut t = Table::new(
            format!("fig10_pareto_alpha{alpha}"),
            ["sigma"].iter().map(|s| s.to_string()).chain(policies.iter().map(|s| s.to_string())).collect(),
        );
        let njobs = ctx.njobs;
        ratio_rows(
            ctx,
            &GRID,
            &policies,
            Reference::OptSrpt,
            |sigma| SynthConfig {
                size_dist: SizeDist::Pareto { alpha },
                sigma,
                njobs,
                ..SynthConfig::default()
            },
            &mut t,
        );
        out.push(t);
    }
    out
}

// --------------------------------------------------------------------
// Fig. 11 — CCDF of trace job sizes (stand-ins; see DESIGN.md §4).
// --------------------------------------------------------------------
pub fn fig11(ctx: &Ctx) -> Vec<Table> {
    let mut t = Table::new(
        "fig11_trace_ccdf",
        vec![
            "fb_size_over_mean".into(),
            "fb_ccdf".into(),
            "ir_size_over_mean".into(),
            "ir_ccdf".into(),
        ],
    );
    let fb = traces::ccdf(&traces::synth_trace(&traces::FACEBOOK, ctx.seed), 100);
    let ir = traces::ccdf(&traces::synth_trace(&traces::IRCACHE, ctx.seed), 100);
    for i in 0..100 {
        t.push(vec![fb[i].0, fb[i].1, ir[i].0, ir[i].1]);
    }
    vec![t]
}

// --------------------------------------------------------------------
// Figs. 12/13 — trace replay: MST / optimal vs sigma.
// --------------------------------------------------------------------
pub fn fig12(ctx: &Ctx) -> Vec<Table> {
    vec![trace_fig("fig12_facebook", &traces::FACEBOOK, ctx, ctx.njobs.min(24_443))]
}

pub fn fig13(ctx: &Ctx) -> Vec<Table> {
    // Full IRCache is 206 914 requests; scale by ctx.njobs for speed.
    vec![trace_fig("fig13_ircache", &traces::IRCACHE, ctx, ctx.njobs.min(206_914))]
}

fn trace_fig(name: &str, stats: &traces::TraceStats, ctx: &Ctx, njobs: usize) -> Table {
    let policies = ["psbs", "fspe", "srpte", "ps", "las"];
    let mut t = Table::new(
        name,
        ["sigma"].iter().map(|s| s.to_string()).chain(policies.iter().map(|s| s.to_string())).collect(),
    );
    let seed0 = ctx.seed;
    // One work item per (sigma, repetition): synthesize the replay and
    // return the per-policy MST/opt ratios for that seed.
    let items: Vec<(f64, u64)> = GRID
        .iter()
        .flat_map(|&sigma| (0..ctx.reps).map(move |r| (sigma, r)))
        .collect();
    let ratios = ctx.par_runs(&items, |&(sigma, r)| {
        let seed = seed0.wrapping_add(r * 104_729);
        let mut recs = traces::synth_trace(stats, seed);
        recs.truncate(njobs);
        let jobs = traces::to_jobs(&recs, 0.9, sigma, seed);
        let opt = Reference::OptSrpt.mst(&jobs);
        policies.iter().map(|p| run_mst(p, &jobs) / opt).collect::<Vec<f64>>()
    });
    let mut it = ratios.into_iter();
    for &sigma in &GRID {
        let mut accs: Vec<Repetitions> = policies.iter().map(|_| Default::default()).collect();
        for _ in 0..ctx.reps {
            let rs = it.next().unwrap();
            for (acc, v) in accs.iter_mut().zip(rs) {
                acc.push(v);
            }
        }
        let mut row = vec![sigma];
        row.extend(accs.iter().map(|a| a.mean()));
        t.push(row);
    }
    t
}

// --------------------------------------------------------------------
// Fig. 14 — impact of load and timeshape (appendix A.2).
// --------------------------------------------------------------------
pub fn fig14(ctx: &Ctx) -> Vec<Table> {
    let policies = ["psbs", "srpte", "fspe", "ps", "las"];
    let base = ctx.cfg();
    let mut load_t = Table::new(
        "fig14a_load",
        ["load"].iter().map(|s| s.to_string()).chain(policies.iter().map(|s| s.to_string())).collect(),
    );
    let loads = [0.5, 0.7, 0.9, 0.95, 0.999];
    ratio_rows(ctx, &loads, &policies, Reference::OptSrpt, |load| base.with_load(load), &mut load_t);

    let mut ts_t = Table::new(
        "fig14b_timeshape",
        ["timeshape"].iter().map(|s| s.to_string()).chain(policies.iter().map(|s| s.to_string())).collect(),
    );
    ratio_rows(ctx, &GRID, &policies, Reference::OptSrpt, |tsh| base.with_timeshape(tsh), &mut ts_t);
    vec![load_t, ts_t]
}

// --------------------------------------------------------------------
// Fig. 15 — PSBS vs PS across shape x {load, timeshape, njobs}.
// --------------------------------------------------------------------
pub fn fig15(ctx: &Ctx) -> Vec<Table> {
    let shapes = GRID;
    let mut out = Vec::new();

    // Each sub-figure is a flat (shape x secondary) grid of single
    // psbs/PS ratio cells.
    let mut t = Table::new("fig15a_load", vec!["shape".into(), "load".into(), "psbs_over_ps".into()]);
    let loads = [0.5, 0.9, 0.999];
    let mut cells = Vec::new();
    for &shape in &shapes {
        for &load in &loads {
            cells.push(SweepCell::ratio(
                "psbs",
                Reference::Ps,
                ctx.cfg().with_shape(shape).with_load(load),
            ));
        }
    }
    let vals = ctx.eval_grid(&cells);
    let mut it = vals.into_iter();
    for &shape in &shapes {
        for &load in &loads {
            t.push(vec![shape, load, it.next().unwrap()]);
        }
    }
    out.push(t);

    let mut t = Table::new(
        "fig15b_timeshape",
        vec!["shape".into(), "timeshape".into(), "psbs_over_ps".into()],
    );
    let tshapes = [0.125, 1.0, 4.0];
    let mut cells = Vec::new();
    for &shape in &shapes {
        for &tsh in &tshapes {
            cells.push(SweepCell::ratio(
                "psbs",
                Reference::Ps,
                ctx.cfg().with_shape(shape).with_timeshape(tsh),
            ));
        }
    }
    let vals = ctx.eval_grid(&cells);
    let mut it = vals.into_iter();
    for &shape in &shapes {
        for &tsh in &tshapes {
            t.push(vec![shape, tsh, it.next().unwrap()]);
        }
    }
    out.push(t);

    let mut t = Table::new(
        "fig15c_njobs",
        vec!["shape".into(), "njobs".into(), "psbs_over_ps".into()],
    );
    let njob_grid = [1_000usize, 10_000, 100_000];
    let mut cells = Vec::new();
    let mut xs: Vec<(f64, f64)> = Vec::new();
    for &shape in &shapes {
        for &njobs in &njob_grid {
            let njobs = njobs.min(ctx.njobs * 10);
            cells.push(SweepCell::ratio(
                "psbs",
                Reference::Ps,
                ctx.cfg().with_shape(shape).with_njobs(njobs),
            ));
            xs.push((shape, njobs as f64));
        }
    }
    let vals = ctx.eval_grid(&cells);
    for ((shape, njobs), v) in xs.into_iter().zip(vals) {
        t.push(vec![shape, njobs, v]);
    }
    out.push(t);
    out
}

// --------------------------------------------------------------------
// Extension experiments (not in the paper; DESIGN.md §3 E20-E22).
// --------------------------------------------------------------------

/// E20 — ablation of the Algorithm-1 bookkeeping fix: PSBS vs the
/// paper-literal pseudocode (`w_v` kept inflated for late jobs) across
/// error levels on the default heavy tail.  Quantifies why the module
/// note's interpretation matters.
pub fn ablation_wv(ctx: &Ctx) -> Vec<Table> {
    let policies = ["psbs", "psbs-paperlit", "fspe", "fspe+ps"];
    let mut t = Table::new(
        "ext_ablation_wv",
        ["sigma"].iter().map(|s| s.to_string()).chain(policies.iter().map(|s| s.to_string())).collect(),
    );
    let base = ctx.cfg();
    ratio_rows(ctx, &GRID, &policies, Reference::OptSrpt, |sigma| base.with_sigma(sigma), &mut t);

    // The real cost of the literal pseudocode is unbounded state: a job
    // that goes late never leaves the virtual system (its weight stays
    // in w_v and its heap entry in O/E forever).  Measure the residual
    // virtual population after a fully drained run.
    let mut resid = Table::new(
        "ext_ablation_wv_residue",
        vec!["sigma".into(), "psbs_residue".into(), "paperlit_residue".into()],
    );
    let seed = ctx.seed;
    let cfgs: Vec<SynthConfig> = GRID.iter().map(|&sigma| ctx.cfg().with_sigma(sigma)).collect();
    let residues = ctx.par_runs(&cfgs, |cfg| {
        let jobs = crate::workload::synthesize(cfg, seed);
        let mut fixed = crate::sched::fsp_family::Psbs::new();
        sim::run(&mut fixed, &jobs);
        let mut lit = crate::sched::fsp_family::FspFamily::psbs_paper_literal();
        sim::run(&mut lit, &jobs);
        (fixed.virtual_residue() as f64, lit.virtual_residue() as f64)
    });
    for (&sigma, (fixed, lit)) in GRID.iter().zip(residues) {
        resid.push(vec![sigma, fixed, lit]);
    }
    vec![t, resid]
}

/// E21 — practical estimators (§2.2) in front of PSBS and SRPTE:
/// oracle, HFSP-style sampling at three sampled fractions, a
/// semi-clairvoyant size-class estimator, and log-normal sigma = 0.5
/// for reference.
pub fn estimators(ctx: &Ctx) -> Vec<Table> {
    use crate::estimate;
    let mut t = Table::new(
        "ext_estimators",
        vec![
            "estimator_idx".into(),
            "log_sigma".into(),
            "correlation".into(),
            "psbs".into(),
            "srpte".into(),
        ],
    );
    // Trait objects aren't Sync; cells rebuild their estimator from
    // the index instead of sharing boxed instances across threads.
    const N_EST: usize = 6;
    fn build(ei: usize) -> Box<dyn crate::estimate::Estimator> {
        match ei {
            0 => Box::new(crate::estimate::OracleEstimator),
            1 => Box::new(crate::estimate::SamplingEstimator::new(0.01, 0.5)),
            2 => Box::new(crate::estimate::SamplingEstimator::new(0.05, 0.5)),
            3 => Box::new(crate::estimate::SamplingEstimator::new(0.25, 0.5)),
            4 => Box::new(crate::estimate::ClassEstimator),
            _ => Box::new(crate::estimate::LogNormalNoise::new(0.5)),
        }
    }
    let base_cfg = ctx.cfg().with_sigma(0.0);
    let seed = ctx.seed;
    let items: Vec<(usize, u64)> = (0..N_EST)
        .flat_map(|ei| (0..ctx.reps).map(move |r| (ei, r)))
        .collect();
    let runs = ctx.par_runs(&items, |&(ei, r)| {
        let est = build(ei);
        let base = crate::workload::synthesize(&base_cfg, seed.wrapping_add(r * 7919));
        let jobs = estimate::apply(&base, est.as_ref(), seed.wrapping_add(r));
        let stats = estimate::measure(&jobs);
        let opt = Reference::OptSrpt.mst(&jobs);
        (
            stats.log_sigma,
            stats.correlation,
            run_mst("psbs", &jobs) / opt,
            run_mst("srpte", &jobs) / opt,
        )
    });
    let mut it = runs.into_iter();
    for ei in 0..N_EST {
        let mut quality = (0.0, 0.0);
        let mut psbs_acc = Repetitions::default();
        let mut srpte_acc = Repetitions::default();
        for _ in 0..ctx.reps {
            let (log_sigma, corr, p, s) = it.next().unwrap();
            quality = (log_sigma, corr);
            psbs_acc.push(p);
            srpte_acc.push(s);
        }
        t.push(vec![ei as f64, quality.0, quality.1, psbs_acc.mean(), srpte_acc.mean()]);
    }
    vec![t]
}

/// E22 — multi-server scaling: MST of a k-server PSBS cluster at fixed
/// per-server load 0.9, least-work vs round-robin dispatch.
pub fn cluster_scaling(ctx: &Ctx) -> Vec<Table> {
    use crate::coordinator::{Cluster, Dispatch};
    let mut t = Table::new(
        "ext_cluster_scaling",
        vec!["k".into(), "leastwork".into(), "roundrobin".into(), "random".into()],
    );
    let dispatches = [Dispatch::LeastWork, Dispatch::RoundRobin, Dispatch::Random];
    let ks = [1usize, 2, 4, 8];
    let seed = ctx.seed;
    // One work item per (k, dispatch, rep), in the serial loop order.
    let mut items: Vec<(usize, usize, u64, SynthConfig)> = Vec::new();
    for &k in &ks {
        // Offered load k*0.9 against k unit servers.
        let cfg = ctx.cfg().with_load(0.9 * k as f64).with_njobs(ctx.njobs.min(10_000));
        for di in 0..dispatches.len() {
            for r in 0..ctx.reps {
                items.push((k, di, r, cfg));
            }
        }
    }
    let msts = ctx.par_runs(&items, |&(k, di, r, cfg)| {
        let jobs = crate::workload::synthesize(&cfg, seed.wrapping_add(r * 7919));
        let mut c = Cluster::new("psbs", k, dispatches[di], seed).unwrap();
        sim::run(&mut c, &jobs).mst(&jobs)
    });
    let mut it = msts.into_iter();
    for &k in &ks {
        let mut row = vec![k as f64];
        for _ in 0..dispatches.len() {
            let mut acc = Repetitions::default();
            for _ in 0..ctx.reps {
                acc.push(it.next().unwrap());
            }
            row.push(acc.mean());
        }
        t.push(row);
    }
    vec![t]
}

/// All figures by number (3-15 = the paper's; 20-22 = extensions).
pub fn by_number(ctx: &Ctx, fig: u64) -> Option<Vec<Table>> {
    Some(match fig {
        3 => fig3(ctx),
        4 => fig4(ctx),
        5 => fig5(ctx),
        6 => fig6(ctx),
        7 => fig7(ctx),
        8 => fig8(ctx),
        9 => fig9(ctx),
        10 => fig10(ctx),
        11 => fig11(ctx),
        12 => fig12(ctx),
        13 => fig13(ctx),
        14 => fig14(ctx),
        15 => fig15(ctx),
        20 => ablation_wv(ctx),
        21 => estimators(ctx),
        22 => cluster_scaling(ctx),
        _ => return None,
    })
}

/// Figure numbers in sweep order (paper figures then extensions).
pub const ALL_FIGS: [u64; 16] = [3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 20, 21, 22];

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ctx() -> Ctx {
        Ctx { reps: 1, njobs: 300, seed: 7, ..Default::default() }
    }

    fn table_bits(tables: &[Table]) -> Vec<Vec<Vec<u64>>> {
        tables
            .iter()
            .map(|t| t.rows.iter().map(|r| r.iter().map(|v| v.to_bits()).collect()).collect())
            .collect()
    }

    #[test]
    fn fig5_shapes_hold_at_small_scale() {
        let ctx = tiny_ctx();
        let t = &fig5(&ctx)[0];
        // Columns: shape, psbs, srpte, fspe, ps, las, fifo.
        for row in &t.rows {
            // Every ratio to the optimum is >= ~1 (tolerance for ties).
            for &v in &row[1..] {
                assert!(v > 0.9, "ratio {v} below optimal in {row:?}");
            }
        }
    }

    #[test]
    fn exact_copy_strips_errors() {
        let jobs = vec![Job { id: 0, arrival: 0.0, size: 2.0, est: 5.0, weight: 1.0 }];
        assert_eq!(exact_copy(&jobs)[0].est, 2.0);
    }

    /// Acceptance check for the parallel sweep executor: a full Fig. 6
    /// regeneration (the sigma sweep, all three shape tables) is
    /// bit-identical across thread counts {1, 2, 4}.
    #[test]
    fn parallel_sweep_is_bit_identical() {
        let serial = {
            let ctx = Ctx { reps: 2, njobs: 200, seed: 11, threads: 1, ..Default::default() };
            table_bits(&fig6(&ctx))
        };
        for threads in [2usize, 4] {
            let ctx = Ctx { reps: 2, njobs: 200, seed: 11, threads, ..Default::default() };
            let par = table_bits(&fig6(&ctx));
            assert_eq!(serial, par, "fig6 output diverged at {threads} threads");
        }
    }

    /// The pooled-population path (per-(policy, rep) work items) is
    /// deterministic too: Fig. 4 at 1 vs 3 threads.
    #[test]
    fn pooled_figures_are_bit_identical() {
        let serial = {
            let ctx = Ctx { reps: 2, njobs: 150, seed: 5, threads: 1, ..Default::default() };
            table_bits(&fig4(&ctx))
        };
        let par = {
            let ctx = Ctx { reps: 2, njobs: 150, seed: 5, threads: 3, ..Default::default() };
            table_bits(&fig4(&ctx))
        };
        assert_eq!(serial, par, "fig4 pooled ECDFs diverged under parallel execution");
    }

    /// Every figure function executes end to end at tiny scale and
    /// yields non-empty, finite-x tables (a safety net for the sweep
    /// CLI — individual figure *values* are checked elsewhere).  Runs
    /// with 2 worker threads so the parallel path is exercised across
    /// every figure's work-item shape.
    #[test]
    fn all_figures_execute_at_tiny_scale() {
        let ctx = Ctx { reps: 1, njobs: 120, seed: 3, threads: 2, ..Default::default() };
        for f in ALL_FIGS {
            let tables = by_number(&ctx, f).unwrap();
            assert!(!tables.is_empty(), "fig {f} produced no tables");
            for t in &tables {
                assert!(!t.rows.is_empty(), "fig {f}: table {} empty", t.name);
                for row in &t.rows {
                    assert_eq!(row.len(), t.header.len(), "fig {f}: ragged row");
                    assert!(row[0].is_finite(), "fig {f}: non-finite x");
                }
            }
        }
    }

    #[test]
    fn by_number_covers_all() {
        for f in ALL_FIGS {
            // Just check dispatch, not execution (expensive).
            assert!(matches!(f, 3..=15 | 20..=22));
        }
        let ctx = tiny_ctx();
        assert!(by_number(&ctx, 99).is_none());
    }
}
