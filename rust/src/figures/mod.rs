//! Figure regeneration harness: one function per figure/table of the
//! paper's evaluation (§7, appendix A.2), each producing [`Table`]s
//! that print the same rows/series the paper plots and land in
//! `results/*.csv`.
//!
//! Absolute numbers differ from the paper's (different RNG, reduced
//! repetition counts unless `--reps`/`--paper-scale` raise them); the
//! *shapes* — who wins, by what factor, where crossovers sit — are the
//! reproduction targets, recorded in EXPERIMENTS.md.
//!
//! ## Declarative scenarios & the shared-workload planner
//!
//! Every scenario-shaped figure — ratio grids (3/5/6/10/14/15),
//! pooled slowdown ECDFs (4/8), conditional slowdowns (7) and trace
//! replays (12/13, stand-ins or on-disk trace files) — is a
//! [`crate::scenario::Scenario`] declaration ([`scenarios_for`] is
//! the single source; `psbs scenario export` dumps them as the
//! committed `scenarios/*.toml` files) evaluated by one generic
//! executor; the remaining figures (per-rep dual-policy runs, CCDFs)
//! describe flat work-item lists run through [`Ctx::par_runs`].  Cell grids go through the
//! [`crate::scenario::planner`]: cells sharing a workload spec are
//! grouped so each `(workload, seed)` workload is synthesized **once**
//! and each reference MST computed **once per seed**, with per-policy
//! simulations fanned out through [`crate::util::pool`]
//! (`Ctx::threads` workers, cost-aware largest-first ordering).
//!
//! Sharing and parallelism are both numerically no-ops: every value is
//! a pure function of (cell, repetition seed), seeds derive
//! independently (`seed + r * 7919`; trace replays keep their
//! historical `r * 104_729` schedule), and results reassemble in cell
//! order — so planner output is **bit-identical** to the per-cell
//! legacy path (`Ctx::share = false`) and parallel output to the
//! serial path (`threads == 1`).
//! `tests::planner_reproduces_per_cell_figures_bitwise` and
//! `tests::parallel_sweep_is_bit_identical` pin both down.

pub mod plot;
pub mod tables;

use crate::metrics;
use crate::scenario::{self, AxisParam, Metric, Scenario, TraceSpec};
use crate::sched;
use crate::sim::{self, Job};
use crate::stats::Repetitions;
use crate::util::pool;
use crate::workload::traces::TraceName;
use crate::workload::{traces, SynthConfig};
pub use crate::scenario::{exact_copy, Reference, SweepCell, SweepParams};
pub use tables::Table;

/// Shared sweep context.
pub struct Ctx {
    /// Repetitions per data point (paper: >= 30; default here: 5).
    pub reps: u64,
    /// Override Table-1 njobs (smaller = faster sweeps).
    pub njobs: usize,
    /// Base seed.
    pub seed: u64,
    /// Output directory for CSVs.
    ///
    /// (The AOT runtime handle that used to live here is gone: its
    /// last figure-path consumer was Fig. 7's bespoke main-thread
    /// loop, replaced by [`Metric::CondSlowdown`] in the scenario
    /// layer.  The artifact pipelines stay cross-checked against the
    /// pure-rust metrics in `rust/tests/integration.rs` and benched
    /// in `rust/benches/runtime.rs`.)
    pub out_dir: String,
    /// Keep repeating past `reps` (up to 10x) until the 95% CI is
    /// within 5% of the mean (§6.3) — slow; off by default.
    pub converge: bool,
    /// Worker threads for grid evaluation (1 = the exact serial path;
    /// results are bit-identical either way).
    pub threads: usize,
    /// Route cell grids through the shared-workload planner (default).
    /// `false` = the per-cell legacy path of PR 1, kept as the
    /// reference the bit-identity tests compare against.
    pub share: bool,
}

impl Default for Ctx {
    fn default() -> Self {
        Ctx {
            reps: 5,
            njobs: 10_000,
            seed: 42,
            out_dir: "results".to_string(),
            converge: false,
            threads: 1,
            share: true,
        }
    }
}

/// The grid used for shape/sigma sweeps (paper: 0.125 .. 4, log-spaced).
pub const GRID: [f64; 6] = [0.125, 0.25, 0.5, 1.0, 2.0, 4.0];

impl Ctx {
    fn cfg(&self) -> SynthConfig {
        SynthConfig::default().with_njobs(self.njobs)
    }

    /// The worker-safe scalar slice of this context.
    pub fn params(&self) -> SweepParams {
        SweepParams { reps: self.reps, seed: self.seed, converge: self.converge }
    }

    /// Mean MST of `policy` over repetitions of `cfg`.
    pub fn mst(&self, policy: &str, cfg: &SynthConfig) -> f64 {
        SweepCell::mst(policy, *cfg).eval(self.params())
    }

    /// Mean of MST ratios policy/reference, paired per seed (paired
    /// ratios suppress the enormous per-workload variance of
    /// heavy-tailed sizes — the reason the paper needs thousands of
    /// repetitions for raw averages).
    pub fn mst_ratio(&self, policy: &str, reference: Reference, cfg: &SynthConfig) -> f64 {
        SweepCell::ratio(policy, reference, *cfg).eval(self.params())
    }

    /// Evaluate a flat sweep grid; results come back in cell order
    /// regardless of thread count or sharing mode.
    pub fn eval_grid(&self, cells: &[SweepCell]) -> Vec<f64> {
        scenario::eval_cells(self.params(), self.threads, self.share, cells)
    }

    /// Evaluate a declarative scenario into its tables (one per split
    /// grid point, plus the ECDF metric's optional tail table).
    pub fn eval_scenario(&self, sc: &Scenario) -> Vec<Table> {
        sc.tables(self.params(), self.threads, self.share)
    }

    /// Evaluate a scenario list, concatenating the tables in order.
    pub fn eval_scenarios(&self, scs: &[Scenario]) -> Vec<Table> {
        scs.iter().flat_map(|sc| self.eval_scenario(sc)).collect()
    }

    /// Parallel map over arbitrary independent work items (figures
    /// whose cells aren't plain MST points: pooled slowdowns, trace
    /// replays, per-rep dual-policy runs).  Deterministic: results in
    /// item order.
    pub fn par_runs<T, R>(&self, items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R>
    where
        T: Sync,
        R: Send,
    {
        pool::par_map(self.threads, items, f)
    }
}

/// Run one policy over one workload; returns MST.  Accepts any policy
/// spec string (`by_name` is a shim over the [`crate::scenario`]
/// parser).
pub fn run_mst(policy: &str, jobs: &[Job]) -> f64 {
    let mut s = sched::by_name(policy).unwrap_or_else(|| panic!("unknown policy {policy}"));
    sim::run(s.as_mut(), jobs).mst(jobs)
}

/// Run one policy; returns per-job slowdowns.
pub fn run_slowdowns(policy: &str, jobs: &[Job]) -> Vec<f64> {
    let mut s = sched::by_name(policy).unwrap_or_else(|| panic!("unknown policy {policy}"));
    sim::run(s.as_mut(), jobs).slowdowns(jobs)
}

// --------------------------------------------------------------------
// Scenario-shaped figures: one declaration each, one generic executor.
// --------------------------------------------------------------------

/// Figure numbers whose every table comes from a [`Scenario`]
/// declaration — the set `psbs scenario export` dumps into
/// `scenarios/` (ratio grids, pooled ECDFs, conditional slowdowns,
/// trace replays).
pub const EXPORTED_FIGS: [u64; 11] = [3, 4, 5, 6, 7, 8, 10, 12, 13, 14, 15];

/// The declarative form of every scenario-shaped figure: the single
/// source behind the `figN()` functions, `psbs scenario export`, and
/// the committed `scenarios/*.toml` files (which must match these —
/// `tests::committed_scenario_files_match_exports`).  `njobs` scales
/// the workload (figures pass `Ctx::njobs`; exports use the Table-1
/// default 10 000).
pub fn scenarios_for(fig: u64, njobs: usize) -> Option<Vec<Scenario>> {
    let cfg = SynthConfig::default().with_njobs(njobs);
    let grid_policies = ["psbs", "srpte", "fspe", "ps", "las"];
    Some(match fig {
        // Fig. 3 — MST against PS over the sigma x shape grid.
        3 => vec![Scenario::new("fig3_mst_vs_ps", cfg)
            .axis("shape", AxisParam::Shape, &GRID)
            .axis("sigma", AxisParam::Sigma, &GRID)
            .policies(&["srpte", "srpte+ps", "srpte+las", "fspe", "fspe+ps", "fspe+las"])
            .vs(Reference::Ps)],
        // Fig. 4 — per-job slowdown ECDF of the §5.1 proposals vs PS,
        // pooled across repetitions, one table per shape.
        4 => vec![Scenario::new("fig4_slowdown_ecdf", cfg)
            .split_axis("shape", AxisParam::Shape, &[0.5, 0.25, 0.125])
            .policies(&["ps", "srpte+ps", "srpte+las", "fspe+ps", "fspe+las"])
            .metric(Metric::PooledEcdf { points: 128, decades: 3.0, tail_above: None })],
        // Fig. 5 — MST / optimal vs shape, all policies (sigma = 0.5).
        5 => vec![Scenario::new("fig5_mst_vs_shape", cfg)
            .axis("shape", AxisParam::Shape, &GRID)
            .policies(&["psbs", "srpte", "fspe", "ps", "las", "fifo"])
            .vs(Reference::OptSrpt)],
        // Fig. 6 — MST / optimal vs sigma for three heavy-tailed shapes.
        6 => vec![Scenario::new("fig6_mst_vs_sigma", cfg)
            .split_axis("shape", AxisParam::Shape, &[0.5, 0.25, 0.125])
            .axis("sigma", AxisParam::Sigma, &GRID)
            .policies(&grid_policies)
            .vs(Reference::OptSrpt)],
        // Fig. 7 — mean conditional slowdown vs job size (100
        // equal-count classes, §7.5's per-size-class fairness lens).
        7 => vec![Scenario::new("fig7_conditional_slowdown", cfg)
            .policies(&["fifo", "srpte", "fspe", "ps", "las", "psbs"])
            .metric(Metric::CondSlowdown { bins: metrics::COND_BINS })],
        // Fig. 8 — per-job slowdown CDF at the defaults + tail numbers.
        8 => vec![Scenario::new("fig8_perjob_slowdown_cdf", cfg)
            .policies(&["fifo", "srpte", "fspe", "ps", "las", "psbs"])
            .metric(Metric::PooledEcdf { points: 128, decades: 4.0, tail_above: Some(100.0) })],
        // Fig. 10 — Pareto job sizes, alpha in {2, 1}.
        10 => vec![Scenario::new("fig10_pareto", cfg)
            .split_axis("alpha", AxisParam::Alpha, &[2.0, 1.0])
            .axis("sigma", AxisParam::Sigma, &GRID)
            .policies(&grid_policies)
            .vs(Reference::OptSrpt)],
        // Figs. 12/13 — trace replay: MST / optimal vs sigma.
        12 => vec![trace_scenario("fig12_facebook", TraceName::Facebook, njobs)],
        13 => vec![trace_scenario("fig13_ircache", TraceName::Ircache, njobs)],
        // Fig. 14 — impact of load and timeshape (appendix A.2).
        14 => vec![
            Scenario::new("fig14a_load", cfg)
                .axis("load", AxisParam::Load, &[0.5, 0.7, 0.9, 0.95, 0.999])
                .policies(&grid_policies)
                .vs(Reference::OptSrpt),
            Scenario::new("fig14b_timeshape", cfg)
                .axis("timeshape", AxisParam::Timeshape, &GRID)
                .policies(&grid_policies)
                .vs(Reference::OptSrpt),
        ],
        // Fig. 15 — PSBS vs PS across shape x {load, timeshape, njobs}.
        15 => {
            let sub = |name: &str, label: &str, param: AxisParam, values: &[f64]| {
                Scenario::new(name, cfg)
                    .axis("shape", AxisParam::Shape, &GRID)
                    .axis(label, param, values)
                    .policy_as("psbs_over_ps", "psbs")
                    .vs(Reference::Ps)
            };
            let njob_grid: Vec<f64> = [1_000usize, 10_000, 100_000]
                .iter()
                .map(|&n| n.min(njobs * 10) as f64)
                .collect();
            vec![
                sub("fig15a_load", "load", AxisParam::Load, &[0.5, 0.9, 0.999]),
                sub("fig15b_timeshape", "timeshape", AxisParam::Timeshape, &[0.125, 1.0, 4.0]),
                sub("fig15c_njobs", "njobs", AxisParam::Njobs, &njob_grid),
            ]
        }
        _ => return None,
    })
}

/// Figs. 12/13 share one shape: replay the stand-in trace (capped at
/// the published record count) across the sigma grid.
fn trace_scenario(name: &str, trace: TraceName, njobs: usize) -> Scenario {
    let spec = TraceSpec {
        source: trace.into(),
        njobs: njobs.min(trace.stats().jobs),
        load: 0.9,
        sigma: 0.5,
    };
    Scenario::with_workload(name, spec)
        .axis("sigma", AxisParam::Sigma, &GRID)
        .policies(&["psbs", "fspe", "srpte", "ps", "las"])
        .vs(Reference::OptSrpt)
}

/// `(file name, canonical TOML)` pairs for one exported figure: what
/// `psbs scenario export` writes and what `scenarios/` commits.
/// Single-scenario figures export as `figN.toml`; multi-scenario ones
/// as `<scenario name>.toml`.
pub fn export_files(fig: u64, njobs: usize) -> Option<Vec<(String, String)>> {
    let scs = scenarios_for(fig, njobs)?;
    let single = scs.len() == 1;
    Some(
        scs.iter()
            .map(|sc| {
                let fname = if single {
                    format!("fig{fig}.toml")
                } else {
                    format!("{}.toml", sc.name)
                };
                (fname, sc.to_toml())
            })
            .collect(),
    )
}

pub fn fig3(ctx: &Ctx) -> Vec<Table> {
    ctx.eval_scenarios(&scenarios_for(3, ctx.njobs).unwrap())
}

pub fn fig4(ctx: &Ctx) -> Vec<Table> {
    ctx.eval_scenarios(&scenarios_for(4, ctx.njobs).unwrap())
}

pub fn fig5(ctx: &Ctx) -> Vec<Table> {
    ctx.eval_scenarios(&scenarios_for(5, ctx.njobs).unwrap())
}

pub fn fig6(ctx: &Ctx) -> Vec<Table> {
    ctx.eval_scenarios(&scenarios_for(6, ctx.njobs).unwrap())
}

// --------------------------------------------------------------------
// Fig. 7 — mean conditional slowdown vs job size (100 classes).  The
// bespoke main-thread path is gone: the scenario layer's
// [`Metric::CondSlowdown`] runs it through the shared executor,
// bit-identical to the old loop
// (`tests::fig7_scenario_path_matches_bespoke_path_bitwise`).  The
// analytics-artifact cross-check of this metric lives in
// `rust/tests/integration.rs`, where both pipelines get identical
// inputs.
// --------------------------------------------------------------------
pub fn fig7(ctx: &Ctx) -> Vec<Table> {
    ctx.eval_scenarios(&scenarios_for(7, ctx.njobs).unwrap())
}

// --------------------------------------------------------------------
// Fig. 8 — per-job slowdown CDF, defaults, + tail zoom numbers.
// --------------------------------------------------------------------
pub fn fig8(ctx: &Ctx) -> Vec<Table> {
    ctx.eval_scenarios(&scenarios_for(8, ctx.njobs).unwrap())
}

// --------------------------------------------------------------------
// Fig. 9 — weighted classes: PSBS vs DPS, beta in {0,1,2}.
// --------------------------------------------------------------------
pub fn fig9(ctx: &Ctx) -> Vec<Table> {
    let seed = ctx.seed;
    let mut out = Vec::new();
    for &shape in &[0.25, 4.0] {
        let mut t = Table::new(
            format!("fig9_weights_shape{shape}"),
            vec![
                "beta".into(),
                "class".into(),
                "psbs_mst".into(),
                "dps_mst".into(),
            ],
        );
        for &beta in &[0.0, 1.0, 2.0] {
            let cfg = ctx.cfg().with_shape(shape).with_beta(beta);
            // One work item per repetition: both policies run on the
            // shared workload inside the cell, and the per-class means
            // are reduced *inside* the cell too (identical arithmetic
            // to the serial path), so each rep returns ~10 floats
            // instead of its full job/sojourn vectors — peak memory
            // stays flat in --reps.
            let rep_items: Vec<u64> = (0..ctx.reps).collect();
            let runs = ctx.par_runs(&rep_items, |&r| {
                let jobs = crate::workload::synthesize(&cfg, seed.wrapping_add(r * 7919));
                let mut class_means = [[None::<f64>; 5]; 2];
                for (pi, policy) in ["psbs", "dps"].into_iter().enumerate() {
                    let mut sch = sched::by_name(policy).unwrap();
                    let soj = sim::run(sch.as_mut(), &jobs).sojourns(&jobs);
                    for class in 1..=5usize {
                        let vals: Vec<f64> = jobs
                            .iter()
                            .zip(&soj)
                            .filter(|(j, _)| {
                                crate::workload::synthetic::weight_class(j.weight, beta)
                                    == class
                            })
                            .map(|(_, &s)| s)
                            .collect();
                        if !vals.is_empty() {
                            class_means[pi][class - 1] = Some(crate::stats::mean(&vals));
                        }
                    }
                }
                class_means
            });
            // Per-class MST accumulators over reps (serial order).
            let mut acc: Vec<(Repetitions, Repetitions)> =
                (0..5).map(|_| Default::default()).collect();
            for class_means in runs {
                for (pi, means) in class_means.iter().enumerate() {
                    for class in 1..=5usize {
                        if let Some(m) = means[class - 1] {
                            if pi == 0 {
                                acc[class - 1].0.push(m);
                            } else {
                                acc[class - 1].1.push(m);
                            }
                        }
                    }
                }
            }
            for class in 1..=5usize {
                t.push(vec![
                    beta,
                    class as f64,
                    acc[class - 1].0.mean(),
                    acc[class - 1].1.mean(),
                ]);
            }
        }
        out.push(t);
    }
    out
}

// --------------------------------------------------------------------
// Fig. 10 — Pareto job sizes, alpha in {2, 1}.
// --------------------------------------------------------------------
pub fn fig10(ctx: &Ctx) -> Vec<Table> {
    ctx.eval_scenarios(&scenarios_for(10, ctx.njobs).unwrap())
}

// --------------------------------------------------------------------
// Fig. 11 — CCDF of trace job sizes (stand-ins; see DESIGN.md §4).
// --------------------------------------------------------------------
pub fn fig11(ctx: &Ctx) -> Vec<Table> {
    let mut t = Table::new(
        "fig11_trace_ccdf",
        vec![
            "fb_size_over_mean".into(),
            "fb_ccdf".into(),
            "ir_size_over_mean".into(),
            "ir_ccdf".into(),
        ],
    );
    let fb = traces::ccdf(&traces::synth_trace(&traces::FACEBOOK, ctx.seed), 100);
    let ir = traces::ccdf(&traces::synth_trace(&traces::IRCACHE, ctx.seed), 100);
    for i in 0..100 {
        t.push(vec![fb[i].0, fb[i].1, ir[i].0, ir[i].1]);
    }
    vec![t]
}

// --------------------------------------------------------------------
// Figs. 12/13 — trace replay: MST / optimal vs sigma.  Trace cells
// flow through the same planner as synthetic ones (each (trace, seed)
// replay synthesized once, the SRPT optimum once per seed).
// --------------------------------------------------------------------
pub fn fig12(ctx: &Ctx) -> Vec<Table> {
    ctx.eval_scenarios(&scenarios_for(12, ctx.njobs).unwrap())
}

pub fn fig13(ctx: &Ctx) -> Vec<Table> {
    // Full IRCache is 206 914 requests; scale by ctx.njobs for speed.
    ctx.eval_scenarios(&scenarios_for(13, ctx.njobs).unwrap())
}

// --------------------------------------------------------------------
// Fig. 14 — impact of load and timeshape (appendix A.2).
// --------------------------------------------------------------------
pub fn fig14(ctx: &Ctx) -> Vec<Table> {
    ctx.eval_scenarios(&scenarios_for(14, ctx.njobs).unwrap())
}

// --------------------------------------------------------------------
// Fig. 15 — PSBS vs PS across shape x {load, timeshape, njobs}.
// --------------------------------------------------------------------
pub fn fig15(ctx: &Ctx) -> Vec<Table> {
    ctx.eval_scenarios(&scenarios_for(15, ctx.njobs).unwrap())
}

// --------------------------------------------------------------------
// Extension experiments (not in the paper; DESIGN.md §3 E20-E22).
// --------------------------------------------------------------------

/// E20 — ablation of the Algorithm-1 bookkeeping fix: PSBS vs the
/// paper-literal pseudocode (`w_v` kept inflated for late jobs) across
/// error levels on the default heavy tail.  Quantifies why the module
/// note's interpretation matters.
pub fn ablation_wv(ctx: &Ctx) -> Vec<Table> {
    let sc = Scenario::new("ext_ablation_wv", ctx.cfg())
        .axis("sigma", AxisParam::Sigma, &GRID)
        .policies(&["psbs", "psbs-paperlit", "fspe", "fspe+ps"])
        .vs(Reference::OptSrpt);
    let t = sc.table(ctx.params(), ctx.threads, ctx.share);

    // The real cost of the literal pseudocode is unbounded state: a job
    // that goes late never leaves the virtual system (its weight stays
    // in w_v and its heap entry in O/E forever).  Measure the residual
    // virtual population after a fully drained run.
    let mut resid = Table::new(
        "ext_ablation_wv_residue",
        vec!["sigma".into(), "psbs_residue".into(), "paperlit_residue".into()],
    );
    let seed = ctx.seed;
    let cfgs: Vec<SynthConfig> = GRID.iter().map(|&sigma| ctx.cfg().with_sigma(sigma)).collect();
    let residues = ctx.par_runs(&cfgs, |cfg| {
        let jobs = crate::workload::synthesize(cfg, seed);
        let mut fixed = crate::sched::fsp_family::Psbs::new();
        sim::run(&mut fixed, &jobs);
        let mut lit = crate::sched::fsp_family::FspFamily::psbs_paper_literal();
        sim::run(&mut lit, &jobs);
        (fixed.virtual_residue() as f64, lit.virtual_residue() as f64)
    });
    for (&sigma, (fixed, lit)) in GRID.iter().zip(residues) {
        resid.push(vec![sigma, fixed, lit]);
    }
    vec![t, resid]
}

/// E21 — practical estimators (§2.2) in front of PSBS and SRPTE:
/// oracle, HFSP-style sampling at three sampled fractions, a
/// semi-clairvoyant size-class estimator, and log-normal sigma = 0.5
/// for reference.
pub fn estimators(ctx: &Ctx) -> Vec<Table> {
    use crate::estimate;
    let mut t = Table::new(
        "ext_estimators",
        vec![
            "estimator_idx".into(),
            "log_sigma".into(),
            "correlation".into(),
            "psbs".into(),
            "srpte".into(),
        ],
    );
    // Trait objects aren't Sync; cells rebuild their estimator from
    // the index instead of sharing boxed instances across threads.
    const N_EST: usize = 6;
    fn build(ei: usize) -> Box<dyn crate::estimate::Estimator> {
        match ei {
            0 => Box::new(crate::estimate::OracleEstimator),
            1 => Box::new(crate::estimate::SamplingEstimator::new(0.01, 0.5)),
            2 => Box::new(crate::estimate::SamplingEstimator::new(0.05, 0.5)),
            3 => Box::new(crate::estimate::SamplingEstimator::new(0.25, 0.5)),
            4 => Box::new(crate::estimate::ClassEstimator),
            _ => Box::new(crate::estimate::LogNormalNoise::new(0.5)),
        }
    }
    let base_cfg = ctx.cfg().with_sigma(0.0);
    let seed = ctx.seed;
    let items: Vec<(usize, u64)> = (0..N_EST)
        .flat_map(|ei| (0..ctx.reps).map(move |r| (ei, r)))
        .collect();
    let runs = ctx.par_runs(&items, |&(ei, r)| {
        let est = build(ei);
        let base = crate::workload::synthesize(&base_cfg, seed.wrapping_add(r * 7919));
        let jobs = estimate::apply(&base, est.as_ref(), seed.wrapping_add(r));
        let stats = estimate::measure(&jobs);
        let opt = Reference::OptSrpt.mst(&jobs);
        (
            stats.log_sigma,
            stats.correlation,
            run_mst("psbs", &jobs) / opt,
            run_mst("srpte", &jobs) / opt,
        )
    });
    let mut it = runs.into_iter();
    for ei in 0..N_EST {
        let mut quality = (0.0, 0.0);
        let mut psbs_acc = Repetitions::default();
        let mut srpte_acc = Repetitions::default();
        for _ in 0..ctx.reps {
            let (log_sigma, corr, p, s) = it.next().unwrap();
            quality = (log_sigma, corr);
            psbs_acc.push(p);
            srpte_acc.push(s);
        }
        t.push(vec![ei as f64, quality.0, quality.1, psbs_acc.mean(), srpte_acc.mean()]);
    }
    vec![t]
}

/// E22 — multi-server scaling: MST of a k-server PSBS cluster at fixed
/// per-server load 0.9, least-work vs round-robin dispatch.
pub fn cluster_scaling(ctx: &Ctx) -> Vec<Table> {
    use crate::coordinator::{Cluster, Dispatch};
    let mut t = Table::new(
        "ext_cluster_scaling",
        vec!["k".into(), "leastwork".into(), "roundrobin".into(), "random".into()],
    );
    let dispatches = [Dispatch::LeastWork, Dispatch::RoundRobin, Dispatch::Random];
    let ks = [1usize, 2, 4, 8];
    let seed = ctx.seed;
    // One work item per (k, dispatch, rep), in the serial loop order.
    let mut items: Vec<(usize, usize, u64, SynthConfig)> = Vec::new();
    for &k in &ks {
        // Offered load k*0.9 against k unit servers.
        let cfg = ctx.cfg().with_load(0.9 * k as f64).with_njobs(ctx.njobs.min(10_000));
        for di in 0..dispatches.len() {
            for r in 0..ctx.reps {
                items.push((k, di, r, cfg));
            }
        }
    }
    let msts = ctx.par_runs(&items, |&(k, di, r, cfg)| {
        let jobs = crate::workload::synthesize(&cfg, seed.wrapping_add(r * 7919));
        let mut c = Cluster::new("psbs", k, dispatches[di], seed).unwrap();
        sim::run(&mut c, &jobs).mst(&jobs)
    });
    let mut it = msts.into_iter();
    for &k in &ks {
        let mut row = vec![k as f64];
        for _ in 0..dispatches.len() {
            let mut acc = Repetitions::default();
            for _ in 0..ctx.reps {
                acc.push(it.next().unwrap());
            }
            row.push(acc.mean());
        }
        t.push(row);
    }
    vec![t]
}

/// All figures by number (3-15 = the paper's; 20-22 = extensions).
pub fn by_number(ctx: &Ctx, fig: u64) -> Option<Vec<Table>> {
    Some(match fig {
        3 => fig3(ctx),
        4 => fig4(ctx),
        5 => fig5(ctx),
        6 => fig6(ctx),
        7 => fig7(ctx),
        8 => fig8(ctx),
        9 => fig9(ctx),
        10 => fig10(ctx),
        11 => fig11(ctx),
        12 => fig12(ctx),
        13 => fig13(ctx),
        14 => fig14(ctx),
        15 => fig15(ctx),
        20 => ablation_wv(ctx),
        21 => estimators(ctx),
        22 => cluster_scaling(ctx),
        _ => return None,
    })
}

/// Figure numbers in sweep order (paper figures then extensions).
pub const ALL_FIGS: [u64; 16] = [3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 20, 21, 22];

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ctx() -> Ctx {
        Ctx { reps: 1, njobs: 300, seed: 7, ..Default::default() }
    }

    fn table_bits(tables: &[Table]) -> Vec<Vec<Vec<u64>>> {
        tables
            .iter()
            .map(|t| t.rows.iter().map(|r| r.iter().map(|v| v.to_bits()).collect()).collect())
            .collect()
    }

    #[test]
    fn fig5_shapes_hold_at_small_scale() {
        let ctx = tiny_ctx();
        let t = &fig5(&ctx)[0];
        // Columns: shape, psbs, srpte, fspe, ps, las, fifo.
        for row in &t.rows {
            // Every ratio to the optimum is >= ~1 (tolerance for ties).
            for &v in &row[1..] {
                assert!(v > 0.9, "ratio {v} below optimal in {row:?}");
            }
        }
    }

    #[test]
    fn exact_copy_strips_errors() {
        let jobs = vec![Job { id: 0, arrival: 0.0, size: 2.0, est: 5.0, weight: 1.0 }];
        assert_eq!(exact_copy(&jobs)[0].est, 2.0);
    }

    /// Acceptance check for the parallel sweep executor: a full Fig. 6
    /// regeneration (the sigma sweep, all three shape tables) is
    /// bit-identical across thread counts {1, 2, 4}.
    #[test]
    fn parallel_sweep_is_bit_identical() {
        let serial = {
            let ctx = Ctx { reps: 2, njobs: 200, seed: 11, threads: 1, ..Default::default() };
            table_bits(&fig6(&ctx))
        };
        for threads in [2usize, 4] {
            let ctx = Ctx { reps: 2, njobs: 200, seed: 11, threads, ..Default::default() };
            let par = table_bits(&fig6(&ctx));
            assert_eq!(serial, par, "fig6 output diverged at {threads} threads");
        }
    }

    /// Acceptance check for the shared-workload planner: figure output
    /// with shared workloads/references (`share = true`, the default)
    /// is bit-identical to the pre-refactor per-cell path
    /// (`share = false`), across thread counts, for the four figure
    /// shapes — plain ratio grids (Fig. 6), pooled populations
    /// (Fig. 4), conditional slowdowns (Fig. 7) and per-rep
    /// dual-policy class means (Fig. 9).
    #[test]
    fn planner_reproduces_per_cell_figures_bitwise() {
        let run = |share: bool, threads: usize, f: u64| {
            let ctx = Ctx {
                reps: 2,
                njobs: 180,
                seed: 13,
                threads,
                share,
                ..Default::default()
            };
            table_bits(&by_number(&ctx, f).unwrap())
        };
        for f in [4u64, 6, 7, 9] {
            let legacy = run(false, 1, f);
            for threads in [1usize, 3] {
                assert_eq!(
                    legacy,
                    run(true, threads, f),
                    "fig {f}: planner output diverged from the per-cell path at {threads} threads"
                );
            }
        }
    }

    /// Converge mode replays the per-cell stopping rule exactly even
    /// though the planner splits work at repetition level.
    #[test]
    fn planner_converge_mode_is_bit_identical() {
        let run = |share: bool, threads: usize| {
            let ctx = Ctx {
                reps: 2,
                njobs: 150,
                seed: 29,
                threads,
                share,
                converge: true,
                ..Default::default()
            };
            table_bits(&fig5(&ctx))
        };
        let legacy = run(false, 1);
        assert_eq!(legacy, run(true, 1));
        assert_eq!(legacy, run(true, 4));
    }

    /// The pooled-population path (per-(policy, rep) work items) is
    /// deterministic too: Fig. 4 at 1 vs 3 threads.
    #[test]
    fn pooled_figures_are_bit_identical() {
        let serial = {
            let ctx = Ctx { reps: 2, njobs: 150, seed: 5, threads: 1, ..Default::default() };
            table_bits(&fig4(&ctx))
        };
        let par = {
            let ctx = Ctx { reps: 2, njobs: 150, seed: 5, threads: 3, ..Default::default() };
            table_bits(&fig4(&ctx))
        };
        assert_eq!(serial, par, "fig4 pooled ECDFs diverged under parallel execution");
    }

    /// Every figure function executes end to end at tiny scale and
    /// yields non-empty, finite-x tables (a safety net for the sweep
    /// CLI — individual figure *values* are checked elsewhere).  Runs
    /// with 2 worker threads so the parallel path is exercised across
    /// every figure's work-item shape.
    #[test]
    fn all_figures_execute_at_tiny_scale() {
        let ctx = Ctx { reps: 1, njobs: 120, seed: 3, threads: 2, ..Default::default() };
        for f in ALL_FIGS {
            let tables = by_number(&ctx, f).unwrap();
            assert!(!tables.is_empty(), "fig {f} produced no tables");
            for t in &tables {
                assert!(!t.rows.is_empty(), "fig {f}: table {} empty", t.name);
                for row in &t.rows {
                    assert_eq!(row.len(), t.header.len(), "fig {f}: ragged row");
                    assert!(row[0].is_finite(), "fig {f}: non-finite x");
                }
            }
        }
    }

    /// Golden check for the scenario-file path: loading the committed
    /// `scenarios/fig6.toml`, rescaling it to test size and running it
    /// through the generic executor is bit-identical to the built-in
    /// `fig6()` path.
    #[test]
    fn fig6_scenario_file_reproduces_builtin_bitwise() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/fig6.toml");
        let loaded = Scenario::load(path).unwrap().with_njobs(160);
        let builtin = &scenarios_for(6, 160).unwrap()[0];
        assert_eq!(&loaded, builtin, "committed fig6.toml drifted from the declaration");
        let ctx = Ctx { reps: 2, njobs: 160, seed: 19, threads: 2, ..Default::default() };
        let from_file = loaded.tables(ctx.params(), ctx.threads, ctx.share);
        assert_eq!(table_bits(&from_file), table_bits(&fig6(&ctx)));
    }

    /// Golden check for the fig-7 migration: the scenario-layer
    /// [`Metric::CondSlowdown`] path is bit-identical to the deleted
    /// bespoke main-thread path — replicated here verbatim (workload
    /// per rep via `seed + r*7919`, `sched::by_name` build, pooling in
    /// rep order, `metrics::conditional_slowdown` over the pooled
    /// population, first column from policy 0's classes).
    #[test]
    fn fig7_scenario_path_matches_bespoke_path_bitwise() {
        let ctx = Ctx { reps: 2, njobs: 250, seed: 23, threads: 2, ..Default::default() };
        // --- the deleted figures::fig7 loop, inlined ---
        let policies = ["fifo", "srpte", "fspe", "ps", "las", "psbs"];
        let cfg = SynthConfig::default().with_njobs(ctx.njobs);
        let mut per_policy: Vec<Vec<(f64, f64)>> = Vec::new();
        for &policy in &policies {
            let mut jobs_all: Vec<Job> = Vec::new();
            let mut slow_all: Vec<f64> = Vec::new();
            for r in 0..ctx.reps {
                let jobs =
                    crate::workload::synthesize(&cfg, ctx.seed.wrapping_add(r * 7919));
                let mut s = sched::by_name(policy).unwrap();
                let res = sim::run(s.as_mut(), &jobs);
                slow_all.extend(res.slowdowns(&jobs));
                jobs_all.extend(jobs);
            }
            per_policy.push(crate::metrics::conditional_slowdown(
                &jobs_all,
                &slow_all,
                crate::metrics::COND_BINS,
            ));
        }
        let mut expected: Vec<Vec<f64>> = Vec::new();
        for b in 0..per_policy[0].len() {
            let mut row = vec![per_policy[0][b].0];
            for pp in &per_policy {
                row.push(pp.get(b).map(|x| x.1).unwrap_or(f64::NAN));
            }
            expected.push(row);
        }
        // --- the scenario path ---
        let got = fig7(&ctx);
        assert_eq!(got.len(), 1);
        let t = &got[0];
        assert_eq!(t.name, "fig7_conditional_slowdown");
        assert_eq!(t.header[0], "size");
        let expected_header: Vec<String> = policies.iter().map(|s| s.to_string()).collect();
        assert_eq!(t.header[1..].to_vec(), expected_header);
        let bits =
            |rows: &[Vec<f64>]| -> Vec<Vec<u64>> {
                rows.iter().map(|r| r.iter().map(|v| v.to_bits()).collect()).collect()
            };
        assert_eq!(bits(&expected), bits(&t.rows), "fig7 diverged from the bespoke path");
    }

    /// The committed trace-file demo scenario (an on-disk
    /// `arrival,size,weight` trace next to it) loads with its path
    /// resolved against `scenarios/`, runs through the shared planner,
    /// and is bit-identical across share x threads.
    #[test]
    fn committed_trace_file_demo_runs_bit_identically() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/scenarios/trace_file_demo.toml");
        let sc = Scenario::load(path).unwrap();
        match &sc.workload {
            scenario::WorkloadSpec::Trace(t) => {
                assert!(matches!(t.source, scenario::TraceSource::File(_)))
            }
            _ => panic!("demo must be a trace-file workload"),
        }
        let p = SweepParams { reps: 2, seed: 11, converge: false };
        let bits = |share: bool, threads: usize| -> Vec<u64> {
            sc.table(p, threads, share).rows.iter().flatten().map(|v| v.to_bits()).collect()
        };
        let base = bits(false, 1);
        assert!(base.iter().any(|&b| f64::from_bits(b) > 0.0));
        for (share, threads) in [(true, 1), (true, 3), (false, 3)] {
            assert_eq!(base, bits(share, threads), "share={share} threads={threads}");
        }
    }

    /// Every committed scenario file is byte-identical to what
    /// `psbs scenario export` would write today: the files in
    /// `scenarios/` can never drift from the in-binary declarations.
    #[test]
    fn committed_scenario_files_match_exports() {
        for fig in EXPORTED_FIGS {
            for (fname, toml) in export_files(fig, 10_000).unwrap() {
                let path = format!("{}/scenarios/{fname}", env!("CARGO_MANIFEST_DIR"));
                let committed = std::fs::read_to_string(&path)
                    .unwrap_or_else(|e| panic!("missing committed scenario {path}: {e}"));
                assert_eq!(
                    committed, toml,
                    "scenarios/{fname} differs from `psbs scenario export fig{fig}`"
                );
            }
        }
    }

    /// Exported scenarios parse back to the exact declarations (the
    /// file format loses nothing the figures need).
    #[test]
    fn exported_scenarios_parse_back_exactly() {
        for fig in EXPORTED_FIGS {
            let scs = scenarios_for(fig, 10_000).unwrap();
            for sc in &scs {
                let parsed = Scenario::parse_toml(&sc.to_toml())
                    .unwrap_or_else(|e| panic!("fig{fig} ({}) export does not parse: {e}", sc.name));
                assert_eq!(&parsed, sc, "fig{fig} ({})", sc.name);
            }
        }
    }

    #[test]
    fn by_number_covers_all() {
        for f in ALL_FIGS {
            // Just check dispatch, not execution (expensive).
            assert!(matches!(f, 3..=15 | 20..=22));
        }
        let ctx = tiny_ctx();
        assert!(by_number(&ctx, 99).is_none());
    }
}
