//! Dependency-free SVG line charts for the figure harness.
//!
//! `psbs sweep --svg` renders each [`Table`] next to its CSV so the
//! paper's figures can be eyeballed directly: column 0 is the x axis,
//! every other column one series.  Log scaling (the paper plots both
//! axes logarithmically in most figures) is automatic when a span
//! exceeds 30x, or forced via [`PlotOpts`].

use super::tables::Table;

/// Rendering options.
#[derive(Debug, Clone)]
pub struct PlotOpts {
    pub width: u32,
    pub height: u32,
    /// None = auto (log when max/min > 30 and all values positive).
    pub log_x: Option<bool>,
    pub log_y: Option<bool>,
    pub title: Option<String>,
}

impl Default for PlotOpts {
    fn default() -> Self {
        PlotOpts { width: 640, height: 420, log_x: None, log_y: None, title: None }
    }
}

/// 8-color palette (Okabe–Ito, color-blind safe).
const PALETTE: [&str; 8] = [
    "#0072B2", "#D55E00", "#009E73", "#CC79A7", "#E69F00", "#56B4E9", "#F0E442", "#000000",
];

const MARGIN_L: f64 = 62.0;
const MARGIN_R: f64 = 12.0;
const MARGIN_T: f64 = 28.0;
const MARGIN_B: f64 = 42.0;

struct Axis {
    min: f64,
    max: f64,
    log: bool,
}

impl Axis {
    fn build(values: impl Iterator<Item = f64>, force_log: Option<bool>) -> Axis {
        let finite: Vec<f64> = values.filter(|v| v.is_finite()).collect();
        let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
        for &v in &finite {
            min = min.min(v);
            max = max.max(v);
        }
        if !min.is_finite() || !max.is_finite() {
            (min, max) = (0.0, 1.0);
        }
        let log = force_log.unwrap_or(min > 0.0 && max / min.max(f64::MIN_POSITIVE) > 30.0)
            && min > 0.0;
        if (max - min).abs() < 1e-300 {
            max = min + 1.0;
        }
        Axis { min, max, log }
    }

    /// Normalize a value to [0, 1] along this axis.
    fn t(&self, v: f64) -> f64 {
        if self.log {
            (v.max(f64::MIN_POSITIVE).ln() - self.min.ln()) / (self.max.ln() - self.min.ln())
        } else {
            (v - self.min) / (self.max - self.min)
        }
    }

    /// Tick positions (data coordinates).
    fn ticks(&self) -> Vec<f64> {
        if self.log {
            let lo = self.min.log10().floor() as i32;
            let hi = self.max.log10().ceil() as i32;
            (lo..=hi).map(|d| 10f64.powi(d)).filter(|&v| v >= self.min * 0.999 && v <= self.max * 1.001).collect()
        } else {
            let span = self.max - self.min;
            let step = 10f64.powf(span.log10().floor());
            let step = if span / step > 5.0 { step } else { step / 2.0 };
            let mut v = (self.min / step).ceil() * step;
            let mut out = Vec::new();
            while v <= self.max + step * 1e-9 {
                out.push(v);
                v += step;
            }
            out
        }
    }
}

fn fmt_tick(v: f64) -> String {
    if v == 0.0 {
        return "0".into();
    }
    let a = v.abs();
    if a >= 10_000.0 || a < 0.01 {
        format!("{v:.0e}")
    } else if v == v.trunc() {
        format!("{v:.0}")
    } else {
        format!("{v}")
            .chars()
            .take(6)
            .collect()
    }
}

/// Render a table as an SVG line chart.
pub fn to_svg(table: &Table, opts: &PlotOpts) -> String {
    let w = opts.width as f64;
    let h = opts.height as f64;
    let plot_w = w - MARGIN_L - MARGIN_R;
    let plot_h = h - MARGIN_T - MARGIN_B;

    let xs = Axis::build(table.rows.iter().map(|r| r[0]), opts.log_x);
    let ys = Axis::build(
        table.rows.iter().flat_map(|r| r[1..].iter().copied()),
        opts.log_y,
    );

    let px = |v: f64| MARGIN_L + xs.t(v) * plot_w;
    let py = |v: f64| MARGIN_T + (1.0 - ys.t(v)) * plot_h;

    let mut s = String::with_capacity(8192);
    s.push_str(&format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{}\" height=\"{}\" \
         viewBox=\"0 0 {} {}\" font-family=\"sans-serif\" font-size=\"11\">\n",
        opts.width, opts.height, opts.width, opts.height
    ));
    s.push_str(&format!(
        "<rect width=\"{}\" height=\"{}\" fill=\"white\"/>\n",
        opts.width, opts.height
    ));
    let title = opts.title.clone().unwrap_or_else(|| table.name.clone());
    s.push_str(&format!(
        "<text x=\"{}\" y=\"17\" text-anchor=\"middle\" font-size=\"13\">{}</text>\n",
        w / 2.0,
        xml_escape(&title)
    ));

    // Grid + ticks.
    for tx in xs.ticks() {
        let x = px(tx);
        s.push_str(&format!(
            "<line x1=\"{x:.1}\" y1=\"{:.1}\" x2=\"{x:.1}\" y2=\"{:.1}\" stroke=\"#ddd\"/>\n",
            MARGIN_T,
            MARGIN_T + plot_h
        ));
        s.push_str(&format!(
            "<text x=\"{x:.1}\" y=\"{:.1}\" text-anchor=\"middle\">{}</text>\n",
            MARGIN_T + plot_h + 16.0,
            fmt_tick(tx)
        ));
    }
    for ty in ys.ticks() {
        let y = py(ty);
        s.push_str(&format!(
            "<line x1=\"{:.1}\" y1=\"{y:.1}\" x2=\"{:.1}\" y2=\"{y:.1}\" stroke=\"#ddd\"/>\n",
            MARGIN_L,
            MARGIN_L + plot_w
        ));
        s.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" text-anchor=\"end\">{}</text>\n",
            MARGIN_L - 6.0,
            y + 4.0,
            fmt_tick(ty)
        ));
    }
    // Axes frame + labels.
    s.push_str(&format!(
        "<rect x=\"{:.1}\" y=\"{:.1}\" width=\"{plot_w:.1}\" height=\"{plot_h:.1}\" \
         fill=\"none\" stroke=\"#333\"/>\n",
        MARGIN_L, MARGIN_T
    ));
    s.push_str(&format!(
        "<text x=\"{:.1}\" y=\"{:.1}\" text-anchor=\"middle\">{}</text>\n",
        MARGIN_L + plot_w / 2.0,
        h - 8.0,
        xml_escape(&table.header[0])
    ));

    // Series.
    for (si, name) in table.header[1..].iter().enumerate() {
        let color = PALETTE[si % PALETTE.len()];
        let pts: Vec<String> = table
            .rows
            .iter()
            .filter(|r| r[si + 1].is_finite() && (!ys.log || r[si + 1] > 0.0))
            .map(|r| format!("{:.1},{:.1}", px(r[0]), py(r[si + 1])))
            .collect();
        if pts.len() > 1 {
            s.push_str(&format!(
                "<polyline points=\"{}\" fill=\"none\" stroke=\"{color}\" stroke-width=\"1.8\"/>\n",
                pts.join(" ")
            ));
        }
        for p in &pts {
            let (x, y) = p.split_once(',').unwrap();
            s.push_str(&format!("<circle cx=\"{x}\" cy=\"{y}\" r=\"2.4\" fill=\"{color}\"/>\n"));
        }
        // Legend entry.
        let ly = MARGIN_T + 14.0 * si as f64 + 8.0;
        let lx = MARGIN_L + plot_w - 110.0;
        s.push_str(&format!(
            "<line x1=\"{lx:.1}\" y1=\"{ly:.1}\" x2=\"{:.1}\" y2=\"{ly:.1}\" \
             stroke=\"{color}\" stroke-width=\"2\"/>\n",
            lx + 18.0
        ));
        s.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\">{}</text>\n",
            lx + 23.0,
            ly + 4.0,
            xml_escape(name)
        ));
    }

    s.push_str("</svg>\n");
    s
}

/// Write `<dir>/<table name>.svg`; returns the path.
pub fn write_svg(table: &Table, dir: &str, opts: &PlotOpts) -> std::io::Result<String> {
    std::fs::create_dir_all(dir)?;
    let path = format!("{dir}/{}.svg", table.name);
    std::fs::write(&path, to_svg(table, opts))?;
    Ok(path)
}

fn xml_escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> Table {
        let mut t = Table::new("plot_test", vec!["x".into(), "a".into(), "b".into()]);
        for i in 1..=10 {
            let x = i as f64;
            t.push(vec![x, x * 2.0, 1000.0 / x]);
        }
        t
    }

    #[test]
    fn svg_is_well_formed_ish() {
        let svg = to_svg(&table(), &PlotOpts::default());
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>\n"));
        assert_eq!(svg.matches("<polyline").count(), 2);
        assert!(svg.contains("plot_test"));
        assert!(svg.contains(">a<") && svg.contains(">b<"), "legend labels");
    }

    #[test]
    fn log_axis_kicks_in_automatically() {
        // y spans 100..1000 over x 1..10 -> log y (span > 30 after
        // combining both series: 2..2000).
        let svg = to_svg(&table(), &PlotOpts::default());
        // Log ticks are decades: 10, 100, 1000 appear as tick labels.
        assert!(svg.contains(">100<") && svg.contains(">1000<"));
    }

    #[test]
    fn nonfinite_and_nonpositive_points_are_dropped() {
        let mut t = Table::new("nan_test", vec!["x".into(), "y".into()]);
        t.push(vec![1.0, 1.0]);
        t.push(vec![2.0, f64::NAN]);
        t.push(vec![3.0, 4.0]);
        t.push(vec![4.0, f64::INFINITY]);
        t.push(vec![5.0, 9.0]);
        let svg = to_svg(&t, &PlotOpts::default());
        assert_eq!(svg.matches("<circle").count(), 3);
    }

    #[test]
    fn write_svg_creates_file() {
        let dir = std::env::temp_dir().join("psbs_plot_test");
        let path = write_svg(&table(), dir.to_str().unwrap(), &PlotOpts::default()).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.contains("<svg"));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn escape_handles_markup() {
        assert_eq!(xml_escape("a<b&c>d"), "a&lt;b&amp;c&gt;d");
    }

    #[test]
    fn constant_series_does_not_collapse() {
        let mut t = Table::new("const", vec!["x".into(), "y".into()]);
        t.push(vec![0.0, 5.0]);
        t.push(vec![1.0, 5.0]);
        let svg = to_svg(&t, &PlotOpts::default());
        assert!(svg.contains("<polyline"));
    }
}
