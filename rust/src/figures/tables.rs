//! Tabular output: pretty-printing and CSV persistence for the figure
//! harness.

use std::io::Write;

/// A named table of f64 rows (figures are numeric series).
#[derive(Debug, Clone)]
pub struct Table {
    pub name: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl Table {
    pub fn new(name: impl Into<String>, header: Vec<String>) -> Table {
        Table { name: name.into(), header, rows: Vec::new() }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        debug_assert_eq!(row.len(), self.header.len(), "row arity mismatch");
        self.rows.push(row);
    }

    /// CSV rendering (header + rows).
    pub fn to_csv(&self) -> String {
        let mut s = self.header.join(",");
        s.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = row.iter().map(|v| format_cell(*v)).collect();
            s.push_str(&cells.join(","));
            s.push('\n');
        }
        s
    }

    /// Write `<dir>/<name>.csv`.
    pub fn write_csv(&self, dir: &str) -> std::io::Result<String> {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{}.csv", self.name);
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
        f.write_all(self.to_csv().as_bytes())?;
        Ok(path)
    }

    /// Terminal rendering with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        let cells: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|row| row.iter().map(|v| format_cell(*v)).collect())
            .collect();
        for row in &cells {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut s = format!("# {}\n", self.name);
        let head: Vec<String> = self
            .header
            .iter()
            .zip(&widths)
            .map(|(h, w)| format!("{h:>w$}"))
            .collect();
        s.push_str(&head.join("  "));
        s.push('\n');
        for row in &cells {
            let line: Vec<String> =
                row.iter().zip(&widths).map(|(c, w)| format!("{c:>w$}")).collect();
            s.push_str(&line.join("  "));
            s.push('\n');
        }
        s
    }
}

fn format_cell(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    if v == v.trunc() && v.abs() < 1e9 {
        format!("{v:.0}")
    } else if v.abs() >= 1000.0 || (v != 0.0 && v.abs() < 0.001) {
        format!("{v:.4e}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("t", vec!["a".into(), "b".into()]);
        t.push(vec![1.0, 2.5]);
        t.push(vec![0.00001, 123456789.0]);
        let csv = t.to_csv();
        assert!(csv.starts_with("a,b\n"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn render_is_aligned() {
        let mut t = Table::new("x", vec!["col".into(), "longer".into()]);
        t.push(vec![1.0, 2.0]);
        let r = t.render();
        assert!(r.contains("# x"));
        assert!(r.contains("col"));
    }

    #[test]
    fn write_csv_creates_file() {
        let mut t = Table::new("psbs_test_table", vec!["v".into()]);
        t.push(vec![3.25]);
        let dir = std::env::temp_dir().join("psbs_tables_test");
        let path = t.write_csv(dir.to_str().unwrap()).unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().contains("3.25"));
        let _ = std::fs::remove_dir_all(dir);
    }
}
