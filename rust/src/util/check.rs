//! Seeded property-testing harness (replaces the unavailable `proptest`).
//!
//! [`property`] runs a predicate over `cases` deterministic seeds; on
//! failure it *shrinks* by re-running the generator with progressively
//! smaller `size` hints until the failure disappears, then reports the
//! smallest failing (seed, size) so the case can be replayed in a unit
//! test.  Generators receive an [`Rng`] plus the size hint and build an
//! arbitrary input; predicates return `Err(description)` on violation.

use super::rng::Rng;

/// Configuration for a property run.
pub struct Config {
    /// Number of random cases.
    pub cases: u64,
    /// Maximum size hint passed to the generator.
    pub max_size: usize,
    /// Base seed; each case uses `substream(case_index)`.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, max_size: 64, seed: 0xC0FFEE }
    }
}

/// Run `prop(gen(rng, size))` over random seeds; panic with a replayable
/// report on the first failure (after shrinking the size hint).
pub fn property<T, G, P>(name: &str, cfg: Config, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let base = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        // Ramp the size hint so early cases are small (cheap + diverse).
        let size = 1 + (case as usize * cfg.max_size) / cfg.cases.max(1) as usize;
        let mut rng = base.substream(case);
        let input = gen(&mut rng, size);
        if let Err(msg) = prop(&input) {
            // Shrink: retry the same stream with smaller size hints.
            let mut best = (size, msg);
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = base.substream(case);
                let input = gen(&mut rng, s);
                match prop(&input) {
                    Err(m) => {
                        best = (s, m);
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (seed={}, case={case}, size={}): {}\n\
                 replay: property with Config {{ seed: {}, .. }} case {case}",
                cfg.seed, best.0, best.1, cfg.seed
            );
        }
    }
}

/// Assert two floats agree within absolute + relative tolerance.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = a.abs().max(b.abs()).max(1.0);
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol}, scale {scale})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        property(
            "sum-commutes",
            Config { cases: 16, ..Default::default() },
            |rng, size| (0..size).map(|_| rng.u01()).collect::<Vec<_>>(),
            |xs| {
                let fwd: f64 = xs.iter().sum();
                let rev: f64 = xs.iter().rev().sum();
                close(fwd, rev, 1e-9)
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        property(
            "always-fails",
            Config { cases: 4, ..Default::default() },
            |_, size| size,
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn close_tolerates() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(close(1.0, 1.1, 1e-9).is_err());
    }
}
