//! Dependency-free substrates: PRNG, CLI parsing, bench harness,
//! property-testing helpers.
//!
//! The build environment is fully offline with only the `xla` crate
//! closure available, so the conventional crates (`rand`, `clap`,
//! `criterion`, `proptest`) are replaced by the small, deterministic
//! implementations in this module (DESIGN.md §4 Substitutions).

pub mod bench;
pub mod check;
pub mod cli;
pub mod pool;
pub mod rng;

/// Absolute time tolerance used by the event-driven simulator when
/// deciding that a remaining quantity has hit zero.  Simulated times in
/// the paper's parameter space are O(10^4) with f64 arithmetic, so 1e-9
/// is ~10^5 ulps of slack — far above accumulated rounding, far below
/// any inter-event gap that matters.
pub const EPS: f64 = 1e-9;
