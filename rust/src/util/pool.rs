//! Scoped-thread work pool: a deterministic parallel map over a slice.
//!
//! Dependency-free (the environment has no `rayon`): workers are
//! `std::thread::scope` threads pulling item indices from one shared
//! atomic counter — the degenerate-but-effective form of work stealing
//! for independent, similarly-sized cells.  *Which* thread computes a
//! cell is nondeterministic, but every cell is a pure function of its
//! item and results are reassembled by index, so [`par_map`] output is
//! **bit-identical** to the serial map (the figure harness asserts
//! this across thread counts; see
//! `figures::tests::parallel_sweep_is_bit_identical`).
//!
//! `threads == 1` short-circuits to a plain serial map on the calling
//! thread — no pool, no atomics — which is the reference path the
//! parallel one is checked against.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count used when the caller does not specify one (the CLI's
/// `--threads` default): the machine's available parallelism, 1 if it
/// cannot be determined.
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `items` on up to `threads` workers, returning results
/// in item order.
///
/// * Output is bit-identical to `items.iter().map(f).collect()` for a
///   pure `f` — parallelism never changes *what* is computed, only
///   *when*.
/// * A panic in any worker is propagated to the caller (after the
///   remaining workers drain), preserving the panic payload.
/// * `threads` is clamped to `[1, items.len()]`; `1` runs serially on
///   the calling thread.
pub fn par_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        return items.iter().map(|it| f(it)).collect();
    }

    let next = AtomicUsize::new(0);
    let mut parts: Vec<Vec<(usize, R)>> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let next = &next;
                let f = &f;
                scope.spawn(move || {
                    let mut local: Vec<(usize, R)> = Vec::with_capacity(n / threads + 1);
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(&items[i])));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(part) => parts.push(part),
                // Re-raise the worker's panic in the caller; the scope
                // joins any remaining workers during unwinding.
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });

    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    for (i, r) in parts.into_iter().flatten() {
        debug_assert!(out[i].is_none(), "par_map: slot {i} produced twice");
        out[i] = Some(r);
    }
    out.into_iter()
        .map(|slot| slot.expect("par_map: slot never produced"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{property, Config};
    use crate::util::rng::Rng;

    /// A deliberately order-sensitive cell: result depends on every
    /// input bit via a seeded stream, so any misrouted index shows.
    fn cell(seed: &u64) -> f64 {
        let mut rng = Rng::new(*seed);
        let mut acc = 0.0;
        for _ in 0..32 {
            acc += rng.u01();
        }
        acc
    }

    #[test]
    fn par_map_equals_serial_map_randomized_grid() {
        property(
            "par_map == serial map",
            Config { cases: 24, max_size: 120, ..Default::default() },
            |rng, size| (0..1 + size).map(|_| rng.next_u64()).collect::<Vec<u64>>(),
            |grid| {
                let serial: Vec<u64> = grid.iter().map(|s| cell(s).to_bits()).collect();
                for threads in [1, 2, available_threads().max(3)] {
                    let par: Vec<u64> = par_map(threads, grid, |s| cell(s).to_bits());
                    if par != serial {
                        return Err(format!("threads={threads}: parallel map diverged"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let items: Vec<u32> = (0..64).collect();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            par_map(4, &items, |&x| {
                if x == 33 {
                    panic!("boom at {x}");
                }
                x * 2
            })
        }));
        assert!(res.is_err(), "worker panic must propagate to the caller");
        // The pool stays usable after a propagated panic.
        assert_eq!(par_map(4, &items[..4], |&x| x + 1), vec![1, 2, 3, 4]);
    }

    #[test]
    fn empty_single_and_oversubscribed() {
        let empty: [u32; 0] = [];
        assert!(par_map(8, &empty, |&x| x).is_empty());
        assert_eq!(par_map(8, &[7u32], |&x| x + 1), vec![8]);
        let items: Vec<usize> = (0..10).collect();
        let expect: Vec<usize> = items.iter().map(|&i| i * i).collect();
        assert_eq!(par_map(64, &items, |&i| i * i), expect);
    }

    #[test]
    fn zero_threads_clamps_to_serial() {
        let items = [1u32, 2, 3];
        assert_eq!(par_map(0, &items, |&x| x * 10), vec![10, 20, 30]);
    }
}
