//! Deterministic PRNG: xoshiro256++ seeded via splitmix64.
//!
//! Replaces the unavailable `rand` crate.  Streams are reproducible
//! across runs and platforms, which every experiment in EXPERIMENTS.md
//! relies on (`--seed` on the CLI); independent substreams are derived
//! by seeding with distinct splitmix64 outputs.

/// xoshiro256++ generator (Blackman & Vigna, 2019).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// splitmix64 step — used for seeding and cheap one-off hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent substream (used per-repetition, per-lane).
    pub fn substream(&self, lane: u64) -> Rng {
        let mut sm = self.s[0] ^ lane.wrapping_mul(0xA24BAED4963EE407);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn u01(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a `log` argument.
    #[inline]
    pub fn u01_open_left(&mut self) -> f64 {
        1.0 - self.u01()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection-free bound is unnecessary
        // here (non-cryptographic simulation use); plain modulo bias at
        // n << 2^64 is < 2^-40.
        self.next_u64() % n
    }

    /// Standard normal via Box-Muller (matches the L1 kernel's method).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.u01_open_left();
        let u2 = self.u01();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn substreams_differ() {
        let base = Rng::new(7);
        let mut a = base.substream(0);
        let mut b = base.substream(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn u01_in_range_and_uniform() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.u01();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 200_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m += z;
            v += z * z;
        }
        m /= n as f64;
        v /= n as f64;
        assert!(m.abs() < 0.01, "mean={m}");
        assert!((v - 1.0).abs() < 0.02, "var={v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
