//! Minimal command-line parsing (replaces the unavailable `clap`).
//!
//! Grammar: `psbs <subcommand> [positional...] [--flag value |
//! --flag=value | --switch]...`  Flags may repeat (`--axis sigma
//! --axis load=0.7,0.9` accumulates; single-value getters take the
//! last occurrence).  Unknown flags and unconsumed positionals are
//! hard errors so typos cannot silently fall back to defaults in the
//! middle of an experiment sweep.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;

/// Parsed command line: a subcommand, positional arguments, and
/// `--key value` options (multi-valued: repeated flags accumulate).
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, Vec<String>>,
    positionals: Vec<String>,
    /// Flags that were consumed by a getter (for unknown-flag checking).
    seen: RefCell<Vec<String>>,
    /// How many positionals a getter has looked at.
    pos_seen: Cell<usize>,
}

impl Args {
    /// Parse `std::env::args()`-style strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            let Some(stripped) = tok.strip_prefix("--") else {
                args.positionals.push(tok);
                continue;
            };
            if let Some((k, v)) = stripped.split_once('=') {
                args.opts.entry(k.to_string()).or_default().push(v.to_string());
            } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                args.opts.entry(stripped.to_string()).or_default().push(it.next().unwrap());
            } else {
                args.opts.entry(stripped.to_string()).or_default().push("true".to_string());
            }
        }
        Ok(args)
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    fn last(&self, key: &str) -> Option<&String> {
        self.opts.get(key).and_then(|v| v.last())
    }

    /// String option with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.last(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn get_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.last(key).cloned()
    }

    /// Was the flag given at all?  (Scenario files carry `reps`/
    /// `converge` defaults; an explicit CLI flag must win over them,
    /// which requires telling "absent" apart from "default value".)
    pub fn has(&self, key: &str) -> bool {
        self.mark(key);
        self.opts.contains_key(key)
    }

    /// Every occurrence of a repeatable flag, in argv order (empty when
    /// absent) — `psbs sweep --axis sigma=0.25,0.5 --axis load=0.7,0.9`.
    pub fn get_multi(&self, key: &str) -> Vec<String> {
        self.mark(key);
        self.opts.get(key).cloned().unwrap_or_default()
    }

    /// f64 option with default.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        self.mark(key);
        match self.last(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: not a number: {v}")),
        }
    }

    /// u64 option with default.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        self.mark(key);
        match self.last(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: not an integer: {v}")),
        }
    }

    /// Comma-separated list option, split parenthesis-aware so
    /// composed policy specs (`cluster(k=4,inner=psbs)`) stay single
    /// elements.  `None` when the flag is absent.
    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.mark(key);
        self.last(key).map(|v| {
            crate::scenario::spec::split_top_level(v, ',')
                .into_iter()
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
    }

    /// Boolean switch (present or `--key true/false`).
    pub fn get_bool(&self, key: &str) -> Result<bool, String> {
        self.mark(key);
        match self.last(key).map(|s| s.as_str()) {
            None => Ok(false),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(v) => Err(format!("--{key}: not a boolean: {v}")),
        }
    }

    /// The `i`-th positional argument after the subcommand
    /// (`psbs scenario export fig6` => positional(0) = "export").
    pub fn positional(&self, i: usize) -> Option<String> {
        self.pos_seen.set(self.pos_seen.get().max(i + 1));
        self.positionals.get(i).cloned()
    }

    /// Error if any provided flag or positional was never consumed by
    /// a getter.
    pub fn check_unknown(&self) -> Result<(), String> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> = self
            .opts
            .keys()
            .filter(|k| !seen.iter().any(|s| s == *k))
            .collect();
        if !unknown.is_empty() {
            return Err(format!("unknown flags: {unknown:?}"));
        }
        if self.positionals.len() > self.pos_seen.get() {
            return Err(format!(
                "unexpected positional arguments: {:?}",
                &self.positionals[self.pos_seen.get()..]
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("simulate --policy psbs --sigma 0.5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.get("policy", "ps"), "psbs");
        assert_eq!(a.get_f64("sigma", 1.0).unwrap(), 0.5);
        assert!(a.get_bool("verbose").unwrap());
    }

    #[test]
    fn equals_syntax() {
        let a = parse("sweep --fig=5 --reps=30");
        assert_eq!(a.get_u64("fig", 0).unwrap(), 5);
        assert_eq!(a.get_u64("reps", 1).unwrap(), 30);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("simulate");
        assert_eq!(a.get_f64("load", 0.9).unwrap(), 0.9);
        assert!(!a.get_bool("verbose").unwrap());
    }

    #[test]
    fn has_detects_presence_and_counts_as_consumed() {
        let a = parse("sweep --reps 3 --tpyo 1");
        assert!(a.has("reps"));
        assert!(!a.has("converge"));
        // `has` consumes the flag for unknown-flag checking purposes.
        let b = parse("sweep --converge");
        assert!(b.has("converge"));
        assert!(b.check_unknown().is_ok());
    }

    #[test]
    fn unknown_flags_detected() {
        let a = parse("simulate --tpyo 3");
        let _ = a.get_f64("load", 0.9);
        assert!(a.check_unknown().is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("simulate --sigma abc");
        assert!(a.get_f64("sigma", 0.5).is_err());
    }

    #[test]
    fn repeated_flags_accumulate_and_last_wins() {
        let a = parse("sweep --axis sigma=0.25,0.5 --axis load=0.7,0.9 --reps 2 --reps 5");
        assert_eq!(a.get_multi("axis"), vec!["sigma=0.25,0.5", "load=0.7,0.9"]);
        // Single-value getters take the last occurrence.
        assert_eq!(a.get_u64("reps", 1).unwrap(), 5);
        assert!(a.get_multi("missing").is_empty());
        assert!(a.check_unknown().is_ok());
    }

    #[test]
    fn positionals_are_collected_and_checked() {
        let a = parse("scenario export fig6");
        assert_eq!(a.subcommand.as_deref(), Some("scenario"));
        assert_eq!(a.positional(0).as_deref(), Some("export"));
        // fig6 not consumed yet: check_unknown flags it.
        assert!(a.check_unknown().is_err());
        assert_eq!(a.positional(1).as_deref(), Some("fig6"));
        assert!(a.check_unknown().is_ok());
        assert_eq!(a.positional(2), None);
    }

    #[test]
    fn unconsumed_positional_rejected() {
        let a = parse("simulate oops");
        assert!(a.check_unknown().is_err());
    }

    #[test]
    fn list_splits_outside_parens_only() {
        let a = parse("sweep --policies psbs,cluster(k=4,dispatch=leastwork,inner=psbs),ps");
        assert_eq!(
            a.get_list("policies").unwrap(),
            vec!["psbs", "cluster(k=4,dispatch=leastwork,inner=psbs)", "ps"]
        );
        assert!(a.get_list("missing").is_none());
    }
}
