//! Tiny timing harness (replaces the unavailable `criterion`).
//!
//! Each `cargo bench` target builds a [`Bench`] and registers closures;
//! the harness warms up, runs timed batches until a wall-clock budget
//! is met, and reports mean / stddev / min per iteration plus optional
//! throughput.  Output format is one line per benchmark so the figure
//! harness and EXPERIMENTS.md can diff runs textually; [`write_json`]
//! additionally emits a machine-readable `BENCH_*.json` report (schema
//! in rust/benches/README.md) so the perf trajectory can be tracked
//! across PRs.

use std::time::{Duration, Instant};

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    /// Optional items/iter for throughput reporting.
    pub items: Option<u64>,
}

/// Harness configuration.
pub struct Bench {
    /// Wall-clock budget per benchmark (measurement phase).
    pub budget: Duration,
    /// Warmup budget per benchmark.
    pub warmup: Duration,
    /// Collected results (also printed as they complete).
    pub samples: Vec<Sample>,
    filter: Vec<String>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // `cargo bench -- <filter>` narrows which benchmarks run.  The
        // filter is a comma-separated list of substrings; a benchmark
        // matching ANY of them runs (e.g. `event/,batch/,soa/` — one
        // bench process, several families), so filtered smoke runs
        // that REWRITE the JSON report can still cover every gated key
        // at once.
        let filter: Vec<String> = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-'))
            .map(|a| a.split(',').filter(|p| !p.is_empty()).map(str::to_string).collect())
            .unwrap_or_default();
        Bench {
            budget: Duration::from_millis(
                std::env::var("BENCH_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(700),
            ),
            warmup: Duration::from_millis(100),
            samples: Vec::new(),
            filter,
        }
    }

    /// Time `f`, which performs ONE iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) {
        self.bench_items(name, None, f)
    }

    /// Time `f` and report throughput as `items` per iteration.
    pub fn bench_items<F: FnMut()>(&mut self, name: &str, items: Option<u64>, mut f: F) {
        if !filter_matches(&self.filter, name) {
            return;
        }
        // Warmup and batch-size calibration.
        let t0 = Instant::now();
        let mut calib_iters: u64 = 0;
        while t0.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
        // Aim for ~30 batches inside the budget.
        let batch = ((self.budget.as_nanos() as f64 / 30.0 / per_iter.max(1.0)) as u64).max(1);

        let mut batches: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let m0 = Instant::now();
        while m0.elapsed() < self.budget || batches.len() < 3 {
            let b0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            batches.push(b0.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
            if batches.len() >= 1000 {
                break;
            }
        }
        let n = batches.len() as f64;
        let mean = batches.iter().sum::<f64>() / n;
        let var = batches.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n.max(1.0);
        let min = batches.iter().cloned().fold(f64::INFINITY, f64::min);
        let s = Sample {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: mean,
            stddev_ns: var.sqrt(),
            min_ns: min,
            items,
        };
        println!("{}", render(&s));
        self.samples.push(s);
    }
}

/// An empty filter runs everything; otherwise any comma-part matching
/// as a substring selects the benchmark.
fn filter_matches(filter: &[String], name: &str) -> bool {
    filter.is_empty() || filter.iter().any(|f| name.contains(f.as_str()))
}

/// Human-readable one-line rendering.
pub fn render(s: &Sample) -> String {
    let tput = match s.items {
        Some(items) if s.mean_ns > 0.0 => {
            format!("  {:>10.2} Kitems/s", items as f64 / s.mean_ns * 1e6)
        }
        _ => String::new(),
    };
    format!(
        "bench {:<44} {:>12} ns/iter (+/- {:>10}) min {:>12}{}",
        s.name,
        fmt_ns(s.mean_ns),
        fmt_ns(s.stddev_ns),
        fmt_ns(s.min_ns),
        tput
    )
}

/// Throughput implied by a sample: `items`/iter when reported, else
/// iterations themselves (events, ops) per second.
pub fn ops_per_sec(s: &Sample) -> f64 {
    if s.mean_ns <= 0.0 {
        return 0.0;
    }
    s.items.unwrap_or(1) as f64 * 1e9 / s.mean_ns
}

/// Output path for a `BENCH_*.json` report: `$BENCH_OUT_DIR` if set,
/// the working directory otherwise (the workspace root under `cargo
/// bench`).
pub fn out_path(file: &str) -> String {
    match std::env::var("BENCH_OUT_DIR") {
        Ok(d) if !d.is_empty() => format!("{d}/{file}"),
        _ => file.to_string(),
    }
}

/// Write samples (+ optional derived scalars, e.g. computed speedups)
/// as a machine-readable JSON report.  Schema `psbs-bench-v1`,
/// documented in rust/benches/README.md.
pub fn write_json(
    path: &str,
    bench: &str,
    samples: &[Sample],
    derived: &[(String, f64)],
) -> std::io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"psbs-bench-v1\",\n");
    s.push_str(&format!("  \"bench\": {},\n", json_str(bench)));
    s.push_str("  \"samples\": [\n");
    for (i, sm) in samples.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": {}, \"iters\": {}, \"mean_ns\": {}, \"stddev_ns\": {}, \
             \"min_ns\": {}, \"items_per_iter\": {}, \"ops_per_sec\": {}}}{}\n",
            json_str(&sm.name),
            sm.iters,
            json_num(sm.mean_ns),
            json_num(sm.stddev_ns),
            json_num(sm.min_ns),
            sm.items.map_or("null".to_string(), |v| v.to_string()),
            json_num(ops_per_sec(sm)),
            if i + 1 < samples.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"derived\": {");
    for (i, (k, v)) in derived.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        s.push_str(&format!("{}: {}", json_str(k), json_num(*v)));
    }
    s.push_str("}\n}\n");
    std::fs::write(path, s)
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON has no NaN/Infinity; non-finite values serialize as null.
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.1}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new();
        b.budget = Duration::from_millis(30);
        b.warmup = Duration::from_millis(5);
        let mut x = 0u64;
        b.bench("noop-ish", || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert_eq!(b.samples.len(), 1);
        assert!(b.samples[0].mean_ns >= 0.0);
        assert!(b.samples[0].iters > 0);
    }

    #[test]
    fn comma_filter_matches_any_part() {
        let f: Vec<String> = "event/,batch/,soa/".split(',').map(str::to_string).collect();
        assert!(filter_matches(&f, "event/psbs/n10000"));
        assert!(filter_matches(&f, "batch/grouped/psbs/burst64"));
        assert!(filter_matches(&f, "soa/event/psbs/n10k"));
        assert!(!filter_matches(&f, "sim/10k_default/psbs"));
        assert!(filter_matches(&[], "anything"), "empty filter runs everything");
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("us"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with('s'));
    }

    #[test]
    fn json_report_roundtrips_structurally() {
        let samples = vec![
            Sample {
                name: "sim/10k \"q\"\\x".to_string(),
                iters: 42,
                mean_ns: 1234.5,
                stddev_ns: 1.5,
                min_ns: 1200.0,
                items: Some(10_000),
            },
            Sample {
                name: "event/psbs".to_string(),
                iters: 7,
                mean_ns: f64::NAN, // must serialize as null, not NaN
                stddev_ns: 0.0,
                min_ns: 0.0,
                items: None,
            },
        ];
        let dir = std::env::temp_dir().join("psbs_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        write_json(
            path.to_str().unwrap(),
            "test",
            &samples,
            &[("speedup_4v1".to_string(), 2.5)],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"schema\": \"psbs-bench-v1\""));
        assert!(text.contains("\"speedup_4v1\": 2.500"));
        assert!(text.contains("\\\"q\\\"\\\\x"), "quotes/backslashes escaped: {text}");
        assert!(!text.contains("NaN"), "non-finite numbers must become null");
        // Structural sanity: balanced braces/brackets.
        let braces = text.matches('{').count();
        assert_eq!(braces, text.matches('}').count());
        assert_eq!(text.matches('[').count(), text.matches(']').count());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn ops_per_sec_uses_items() {
        let mut s = Sample {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9,
            stddev_ns: 0.0,
            min_ns: 0.0,
            items: Some(5000),
        };
        assert!((ops_per_sec(&s) - 5000.0).abs() < 1e-9);
        s.items = None;
        assert!((ops_per_sec(&s) - 1.0).abs() < 1e-12);
    }
}
