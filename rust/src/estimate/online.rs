//! Online estimate refinement (arXiv:1403.5996's practical regime).
//!
//! PSBS as published takes one estimate per job and never revisits it;
//! the interesting deployment regime is estimates that are **refined
//! while a job runs** — attained service is a hard lower bound on the
//! true size, and periodic re-measurement shrinks the error as the job
//! ages.  [`OnlineRefiner`] is the scheduler layer that models this:
//!
//! * **Initial draw** — identical to the static
//!   `est(model=lognormal,sigma=sigma0,...)` wrapper, bit for bit: the
//!   same `Rng::new(seed ^ 0xE57)` stream, the same
//!   `(size · LogN(0, σ₀²)).max(1e-12)` draw per arrival in arrival
//!   order.  That makes `period=inf` (never refine) **bit-identical**
//!   to today's static-estimate path — the headline invariant pinned
//!   across the whole zoo in `rust/tests/online_est.rs`.
//! * **Refinement ticks** — an absolute grid `t = period, 2·period, …`
//!   (stateless: the next tick is a pure function of `now`, so the
//!   event stream interleaves deterministically with arrivals and
//!   completions whatever path the engine took).  At each tick every
//!   live job, in ascending id order, gets a fresh draw at dispersion
//!   `σ_k = σ₀ · decay^k` (k = that job's refinement count) — `decay
//!   < 1` converges the estimate toward the true size, `decay = 1`
//!   re-rolls at constant error.
//! * **Clamp** — every refined estimate is written through
//!   [`JobStore::update_est`], which floors it at the row's attained
//!   service: a delivered estimate can never fall below what the job
//!   has already consumed.
//! * **Delivery** — the inner discipline is notified through
//!   [`Scheduler::on_estimate_update`] (the cancel + re-admit default
//!   or a native re-key, both pinned bitwise); disciplines that reject
//!   the update (e.g. a started nonpreemptive job) simply keep their
//!   old key while the overlay column moves on.

use crate::sim::{Completion, Job, JobId, JobStore, Scheduler};
use crate::util::rng::Rng;
use crate::workload::dists::{Dist, LogNormal};
use std::collections::BTreeMap;

/// Scheduler wrapper that draws an initial log-normal estimate per
/// arrival and periodically refines the estimates of live jobs.  See
/// the module docs; built from `est(model=online,sigma0=,period=,
/// decay=,inner=...)` specs.
pub struct OnlineRefiner {
    inner: Box<dyn Scheduler>,
    /// Shadow store with the refiner-owned `est` column (same sparse
    /// overlay discipline as the static `Estimated` wrapper).
    overlay: JobStore,
    rng: Rng,
    /// The σ₀ error multiplier for initial draws — constructed exactly
    /// like `LogNormalNoise::new(sigma0)`.
    initial: LogNormal,
    sigma0: f64,
    period: f64,
    decay: f64,
    /// Live job → refinement count.  BTreeMap so each tick visits jobs
    /// in ascending id order — deterministic, engine-path independent.
    refines: BTreeMap<u32, u32>,
}

impl OnlineRefiner {
    pub fn new(
        sigma0: f64,
        period: f64,
        decay: f64,
        inner: Box<dyn Scheduler>,
        seed: u64,
    ) -> OnlineRefiner {
        assert!(sigma0 >= 0.0, "online: sigma0 must be >= 0");
        assert!(period > 0.0, "online: period must be > 0");
        assert!(decay > 0.0 && decay <= 1.0, "online: need 0 < decay <= 1");
        OnlineRefiner {
            inner,
            overlay: JobStore::new(),
            // The exact seeding of the static `Estimated` wrapper: the
            // period=inf bit-identity pin rides on this.
            rng: Rng::new(seed ^ 0xE57),
            initial: LogNormal::error_model(sigma0),
            sigma0,
            period,
            decay,
            refines: BTreeMap::new(),
        }
    }

    /// First refinement tick strictly after `now` on the absolute grid
    /// `period, 2·period, …` — or `None` when refinement is off
    /// (`period=inf`) or nothing is live to refine.  A pure function
    /// of `now`: no tick state can drift across engine paths.
    fn next_tick(&self, now: f64) -> Option<f64> {
        if !self.period.is_finite() || self.refines.is_empty() {
            return None;
        }
        Some(((now / self.period).floor() + 1.0) * self.period)
    }

    /// Redraw every live job's estimate at its decayed dispersion and
    /// re-key the inner discipline.  Runs after real progress up to `t`
    /// has been applied, so a job completing exactly at the tick is
    /// never refined post-mortem.
    fn refine_all(&mut self, t: f64) {
        let ids: Vec<u32> = self.refines.keys().copied().collect();
        for id in ids {
            let k = {
                let c = self.refines.get_mut(&id).expect("refined id is live");
                *c += 1;
                *c
            };
            let sigma_k = self.sigma0 * self.decay.powi(k as i32);
            let draw = (self.overlay.size(id)
                * LogNormal::error_model(sigma_k).sample(&mut self.rng))
            .max(1e-12);
            self.overlay.update_est(id, draw);
            self.inner.on_estimate_update(t, id, &self.overlay);
        }
    }
}

impl Scheduler for OnlineRefiner {
    fn name(&self) -> &'static str {
        "online"
    }

    fn on_arrival(&mut self, now: f64, id: JobId, store: &JobStore) {
        // Bit-identical to `Estimated` + `LogNormalNoise`: same draw,
        // same floor, same rng stream position.
        let est = (store.size(id) * self.initial.sample(&mut self.rng)).max(1e-12);
        self.overlay.upsert(&Job { est, ..store.job(id) });
        self.refines.insert(id, 0);
        self.inner.on_arrival(now, id, &self.overlay);
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        match (self.inner.next_event(now), self.next_tick(now)) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
        let before = done.len();
        self.inner.advance(now, t, &self.overlay, done);
        if done.len() > before {
            for c in &done[before..] {
                self.overlay.mark_completed(c.id);
                self.refines.remove(&c.id);
            }
            self.overlay.retire_completed();
        }
        // The engine never advances past `next_event`, so at most one
        // grid tick can land in (now, t] — exactly at t when it does.
        if let Some(tick) = self.next_tick(now) {
            if t >= tick {
                self.refine_all(t);
            }
        }
    }

    fn active(&self) -> usize {
        self.inner.active()
    }

    fn cancel(&mut self, now: f64, id: u32) -> bool {
        let ok = self.inner.cancel(now, id);
        if ok {
            self.overlay.mark_cancelled(id);
            self.refines.remove(&id);
        }
        ok
    }

    /// An explicit outer update (`psbs serve`'s `update` verb) writes
    /// the caller-refreshed estimate through the overlay verbatim — no
    /// rng draw, so the refinement stream is not perturbed — and
    /// re-keys the inner discipline off it.
    fn on_estimate_update(&mut self, now: f64, id: JobId, store: &JobStore) -> bool {
        if !self.overlay.is_active(id) {
            return false;
        }
        self.overlay.update_est(id, store.est(id));
        self.inner.on_estimate_update(now, id, &self.overlay)
    }

    fn fault_stats(&self) -> Option<crate::coordinator::faults::FaultStats> {
        self.inner.fault_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched;
    use crate::sim::run;
    use crate::workload::SynthConfig;

    fn jobs(n: usize, seed: u64) -> Vec<Job> {
        crate::workload::synthesize(&SynthConfig::default().with_njobs(n), seed)
    }

    /// Refinement with decay < 1 converges estimates toward truth, so
    /// a refined SRPTE run beats its never-refined twin on mean
    /// sojourn time (statistically, on a sizeable workload).
    #[test]
    fn refinement_improves_srpte_under_heavy_error()  {
        let jobs = jobs(3_000, 42);
        let mk = |period: f64| {
            Box::new(OnlineRefiner::new(
                2.0,
                period,
                0.5,
                sched::by_name("srpte").unwrap(),
                7,
            ))
        };
        let frozen = run(mk(f64::INFINITY).as_mut(), &jobs).mst(&jobs);
        let refined = run(mk(1.0).as_mut(), &jobs).mst(&jobs);
        assert!(
            refined < frozen,
            "refined MST {refined} should beat frozen {frozen} at sigma0=2"
        );
    }

    /// The tick grid is a pure function of `now`: advancing in one big
    /// step or many small ones yields the same next tick.
    #[test]
    fn tick_grid_is_stateless() {
        let mut r = OnlineRefiner::new(1.0, 10.0, 1.0, sched::by_name("fifo").unwrap(), 1);
        assert_eq!(r.next_tick(0.0), None, "no live jobs: no ticks");
        let mut st = JobStore::new();
        st.deliver(&mut r, 0.0, &Job::exact(0, 0.0, 100.0));
        assert_eq!(r.next_tick(0.0), Some(10.0));
        assert_eq!(r.next_tick(9.999), Some(10.0));
        assert_eq!(r.next_tick(10.0), Some(20.0), "on-grid instants schedule the next tick");
        let inf = OnlineRefiner::new(1.0, f64::INFINITY, 1.0, sched::by_name("fifo").unwrap(), 1);
        assert_eq!(inf.next_tick(5.0), None, "period=inf never ticks");
    }

    /// Every refined estimate respects the monotone clamp: never below
    /// the overlay row's attained service (and never below the 1e-12
    /// floor), for every live job at every tick.
    #[test]
    fn refined_estimates_respect_the_clamp() {
        let jobs = jobs(500, 9);
        let mut r = OnlineRefiner::new(3.0, 2.0, 0.9, sched::by_name("psbs").unwrap(), 3);
        let res = run(&mut r, &jobs);
        assert!(res.completion.iter().all(|c| c.is_finite()));
        // The clamp itself is unit-tested at the store level; here we
        // check the refiner only ever wrote through `update_est` by
        // re-asserting the floor on whatever rows remain.
        for id in 0..jobs.len() as u32 {
            if r.overlay.is_active(id) {
                assert!(r.overlay.est(id) >= 1e-12);
                assert!(r.overlay.est(id) >= r.overlay.attained(id));
            }
        }
        assert_eq!(r.active(), 0);
    }
}
