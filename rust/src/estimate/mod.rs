//! Job-size estimation (paper §2.2).
//!
//! PSBS takes *one* estimate per job and never re-estimates; this
//! module supplies the estimators a deployment would plug in front of
//! it, mirroring the approaches the paper surveys:
//!
//! * [`OracleEstimator`] — exact sizes (the no-error baseline);
//! * [`LogNormalNoise`] — the paper's synthetic error model (Eq. 1):
//!   `s_hat = s · LogN(0, σ²)`;
//! * [`SamplingEstimator`] — HFSP-style [15]: run a fraction of the
//!   job, extrapolate from the observed rate (sampling noise shrinks
//!   with the sampled fraction);
//! * [`ProxyEstimator`] — web-server-style [16]: a correlated proxy
//!   (e.g. file size) with multiplicative bias and dispersion;
//! * [`ClassEstimator`] — semi-clairvoyant [10, 11]: only the size
//!   class ⌊log₂ s⌋ is known, the estimate is the class midpoint.
//!
//! [`measure`] evaluates any estimator *a posteriori* (§2.2: "estimation
//! error can always be evaluated a posteriori") — log-error moments and
//! the size↔estimate correlation the paper uses to report σ quality.
//!
//! The estimators above are one-shot; [`online::OnlineRefiner`] is the
//! *online* layer (arXiv:1403.5996) that re-draws a live job's estimate
//! on a periodic grid with per-job decaying dispersion, clamped so a
//! delivered estimate never falls below attained service.

pub mod online;

pub use online::OnlineRefiner;

use crate::sim::Job;
use crate::util::rng::Rng;
use crate::workload::dists::{Dist, LogNormal};

/// A job-size estimator: maps true size -> estimate (possibly random).
pub trait Estimator {
    /// Human-readable name for reports.
    fn name(&self) -> &'static str;
    /// Produce an estimate for a job of true size `size`.
    fn estimate(&self, size: f64, rng: &mut Rng) -> f64;
}

/// Exact information (σ = 0).
#[derive(Debug, Default)]
pub struct OracleEstimator;

impl Estimator for OracleEstimator {
    fn name(&self) -> &'static str {
        "oracle"
    }
    fn estimate(&self, size: f64, _rng: &mut Rng) -> f64 {
        size
    }
}

/// The paper's Eq. 1 error model: `s_hat = s · X`, `X ~ LogN(0, σ²)`.
#[derive(Debug)]
pub struct LogNormalNoise {
    dist: LogNormal,
}

impl LogNormalNoise {
    pub fn new(sigma: f64) -> Self {
        LogNormalNoise { dist: LogNormal::error_model(sigma) }
    }
}

impl Estimator for LogNormalNoise {
    fn name(&self) -> &'static str {
        "lognormal"
    }
    fn estimate(&self, size: f64, rng: &mut Rng) -> f64 {
        (size * self.dist.sample(rng)).max(1e-12)
    }
}

/// HFSP-style sampling [15]: execute a fraction `f` of the job, observe
/// a noisy per-unit rate, extrapolate.  The observed rate is modeled as
/// log-normal with dispersion shrinking as `sigma0 · sqrt(f0 / f)` —
/// sampling more of the job averages out more rate noise (CLT), which
/// reproduces HFSP's empirically log-normal estimate errors.
#[derive(Debug)]
pub struct SamplingEstimator {
    /// Sampled fraction of the job (0 < f <= 1).
    pub fraction: f64,
    /// Rate-noise dispersion at the reference fraction `f0 = 0.01`.
    pub sigma0: f64,
}

impl SamplingEstimator {
    pub fn new(fraction: f64, sigma0: f64) -> Self {
        assert!(fraction > 0.0 && fraction <= 1.0);
        SamplingEstimator { fraction, sigma0 }
    }

    /// Effective log-dispersion of this estimator.
    pub fn effective_sigma(&self) -> f64 {
        self.sigma0 * (0.01 / self.fraction).sqrt()
    }
}

impl Estimator for SamplingEstimator {
    fn name(&self) -> &'static str {
        "sampling"
    }
    fn estimate(&self, size: f64, rng: &mut Rng) -> f64 {
        let sigma = self.effective_sigma();
        let noise = (sigma * rng.normal()).exp();
        // The sampled prefix is known exactly; only the remainder is
        // extrapolated through the noisy rate.
        let sampled = size * self.fraction;
        let rest = size * (1.0 - self.fraction);
        (sampled + rest * noise).max(1e-12)
    }
}

/// Correlated-proxy estimation [16]: `s_hat = bias · s · LogN(0, σ²)`.
/// A web server using file size as the job-size proxy has `bias` =
/// 1/bandwidth (units change) and dispersion from bandwidth variance —
/// note PSBS is scale-free in estimates with equal weights, so pure
/// bias is harmless; dispersion is what hurts.
#[derive(Debug)]
pub struct ProxyEstimator {
    pub bias: f64,
    dist: LogNormal,
}

impl ProxyEstimator {
    pub fn new(bias: f64, sigma: f64) -> Self {
        assert!(bias > 0.0);
        ProxyEstimator { bias, dist: LogNormal::error_model(sigma) }
    }
}

impl Estimator for ProxyEstimator {
    fn name(&self) -> &'static str {
        "proxy"
    }
    fn estimate(&self, size: f64, rng: &mut Rng) -> f64 {
        (self.bias * size * self.dist.sample(rng)).max(1e-12)
    }
}

/// Semi-clairvoyant estimation [10, 11]: the scheduler knows only the
/// size class ⌊log₂ s⌋; the estimate is the geometric midpoint of the
/// class interval `[2^k, 2^(k+1))`.
#[derive(Debug, Default)]
pub struct ClassEstimator;

impl Estimator for ClassEstimator {
    fn name(&self) -> &'static str {
        "class"
    }
    fn estimate(&self, size: f64, _rng: &mut Rng) -> f64 {
        let k = size.log2().floor();
        (2f64.powf(k) * std::f64::consts::SQRT_2).max(1e-12)
    }
}

/// Apply an estimator to a workload (replaces each job's `est`).
pub fn apply(jobs: &[Job], est: &dyn Estimator, seed: u64) -> Vec<Job> {
    let mut rng = Rng::new(seed ^ 0xE57);
    jobs.iter().map(|j| Job { est: est.estimate(j.size, &mut rng), ..*j }).collect()
}

/// A-posteriori quality measurement (§2.2 / §6.3).
#[derive(Debug, Clone, Copy)]
pub struct ErrorStats {
    /// Mean of ln(est/size) — systematic bias in log space.
    pub log_bias: f64,
    /// Std dev of ln(est/size) — the empirical σ of Eq. 1.
    pub log_sigma: f64,
    /// Pearson correlation between size and estimate (the quality
    /// number Lu et al. [8] and §6.3 report).
    pub correlation: f64,
    /// Fraction of under-estimated jobs (est < size) — the §4.2 risk.
    pub frac_under: f64,
}

/// Measure estimate quality over a workload.
pub fn measure(jobs: &[Job]) -> ErrorStats {
    let n = jobs.len().max(1) as f64;
    let logs: Vec<f64> = jobs.iter().map(|j| (j.est / j.size).ln()).collect();
    let log_bias = crate::stats::mean(&logs);
    let log_sigma = crate::stats::stddev(&logs);
    let frac_under = jobs.iter().filter(|j| j.est < j.size).count() as f64 / n;

    let (mut sx, mut sy, mut sxx, mut syy, mut sxy) = (0.0, 0.0, 0.0, 0.0, 0.0);
    for j in jobs {
        sx += j.size;
        sy += j.est;
        sxx += j.size * j.size;
        syy += j.est * j.est;
        sxy += j.size * j.est;
    }
    let cov = sxy - sx * sy / n;
    let vx = sxx - sx * sx / n;
    let vy = syy - sy * sy / n;
    let correlation = if vx > 0.0 && vy > 0.0 { cov / (vx * vy).sqrt() } else { 1.0 };

    ErrorStats { log_bias, log_sigma, correlation, frac_under }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::SynthConfig;

    fn base_jobs(n: usize) -> Vec<Job> {
        let cfg = SynthConfig::default().with_sigma(0.0).with_njobs(n);
        crate::workload::synthesize(&cfg, 77)
    }

    #[test]
    fn oracle_is_exact() {
        let jobs = apply(&base_jobs(500), &OracleEstimator, 1);
        let s = measure(&jobs);
        assert_eq!(s.log_sigma, 0.0);
        assert_eq!(s.frac_under, 0.0);
        assert!((s.correlation - 1.0).abs() < 1e-12);
    }

    #[test]
    fn lognormal_noise_matches_requested_sigma() {
        for sigma in [0.25, 1.0, 2.0] {
            let jobs = apply(&base_jobs(20_000), &LogNormalNoise::new(sigma), 2);
            let s = measure(&jobs);
            assert!((s.log_sigma - sigma).abs() < 0.05, "sigma {sigma}: got {}", s.log_sigma);
            assert!(s.log_bias.abs() < 0.05, "bias {}", s.log_bias);
            // Under- and over-estimation equally likely (§6.3).
            assert!((s.frac_under - 0.5).abs() < 0.02, "under {}", s.frac_under);
        }
    }

    #[test]
    fn sampling_more_reduces_error() {
        let jobs = base_jobs(20_000);
        let rough = measure(&apply(&jobs, &SamplingEstimator::new(0.01, 0.5), 3));
        let fine = measure(&apply(&jobs, &SamplingEstimator::new(0.25, 0.5), 3));
        assert!(
            fine.log_sigma < rough.log_sigma / 2.0,
            "fine {} vs rough {}",
            fine.log_sigma,
            rough.log_sigma
        );
        // The sampled prefix is never under-estimated below f*s.
        let full = measure(&apply(&jobs, &SamplingEstimator::new(1.0, 0.5), 3));
        assert!(full.log_sigma < 1e-9, "fully sampled job is exact");
    }

    #[test]
    fn proxy_bias_is_pure_scale() {
        let jobs = apply(&base_jobs(5_000), &ProxyEstimator::new(100.0, 0.0), 4);
        let s = measure(&jobs);
        assert!((s.log_bias - 100f64.ln()).abs() < 1e-9);
        assert!(s.log_sigma < 1e-9, "sigma {}", s.log_sigma);
        assert!((s.correlation - 1.0).abs() < 1e-9);
    }

    #[test]
    fn class_estimator_quantizes_to_octaves() {
        let mut rng = Rng::new(5);
        let e = ClassEstimator;
        for s in [0.1, 1.0, 3.0, 1000.0] {
            let est = e.estimate(s, &mut rng);
            // Estimate within a factor sqrt(2) of the true size.
            let ratio = est / s;
            assert!(
                (std::f64::consts::FRAC_1_SQRT_2..=std::f64::consts::SQRT_2 + 1e-12)
                    .contains(&ratio),
                "size {s}: ratio {ratio}"
            );
        }
    }

    #[test]
    fn correlation_drops_with_sigma() {
        // §6.3's table: sigma 0.5 -> ~0.9, sigma 4 -> ~0.05.
        let jobs = base_jobs(50_000);
        let tight = measure(&apply(&jobs, &LogNormalNoise::new(0.5), 6));
        let loose = measure(&apply(&jobs, &LogNormalNoise::new(4.0), 6));
        assert!(tight.correlation > 0.6, "tight {}", tight.correlation);
        assert!(loose.correlation < 0.3, "loose {}", loose.correlation);
    }

    /// End to end: scheduling with a sampling estimator behaves like
    /// scheduling with the equivalent log-normal sigma (the paper's
    /// claim that the synthetic model covers practical estimators).
    #[test]
    fn sampling_estimator_schedules_like_equivalent_sigma() {
        use crate::figures::run_mst;
        let jobs = base_jobs(5_000);
        let est = SamplingEstimator::new(0.04, 0.5);
        let sampled = apply(&jobs, &est, 7);
        let sigma_eq = est.effective_sigma();
        let synthetic = apply(&jobs, &LogNormalNoise::new(sigma_eq), 7);
        let a = run_mst("psbs", &sampled);
        let b = run_mst("psbs", &synthetic);
        // Same ballpark (both near-optimal): within 25% of each other.
        assert!((a / b - 1.0).abs() < 0.25, "sampled {a} vs synthetic {b}");
    }
}
