//! Multi-Level Feedback Queue (paper §2.1, refs [6, 7]) — the classic
//! size-oblivious approximation of LAS used by real OS schedulers.
//!
//! `L` levels with geometrically growing service quanta
//! (`q, 2q, 4q, ...`): a job enters level 0; whenever it exhausts the
//! cumulative quantum of its level it is demoted one level.  The lowest
//! non-empty level is served, PS-sharing among its jobs (the fluid
//! limit of round-robin within a level).  With quanta → 0 and L → ∞
//! this converges to LAS; with one level it *is* PS — MLFQ interpolates
//! between the two, which is exactly how the scheduling literature
//! positions it.  Included in the zoo as the realistic size-oblivious
//! baseline a kernel would actually ship (cf. CFS in §5.2.2).
//!
//! Implementation: per level, a set of jobs PS-sharing; the next event
//! is the earliest of (a) a completion in the served level, (b) a
//! demotion (a job reaching its level's cumulative quantum).  Per-job
//! state is attained service; jobs within a level share equally, so a
//! level is represented by a [`MinHeap`] on *demotion threshold minus
//! attained* … but since all jobs in a level joined with different
//! attained values (only level 0 admits at 0), we keep per-job attained
//! and scan the level head; levels are small relative to n and every
//! operation stays O(log n) amortized via the heaps.

use super::MinHeap;
use crate::sim::{Completion, JobId, JobStore, Scheduler};
use crate::util::EPS;

/// One feedback level: jobs PS-share; each job is keyed by the service
/// amount at which it next *leaves* the level (completion or demotion,
/// whichever is smaller).
#[derive(Debug)]
struct Level {
    /// Cumulative attained-service ceiling of this level (f64::INFINITY
    /// for the last level).
    ceiling: f64,
    /// Jobs keyed by min(size, ceiling) — the attained-service value at
    /// which the job exits this level.  Payload: true size.
    jobs: MinHeap<f64>,
    /// Common attained service *within this level* is NOT uniform —
    /// jobs carry their own attained; we track the level's fluid
    /// progress `p`: every resident job has attained = its entry
    /// attained + (p - its entry p).  Entry attained equals the
    /// previous level's ceiling (or 0), so attained = entry + p - p_in.
    /// We fold `p_in` into the heap key: key = exit_point - entry + p_in.
    p: f64,
}

/// Multi-level feedback queue.
#[derive(Debug)]
pub struct Mlfq {
    levels: Vec<Level>,
    active: usize,
}

impl Mlfq {
    /// `nlevels` levels, base quantum `q0` (level k ceiling:
    /// `q0 · (2^(k+1) − 1)`).
    pub fn new(nlevels: usize, q0: f64) -> Self {
        assert!(nlevels >= 1 && q0 > 0.0);
        let mut ceiling = 0.0;
        let levels = (0..nlevels)
            .map(|k| {
                ceiling += q0 * (1 << k) as f64;
                Level {
                    ceiling: if k + 1 == nlevels { f64::INFINITY } else { ceiling },
                    jobs: MinHeap::new(),
                    p: 0.0,
                }
            })
            .collect();
        Mlfq { levels, active: 0 }
    }

    /// The paper-calibrated default: 8 levels, base quantum 0.05 (mean
    /// job size is 1 in Table-1 workloads, so small jobs finish in the
    /// top levels and elephants sink).
    pub fn default_zoo() -> Self {
        Mlfq::new(8, 0.05)
    }

    /// Served level = lowest non-empty.
    fn served(&self) -> Option<usize> {
        self.levels.iter().position(|l| !l.jobs.is_empty())
    }

    /// Entry attained-service of a level (previous ceiling).
    fn entry_of(&self, level: usize) -> f64 {
        if level == 0 {
            0.0
        } else {
            self.levels[level - 1].ceiling
        }
    }
}

impl Scheduler for Mlfq {
    fn name(&self) -> &'static str {
        "mlfq"
    }

    fn on_arrival(&mut self, _now: f64, id: JobId, store: &JobStore) {
        let size = store.size(id);
        self.active += 1;
        let l = &mut self.levels[0];
        // Exit point in fluid-progress coordinates: the job leaves
        // level 0 after min(size, ceiling) service; it has had 0.
        let exit = size.min(l.ceiling);
        l.jobs.push(l.p + exit, id as u64, size);
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        let lvl = self.served()?;
        let l = &self.levels[lvl];
        let (key, _, _) = l.jobs.peek()?;
        let k = l.jobs.len() as f64;
        // Fluid progress advances at 1/k per unit time.
        Some(now + ((key - l.p) * k).max(0.0))
    }

    fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
        let Some(lvl) = self.served() else { return };
        let entry = self.entry_of(lvl);
        let next_entry_p = if lvl + 1 < self.levels.len() {
            Some(self.levels[lvl + 1].p)
        } else {
            None
        };
        let l = &mut self.levels[lvl];
        let k = l.jobs.len() as f64;
        if k > 0.0 {
            l.p += (t - now) / k;
        }
        // Process exits at the head: completions and demotions.
        let mut demoted: Vec<(u64, f64)> = Vec::new();
        while let Some((key, _, _)) = l.jobs.peek() {
            if key - l.p > EPS {
                break;
            }
            let (_, id, size) = l.jobs.pop().unwrap();
            let attained_at_exit = entry + (key - (key - l.p)) - l.p + (key - l.p);
            let _ = attained_at_exit; // attained at exit == entry + (key - p_in)
            if size <= l.ceiling + EPS {
                // Exit point was completion.
                self.active -= 1;
                done.push(Completion { id: id as u32, time: t });
            } else {
                demoted.push((id, size));
            }
        }
        if let (Some(p_next), false) = (next_entry_p, demoted.is_empty()) {
            let ceiling_here = l.ceiling;
            let next = &mut self.levels[lvl + 1];
            for (id, size) in demoted {
                // The job has attained exactly `ceiling_here`; in the
                // next level it exits after min(size, next.ceiling) -
                // ceiling_here more service.
                let more = size.min(next.ceiling) - ceiling_here;
                next.jobs.push(p_next.max(next.p) + more, id, size);
            }
            let _ = p_next;
        }
    }

    fn active(&self) -> usize {
        self.active
    }

    /// §5.2.2 kill bookkeeping: probe each level for the id (levels
    /// are few — the geometric quantum ladder — and `remove_by_seq`
    /// scans only the owning level's heap).  The level's fluid progress
    /// `p` is untouched: remaining residents keep their exact attained
    /// service and simply split the freed capacity.
    fn cancel(&mut self, _now: f64, id: u32) -> bool {
        for l in self.levels.iter_mut() {
            if l.jobs.remove_by_seq(id as u64).is_some() {
                self.active -= 1;
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run, Job};

    #[test]
    fn single_level_is_ps() {
        let jobs = vec![Job::exact(0, 0.0, 1.0), Job::exact(1, 0.0, 1.0)];
        let r = run(&mut Mlfq::new(1, 1.0), &jobs);
        assert!((r.completion[0] - 2.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[1] - 2.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn small_job_beats_elephant() {
        // Elephant (size 10) sinks below level 0; a size-0.04 job
        // arriving later finishes almost immediately.
        let jobs = vec![Job::exact(0, 0.0, 10.0), Job::exact(1, 1.0, 0.04)];
        let r = run(&mut Mlfq::default_zoo(), &jobs);
        let sojourn1 = r.completion[1] - 1.0;
        assert!(sojourn1 < 0.1, "small job sojourn {sojourn1}");
        assert!((r.completion[0] - 10.04).abs() < 1e-6, "{:?}", r.completion);
    }

    #[test]
    fn demotion_chain_completes_everything() {
        let jobs: Vec<Job> = (0..20)
            .map(|i| Job::exact(i, i as f64 * 0.1, 0.01 + 0.37 * i as f64))
            .collect();
        let r = run(&mut Mlfq::default_zoo(), &jobs);
        assert!(r.completion.iter().all(|c| c.is_finite()));
        // Work conservation on the busy period tail.
        let total: f64 = jobs.iter().map(|j| j.size).sum();
        let last = r.completion.iter().cloned().fold(0.0, f64::max);
        assert!(last <= jobs.last().unwrap().arrival + total + 1e-6);
    }

    #[test]
    fn sits_between_ps_and_las_on_heavy_tail() {
        use crate::figures::run_mst;
        let cfg = crate::workload::SynthConfig::default().with_njobs(4_000);
        let jobs = crate::workload::synthesize(&cfg, 11);
        let mlfq = run(&mut Mlfq::default_zoo(), &jobs).mst(&jobs);
        let ps = run_mst("ps", &jobs);
        let las = run_mst("las", &jobs);
        // MLFQ approximates LAS: better than PS, within 2x of LAS.
        assert!(mlfq < ps, "mlfq {mlfq} should beat ps {ps}");
        assert!(mlfq < las * 2.0, "mlfq {mlfq} vs las {las}");
    }

    #[test]
    fn size_oblivious() {
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 1.0, est: 100.0, weight: 1.0 },
            Job { id: 1, arrival: 0.0, size: 1.0, est: 0.001, weight: 1.0 },
        ];
        let r = run(&mut Mlfq::default_zoo(), &jobs);
        assert!((r.completion[0] - r.completion[1]).abs() < 1e-9);
    }

    /// Kill a demoted elephant and a top-level job; survivors finish.
    #[test]
    fn cancel_across_levels() {
        let mut s = Mlfq::default_zoo();
        let mut st = crate::sim::JobStore::new();
        let mut done = Vec::new();
        st.deliver(&mut s, 0.0, &Job::exact(0, 0.0, 10.0));
        // Serve long enough that the elephant sinks below level 0
        // (level-0 ceiling is 0.05 in the default zoo).
        s.advance(0.0, s.next_event(0.0).unwrap(), &st, &mut done);
        st.deliver(&mut s, 1.0, &Job::exact(1, 1.0, 0.04));
        st.deliver(&mut s, 1.0, &Job::exact(2, 1.0, 0.04));
        assert!(done.is_empty());
        assert!(s.cancel(1.0, 0), "kill the demoted elephant");
        assert!(s.cancel(1.0, 1), "kill a level-0 job");
        assert!(!s.cancel(1.0, 1), "double kill must fail");
        assert!(!s.cancel(1.0, 7), "unknown id must fail");
        assert_eq!(s.active(), 1);
        let ev = s.next_event(1.0).unwrap();
        s.advance(1.0, ev, &st, &mut done);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 2);
        assert_eq!(s.active(), 0);
    }
}
