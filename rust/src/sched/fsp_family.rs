//! The FSP family: FSPE, FSPE+PS, FSPE+LAS and **PSBS** (Algorithm 1).
//!
//! All four share the same O(log n) core, which is the paper's §5.2.2
//! contribution: a *virtual* DPS system emulated with the virtual-lag
//! trick.  The global lag `g` grows at `1/w_v` (`w_v` = Σ weights of
//! jobs running in virtual time); an arriving job gets an immutable
//! completion lag `g_i = g + s_hat_i / w_i` and two binary min-heaps on
//! `g_i` — `O` (running in both systems) and `E` ("early": really done,
//! virtually running) — yield virtual completions in O(log n) with *no
//! per-arrival updates of other jobs* (the classic FSP implementation
//! pays O(n) there; see [`super::fsp_naive`] and the `psbs_ops` bench).
//!
//! Real-side scheduling:
//! * no late jobs → serve the head of `O` (earliest virtual completion)
//!   at rate 1;
//! * late jobs present (virtually complete, really pending — the §4.2
//!   failure mode) → serve **only** the late set `L`, owned by the
//!   shared [`LateSet`] engine and shared per [`LateMode`]:
//!   - [`LateMode::Serial`]: one at a time in virtual-completion order
//!     — plain **FSPE**, kept faithful to reproduce its pathology;
//!   - [`LateMode::Ps`]: equal split — **FSPE+PS**;
//!   - [`LateMode::Las`]: least-attained-service split — **FSPE+LAS**;
//!   - [`LateMode::Dps`]: weight-proportional split — **PSBS** (with
//!     the virtual system also weight-aware).
//!
//! Every late-set operation — membership, per-mode event computation,
//! §5.2.2 cancellation — is O(log |L|) via [`LateSet`]; the flat
//! per-event folds this module used to carry are gone, which is what
//! makes the hot path scale in the heavy-underestimation regime where
//! |L| grows large.  Both `w_v` (here) and `w_l` (inside the set) are
//! drift-proof compensated sums, recomputed/reset on empty.
//!
//! ### Note on the paper's pseudocode
//! Algorithm 1 as printed decrements `w_v` only when a virtual
//! completion pops from `E`; when a job pops from `O` into the late map
//! it would keep (forever) inflating `w_v`, contradicting the listing's
//! own invariant comment "`w_v = Σ w_i` ∀ i running in virtual time".
//! The paper explicitly defers "additional bookkeeping" to its
//! simulator, whose released implementation removes late jobs from the
//! virtual system.  We decrement in both branches; the no-error
//! equivalence with FSP (tested in `rust/tests/equivalence.rs`) and the
//! Fig. 2 worked example both pin this choice down.

use super::late_set::{CompensatedSum, LateSet};
use super::MinHeap;
use crate::sim::{Completion, JobId, JobStore, Scheduler};
use crate::util::EPS;

pub use super::late_set::LateMode;

/// Per-job real-side state for jobs in `O` (indexed by heap payload).
#[derive(Debug, Clone, Copy)]
struct OJob {
    weight: f64,
    true_rem: f64,
    size: f64,
}

/// FSPE / FSPE+PS / FSPE+LAS / PSBS scheduler (Algorithm 1).
#[derive(Debug)]
pub struct FspFamily {
    /// Respect `Job::weight` (PSBS); the FSPE variants force 1.
    use_weights: bool,
    /// Ablation: keep `w_v` inflated when a job pops from `O` into the
    /// late map, as the paper's Algorithm 1 listing literally reads
    /// (see the module note).  Slows virtual time while late jobs
    /// exist; exposed as `psbs-paperlit` for the ablation bench.
    paper_literal_wv: bool,
    /// Virtual lag `g`.
    g: f64,
    /// Σ weights running in the virtual system (`O` ∪ `E`) —
    /// compensated so millions of arrivals/departures cannot drift the
    /// virtual clock rate, and still reset when the system empties.
    w_v: CompensatedSum,
    /// Jobs running in both systems, keyed by `g_i`.
    o: MinHeap<OJob>,
    /// Early jobs (really done, virtually running), keyed by `g_i`.
    e: MinHeap<f64>, // payload: weight
    /// The late set (virtually complete, really pending), sharing the
    /// server per its [`LateMode`]; owns `w_l`.
    late: LateSet,
    /// Periodic `w_v`-vs-fold drift check (debug builds only).
    #[cfg(debug_assertions)]
    check_tick: u32,
}

/// The paper's headline scheduler: weight-aware FSPE+PS.
pub type Psbs = FspFamily;

impl FspFamily {
    fn with(late_mode: LateMode, use_weights: bool) -> Self {
        FspFamily {
            use_weights,
            paper_literal_wv: false,
            g: 0.0,
            w_v: CompensatedSum::new(),
            // `o` is indexed: cancellation removes by job id, and the
            // seq -> slot index makes that O(log n) (§5.2.2
            // bookkeeping).  Job ids are dense (the engine asserts it),
            // so the index is the dense `Vec<usize>` variant: sift
            // swaps on the arrival/virtual-completion hot path pay one
            // array write instead of a hash probe (the `event/` vs
            // `cancel/` trade-off tracked in BENCH_psbs_ops.json).
            // `e` is only ever popped from the top; no index needed.
            o: MinHeap::with_dense_index(),
            e: MinHeap::new(),
            late: LateSet::new(late_mode),
            #[cfg(debug_assertions)]
            check_tick: 0,
        }
    }

    /// PSBS (§5.2): DPS among late jobs, weighted virtual system.
    pub fn new() -> Self {
        Self::with(LateMode::Dps, true)
    }

    /// Plain FSPE (§4.2): serial late jobs — the pathological baseline.
    pub fn fspe() -> Self {
        Self::with(LateMode::Serial, false)
    }

    /// FSPE+PS (§5.1): PS among late jobs.
    pub fn fspe_ps() -> Self {
        Self::with(LateMode::Ps, false)
    }

    /// FSPE+LAS (§5.1): LAS among late jobs.
    pub fn fspe_las() -> Self {
        Self::with(LateMode::Las, false)
    }

    /// Ablation: PSBS with the w_v bookkeeping exactly as Algorithm 1
    /// is printed (no decrement when a job goes late).  Late jobs then
    /// keep slowing the virtual clock they no longer participate in,
    /// delaying subsequent virtual completions.  Still work-conserving
    /// and correct — just a different (worse) aging rate; the ablation
    /// bench quantifies the gap that justifies the module-note fix.
    pub fn psbs_paper_literal() -> Self {
        let mut s = Self::with(LateMode::Dps, true);
        s.paper_literal_wv = true;
        s
    }

    /// Residual virtual-system population (jobs still tracked in `O` ∪
    /// `E`) — 0 after a drained run with correct bookkeeping; grows
    /// without bound under the paper-literal `w_v` ablation (every job
    /// that ever went late parks a tombstone in the virtual system).
    pub fn virtual_residue(&self) -> usize {
        self.o.len() + self.e.len()
    }

    fn weight_of(&self, weight: f64) -> f64 {
        if self.use_weights {
            weight
        } else {
            1.0
        }
    }

    /// Rebuild with a plain (unindexed) `O` heap — the opt-in escape
    /// hatch for sweep deployments with no kill path (see
    /// `PolicySpec::build_sweep`).  Only valid on a fresh instance.
    pub fn unindexed(self) -> Self {
        debug_assert_eq!(self.o.len(), 0, "unindexed() only on fresh instances");
        FspFamily { o: MinHeap::new(), ..self }
    }

    /// `NextVirtualCompletionTime` (Algorithm 1): when `g` reaches the
    /// smallest `g_i` across `O` and `E`.
    fn next_virtual_completion(&self, now: f64) -> Option<f64> {
        let g_o = self.o.peek().map(|(g, _, _)| g);
        let g_e = self.e.peek().map(|(g, _, _)| g);
        let g_hat = match (g_o, g_e) {
            (None, None) => return None,
            (Some(a), None) => a,
            (None, Some(b)) => b,
            (Some(a), Some(b)) => a.min(b),
        };
        Some(now + ((g_hat - self.g) * self.w_v.value()).max(0.0))
    }

    /// `VirtualJobCompletion`: pop every virtually-complete job.
    fn drain_virtual_completions(&mut self) {
        loop {
            let g_o = self.o.peek().map(|(g, _, _)| g);
            let g_e = self.e.peek().map(|(g, _, _)| g);
            let (g_hat, from_o) = match (g_o, g_e) {
                (None, None) => break,
                (Some(a), None) => (a, true),
                (None, Some(b)) => (b, false),
                (Some(a), Some(b)) => {
                    if a <= b {
                        (a, true)
                    } else {
                        (b, false)
                    }
                }
            };
            if (g_hat - self.g) * self.w_v.value() > EPS {
                break;
            }
            if from_o {
                // The job becomes late: it leaves the virtual system
                // and joins L (see module note on the w_v decrement).
                let (_, id, oj) = self.o.pop().unwrap();
                if !self.paper_literal_wv {
                    self.w_v.sub(oj.weight);
                }
                self.late.insert(id as u32, oj.weight, oj.true_rem, oj.size);
            } else {
                let (_, _, w) = self.e.pop().unwrap();
                self.w_v.sub(w);
            }
            if self.o.is_empty() && self.e.is_empty() && !self.paper_literal_wv {
                self.w_v.reset(); // kill accumulated rounding
            }
        }
        self.debug_check_wv();
    }

    /// Periodic drift pin: the incremental `w_v` must match a fresh
    /// fold over `O` ∪ `E` (every 64th drain + whenever either heap
    /// empties; debug builds only).
    #[cfg(debug_assertions)]
    fn debug_check_wv(&mut self) {
        if self.paper_literal_wv {
            return; // the ablation inflates w_v on purpose
        }
        self.check_tick = self.check_tick.wrapping_add(1);
        if self.virtual_residue() != 0 && self.check_tick % 64 != 0 {
            return;
        }
        let fold: f64 = self.o.iter().map(|(_, _, oj)| oj.weight).sum::<f64>()
            + self.e.iter().map(|(_, _, w)| *w).sum::<f64>();
        let scale = fold.abs().max(1.0);
        debug_assert!(
            (self.w_v.value() - fold).abs() <= 1e-9 * scale,
            "w_v drift: incremental {} vs fold {}",
            self.w_v.value(),
            fold
        );
    }

    #[cfg(not(debug_assertions))]
    #[inline]
    fn debug_check_wv(&mut self) {}
}

impl Default for FspFamily {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for FspFamily {
    fn name(&self) -> &'static str {
        match self.late.mode() {
            LateMode::Serial => "fspe",
            LateMode::Ps => "fspe+ps",
            LateMode::Las => "fspe+las",
            LateMode::Dps => "psbs",
        }
    }

    /// `JobArrival` (Algorithm 1): O(1) amortized — one heap push, no
    /// updates to other jobs.
    fn on_arrival(&mut self, _now: f64, id: JobId, store: &JobStore) {
        // The engine has already advanced state (UpdateVirtualTime) to
        // `now`.
        let size = store.size(id);
        let w = self.weight_of(store.weight(id));
        let g_i = self.g + store.est(id) / w;
        self.o.push(g_i, id as u64, OJob { weight: w, true_rem: size, size });
        self.w_v.add(w);
    }

    /// Explicit batch-admission hook for the FSP family: today the
    /// body is the same per-id loop as the trait default (delivery
    /// order and every fp operation identical, so results stay
    /// bit-identical to per-job delivery); it exists so a future bulk
    /// admission — e.g. building the burst's O-heap entries with one
    /// heapify instead of per-push sifts — lands here without touching
    /// the trait.  `inline(always)` on `on_arrival` is not needed: the
    /// loop monomorphizes against `Self`, so the calls are static.
    fn on_arrival_batch(&mut self, now: f64, ids: std::ops::Range<JobId>, store: &JobStore) {
        for id in ids {
            self.on_arrival(now, id, store);
        }
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        let mut dt = f64::INFINITY;
        // Virtual completion.
        if let Some(t_v) = self.next_virtual_completion(now) {
            dt = dt.min(t_v - now);
        }
        if self.late.is_empty() {
            // Real side: head of O at rate 1.
            if let Some((_, _, oj)) = self.o.peek() {
                dt = dt.min(oj.true_rem);
            }
        } else {
            // Real side: the late set owns the server; its earliest
            // completion / regroup is an O(1) read.
            if let Some(d) = self.late.next_event_dt(self.late.exclusive_share()) {
                dt = dt.min(d);
            }
        }
        if dt.is_finite() {
            Some(now + dt.max(0.0))
        } else {
            None
        }
    }

    fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
        let dt = t - now;

        // ---- real progress over [now, t) (rates constant inside) ----
        if self.late.is_empty() {
            // Serve the head of O at rate 1; in-place O(1) update (the
            // heap key g_i never changes).
            let completed = match self.o.head_mut() {
                Some(oj) => {
                    oj.true_rem -= dt;
                    oj.true_rem <= EPS
                }
                None => false,
            };
            if completed {
                // `RealJobCompletion`: push pop(O) into E.
                let (g_i, id, oj) = self.o.pop().unwrap();
                self.e.push(g_i, id, oj.weight);
                done.push(Completion { id: id as u32, time: t });
            }
        } else {
            // `RealJobCompletion` for late jobs happens inside the set.
            let share = self.late.exclusive_share();
            self.late.advance(dt, share, t, done);
        }

        // ---- virtual progress (`UpdateVirtualTime`) ----
        if self.w_v.value() > 0.0 {
            self.g += dt / self.w_v.value();
        }
        self.drain_virtual_completions();
    }

    fn active(&self) -> usize {
        self.o.len() + self.late.len()
    }

    /// §5.2.2's "additional bookkeeping": a killed job leaves the real
    /// system immediately.  If it was still running virtually (in `O`)
    /// it must keep its virtual share until its virtual completion —
    /// exactly like a job that finished early — so it moves to `E`;
    /// a late job simply leaves `L` (O(log |L|) via the set's index).
    fn cancel(&mut self, _now: f64, id: u32) -> bool {
        if let Some((g_i, seq, oj)) = self.o.remove_by_seq(id as u64) {
            self.e.push(g_i, seq, oj.weight);
            return true;
        }
        self.late.cancel(id)
    }

    /// Native virtual-schedule re-key, bitwise-equal to cancel +
    /// re-admit (the trait default, pinned in `rust/tests/online_est.rs`)
    /// — here the equivalence is exact by construction, because the
    /// virtual-lag algebra leaves no cheaper sound shortcut: a job's
    /// completion lag `g_i` is immutable once issued (that immutability
    /// is what makes arrivals O(1) amortized), so re-keying *means*
    /// retiring the old entry and issuing a new lag.  The two late-set
    /// boundary directions are handled explicitly:
    ///
    /// * **O → E ghost**: a job still running virtually keeps its old
    ///   `g_i` share until that virtual completion — exactly the
    ///   §5.2.2 kill bookkeeping — while the refreshed job re-enters
    ///   `O` below at `g + est_new / w` (so `w_v` counts both the
    ///   ghost and the live entry until the ghost drains);
    /// * **late → O**: a late job's refreshed estimate supersedes the
    ///   "virtually complete" verdict — it leaves `L` and rejoins the
    ///   virtual system as a fresh arrival (crossing back out of the
    ///   late set; the inward crossing happens on the next virtual
    ///   completion if the new estimate is still too small).
    fn on_estimate_update(&mut self, now: f64, id: JobId, store: &JobStore) -> bool {
        if let Some((g_old, seq, oj)) = self.o.remove_by_seq(id as u64) {
            self.e.push(g_old, seq, oj.weight);
        } else if !self.late.cancel(id) {
            return false;
        }
        self.on_arrival(now, id, store);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run, Job};

    /// The paper's Fig. 2 worked example, end to end.
    #[test]
    fn fig2_virtual_lag_example() {
        // Sizes 10, 5, 2 arriving at t = 0, 3, 5 with unit weights.
        let jobs = vec![
            Job::exact(0, 0.0, 10.0),
            Job::exact(1, 3.0, 5.0),
            Job::exact(2, 5.0, 2.0),
        ];
        let mut s = Psbs::new();
        let mut st = crate::sim::JobStore::new();
        // Drive arrivals manually to inspect the lag values the paper
        // quotes: g1 = 10, g2 = 3 + 5 = 8, g3 = 4 + 2 = 6.
        let mut done = Vec::new();
        st.deliver(&mut s, 0.0, &jobs[0]);
        assert!((head_g(&s.o) - 10.0).abs() < 1e-12);
        s.advance(0.0, 3.0, &st, &mut done);
        assert!((s.g - 3.0).abs() < 1e-12);
        st.deliver(&mut s, 3.0, &jobs[1]);
        s.advance(3.0, 5.0, &st, &mut done);
        assert!((s.g - 4.0).abs() < 1e-12, "g={} (paper: 4)", s.g);
        st.deliver(&mut s, 5.0, &jobs[2]);
        // g3 = 4 + 2/1 = 6 and J3 is now the virtual-order head.
        assert!((head_g(&s.o) - 6.0).abs() < 1e-12);

        // Full run: real completions follow FSP: J3 at 7, J2 at 10, J1 at 17.
        let r = run(&mut Psbs::new(), &jobs);
        assert!((r.completion[2] - 7.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[1] - 10.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[0] - 17.0).abs() < 1e-9, "{:?}", r.completion);
    }

    fn head_g(h: &MinHeap<OJob>) -> f64 {
        h.peek().map(|(g, _, _)| g).unwrap()
    }

    #[test]
    fn no_errors_means_no_late_jobs() {
        use crate::workload::dists::{Dist, Weibull};
        let mut rng = crate::util::rng::Rng::new(3);
        let w = Weibull::unit_mean(0.25);
        let mut t = 0.0;
        let jobs: Vec<Job> = (0..500)
            .map(|i| {
                t += rng.u01();
                Job::exact(i, t, w.sample(&mut rng).max(1e-9))
            })
            .collect();
        // With exact sizes FSP dominance guarantees real completion
        // never precedes virtual completion, so L stays empty and the
        // run completes with PSBS == FSP semantics.
        let r = run(&mut Psbs::new(), &jobs);
        assert!(r.completion.iter().all(|c| c.is_finite()));
    }

    #[test]
    fn underestimated_job_goes_late_but_does_not_block_psbs() {
        // J0: size 4, est 1. Virtually completes at t=1 (alone) -> late.
        // J1 (size 1, exact) arrives at 2: under plain FSPE it waits
        // for J0 (done at 4), completing at 5; under PSBS it shares.
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 4.0, est: 1.0, weight: 1.0 },
            Job::exact(1, 2.0, 1.0),
        ];
        let fspe = run(&mut FspFamily::fspe(), &jobs);
        assert!((fspe.completion[0] - 4.0).abs() < 1e-9, "{:?}", fspe.completion);
        assert!((fspe.completion[1] - 5.0).abs() < 1e-9, "{:?}", fspe.completion);

        let psbs = run(&mut Psbs::new(), &jobs);
        // J0 late alone until t=2. J1 arrives: virtual system has only
        // J1 (J0 left it): g_1 = g + 1. J1 completes virtually at
        // t = 3 and becomes late too; late set shares equally after 3.
        // [2,3): J0 alone (serial? no: late set = {J0}, J1 not late yet,
        // and with late jobs present only L is served). J0 rem 4-2-1=1.
        // [3,...): {J0 rem 1, J1 rem 1} at 1/2 -> both done at 5?
        // J0 done at 5, J1 done at 5.
        assert!((psbs.completion[1] - 5.0).abs() < 1e-9, "{:?}", psbs.completion);
        assert!((psbs.completion[0] - 5.0).abs() < 1e-9, "{:?}", psbs.completion);
    }

    #[test]
    fn heap_invariants_hold_under_churn() {
        use crate::workload::dists::{Dist, LogNormal, Weibull};
        let mut rng = crate::util::rng::Rng::new(17);
        let w = Weibull::unit_mean(0.25);
        let e = LogNormal::error_model(2.0);
        let mut t = 0.0;
        let jobs: Vec<Job> = (0..400)
            .map(|i| {
                t += rng.u01() * 0.2;
                let size = w.sample(&mut rng).max(1e-9);
                Job { id: i, arrival: t, size, est: size * e.sample(&mut rng), weight: 1.0 }
            })
            .collect();
        let mut s = Psbs::new();
        let r = run(&mut s, &jobs);
        assert!(s.o.check_invariant() && s.e.check_invariant());
        assert!(r.completion.iter().all(|c| c.is_finite()));
        assert_eq!(s.active(), 0);
    }

    #[test]
    fn weights_prioritize_heavy_class() {
        // Two identical streams, one with weight 4: the heavy job beats
        // the light one arriving at the same instant.
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 2.0, est: 2.0, weight: 1.0 },
            Job { id: 1, arrival: 0.0, size: 2.0, est: 2.0, weight: 4.0 },
        ];
        let r = run(&mut Psbs::new(), &jobs);
        assert!(
            r.completion[1] < r.completion[0],
            "heavier job must complete first: {:?}",
            r.completion
        );
        // g_0 = 2/1 = 2, g_1 = 2/4 = 0.5 -> J1 served first, done at 2;
        // J0 done at 4.
        assert!((r.completion[1] - 2.0).abs() < 1e-9);
        assert!((r.completion[0] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn psbs_matches_fspe_ps_with_unit_weights() {
        use crate::workload::dists::{Dist, LogNormal, Weibull};
        let mut rng = crate::util::rng::Rng::new(29);
        let w = Weibull::unit_mean(0.5);
        let e = LogNormal::error_model(1.0);
        let mut t = 0.0;
        let jobs: Vec<Job> = (0..300)
            .map(|i| {
                t += rng.u01() * 0.5;
                let size = w.sample(&mut rng).max(1e-9);
                Job { id: i, arrival: t, size, est: size * e.sample(&mut rng), weight: 1.0 }
            })
            .collect();
        let a = run(&mut Psbs::new(), &jobs).completion;
        let b = run(&mut FspFamily::fspe_ps(), &jobs).completion;
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!((x - y).abs() < 1e-6, "job {i}: psbs {x} vs fspe+ps {y}");
        }
    }

    /// Killing a late job in every mode: the set's cancel path.
    #[test]
    fn cancel_late_job_every_mode() {
        for mk in [FspFamily::fspe, FspFamily::fspe_ps, FspFamily::fspe_las, FspFamily::new] {
            let mut s = mk();
            let mut st = crate::sim::JobStore::new();
            // Underestimated: goes late at t=1 while really pending.
            st.deliver(&mut s, 0.0, &Job { id: 0, arrival: 0.0, size: 4.0, est: 1.0, weight: 1.0 });
            let mut done = Vec::new();
            s.advance(0.0, 1.5, &st, &mut done);
            assert!(done.is_empty(), "{}: nothing really completes by 1.5", s.name());
            assert_eq!(s.late.len(), 1, "{}: job must be late", s.name());
            assert!(s.cancel(1.5, 0), "{}", s.name());
            assert!(!s.cancel(1.5, 0), "{}: double cancel", s.name());
            assert_eq!(s.active(), 0, "{}", s.name());
        }
    }
}
