//! The scheduler zoo: every discipline evaluated in the paper.
//!
//! | module | disciplines | kill (`cancel`) semantics | `on_estimate_update` strategy | paper § |
//! |--------|-------------|---------------------------|-------------------------------|---------|
//! | [`fifo`] | FIFO | queue removal; killed head promotes the next job | default (est-oblivious: cancel + re-admit legally moves the queue position) | §6.1 |
//! | [`ps`] | PS, DPS (virtual-lag implementation) | lag-heap removal; survivors split the freed weight | default (est-oblivious: re-admit re-issues the lag) | §6.1 |
//! | [`las`] | LAS (attained-service levels) | id → level map, heap removal, empty-level reclaim | default (est-oblivious: re-admit resets attained to level 0) | §2.1, §6.1 |
//! | [`mlfq`] | MLFQ (geometric quanta) | per-level probe + heap removal | default (est-oblivious: re-admit restarts at the top queue) | §2.1 |
//! | [`srpt`] | SRPT / SRPTE (late jobs block) | served slot cleared (next waiter pulled) or heap removal | **native**: in-place slot re-key fast path, waiting-heap re-sift | §4 |
//! | [`srpte_hybrid`] | SRPTE+PS, SRPTE+LAS | slot / [`late_set`] / waiting-heap removal, O(log n) | **native**: slot fast path; late → eligible boundary crossing; heap re-sift | §5.1 |
//! | [`fsp_family`] | FSPE, FSPE+PS, FSPE+LAS, **PSBS** (Algorithm 1) | `O` job keeps its virtual share (moves to `E`); late job leaves [`late_set`] | **native**: virtual re-key — O → `E` ghost + fresh lag, or late → O re-entry | §4.2, §5 |
//! | [`fsp_naive`] | FSP/FSPE with the classic O(n) virtual update | same semantics as `fsp_family`, O(n) | default (cancel + re-admit already is the flat-path re-key) | §3, §5.2.2 |
//! | [`pri`] | Pri_S — the §3 dominance construction | rank-heap removal | default (cancel + re-admit re-ranks) | §3 |
//! | [`nonpreemptive`] | SPT (by estimate), SJF (by true size) | waiting-heap removal; a **started job rejects** the kill | default; started jobs report unsupported (cancel fails) | — |
//!
//! Every native `on_estimate_update` override is pinned **bitwise**
//! against the trait default (cancel + re-admit) under refinement +
//! kill churn in `rust/tests/online_est.rs`; est-oblivious disciplines
//! keep the default, because for them a no-op would *not* equal cancel
//! + re-admit (which legally resets queue position / lag / attained).
//!
//! Every discipline supports `cancel` — the §5.2.2 "additional
//! bookkeeping … to handle jobs that complete even when they are not
//! scheduled (e.g. … after being killed)" — so `coordinator::Service`
//! kills work across the whole zoo (property-tested under churn in
//! `rust/tests/cancellation.rs`).  The same `cancel` path is what
//! server **crashes** ride: under a `coordinator::FaultPlan` the
//! cluster cancels every copy on the crashed server (attained work
//! lost — LAS/MLFQ levels, FSP virtual shares and late-set membership
//! all reset for the re-dispatched attempt, which arrives as a fresh
//! job) and retries it per `coordinator::RetryPolicy` until it
//! completes or is accounted lost; disciplines need no fault-specific
//! code, and `completions + lost == arrivals` is conserved for every
//! row of the table above (`rust/tests/faults.rs`).  [`late_set`] is the shared engine
//! behind the error-tolerant disciplines' late sets — O(log |L|)
//! membership (plus O(#levels) level positioning in Las mode) and
//! O(1) per-event reads, replacing the old flat O(|L|) folds.
//!
//! All implement [`crate::sim::Scheduler`] and are cross-validated
//! against the independent small-step oracle in `rust/tests/crossval.rs`.
//!
//! ### The store-aware trait contract
//! Arrivals are delivered as `on_arrival(now, id, store: &JobStore)`:
//! the job's `arrival`/`size`/`est`/`weight` live once, as columns of
//! the engine-owned struct-of-arrays [`crate::sim::JobStore`], and a
//! discipline reads the fields it keys on (`store.size(id)`,
//! `store.est(id)`, …) instead of receiving a `Job` copy.  A
//! discipline may read any column of any id it has been delivered and
//! not yet completed/cancelled, and must copy whatever it needs beyond
//! that window — the engine retires completed prefix rows to keep
//! streaming memory O(active).  `advance(now, t, store, done)` borrows
//! the store too (composite schedulers read job fields mid-step).
//! Same-instant arrival bursts arrive as one
//! `on_arrival_batch(now, ids, store)` call whose default body is the
//! per-id loop: batching is an engine-side dispatch optimization
//! (one virtual call per burst), never a semantic change — overriders
//! must deliver in id order, and none of the zoo's disciplines
//! override it (the bit-identity pins across PRs 1–8 rely on the
//! one-by-one fp operation order).

pub mod fifo;
pub mod fsp_family;
pub mod fsp_naive;
pub mod las;
pub mod late_set;
pub mod mlfq;
pub mod nonpreemptive;
pub mod pri;
pub mod ps;
pub mod srpt;
pub mod srpte_hybrid;

// The headline scheduler gets a short path: `sched::psbs::Psbs`.
pub mod psbs {
    pub use super::fsp_family::Psbs;
}

use crate::sim::Scheduler;

/// Policy names accepted by [`by_name`] (and the CLI / figure harness).
pub const ALL_POLICIES: &[&str] = &[
    "fifo", "ps", "dps", "las", "mlfq", "srpt", "srpte", "srpte+ps", "srpte+las",
    "fsp", "fspe", "fspe+ps", "fspe+las", "psbs", "psbs-paperlit", "fsp-naive",
    "spt", "sjf",
];

/// Construct a scheduler by CLI name — a thin compatibility shim over
/// [`crate::scenario::PolicySpec::parse`], so every call site that
/// accepted a bare name also accepts composed specs
/// (`cluster(k=4,dispatch=leastwork,inner=psbs)`,
/// `est(model=lognormal,sigma=2,inner=srpte)`, `mlfq(levels=12)`).
///
/// `srpt` and `srpte` share one implementation (SRPT *is* SRPTE with
/// exact estimates); likewise `fsp`/`fspe`.  `fsp-naive` is the classic
/// O(n)-per-arrival FSP used for the §5.2.2 complexity comparison.
/// Base-discipline construction itself lives in
/// [`crate::scenario::BasePolicy::build`].
pub fn by_name(name: &str) -> Option<Box<dyn Scheduler>> {
    Some(crate::scenario::PolicySpec::parse(name).ok()?.build())
}

/// Binary min-heap keyed by `(f64, u64)` — the `(g_i, id)` priority
/// queues of Algorithm 1 and friends.  `std::collections::BinaryHeap`
/// is unusable here because f64 is not `Ord`; this implementation also
/// gives us deterministic tie-breaking by sequence number, which the
/// simulator's reproducibility relies on.
///
/// **Layout.** Keys and payloads live in two parallel vectors: the
/// sift loops touch only the dense `(f64, u64)` key array (16
/// bytes/slot, four per cache line), so payload size no longer dilutes
/// the comparison-heavy hot path.  The split also makes the ordering
/// key *physically* immutable through [`MinHeap::head_mut`] — a caller
/// mutating the payload cannot corrupt heap order, because order lives
/// only in `keys` (tested by `head_mut_cannot_corrupt_order`).
///
/// **Indexing.** [`MinHeap::with_index`] maintains a seq → slot map
/// across sifts, turning [`MinHeap::remove_by_seq`] from an O(n) scan
/// into O(log n) — the §5.2.2 job-cancellation path.  Unindexed heaps
/// pay nothing for it.  [`MinHeap::with_dense_index`] keeps the same
/// contract in a flat `Vec<usize>` keyed directly by seq — for dense
/// small seqs (job ids are the dense indices `0..n`, which the engine
/// asserts), every index maintenance touch is one bounds-checked array
/// write instead of a hash probe, which keeps the per-sift overhead on
/// the arrival/virtual-completion hot path near zero (the `heap/` +
/// `event/` vs `cancel/` samples in `BENCH_psbs_ops.json` record the
/// trade-off).
#[derive(Debug, Clone)]
pub struct MinHeap<T> {
    /// Hot half of the split layout: `(key, seq)`, heap-ordered.
    keys: Vec<(f64, u64)>,
    /// Cold half: `payloads[i]` belongs to `keys[i]`.
    payloads: Vec<T>,
    /// Optional seq → slot index (see [`MinHeap::with_index`] /
    /// [`MinHeap::with_dense_index`]).
    slots: SeqIndex,
}

/// The seq → slot index backing (a pure accelerator: it must never
/// change observable heap behavior, only the cost of `remove_by_seq`).
#[derive(Debug, Clone)]
enum SeqIndex {
    /// No index: `remove_by_seq` scans.
    None,
    /// HashMap index: arbitrary (sparse, large) seqs.
    Map(std::collections::HashMap<u64, usize>),
    /// Dense vector index: `dense[seq] = slot`, [`ABSENT`] when the seq
    /// is not live.  Memory is proportional to the largest seq ever
    /// pushed, so this fits seqs that are dense small integers — job
    /// ids in this codebase.
    Dense(Vec<usize>),
}

/// Sentinel slot for "seq not present" in the dense index.
const ABSENT: usize = usize::MAX;

impl SeqIndex {
    /// Record `seq -> slot` for a fresh push; returns false if the seq
    /// was already live (callers debug_assert on that).
    #[inline]
    fn insert_new(&mut self, seq: u64, slot: usize) -> bool {
        match self {
            SeqIndex::None => true,
            SeqIndex::Map(m) => m.insert(seq, slot).is_none(),
            SeqIndex::Dense(v) => {
                let i = seq as usize;
                if i >= v.len() {
                    v.resize(i + 1, ABSENT);
                }
                let fresh = v[i] == ABSENT;
                v[i] = slot;
                fresh
            }
        }
    }

    /// Update the slot of a live seq (sift bookkeeping).
    #[inline]
    fn set(&mut self, seq: u64, slot: usize) {
        match self {
            SeqIndex::None => {}
            SeqIndex::Map(m) => {
                m.insert(seq, slot);
            }
            SeqIndex::Dense(v) => v[seq as usize] = slot,
        }
    }

    /// Drop a seq that left the heap.
    #[inline]
    fn remove(&mut self, seq: u64) {
        match self {
            SeqIndex::None => {}
            SeqIndex::Map(m) => {
                m.remove(&seq);
            }
            SeqIndex::Dense(v) => {
                v[seq as usize] = ABSENT;
                // Reclaim the tail so long-running deployments with
                // monotonically growing seqs (the online service) keep
                // the index proportional to the live seq span, not to
                // every seq ever pushed.  Amortized O(1): each popped
                // slot was resized in exactly once.
                while v.last() == Some(&ABSENT) {
                    v.pop();
                }
            }
        }
    }

    /// Current slot of a live seq (None on unindexed heaps too — the
    /// caller falls back to a scan there).
    #[inline]
    fn lookup(&self, seq: u64) -> Option<Option<usize>> {
        match self {
            SeqIndex::None => None,
            SeqIndex::Map(m) => Some(m.get(&seq).copied()),
            SeqIndex::Dense(v) => {
                Some(v.get(seq as usize).copied().filter(|&s| s != ABSENT))
            }
        }
    }

    fn clear(&mut self) {
        match self {
            SeqIndex::None => {}
            SeqIndex::Map(m) => m.clear(),
            SeqIndex::Dense(v) => v.clear(),
        }
    }
}

impl<T> Default for MinHeap<T> {
    fn default() -> Self {
        MinHeap { keys: Vec::new(), payloads: Vec::new(), slots: SeqIndex::None }
    }
}

impl<T> MinHeap<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// A heap that additionally maintains a seq → slot index, making
    /// [`MinHeap::remove_by_seq`] O(log n).  Live entries must have
    /// unique `seq`s (job ids do).
    pub fn with_index() -> Self {
        MinHeap {
            keys: Vec::new(),
            payloads: Vec::new(),
            slots: SeqIndex::Map(std::collections::HashMap::new()),
        }
    }

    /// Like [`MinHeap::with_index`], backed by a dense `Vec<usize>`
    /// keyed directly by seq: O(1) array writes per sift swap instead
    /// of hash probes.  Requires seqs to be dense small integers (the
    /// index holds `max_seq + 1` slots) — exactly the job-id contract
    /// the engine already asserts.
    pub fn with_dense_index() -> Self {
        MinHeap { keys: Vec::new(), payloads: Vec::new(), slots: SeqIndex::Dense(Vec::new()) }
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// O(log n) push; `seq` breaks key ties deterministically.
    pub fn push(&mut self, key: f64, seq: u64, value: T) {
        let i = self.keys.len();
        self.keys.push((key, seq));
        self.payloads.push(value);
        let fresh = self.slots.insert_new(seq, i);
        debug_assert!(fresh, "duplicate seq {seq} in indexed MinHeap");
        self.sift_up(i);
    }

    /// Minimum element: `(key, seq, &value)`.
    pub fn peek(&self) -> Option<(f64, u64, &T)> {
        self.keys.first().map(|&(k, s)| (k, s, &self.payloads[0]))
    }

    /// Mutable access to the minimum element's payload (used by the FSP
    /// family to update the served job's remaining work in O(1)).  The
    /// ordering key is stored separately and cannot be reached — let
    /// alone corrupted — through this reference.
    pub fn head_mut(&mut self) -> Option<&mut T> {
        self.payloads.first_mut()
    }

    /// O(log n) pop of the minimum.
    pub fn pop(&mut self) -> Option<(f64, u64, T)> {
        if self.keys.is_empty() {
            return None;
        }
        let last = self.keys.len() - 1;
        self.swap_slots(0, last);
        let (k, s) = self.keys.pop().unwrap();
        let v = self.payloads.pop().unwrap();
        self.slots.remove(s);
        if !self.keys.is_empty() {
            self.sift_down(0);
        }
        Some((k, s, v))
    }

    pub fn clear(&mut self) {
        self.keys.clear();
        self.payloads.clear();
        self.slots.clear();
    }

    /// Removal by sequence number (the job-cancellation path): O(log n)
    /// on indexed heaps ([`MinHeap::with_index`] /
    /// [`MinHeap::with_dense_index`]), an O(n) scan plus O(log n)
    /// fix-up otherwise.
    pub fn remove_by_seq(&mut self, seq: u64) -> Option<(f64, u64, T)> {
        let i = match self.slots.lookup(seq) {
            Some(slot) => slot?,
            None => self.keys.iter().position(|&(_, s)| s == seq)?,
        };
        let last = self.keys.len() - 1;
        self.swap_slots(i, last);
        let (k, s) = self.keys.pop().unwrap();
        let v = self.payloads.pop().unwrap();
        debug_assert_eq!(s, seq, "seq index out of sync");
        self.slots.remove(s);
        if i < self.keys.len() {
            // The swapped-in element may violate order in either
            // direction relative to its new position.
            self.sift_down(i);
            self.sift_up(i);
        }
        Some((k, s, v))
    }

    pub fn iter(&self) -> impl Iterator<Item = (f64, u64, &T)> {
        self.keys.iter().zip(&self.payloads).map(|(&(k, s), v)| (k, s, v))
    }

    /// Swap two slots in both halves, keeping the seq index in sync.
    #[inline]
    fn swap_slots(&mut self, a: usize, b: usize) {
        self.keys.swap(a, b);
        self.payloads.swap(a, b);
        if !matches!(self.slots, SeqIndex::None) {
            self.slots.set(self.keys[a].1, a);
            self.slots.set(self.keys[b].1, b);
        }
    }

    #[inline]
    fn less(&self, a: usize, b: usize) -> bool {
        let (ka, sa) = &self.keys[a];
        let (kb, sb) = &self.keys[b];
        match ka.partial_cmp(kb).expect("NaN key in MinHeap") {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => sa < sb,
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.less(i, parent) {
                self.swap_slots(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if l < self.keys.len() && self.less(l, smallest) {
                smallest = l;
            }
            if r < self.keys.len() && self.less(r, smallest) {
                smallest = r;
            }
            if smallest == i {
                return;
            }
            self.swap_slots(i, smallest);
            i = smallest;
        }
    }

    /// Invariant check (test/debug support): heap order, split halves
    /// in lockstep, and — when indexed — every live seq mapping to its
    /// actual slot.
    pub fn check_invariant(&self) -> bool {
        let ordered = (1..self.keys.len()).all(|i| !self.less(i, (i - 1) / 2));
        let aligned = self.keys.len() == self.payloads.len();
        let indexed = match &self.slots {
            SeqIndex::None => true,
            SeqIndex::Map(m) => {
                m.len() == self.keys.len()
                    && self.keys.iter().enumerate().all(|(i, &(_, s))| m.get(&s) == Some(&i))
            }
            SeqIndex::Dense(v) => {
                v.iter().filter(|&&s| s != ABSENT).count() == self.keys.len()
                    && self
                        .keys
                        .iter()
                        .enumerate()
                        .all(|(i, &(_, s))| v.get(s as usize) == Some(&i))
            }
        };
        ordered && aligned && indexed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minheap_sorts() {
        let mut h = MinHeap::new();
        for (i, k) in [5.0, 1.0, 3.0, 2.0, 4.0].into_iter().enumerate() {
            h.push(k, i as u64, ());
        }
        let mut out = Vec::new();
        while let Some((k, _, _)) = h.pop() {
            out.push(k);
        }
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn minheap_tie_breaks_by_seq() {
        let mut h = MinHeap::new();
        h.push(1.0, 7, "b");
        h.push(1.0, 3, "a");
        assert_eq!(h.pop().unwrap().2, "a");
        assert_eq!(h.pop().unwrap().2, "b");
    }

    #[test]
    fn minheap_invariant_random() {
        let mut rng = crate::util::rng::Rng::new(9);
        let mut h = MinHeap::new();
        for i in 0..1000u64 {
            h.push(rng.u01(), i, i);
            assert!(h.check_invariant());
            if rng.u01() < 0.3 {
                h.pop();
            }
        }
        let mut last = f64::NEG_INFINITY;
        while let Some((k, _, _)) = h.pop() {
            assert!(k >= last);
            last = k;
        }
    }

    #[test]
    fn by_name_covers_all_policies() {
        for name in ALL_POLICIES {
            assert!(by_name(name).is_some(), "missing policy {name}");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn remove_by_seq_preserves_invariant_and_order() {
        crate::util::check::property(
            "minheap remove_by_seq",
            crate::util::check::Config { cases: 48, max_size: 80, ..Default::default() },
            |rng, size| {
                let keys: Vec<f64> = (0..2 + size).map(|_| rng.u01()).collect();
                let removals: Vec<u64> =
                    (0..size / 2).map(|_| rng.below(keys.len() as u64 + 4)).collect();
                (keys, removals)
            },
            |(keys, removals)| {
                // All three index modes must behave identically.
                for indexed in [0usize, 1, 2] {
                    let mut h = match indexed {
                        0 => MinHeap::new(),
                        1 => MinHeap::with_index(),
                        _ => MinHeap::with_dense_index(),
                    };
                    for (i, &k) in keys.iter().enumerate() {
                        h.push(k, i as u64, i);
                    }
                    let mut gone = std::collections::HashSet::new();
                    for &seq in removals {
                        let removed = h.remove_by_seq(seq);
                        let expect = (seq as usize) < keys.len() && !gone.contains(&seq);
                        if removed.is_some() != expect {
                            return Err(format!("indexed={indexed} remove {seq}: got {removed:?}"));
                        }
                        if removed.is_some() {
                            gone.insert(seq);
                        }
                        if !h.check_invariant() {
                            return Err(format!(
                                "indexed={indexed}: heap invariant broken after removing {seq}"
                            ));
                        }
                    }
                    // Remaining elements pop in sorted order.
                    let mut last = f64::NEG_INFINITY;
                    let mut popped = 0;
                    while let Some((k, s, _)) = h.pop() {
                        if k < last {
                            return Err(format!("out of order: {k} after {last}"));
                        }
                        if gone.contains(&s) {
                            return Err(format!("removed element {s} resurfaced"));
                        }
                        last = k;
                        popped += 1;
                    }
                    if popped + gone.len() != keys.len() {
                        return Err("element count mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }

    /// Indexed (map and dense) and unindexed heaps agree
    /// operation-for-operation under a random push/pop/remove
    /// interleaving (the index is a pure accelerator — it must never
    /// change observable behavior).
    #[test]
    fn indexed_heap_matches_unindexed() {
        let mut rng = crate::util::rng::Rng::new(41);
        let mut plain: MinHeap<u64> = MinHeap::new();
        let mut fast: MinHeap<u64> = MinHeap::with_index();
        let mut dense: MinHeap<u64> = MinHeap::with_dense_index();
        let mut seq = 0u64;
        for _ in 0..2000 {
            match rng.below(4) {
                0 | 1 => {
                    let k = rng.u01();
                    plain.push(k, seq, seq);
                    fast.push(k, seq, seq);
                    dense.push(k, seq, seq);
                    seq += 1;
                }
                2 => {
                    let want = plain.pop();
                    assert_eq!(want, fast.pop());
                    assert_eq!(want, dense.pop());
                }
                _ => {
                    let target = rng.below(seq.max(1));
                    let want = plain.remove_by_seq(target);
                    assert_eq!(want, fast.remove_by_seq(target));
                    assert_eq!(want, dense.remove_by_seq(target));
                }
            }
            assert!(plain.check_invariant() && fast.check_invariant() && dense.check_invariant());
        }
        while let Some(x) = plain.pop() {
            assert_eq!(Some(x), fast.pop());
            assert_eq!(Some(x), dense.pop());
        }
        assert!(fast.is_empty() && dense.is_empty());
    }

    /// The dense index copes with seqs pushed out of order, re-pushed
    /// after removal, and queried past the end of the backing vector.
    #[test]
    fn dense_index_reuse_and_out_of_range() {
        let mut h: MinHeap<&str> = MinHeap::with_dense_index();
        h.push(2.0, 5, "five");
        h.push(1.0, 0, "zero");
        assert!(h.check_invariant());
        assert_eq!(h.remove_by_seq(99), None, "past-the-end seq is absent, not a panic");
        assert_eq!(h.remove_by_seq(5).unwrap().2, "five");
        h.push(0.5, 5, "five again");
        assert!(h.check_invariant());
        assert_eq!(h.pop().unwrap().2, "five again");
        assert_eq!(h.pop().unwrap().2, "zero");
        h.clear();
        h.push(1.0, 3, "post-clear");
        assert!(h.check_invariant());
        assert_eq!(h.remove_by_seq(3).unwrap().2, "post-clear");
    }

    /// The split layout stores ordering keys apart from payloads, so a
    /// caller mutating the head payload — the historical `head_mut`
    /// footgun — cannot corrupt heap order.
    #[test]
    fn head_mut_cannot_corrupt_order() {
        let mut h = MinHeap::new();
        h.push(1.0, 1, 1.0f64);
        h.push(2.0, 2, 2.0);
        h.push(3.0, 3, 3.0);
        *h.head_mut().unwrap() = 999.0; // pathological payload mutation
        assert!(h.check_invariant(), "payload mutation must not affect order");
        let order: Vec<u64> = std::iter::from_fn(|| h.pop().map(|(_, s, _)| s)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    /// Stress: every policy survives a batch of simultaneous arrivals
    /// (an engine edge case — all jobs delivered at one instant) mixed
    /// with near-zero sizes, and completes everything.
    #[test]
    fn mass_simultaneous_arrivals_stress() {
        use crate::sim::{run, Job};
        let mut rng = crate::util::rng::Rng::new(99);
        let jobs: Vec<Job> = (0..300)
            .map(|i| {
                let size = if i % 7 == 0 { 1e-9 } else { rng.u01() + 1e-6 };
                Job {
                    id: i,
                    arrival: if i < 150 { 0.0 } else { 1.0 },
                    size,
                    est: (size * (0.1 + rng.u01() * 5.0)).max(1e-12),
                    weight: 1.0 / (1.0 + (i % 4) as f64),
                }
            })
            .collect();
        for policy in ALL_POLICIES {
            let mut s = by_name(policy).unwrap();
            let r = run(s.as_mut(), &jobs);
            assert!(
                r.completion.iter().all(|c| c.is_finite()),
                "{policy} left jobs incomplete"
            );
            assert_eq!(s.active(), 0, "{policy} leaked active jobs");
        }
    }
}
