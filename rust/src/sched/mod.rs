//! The scheduler zoo: every discipline evaluated in the paper.
//!
//! | module | disciplines | paper § |
//! |--------|-------------|---------|
//! | [`fifo`] | FIFO | §6.1 |
//! | [`ps`] | PS, DPS (virtual-lag implementation) | §6.1 |
//! | [`las`] | LAS (attained-service levels) | §2.1, §6.1 |
//! | [`srpt`] | SRPT / SRPTE (late jobs block) | §4 |
//! | [`srpte_hybrid`] | SRPTE+PS, SRPTE+LAS | §5.1 |
//! | [`fsp_family`] | FSPE, FSPE+PS, FSPE+LAS, **PSBS** (Algorithm 1) | §4.2, §5 |
//! | [`fsp_naive`] | FSP/FSPE with the classic O(n) virtual update | §3, §5.2.2 |
//! | [`pri`] | Pri_S — the §3 dominance construction | §3 |
//!
//! All implement [`crate::sim::Scheduler`] and are cross-validated
//! against the independent small-step oracle in `rust/tests/crossval.rs`.

pub mod fifo;
pub mod fsp_family;
pub mod fsp_naive;
pub mod las;
pub mod mlfq;
pub mod pri;
pub mod ps;
pub mod srpt;
pub mod srpte_hybrid;

// The headline scheduler gets a short path: `sched::psbs::Psbs`.
pub mod psbs {
    pub use super::fsp_family::Psbs;
}

use crate::sim::Scheduler;

/// Policy names accepted by [`by_name`] (and the CLI / figure harness).
pub const ALL_POLICIES: &[&str] = &[
    "fifo", "ps", "dps", "las", "mlfq", "srpt", "srpte", "srpte+ps", "srpte+las",
    "fsp", "fspe", "fspe+ps", "fspe+las", "psbs", "psbs-paperlit", "fsp-naive",
];

/// Construct a scheduler by CLI name.
///
/// `srpt` and `srpte` share one implementation (SRPT *is* SRPTE with
/// exact estimates); likewise `fsp`/`fspe`.  `fsp-naive` is the classic
/// O(n)-per-arrival FSP used for the §5.2.2 complexity comparison.
pub fn by_name(name: &str) -> Option<Box<dyn Scheduler>> {
    Some(match name {
        "fifo" => Box::new(fifo::Fifo::new()),
        "ps" => Box::new(ps::Dps::ps()),
        "dps" => Box::new(ps::Dps::new()),
        "las" => Box::new(las::Las::new()),
        "mlfq" => Box::new(mlfq::Mlfq::default_zoo()),
        "srpt" | "srpte" => Box::new(srpt::Srpte::new()),
        "srpte+ps" => Box::new(srpte_hybrid::SrpteHybrid::ps()),
        "srpte+las" => Box::new(srpte_hybrid::SrpteHybrid::las()),
        "fsp" | "fspe" => Box::new(fsp_family::FspFamily::fspe()),
        "fspe+ps" => Box::new(fsp_family::FspFamily::fspe_ps()),
        "fspe+las" => Box::new(fsp_family::FspFamily::fspe_las()),
        "psbs" => Box::new(fsp_family::Psbs::new()),
        "psbs-paperlit" => Box::new(fsp_family::FspFamily::psbs_paper_literal()),
        "fsp-naive" => Box::new(fsp_naive::FspNaive::new()),
        _ => return None,
    })
}

/// Binary min-heap keyed by `(f64, u64)` — the `(g_i, id)` priority
/// queues of Algorithm 1 and friends.  `std::collections::BinaryHeap`
/// is unusable here because f64 is not `Ord`; this implementation also
/// gives us deterministic tie-breaking by sequence number, which the
/// simulator's reproducibility relies on.
#[derive(Debug, Clone)]
pub struct MinHeap<T> {
    items: Vec<(f64, u64, T)>,
}

impl<T> Default for MinHeap<T> {
    fn default() -> Self {
        MinHeap { items: Vec::new() }
    }
}

impl<T> MinHeap<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// O(log n) push; `seq` breaks key ties deterministically.
    pub fn push(&mut self, key: f64, seq: u64, value: T) {
        self.items.push((key, seq, value));
        self.sift_up(self.items.len() - 1);
    }

    /// Minimum element: `(key, seq, &value)`.
    pub fn peek(&self) -> Option<(f64, u64, &T)> {
        self.items.first().map(|(k, s, v)| (*k, *s, v))
    }

    /// Mutable access to the minimum element's payload.  The caller
    /// must not change anything the *key* depends on (used by the FSP
    /// family to update the served job's remaining work in O(1)).
    pub fn head_mut(&mut self) -> Option<&mut T> {
        self.items.first_mut().map(|(_, _, v)| v)
    }

    /// O(log n) pop of the minimum.
    pub fn pop(&mut self) -> Option<(f64, u64, T)> {
        if self.items.is_empty() {
            return None;
        }
        let last = self.items.len() - 1;
        self.items.swap(0, last);
        let out = self.items.pop();
        if !self.items.is_empty() {
            self.sift_down(0);
        }
        out
    }

    pub fn clear(&mut self) {
        self.items.clear();
    }

    /// O(n) removal by sequence number (used by job cancellation — rare
    /// by assumption, so the linear scan is acceptable; the swap-remove
    /// plus one sift restores the heap in O(log n) after the scan).
    pub fn remove_by_seq(&mut self, seq: u64) -> Option<(f64, u64, T)> {
        let i = self.items.iter().position(|(_, s, _)| *s == seq)?;
        let item = self.items.swap_remove(i);
        if i < self.items.len() {
            // The swapped-in element may violate order in either
            // direction relative to its new position.
            self.sift_down(i);
            self.sift_up(i);
        }
        Some(item)
    }

    pub fn iter(&self) -> impl Iterator<Item = (f64, u64, &T)> {
        self.items.iter().map(|(k, s, v)| (*k, *s, v))
    }

    #[inline]
    fn less(&self, a: usize, b: usize) -> bool {
        let (ka, sa, _) = &self.items[a];
        let (kb, sb, _) = &self.items[b];
        match ka.partial_cmp(kb).expect("NaN key in MinHeap") {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => sa < sb,
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.less(i, parent) {
                self.items.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if l < self.items.len() && self.less(l, smallest) {
                smallest = l;
            }
            if r < self.items.len() && self.less(r, smallest) {
                smallest = r;
            }
            if smallest == i {
                return;
            }
            self.items.swap(i, smallest);
            i = smallest;
        }
    }

    /// Heap-order invariant check (test/debug support).
    pub fn check_invariant(&self) -> bool {
        (1..self.items.len()).all(|i| !self.less(i, (i - 1) / 2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minheap_sorts() {
        let mut h = MinHeap::new();
        for (i, k) in [5.0, 1.0, 3.0, 2.0, 4.0].into_iter().enumerate() {
            h.push(k, i as u64, ());
        }
        let mut out = Vec::new();
        while let Some((k, _, _)) = h.pop() {
            out.push(k);
        }
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn minheap_tie_breaks_by_seq() {
        let mut h = MinHeap::new();
        h.push(1.0, 7, "b");
        h.push(1.0, 3, "a");
        assert_eq!(h.pop().unwrap().2, "a");
        assert_eq!(h.pop().unwrap().2, "b");
    }

    #[test]
    fn minheap_invariant_random() {
        let mut rng = crate::util::rng::Rng::new(9);
        let mut h = MinHeap::new();
        for i in 0..1000u64 {
            h.push(rng.u01(), i, i);
            assert!(h.check_invariant());
            if rng.u01() < 0.3 {
                h.pop();
            }
        }
        let mut last = f64::NEG_INFINITY;
        while let Some((k, _, _)) = h.pop() {
            assert!(k >= last);
            last = k;
        }
    }

    #[test]
    fn by_name_covers_all_policies() {
        for name in ALL_POLICIES {
            assert!(by_name(name).is_some(), "missing policy {name}");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn remove_by_seq_preserves_invariant_and_order() {
        crate::util::check::property(
            "minheap remove_by_seq",
            crate::util::check::Config { cases: 48, max_size: 80, ..Default::default() },
            |rng, size| {
                let keys: Vec<f64> = (0..2 + size).map(|_| rng.u01()).collect();
                let removals: Vec<u64> =
                    (0..size / 2).map(|_| rng.below(keys.len() as u64 + 4)).collect();
                (keys, removals)
            },
            |(keys, removals)| {
                let mut h = MinHeap::new();
                for (i, &k) in keys.iter().enumerate() {
                    h.push(k, i as u64, i);
                }
                let mut gone = std::collections::HashSet::new();
                for &seq in removals {
                    let removed = h.remove_by_seq(seq);
                    let expect = (seq as usize) < keys.len() && !gone.contains(&seq);
                    if removed.is_some() != expect {
                        return Err(format!("remove {seq}: got {removed:?}"));
                    }
                    if removed.is_some() {
                        gone.insert(seq);
                    }
                    if !h.check_invariant() {
                        return Err(format!("heap invariant broken after removing {seq}"));
                    }
                }
                // Remaining elements pop in sorted order.
                let mut last = f64::NEG_INFINITY;
                let mut popped = 0;
                while let Some((k, s, _)) = h.pop() {
                    if k < last {
                        return Err(format!("out of order: {k} after {last}"));
                    }
                    if gone.contains(&s) {
                        return Err(format!("removed element {s} resurfaced"));
                    }
                    last = k;
                    popped += 1;
                }
                if popped + gone.len() != keys.len() {
                    return Err("element count mismatch".into());
                }
                Ok(())
            },
        );
    }

    /// Stress: every policy survives a batch of simultaneous arrivals
    /// (an engine edge case — all jobs delivered at one instant) mixed
    /// with near-zero sizes, and completes everything.
    #[test]
    fn mass_simultaneous_arrivals_stress() {
        use crate::sim::{run, Job};
        let mut rng = crate::util::rng::Rng::new(99);
        let jobs: Vec<Job> = (0..300)
            .map(|i| {
                let size = if i % 7 == 0 { 1e-9 } else { rng.u01() + 1e-6 };
                Job {
                    id: i,
                    arrival: if i < 150 { 0.0 } else { 1.0 },
                    size,
                    est: (size * (0.1 + rng.u01() * 5.0)).max(1e-12),
                    weight: 1.0 / (1.0 + (i % 4) as f64),
                }
            })
            .collect();
        for policy in ALL_POLICIES {
            let mut s = by_name(policy).unwrap();
            let r = run(s.as_mut(), &jobs);
            assert!(
                r.completion.iter().all(|c| c.is_finite()),
                "{policy} left jobs incomplete"
            );
            assert_eq!(s.active(), 0, "{policy} leaked active jobs");
        }
    }
}
