//! Classic O(n) FSP — Friedman & Henderson's formulation, kept as
//! (a) an independent oracle for the O(log n) implementation in
//! [`super::fsp_family`] and (b) the baseline of the §5.2.2 complexity
//! claim (`psbs_ops` bench: per-event cost O(n) vs O(log n)).
//!
//! The virtual PS system is emulated *literally*: every pending job's
//! virtual remaining size is updated on every event (the O(n) step the
//! virtual-lag trick removes).  Real side is identical to plain FSPE:
//! serve the earliest virtual completer; late jobs run serially.

use crate::sim::{Completion, JobId, JobStore, Scheduler};
use crate::util::EPS;

#[derive(Debug, Clone, Copy)]
struct NJob {
    id: u32,
    /// Remaining size in the virtual PS system (estimated units).
    virt_rem: f64,
    true_rem: f64,
    /// usize::MAX until the job completes virtually; then its rank.
    virt_order: usize,
}

/// Naive-update FSP/FSPE.
#[derive(Debug, Default)]
pub struct FspNaive {
    /// All jobs still active in either system (O(n) scans by design).
    jobs: Vec<NJob>,
    virt_seq: usize,
}

impl FspNaive {
    pub fn new() -> Self {
        Self::default()
    }

    fn virt_pending(&self) -> usize {
        self.jobs.iter().filter(|j| j.virt_order == usize::MAX).count()
    }

    /// Index of the served job: earliest late job, else the pending job
    /// with minimum virtual remaining (they all shrink at the same
    /// rate, so min remaining == earliest virtual completion).
    fn serving(&self) -> Option<usize> {
        let late = self
            .jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| j.virt_order != usize::MAX && j.true_rem > 0.0)
            .min_by_key(|(_, j)| j.virt_order);
        if let Some((i, _)) = late {
            return Some(i);
        }
        self.jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| j.true_rem > 0.0)
            .min_by(|(a, x), (b, y)| {
                x.virt_rem.partial_cmp(&y.virt_rem).unwrap().then(a.cmp(b))
            })
            .map(|(i, _)| i)
    }
}

impl Scheduler for FspNaive {
    fn name(&self) -> &'static str {
        "fsp-naive"
    }

    fn on_arrival(&mut self, _now: f64, id: JobId, store: &JobStore) {
        // O(n) by construction: nothing to update here, but every
        // `advance` touches all virtually-pending jobs.
        self.jobs.push(NJob {
            id,
            virt_rem: store.est(id),
            true_rem: store.size(id),
            virt_order: usize::MAX,
        });
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        let mut dt = f64::INFINITY;
        let n_virt = self.virt_pending();
        if n_virt > 0 {
            // Earliest virtual completion: min virt_rem * n.
            let min_rem = self
                .jobs
                .iter()
                .filter(|j| j.virt_order == usize::MAX)
                .map(|j| j.virt_rem)
                .fold(f64::INFINITY, f64::min);
            dt = dt.min(min_rem * n_virt as f64);
        }
        if let Some(i) = self.serving() {
            dt = dt.min(self.jobs[i].true_rem);
        }
        if dt.is_finite() {
            Some(now + dt.max(0.0))
        } else {
            None
        }
    }

    fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
        let dt = t - now;
        // Real progress.
        if let Some(i) = self.serving() {
            self.jobs[i].true_rem -= dt;
            if self.jobs[i].true_rem <= EPS {
                self.jobs[i].true_rem = 0.0;
                done.push(Completion { id: self.jobs[i].id, time: t });
            }
        }
        // Virtual progress: the O(n) update.
        let n_virt = self.virt_pending();
        if n_virt > 0 {
            let share = dt / n_virt as f64;
            for j in self.jobs.iter_mut() {
                if j.virt_order == usize::MAX {
                    j.virt_rem -= share;
                }
            }
            // Virtual completions in deterministic order.
            loop {
                let next = self
                    .jobs
                    .iter_mut()
                    .filter(|j| j.virt_order == usize::MAX && j.virt_rem <= EPS)
                    .min_by(|x, y| {
                        x.virt_rem.partial_cmp(&y.virt_rem).unwrap().then(x.id.cmp(&y.id))
                    });
                match next {
                    Some(j) => {
                        j.virt_order = self.virt_seq;
                        self.virt_seq += 1;
                    }
                    None => break,
                }
            }
        }
        // Garbage-collect jobs done in both systems.
        self.jobs
            .retain(|j| j.true_rem > 0.0 || j.virt_order == usize::MAX);
    }

    fn active(&self) -> usize {
        self.jobs.iter().filter(|j| j.true_rem > 0.0).count()
    }

    /// Kill a pending job.  Mirrors the O(log n) family's semantics:
    /// the job leaves the real system but keeps its virtual share until
    /// its virtual completion (late jobs simply disappear).
    fn cancel(&mut self, _now: f64, id: u32) -> bool {
        let Some(i) = self.jobs.iter().position(|j| j.id == id && j.true_rem > 0.0) else {
            return false;
        };
        if self.jobs[i].virt_order != usize::MAX {
            self.jobs.remove(i); // late: gone from both systems
        } else {
            self.jobs[i].true_rem = 0.0; // "early": still ages virtually
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run, Job};

    #[test]
    fn fig2_example_matches_fsp() {
        let jobs = vec![
            Job::exact(0, 0.0, 10.0),
            Job::exact(1, 3.0, 5.0),
            Job::exact(2, 5.0, 2.0),
        ];
        let r = run(&mut FspNaive::new(), &jobs);
        assert!((r.completion[2] - 7.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[1] - 10.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[0] - 17.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn matches_ologn_family_without_errors() {
        use crate::workload::dists::{Dist, Weibull};
        let mut rng = crate::util::rng::Rng::new(41);
        let w = Weibull::unit_mean(0.5);
        let mut t = 0.0;
        let jobs: Vec<Job> = (0..200)
            .map(|i| {
                t += rng.u01();
                Job::exact(i, t, w.sample(&mut rng).max(1e-9))
            })
            .collect();
        let naive = run(&mut FspNaive::new(), &jobs).completion;
        let fast = run(&mut super::super::fsp_family::Psbs::new(), &jobs).completion;
        for (i, (a, b)) in naive.iter().zip(&fast).enumerate() {
            assert!((a - b).abs() < 1e-6, "job {i}: naive {a} vs psbs {b}");
        }
    }

    #[test]
    fn matches_fspe_with_errors() {
        use crate::workload::dists::{Dist, LogNormal, Weibull};
        let mut rng = crate::util::rng::Rng::new(43);
        let w = Weibull::unit_mean(0.25);
        let e = LogNormal::error_model(1.5);
        let mut t = 0.0;
        let jobs: Vec<Job> = (0..200)
            .map(|i| {
                t += rng.u01() * 0.3;
                let size = w.sample(&mut rng).max(1e-9);
                Job { id: i, arrival: t, size, est: size * e.sample(&mut rng), weight: 1.0 }
            })
            .collect();
        let naive = run(&mut FspNaive::new(), &jobs).completion;
        let fspe = run(&mut super::super::fsp_family::FspFamily::fspe(), &jobs).completion;
        for (i, (a, b)) in naive.iter().zip(&fspe).enumerate() {
            assert!((a - b).abs() < 1e-6, "job {i}: naive {a} vs fspe {b}");
        }
    }
}
