//! Pri_S — the §3 dominance construction.
//!
//! Given a *completion sequence* S (an ordering of all job ids), Pri_S
//! serves, at every instant, the first pending job in S at full rate.
//! The paper's theorem: Pri_S **dominates** any schedule whose
//! completion sequence is S — no job completes later.  FSP is Pri_S
//! applied to the completion sequence of PS; PSBS (without errors) is
//! Pri_S applied to DPS.  The dominance property tests in
//! `rust/tests/dominance.rs` exercise this scheduler directly.

use super::MinHeap;
use crate::sim::{Completion, JobId, JobStore, Scheduler};
use crate::util::EPS;

/// Serve jobs serially in a fixed priority order.
#[derive(Debug)]
pub struct Pri {
    /// position[id] = rank in S (lower = earlier = higher priority).
    position: Vec<usize>,
    /// Pending jobs keyed by rank; payload = true remaining.
    pending: MinHeap<f64>,
}

impl Pri {
    /// Build from a completion sequence (job ids, earliest first).
    pub fn new(sequence: &[u32]) -> Self {
        let mut position = vec![usize::MAX; sequence.len()];
        for (rank, &id) in sequence.iter().enumerate() {
            assert!(
                position[id as usize] == usize::MAX,
                "duplicate id {id} in completion sequence"
            );
            position[id as usize] = rank;
        }
        assert!(
            position.iter().all(|&p| p != usize::MAX),
            "completion sequence must cover all ids 0..n"
        );
        Pri { position, pending: MinHeap::new() }
    }

    /// Convenience: Pri_S for the completion sequence of a finished
    /// simulation (sort ids by completion time, ties by id).
    pub fn from_completions(completion: &[f64]) -> Self {
        let mut ids: Vec<u32> = (0..completion.len() as u32).collect();
        ids.sort_by(|&a, &b| {
            completion[a as usize]
                .partial_cmp(&completion[b as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        Pri::new(&ids)
    }
}

impl Scheduler for Pri {
    fn name(&self) -> &'static str {
        "pri"
    }

    fn on_arrival(&mut self, _now: f64, id: JobId, store: &JobStore) {
        let rank = self.position[id as usize];
        self.pending.push(rank as f64, id as u64, store.size(id));
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        self.pending.peek().map(|(_, _, rem)| now + rem)
    }

    fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
        let dt = t - now;
        let completed = match self.pending.head_mut() {
            Some(rem) => {
                *rem -= dt;
                *rem <= EPS
            }
            None => false,
        };
        if completed {
            let (_, id, _) = self.pending.pop().unwrap();
            done.push(Completion { id: id as u32, time: t });
        }
    }

    fn active(&self) -> usize {
        self.pending.len()
    }

    /// §5.2.2 kill bookkeeping: drop the job from the rank heap; the
    /// next job in S is served as if the victim had completed.
    fn cancel(&mut self, _now: f64, id: u32) -> bool {
        self.pending.remove_by_seq(id as u64).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run, Job};

    #[test]
    fn serves_in_sequence_order() {
        let jobs = vec![
            Job::exact(0, 0.0, 2.0),
            Job::exact(1, 0.0, 1.0),
            Job::exact(2, 0.0, 1.0),
        ];
        let r = run(&mut Pri::new(&[2, 0, 1]), &jobs);
        assert_eq!(r.completion, vec![3.0, 4.0, 1.0]);
    }

    #[test]
    fn preempts_for_higher_priority_arrival() {
        let jobs = vec![Job::exact(0, 0.0, 3.0), Job::exact(1, 1.0, 1.0)];
        let r = run(&mut Pri::new(&[1, 0]), &jobs);
        // J0 runs [0,1); J1 (higher priority) preempts, runs [1,2);
        // J0 resumes, done at 4.
        assert_eq!(r.completion, vec![4.0, 2.0]);
    }

    #[test]
    fn fsp_is_pri_of_ps_sequence() {
        // The theorem's construction: run PS, take its completion
        // sequence, Pri_S over it must equal FSP's real schedule.
        let jobs = vec![
            Job::exact(0, 0.0, 10.0),
            Job::exact(1, 3.0, 5.0),
            Job::exact(2, 5.0, 2.0),
        ];
        let ps = run(&mut super::super::ps::Dps::ps(), &jobs);
        let pri = run(&mut Pri::from_completions(&ps.completion), &jobs);
        let fsp = run(&mut super::super::fsp_family::Psbs::new(), &jobs);
        for i in 0..jobs.len() {
            assert!(
                (pri.completion[i] - fsp.completion[i]).abs() < 1e-9,
                "job {i}: pri {} vs fsp {}",
                pri.completion[i],
                fsp.completion[i]
            );
        }
    }

    #[test]
    #[should_panic(expected = "duplicate id")]
    fn rejects_duplicate_sequence() {
        Pri::new(&[0, 0]);
    }

    /// Killing the served (highest-rank) job hands the server to the
    /// next job in S.
    #[test]
    fn cancel_served_job_promotes_next_in_sequence() {
        let mut s = Pri::new(&[0, 1, 2]);
        let mut st = crate::sim::JobStore::new();
        let mut done = Vec::new();
        for i in 0..3u32 {
            st.deliver(&mut s, 0.0, &Job::exact(i, 0.0, 2.0));
        }
        s.advance(0.0, 1.0, &st, &mut done); // J0 served, 1 left
        assert!(s.cancel(1.0, 0));
        assert!(s.cancel(1.0, 2), "waiting job killable too");
        assert!(!s.cancel(1.0, 0), "double kill must fail");
        assert_eq!(s.active(), 1);
        let ev = s.next_event(1.0).unwrap();
        assert!((ev - 3.0).abs() < 1e-9, "J1 (full size 2) from t=1: {ev}");
        s.advance(1.0, ev, &st, &mut done);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
    }
}
