//! LAS (Least Attained Service, a.k.a. Foreground-Background / SET) —
//! §2.1, §6.1 of the paper.
//!
//! LAS serves the job(s) that have received the least service so far,
//! PS-sharing among ties.  The implementation keeps jobs grouped into
//! *levels* of equal attained service, sorted ascending; only the front
//! (minimum) level is served, its attained service rising at `1/k` for
//! `k` jobs.  Internal events are (a) a completion inside the front
//! level (its smallest job reaches its size) and (b) a *catch-up*: the
//! front level reaches the next level's attained service and the two
//! merge.  New arrivals have attained 0 and thus form (or join) the
//! front level.  Every operation is O(log n) amortized: each job is
//! pushed into a level heap once per merge, and levels only ever merge
//! forward.

use super::MinHeap;
use crate::sim::{Completion, Job, Scheduler};
use crate::util::EPS;
use std::collections::VecDeque;

#[derive(Debug)]
struct Level {
    /// Attained service of every job in this level.
    attained: f64,
    /// Jobs keyed by *size* (same attained => least size completes first).
    jobs: MinHeap<()>,
}

/// Least-Attained-Service scheduler.
#[derive(Debug, Default)]
pub struct Las {
    /// Levels sorted by ascending `attained`; front is served.
    levels: VecDeque<Level>,
    active: usize,
}

impl Las {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time (from `now`) to the next internal event, if any.
    fn next_dt(&self) -> Option<f64> {
        let front = self.levels.front()?;
        let k = front.jobs.len() as f64;
        // (a) smallest job in the front level completes
        let (min_size, _, _) = front.jobs.peek()?;
        let dt_complete = (min_size - front.attained) * k;
        // (b) front catches up with the next level
        let dt_merge = self
            .levels
            .get(1)
            .map(|l| (l.attained - front.attained) * k);
        Some(match dt_merge {
            Some(m) if m < dt_complete => m,
            _ => dt_complete,
        })
    }
}

impl Scheduler for Las {
    fn name(&self) -> &'static str {
        "las"
    }

    fn on_arrival(&mut self, _now: f64, job: &Job) {
        self.active += 1;
        // Attained service of a new job is 0 — it belongs to the front
        // level iff that level has attained 0 (never served).
        match self.levels.front_mut() {
            Some(front) if front.attained <= EPS => {
                front.jobs.push(job.size, job.id as u64, ());
            }
            _ => {
                let mut jobs = MinHeap::new();
                jobs.push(job.size, job.id as u64, ());
                self.levels.push_front(Level { attained: 0.0, jobs });
            }
        }
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        self.next_dt().map(|dt| now + dt.max(0.0))
    }

    fn advance(&mut self, now: f64, t: f64, done: &mut Vec<Completion>) {
        let Some(front) = self.levels.front_mut() else { return };
        let k = front.jobs.len() as f64;
        if k > 0.0 {
            front.attained += (t - now) / k;
        }
        // (a) completions: every job whose size has been attained.
        while let Some((size, _, _)) = front.jobs.peek() {
            if size - front.attained <= EPS {
                let (_, id, _) = front.jobs.pop().unwrap();
                self.active -= 1;
                done.push(Completion { id: id as u32, time: t });
            } else {
                break;
            }
        }
        if front.jobs.is_empty() {
            self.levels.pop_front();
            return;
        }
        // (b) merge with the next level on catch-up.
        let front_attained = front.attained;
        if let Some(next) = self.levels.get(1) {
            if next.attained - front_attained <= EPS {
                let mut front = self.levels.pop_front().unwrap();
                let next = self.levels.front_mut().unwrap();
                // Move the smaller heap into the larger one.
                if front.jobs.len() > next.jobs.len() {
                    std::mem::swap(&mut front.jobs, &mut next.jobs);
                }
                while let Some((size, id, _)) = front.jobs.pop() {
                    next.jobs.push(size, id, ());
                }
            }
        }
    }

    fn active(&self) -> usize {
        self.active
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::run;

    #[test]
    fn newcomer_preempts_older_job() {
        // J0 (size 2) served [0,1); J1 (size 1) arrives with attained 0
        // and is served alone until parity at attained 1 — but it
        // completes exactly then (t=2). J0 finishes at 3.
        let jobs = vec![Job::exact(0, 0.0, 2.0), Job::exact(1, 1.0, 1.0)];
        let r = run(&mut Las::new(), &jobs);
        assert!((r.completion[1] - 2.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[0] - 3.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn catch_up_then_share() {
        // J0 size 3, J1 size 3 arrives at 1. J1 alone [1,2) until both
        // have attained 1; then they share: each needs 2 more at rate
        // 1/2 -> both complete at 2 + 4 = 6.
        let jobs = vec![Job::exact(0, 0.0, 3.0), Job::exact(1, 1.0, 3.0)];
        let r = run(&mut Las::new(), &jobs);
        assert!((r.completion[0] - 6.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[1] - 6.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn equal_jobs_behave_like_ps() {
        let jobs: Vec<Job> = (0..5).map(|i| Job::exact(i, 0.0, 1.0)).collect();
        let r = run(&mut Las::new(), &jobs);
        for c in &r.completion {
            assert!((c - 5.0).abs() < 1e-9);
        }
    }

    #[test]
    fn small_jobs_fly_past_large_one() {
        // The heavy-tail motivation (§2.1): a size-10 job in progress
        // does not delay a stream of size-0.1 jobs at all.
        let mut jobs = vec![Job::exact(0, 0.0, 10.0)];
        for i in 1..=5 {
            jobs.push(Job::exact(i, i as f64, 0.1));
        }
        let r = run(&mut Las::new(), &jobs);
        for i in 1..=5usize {
            let sojourn = r.completion[i] - jobs[i].arrival;
            assert!((sojourn - 0.1).abs() < 1e-9, "job {i}: {sojourn}");
        }
    }

    #[test]
    fn size_oblivious_ignores_estimates() {
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 1.0, est: 100.0, weight: 1.0 },
            Job { id: 1, arrival: 0.0, size: 1.0, est: 0.001, weight: 1.0 },
        ];
        let r = run(&mut Las::new(), &jobs);
        assert!((r.completion[0] - 2.0).abs() < 1e-9);
        assert!((r.completion[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn three_levels_merge_in_order() {
        // Construct distinct attained levels then verify completions
        // come out in a work-conserving order.
        let jobs = vec![
            Job::exact(0, 0.0, 5.0),
            Job::exact(1, 1.0, 4.0),
            Job::exact(2, 2.0, 3.0),
        ];
        let r = run(&mut Las::new(), &jobs);
        // Hand-computed: levels equalize at attained 1 by t=3; then the
        // smallest job (J2) completes at t=9, J1 at 11, J0 at 12.
        assert!((r.completion[2] - 9.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[1] - 11.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[0] - 12.0).abs() < 1e-9, "{:?}", r.completion);
    }
}
