//! LAS (Least Attained Service, a.k.a. Foreground-Background / SET) —
//! §2.1, §6.1 of the paper.
//!
//! LAS serves the job(s) that have received the least service so far,
//! PS-sharing among ties.  The implementation keeps jobs grouped into
//! *levels* of equal attained service, sorted ascending; only the front
//! (minimum) level is served, its attained service rising at `1/k` for
//! `k` jobs.  Internal events are (a) a completion inside the front
//! level (its smallest job reaches its size) and (b) a *catch-up*: the
//! front level reaches the next level's attained service and the two
//! merge — **looped**, so several levels within `EPS` of each other
//! (a cascading catch-up, or an overshooting external driver) collapse
//! in one `advance` instead of leaking zero-length events.  New
//! arrivals have attained 0 and thus form (or join) the front level.
//! Every operation is O(log n) amortized: each job is pushed into a
//! level heap once per merge, and levels only ever merge forward.
//!
//! Cancellation (§5.2.2 kills) is supported through an id → level map
//! (levels carry stable tags; deque positions shift): find the level,
//! drop the job from its heap, reclaim empty levels.
//!
//! Relation to [`super::late_set`]: the late-set engine's Las mode is
//! the *generalized* form of this structure (members admitted at
//! arbitrary attained service, exact finish-key rebasing on merge,
//! map-indexed level heaps for O(log) kills).  Plain LAS deliberately
//! keeps this leaner specialization — arrivals only ever join at
//! attained 0, so absolute job *sizes* are valid heap keys with no
//! rebasing, and the unindexed level heaps keep hash maintenance off
//! the arrival/completion hot path (LAS is a reference discipline in
//! every sweep).  The catch-up merge loop below intentionally mirrors
//! `late_set`'s `merge_caught_levels`; fixes to one should be
//! considered for the other.

use super::MinHeap;
use crate::sim::{Completion, JobId, JobStore, Scheduler};
use crate::util::EPS;
use std::collections::{HashMap, VecDeque};

#[derive(Debug)]
struct Level {
    /// Stable identity for the id → level map.
    tag: u32,
    /// Attained service of every job in this level.
    attained: f64,
    /// Jobs keyed by *size* (same attained => least size completes first).
    jobs: MinHeap<()>,
}

/// Least-Attained-Service scheduler.
#[derive(Debug, Default)]
pub struct Las {
    /// Levels sorted by ascending `attained`; front is served.
    levels: VecDeque<Level>,
    /// id → level tag (the kill path; see [`Las::cancel`]).
    where_is: HashMap<u32, u32>,
    next_tag: u32,
    active: usize,
}

impl Las {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time (from `now`) to the next internal event, if any.
    fn next_dt(&self) -> Option<f64> {
        let front = self.levels.front()?;
        let k = front.jobs.len() as f64;
        // (a) smallest job in the front level completes
        let (min_size, _, _) = front.jobs.peek()?;
        let dt_complete = (min_size - front.attained) * k;
        // (b) front catches up with the next level
        let dt_merge = self
            .levels
            .get(1)
            .map(|l| (l.attained - front.attained) * k);
        Some(match dt_merge {
            Some(m) if m < dt_complete => m,
            _ => dt_complete,
        })
    }
}

impl Scheduler for Las {
    fn name(&self) -> &'static str {
        "las"
    }

    fn on_arrival(&mut self, _now: f64, id: JobId, store: &JobStore) {
        let size = store.size(id);
        self.active += 1;
        // Attained service of a new job is 0 — it belongs to the front
        // level iff that level has attained 0 (never served).
        match self.levels.front_mut() {
            Some(front) if front.attained <= EPS => {
                front.jobs.push(size, id as u64, ());
                self.where_is.insert(id, front.tag);
            }
            _ => {
                let tag = self.next_tag;
                self.next_tag = self.next_tag.wrapping_add(1);
                let mut jobs = MinHeap::new();
                jobs.push(size, id as u64, ());
                self.levels.push_front(Level { tag, attained: 0.0, jobs });
                self.where_is.insert(id, tag);
            }
        }
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        self.next_dt().map(|dt| now + dt.max(0.0))
    }

    fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
        let Some(front) = self.levels.front_mut() else { return };
        let k = front.jobs.len() as f64;
        if k > 0.0 {
            front.attained += (t - now) / k;
        }
        // (a) completions: every job whose size has been attained.
        while let Some((size, _, _)) = front.jobs.peek() {
            if size - front.attained <= EPS {
                let (_, id, _) = front.jobs.pop().unwrap();
                self.where_is.remove(&(id as u32));
                self.active -= 1;
                done.push(Completion { id: id as u32, time: t });
            } else {
                break;
            }
        }
        if front.jobs.is_empty() {
            self.levels.pop_front();
            return;
        }
        // (b) merge on catch-up — looped.  `reach` tracks how far the
        // served group has actually advanced: the surviving level keeps
        // the (possibly lower) attained of the merge target, so an
        // overshot front must keep comparing successors against its own
        // high-water mark or a cascading catch-up stalls after one
        // merge (the bug this loop replaces).
        let mut reach = self.levels.front().unwrap().attained;
        while self.levels.len() >= 2 && self.levels[1].attained - reach <= EPS {
            let mut front = self.levels.pop_front().unwrap();
            let next = self.levels.front_mut().unwrap();
            // Move the smaller heap into the larger one; the level tag
            // follows its heap so untouched members stay mapped.
            if front.jobs.len() > next.jobs.len() {
                std::mem::swap(&mut front.jobs, &mut next.jobs);
                std::mem::swap(&mut front.tag, &mut next.tag);
            }
            reach = reach.max(next.attained);
            while let Some((size, id, _)) = front.jobs.pop() {
                next.jobs.push(size, id, ());
                self.where_is.insert(id as u32, next.tag);
            }
        }
    }

    fn active(&self) -> usize {
        self.active
    }

    /// §5.2.2 kill bookkeeping: the id → level map locates the job's
    /// level (positions shift, tags don't), the level heap drops it,
    /// and an emptied level is reclaimed so it cannot stall the
    /// front-level rotation.
    fn cancel(&mut self, _now: f64, id: u32) -> bool {
        let Some(tag) = self.where_is.remove(&id) else {
            return false;
        };
        let pos = self
            .levels
            .iter()
            .position(|l| l.tag == tag)
            .expect("LAS level map out of sync");
        let removed = self.levels[pos].jobs.remove_by_seq(id as u64);
        debug_assert!(removed.is_some(), "LAS id map out of sync");
        if removed.is_none() {
            return false;
        }
        self.active -= 1;
        if self.levels[pos].jobs.is_empty() {
            self.levels.remove(pos);
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run, Job};

    #[test]
    fn newcomer_preempts_older_job() {
        // J0 (size 2) served [0,1); J1 (size 1) arrives with attained 0
        // and is served alone until parity at attained 1 — but it
        // completes exactly then (t=2). J0 finishes at 3.
        let jobs = vec![Job::exact(0, 0.0, 2.0), Job::exact(1, 1.0, 1.0)];
        let r = run(&mut Las::new(), &jobs);
        assert!((r.completion[1] - 2.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[0] - 3.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn catch_up_then_share() {
        // J0 size 3, J1 size 3 arrives at 1. J1 alone [1,2) until both
        // have attained 1; then they share: each needs 2 more at rate
        // 1/2 -> both complete at 2 + 4 = 6.
        let jobs = vec![Job::exact(0, 0.0, 3.0), Job::exact(1, 1.0, 3.0)];
        let r = run(&mut Las::new(), &jobs);
        assert!((r.completion[0] - 6.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[1] - 6.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn equal_jobs_behave_like_ps() {
        let jobs: Vec<Job> = (0..5).map(|i| Job::exact(i, 0.0, 1.0)).collect();
        let r = run(&mut Las::new(), &jobs);
        for c in &r.completion {
            assert!((c - 5.0).abs() < 1e-9);
        }
    }

    #[test]
    fn small_jobs_fly_past_large_one() {
        // The heavy-tail motivation (§2.1): a size-10 job in progress
        // does not delay a stream of size-0.1 jobs at all.
        let mut jobs = vec![Job::exact(0, 0.0, 10.0)];
        for i in 1..=5 {
            jobs.push(Job::exact(i, i as f64, 0.1));
        }
        let r = run(&mut Las::new(), &jobs);
        for i in 1..=5usize {
            let sojourn = r.completion[i] - jobs[i].arrival;
            assert!((sojourn - 0.1).abs() < 1e-9, "job {i}: {sojourn}");
        }
    }

    #[test]
    fn size_oblivious_ignores_estimates() {
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 1.0, est: 100.0, weight: 1.0 },
            Job { id: 1, arrival: 0.0, size: 1.0, est: 0.001, weight: 1.0 },
        ];
        let r = run(&mut Las::new(), &jobs);
        assert!((r.completion[0] - 2.0).abs() < 1e-9);
        assert!((r.completion[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn three_levels_merge_in_order() {
        // Construct distinct attained levels then verify completions
        // come out in a work-conserving order.
        let jobs = vec![
            Job::exact(0, 0.0, 5.0),
            Job::exact(1, 1.0, 4.0),
            Job::exact(2, 2.0, 3.0),
        ];
        let r = run(&mut Las::new(), &jobs);
        // Hand-computed: levels equalize at attained 1 by t=3; then the
        // smallest job (J2) completes at t=9, J1 at 11, J0 at 12.
        assert!((r.completion[2] - 9.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[1] - 11.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[0] - 12.0).abs() < 1e-9, "{:?}", r.completion);
    }

    /// Regression (the merge-at-most-once bug): one `advance` carrying
    /// the front past SEVERAL level boundaries — an external driver
    /// merging event streams can legally land past a boundary by
    /// rounding — must fuse every caught level, not just the first.
    #[test]
    fn cascading_catch_up_merges_every_level() {
        let mut s = Las::new();
        let mut st = crate::sim::JobStore::new();
        let mut done = Vec::new();
        // Three levels with attained 0 (J2), 3 (J1), 5 (J0).
        st.deliver(&mut s, 0.0, &Job::exact(0, 0.0, 10.0));
        s.advance(0.0, 5.0, &st, &mut done); // J0 attained 5
        st.deliver(&mut s, 5.0, &Job::exact(1, 5.0, 10.0));
        s.advance(5.0, 8.0, &st, &mut done); // J1 attained 3
        st.deliver(&mut s, 8.0, &Job::exact(2, 8.0, 10.0));
        assert_eq!(s.levels.len(), 3);
        assert!(done.is_empty());
        // J2 (alone, rate 1) attains 5 + a rounding hair: it catches J1
        // *and* the fused pair catches J0 — a cascade in one call.
        s.advance(8.0, 13.0 + 1e-10, &st, &mut done);
        assert!(done.is_empty());
        assert_eq!(s.levels.len(), 1, "cascade must merge every caught level");
        assert_eq!(s.levels[0].jobs.len(), 3);
        // The fused group drains normally.
        let dt = s.next_dt().unwrap();
        s.advance(13.0, 13.0 + dt, &st, &mut done);
        assert_eq!(done.len(), 3, "all three share and finish together");
        assert_eq!(s.active(), 0);
    }

    /// Kill coverage: front-level job, deeper-level job, served job;
    /// the map stays consistent across merges.
    #[test]
    fn cancel_any_level() {
        let mut s = Las::new();
        let mut st = crate::sim::JobStore::new();
        let mut done = Vec::new();
        st.deliver(&mut s, 0.0, &Job::exact(0, 0.0, 6.0));
        s.advance(0.0, 2.0, &st, &mut done); // J0 attained 2
        st.deliver(&mut s, 2.0, &Job::exact(1, 2.0, 6.0));
        st.deliver(&mut s, 2.0, &Job::exact(2, 2.0, 6.0));
        assert_eq!(s.levels.len(), 2);
        // Kill the deep (already-served) job, then a front job.
        assert!(s.cancel(2.0, 0), "deep-level kill");
        assert!(s.cancel(2.0, 2), "front-level kill");
        assert!(!s.cancel(2.0, 2), "double kill must fail");
        assert!(!s.cancel(2.0, 9), "unknown id must fail");
        assert_eq!(s.active(), 1);
        // The survivor completes alone.
        let r_dt = s.next_dt().unwrap();
        s.advance(2.0, 2.0 + r_dt, &st, &mut done);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(s.active(), 0);
        assert!(s.where_is.is_empty(), "map must drain with the jobs");
    }

    /// Kills interleaved with merges: moved jobs stay findable.
    #[test]
    fn cancel_after_merge_keeps_map_consistent() {
        let mut s = Las::new();
        let mut st = crate::sim::JobStore::new();
        let mut done = Vec::new();
        st.deliver(&mut s, 0.0, &Job::exact(0, 0.0, 8.0));
        s.advance(0.0, 1.0, &st, &mut done); // J0 attained 1
        st.deliver(&mut s, 1.0, &Job::exact(1, 1.0, 8.0));
        st.deliver(&mut s, 1.0, &Job::exact(2, 1.0, 8.0));
        // Front {J1,J2} catches J0 at attained 1 (t = 1 + 2).
        s.advance(1.0, 3.0, &st, &mut done);
        assert_eq!(s.levels.len(), 1, "catch-up merged");
        for id in [0u32, 1, 2] {
            assert!(s.cancel(3.0, id), "job {id} findable after merge");
        }
        assert_eq!(s.active(), 0);
        assert!(s.levels.is_empty() || s.levels[0].jobs.is_empty());
    }
}
