//! Nonpreemptive SPT and SJF — the classic single-server comparison
//! points (arXiv:1907.04824) the size-based preemptive zoo is measured
//! against.
//!
//! Both serve one job at a time **to completion**: once a job starts it
//! holds the server until it finishes, whatever arrives meanwhile.
//! They differ only in the queueing key:
//!
//! * **SPT** (shortest *estimated* processing time) picks the waiting
//!   job with the smallest size *estimate* — the nonpreemptive
//!   counterpart of SRPTE, and like it degraded by estimate error;
//! * **SJF** (shortest job first) picks by *true* size — the
//!   clairvoyant nonpreemptive baseline.
//!
//! ### Kill semantics (§5.2.2 bookkeeping)
//! A *waiting* job can be killed (O(log n) heap removal via the dense
//! seq index).  A job that has **started service is rejected**
//! (`cancel` returns `false`): nonpreemptive semantics mean the server
//! cannot be reclaimed mid-job, mirroring real batch systems where a
//! dispatched task is past the point of cheap revocation.  The same
//! rule makes estimate updates on a started job report unsupported
//! through the `on_estimate_update` default (cancel fails, so no
//! re-key) — a started job's priority is spent, so a refreshed
//! estimate can no longer change anything.  The cancellation property
//! suite (`rust/tests/cancellation.rs`) covers both rules explicitly.

use super::MinHeap;
use crate::sim::{Completion, JobId, JobStore, Scheduler};
use crate::util::EPS;

/// Which column the queue is keyed on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NpKey {
    /// Size estimate (`store.est`) — SPT.
    Est,
    /// True size (`store.size`) — SJF.
    Size,
}

/// Nonpreemptive shortest-first scheduler (SPT over estimates, SJF
/// over true sizes).
#[derive(Debug)]
pub struct NonPreemptive {
    key: NpKey,
    /// The started job: `(id, true remaining)` — immune to arrivals,
    /// kills and estimate updates until it completes.
    serving: Option<(u32, f64)>,
    /// Waiting jobs keyed by estimate (SPT) or size (SJF); payload:
    /// true size.  Dense seq index: `remove_by_seq` (the kill path)
    /// is O(log n).
    waiting: MinHeap<f64>,
}

impl NonPreemptive {
    pub fn new(key: NpKey) -> Self {
        NonPreemptive { key, serving: None, waiting: MinHeap::with_dense_index() }
    }

    /// SPT: shortest estimated processing time.
    pub fn spt() -> Self {
        Self::new(NpKey::Est)
    }

    /// SJF: shortest (true-size) job first.
    pub fn sjf() -> Self {
        Self::new(NpKey::Size)
    }

    /// Rebuild with a plain (unindexed) waiting heap — the opt-in
    /// escape hatch for sweep deployments with no kill path (see
    /// `PolicySpec::build_sweep`).  Only valid on a fresh instance.
    pub fn unindexed(self) -> Self {
        debug_assert_eq!(self.waiting.len(), 0, "unindexed() only on fresh instances");
        NonPreemptive { waiting: MinHeap::new(), ..self }
    }

    fn pull_next(&mut self) {
        if self.serving.is_none() {
            if let Some((_, id, size)) = self.waiting.pop() {
                self.serving = Some((id as u32, size));
            }
        }
    }
}

impl Scheduler for NonPreemptive {
    fn name(&self) -> &'static str {
        match self.key {
            NpKey::Est => "spt",
            NpKey::Size => "sjf",
        }
    }

    fn on_arrival(&mut self, _now: f64, id: JobId, store: &JobStore) {
        let size = store.size(id);
        if self.serving.is_none() {
            // Idle server: start immediately (the queue is necessarily
            // empty — completions pull the next waiter synchronously).
            self.serving = Some((id, size));
        } else {
            let key = match self.key {
                NpKey::Est => store.est(id),
                NpKey::Size => size,
            };
            self.waiting.push(key, id as u64, size);
        }
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        self.serving.map(|(_, rem)| now + rem)
    }

    fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
        let dt = t - now;
        if let Some((id, rem)) = self.serving.as_mut() {
            *rem -= dt;
            if *rem <= EPS {
                done.push(Completion { id: *id, time: t });
                self.serving = None;
                self.pull_next();
            }
        }
    }

    fn active(&self) -> usize {
        self.waiting.len() + usize::from(self.serving.is_some())
    }

    /// Waiting jobs are killable; the started job is not (see the
    /// module docs) — `false` for it, exactly as for an unknown id.
    fn cancel(&mut self, _now: f64, id: u32) -> bool {
        if self.serving.map(|(sid, _)| sid) == Some(id) {
            return false;
        }
        self.waiting.remove_by_seq(id as u64).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run, Job};

    #[test]
    fn serves_to_completion_despite_shorter_arrival() {
        // J0 (size 10) starts at 0; J1 (size 1) at t=1 must wait the
        // full residue — the defining nonpreemptive behavior.
        let jobs = vec![Job::exact(0, 0.0, 10.0), Job::exact(1, 1.0, 1.0)];
        for mk in [NonPreemptive::spt, NonPreemptive::sjf] {
            let r = run(&mut mk(), &jobs);
            assert!((r.completion[0] - 10.0).abs() < 1e-9, "{:?}", r.completion);
            assert!((r.completion[1] - 11.0).abs() < 1e-9, "{:?}", r.completion);
        }
    }

    #[test]
    fn queue_orders_by_key_at_each_completion() {
        // While J0 runs, J1 (big) then J2 (small) queue; the small one
        // goes next regardless of arrival order.
        let jobs =
            vec![Job::exact(0, 0.0, 4.0), Job::exact(1, 1.0, 5.0), Job::exact(2, 2.0, 1.0)];
        for mk in [NonPreemptive::spt, NonPreemptive::sjf] {
            let r = run(&mut mk(), &jobs);
            assert!((r.completion[2] - 5.0).abs() < 1e-9, "{:?}", r.completion);
            assert!((r.completion[1] - 10.0).abs() < 1e-9, "{:?}", r.completion);
        }
    }

    #[test]
    fn spt_keys_on_estimates_sjf_on_sizes() {
        // J1 has a huge size but tiny estimate, J2 the reverse: SPT
        // believes the estimates, SJF sees through them.
        let jobs = vec![
            Job::exact(0, 0.0, 4.0),
            Job { id: 1, arrival: 1.0, size: 6.0, est: 0.5, weight: 1.0 },
            Job { id: 2, arrival: 2.0, size: 1.0, est: 9.0, weight: 1.0 },
        ];
        let spt = run(&mut NonPreemptive::spt(), &jobs);
        assert!((spt.completion[1] - 10.0).abs() < 1e-9, "{:?}", spt.completion);
        assert!((spt.completion[2] - 11.0).abs() < 1e-9, "{:?}", spt.completion);
        let sjf = run(&mut NonPreemptive::sjf(), &jobs);
        assert!((sjf.completion[2] - 5.0).abs() < 1e-9, "{:?}", sjf.completion);
        assert!((sjf.completion[1] - 11.0).abs() < 1e-9, "{:?}", sjf.completion);
    }

    /// Kill semantics: waiting jobs are killable, the started job is
    /// rejected, and a rejected kill leaves the run unperturbed.
    #[test]
    fn cancel_rejects_started_job_accepts_waiting() {
        for mk in [NonPreemptive::spt, NonPreemptive::sjf] {
            let mut s = mk();
            let mut st = crate::sim::JobStore::new();
            st.deliver(&mut s, 0.0, &Job::exact(0, 0.0, 5.0));
            st.deliver(&mut s, 0.0, &Job::exact(1, 0.0, 3.0));
            assert!(!s.cancel(0.0, 0), "{}: started job must reject the kill", s.name());
            assert!(s.cancel(0.0, 1), "{}: waiting job is killable", s.name());
            assert!(!s.cancel(0.0, 1), "{}: double kill", s.name());
            assert_eq!(s.active(), 1, "{}", s.name());
            let mut done = Vec::new();
            s.advance(0.0, 5.0, &st, &mut done);
            assert_eq!(done.len(), 1, "{}: survivor completes", s.name());
            assert_eq!(done[0].id, 0, "{}", s.name());
        }
    }

    /// Estimate updates ride the trait default: a waiting job re-keys
    /// (cancel + re-admit), the started job reports unsupported.
    #[test]
    fn estimate_update_rekeys_waiting_rejects_started() {
        let mut s = NonPreemptive::spt();
        let mut st = crate::sim::JobStore::new();
        st.deliver(&mut s, 0.0, &Job::exact(0, 0.0, 5.0));
        st.deliver(&mut s, 0.0, &Job { id: 1, arrival: 0.0, size: 3.0, est: 3.0, weight: 1.0 });
        st.deliver(&mut s, 0.0, &Job { id: 2, arrival: 0.0, size: 4.0, est: 4.0, weight: 1.0 });
        st.update_est(0, 1.0);
        assert!(!s.on_estimate_update(0.0, 0, &st), "started job cannot re-key");
        // Re-key J2 below J1: it must now be served before J1.
        st.update_est(2, 2.0);
        assert!(s.on_estimate_update(0.0, 2, &st));
        let mut done = Vec::new();
        s.advance(0.0, 5.0, &st, &mut done); // J0 completes
        s.advance(5.0, 9.0, &st, &mut done); // J2 (size 4) jumped the queue
        s.advance(9.0, 12.0, &st, &mut done); // J1 last
        let order: Vec<u32> = done.iter().map(|c| c.id).collect();
        assert_eq!(order, vec![0, 2, 1]);
    }
}
