//! Shared late-set engine — the §5.2.2 "additional bookkeeping".
//!
//! A *late* job is really pending while its estimated service is
//! exhausted: virtually complete in the FSP family (§4.2), estimated
//! remainder ≤ 0 in the SRPTE hybrids (§5.1).  Both families used to
//! keep those jobs in flat `VecDeque`/`Vec`s, folding over the whole
//! set once per `next_event` *and* once per `advance` and paying
//! O(|L|) removals — fine while |L| is small (§7.2), wrong in the
//! regime arXiv:1403.5996 identifies as the hard one (heavy
//! underestimation of skewed sizes, where |L| grows with the error).
//!
//! [`LateSet`] owns membership, per-mode sharing and event computation.
//! Serial/Ps/Dps insert, complete and cancel are O(log |L|); the Las
//! engine's completions are O(log |L|) and its admissions/cancels pay
//! an additional O(#levels) for level positioning (a binary search
//! plus a level-pointer memmove / tag scan — #levels is the number of
//! distinct EPS-separated attained groups, far below |L| in every
//! workload shape the paper studies, and the per-*event* folds are
//! gone in all modes, which is where the flat path actually burned
//! O(|L|)):
//!
//! * [`LateMode::Serial`] — one job at a time in insertion (= virtual
//!   completion) order: a rank-keyed [`MinHeap`], only the head's
//!   remaining work changes (in place, O(1) per step).
//! * [`LateMode::Ps`] / [`LateMode::Dps`] — the paper's own virtual-lag
//!   trick (§5.2.2), replayed *inside* the late set: a lag `g` grows at
//!   the per-weight service rate, a member admitted with remaining work
//!   `r` and weight `w` completes when `g` reaches its immutable
//!   `g + r/w`, and a `g`-keyed heap yields completions in order with
//!   no per-member updates.  The weight sum (the DPS denominator,
//!   arXiv:1506.09158's fairness bookkeeping) is a Neumaier-
//!   [`CompensatedSum`], reset on empty and debug-checked against a
//!   fresh fold, so long adversarial churn cannot drift the rates.
//! * [`LateMode::Las`] — attained-service levels as in [`super::las`],
//!   generalized to members arriving at *arbitrary* attained service:
//!   the front (minimum) group's common attained, size and next regroup
//!   boundary are all O(1) reads, replacing the two full folds the flat
//!   path paid per event; catch-up merges cascade through every level
//!   within `EPS` in a single `advance`.
//!
//! Cancellation ("jobs that complete even when they are not scheduled —
//! e.g. … after being killed") is first-class in every mode: the
//! serial/lag heaps carry a dense seq index (ids are the engine's dense
//! job ids), the LAS engine an id → level map.
//!
//! Exactness contract: per-member *remaining work* is represented
//! losslessly in every mode (head payload, lag gap × weight, finish
//! key − level attained), so the rewired schedulers reproduce the flat
//! path's completions to ≤ 1e-9 — pinned by `rust/tests/late_set_equiv.rs`
//! and the `sim::smallstep` cross-validation.

use super::MinHeap;
use crate::sim::Completion;
use crate::util::EPS;
use std::collections::{HashMap, VecDeque};

/// How the late set shares the server (the §5.1/§5.2 amendments).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LateMode {
    /// One at a time in virtual-completion order — plain FSPE (§4.2).
    Serial,
    /// Equal split — FSPE+PS / the SRPTE+PS eligible pool.
    Ps,
    /// Least-attained-service split — FSPE+LAS / SRPTE+LAS.
    Las,
    /// Weight-proportional split — PSBS (§5.2).
    Dps,
}

/// Re-exported from [`crate::stats`] (its home since the online
/// metrics layer began sharing it): the drift-proof backing for the
/// `w_l`/`w_v` weight sums that feed DPS rate denominators on every
/// event.  (Recompute-on-empty stays as a second line of defense: the
/// owners reset the sum whenever their population drains.)
pub use crate::stats::CompensatedSum;

/// Service split over one event step (rates are constant inside a
/// step; both owners recompute it per step).  The single field is the
/// service rate each *served* member receives: per unit weight in the
/// lag modes (`Ps`/`Dps` — a member of weight `w` progresses at
/// `w * rate`), per job in `Serial`/`Las` (head / front group).
/// `rate == 0.0` means the set is not served this step (e.g. an
/// SRPTE+LAS slot job strictly below the front group).
#[derive(Debug, Clone, Copy)]
pub struct Share {
    pub rate: f64,
}

/// One attained-service level of the LAS engine: every member has the
/// common `attained`; a member's heap key is the value of `attained`
/// at which it completes (`finish = attained_at_admission + remaining`),
/// so per-member remaining work is exact regardless of the ≤ EPS snap
/// at admission or merge.
#[derive(Debug)]
struct Level {
    /// Stable identity for the id → level map (positions shift).
    tag: u32,
    attained: f64,
    /// Keyed by finish, seq = job id.
    jobs: MinHeap<()>,
}

/// The LAS engine: levels sorted ascending by attained; the front is
/// the served group.  Adjacent levels always differ by more than EPS
/// (admission joins within EPS, catch-up merges at ≤ EPS), which keeps
/// the front's `(min_attained, k)` and the regroup boundary O(1).
#[derive(Debug, Default)]
struct LasLevels {
    levels: VecDeque<Level>,
    /// id → level tag (the §5.2.2 cancellation path).
    where_is: HashMap<u32, u32>,
    next_tag: u32,
}

impl LasLevels {
    fn insert(&mut self, id: u32, true_rem: f64, size: f64) {
        let attained = (size - true_rem).max(0.0);
        // First level strictly above the member's attained service.
        let pos = self.levels.partition_point(|lv| lv.attained <= attained);
        // Join the nearest level when within EPS; adjacent levels
        // differ by > EPS, so at most one side qualifies.
        let join = if pos > 0 && attained - self.levels[pos - 1].attained <= EPS {
            Some(pos - 1)
        } else if pos < self.levels.len() && self.levels[pos].attained - attained <= EPS {
            Some(pos)
        } else {
            None
        };
        match join {
            Some(i) => {
                let lv = &mut self.levels[i];
                lv.jobs.push(lv.attained + true_rem, id as u64, ());
                self.where_is.insert(id, lv.tag);
            }
            None => {
                let tag = self.next_tag;
                self.next_tag = self.next_tag.wrapping_add(1);
                // Map-indexed: cancellation inside a level is O(log)
                // instead of a scan (ids are sparse within one level,
                // so the dense-Vec index variant does not fit here).
                let mut jobs = MinHeap::with_index();
                jobs.push(attained + true_rem, id as u64, ());
                self.levels.insert(pos, Level { tag, attained, jobs });
                self.where_is.insert(id, tag);
            }
        }
    }

    fn cancel(&mut self, id: u32) -> bool {
        let Some(tag) = self.where_is.remove(&id) else {
            return false;
        };
        let pos = self
            .levels
            .iter()
            .position(|lv| lv.tag == tag)
            .expect("late-set LAS level map out of sync");
        let removed = self.levels[pos].jobs.remove_by_seq(id as u64);
        debug_assert!(removed.is_some(), "late-set LAS id map out of sync");
        if self.levels[pos].jobs.is_empty() {
            self.levels.remove(pos);
        }
        removed.is_some()
    }

    /// Integrate `step` units of per-member service into the front
    /// group, pop completions (landing at absolute time `t`), then
    /// cascade-merge every level the front has caught.
    fn advance(&mut self, step: f64, t: f64, done: &mut Vec<Completion>) {
        if let Some(front) = self.levels.front_mut() {
            front.attained += step;
        }
        while let Some(front) = self.levels.front_mut() {
            let due = match front.jobs.peek() {
                Some((finish, _, _)) => finish - front.attained <= EPS,
                None => false,
            };
            if due {
                let (_, id, ()) = front.jobs.pop().unwrap();
                self.where_is.remove(&(id as u32));
                done.push(Completion { id: id as u32, time: t });
            } else if front.jobs.is_empty() {
                // Front drained: the next level takes over.  It saw no
                // service this step, so no completions are due there —
                // re-running the loop keeps that an invariant rather
                // than an assumption.
                self.levels.pop_front();
            } else {
                break;
            }
        }
        self.merge_caught_levels();
    }

    /// Merge the front into its successor while the gap is ≤ EPS —
    /// **looped**, so several equal-attained levels (a cascading
    /// catch-up, or an `advance` overshooting a boundary by rounding)
    /// collapse into one served group within a single call instead of
    /// leaking zero-length events.  `reach` tracks how far the served
    /// group has actually advanced: a merge keeps one level's frame
    /// (the larger heap's), which can sit below an overshot front —
    /// comparing successors against `reach` instead of the surviving
    /// frame keeps the cascade going through every caught level.
    fn merge_caught_levels(&mut self) {
        let Some(front) = self.levels.front() else { return };
        let mut reach = front.attained;
        while self.levels.len() >= 2 && self.levels[1].attained - reach <= EPS {
            let mut small = self.levels.pop_front().unwrap();
            let keep = self.levels.front_mut().unwrap();
            // Keep the larger heap (amortized-cheap merges, as in
            // `super::las`); the frame — attained and tag — travels
            // with the heap it describes.
            if small.jobs.len() > keep.jobs.len() {
                std::mem::swap(&mut small.jobs, &mut keep.jobs);
                std::mem::swap(&mut small.attained, &mut keep.attained);
                std::mem::swap(&mut small.tag, &mut keep.tag);
            }
            // Rebase the smaller side into the surviving frame; the
            // shift keeps every moved member's remaining work exact.
            let shift = keep.attained - small.attained;
            reach = reach.max(keep.attained);
            while let Some((finish, id, ())) = small.jobs.pop() {
                keep.jobs.push(finish + shift, id, ());
                self.where_is.insert(id as u32, keep.tag);
            }
        }
    }
}

#[derive(Debug)]
enum Engine {
    /// Insertion-order queue; only the head is served.
    Serial { queue: MinHeap<f64>, next_rank: u64 },
    /// Weighted virtual-lag pool (Ps: all weights forced to 1).
    Lag { heap: MinHeap<f64>, g: f64, w: CompensatedSum },
    Las(LasLevels),
}

/// The shared late set: membership, per-[`LateMode`] sharing and event
/// computation for the FSP family and the SRPTE hybrids.
#[derive(Debug)]
pub struct LateSet {
    mode: LateMode,
    engine: Engine,
    /// Mutation counter driving the periodic drift debug-check.
    #[cfg(debug_assertions)]
    check_tick: u32,
}

impl LateSet {
    pub fn new(mode: LateMode) -> LateSet {
        let engine = match mode {
            LateMode::Serial => Engine::Serial {
                // Dense seq index: seqs are the engine's dense job ids,
                // making cancel O(log |L|) (same trade-off as the PSBS
                // `O` heap, tracked in BENCH_psbs_ops.json).
                queue: MinHeap::with_dense_index(),
                next_rank: 0,
            },
            LateMode::Ps | LateMode::Dps => Engine::Lag {
                heap: MinHeap::with_dense_index(),
                g: 0.0,
                w: CompensatedSum::new(),
            },
            LateMode::Las => Engine::Las(LasLevels::default()),
        };
        LateSet {
            mode,
            engine,
            #[cfg(debug_assertions)]
            check_tick: 0,
        }
    }

    pub fn mode(&self) -> LateMode {
        self.mode
    }

    pub fn len(&self) -> usize {
        match &self.engine {
            Engine::Serial { queue, .. } => queue.len(),
            Engine::Lag { heap, .. } => heap.len(),
            Engine::Las(l) => l.where_is.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Σ weights of members — the DPS rate denominator (`w_l`), kept
    /// drift-proof; equals `len()` in the unweighted modes.
    pub fn weight(&self) -> f64 {
        match &self.engine {
            Engine::Lag { w, .. } => w.value(),
            _ => self.len() as f64,
        }
    }

    /// Size of the group currently receiving service when the set is
    /// served: 1 (Serial), everyone (Ps/Dps), the front group (Las).
    pub fn served(&self) -> usize {
        match &self.engine {
            Engine::Serial { queue, .. } => queue.len().min(1),
            Engine::Lag { heap, .. } => heap.len(),
            Engine::Las(l) => l.levels.front().map_or(0, |lv| lv.jobs.len()),
        }
    }

    /// Las: common attained service of the front group (the set-wide
    /// minimum), O(1).  `None` in other modes or when empty.
    pub fn front_attained(&self) -> Option<f64> {
        match &self.engine {
            Engine::Las(l) => l.levels.front().map(|lv| lv.attained),
            _ => None,
        }
    }

    /// Las: the next attained level above the front — the §5.1 regroup
    /// boundary — O(1).
    pub fn regroup_boundary(&self) -> Option<f64> {
        match &self.engine {
            Engine::Las(l) => l.levels.get(1).map(|lv| lv.attained),
            _ => None,
        }
    }

    /// The share when the set owns the whole server (the FSP-family
    /// real side while late jobs exist).
    pub fn exclusive_share(&self) -> Share {
        let rate = if self.is_empty() {
            0.0
        } else {
            match &self.engine {
                Engine::Serial { .. } => 1.0,
                Engine::Lag { w, .. } => 1.0 / w.value(),
                Engine::Las(l) => {
                    1.0 / l.levels.front().map_or(1, |lv| lv.jobs.len()) as f64
                }
            }
        };
        Share { rate }
    }

    /// Admit a member: O(log |L|) (Las additionally pays O(#levels)
    /// to position/create the member's level).  `true_rem` must be
    /// > EPS (a job with no real work left completes instead of going
    /// late — both owners guarantee it).  `weight` is honored in Dps
    /// mode only.
    pub fn insert(&mut self, id: u32, weight: f64, true_rem: f64, size: f64) {
        let dps = self.mode == LateMode::Dps;
        match &mut self.engine {
            Engine::Serial { queue, next_rank } => {
                queue.push(*next_rank as f64, id as u64, true_rem);
                *next_rank += 1;
            }
            Engine::Lag { heap, g, w } => {
                let w_i = if dps { weight } else { 1.0 };
                heap.push(*g + true_rem / w_i, id as u64, w_i);
                w.add(w_i);
            }
            Engine::Las(l) => l.insert(id, true_rem, size),
        }
        self.debug_check_weight();
    }

    /// Remove a killed member without completing it: O(log |L|) in the
    /// indexed modes, O(#levels + log) in Las.
    pub fn cancel(&mut self, id: u32) -> bool {
        let hit = match &mut self.engine {
            Engine::Serial { queue, next_rank } => {
                let hit = queue.remove_by_seq(id as u64).is_some();
                if queue.is_empty() {
                    *next_rank = 0;
                }
                hit
            }
            Engine::Lag { heap, g, w } => match heap.remove_by_seq(id as u64) {
                Some((_, _, w_i)) => {
                    w.sub(w_i);
                    if heap.is_empty() {
                        w.reset();
                        *g = 0.0;
                    }
                    true
                }
                None => false,
            },
            Engine::Las(l) => l.cancel(id),
        };
        self.debug_check_weight();
        hit
    }

    /// Time until the earliest internal event of the set — a member
    /// completion, or a LAS catch-up with the level above the front —
    /// when served according to `share`.  O(1).
    pub fn next_event_dt(&self, share: Share) -> Option<f64> {
        if share.rate <= 0.0 || self.is_empty() {
            return None;
        }
        match &self.engine {
            Engine::Serial { queue, .. } => {
                queue.peek().map(|(_, _, rem)| (rem / share.rate).max(0.0))
            }
            Engine::Lag { heap, g, .. } => {
                heap.peek().map(|(g_min, _, _)| ((g_min - g) / share.rate).max(0.0))
            }
            Engine::Las(l) => {
                let front = l.levels.front()?;
                let (finish, _, _) = front.jobs.peek()?;
                let mut dt = (finish - front.attained).max(0.0);
                if let Some(next) = l.levels.get(1) {
                    dt = dt.min((next.attained - front.attained).max(0.0));
                }
                Some(dt / share.rate)
            }
        }
    }

    /// Integrate `dt` of wall-clock under `share`; completions land at
    /// the absolute time `t` (the step's end, as the flat path had it).
    pub fn advance(&mut self, dt: f64, share: Share, t: f64, done: &mut Vec<Completion>) {
        debug_assert!(dt >= 0.0, "late-set advance must move forward");
        let step = if share.rate > 0.0 { dt * share.rate } else { 0.0 };
        match &mut self.engine {
            Engine::Serial { queue, next_rank } => {
                if let Some(rem) = queue.head_mut() {
                    *rem -= step;
                }
                loop {
                    let due = match queue.peek() {
                        Some((_, _, &rem)) => rem <= EPS,
                        None => false,
                    };
                    if !due {
                        break;
                    }
                    let (_, id, _) = queue.pop().unwrap();
                    done.push(Completion { id: id as u32, time: t });
                }
                if queue.is_empty() {
                    *next_rank = 0;
                }
            }
            Engine::Lag { heap, g, w } => {
                *g += step; // step = dt · per-weight rate = dg
                loop {
                    // Completion when remaining work (lag gap × weight)
                    // is exhausted — the same per-member work-units EPS
                    // the flat path used.
                    let due = match heap.peek() {
                        Some((g_i, _, &w_i)) => (g_i - *g) * w_i <= EPS,
                        None => false,
                    };
                    if !due {
                        break;
                    }
                    let (_, id, w_i) = heap.pop().unwrap();
                    w.sub(w_i);
                    done.push(Completion { id: id as u32, time: t });
                }
                if heap.is_empty() {
                    // Kill accumulated rounding in both running values.
                    w.reset();
                    *g = 0.0;
                }
            }
            Engine::Las(l) => l.advance(step, t, done),
        }
        self.debug_check_weight();
    }

    /// Fold-recompute of the weight sum (test support + debug check).
    pub fn fold_weight(&self) -> f64 {
        match &self.engine {
            Engine::Lag { heap, .. } => heap.iter().map(|(_, _, w_i)| *w_i).sum(),
            _ => self.len() as f64,
        }
    }

    /// Periodic debug assertion: the incremental, compensated weight
    /// sum must match a fresh fold (the ISSUE's drift pin).  Runs every
    /// 64th mutation plus whenever the set empties; debug builds only.
    #[cfg(debug_assertions)]
    fn debug_check_weight(&mut self) {
        if let Engine::Lag { heap, w, .. } = &self.engine {
            self.check_tick = self.check_tick.wrapping_add(1);
            if !heap.is_empty() && self.check_tick % 64 != 0 {
                return;
            }
            let fold: f64 = heap.iter().map(|(_, _, w_i)| *w_i).sum();
            let scale = fold.abs().max(1.0);
            debug_assert!(
                (w.value() - fold).abs() <= 1e-9 * scale,
                "late-set weight drift: incremental {} vs fold {}",
                w.value(),
                fold
            );
        }
    }

    #[cfg(not(debug_assertions))]
    #[inline]
    fn debug_check_weight(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn drain(set: &mut LateSet) -> Vec<(u32, f64)> {
        // Run the set alone to completion, recording (id, time).
        let mut out = Vec::new();
        let mut now = 0.0;
        let mut done = Vec::new();
        let mut steps = 0u32;
        while !set.is_empty() {
            let share = set.exclusive_share();
            let dt = set.next_event_dt(share).expect("non-empty set has an event");
            done.clear();
            set.advance(dt, share, now + dt, &mut done);
            now += dt;
            for c in &done {
                out.push((c.id, c.time));
            }
            steps += 1;
            assert!(steps <= 100_000, "late set failed to drain");
        }
        out
    }

    #[test]
    fn serial_completes_in_insertion_order() {
        let mut s = LateSet::new(LateMode::Serial);
        s.insert(7, 1.0, 2.0, 2.0);
        s.insert(3, 1.0, 1.0, 1.0);
        s.insert(9, 1.0, 0.5, 0.5);
        let got = drain(&mut s);
        let ids: Vec<u32> = got.iter().map(|&(id, _)| id).collect();
        assert_eq!(ids, vec![7, 3, 9], "serial mode is strict insertion order");
        let times: Vec<f64> = got.iter().map(|&(_, t)| t).collect();
        assert!((times[0] - 2.0).abs() < 1e-12);
        assert!((times[1] - 3.0).abs() < 1e-12);
        assert!((times[2] - 3.5).abs() < 1e-12);
    }

    #[test]
    fn ps_mode_shares_equally() {
        let mut s = LateSet::new(LateMode::Ps);
        s.insert(0, 1.0, 1.0, 1.0);
        s.insert(1, 1.0, 2.0, 2.0);
        // Rates 1/2 each: J0 done at 2; J1 then alone, done at 3.
        let got = drain(&mut s);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, 0);
        assert!((got[0].1 - 2.0).abs() < 1e-12, "{got:?}");
        assert!((got[1].1 - 3.0).abs() < 1e-12, "{got:?}");
    }

    #[test]
    fn dps_mode_shares_by_weight() {
        let mut s = LateSet::new(LateMode::Dps);
        s.insert(0, 3.0, 3.0, 3.0);
        s.insert(1, 1.0, 1.0, 1.0);
        // Rates 3/4 and 1/4: both complete exactly at t = 4.
        let got = drain(&mut s);
        assert_eq!(got.len(), 2);
        for (_, t) in got {
            assert!((t - 4.0).abs() < 1e-12);
        }
    }

    #[test]
    fn las_mode_serves_least_attained_first() {
        let mut s = LateSet::new(LateMode::Las);
        // J0 attained 2 (size 4, rem 2); J1 attained 0 (size 1, rem 1).
        s.insert(0, 1.0, 2.0, 4.0);
        s.insert(1, 1.0, 1.0, 1.0);
        assert_eq!(s.served(), 1, "front group = the attained-0 job");
        assert!((s.front_attained().unwrap() - 0.0).abs() < 1e-12);
        assert!((s.regroup_boundary().unwrap() - 2.0).abs() < 1e-12);
        // J1 alone until done at 1; J0 resumes alone, done at 3.
        let got = drain(&mut s);
        assert_eq!(got[0].0, 1);
        assert!((got[0].1 - 1.0).abs() < 1e-12, "{got:?}");
        assert_eq!(got[1].0, 0);
        assert!((got[1].1 - 3.0).abs() < 1e-12, "{got:?}");
    }

    #[test]
    fn las_catch_up_merges_and_shares() {
        let mut s = LateSet::new(LateMode::Las);
        // J0 attained 1 (rem 3), J1 attained 0 (rem 3): J1 alone for 1
        // unit (catch-up), then both share at 1/2.  J1 (rem 2 at the
        // merge) completes at 1 + 2·2 = 5; J0 has rem 3 − 2 = 1 then
        // and finishes alone at 6.
        s.insert(0, 1.0, 3.0, 4.0);
        s.insert(1, 1.0, 3.0, 3.0);
        let got = drain(&mut s);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, 1);
        assert!((got[0].1 - 5.0).abs() < 1e-12, "{got:?}");
        assert!((got[1].1 - 6.0).abs() < 1e-12, "{got:?}");
    }

    /// Three levels brought within EPS of each other collapse to one
    /// group in a single advance (the cascading catch-up the flat scan
    /// handled implicitly and the old level code left unmerged).
    #[test]
    fn las_cascading_catch_up_merges_all_levels() {
        let mut s = LateSet::new(LateMode::Las);
        s.insert(0, 1.0, 10.0, 10.0); // attained 0 (front)
        s.insert(1, 1.0, 10.0, 13.0); // attained 3
        s.insert(2, 1.0, 10.0, 13.0 + 2.0 * EPS); // attained 3 + 2eps
        assert_eq!(s.served(), 1);
        // Drive the front past BOTH boundaries in one call (an
        // overshooting driver — rounding in an external event merge can
        // legally land here); the cascade must absorb both levels.
        // 1.5·EPS keeps each gap comfortably inside the ≤ EPS merge
        // band (no exact-EPS fp coin flips).
        let share = s.exclusive_share();
        let mut done = Vec::new();
        s.advance(3.0 + 1.5 * EPS, share, 3.0 + 1.5 * EPS, &mut done);
        assert!(done.is_empty());
        assert_eq!(
            s.served(),
            3,
            "all three members must share after the cascading catch-up"
        );
        // And the set still drains cleanly.
        let got = drain(&mut s);
        assert_eq!(got.len(), 3);
    }

    #[test]
    fn cancel_every_mode_mid_flight() {
        for mode in [LateMode::Serial, LateMode::Ps, LateMode::Las, LateMode::Dps] {
            let mut s = LateSet::new(mode);
            for id in 0..10u32 {
                s.insert(id, 1.0 + (id % 3) as f64, 1.0 + id as f64 * 0.3, 2.0 + id as f64);
            }
            assert!(s.cancel(4), "{mode:?}: member 4 is present");
            assert!(!s.cancel(4), "{mode:?}: double cancel must fail");
            assert!(!s.cancel(77), "{mode:?}: unknown id must fail");
            assert_eq!(s.len(), 9);
            let got = drain(&mut s);
            assert_eq!(got.len(), 9, "{mode:?}");
            assert!(got.iter().all(|&(id, _)| id != 4), "{mode:?}: cancelled member completed");
        }
    }

    /// Long adversarial churn with wildly mixed weights: the
    /// compensated `w_l` must match a fresh fold to ~1e-12 relative —
    /// the drift pin for the DPS rates (a plain running sum drifts
    /// orders of magnitude further under this schedule).
    #[test]
    fn dps_weight_sum_survives_adversarial_churn() {
        let mut rng = Rng::new(0xD217);
        let mut s = LateSet::new(LateMode::Dps);
        let mut live: Vec<u32> = Vec::new();
        let mut next_id = 0u32;
        for round in 0..20_000u32 {
            let op = rng.below(3);
            if op < 2 || live.is_empty() {
                // Weights spanning ~12 orders of magnitude.
                let w = 10f64.powf(rng.u01() * 12.0 - 6.0);
                s.insert(next_id, w, 1.0 + rng.u01(), 2.0 + rng.u01());
                live.push(next_id);
                next_id += 1;
            } else {
                let i = rng.below(live.len() as u64) as usize;
                let id = live.swap_remove(i);
                assert!(s.cancel(id));
            }
            if round % 512 == 0 {
                let fold = s.fold_weight();
                let err = (s.weight() - fold).abs() / fold.max(1.0);
                assert!(err < 1e-12, "round {round}: w_l drift {err:e}");
            }
        }
        // Drain and re-check the empty reset.
        for &id in &live {
            assert!(s.cancel(id));
        }
        assert!(s.is_empty());
        assert_eq!(s.weight(), 0.0, "empty set must reset its weight sum exactly");
    }

    /// The compensated sum itself: alternating add/sub churn of
    /// mixed-magnitude values stays exact where a naive sum drifts.
    #[test]
    fn compensated_sum_beats_naive_under_churn() {
        let mut rng = Rng::new(42);
        let mut comp = CompensatedSum::new();
        let mut naive = 0.0f64;
        let mut vals: Vec<f64> = Vec::new();
        for _ in 0..50_000 {
            if vals.is_empty() || rng.u01() < 0.6 {
                let v = 10f64.powf(rng.u01() * 16.0 - 8.0);
                comp.add(v);
                naive += v;
                vals.push(v);
            } else {
                let v = vals.swap_remove(rng.below(vals.len() as u64) as usize);
                comp.sub(v);
                naive -= v;
            }
        }
        let exact: f64 = vals.iter().sum();
        let scale = exact.abs().max(1.0);
        let comp_err = (comp.value() - exact).abs() / scale;
        let naive_err = (naive - exact).abs() / scale;
        assert!(comp_err < 1e-13, "compensated error {comp_err:e}");
        assert!(
            comp_err <= naive_err,
            "compensation must not be worse than the naive sum ({comp_err:e} vs {naive_err:e})"
        );
    }

    /// Randomized agreement with a flat O(|L|) reference across all
    /// four modes (the in-crate half of the old-path equivalence pin;
    /// the full scheduler-level pin lives in tests/late_set_equiv.rs).
    #[test]
    fn matches_flat_reference_all_modes() {
        #[derive(Clone, Copy)]
        struct Flat {
            id: u32,
            weight: f64,
            true_rem: f64,
            size: f64,
        }
        fn flat_drain(mode: LateMode, jobs: &[Flat]) -> Vec<(u32, f64)> {
            let mut late: Vec<Flat> = jobs.to_vec();
            let mut now = 0.0;
            let mut out = Vec::new();
            while !late.is_empty() {
                let w_l: f64 = late.iter().map(|l| l.weight).sum();
                let min_att = late
                    .iter()
                    .map(|l| l.size - l.true_rem)
                    .fold(f64::INFINITY, f64::min);
                let k = late
                    .iter()
                    .filter(|l| l.size - l.true_rem <= min_att + EPS)
                    .count() as f64;
                let rate = |i: usize, l: &Flat| -> f64 {
                    match mode {
                        LateMode::Serial => {
                            if i == 0 {
                                1.0
                            } else {
                                0.0
                            }
                        }
                        LateMode::Ps => 1.0 / late.len() as f64,
                        LateMode::Dps => l.weight / w_l,
                        LateMode::Las => {
                            if l.size - l.true_rem <= min_att + EPS {
                                1.0 / k
                            } else {
                                0.0
                            }
                        }
                    }
                };
                let mut dt = f64::INFINITY;
                for (i, l) in late.iter().enumerate() {
                    let r = rate(i, l);
                    if r > 0.0 {
                        dt = dt.min(l.true_rem / r);
                    }
                }
                if mode == LateMode::Las {
                    let next = late
                        .iter()
                        .map(|l| l.size - l.true_rem)
                        .filter(|a| *a > min_att + EPS)
                        .fold(f64::INFINITY, f64::min);
                    if next.is_finite() {
                        dt = dt.min((next - min_att) * k);
                    }
                }
                let rates: Vec<f64> =
                    late.iter().enumerate().map(|(i, l)| rate(i, l)).collect();
                for (l, r) in late.iter_mut().zip(&rates) {
                    l.true_rem -= r * dt;
                }
                now += dt;
                let mut i = 0;
                while i < late.len() {
                    if late[i].true_rem <= EPS {
                        out.push((late[i].id, now));
                        late.remove(i);
                    } else {
                        i += 1;
                    }
                }
            }
            out
        }

        let mut rng = Rng::new(7);
        for mode in [LateMode::Serial, LateMode::Ps, LateMode::Las, LateMode::Dps] {
            for case in 0..30 {
                let n = 2 + (case % 9);
                let jobs: Vec<Flat> = (0..n)
                    .map(|id| {
                        let size = 0.2 + rng.u01() * 4.0;
                        let true_rem = (size * (0.2 + 0.8 * rng.u01())).max(0.05);
                        let weight = 1.0 / (1.0 + rng.below(4) as f64);
                        Flat { id, weight, true_rem, size }
                    })
                    .collect();
                let mut s = LateSet::new(mode);
                for j in &jobs {
                    s.insert(j.id, j.weight, j.true_rem, j.size);
                }
                let mut got = drain(&mut s);
                let mut want = flat_drain(mode, &jobs);
                got.sort_by(|a, b| a.0.cmp(&b.0));
                want.sort_by(|a, b| a.0.cmp(&b.0));
                assert_eq!(got.len(), want.len(), "{mode:?} case {case}");
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.0, w.0, "{mode:?} case {case}");
                    assert!(
                        (g.1 - w.1).abs() < 1e-9,
                        "{mode:?} case {case} job {}: {} vs {}",
                        g.0,
                        g.1,
                        w.1
                    );
                }
            }
        }
    }
}
