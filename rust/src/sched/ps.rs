//! PS and DPS via the paper's own virtual-lag trick (§5.2.2).
//!
//! Processor sharing: all pending jobs receive rate `1/n` (DPS:
//! `w_i/Σw`).  Instead of updating every job's remaining size at each
//! event (O(n)), we track a global *lag* `g` growing at `1/Σw` and give
//! each arriving job an immutable completion lag `g_i = g + s_i/w_i`;
//! jobs complete when `g` reaches `g_i`, in `g_i` order, from a binary
//! min-heap — O(log n) per event.  (This is exactly the structure PSBS
//! uses for its *virtual* system; here it runs the *real* one.)

use super::MinHeap;
use crate::sim::{Completion, JobId, JobStore, Scheduler};
use crate::util::EPS;

/// Discriminatory processor sharing (PS when `use_weights` is false or
/// all weights are 1).
#[derive(Debug)]
pub struct Dps {
    /// Completion-lag heap: key `g_i`, payload weight.
    heap: MinHeap<f64>,
    /// Global lag `g` (grows at `1/Σw` while jobs are pending).
    g: f64,
    /// Σ weights of pending jobs.
    wsum: f64,
    use_weights: bool,
}

impl Dps {
    /// Weight-respecting DPS (§6.1, §7.6).
    pub fn new() -> Self {
        // Dense seq index (job ids are dense by the engine contract):
        // `remove_by_seq` — the §5.2.2 kill path — is O(log n) instead
        // of an O(n) scan, at one array write per sift swap on the
        // event path (the `heap/` trade-off in BENCH_psbs_ops.json).
        Dps { heap: MinHeap::with_dense_index(), g: 0.0, wsum: 0.0, use_weights: true }
    }

    /// Plain PS: every job weighs 1 regardless of `Job::weight`.
    pub fn ps() -> Self {
        Dps { use_weights: false, ..Dps::new() }
    }

    fn weight_of(&self, weight: f64) -> f64 {
        if self.use_weights {
            weight
        } else {
            1.0
        }
    }

    /// Rebuild with plain (unindexed) heaps — the opt-in escape hatch
    /// for sweep deployments where no kill path exists (see
    /// `PolicySpec::build_sweep`).  Only valid on a fresh instance.
    pub fn unindexed(self) -> Self {
        debug_assert_eq!(self.heap.len(), 0, "unindexed() only on fresh instances");
        Dps { heap: MinHeap::new(), ..self }
    }
}

impl Default for Dps {
    fn default() -> Self {
        Dps::new()
    }
}

impl Scheduler for Dps {
    fn name(&self) -> &'static str {
        if self.use_weights {
            "dps"
        } else {
            "ps"
        }
    }

    fn on_arrival(&mut self, _now: f64, id: JobId, store: &JobStore) {
        let w = self.weight_of(store.weight(id));
        // True size: PS is size-oblivious; a job leaves when it has
        // *received* its true service demand.
        self.heap.push(self.g + store.size(id) / w, id as u64, w);
        self.wsum += w;
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        let (g_min, _, _) = self.heap.peek()?;
        Some(now + (g_min - self.g).max(0.0) * self.wsum)
    }

    fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
        if self.wsum > 0.0 {
            self.g += (t - now) / self.wsum;
        }
        // Complete every job whose lag has been reached. Comparison in
        // *time* units (lag gap x Σw) so EPS keeps its meaning.
        while let Some((g_i, _, _)) = self.heap.peek() {
            if (g_i - self.g) * self.wsum <= EPS {
                let (_, id, w) = self.heap.pop().unwrap();
                self.wsum -= w;
                if self.heap.is_empty() {
                    self.wsum = 0.0; // kill accumulated rounding
                }
                done.push(Completion { id: id as u32, time: t });
            } else {
                break;
            }
        }
    }

    fn active(&self) -> usize {
        self.heap.len()
    }

    /// §5.2.2 kill bookkeeping: drop the job's lag entry and its weight
    /// share — the remaining jobs immediately split the freed capacity
    /// (their completion lags are immutable; only `Σw` changes).
    fn cancel(&mut self, _now: f64, id: u32) -> bool {
        match self.heap.remove_by_seq(id as u64) {
            Some((_, _, w)) => {
                self.wsum -= w;
                if self.heap.is_empty() {
                    self.wsum = 0.0; // kill accumulated rounding
                }
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run, Job};

    #[test]
    fn two_equal_jobs_share() {
        let jobs = vec![Job::exact(0, 0.0, 1.0), Job::exact(1, 0.0, 1.0)];
        let r = run(&mut Dps::ps(), &jobs);
        assert!((r.completion[0] - 2.0).abs() < 1e-9);
        assert!((r.completion[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn staggered_arrival_hand_computed() {
        // J0 (size 2) alone on [0,1): rem 1. J1 (size 1) arrives at 1;
        // both at rate 1/2: J1 needs 2 time units -> done at 3; J0 also
        // has rem 1 at t=1 -> done at 3.
        let jobs = vec![Job::exact(0, 0.0, 2.0), Job::exact(1, 1.0, 1.0)];
        let r = run(&mut Dps::ps(), &jobs);
        assert!((r.completion[0] - 3.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[1] - 3.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn dps_weights_shift_completion() {
        // weights 2:1, sizes 1:1 -> rates 2/3, 1/3; J0 done at 1.5;
        // then J1 alone (rem 0.5) -> done at 2.0.
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 1.0, est: 1.0, weight: 2.0 },
            Job { id: 1, arrival: 0.0, size: 1.0, est: 1.0, weight: 1.0 },
        ];
        let r = run(&mut Dps::new(), &jobs);
        assert!((r.completion[0] - 1.5).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[1] - 2.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn ps_ignores_weights() {
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 1.0, est: 1.0, weight: 100.0 },
            Job { id: 1, arrival: 0.0, size: 1.0, est: 1.0, weight: 1.0 },
        ];
        let r = run(&mut Dps::ps(), &jobs);
        assert!((r.completion[0] - 2.0).abs() < 1e-9);
        assert!((r.completion[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn slowdown_constant_under_ps_batch() {
        // A PS batch arriving together: slowdown of each job is n for
        // equal sizes (paper §7.2's "staircase" intuition).
        let jobs: Vec<Job> = (0..4).map(|i| Job::exact(i, 0.0, 1.0)).collect();
        let r = run(&mut Dps::ps(), &jobs);
        for j in &jobs {
            assert!((j.slowdown(r.completion[j.id as usize]) - 4.0).abs() < 1e-9);
        }
    }

    #[test]
    fn idle_gap_between_bursts() {
        let jobs = vec![Job::exact(0, 0.0, 1.0), Job::exact(1, 10.0, 1.0)];
        let r = run(&mut Dps::ps(), &jobs);
        assert!((r.completion[0] - 1.0).abs() < 1e-9);
        assert!((r.completion[1] - 11.0).abs() < 1e-9);
    }

    /// Killing a sharer frees its share for the survivors at once.
    #[test]
    fn cancel_releases_the_share() {
        let mut s = Dps::ps();
        let mut st = crate::sim::JobStore::new();
        let mut done = Vec::new();
        st.deliver(&mut s, 0.0, &Job::exact(0, 0.0, 4.0));
        st.deliver(&mut s, 0.0, &Job::exact(1, 0.0, 4.0));
        s.advance(0.0, 2.0, &st, &mut done); // each has 3 remaining
        assert!(s.cancel(2.0, 0));
        assert!(!s.cancel(2.0, 0), "double kill must fail");
        assert_eq!(s.active(), 1);
        // Survivor now runs at rate 1: done at 2 + 3 = 5.
        let ev = s.next_event(2.0).unwrap();
        assert!((ev - 5.0).abs() < 1e-9, "survivor event at {ev}");
        s.advance(2.0, ev, &st, &mut done);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(s.active(), 0);
    }

    /// DPS: killing a heavy job re-weights the survivors correctly.
    #[test]
    fn dps_cancel_reweights() {
        let mut s = Dps::new();
        let mut st = crate::sim::JobStore::new();
        let mut done = Vec::new();
        st.deliver(&mut s, 0.0, &Job { id: 0, arrival: 0.0, size: 10.0, est: 10.0, weight: 3.0 });
        st.deliver(&mut s, 0.0, &Job { id: 1, arrival: 0.0, size: 2.0, est: 2.0, weight: 1.0 });
        // Rates 3/4, 1/4. At t=1: J0 rem 9.25, J1 rem 1.75.
        s.advance(0.0, 1.0, &st, &mut done);
        assert!(s.cancel(1.0, 0));
        // J1 alone at rate 1: done at 1 + 1.75 = 2.75.
        let ev = s.next_event(1.0).unwrap();
        assert!((ev - 2.75).abs() < 1e-9, "survivor event at {ev}");
        s.advance(1.0, ev, &st, &mut done);
        assert_eq!(done.len(), 1);
        assert_eq!(s.active(), 0);
    }

    /// The seq→slot index is a pure accelerator: an unindexed build
    /// produces bitwise-identical results on a plain sweep workload.
    #[test]
    fn unindexed_matches_indexed_bitwise() {
        let jobs: Vec<Job> = (0..60)
            .map(|i| Job { id: i, arrival: i as f64 * 0.3, size: 1.0 + (i % 7) as f64, est: 1.0, weight: 1.0 + (i % 3) as f64 })
            .collect();
        let a = run(&mut Dps::new(), &jobs);
        let b = run(&mut Dps::new().unindexed(), &jobs);
        assert_eq!(a.events, b.events);
        for (x, y) in a.completion.iter().zip(&b.completion) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
