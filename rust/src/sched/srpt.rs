//! SRPT / SRPTE — shortest remaining (estimated) processing time, §4.
//!
//! One job is served at a time: the one with the smallest *estimated*
//! remaining processing time.  A newly arrived job preempts the served
//! one iff its estimate is strictly smaller than the served job's
//! estimated remainder — **and** the served job is not *late*.  A late
//! job (estimated remainder <= 0, §4.2) can never be preempted, because
//! every new estimate is positive: this is precisely the pathological
//! behavior the paper identifies (an under-estimated large job
//! monopolizes the server), kept here faithfully so the SRPTE curves of
//! Figs. 3a/5/6 reproduce.
//!
//! With exact estimates this is textbook SRPT (optimal mean sojourn
//! time).  Waiting jobs' estimated remainders never change (they are
//! not served), so a plain min-heap suffices: O(log n) per event.

use super::MinHeap;
use crate::sim::{Completion, JobId, JobStore, Scheduler};
use crate::util::EPS;

#[derive(Debug, Clone, Copy)]
struct Serving {
    id: u32,
    est_rem: f64,
    true_rem: f64,
}

/// SRPT over (possibly wrong) estimates.
#[derive(Debug, Default)]
pub struct Srpte {
    serving: Option<Serving>,
    /// Waiting jobs keyed by estimated remainder (static while waiting;
    /// strictly positive — jobs can only go late *while served*).
    waiting: MinHeap<f64>, // payload: true remaining
}

impl Srpte {
    pub fn new() -> Self {
        Self::default()
    }

    fn pull_next(&mut self) {
        if let Some((est_rem, id, true_rem)) = self.waiting.pop() {
            self.serving = Some(Serving { id: id as u32, est_rem, true_rem });
        }
    }
}

impl Scheduler for Srpte {
    fn name(&self) -> &'static str {
        "srpte"
    }

    fn on_arrival(&mut self, _now: f64, id: JobId, store: &JobStore) {
        let (est, size) = (store.est(id), store.size(id));
        match self.serving {
            None => {
                self.serving = Some(Serving { id, est_rem: est, true_rem: size });
            }
            Some(cur) if cur.est_rem > 0.0 && est < cur.est_rem => {
                // Preempt: push the current job back with its updated
                // estimated remainder (still positive).
                self.waiting.push(cur.est_rem, cur.id as u64, cur.true_rem);
                self.serving = Some(Serving { id, est_rem: est, true_rem: size });
            }
            Some(_) => {
                self.waiting.push(est, id as u64, size);
            }
        }
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        self.serving.map(|s| now + s.true_rem)
    }

    fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
        let dt = t - now;
        if let Some(s) = self.serving.as_mut() {
            s.true_rem -= dt;
            s.est_rem -= dt;
            if s.true_rem <= EPS {
                done.push(Completion { id: s.id, time: t });
                self.serving = None;
                self.pull_next();
                // Chain any zero-size successors (true_rem == 0 ties are
                // surfaced on the next engine iteration).
            }
        }
    }

    fn active(&self) -> usize {
        self.waiting.len() + usize::from(self.serving.is_some())
    }

    fn cancel(&mut self, _now: f64, id: u32) -> bool {
        if self.serving.map(|s| s.id) == Some(id) {
            self.serving = None;
            self.pull_next();
            return true;
        }
        self.waiting.remove_by_seq(id as u64).is_some()
    }

    /// Native estimate re-key, bitwise-equal to cancel + re-admit (the
    /// trait default, pinned in `rust/tests/online_est.rs`): the job
    /// restarts with `est_rem = est` and `true_rem = size`, exactly as
    /// a fresh arrival would.  The win over the default is the served
    /// job's fast path — when the refreshed estimate still beats every
    /// waiter, the heap is left untouched instead of paying the
    /// default's pop + push round trip (same entry multiset either
    /// way, and pop order depends only on the `(key, seq)` multiset,
    /// so the shortcut cannot change any later decision).
    fn on_estimate_update(&mut self, now: f64, id: JobId, store: &JobStore) -> bool {
        if self.serving.map(|s| s.id) == Some(id) {
            let (est, size) = (store.est(id), store.size(id));
            match self.waiting.peek() {
                // A waiter wins (ties included — preemption in
                // `on_arrival` is strict, and waiting keys are always
                // positive): it takes the server, the refreshed job
                // re-queues at its new estimate.
                Some((wkey, _, _)) if est >= wkey => {
                    let (wkey, wid, wtrue) = self.waiting.pop().unwrap();
                    self.serving =
                        Some(Serving { id: wid as u32, est_rem: wkey, true_rem: wtrue });
                    self.waiting.push(est, id as u64, size);
                }
                _ => self.serving = Some(Serving { id, est_rem: est, true_rem: size }),
            }
            return true;
        }
        if self.waiting.remove_by_seq(id as u64).is_some() {
            self.on_arrival(now, id, store);
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run, Job};

    #[test]
    fn exact_srpt_prefers_short_jobs() {
        let jobs = vec![
            Job::exact(0, 0.0, 3.0),
            Job::exact(1, 1.0, 1.0),
            Job::exact(2, 1.0, 2.0),
        ];
        let r = run(&mut Srpte::new(), &jobs);
        // J1 preempts (1 < rem 2), runs [1,2]; J2 next (2 <= 2 tie keeps
        // J0? rem(J0)=2, est J2=2: not strictly smaller -> J0 resumes).
        assert!((r.completion[1] - 2.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[0] - 4.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[2] - 6.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn overestimated_job_is_the_only_victim() {
        // Paper Fig. 1 (left): over-estimating J1 lets later smaller
        // jobs preempt it; only J1's sojourn suffers.
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 2.0, est: 10.0, weight: 1.0 },
            Job::exact(1, 1.0, 1.5),
        ];
        let r = run(&mut Srpte::new(), &jobs);
        // J1 preempts (1.5 < 9): runs [1, 2.5]; J0 resumes, done at 3.5.
        assert!((r.completion[1] - 2.5).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[0] - 3.5).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn underestimated_job_goes_late_and_blocks() {
        // Paper Fig. 1 (right): J0 size 4, est 1 -> late at t=1; the
        // size-1 job arriving at t=2 cannot preempt and waits 2 extra.
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 4.0, est: 1.0, weight: 1.0 },
            Job::exact(1, 2.0, 1.0),
        ];
        let r = run(&mut Srpte::new(), &jobs);
        assert!((r.completion[0] - 4.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[1] - 5.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn mst_optimal_vs_ps_on_exact_sizes() {
        use crate::workload::dists::{Dist, Weibull};
        let mut rng = crate::util::rng::Rng::new(5);
        let w = Weibull::unit_mean(0.5);
        let mut t = 0.0;
        let jobs: Vec<Job> = (0..200)
            .map(|i| {
                t += rng.u01() * 0.5;
                Job::exact(i, t, w.sample(&mut rng).max(1e-6))
            })
            .collect();
        let srpt = run(&mut Srpte::new(), &jobs).mst(&jobs);
        let ps = run(&mut super::super::ps::Dps::ps(), &jobs).mst(&jobs);
        assert!(srpt <= ps + 1e-9, "SRPT {srpt} should beat PS {ps}");
    }

    #[test]
    fn work_conserving() {
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 2.0, est: 0.5, weight: 1.0 },
            Job { id: 1, arrival: 0.5, size: 1.0, est: 3.0, weight: 1.0 },
        ];
        let r = run(&mut Srpte::new(), &jobs);
        let last = r.completion.iter().cloned().fold(0.0, f64::max);
        assert!((last - 3.0).abs() < 1e-9, "{:?}", r.completion);
    }
}
