//! SRPTE+PS and SRPTE+LAS — the paper's §5.1 amendments to SRPTE.
//!
//! As long as no job is late these behave exactly like SRPTE.  When
//! late jobs exist, the *eligible set* is **all late jobs plus the
//! highest-priority non-late job** (jobs go late only while served, so
//! non-late jobs must keep getting a chance — unlike the FSPE variants
//! which schedule late jobs only).  Eligible jobs share the server:
//!
//! * `SrpteHybrid::ps()`  — PS among eligible jobs;
//! * `SrpteHybrid::las()` — LAS among eligible jobs (equal split of the
//!   least-attained group).
//!
//! The late jobs live in the shared [`LateSet`] engine (`Ps`/`Las`
//! mode), so membership, completions and §5.2.2 cancellation are
//! O(log |L|) (Las admissions/cancels add O(#levels) positioning) and
//! the per-event sharing state — PS pool size, LAS front group and
//! regroup boundary — is an O(1) read.  The paper
//! argues |L| stays small in practice (§7.2), but under heavy
//! underestimation of skewed sizes (the arXiv:1403.5996 hard regime)
//! it does not, and the flat O(|L|) per-event scans this module used
//! to carry became the bottleneck.  The slot job is the one eligible
//! member outside the set; the [`RateCtx`] glue below splits the
//! server between the two.

use super::late_set::{LateMode, LateSet, Share};
use super::MinHeap;
use crate::sim::{Completion, JobId, JobStore, Scheduler};
use crate::util::EPS;

/// How eligible jobs share the server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShareMode {
    Ps,
    Las,
}

#[derive(Debug, Clone, Copy)]
struct Elig {
    id: u32,
    est_rem: f64,
    true_rem: f64,
    /// Original size (attained = size - true_rem, for LAS mode).
    size: f64,
}

impl Elig {
    fn attained(&self) -> f64 {
        self.size - self.true_rem
    }
}

/// SRPTE with PS/LAS among late jobs + the best non-late job.
#[derive(Debug)]
pub struct SrpteHybrid {
    mode: ShareMode,
    /// The non-late eligible job (highest SRPTE priority).
    slot: Option<Elig>,
    /// Late jobs (est_rem <= 0): the shared O(log |L|) engine.
    late: LateSet,
    /// Non-late, non-eligible jobs keyed by estimated remainder
    /// (static while waiting). Payload: (true_rem, size).  Dense
    /// seq index: `remove_by_seq` (the kill path) is O(log n).
    waiting: MinHeap<(f64, f64)>,
}

impl SrpteHybrid {
    pub fn new(mode: ShareMode) -> Self {
        let late = LateSet::new(match mode {
            ShareMode::Ps => LateMode::Ps,
            ShareMode::Las => LateMode::Las,
        });
        SrpteHybrid { mode, slot: None, late, waiting: MinHeap::with_dense_index() }
    }

    pub fn ps() -> Self {
        Self::new(ShareMode::Ps)
    }

    pub fn las() -> Self {
        Self::new(ShareMode::Las)
    }

    /// Rebuild with a plain (unindexed) waiting heap — the opt-in
    /// escape hatch for sweep deployments with no kill path (see
    /// `PolicySpec::build_sweep`).  Only valid on a fresh instance.
    pub fn unindexed(self) -> Self {
        debug_assert_eq!(self.waiting.len(), 0, "unindexed() only on fresh instances");
        SrpteHybrid { waiting: MinHeap::new(), ..self }
    }

    fn pull_slot(&mut self) {
        if self.slot.is_none() {
            if let Some((est_rem, id, (true_rem, size))) = self.waiting.pop() {
                self.slot = Some(Elig { id: id as u32, est_rem, true_rem, size });
            }
        }
    }

    /// Sharing descriptor for one event step (rates sum to 1 when any
    /// job is eligible), precomputed once per call from O(1) late-set
    /// reads — no fold over the late members.
    fn rate_ctx(&self) -> RateCtx {
        let has_slot = self.slot.is_some();
        let n_elig = self.late.len() + usize::from(has_slot);
        if n_elig == 0 {
            return RateCtx { set_share: Share { rate: 0.0 }, slot_rate: 0.0 };
        }
        match self.mode {
            ShareMode::Ps => {
                // Equal split of the whole eligible pool (unit weights:
                // the per-weight lag rate IS the per-job rate).
                let share = 1.0 / n_elig as f64;
                RateCtx {
                    set_share: Share { rate: share },
                    slot_rate: if has_slot { share } else { 0.0 },
                }
            }
            ShareMode::Las => {
                // Equal split of the least-attained group among
                // eligible; the late side's group is the front level.
                let slot_att = self.slot.map(|s| s.attained());
                match (slot_att, self.late.front_attained()) {
                    (None, None) => unreachable!("n_elig > 0"),
                    (Some(_), None) => {
                        RateCtx { set_share: Share { rate: 0.0 }, slot_rate: 1.0 }
                    }
                    (None, Some(_)) => RateCtx {
                        set_share: Share { rate: 1.0 / self.late.served() as f64 },
                        slot_rate: 0.0,
                    },
                    (Some(sa), Some(fa)) => {
                        if sa < fa - EPS {
                            // Slot strictly least-attained: served alone.
                            RateCtx { set_share: Share { rate: 0.0 }, slot_rate: 1.0 }
                        } else if sa <= fa + EPS {
                            // Slot inside the front group.
                            let share = 1.0 / (self.late.served() + 1) as f64;
                            RateCtx { set_share: Share { rate: share }, slot_rate: share }
                        } else {
                            RateCtx {
                                set_share: Share { rate: 1.0 / self.late.served() as f64 },
                                slot_rate: 0.0,
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Precomputed sharing state for one event step.
#[derive(Debug, Clone, Copy)]
struct RateCtx {
    /// Per-member rate handed to the late set (0 when the set is not
    /// served, e.g. the slot is strictly least-attained in LAS mode).
    set_share: Share,
    /// Rate of the slot job (0 when idle or outside the LAS group).
    slot_rate: f64,
}

impl Scheduler for SrpteHybrid {
    fn name(&self) -> &'static str {
        match self.mode {
            ShareMode::Ps => "srpte+ps",
            ShareMode::Las => "srpte+las",
        }
    }

    fn on_arrival(&mut self, _now: f64, id: JobId, store: &JobStore) {
        let (est, size) = (store.est(id), store.size(id));
        let fresh = Elig { id, est_rem: est, true_rem: size, size };
        match self.slot {
            None => self.slot = Some(fresh),
            Some(cur) if est < cur.est_rem => {
                // The slot job is non-late by construction (it would
                // have moved to the late set otherwise), so preemption
                // is purely priority-based.
                self.waiting.push(cur.est_rem, cur.id as u64, (cur.true_rem, cur.size));
                self.slot = Some(fresh);
            }
            Some(_) => self.waiting.push(est, id as u64, (size, size)),
        }
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        let ctx = self.rate_ctx();
        let mut dt = f64::INFINITY;
        // Late-side completion / internal LAS regroup: O(1).
        if let Some(d) = self.late.next_event_dt(ctx.set_share) {
            dt = dt.min(d);
        }
        if let Some(s) = &self.slot {
            if ctx.slot_rate > 0.0 {
                // Completion, or the slot job going late (est hits 0).
                dt = dt.min(s.true_rem / ctx.slot_rate);
                if s.est_rem > 0.0 {
                    dt = dt.min(s.est_rem / ctx.slot_rate);
                }
            }
            // LAS regroup boundaries that involve the slot (the
            // set-internal one is part of `next_event_dt`): whichever
            // of the slot / the front group trails catches the other.
            if self.mode == ShareMode::Las {
                if let Some(fa) = self.late.front_attained() {
                    let sa = s.attained();
                    if ctx.set_share.rate <= 0.0 && ctx.slot_rate > 0.0 {
                        dt = dt.min((fa - sa).max(0.0) / ctx.slot_rate);
                    } else if ctx.slot_rate <= 0.0 && ctx.set_share.rate > 0.0 {
                        dt = dt.min((sa - fa).max(0.0) / ctx.set_share.rate);
                    }
                }
            }
        }
        if dt.is_finite() {
            Some(now + dt.max(0.0))
        } else {
            None
        }
    }

    fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
        let dt = t - now;
        let ctx = self.rate_ctx();
        // Late-side progress + completions (rates are step-start, as
        // the flat rate vectors had it: ctx is precomputed).
        self.late.advance(dt, ctx.set_share, t, done);
        if let Some(s) = self.slot.as_mut() {
            s.true_rem -= ctx.slot_rate * dt;
            s.est_rem -= ctx.slot_rate * dt;
        }
        // Slot: completion, or late transition.
        if let Some(s) = self.slot {
            if s.true_rem <= EPS {
                done.push(Completion { id: s.id, time: t });
                self.slot = None;
            } else if s.est_rem <= EPS {
                self.late.insert(s.id, 1.0, s.true_rem, s.size);
                self.slot = None;
            }
        }
        self.pull_slot();
    }

    fn active(&self) -> usize {
        self.late.len() + self.waiting.len() + usize::from(self.slot.is_some())
    }

    /// §5.2.2 kill bookkeeping: remove the job from whichever of the
    /// three homes holds it — the slot (the next-priority waiter takes
    /// over), the late set (O(log |L|)), or the waiting heap (O(log n)
    /// via the dense seq index).
    fn cancel(&mut self, _now: f64, id: u32) -> bool {
        if self.slot.map(|s| s.id) == Some(id) {
            self.slot = None;
            self.pull_slot();
            return true;
        }
        if self.late.cancel(id) {
            return true;
        }
        self.waiting.remove_by_seq(id as u64).is_some()
    }

    /// Native estimate re-key, bitwise-equal to cancel + re-admit (the
    /// trait default, pinned in `rust/tests/online_est.rs`).  Three
    /// homes, like [`SrpteHybrid::cancel`]:
    ///
    /// * **slot** — when the refreshed estimate still beats every
    ///   waiter the slot is re-keyed in place (heap untouched; the
    ///   default pays a pop + push of the best waiter, which leaves
    ///   the same entry multiset, and pop order depends only on the
    ///   `(key, seq)` multiset);
    /// * **late set** — the outward boundary crossing: a refreshed
    ///   (positive) estimate means the job is no longer virtually
    ///   complete, so it leaves `L` and re-enters on the non-late side
    ///   as a fresh arrival;
    /// * **waiting** — remove + re-admit re-keys the heap entry.
    ///
    /// In every home the job restarts with `true_rem = size` (attained
    /// service resets), exactly as cancel + re-admit defines.
    fn on_estimate_update(&mut self, now: f64, id: JobId, store: &JobStore) -> bool {
        if self.slot.map(|s| s.id) == Some(id) {
            let (est, size) = (store.est(id), store.size(id));
            match self.waiting.peek() {
                Some((wkey, _, _)) if est >= wkey => {
                    let (wkey, wid, (wtrue, wsize)) = self.waiting.pop().unwrap();
                    self.slot =
                        Some(Elig { id: wid as u32, est_rem: wkey, true_rem: wtrue, size: wsize });
                    self.waiting.push(est, id as u64, (size, size));
                }
                _ => self.slot = Some(Elig { id, est_rem: est, true_rem: size, size }),
            }
            return true;
        }
        if self.late.cancel(id) {
            self.on_arrival(now, id, store);
            return true;
        }
        if self.waiting.remove_by_seq(id as u64).is_some() {
            self.on_arrival(now, id, store);
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run, Job};

    /// §5.1's motivating example: a late job no longer blocks.
    #[test]
    fn ps_mode_shares_with_late_job() {
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 4.0, est: 1.0, weight: 1.0 },
            Job::exact(1, 2.0, 1.0),
        ];
        let r = run(&mut SrpteHybrid::ps(), &jobs);
        // From t=2: late J0 (rem 2) and J1 (rem 1) each at 1/2.
        // J1 done at 4; J0 rem 1 at t=4, alone -> done at 5.
        assert!((r.completion[1] - 4.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[0] - 5.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn las_mode_favors_fresh_small_job() {
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 4.0, est: 1.0, weight: 1.0 },
            Job::exact(1, 2.0, 1.0),
        ];
        let r = run(&mut SrpteHybrid::las(), &jobs);
        // At t=2 late J0 has attained 2, J1 attained 0: LAS serves J1
        // alone -> done at 3 (slowdown 1); J0 resumes -> done at 5.
        assert!((r.completion[1] - 3.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[0] - 5.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn equals_srpte_without_errors() {
        use crate::workload::dists::{Dist, Weibull};
        let mut rng = crate::util::rng::Rng::new(11);
        let w = Weibull::unit_mean(0.5);
        let mut t = 0.0;
        let jobs: Vec<Job> = (0..300)
            .map(|i| {
                t += rng.u01();
                Job::exact(i, t, w.sample(&mut rng).max(1e-6))
            })
            .collect();
        let a = run(&mut SrpteHybrid::ps(), &jobs).completion;
        let b = run(&mut super::super::srpt::Srpte::new(), &jobs).completion;
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6, "SRPTE+PS must equal SRPTE with exact sizes");
        }
        let c = run(&mut SrpteHybrid::las(), &jobs).completion;
        for (x, y) in c.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6, "SRPTE+LAS must equal SRPTE with exact sizes");
        }
    }

    #[test]
    fn multiple_late_jobs_share() {
        // Two under-estimated jobs both go late; they then share.
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 3.0, est: 1.0, weight: 1.0 },
            Job { id: 1, arrival: 0.0, size: 3.0, est: 2.0, weight: 1.0 },
        ];
        let r = run(&mut SrpteHybrid::ps(), &jobs);
        // J0 served first (est 1 < 2), late at t=1; then J0(late) + J1
        // (slot) share. J1 goes late after serving 2 => t=5. Then both
        // late, sharing; J0 rem = 3-1-2=0 at t=5... step through:
        // [0,1): J0 alone, att 1, late. [1,?): J0,J1 at 1/2.
        // J1 est 2 -> late after 2 att => t=5. J0 att 1+2=3 => done t=5.
        // J1 rem 1, alone -> done t=6.
        assert!((r.completion[0] - 5.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[1] - 6.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn work_conserving_random() {
        use crate::workload::dists::{Dist, LogNormal, Weibull};
        let mut rng = crate::util::rng::Rng::new(23);
        let w = Weibull::unit_mean(0.25);
        let e = LogNormal::error_model(2.0);
        let mut t = 0.0;
        let jobs: Vec<Job> = (0..200)
            .map(|i| {
                t += rng.u01() * 0.3;
                let size = w.sample(&mut rng).max(1e-6);
                Job { id: i, arrival: t, size, est: size * e.sample(&mut rng), weight: 1.0 }
            })
            .collect();
        for mut s in [SrpteHybrid::ps(), SrpteHybrid::las()] {
            let r = run(&mut s, &jobs);
            assert!(r.completion.iter().all(|c| c.is_finite()));
        }
    }

    /// Kill coverage for all three homes a job can be in: the slot,
    /// the late set, and the waiting heap.
    #[test]
    fn cancel_from_every_home() {
        for mk in [SrpteHybrid::ps, SrpteHybrid::las] {
            let mut s = mk();
            let mut st = crate::sim::JobStore::new();
            // J0 underestimated -> will go late; J1 next priority;
            // J2 parks in waiting.
            st.deliver(&mut s, 0.0, &Job { id: 0, arrival: 0.0, size: 5.0, est: 1.0, weight: 1.0 });
            st.deliver(&mut s, 0.0, &Job { id: 1, arrival: 0.0, size: 3.0, est: 3.0, weight: 1.0 });
            st.deliver(&mut s, 0.0, &Job { id: 2, arrival: 0.0, size: 4.0, est: 4.0, weight: 1.0 });
            let mut done = Vec::new();
            s.advance(0.0, 1.5, &st, &mut done);
            assert!(done.is_empty(), "{}", s.name());
            assert_eq!(s.late.len(), 1, "{}: J0 must be late", s.name());
            assert!(s.cancel(0.0, 0), "{}: late kill", s.name());
            assert!(s.cancel(0.0, 2), "{}: waiting kill", s.name());
            assert!(s.cancel(0.0, 1), "{}: slot kill", s.name());
            assert!(!s.cancel(0.0, 1), "{}: double kill", s.name());
            assert_eq!(s.active(), 0, "{}", s.name());
        }
    }
}
