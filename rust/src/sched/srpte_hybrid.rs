//! SRPTE+PS and SRPTE+LAS — the paper's §5.1 amendments to SRPTE.
//!
//! As long as no job is late these behave exactly like SRPTE.  When
//! late jobs exist, the *eligible set* is **all late jobs plus the
//! highest-priority non-late job** (jobs go late only while served, so
//! non-late jobs must keep getting a chance — unlike the FSPE variants
//! which schedule late jobs only).  Eligible jobs share the server:
//!
//! * `SrpteHybrid::ps()`  — PS among eligible jobs;
//! * `SrpteHybrid::las()` — LAS among eligible jobs (equal split of the
//!   least-attained group).
//!
//! The late set is small in practice (§7.2), so per-event O(|L|) scans
//! are the right trade-off versus maintaining more heaps.

use super::MinHeap;
use crate::sim::{Completion, Job, Scheduler};
use crate::util::EPS;

/// How eligible jobs share the server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShareMode {
    Ps,
    Las,
}

#[derive(Debug, Clone, Copy)]
struct Elig {
    id: u32,
    est_rem: f64,
    true_rem: f64,
    /// Original size (attained = size - true_rem, for LAS mode).
    size: f64,
}

impl Elig {
    fn attained(&self) -> f64 {
        self.size - self.true_rem
    }
}

/// SRPTE with PS/LAS among late jobs + the best non-late job.
#[derive(Debug)]
pub struct SrpteHybrid {
    mode: ShareMode,
    /// The non-late eligible job (highest SRPTE priority).
    slot: Option<Elig>,
    /// Late jobs (est_rem <= 0); unordered, scanned per event.
    late: Vec<Elig>,
    /// Non-late, non-eligible jobs keyed by estimated remainder
    /// (static while waiting). Payload: (true_rem, size).
    waiting: MinHeap<(f64, f64)>,
}

impl SrpteHybrid {
    pub fn new(mode: ShareMode) -> Self {
        SrpteHybrid { mode, slot: None, late: Vec::new(), waiting: MinHeap::new() }
    }

    pub fn ps() -> Self {
        Self::new(ShareMode::Ps)
    }

    pub fn las() -> Self {
        Self::new(ShareMode::Las)
    }

    fn pull_slot(&mut self) {
        if self.slot.is_none() {
            if let Some((est_rem, id, (true_rem, size))) = self.waiting.pop() {
                self.slot = Some(Elig { id: id as u32, est_rem, true_rem, size });
            }
        }
    }

    /// Sharing descriptor for one event step (rates sum to 1 when any
    /// job is eligible), precomputed once per call.  Allocation-free
    /// replacement for the former per-call rate `Vec`s: `next_event`
    /// and `advance` run once per simulator event, so those fresh
    /// allocations dominated the per-event profile.
    fn rate_ctx(&self) -> RateCtx {
        let n_elig = self.late.len() + usize::from(self.slot.is_some());
        if n_elig == 0 {
            return RateCtx { share: 0.0, min_att: f64::INFINITY, k: 0, slot_rate: 0.0 };
        }
        match self.mode {
            ShareMode::Ps => {
                let share = 1.0 / n_elig as f64;
                RateCtx {
                    share,
                    // +inf ceiling: every eligible job is in the group.
                    min_att: f64::INFINITY,
                    k: n_elig,
                    slot_rate: if self.slot.is_some() { share } else { 0.0 },
                }
            }
            ShareMode::Las => {
                // Equal split of the least-attained group among eligible.
                let slot_att = self.slot.map(|s| s.attained());
                let min_att = self
                    .late
                    .iter()
                    .map(|e| e.attained())
                    .chain(slot_att)
                    .fold(f64::INFINITY, f64::min);
                let in_group = |a: f64| a <= min_att + EPS;
                let k = self.late.iter().filter(|e| in_group(e.attained())).count()
                    + usize::from(slot_att.map_or(false, in_group));
                let share = 1.0 / k as f64;
                RateCtx {
                    share,
                    min_att,
                    k,
                    slot_rate: if slot_att.map_or(false, in_group) { share } else { 0.0 },
                }
            }
        }
    }
}

/// Precomputed sharing state for one event step.
#[derive(Debug, Clone, Copy)]
struct RateCtx {
    /// Per-served-job rate (1/k).
    share: f64,
    /// Attained-service ceiling of the served group: a late job with
    /// `attained <= min_att + EPS` is served.  `+inf` in PS mode
    /// (everyone served); the LAS front-group minimum otherwise.
    min_att: f64,
    /// Served-group size.
    k: usize,
    /// Rate of the slot job (0 when idle or outside the LAS group).
    slot_rate: f64,
}

/// Rate of a late job with the given attained service.
#[inline]
fn late_rate(ctx: RateCtx, attained: f64) -> f64 {
    if attained <= ctx.min_att + EPS {
        ctx.share
    } else {
        0.0
    }
}

impl Scheduler for SrpteHybrid {
    fn name(&self) -> &'static str {
        match self.mode {
            ShareMode::Ps => "srpte+ps",
            ShareMode::Las => "srpte+las",
        }
    }

    fn on_arrival(&mut self, _now: f64, job: &Job) {
        let fresh = Elig { id: job.id, est_rem: job.est, true_rem: job.size, size: job.size };
        match self.slot {
            None => self.slot = Some(fresh),
            Some(cur) if job.est < cur.est_rem => {
                // The slot job is non-late by construction (it would
                // have moved to `late` otherwise), so preemption is
                // purely priority-based.
                self.waiting.push(cur.est_rem, cur.id as u64, (cur.true_rem, cur.size));
                self.slot = Some(fresh);
            }
            Some(_) => self.waiting.push(job.est, job.id as u64, (job.size, job.size)),
        }
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        let ctx = self.rate_ctx();
        let mut dt = f64::INFINITY;
        for e in &self.late {
            let r = late_rate(ctx, e.attained());
            if r > 0.0 {
                dt = dt.min(e.true_rem / r);
            }
        }
        if let Some(s) = &self.slot {
            if ctx.slot_rate > 0.0 {
                // Completion, or the slot job going late (est hits 0).
                dt = dt.min(s.true_rem / ctx.slot_rate);
                if s.est_rem > 0.0 {
                    dt = dt.min(s.est_rem / ctx.slot_rate);
                }
            }
        }
        if self.mode == ShareMode::Las && ctx.k > 0 {
            // Regroup: the served group catches the next attained
            // level.  The group's minimum attained service is exactly
            // `ctx.min_att` (the group is defined as everything within
            // EPS of it).
            let next_att = self
                .late
                .iter()
                .map(|e| e.attained())
                .chain(self.slot.map(|s| s.attained()))
                .filter(|a| *a > ctx.min_att + EPS)
                .fold(f64::INFINITY, f64::min);
            if next_att.is_finite() {
                dt = dt.min((next_att - ctx.min_att) * ctx.k as f64);
            }
        }
        if dt.is_finite() {
            Some(now + dt.max(0.0))
        } else {
            None
        }
    }

    fn advance(&mut self, now: f64, t: f64, done: &mut Vec<Completion>) {
        let dt = t - now;
        let ctx = self.rate_ctx();
        for e in self.late.iter_mut() {
            // `attained()` is read before the update, so the rate is
            // the step-start rate (as the old rate vectors had it).
            let r = late_rate(ctx, e.attained());
            e.true_rem -= r * dt;
            e.est_rem -= r * dt;
        }
        if let Some(s) = self.slot.as_mut() {
            s.true_rem -= ctx.slot_rate * dt;
            s.est_rem -= ctx.slot_rate * dt;
        }

        // Completions among late jobs.
        let mut i = 0;
        while i < self.late.len() {
            if self.late[i].true_rem <= EPS {
                let e = self.late.swap_remove(i);
                done.push(Completion { id: e.id, time: t });
            } else {
                i += 1;
            }
        }
        // Slot: completion, or late transition.
        if let Some(s) = self.slot {
            if s.true_rem <= EPS {
                done.push(Completion { id: s.id, time: t });
                self.slot = None;
            } else if s.est_rem <= EPS {
                self.late.push(s);
                self.slot = None;
            }
        }
        self.pull_slot();
    }

    fn active(&self) -> usize {
        self.late.len() + self.waiting.len() + usize::from(self.slot.is_some())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::run;

    /// §5.1's motivating example: a late job no longer blocks.
    #[test]
    fn ps_mode_shares_with_late_job() {
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 4.0, est: 1.0, weight: 1.0 },
            Job::exact(1, 2.0, 1.0),
        ];
        let r = run(&mut SrpteHybrid::ps(), &jobs);
        // From t=2: late J0 (rem 2) and J1 (rem 1) each at 1/2.
        // J1 done at 4; J0 rem 1 at t=4, alone -> done at 5.
        assert!((r.completion[1] - 4.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[0] - 5.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn las_mode_favors_fresh_small_job() {
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 4.0, est: 1.0, weight: 1.0 },
            Job::exact(1, 2.0, 1.0),
        ];
        let r = run(&mut SrpteHybrid::las(), &jobs);
        // At t=2 late J0 has attained 2, J1 attained 0: LAS serves J1
        // alone -> done at 3 (slowdown 1); J0 resumes -> done at 5.
        assert!((r.completion[1] - 3.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[0] - 5.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn equals_srpte_without_errors() {
        use crate::workload::dists::{Dist, Weibull};
        let mut rng = crate::util::rng::Rng::new(11);
        let w = Weibull::unit_mean(0.5);
        let mut t = 0.0;
        let jobs: Vec<Job> = (0..300)
            .map(|i| {
                t += rng.u01();
                Job::exact(i, t, w.sample(&mut rng).max(1e-6))
            })
            .collect();
        let a = run(&mut SrpteHybrid::ps(), &jobs).completion;
        let b = run(&mut super::super::srpt::Srpte::new(), &jobs).completion;
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6, "SRPTE+PS must equal SRPTE with exact sizes");
        }
        let c = run(&mut SrpteHybrid::las(), &jobs).completion;
        for (x, y) in c.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6, "SRPTE+LAS must equal SRPTE with exact sizes");
        }
    }

    #[test]
    fn multiple_late_jobs_share() {
        // Two under-estimated jobs both go late; they then share.
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 3.0, est: 1.0, weight: 1.0 },
            Job { id: 1, arrival: 0.0, size: 3.0, est: 2.0, weight: 1.0 },
        ];
        let r = run(&mut SrpteHybrid::ps(), &jobs);
        // J0 served first (est 1 < 2), late at t=1; then J0(late) + J1
        // (slot) share. J1 goes late after serving 2 => t=5. Then both
        // late, sharing; J0 rem = 3-1-2=0 at t=5... step through:
        // [0,1): J0 alone, att 1, late. [1,?): J0,J1 at 1/2.
        // J1 est 2 -> late after 2 att => t=5. J0 att 1+2=3 => done t=5.
        // J1 rem 1, alone -> done t=6.
        assert!((r.completion[0] - 5.0).abs() < 1e-9, "{:?}", r.completion);
        assert!((r.completion[1] - 6.0).abs() < 1e-9, "{:?}", r.completion);
    }

    #[test]
    fn work_conserving_random() {
        use crate::workload::dists::{Dist, LogNormal, Weibull};
        let mut rng = crate::util::rng::Rng::new(23);
        let w = Weibull::unit_mean(0.25);
        let e = LogNormal::error_model(2.0);
        let mut t = 0.0;
        let jobs: Vec<Job> = (0..200)
            .map(|i| {
                t += rng.u01() * 0.3;
                let size = w.sample(&mut rng).max(1e-6);
                Job { id: i, arrival: t, size, est: size * e.sample(&mut rng), weight: 1.0 }
            })
            .collect();
        for mut s in [SrpteHybrid::ps(), SrpteHybrid::las()] {
            let r = run(&mut s, &jobs);
            assert!(r.completion.iter().all(|c| c.is_finite()));
        }
    }
}
