//! FIFO — jobs run to completion in arrival order (§6.1).
//!
//! The paper uses FIFO both as the Hadoop-default baseline and as the
//! limit case of a size-based scheduler whose estimates carry no
//! information (§7.3).

use crate::sim::{Completion, JobId, JobStore, Scheduler};
use crate::util::EPS;
use std::collections::VecDeque;

/// First-in-first-out, non-preemptive, serial service at rate 1.
#[derive(Debug, Default)]
pub struct Fifo {
    /// (id, remaining); front is being served.
    queue: VecDeque<(u32, f64)>,
}

impl Fifo {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn on_arrival(&mut self, _now: f64, id: JobId, store: &JobStore) {
        self.queue.push_back((id, store.size(id)));
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        self.queue.front().map(|&(_, rem)| now + rem)
    }

    fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
        let mut budget = t - now;
        while let Some((id, rem)) = self.queue.front_mut() {
            if *rem <= budget + EPS {
                budget -= *rem;
                let finished_at = t - budget.max(0.0);
                let id = *id;
                self.queue.pop_front();
                done.push(Completion { id, time: finished_at });
            } else {
                *rem -= budget;
                break;
            }
        }
    }

    fn active(&self) -> usize {
        self.queue.len()
    }

    /// §5.2.2 kill bookkeeping: drop the job from the queue (killing
    /// the served front simply starts the next job; later jobs keep
    /// their order).  O(n) scan — FIFO keeps no per-id index and kills
    /// are cold.
    fn cancel(&mut self, _now: f64, id: u32) -> bool {
        match self.queue.iter().position(|&(i, _)| i == id) {
            Some(pos) => {
                self.queue.remove(pos);
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{run, Job};

    #[test]
    fn serial_in_arrival_order() {
        let jobs = vec![
            Job::exact(0, 0.0, 2.0),
            Job::exact(1, 0.5, 1.0), // smaller but must wait
            Job::exact(2, 0.5, 0.1),
        ];
        let r = run(&mut Fifo::new(), &jobs);
        assert_eq!(r.completion, vec![2.0, 3.0, 3.1]);
    }

    #[test]
    fn estimates_are_ignored() {
        let jobs = vec![
            Job { id: 0, arrival: 0.0, size: 2.0, est: 100.0, weight: 1.0 },
            Job { id: 1, arrival: 0.0, size: 1.0, est: 0.01, weight: 1.0 },
        ];
        let r = run(&mut Fifo::new(), &jobs);
        assert_eq!(r.completion, vec![2.0, 3.0]);
    }

    #[test]
    fn idle_period_resets_service() {
        let jobs = vec![Job::exact(0, 0.0, 1.0), Job::exact(1, 5.0, 1.0)];
        let r = run(&mut Fifo::new(), &jobs);
        assert_eq!(r.completion, vec![1.0, 6.0]);
    }

    /// Killing the served head promotes the next job immediately.
    #[test]
    fn cancel_head_and_waiter() {
        let mut s = Fifo::new();
        let mut st = crate::sim::JobStore::new();
        let mut done = Vec::new();
        st.deliver(&mut s, 0.0, &Job::exact(0, 0.0, 5.0));
        st.deliver(&mut s, 0.0, &Job::exact(1, 0.0, 1.0));
        st.deliver(&mut s, 0.0, &Job::exact(2, 0.0, 1.0));
        s.advance(0.0, 2.0, &st, &mut done); // head J0 has 3 left
        assert!(s.cancel(2.0, 0), "kill the served head");
        assert!(s.cancel(2.0, 2), "kill a waiter");
        assert!(!s.cancel(2.0, 0), "double kill must fail");
        // J1 is now the head with its full size: done at 3.
        let ev = s.next_event(2.0).unwrap();
        assert!((ev - 3.0).abs() < 1e-9, "promoted head event at {ev}");
        s.advance(2.0, ev, &st, &mut done);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(s.active(), 0);
    }
}
