//! Binary trace cache (`.psbt`): fixed-width little-endian records for
//! fast re-replay of large traces — reading floats back beats
//! re-parsing decimal CSV by an order of magnitude (tracked by the
//! `trace_cache_speedup` derived bench key).
//!
//! ## Layout
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"PSBT"
//! 4       4     version (u32 LE, currently 1)
//! 8       8     record count (u64 LE)
//! 16      8     checksum (u64 LE, splitmix64 chain over all record words)
//! 24      32*n  records: arrival, size, weight, estimate (f64 LE each;
//!               estimate is NaN when the trace carries none)
//! ```
//!
//! [`CacheReader::open`] verifies magic, version, length (header count
//! vs file size — truncation is a hard error, not a short replay) and
//! the checksum (one streaming pass) before the first row is served;
//! every failure mode is a distinct error.  Semantic validity
//! (ordered arrivals, positive sizes/weights/estimates) is enforced at
//! write time by [`CacheWriter::push`] with the same wording as the
//! CSV parser, and cheaply re-checked per record on read so a file
//! that checksums but was written by a buggy tool still fails hard.

use super::trace_file::{RowStream, TraceRow};
use crate::error::Error;
use crate::util::rng::splitmix64;
use std::io::{Read, Seek, SeekFrom, Write};

const MAGIC: [u8; 4] = *b"PSBT";
const VERSION: u32 = 1;
const HEADER_LEN: u64 = 24;
const RECORD_LEN: u64 = 32;

/// splitmix64-chained checksum over 64-bit words: order-sensitive,
/// avalanching, dependency-free.
#[derive(Debug, Clone, Copy)]
struct Checksum(u64);

impl Checksum {
    fn new() -> Self {
        // Arbitrary non-zero start so an empty stream doesn't hash to 0.
        Checksum(0x5053_4254) // "PSBT"
    }
    #[inline]
    fn fold(&mut self, word: u64) {
        let mut s = self.0 ^ word;
        self.0 = splitmix64(&mut s);
    }
    fn fold_row(&mut self, r: &TraceRow) {
        self.fold(r.arrival.to_bits());
        self.fold(r.size.to_bits());
        self.fold(r.weight.to_bits());
        self.fold(r.est.unwrap_or(f64::NAN).to_bits());
    }
    fn value(&self) -> u64 {
        self.0
    }
}

/// Incremental `.psbt` writer: records stream straight to disk (a
/// million-row cache never materializes), count and checksum are
/// patched into the header by [`CacheWriter::finish`].
pub struct CacheWriter {
    file: std::io::BufWriter<std::fs::File>,
    path: String,
    count: u64,
    sum: Checksum,
    prev_arrival: f64,
}

impl CacheWriter {
    pub fn create(path: &str) -> Result<CacheWriter, Error> {
        let mut file = std::fs::File::create(path)
            .map_err(|e| Error::cache(format!("writing trace cache {path}: {e}")))
            .map(std::io::BufWriter::new)?;
        // Placeholder header; finish() rewrites count + checksum.
        file.write_all(&MAGIC)
            .and_then(|_| file.write_all(&VERSION.to_le_bytes()))
            .and_then(|_| file.write_all(&0u64.to_le_bytes()))
            .and_then(|_| file.write_all(&0u64.to_le_bytes()))
            .map_err(|e| Error::cache(format!("writing trace cache {path}: {e}")))?;
        Ok(CacheWriter {
            file,
            path: path.to_string(),
            count: 0,
            sum: Checksum::new(),
            prev_arrival: f64::NEG_INFINITY,
        })
    }

    /// Append one record; rejects rows the CSV parser would reject
    /// (record numbers are 1-based, mirroring its line numbers).
    pub fn push(&mut self, r: &TraceRow) -> Result<(), Error> {
        let n = self.count + 1;
        if !r.arrival.is_finite() || r.arrival < 0.0 {
            return Err(Error::cache(format!(
                "record {n}: arrival must be non-negative, got {}",
                r.arrival
            )));
        }
        if r.arrival < self.prev_arrival {
            return Err(Error::cache(format!(
                "record {n}: arrivals must be non-decreasing ({} after {})",
                r.arrival, self.prev_arrival
            )));
        }
        if !r.size.is_finite() || r.size <= 0.0 {
            return Err(Error::cache(format!(
                "record {n}: job size must be positive, got {}",
                r.size
            )));
        }
        if !r.weight.is_finite() || r.weight <= 0.0 {
            return Err(Error::cache(format!(
                "record {n}: weight must be positive, got {}",
                r.weight
            )));
        }
        if let Some(e) = r.est {
            if !e.is_finite() || e <= 0.0 {
                return Err(Error::cache(format!(
                    "record {n}: size estimate must be positive, got {e}"
                )));
            }
        }
        self.prev_arrival = r.arrival;
        let mut buf = [0u8; RECORD_LEN as usize];
        buf[0..8].copy_from_slice(&r.arrival.to_le_bytes());
        buf[8..16].copy_from_slice(&r.size.to_le_bytes());
        buf[16..24].copy_from_slice(&r.weight.to_le_bytes());
        buf[24..32].copy_from_slice(&r.est.unwrap_or(f64::NAN).to_le_bytes());
        self.file
            .write_all(&buf)
            .map_err(|e| Error::cache(format!("writing trace cache {}: {e}", self.path)))?;
        self.sum.fold_row(r);
        self.count += 1;
        Ok(())
    }

    /// Patch the header (count + checksum) and flush.  Returns the
    /// record count.  An empty cache is an error — it could never be
    /// replayed.
    pub fn finish(mut self) -> Result<u64, Error> {
        if self.count == 0 {
            return Err(Error::cache(format!("trace cache {}: no records written", self.path)));
        }
        let err = |e| Error::cache(format!("writing trace cache {}: {e}", self.path));
        self.file.flush().map_err(err)?;
        let mut inner = self.file.into_inner().map_err(|e| {
            Error::cache(format!("writing trace cache {}: {e}", self.path))
        })?;
        inner.seek(SeekFrom::Start(8)).map_err(err)?;
        inner.write_all(&self.count.to_le_bytes()).map_err(err)?;
        inner.write_all(&self.sum.value().to_le_bytes()).map_err(err)?;
        inner.sync_data().ok();
        Ok(self.count)
    }
}

/// Write an entire row stream into a cache file; returns the count.
pub fn write_cache<I>(path: &str, rows: I) -> Result<u64, Error>
where
    I: IntoIterator<Item = TraceRow>,
{
    let mut w = CacheWriter::create(path)?;
    for r in rows {
        w.push(&r)?;
    }
    w.finish()
}

/// Validated streaming `.psbt` reader — a [`RowStream`], so it plugs
/// into [`crate::workload::trace_file::TraceJobSource`] exactly like
/// the chunked CSV reader.
pub struct CacheReader {
    file: std::io::BufReader<std::fs::File>,
    path: String,
    count: u64,
    read: u64,
    prev_arrival: f64,
}

impl CacheReader {
    /// Open and fully verify a cache: magic, version, length and
    /// checksum are all checked *before* the first row is served, each
    /// with its own distinct hard error.
    pub fn open(path: &str) -> Result<CacheReader, Error> {
        let file = std::fs::File::open(path)
            .map_err(|e| Error::cache(format!("reading trace cache {path}: {e}")))?;
        let actual_len = file
            .metadata()
            .map_err(|e| Error::cache(format!("reading trace cache {path}: {e}")))?
            .len();
        let mut file = std::io::BufReader::with_capacity(64 * 1024, file);
        let err = |e| Error::cache(format!("reading trace cache {path}: {e}"));
        let mut header = [0u8; HEADER_LEN as usize];
        if actual_len < HEADER_LEN {
            return Err(Error::cache_at(
                path,
                format!(
                    "truncated trace cache: {actual_len} bytes is shorter than the \
                     {HEADER_LEN}-byte header"
                ),
            ));
        }
        file.read_exact(&mut header).map_err(err)?;
        if header[0..4] != MAGIC {
            return Err(Error::cache_at(path, "not a PSBT trace cache (bad magic)"));
        }
        let version = u32::from_le_bytes(header[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(Error::cache_at(
                path,
                format!("unsupported trace cache version {version} (expected {VERSION})"),
            ));
        }
        let count = u64::from_le_bytes(header[8..16].try_into().unwrap());
        if count == 0 {
            return Err(Error::cache_at(path, "trace cache has no records"));
        }
        let want_sum = u64::from_le_bytes(header[16..24].try_into().unwrap());
        let expect_len = HEADER_LEN + count * RECORD_LEN;
        if actual_len != expect_len {
            return Err(Error::cache_at(
                path,
                format!(
                    "truncated trace cache: header says {count} records \
                     ({expect_len} bytes), file has {actual_len} bytes"
                ),
            ));
        }
        // Checksum pass over every record word, then rewind.
        let mut sum = Checksum::new();
        let mut word = [0u8; 8];
        for _ in 0..count * 4 {
            file.read_exact(&mut word).map_err(err)?;
            sum.fold(u64::from_le_bytes(word));
        }
        if sum.value() != want_sum {
            return Err(Error::cache_at(path, "trace cache checksum mismatch (file corrupt)"));
        }
        file.seek(SeekFrom::Start(HEADER_LEN)).map_err(err)?;
        Ok(CacheReader {
            file,
            path: path.to_string(),
            count,
            read: 0,
            prev_arrival: f64::NEG_INFINITY,
        })
    }

    /// Records the header promises.
    pub fn len(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }
}

impl RowStream for CacheReader {
    fn next_row(&mut self) -> Result<Option<TraceRow>, Error> {
        if self.read >= self.count {
            return Ok(None);
        }
        let mut buf = [0u8; RECORD_LEN as usize];
        self.file
            .read_exact(&mut buf)
            .map_err(|e| Error::cache(format!("reading trace cache {}: {e}", self.path)))?;
        let n = self.read + 1;
        let f = |i: usize| f64::from_le_bytes(buf[i * 8..i * 8 + 8].try_into().unwrap());
        let (arrival, size, weight, est_raw) = (f(0), f(1), f(2), f(3));
        // The writer refuses these, so a record failing here was
        // produced by something else — fail as hard as the CSV path.
        if !arrival.is_finite() || arrival < 0.0 {
            return Err(Error::cache_at(
                &self.path,
                format!("record {n}: arrival must be non-negative, got {arrival}"),
            ));
        }
        if arrival < self.prev_arrival {
            return Err(Error::cache_at(
                &self.path,
                format!(
                    "record {n}: arrivals must be non-decreasing ({arrival} after {})",
                    self.prev_arrival
                ),
            ));
        }
        if !size.is_finite() || size <= 0.0 {
            return Err(Error::cache_at(
                &self.path,
                format!("record {n}: job size must be positive, got {size}"),
            ));
        }
        if !weight.is_finite() || weight <= 0.0 {
            return Err(Error::cache_at(
                &self.path,
                format!("record {n}: weight must be positive, got {weight}"),
            ));
        }
        let est = if est_raw.is_nan() { None } else { Some(est_raw) };
        if let Some(e) = est {
            if !e.is_finite() || e <= 0.0 {
                return Err(Error::cache_at(
                    &self.path,
                    format!("record {n}: size estimate must be positive, got {e}"),
                ));
            }
        }
        self.prev_arrival = arrival;
        self.read = n;
        Ok(Some(TraceRow { arrival, size, weight, est }))
    }

    fn rewind(&mut self) -> Result<(), Error> {
        self.file
            .seek(SeekFrom::Start(HEADER_LEN))
            .map_err(|e| Error::cache(format!("reading trace cache {}: {e}", self.path)))?;
        self.read = 0;
        self.prev_arrival = f64::NEG_INFINITY;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace_file::parse;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("psbs_cache_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn read_all(path: &str) -> Vec<TraceRow> {
        let mut r = CacheReader::open(path).unwrap();
        let mut out = Vec::new();
        while let Some(row) = r.next_row().unwrap() {
            out.push(row);
        }
        out
    }

    #[test]
    fn round_trips_rows_exactly() {
        let rows = parse("arrival,size,weight,estimate\n0,10,1,12\n1.5,20,2,15\n").unwrap();
        let path = tmp("rt.psbt");
        let n = write_cache(path.to_str().unwrap(), rows.iter().copied()).unwrap();
        assert_eq!(n, 2);
        assert_eq!(read_all(path.to_str().unwrap()), rows);
        // Absent estimates survive the NaN encoding.
        let rows = parse("0,10\n3,20\n").unwrap();
        let path = tmp("rt2.psbt");
        write_cache(path.to_str().unwrap(), rows.iter().copied()).unwrap();
        let back = read_all(path.to_str().unwrap());
        assert_eq!(back, rows);
        assert_eq!(back[0].est, None);
    }

    #[test]
    fn rewind_restarts_the_stream() {
        let rows = parse("0,1\n1,2\n2,3\n").unwrap();
        let path = tmp("rw.psbt");
        write_cache(path.to_str().unwrap(), rows.iter().copied()).unwrap();
        let mut r = CacheReader::open(path.to_str().unwrap()).unwrap();
        assert_eq!(r.len(), 3);
        assert_eq!(r.next_row().unwrap(), Some(rows[0]));
        assert_eq!(r.next_row().unwrap(), Some(rows[1]));
        r.rewind().unwrap();
        assert_eq!(r.next_row().unwrap(), Some(rows[0]));
    }

    #[test]
    fn corruption_failure_modes_are_distinct_hard_errors() {
        let rows = parse("0,1\n1,2\n2,3\n").unwrap();
        let path = tmp("bad.psbt");
        let p = path.to_str().unwrap();
        write_cache(p, rows.iter().copied()).unwrap();
        let good = std::fs::read(&path).unwrap();

        // Bad magic.
        let mut bytes = good.clone();
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        assert!(CacheReader::open(p).unwrap_err().to_string().contains("bad magic"));

        // Unsupported version.
        let mut bytes = good.clone();
        bytes[4] = 9;
        std::fs::write(&path, &bytes).unwrap();
        assert!(CacheReader::open(p)
            .unwrap_err()
            .to_string()
            .contains("unsupported trace cache version"));

        // Truncated mid-record.
        std::fs::write(&path, &good[..good.len() - 7]).unwrap();
        assert!(CacheReader::open(p).unwrap_err().to_string().contains("truncated trace cache"));

        // Shorter than the header.
        std::fs::write(&path, &good[..10]).unwrap();
        assert!(CacheReader::open(p).unwrap_err().to_string().contains("shorter than the"));

        // A flipped payload byte fails the checksum.
        let mut bytes = good.clone();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(CacheReader::open(p).unwrap_err().to_string().contains("checksum mismatch"));

        // Missing file.
        assert!(CacheReader::open("/nonexistent/x.psbt")
            .unwrap_err()
            .to_string()
            .contains("reading trace cache"));
    }

    #[test]
    fn writer_rejects_invalid_rows_and_empty_caches() {
        let path = tmp("rej.psbt");
        let p = path.to_str().unwrap();
        let mut w = CacheWriter::create(p).unwrap();
        let bad = TraceRow { arrival: 1.0, size: -2.0, weight: 1.0, est: None };
        assert!(w.push(&bad).unwrap_err().to_string().contains("job size must be positive"));
        let ok = TraceRow { arrival: 1.0, size: 2.0, weight: 1.0, est: None };
        w.push(&ok).unwrap();
        let regress = TraceRow { arrival: 0.5, size: 2.0, weight: 1.0, est: None };
        assert!(w.push(&regress).unwrap_err().to_string().contains("non-decreasing"));
        assert_eq!(w.finish().unwrap(), 1);

        let empty = CacheWriter::create(p).unwrap();
        assert!(empty.finish().unwrap_err().to_string().contains("no records written"));
    }
}
