//! On-disk trace ingestion: a dependency-free CSV-like trace format so
//! `kind = "trace"` scenarios can replay *user-supplied* workloads, not
//! just the two published stand-ins (ROADMAP "scenario files for
//! arbitrary on-disk traces").
//!
//! ## Format
//!
//! One job per line, comma-separated:
//!
//! ```text
//! arrival,size[,weight][,estimate]
//! ```
//!
//! * `arrival` — submission time, non-negative, non-decreasing down the
//!   file (the simulator requires arrival-sorted workloads — a shuffled
//!   trace is a hard error, not something to silently re-sort, because
//!   row order is how trace tools express causality);
//! * `size` — job size in any consistent unit (bytes, seconds, ...);
//!   must be positive.  Sizes are re-expressed in seconds of service by
//!   the load normalization below, so the unit cancels;
//! * `weight` — optional per-job weight (default 1), must be positive;
//! * `estimate` — optional a-priori size estimate in the same unit as
//!   `size`, must be positive.  Only honored at `sigma = 0`; any
//!   `sigma > 0` *re-estimates* (see [`TraceFile::to_jobs`]).
//!
//! Blank lines and `#` comments are skipped.  An optional header line
//! (`arrival,size`, `arrival,size,weight` or
//! `arrival,size,weight,estimate`) both documents and *enforces* the
//! column count; without one, the first data row fixes it.  Everything
//! else — ragged rows, non-numeric fields, negative sizes, non-monotone
//! arrivals — is a hard error carrying the offending line number: a
//! half-ingested trace must never silently become an experiment.
//!
//! ## Normalization
//!
//! [`TraceFile::to_jobs`] applies the same three knobs
//! [`crate::scenario::TraceSpec`] already applies to the built-in
//! stand-ins: an `njobs` cap (replay a prefix), the paper's §7.8
//! offered-load rescaling (pick the service speed so the replayed
//! prefix offers exactly `load`), and log-normal size-error
//! re-estimation with parameter `sigma` (seeded per repetition, exactly
//! like [`crate::workload::traces::to_jobs`]).

use super::dists::{Dist, LogNormal};
use super::synthetic::MIN_SIZE;
use crate::error::Error;
use crate::sim::{job, Job, JobSource};
use crate::util::rng::Rng;
use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// One parsed trace row, in file units.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRow {
    pub arrival: f64,
    pub size: f64,
    pub weight: f64,
    /// A-priori size estimate in file units (None: none recorded).
    pub est: Option<f64>,
}

/// A loaded on-disk trace: the path as written (scenario files render
/// it back verbatim) plus the parsed rows, shared so cloning a
/// [`crate::scenario::WorkloadSpec`] across planner groups and axis
/// expansions never re-reads or copies the data.
#[derive(Debug, Clone)]
pub struct TraceFile {
    pub path: String,
    pub rows: Arc<Vec<TraceRow>>,
}

/// Two trace files are the same workload source iff they were named by
/// the same path and carry the same rows (a re-load of an edited file
/// must not compare equal).
impl PartialEq for TraceFile {
    fn eq(&self, other: &Self) -> bool {
        self.path == other.path && self.rows == other.rows
    }
}

/// Column names, in order; also the accepted header spellings.
const COLUMNS: [&str; 4] = ["arrival", "size", "weight", "estimate"];

/// Stateful per-line parser shared by the whole-file [`parse`] and the
/// chunked [`ChunkedCsvReader`]: header/column-count pinning and the
/// non-decreasing-arrivals check live here exactly once, so the two
/// ingestion paths cannot diverge in what they accept or in the
/// (test-pinned) error strings they produce.
#[derive(Debug, Clone)]
pub struct RowParser {
    ncols: Option<usize>,
    prev_arrival: f64,
    rows: u64,
}

impl Default for RowParser {
    fn default() -> Self {
        RowParser::new()
    }
}

impl RowParser {
    pub fn new() -> Self {
        RowParser { ncols: None, prev_arrival: f64::NEG_INFINITY, rows: 0 }
    }

    /// Parse one raw line (`ln` is 1-based).  `Ok(None)` for blanks,
    /// comments and the header; `Ok(Some(row))` for a data row; errors
    /// are [`Error::Trace`] carrying the offending line number and are
    /// distinct per failure mode (the CLI and the scenario loader
    /// surface them verbatim).
    pub fn line(&mut self, ln: usize, raw: &str) -> Result<Option<TraceRow>, Error> {
        let at = ln as u64;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            return Ok(None);
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        if self.ncols.is_none() && fields[0].parse::<f64>().is_err() {
            // Optional header line: must spell a prefix of COLUMNS of
            // length 2..=4; it then pins the column count for the rest
            // of the file.
            let is_header = (2..=COLUMNS.len()).contains(&fields.len())
                && fields.iter().zip(COLUMNS).all(|(f, c)| *f == c);
            if !is_header {
                return Err(Error::trace_line(
                    at,
                    format!(
                        "malformed row `{line}`: expected \
                         `arrival,size[,weight][,estimate]` (numbers) or a matching header"
                    ),
                ));
            }
            self.ncols = Some(fields.len());
            return Ok(None);
        }
        let expect = *self.ncols.get_or_insert(fields.len().clamp(2, 4));
        if fields.len() != expect {
            return Err(Error::trace_line(
                at,
                format!(
                    "malformed row `{line}`: expected {expect} comma-separated \
                     fields ({}), got {}",
                    COLUMNS[..expect].join(","),
                    fields.len()
                ),
            ));
        }
        let mut nums = [0.0f64; 4];
        for (i, f) in fields.iter().enumerate() {
            nums[i] = f.parse::<f64>().map_err(|_| {
                Error::trace_line(
                    at,
                    format!("malformed row: `{f}` is not a number (column `{}`)", COLUMNS[i]),
                )
            })?;
            if !nums[i].is_finite() {
                return Err(Error::trace_line(
                    at,
                    format!("malformed row: `{f}` is not finite (column `{}`)", COLUMNS[i]),
                ));
            }
        }
        let arrival = nums[0];
        if arrival < 0.0 {
            return Err(Error::trace_line(at, format!("arrival must be non-negative, got {arrival}")));
        }
        if arrival < self.prev_arrival {
            return Err(Error::trace_line(
                at,
                format!("arrivals must be non-decreasing ({arrival} after {})", self.prev_arrival),
            ));
        }
        self.prev_arrival = arrival;
        let size = nums[1];
        if size <= 0.0 {
            return Err(Error::trace_line(at, format!("job size must be positive, got {size}")));
        }
        let weight = if expect >= 3 { nums[2] } else { 1.0 };
        if weight <= 0.0 {
            return Err(Error::trace_line(at, format!("weight must be positive, got {weight}")));
        }
        let est = (expect >= 4).then_some(nums[3]);
        if let Some(e) = est {
            if e <= 0.0 {
                return Err(Error::trace_line(at, format!("size estimate must be positive, got {e}")));
            }
        }
        self.rows += 1;
        Ok(Some(TraceRow { arrival, size, weight, est }))
    }

    /// End-of-input check: a trace with no data rows is an error.
    pub fn finish(&self) -> Result<(), Error> {
        if self.rows == 0 {
            return Err(Error::trace("trace has no data rows"));
        }
        Ok(())
    }
}

/// Parse trace text (fully materialized).  Errors carry the offending
/// 1-based line number — see [`RowParser::line`].
pub fn parse(text: &str) -> Result<Vec<TraceRow>, Error> {
    let mut rows: Vec<TraceRow> = Vec::new();
    let mut p = RowParser::new();
    for (ln, raw) in text.lines().enumerate() {
        if let Some(row) = p.line(ln + 1, raw)? {
            rows.push(row);
        }
    }
    p.finish()?;
    Ok(rows)
}

/// An arrival-ordered stream of validated trace rows that supports a
/// second pass — the shape the streaming replay path consumes, whether
/// the rows come from chunked CSV parsing ([`ChunkedCsvReader`]), the
/// binary cache ([`crate::workload::cache::CacheReader`]) or memory
/// ([`SliceRows`]).
pub trait RowStream {
    /// Next validated row, or `Ok(None)` at end of stream.
    fn next_row(&mut self) -> Result<Option<TraceRow>, Error>;
    /// Reset to the first row (the normalization pre-pass rewinds once).
    fn rewind(&mut self) -> Result<(), Error>;
}

/// Chunked CSV trace reader: a fixed-size read buffer over the file,
/// one [`TraceRow`] at a time — O(buffer) memory however long the
/// trace, accepting exactly what [`parse`] accepts and failing with
/// the same line-numbered errors (prefixed with the path, matching
/// [`TraceFile::load`]).
pub struct ChunkedCsvReader {
    reader: std::io::BufReader<std::fs::File>,
    parser: RowParser,
    path: String,
    line: String,
    ln: usize,
    eof: bool,
}

/// Read-buffer size for [`ChunkedCsvReader`] — the "chunk".
const CSV_CHUNK: usize = 64 * 1024;

impl ChunkedCsvReader {
    /// Open a trace file for streaming.  A missing or unreadable file
    /// is the same distinct error [`TraceFile::load`] produces.
    pub fn open(path: &str) -> Result<Self, Error> {
        let file = std::fs::File::open(path)
            .map_err(|e| Error::trace(format!("reading trace file {path}: {e}")))?;
        Ok(ChunkedCsvReader {
            reader: std::io::BufReader::with_capacity(CSV_CHUNK, file),
            parser: RowParser::new(),
            path: path.to_string(),
            line: String::new(),
            ln: 0,
            eof: false,
        })
    }
}

impl RowStream for ChunkedCsvReader {
    fn next_row(&mut self) -> Result<Option<TraceRow>, Error> {
        loop {
            if self.eof {
                return Ok(None);
            }
            self.line.clear();
            let n = self
                .reader
                .read_line(&mut self.line)
                .map_err(|e| Error::trace(format!("reading trace file {}: {e}", self.path)))?;
            if n == 0 {
                self.eof = true;
                self.parser.finish().map_err(|e| e.with_path(&self.path))?;
                return Ok(None);
            }
            self.ln += 1;
            match self.parser.line(self.ln, &self.line) {
                Ok(Some(row)) => return Ok(Some(row)),
                Ok(None) => continue,
                Err(e) => return Err(e.with_path(&self.path)),
            }
        }
    }

    fn rewind(&mut self) -> Result<(), Error> {
        use std::io::Seek;
        self.reader
            .seek(std::io::SeekFrom::Start(0))
            .map_err(|e| Error::trace(format!("reading trace file {}: {e}", self.path)))?;
        self.parser = RowParser::new();
        self.ln = 0;
        self.eof = false;
        Ok(())
    }
}

/// [`RowStream`] over rows already in memory (a loaded [`TraceFile`]).
pub struct SliceRows {
    rows: Arc<Vec<TraceRow>>,
    next: usize,
}

impl SliceRows {
    pub fn new(rows: Arc<Vec<TraceRow>>) -> Self {
        SliceRows { rows, next: 0 }
    }
}

impl RowStream for SliceRows {
    fn next_row(&mut self) -> Result<Option<TraceRow>, Error> {
        let r = self.rows.get(self.next).copied();
        if r.is_some() {
            self.next += 1;
        }
        Ok(r)
    }
    fn rewind(&mut self) -> Result<(), Error> {
        self.next = 0;
        Ok(())
    }
}

/// Streaming analogue of [`TraceFile::to_jobs`]: a [`JobSource`] that
/// applies the identical njobs-cap / §7.8 load-rescaling / sigma
/// re-estimation normalization while holding O(1) state.  Construction
/// makes one aggregation pre-pass over the (capped) stream to fix the
/// service speed and time origin — the same row-order sums `to_jobs`
/// computes — then rewinds; jobs are bit-identical to the materialized
/// path (pinned by `rust/tests/streaming.rs`).
pub struct TraceJobSource<R: RowStream> {
    stream: R,
    njobs: usize,
    produced: usize,
    speed: f64,
    t0: f64,
    sigma: f64,
    err: LogNormal,
    err_rng: Rng,
    peeked: Option<Job>,
}

impl<R: RowStream> TraceJobSource<R> {
    pub fn new(
        mut stream: R,
        njobs: usize,
        load: f64,
        sigma: f64,
        seed: u64,
    ) -> Result<Self, Error> {
        assert!(load > 0.0, "trace load normalization requires load > 0, got {load}");
        // Aggregation pre-pass, in row order (f64 summation order is
        // part of the bit-identity contract with `to_jobs`).
        let mut rows = 0usize;
        let mut total = 0.0f64;
        let mut t0 = 0.0f64;
        let mut last = 0.0f64;
        while rows < njobs {
            match stream.next_row()? {
                Some(r) => {
                    if rows == 0 {
                        t0 = r.arrival;
                    }
                    total += r.size;
                    last = r.arrival;
                    rows += 1;
                }
                None => break,
            }
        }
        if rows == 0 {
            return Err(Error::trace("trace replays zero rows"));
        }
        let span = (last - t0).max(1e-9);
        // load = total_work / (speed * span)  =>  speed = total / (span*load)
        let speed = total / (span * load);
        stream.rewind()?;
        Ok(TraceJobSource {
            stream,
            njobs: rows,
            produced: 0,
            speed,
            t0,
            sigma,
            err: LogNormal::error_model(sigma),
            err_rng: Rng::new(seed).substream(3),
            peeked: None,
        })
    }

    /// Jobs this source will produce in total (the capped row count).
    pub fn len(&self) -> usize {
        self.njobs
    }

    pub fn is_empty(&self) -> bool {
        self.njobs == 0
    }

    fn pull(&mut self) -> Option<Job> {
        if self.produced >= self.njobs {
            return None;
        }
        // The pre-pass validated every row this pass re-reads; an
        // error or early end here means the underlying file changed
        // between passes — never silently truncate the replay.
        let r = self
            .stream
            .next_row()
            .expect("trace changed during streaming replay")
            .expect("trace shrank during streaming replay");
        let i = self.produced;
        self.produced += 1;
        let size = (r.size / self.speed).max(MIN_SIZE);
        let est = if self.sigma > 0.0 {
            (size * self.err.sample(&mut self.err_rng)).max(MIN_SIZE)
        } else {
            match r.est {
                Some(e) => (e / self.speed).max(MIN_SIZE),
                None => size,
            }
        };
        Some(Job { id: i as u32, arrival: r.arrival - self.t0, size, est, weight: r.weight })
    }
}

impl<R: RowStream> JobSource for TraceJobSource<R> {
    fn peek_arrival(&mut self) -> Option<f64> {
        if self.peeked.is_none() {
            self.peeked = self.pull();
        }
        self.peeked.as_ref().map(|j| j.arrival)
    }

    fn next_job(&mut self) -> Option<Job> {
        if let Some(j) = self.peeked.take() {
            return Some(j);
        }
        self.pull()
    }
}

impl TraceFile {
    /// Load and parse a trace file.  A missing or unreadable file is
    /// its own error (distinct from every parse error).
    pub fn load(path: &str) -> Result<TraceFile, Error> {
        TraceFile::load_relative(path, None)
    }

    /// Load with relative paths resolved against `base` (scenario
    /// files resolve trace paths against their own directory, so a
    /// committed scenario works from any working directory).  `path`
    /// is stored as written — rendering a scenario back to TOML must
    /// not bake the load-time working directory into the file.
    pub fn load_relative(path: &str, base: Option<&Path>) -> Result<TraceFile, Error> {
        let resolved = match base {
            Some(dir) if !Path::new(path).is_absolute() => dir.join(path),
            _ => PathBuf::from(path),
        };
        let text = std::fs::read_to_string(&resolved)
            .map_err(|e| Error::trace(format!("reading trace file {}: {e}", resolved.display())))?;
        let rows = parse(&text).map_err(|e| e.with_path(&resolved.display().to_string()))?;
        Ok(TraceFile { path: path.to_string(), rows: Arc::new(rows) })
    }

    /// Convert (a prefix of) the trace into simulator jobs, applying
    /// the same normalization as the built-in stand-ins
    /// ([`crate::workload::traces::to_jobs`]): replay at most `njobs`
    /// rows, pick the service speed so the replayed prefix offers
    /// exactly `load`, and model size information as
    ///
    /// * `sigma > 0` — *re-estimation*: estimates are re-drawn from the
    ///   log-normal error model (seeded per repetition; any `estimate`
    ///   column is ignored), so repetitions of a fixed trace vary in
    ///   their size information exactly like stand-in replays;
    /// * `sigma = 0` — the file's `estimate` column when present
    ///   (rescaled by the same speed), exact sizes otherwise.
    pub fn to_jobs(&self, njobs: usize, load: f64, sigma: f64, seed: u64) -> Vec<Job> {
        let rows = &self.rows[..njobs.min(self.rows.len())];
        assert!(!rows.is_empty(), "trace {} replays zero rows", self.path);
        assert!(load > 0.0, "trace load normalization requires load > 0, got {load}");
        let total: f64 = rows.iter().map(|r| r.size).sum();
        let t0 = rows.first().unwrap().arrival;
        let span = (rows.last().unwrap().arrival - t0).max(1e-9);
        // load = total_work / (speed * span)  =>  speed = total / (span*load)
        let speed = total / (span * load);

        let err = LogNormal::error_model(sigma);
        let mut err_rng = Rng::new(seed).substream(3);
        let jobs: Vec<Job> = rows
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let size = (r.size / speed).max(MIN_SIZE);
                let est = if sigma > 0.0 {
                    (size * err.sample(&mut err_rng)).max(MIN_SIZE)
                } else {
                    match r.est {
                        Some(e) => (e / speed).max(MIN_SIZE),
                        None => size,
                    }
                };
                Job { id: i as u32, arrival: r.arrival - t0, size, est, weight: r.weight }
            })
            .collect();
        job::validate(&jobs);
        jobs
    }

    /// Streaming counterpart of [`TraceFile::to_jobs`] over the loaded
    /// rows: same normalization, jobs produced one at a time.
    pub fn stream_jobs(
        &self,
        njobs: usize,
        load: f64,
        sigma: f64,
        seed: u64,
    ) -> Result<TraceJobSource<SliceRows>, Error> {
        TraceJobSource::new(SliceRows::new(self.rows.clone()), njobs, load, sigma, seed)
            .map_err(|e| e.with_path(&self.path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "\
# a comment\n\
arrival,size,weight\n\
0.0,100,1\n\
\n\
1.5,50,2\n\
1.5,200,0.5\n\
4,25,1\n";

    #[test]
    fn parses_header_comments_and_blank_lines() {
        let rows = parse(GOOD).unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0], TraceRow { arrival: 0.0, size: 100.0, weight: 1.0, est: None });
        assert_eq!(rows[2].weight, 0.5);
        // Equal arrivals are fine (non-decreasing, not strict).
        assert_eq!(rows[1].arrival, rows[2].arrival);
    }

    #[test]
    fn two_and_four_column_forms_parse() {
        let rows = parse("0,10\n1,20\n").unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].weight, 1.0);
        assert_eq!(rows[0].est, None);

        let rows = parse("arrival,size,weight,estimate\n0,10,1,12\n1,20,2,15\n").unwrap();
        assert_eq!(rows[0].est, Some(12.0));
        assert_eq!(rows[1].weight, 2.0);
    }

    /// Each ingestion failure mode yields its own distinct error
    /// message (with the offending line number) — the ISSUE-4
    /// acceptance list, plus the neighbours.
    #[test]
    fn error_paths_are_distinct() {
        for (text, needle) in [
            // Malformed rows: garbage text, ragged width, bad number.
            ("hello world\n", "malformed row"),
            ("0,10\n1\n", "expected 2 comma-separated fields"),
            ("0,10,1\n1,20\n", "expected 3 comma-separated fields"),
            ("0,abc\n", "`abc` is not a number (column `size`)"),
            ("xyz,10\n0,10\n", "malformed row"),
            ("0,inf\n", "not finite"),
            // Non-monotone arrivals.
            ("2,10\n1,20\n", "arrivals must be non-decreasing (1 after 2)"),
            // Negative / zero quantities.
            ("0,-5\n", "job size must be positive, got -5"),
            ("0,0\n", "job size must be positive, got 0"),
            ("-1,10\n", "arrival must be non-negative"),
            ("0,10,-1\n", "weight must be positive"),
            ("0,10,1,0\n", "size estimate must be positive"),
            // Bad header.
            ("arrival,bytes\n0,10\n", "malformed row"),
            ("arrival\n0,10\n", "malformed row"),
            // Empty.
            ("", "no data rows"),
            ("# only comments\n\n", "no data rows"),
        ] {
            let err = parse(text).unwrap_err().to_string();
            assert!(err.contains(needle), "for {text:?}: got `{err}`, wanted `{needle}`");
        }
    }

    #[test]
    fn error_lines_are_one_based_and_skip_decorations() {
        let err = parse("# c\narrival,size\n0,10\n0,-1\n").unwrap_err().to_string();
        assert!(err.starts_with("line 4:"), "{err}");
    }

    #[test]
    fn missing_file_is_a_distinct_error() {
        let err = TraceFile::load("/nonexistent/psbs_no_such_trace.csv").unwrap_err().to_string();
        assert!(err.contains("reading trace file"), "{err}");
    }

    #[test]
    fn load_resolves_relative_paths_against_base() {
        let dir = std::env::temp_dir().join("psbs_trace_file_base_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t.csv"), "0,10\n1,20\n").unwrap();
        let tf = TraceFile::load_relative("t.csv", Some(dir.as_path())).unwrap();
        assert_eq!(tf.path, "t.csv", "path stored as written, not resolved");
        assert_eq!(tf.rows.len(), 2);
        let _ = std::fs::remove_dir_all(dir);
    }

    fn fixture() -> TraceFile {
        TraceFile { path: "mem".into(), rows: Arc::new(parse(GOOD).unwrap()) }
    }

    #[test]
    fn to_jobs_normalizes_load_and_caps_njobs() {
        let tf = fixture();
        let jobs = tf.to_jobs(usize::MAX, 0.9, 0.0, 0);
        assert_eq!(jobs.len(), 4);
        let total: f64 = jobs.iter().map(|j| j.size).sum();
        let span = jobs.last().unwrap().arrival;
        assert!((total / span - 0.9).abs() < 1e-9);
        assert_eq!(jobs[1].weight, 2.0, "weight column survives");
        // njobs cap replays a prefix, re-normalized on the prefix.
        let jobs = tf.to_jobs(2, 0.5, 0.0, 0);
        assert_eq!(jobs.len(), 2);
        let total: f64 = jobs.iter().map(|j| j.size).sum();
        assert!((total / jobs.last().unwrap().arrival - 0.5).abs() < 1e-9);
    }

    #[test]
    fn sigma_reestimates_per_seed_and_zero_keeps_file_estimates() {
        let tf = TraceFile {
            path: "mem".into(),
            rows: Arc::new(parse("0,10,1,20\n1,10,1,5\n2,10,1,10\n").unwrap()),
        };
        // sigma = 0: the estimate column, rescaled by the same speed.
        let exact = tf.to_jobs(usize::MAX, 0.9, 0.0, 7);
        assert!((exact[0].est / exact[0].size - 2.0).abs() < 1e-12);
        assert!((exact[1].est / exact[1].size - 0.5).abs() < 1e-12);
        // sigma > 0 re-estimates (ignores the column), seeded per rep.
        let a = tf.to_jobs(usize::MAX, 0.9, 1.0, 7);
        let b = tf.to_jobs(usize::MAX, 0.9, 1.0, 7);
        let c = tf.to_jobs(usize::MAX, 0.9, 1.0, 8);
        assert_eq!(a, b, "same seed reproduces");
        assert_ne!(a, c, "different seeds differ");
        assert!(a.iter().any(|j| (j.est / j.size - 2.0).abs() > 1e-9));
        // Sizes themselves never depend on sigma or seed.
        for (x, y) in a.iter().zip(&exact) {
            assert_eq!(x.size, y.size);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    /// The chunked reader accepts exactly what `parse` accepts and
    /// yields the same rows — both ride the one `RowParser`.
    #[test]
    fn chunked_reader_matches_parse() {
        let dir = std::env::temp_dir().join("psbs_chunked_reader_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        std::fs::write(&path, GOOD).unwrap();
        let want = parse(GOOD).unwrap();
        let mut r = ChunkedCsvReader::open(path.to_str().unwrap()).unwrap();
        let mut got = Vec::new();
        while let Some(row) = r.next_row().unwrap() {
            got.push(row);
        }
        assert_eq!(got, want);
        // Rewind replays from the top.
        r.rewind().unwrap();
        assert_eq!(r.next_row().unwrap(), Some(want[0]));
        let _ = std::fs::remove_dir_all(dir);
    }

    /// Chunked-reader errors are the same line-numbered strings as
    /// `parse`, prefixed with the path like `TraceFile::load`.
    #[test]
    fn chunked_reader_errors_match_parse() {
        let dir = std::env::temp_dir().join("psbs_chunked_reader_err_test");
        std::fs::create_dir_all(&dir).unwrap();
        for (i, text) in ["# c\narrival,size\n0,10\n0,-1\n", "2,10\n1,20\n", "# only\n"]
            .iter()
            .enumerate()
        {
            let path = dir.join(format!("t{i}.csv"));
            std::fs::write(&path, text).unwrap();
            let want = parse(text).unwrap_err();
            let mut r = ChunkedCsvReader::open(path.to_str().unwrap()).unwrap();
            let got = loop {
                match r.next_row() {
                    Ok(Some(_)) => continue,
                    Ok(None) => panic!("expected an error for {text:?}"),
                    Err(e) => break e,
                }
            };
            assert_eq!(got.to_string(), format!("{}: {want}", path.display()));
        }
        let err = ChunkedCsvReader::open("/nonexistent/psbs_no_such.csv").unwrap_err().to_string();
        assert!(err.contains("reading trace file"), "{err}");
        let _ = std::fs::remove_dir_all(dir);
    }

    /// The streaming job source replays `to_jobs` bit-for-bit,
    /// including the njobs cap and sigma re-estimation.
    #[test]
    fn stream_jobs_is_bit_identical_to_to_jobs() {
        let tf = TraceFile {
            path: "mem".into(),
            rows: Arc::new(parse("0,10,1,20\n1,30,2,5\n2,10,1,10\n5,70,1,1\n").unwrap()),
        };
        for (njobs, load, sigma, seed) in
            [(usize::MAX, 0.9, 0.0, 7u64), (3, 0.5, 1.0, 7), (usize::MAX, 0.7, 2.0, 9)]
        {
            let want = tf.to_jobs(njobs, load, sigma, seed);
            let mut src = tf.stream_jobs(njobs, load, sigma, seed).unwrap();
            assert_eq!(src.len(), want.len());
            let mut got = Vec::new();
            assert_eq!(src.peek_arrival(), Some(want[0].arrival), "peek before pull");
            while let Some(j) = src.next_job() {
                got.push(j);
            }
            assert_eq!(got, want, "njobs={njobs} load={load} sigma={sigma}");
        }
    }

    #[test]
    fn equality_tracks_path_and_rows() {
        let a = fixture();
        let b = fixture();
        assert_eq!(a, b);
        let c = TraceFile { path: "other".into(), rows: b.rows.clone() };
        assert_ne!(a, c);
        let d = TraceFile {
            path: "mem".into(),
            rows: Arc::new(parse("0,1\n").unwrap()),
        };
        assert_ne!(a, d);
    }
}
