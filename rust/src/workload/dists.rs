//! Probability distributions used by the paper's workload model
//! (§6.3, Table 1): Weibull (sizes and inter-arrival gaps), Pareto
//! (Fig. 10), log-normal (size-estimation error, Eq. 1).
//!
//! These are the pure-rust implementations; the production sweep path
//! generates the same transforms through the AOT `workload` artifact
//! (python/compile/kernels) and the two are cross-checked in
//! `rust/tests/integration.rs`.

use crate::stats::gamma;
use crate::util::rng::Rng;

/// A sampleable distribution over positive reals.
pub trait Dist {
    /// Draw one sample.
    fn sample(&self, rng: &mut Rng) -> f64;
    /// Distribution mean (used for load normalization).
    fn mean(&self) -> f64;
    /// Inverse CDF (used by the artifact cross-check and tests).
    fn icdf(&self, u: f64) -> f64;
}

/// Weibull(k, lambda): CDF `1 - exp(-(x/lambda)^k)`.
///
/// `shape` (k) interpolates heavy-tailed (k < 1), exponential (k = 1)
/// and light-tailed (k > 1) regimes — the paper's main workload knob.
#[derive(Debug, Clone, Copy)]
pub struct Weibull {
    pub shape: f64,
    pub scale: f64,
}

impl Weibull {
    pub fn new(shape: f64, scale: f64) -> Self {
        assert!(shape > 0.0 && scale > 0.0, "Weibull params must be positive");
        Weibull { shape, scale }
    }

    /// Weibull with the given shape, scaled to unit mean (Table 1:
    /// "we set the scale parameter to ensure that its mean is 1").
    pub fn unit_mean(shape: f64) -> Self {
        Weibull::new(shape, 1.0 / gamma(1.0 + 1.0 / shape))
    }

    /// Weibull with the given shape scaled so the mean is `mean`.
    pub fn with_mean(shape: f64, mean: f64) -> Self {
        let w = Weibull::unit_mean(shape);
        Weibull::new(shape, w.scale * mean)
    }
}

impl Dist for Weibull {
    fn sample(&self, rng: &mut Rng) -> f64 {
        self.icdf(rng.u01())
    }

    fn mean(&self) -> f64 {
        self.scale * gamma(1.0 + 1.0 / self.shape)
    }

    fn icdf(&self, u: f64) -> f64 {
        // Mirrors the L1 kernel: clamp, then scale*(-log1p(-u))^(1/k).
        let u = u.clamp(1e-12, 1.0 - 1e-12);
        self.scale * (-(-u).ln_1p()).powf(1.0 / self.shape)
    }
}

/// Pareto(x_m, alpha) — Fig. 10 uses alpha in {1, 2}.
///
/// For alpha <= 1 the mean is infinite; the paper nevertheless uses
/// alpha = 1 workloads (normalizing load empirically over the generated
/// sample), so `mean()` returns the *truncation-free analytic* mean and
/// callers must normalize empirically when it is infinite.
#[derive(Debug, Clone, Copy)]
pub struct Pareto {
    pub xm: f64,
    pub alpha: f64,
}

impl Pareto {
    pub fn new(xm: f64, alpha: f64) -> Self {
        assert!(xm > 0.0 && alpha > 0.0, "Pareto params must be positive");
        Pareto { xm, alpha }
    }

    /// Unit-mean Pareto for alpha > 1: mean = alpha*xm/(alpha-1).
    pub fn unit_mean(alpha: f64) -> Self {
        assert!(alpha > 1.0, "unit-mean Pareto needs alpha > 1");
        Pareto::new((alpha - 1.0) / alpha, alpha)
    }
}

impl Dist for Pareto {
    fn sample(&self, rng: &mut Rng) -> f64 {
        self.icdf(rng.u01())
    }

    fn mean(&self) -> f64 {
        if self.alpha > 1.0 {
            self.alpha * self.xm / (self.alpha - 1.0)
        } else {
            f64::INFINITY
        }
    }

    fn icdf(&self, u: f64) -> f64 {
        let u = u.clamp(0.0, 1.0 - 1e-12);
        self.xm / (1.0 - u).powf(1.0 / self.alpha)
    }
}

/// LogNormal(mu, sigma^2) of the *logarithm*.
///
/// The paper's error model (Eq. 1) is LogNormal(0, sigma^2): the
/// estimate is `s_hat = s * X`, multiplicative and median-1, so under-
/// and over-estimation by any factor k are equally likely.
#[derive(Debug, Clone, Copy)]
pub struct LogNormal {
    pub mu: f64,
    pub sigma: f64,
}

impl LogNormal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        assert!(sigma >= 0.0, "sigma must be non-negative");
        LogNormal { mu, sigma }
    }

    /// The paper's error multiplier distribution.
    pub fn error_model(sigma: f64) -> Self {
        LogNormal::new(0.0, sigma)
    }
}

impl Dist for LogNormal {
    fn sample(&self, rng: &mut Rng) -> f64 {
        (self.mu + self.sigma * rng.normal()).exp()
    }

    fn mean(&self) -> f64 {
        (self.mu + self.sigma * self.sigma / 2.0).exp()
    }

    fn icdf(&self, u: f64) -> f64 {
        (self.mu + self.sigma * std::f64::consts::SQRT_2 * erf_inv(2.0 * u - 1.0)).exp()
    }
}

/// Exponential as Weibull(1, mean) — convenience for arrivals.
pub fn exponential(mean: f64) -> Weibull {
    Weibull::new(1.0, mean)
}

/// Inverse error function (Giles 2012 single-precision-grade rational
/// approximation, adequate for icdf-based tests; sampling uses
/// Box-Muller instead).
pub fn erf_inv(x: f64) -> f64 {
    let x = x.clamp(-1.0 + 1e-15, 1.0 - 1e-15);
    let w = -((1.0 - x) * (1.0 + x)).ln();
    let mut p;
    if w < 5.0 {
        let w = w - 2.5;
        p = 2.81022636e-08;
        p = 3.43273939e-07 + p * w;
        p = -3.5233877e-06 + p * w;
        p = -4.39150654e-06 + p * w;
        p = 0.00021858087 + p * w;
        p = -0.00125372503 + p * w;
        p = -0.00417768164 + p * w;
        p = 0.246640727 + p * w;
        p = 1.50140941 + p * w;
    } else {
        let w = w.sqrt() - 3.0;
        p = -0.000200214257;
        p = 0.000100950558 + p * w;
        p = 0.00134934322 + p * w;
        p = -0.00367342844 + p * w;
        p = 0.00573950773 + p * w;
        p = -0.0076224613 + p * w;
        p = 0.00943887047 + p * w;
        p = 1.00167406 + p * w;
        p = 2.83297682 + p * w;
    }
    p * x
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_mean<D: Dist>(d: &D, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64
    }

    #[test]
    fn weibull_unit_mean_holds() {
        for shape in [0.5, 1.0, 2.0, 4.0] {
            let w = Weibull::unit_mean(shape);
            assert!((w.mean() - 1.0).abs() < 1e-12);
            let m = sample_mean(&w, 200_000, 1);
            assert!((m - 1.0).abs() < 0.02, "shape={shape} mean={m}");
        }
    }

    #[test]
    fn weibull_heavy_tail_sample_mean() {
        // shape 0.25 is very skewed; mean converges slowly, allow 10%.
        let w = Weibull::unit_mean(0.25);
        let m = sample_mean(&w, 2_000_000, 2);
        assert!((m - 1.0).abs() < 0.1, "mean={m}");
    }

    #[test]
    fn weibull_icdf_monotone() {
        let w = Weibull::unit_mean(0.25);
        let mut last = 0.0;
        for i in 1..100 {
            let v = w.icdf(i as f64 / 100.0);
            assert!(v >= last);
            last = v;
        }
    }

    #[test]
    fn exponential_is_weibull_shape1() {
        let e = exponential(2.0);
        assert_eq!(e.shape, 1.0);
        assert!((e.mean() - 2.0).abs() < 1e-12);
        // icdf is -mean*ln(1-u)
        assert!((e.icdf(0.5) - 2.0 * std::f64::consts::LN_2).abs() < 1e-9);
    }

    #[test]
    fn pareto_mean_and_tail() {
        let p = Pareto::unit_mean(2.0);
        assert!((p.mean() - 1.0).abs() < 1e-12);
        let m = sample_mean(&p, 500_000, 3);
        assert!((m - 1.0).abs() < 0.05, "mean={m}");
        assert_eq!(Pareto::new(1.0, 1.0).mean(), f64::INFINITY);
    }

    #[test]
    fn lognormal_median_one() {
        let ln = LogNormal::error_model(2.0);
        let mut rng = Rng::new(4);
        let mut xs: Vec<f64> = (0..100_001).map(|_| ln.sample(&mut rng)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[50_000];
        assert!((med - 1.0).abs() < 0.05, "median={med}");
    }

    #[test]
    fn lognormal_mean_grows_with_sigma() {
        // §6.3: the mean exceeds 1 and grows with sigma — the paper's
        // explanation for FSPE's non-monotonic error response.
        assert!(LogNormal::error_model(0.5).mean() > 1.0);
        assert!(LogNormal::error_model(2.0).mean() > LogNormal::error_model(1.0).mean());
    }

    #[test]
    fn lognormal_sigma_correlation_table() {
        // §6.3: corr(s, s_hat) for sigma = 0.5, 1, 2, 4 is about
        // 0.9, 0.6, 0.15, 0.05. Reproduce via sampling: corr of
        // (X, X*E) with X Weibull(0.25), E LogNormal(0, sigma).
        let w = Weibull::unit_mean(0.25);
        for (sigma, lo, hi) in [(0.5, 0.7, 0.99), (4.0, 0.0, 0.3)] {
            let e = LogNormal::error_model(sigma);
            let mut rng = Rng::new(5);
            let n = 200_000;
            let (mut sx, mut sy, mut sxx, mut syy, mut sxy) = (0.0, 0.0, 0.0, 0.0, 0.0);
            for _ in 0..n {
                let x = w.sample(&mut rng);
                let y = x * e.sample(&mut rng);
                sx += x;
                sy += y;
                sxx += x * x;
                syy += y * y;
                sxy += x * y;
            }
            let nf = n as f64;
            let corr = (sxy - sx * sy / nf)
                / ((sxx - sx * sx / nf).sqrt() * (syy - sy * sy / nf).sqrt());
            assert!(
                (lo..=hi).contains(&corr),
                "sigma={sigma} corr={corr} not in [{lo},{hi}]"
            );
        }
    }

    #[test]
    fn erf_inv_roundtrip() {
        for x in [-0.9, -0.5, 0.0, 0.3, 0.99] {
            // erf(erf_inv(x)) ~= x, via the normal CDF relation.
            let z = erf_inv(x);
            // erf via Abramowitz-Stegun-ish numeric integration check:
            let erf = {
                let n = 20_000;
                let h = z / n as f64;
                let mut s = 0.0;
                for i in 0..n {
                    let t = (i as f64 + 0.5) * h;
                    s += (-t * t).exp() * h;
                }
                2.0 / std::f64::consts::PI.sqrt() * s
            };
            assert!((erf - x).abs() < 1e-4, "x={x} erf={erf}");
        }
    }
}
