//! Synthetic workload generation — the paper's Table 1 model.
//!
//! Job sizes ~ Weibull(`shape`) with unit mean (or Pareto for Fig. 10);
//! inter-arrival gaps ~ Weibull(`timeshape`) scaled so that
//! `load = mean_size / mean_gap`; size estimates are
//! `s_hat = s * LogNormal(0, sigma^2)`; optional weight classes for the
//! §7.6 experiments.
//!
//! Generation is available through two equivalent paths:
//! * pure rust ([`synthesize`]) — used by tests and as a fallback;
//! * the AOT `workload` artifact ([`crate::runtime`]) — rust supplies
//!   the uniforms, the Weibull/log-normal transforms run in the
//!   compiled HLO (the production sweep path).
//!
//! `rust/tests/integration.rs` checks the two produce the same
//! workloads to f32 tolerance.

use super::dists::{Dist, LogNormal, Pareto, Weibull};
use crate::sim::{job, Job, JobSource};
use crate::util::rng::Rng;

/// Job size distribution choice (Table 1 default: Weibull).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SizeDist {
    /// Weibull with the given shape, unit mean.
    Weibull { shape: f64 },
    /// Pareto with x_m chosen for unit mean when alpha > 1, else
    /// x_m = 1 and empirical load normalization (Fig. 10, alpha = 1).
    Pareto { alpha: f64 },
}

/// Table 1 parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SynthConfig {
    /// Job size distribution (`shape` column of Table 1).
    pub size_dist: SizeDist,
    /// sigma of the log-normal estimation error (0 = exact sizes).
    pub sigma: f64,
    /// Shape of the Weibull inter-arrival gap distribution.
    pub timeshape: f64,
    /// Offered load = mean size / mean gap.
    pub load: f64,
    /// Number of jobs per workload.
    pub njobs: usize,
    /// Weight-class skew (§7.6): job in class c in 1..=5 gets weight
    /// 1/c^beta. 0 disables weighting (all weights 1).
    pub beta: f64,
}

impl Default for SynthConfig {
    /// The paper's defaults (Table 1): shape 0.25, sigma 0.5,
    /// timeshape 1, load 0.9, njobs 10 000, uniform weights.
    fn default() -> Self {
        SynthConfig {
            size_dist: SizeDist::Weibull { shape: 0.25 },
            sigma: 0.5,
            timeshape: 1.0,
            load: 0.9,
            njobs: 10_000,
            beta: 0.0,
        }
    }
}

impl SynthConfig {
    pub fn with_shape(mut self, shape: f64) -> Self {
        self.size_dist = SizeDist::Weibull { shape };
        self
    }
    pub fn with_sigma(mut self, sigma: f64) -> Self {
        self.sigma = sigma;
        self
    }
    pub fn with_load(mut self, load: f64) -> Self {
        self.load = load;
        self
    }
    pub fn with_njobs(mut self, njobs: usize) -> Self {
        self.njobs = njobs;
        self
    }
    pub fn with_timeshape(mut self, timeshape: f64) -> Self {
        self.timeshape = timeshape;
        self
    }
    pub fn with_beta(mut self, beta: f64) -> Self {
        self.beta = beta;
        self
    }
}

/// Minimum job size: guards the simulator against degenerate zero-size
/// jobs from the far left tail of f32-sampled distributions.
pub const MIN_SIZE: f64 = 1e-9;

/// Generate one workload (sorted by arrival, ids dense).
pub fn synthesize(cfg: &SynthConfig, seed: u64) -> Vec<Job> {
    let rng = Rng::new(seed);
    let mut size_rng = rng.substream(1);
    let mut gap_rng = rng.substream(2);
    let mut err_rng = rng.substream(3);
    let mut class_rng = rng.substream(4);

    // --- sizes ---
    let sizes: Vec<f64> = match cfg.size_dist {
        SizeDist::Weibull { shape } => {
            let d = Weibull::unit_mean(shape);
            (0..cfg.njobs).map(|_| d.sample(&mut size_rng).max(MIN_SIZE)).collect()
        }
        SizeDist::Pareto { alpha } => {
            let d = if alpha > 1.0 {
                Pareto::unit_mean(alpha)
            } else {
                Pareto::new(1.0, alpha)
            };
            (0..cfg.njobs).map(|_| d.sample(&mut size_rng).max(MIN_SIZE)).collect()
        }
    };

    // --- arrival gaps ---
    // load = mean_size / mean_gap  =>  mean_gap = mean_size / load.
    // For finite-mean size dists mean_size = 1 analytically; for
    // Pareto(alpha<=1) normalize on the empirical sample (the paper's
    // trace treatment: pick service speed for load 0.9).
    let mean_size = match cfg.size_dist {
        SizeDist::Weibull { .. } => 1.0,
        SizeDist::Pareto { alpha } if alpha > 1.0 => 1.0,
        SizeDist::Pareto { .. } => sizes.iter().sum::<f64>() / sizes.len() as f64,
    };
    let gap_dist = Weibull::with_mean(cfg.timeshape, mean_size / cfg.load);

    // --- error multipliers ---
    let err = LogNormal::error_model(cfg.sigma);

    let mut t = 0.0;
    let jobs: Vec<Job> = sizes
        .iter()
        .enumerate()
        .map(|(i, &size)| {
            t += gap_dist.sample(&mut gap_rng);
            let mult = if cfg.sigma > 0.0 { err.sample(&mut err_rng) } else { 1.0 };
            let weight = if cfg.beta > 0.0 {
                let class = (1 + class_rng.below(5)) as f64; // classes 1..=5
                1.0 / class.powf(cfg.beta)
            } else {
                1.0
            };
            Job {
                id: i as u32,
                arrival: t,
                size,
                est: (size * mult).max(MIN_SIZE),
                weight,
            }
        })
        .collect();

    job::validate(&jobs);
    jobs
}

/// One size-distribution sampler (the match in [`synthesize`], hoisted
/// so the streaming source draws from exactly the same object).
#[derive(Debug, Clone, Copy)]
enum SizeSampler {
    Weibull(Weibull),
    Pareto(Pareto),
}

impl SizeSampler {
    fn new(size_dist: SizeDist) -> SizeSampler {
        match size_dist {
            SizeDist::Weibull { shape } => SizeSampler::Weibull(Weibull::unit_mean(shape)),
            SizeDist::Pareto { alpha } => SizeSampler::Pareto(if alpha > 1.0 {
                Pareto::unit_mean(alpha)
            } else {
                Pareto::new(1.0, alpha)
            }),
        }
    }
    fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            SizeSampler::Weibull(d) => d.sample(rng).max(MIN_SIZE),
            SizeSampler::Pareto(d) => d.sample(rng).max(MIN_SIZE),
        }
    }
}

/// Streaming synthetic generator: a [`JobSource`] producing the exact
/// jobs [`synthesize`] materializes (bit-identical, pinned by tests),
/// in O(1) memory per job.
///
/// Equivalence is by construction: the four substreams (sizes, gaps,
/// errors, classes) are independent generators, so drawing them
/// interleaved per job consumes each stream in the same order as the
/// batch path's pass-per-stream.  The one batch-only dependency —
/// Pareto `alpha <= 1`, whose gap scale needs the *empirical* mean of
/// all sizes — is handled by pre-walking a clone of the size stream
/// (O(1) memory, the real stream then re-draws the same values).
pub struct SynthSource {
    cfg: SynthConfig,
    sampler: SizeSampler,
    gap_dist: Weibull,
    err: LogNormal,
    size_rng: Rng,
    gap_rng: Rng,
    err_rng: Rng,
    class_rng: Rng,
    t: f64,
    i: usize,
    peeked: Option<Job>,
}

impl SynthSource {
    pub fn new(cfg: &SynthConfig, seed: u64) -> SynthSource {
        let rng = Rng::new(seed);
        let size_rng = rng.substream(1);
        let gap_rng = rng.substream(2);
        let err_rng = rng.substream(3);
        let class_rng = rng.substream(4);
        let sampler = SizeSampler::new(cfg.size_dist);
        let mean_size = match cfg.size_dist {
            SizeDist::Weibull { .. } => 1.0,
            SizeDist::Pareto { alpha } if alpha > 1.0 => 1.0,
            SizeDist::Pareto { .. } => {
                let mut probe = size_rng.clone();
                let mut sum = 0.0;
                for _ in 0..cfg.njobs {
                    sum += sampler.sample(&mut probe);
                }
                sum / cfg.njobs as f64
            }
        };
        let gap_dist = Weibull::with_mean(cfg.timeshape, mean_size / cfg.load);
        SynthSource {
            cfg: *cfg,
            sampler,
            gap_dist,
            err: LogNormal::error_model(cfg.sigma),
            size_rng,
            gap_rng,
            err_rng,
            class_rng,
            t: 0.0,
            i: 0,
            peeked: None,
        }
    }

    /// Total jobs this source will produce.
    pub fn len(&self) -> usize {
        self.cfg.njobs
    }

    pub fn is_empty(&self) -> bool {
        self.cfg.njobs == 0
    }

    fn pull(&mut self) -> Option<Job> {
        if self.i >= self.cfg.njobs {
            return None;
        }
        let i = self.i;
        self.i += 1;
        let size = self.sampler.sample(&mut self.size_rng);
        self.t += self.gap_dist.sample(&mut self.gap_rng);
        let mult = if self.cfg.sigma > 0.0 { self.err.sample(&mut self.err_rng) } else { 1.0 };
        let weight = if self.cfg.beta > 0.0 {
            let class = (1 + self.class_rng.below(5)) as f64; // classes 1..=5
            1.0 / class.powf(self.cfg.beta)
        } else {
            1.0
        };
        Some(Job { id: i as u32, arrival: self.t, size, est: (size * mult).max(MIN_SIZE), weight })
    }
}

impl JobSource for SynthSource {
    fn peek_arrival(&mut self) -> Option<f64> {
        if self.peeked.is_none() {
            self.peeked = self.pull();
        }
        self.peeked.as_ref().map(|j| j.arrival)
    }

    fn next_job(&mut self) -> Option<Job> {
        if let Some(j) = self.peeked.take() {
            return Some(j);
        }
        self.pull()
    }
}

/// Weight class of a job generated with `beta > 0` (1..=5), recovered
/// from the weight value — used by the Fig. 9 harness to group MSTs.
pub fn weight_class(weight: f64, beta: f64) -> usize {
    if beta <= 0.0 {
        return 1;
    }
    (1..=5)
        .min_by(|&a, &b| {
            let wa = 1.0 / (a as f64).powf(beta);
            let wb = 1.0 / (b as f64).powf(beta);
            (wa - weight).abs().partial_cmp(&(wb - weight).abs()).unwrap()
        })
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_defaults_match_table1() {
        let cfg = SynthConfig::default();
        assert_eq!(cfg.size_dist, SizeDist::Weibull { shape: 0.25 });
        assert_eq!(cfg.sigma, 0.5);
        assert_eq!(cfg.timeshape, 1.0);
        assert_eq!(cfg.load, 0.9);
        assert_eq!(cfg.njobs, 10_000);
    }

    #[test]
    fn workload_is_valid_and_seeded() {
        let cfg = SynthConfig::default().with_njobs(1000);
        let a = synthesize(&cfg, 1);
        let b = synthesize(&cfg, 1);
        let c = synthesize(&cfg, 2);
        assert_eq!(a.len(), 1000);
        assert_eq!(a, b, "same seed must reproduce");
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn load_is_respected() {
        // Empirical load = total size / span of arrivals ~ cfg.load.
        let cfg = SynthConfig::default().with_shape(1.0).with_njobs(200_000).with_load(0.5);
        let jobs = synthesize(&cfg, 3);
        let total: f64 = jobs.iter().map(|j| j.size).sum();
        let span = jobs.last().unwrap().arrival;
        let load = total / span;
        assert!((load - 0.5).abs() < 0.02, "load={load}");
    }

    #[test]
    fn sigma_zero_is_exact() {
        let cfg = SynthConfig::default().with_sigma(0.0).with_njobs(100);
        for j in synthesize(&cfg, 4) {
            assert_eq!(j.size, j.est);
        }
    }

    #[test]
    fn sigma_controls_error_spread() {
        let small = SynthConfig::default().with_sigma(0.125).with_njobs(5000);
        let big = SynthConfig::default().with_sigma(4.0).with_njobs(5000);
        let spread = |jobs: &[Job]| {
            jobs.iter().map(|j| (j.est / j.size).ln().abs()).sum::<f64>() / jobs.len() as f64
        };
        let s = spread(&synthesize(&small, 5));
        let b = spread(&synthesize(&big, 5));
        assert!(b > 10.0 * s, "spread small={s} big={b}");
    }

    #[test]
    fn beta_creates_five_weight_classes() {
        let cfg = SynthConfig::default().with_beta(1.0).with_njobs(5000);
        let jobs = synthesize(&cfg, 6);
        let mut weights: Vec<f64> = jobs.iter().map(|j| j.weight).collect();
        weights.sort_by(|a, b| a.partial_cmp(b).unwrap());
        weights.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
        assert_eq!(weights.len(), 5);
        for j in &jobs {
            let c = weight_class(j.weight, 1.0);
            assert!((j.weight - 1.0 / c as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn pareto_workload_valid() {
        for alpha in [1.0, 2.0] {
            let cfg = SynthConfig {
                size_dist: SizeDist::Pareto { alpha },
                njobs: 2000,
                ..Default::default()
            };
            let jobs = synthesize(&cfg, 7);
            assert_eq!(jobs.len(), 2000);
            assert!(jobs.iter().all(|j| j.size > 0.0));
        }
    }

    /// The streaming generator reproduces `synthesize` bit-for-bit
    /// over every distribution family and knob, including the
    /// empirical-mean Pareto normalization and the error/weight
    /// substreams.
    #[test]
    fn synth_source_is_bit_identical_to_synthesize() {
        let cases = [
            SynthConfig::default().with_njobs(500),
            SynthConfig::default().with_njobs(500).with_sigma(0.0),
            SynthConfig::default().with_njobs(500).with_sigma(2.0).with_beta(1.0),
            SynthConfig {
                size_dist: SizeDist::Pareto { alpha: 2.0 },
                njobs: 500,
                ..Default::default()
            },
            SynthConfig {
                size_dist: SizeDist::Pareto { alpha: 1.0 }, // empirical mean path
                njobs: 500,
                ..Default::default()
            },
            SynthConfig::default().with_njobs(500).with_timeshape(0.25).with_load(0.5),
        ];
        for (k, cfg) in cases.iter().enumerate() {
            let want = synthesize(cfg, 40 + k as u64);
            let mut src = SynthSource::new(cfg, 40 + k as u64);
            assert_eq!(src.len(), want.len());
            assert_eq!(src.peek_arrival(), Some(want[0].arrival));
            let mut got = Vec::with_capacity(want.len());
            while let Some(j) = src.next_job() {
                got.push(j);
            }
            assert_eq!(got, want, "case {k}");
        }
    }

    #[test]
    fn timeshape_bursty_vs_regular() {
        // Low timeshape => bursty: higher variance of gaps.
        let bursty = SynthConfig::default().with_timeshape(0.125).with_njobs(20_000);
        let regular = SynthConfig::default().with_timeshape(4.0).with_njobs(20_000);
        let cv = |jobs: &[Job]| {
            let gaps: Vec<f64> = jobs.windows(2).map(|w| w[1].arrival - w[0].arrival).collect();
            crate::stats::stddev(&gaps) / crate::stats::mean(&gaps)
        };
        let b = cv(&synthesize(&bursty, 8));
        let r = cv(&synthesize(&regular, 8));
        assert!(b > 3.0, "bursty cv={b}");
        assert!(r < 0.5, "regular cv={r}");
    }
}
