//! Real-workload replay (paper §7.8) and synthetic stand-ins.
//!
//! The paper evaluates two traces:
//! * **Facebook Hadoop 2010** (SWIM repository): 24 443 jobs over one
//!   day; job size = bytes handled (input + shuffle + output); mean
//!   76.1 GiB, max 85.2 TiB (tail spans 3 decades above the mean).
//! * **IRCache web cache 2007** (squid access log): 206 914 requests
//!   over one day; mean 14.6 KiB, max 174 MiB (4 decades).
//!
//! This module provides (a) parsers for both on-disk formats, so the
//! original traces replay directly when available, and (b) *synthetic
//! stand-ins* matched to the published statistics (count, duration,
//! mean, max, CCDF decade-span) for the offline environment — see
//! DESIGN.md §4 Substitutions.  Fig. 12/13 depend only on the
//! (arrival, size) marginals and the paper's own load-0.9 speed
//! normalization, which [`to_jobs`] reproduces.

use super::dists::{Dist, LogNormal};
use crate::sim::{job, Job};
use crate::util::rng::Rng;

/// One trace record: submission time (seconds) and size (bytes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Record {
    pub submit: f64,
    pub bytes: f64,
}

/// Parse a SWIM workload-suite TSV (Facebook Hadoop trace).
///
/// Columns: job-id, submit-time(s), inter-arrival-gap(s), map-input
/// bytes, shuffle bytes, reduce-output bytes.  Job size is the sum of
/// the three byte columns (the paper's treatment).  Malformed or
/// zero-size rows are skipped (the simulator requires positive sizes).
pub fn parse_swim(text: &str) -> Vec<Record> {
    let mut out = Vec::new();
    for line in text.lines() {
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() < 6 {
            continue;
        }
        let (Ok(submit), Ok(a), Ok(b), Ok(c)) = (
            f[1].parse::<f64>(),
            f[3].parse::<f64>(),
            f[4].parse::<f64>(),
            f[5].parse::<f64>(),
        ) else {
            continue;
        };
        let bytes = a + b + c;
        if bytes > 0.0 && submit >= 0.0 {
            out.push(Record { submit, bytes });
        }
    }
    out.sort_by(|x, y| x.submit.partial_cmp(&y.submit).unwrap());
    out
}

/// Parse a squid `access.log` (IRCache trace).
///
/// Fields: `timestamp elapsed client action/code size method url ...`;
/// we keep `timestamp` (s, possibly fractional) and `size` (bytes).
pub fn parse_squid(text: &str) -> Vec<Record> {
    let mut out = Vec::new();
    for line in text.lines() {
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() < 5 {
            continue;
        }
        let (Ok(ts), Ok(bytes)) = (f[0].parse::<f64>(), f[4].parse::<f64>()) else {
            continue;
        };
        if bytes > 0.0 && ts >= 0.0 {
            out.push(Record { submit: ts, bytes });
        }
    }
    out.sort_by(|x, y| x.submit.partial_cmp(&y.submit).unwrap());
    out
}

/// Published statistics a stand-in must match.
#[derive(Debug, Clone, Copy)]
pub struct TraceStats {
    pub jobs: usize,
    pub duration_s: f64,
    pub mean_bytes: f64,
    pub max_bytes: f64,
}

/// Named trace preset — the declarable handle scenario files and
/// [`crate::scenario::TraceSpec`] use to refer to a stand-in without
/// carrying the raw statistics around.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TraceName {
    /// Facebook Hadoop 2010 (Fig. 12).
    Facebook,
    /// IRCache web cache 2007 (Fig. 13).
    Ircache,
}

impl TraceName {
    /// The published statistics behind this preset.
    pub fn stats(self) -> &'static TraceStats {
        match self {
            TraceName::Facebook => &FACEBOOK,
            TraceName::Ircache => &IRCACHE,
        }
    }

    /// Canonical lowercase name (the `gen-trace --stats` / scenario-file
    /// spelling).
    pub fn name(self) -> &'static str {
        match self {
            TraceName::Facebook => "facebook",
            TraceName::Ircache => "ircache",
        }
    }

    /// Inverse of [`TraceName::name`].
    pub fn from_name(s: &str) -> Option<TraceName> {
        Some(match s {
            "facebook" => TraceName::Facebook,
            "ircache" => TraceName::Ircache,
            _ => return None,
        })
    }
}

/// Facebook Hadoop 2010 (Chen et al. [37] / SWIM).
pub const FACEBOOK: TraceStats = TraceStats {
    jobs: 24_443,
    duration_s: 86_400.0,
    mean_bytes: 76.1 * GIB,
    max_bytes: 85.2 * TIB,
};

/// IRCache one-day server trace (2007-01-09).
pub const IRCACHE: TraceStats = TraceStats {
    jobs: 206_914,
    duration_s: 86_400.0,
    mean_bytes: 14.6 * KIB,
    max_bytes: 174.0 * MIB,
};

pub const KIB: f64 = 1024.0;
pub const MIB: f64 = 1024.0 * KIB;
pub const GIB: f64 = 1024.0 * MIB;
pub const TIB: f64 = 1024.0 * GIB;

/// Synthesize a stand-in trace matching `stats`: log-normal sizes with
/// the tail exponent chosen so the expected sample maximum over
/// `stats.jobs` draws lands on `stats.max_bytes`, rescaled to the exact
/// published mean and clipped at the published max; arrivals are a
/// diurnally-modulated Poisson process over the published duration
/// (rate ∝ 1 + 0.6·sin — Hadoop and web traffic both show strong
/// day/night cycles, which is exactly the kind of structure the paper
/// replays traces to capture).
pub fn synth_trace(stats: &TraceStats, seed: u64) -> Vec<Record> {
    let rng = Rng::new(seed ^ 0x7A3C_E5);
    let mut size_rng = rng.substream(1);
    let mut gap_rng = rng.substream(2);

    // Choose sigma: E[max of n lognormals] ~ exp(mu + sigma*sqrt(2 ln n));
    // mean = exp(mu + sigma^2/2). Solve sigma^2/2 - sigma*sqrt(2 ln n)
    // + ln(max/mean) = 0 for the smaller root.
    let n = stats.jobs as f64;
    let span = (stats.max_bytes / stats.mean_bytes).ln();
    let b = (2.0 * n.ln()).sqrt();
    let disc = (b * b - 2.0 * span).max(0.0).sqrt();
    let sigma = (b - disc).max(0.5);
    let body = LogNormal::new(0.0, sigma);

    let mut sizes: Vec<f64> = (0..stats.jobs).map(|_| body.sample(&mut size_rng)).collect();
    // Rescale to the published mean, then clip the far tail at the
    // published max (re-rescaling after the clip keeps the mean within
    // a fraction of a percent).
    let m = sizes.iter().sum::<f64>() / n;
    for s in sizes.iter_mut() {
        *s = (*s / m * stats.mean_bytes).min(stats.max_bytes).max(1.0);
    }

    // Diurnal non-homogeneous Poisson via thinning.
    let base_rate = n / stats.duration_s; // jobs per second (average)
    let peak = base_rate * 1.6;
    let mut t = 0.0;
    let mut submits = Vec::with_capacity(stats.jobs);
    while submits.len() < stats.jobs {
        t += -gap_rng.u01_open_left().ln() / peak;
        let phase = 2.0 * std::f64::consts::PI * t / stats.duration_s;
        let rate = base_rate * (1.0 + 0.6 * phase.sin());
        if gap_rng.u01() < rate / peak {
            submits.push(t);
        }
    }

    submits
        .into_iter()
        .zip(sizes)
        .map(|(submit, bytes)| Record { submit, bytes })
        .collect()
}

/// Convert trace records into simulator jobs: pick the service speed
/// (bytes/second) so the offered load is `load` (the paper's §7.8
/// normalization), then express sizes in seconds of service and apply
/// the log-normal estimation-error model with parameter `sigma`.
pub fn to_jobs(records: &[Record], load: f64, sigma: f64, seed: u64) -> Vec<Job> {
    assert!(!records.is_empty());
    let total_bytes: f64 = records.iter().map(|r| r.bytes).sum();
    let t0 = records.first().unwrap().submit;
    let span = (records.last().unwrap().submit - t0).max(1e-9);
    // load = total_work / (speed * span)  =>  speed = total / (span*load)
    let speed = total_bytes / (span * load);

    let err = LogNormal::error_model(sigma);
    let mut err_rng = Rng::new(seed).substream(3);
    let jobs: Vec<Job> = records
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let size = (r.bytes / speed).max(super::synthetic::MIN_SIZE);
            let mult = if sigma > 0.0 { err.sample(&mut err_rng) } else { 1.0 };
            Job {
                id: i as u32,
                arrival: r.submit - t0,
                size,
                est: (size * mult).max(super::synthetic::MIN_SIZE),
                weight: 1.0,
            }
        })
        .collect();
    job::validate(&jobs);
    jobs
}

/// CCDF points (size/mean, fraction of jobs larger) for Fig. 11.
pub fn ccdf(records: &[Record], points: usize) -> Vec<(f64, f64)> {
    let mut sizes: Vec<f64> = records.iter().map(|r| r.bytes).collect();
    sizes.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = sizes.iter().sum::<f64>() / sizes.len() as f64;
    let n = sizes.len();
    (0..points)
        .map(|k| {
            let idx = k * (n - 1) / (points - 1).max(1);
            let frac_larger = (n - 1 - idx) as f64 / n as f64;
            (sizes[idx] / mean, frac_larger)
        })
        .collect()
}

/// Load a trace file by format name ("swim" | "squid").
pub fn load_file(path: &str, format: &str) -> std::io::Result<Vec<Record>> {
    let text = std::fs::read_to_string(path)?;
    Ok(match format {
        "swim" => parse_swim(&text),
        "squid" => parse_squid(&text),
        other => {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("unknown trace format: {other}"),
            ))
        }
    })
}

/// Write records in SWIM TSV form (used by `psbs gen-trace`).
pub fn write_swim(records: &[Record], path: &str) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for (i, r) in records.iter().enumerate() {
        // One byte column carries the size; gap column is derivable.
        let gap = if i == 0 { r.submit } else { r.submit - records[i - 1].submit };
        writeln!(f, "job{i}\t{:.3}\t{:.3}\t{:.0}\t0\t0", r.submit, gap, r.bytes)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SWIM_FIXTURE: &str = "\
job0\t0.0\t0.0\t1000\t500\t250\n\
job1\t3.5\t3.5\t2000\t0\t0\n\
badline\n\
job2\t2.0\t-1.5\t0\t0\t4096\n\
job3\t9.0\t7.0\t0\t0\t0\n"; // zero size: dropped

    #[test]
    fn swim_parser_handles_fixture() {
        let recs = parse_swim(SWIM_FIXTURE);
        assert_eq!(recs.len(), 3);
        // Sorted by submit time.
        assert_eq!(recs[0], Record { submit: 0.0, bytes: 1750.0 });
        assert_eq!(recs[1], Record { submit: 2.0, bytes: 4096.0 });
        assert_eq!(recs[2], Record { submit: 3.5, bytes: 2000.0 });
    }

    const SQUID_FIXTURE: &str = "\
1168300000.123 45 10.0.0.1 TCP_HIT/200 5120 GET http://a/ - NONE/- text/html\n\
1168300001.500 10 10.0.0.2 TCP_MISS/200 1024 GET http://b/ - DIRECT/x image/png\n\
garbage line\n\
1168300000.900 10 10.0.0.3 TCP_MISS/304 0 GET http://c/ - NONE/- -\n";

    #[test]
    fn squid_parser_handles_fixture() {
        let recs = parse_squid(SQUID_FIXTURE);
        assert_eq!(recs.len(), 2); // zero-size 304 dropped
        assert!(recs[0].submit < recs[1].submit);
        assert_eq!(recs[0].bytes, 5120.0);
    }

    #[test]
    fn facebook_standin_matches_published_stats() {
        let recs = synth_trace(&FACEBOOK, 1);
        assert_eq!(recs.len(), FACEBOOK.jobs);
        let mean = recs.iter().map(|r| r.bytes).sum::<f64>() / recs.len() as f64;
        assert!((mean / FACEBOOK.mean_bytes - 1.0).abs() < 0.05, "mean={mean}");
        let max = recs.iter().map(|r| r.bytes).fold(0.0, f64::max);
        // Tail spans ~3 decades above the mean (Fig. 11).
        assert!(max / mean > 150.0, "max/mean={}", max / mean);
        assert!(max <= FACEBOOK.max_bytes * 1.001);
        // Duration near one day.
        let span = recs.last().unwrap().submit - recs[0].submit;
        assert!((span / FACEBOOK.duration_s - 1.0).abs() < 0.2, "span={span}");
    }

    #[test]
    fn ircache_standin_is_heavier_tailed_than_facebook() {
        // Fig. 11: IRCache's biggest requests are ~4 decades above the
        // mean vs ~3 for Facebook.
        let fb = synth_trace(&FACEBOOK, 2);
        let ir = synth_trace(&IRCACHE, 2);
        let decades = |rs: &[Record]| {
            let mean = rs.iter().map(|r| r.bytes).sum::<f64>() / rs.len() as f64;
            let max = rs.iter().map(|r| r.bytes).fold(0.0, f64::max);
            (max / mean).log10()
        };
        assert!(decades(&ir) > decades(&fb), "ir={} fb={}", decades(&ir), decades(&fb));
    }

    #[test]
    fn to_jobs_normalizes_load() {
        let recs = synth_trace(&FACEBOOK, 3);
        let jobs = to_jobs(&recs, 0.9, 0.0, 0);
        let total: f64 = jobs.iter().map(|j| j.size).sum();
        let span = jobs.last().unwrap().arrival;
        assert!((total / span - 0.9).abs() < 1e-6);
        assert!(jobs.iter().all(|j| j.est == j.size)); // sigma 0
    }

    #[test]
    fn to_jobs_applies_errors() {
        let recs = synth_trace(&IRCACHE, 4);
        let jobs = to_jobs(&recs[..1000.min(recs.len())], 0.9, 1.0, 7);
        let off = jobs.iter().filter(|j| (j.est / j.size - 1.0).abs() > 0.01).count();
        assert!(off > 900, "errors applied to most jobs: {off}");
    }

    #[test]
    fn ccdf_is_monotone() {
        let recs = synth_trace(&FACEBOOK, 5);
        let pts = ccdf(&recs, 50);
        for w in pts.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 <= w[0].1);
        }
    }

    #[test]
    fn trace_names_round_trip() {
        for t in [TraceName::Facebook, TraceName::Ircache] {
            assert_eq!(TraceName::from_name(t.name()), Some(t));
        }
        assert_eq!(TraceName::from_name("nope"), None);
        assert_eq!(TraceName::Facebook.stats().jobs, FACEBOOK.jobs);
    }

    #[test]
    fn swim_roundtrip_via_tempfile() {
        let recs = vec![
            Record { submit: 0.0, bytes: 100.0 },
            Record { submit: 1.5, bytes: 2000.0 },
        ];
        let path = std::env::temp_dir().join("psbs_swim_roundtrip.tsv");
        let path = path.to_str().unwrap();
        write_swim(&recs, path).unwrap();
        let back = load_file(path, "swim").unwrap();
        assert_eq!(back, recs);
        let _ = std::fs::remove_file(path);
    }
}
