//! Workload generation and trace replay (paper §6.3, §7.8).

pub mod cache;
pub mod dists;
pub mod synthetic;
pub mod trace_file;
pub mod traces;

pub use synthetic::{synthesize, SizeDist, SynthConfig, SynthSource};
