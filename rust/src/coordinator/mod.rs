//! The online scheduling **service** — PSBS deployed as a coordinator.
//!
//! The paper's closing argument (§8) is that PSBS is *practical*: an
//! O(log n) discipline a real system can run online.  This module is
//! that deployment shape: a leader thread owns the scheduler and a
//! simulated machine of configurable speed; clients submit jobs (with
//! size estimates and weights) over a channel and receive completion
//! notifications.  Time is real (wall-clock scaled by `speed`), so the
//! service measures actual end-to-end latencies — used by
//! `examples/online_service.rs` to report throughput/latency.
//!
//! Offline environment note: tokio is unavailable, so the topology is
//! std::thread + mpsc (DESIGN.md §4); the service is I/O-light and the
//! leader loop is identical in shape to an async reactor — wait until
//! (next internal event | submission), advance, notify.
//!
//! # Crash / retry semantics
//!
//! The [`faults`] module supplies deterministic per-server crash,
//! recovery and slowdown schedules; [`Cluster`] consumes them.  The
//! contract, uniform across every discipline in the zoo:
//!
//! * **Crash** — every copy placed on the server is cancelled through
//!   the PR-5 [`crate::sim::Scheduler::cancel`] path.  Attained work is
//!   lost (no checkpointing); for LAS/FSP/PSBS-family disciplines the
//!   retried copy re-enters as a *fresh* job with its full size, so
//!   their aging/virtual-time machinery restarts cleanly.  A discipline
//!   whose `cancel` rejects (or is unsupported) leaks a phantom into
//!   that server's queue; the cluster still re-dispatches the real job
//!   and reports the anomaly via `kills_rejected`/`kills_unsupported`
//!   in [`faults::FaultStats`] — surfaced as a warning by the sweep and
//!   serve CLIs.
//! * **Retry** — governed by [`faults::RetryPolicy`]: attempt `a+1`
//!   starts `backoff * 2^(a-1)` after the crash (attempt numbering
//!   counts the initial dispatch).  A job crashed on its
//!   `max_attempts`-th attempt is accounted **lost**: it never
//!   completes, and `completions + lost == arrivals` is the conserved
//!   quantity (property-tested across the zoo in `tests/faults.rs`).
//! * **Speculation** — `speculate(after=A, inner=...)` arms a deadline
//!   `A * est` after each dispatch; if the job is still unfinished, a
//!   backup copy launches on the least-loaded other up server.  The
//!   first copy to complete wins; the loser is cancelled.  Each job
//!   completes at most once regardless of copies.
//! * **Empty plan** — a `FaultSpec` with `mtbf <= 0`, unit speeds and
//!   no speculation short-circuits to the original bit-exact cluster
//!   code paths: fault-free runs are bit-identical to earlier PRs.

pub mod cluster;
pub mod faults;
pub mod service;

pub use cluster::{Cluster, Dispatch};
pub use faults::{FaultConfig, FaultPlan, FaultSpec, FaultStats, RetryPolicy};
pub use service::{CompletionInfo, Service, ServiceConfig, ServiceStats};
