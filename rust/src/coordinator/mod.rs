//! The online scheduling **service** — PSBS deployed as a coordinator.
//!
//! The paper's closing argument (§8) is that PSBS is *practical*: an
//! O(log n) discipline a real system can run online.  This module is
//! that deployment shape: a leader thread owns the scheduler and a
//! simulated machine of configurable speed; clients submit jobs (with
//! size estimates and weights) over a channel and receive completion
//! notifications.  Time is real (wall-clock scaled by `speed`), so the
//! service measures actual end-to-end latencies — used by
//! `examples/online_service.rs` to report throughput/latency.
//!
//! Offline environment note: tokio is unavailable, so the topology is
//! std::thread + mpsc (DESIGN.md §4); the service is I/O-light and the
//! leader loop is identical in shape to an async reactor — wait until
//! (next internal event | submission), advance, notify.

pub mod cluster;
pub mod service;

pub use cluster::{Cluster, Dispatch};
pub use service::{CompletionInfo, Service, ServiceConfig, ServiceStats};
