//! Leader thread + submission/notification channels.

use crate::scenario::PolicySpec;
use crate::sim::{Completion, Job, JobStore, Scheduler};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Scheduling discipline: a typed [`PolicySpec`] (string literals
    /// convert via `From<&str>`, so `policy: "psbs".into()` and
    /// composed specs like `"cluster(k=4,inner=psbs)".into()` both
    /// work; parse user input with [`PolicySpec::parse`]).
    pub policy: PolicySpec,
    /// Machine speed: service units per wall-clock second.
    pub speed: f64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { policy: PolicySpec::psbs(), speed: 1000.0 }
    }
}

/// Completion notification delivered to the submitting client.
#[derive(Debug, Clone)]
pub struct CompletionInfo {
    pub job_id: u32,
    /// True size (service units).
    pub size: f64,
    /// Wall-clock end-to-end latency (submit -> completion notification).
    pub latency: Duration,
    /// Slowdown in service-time units: latency / (size / speed).
    pub slowdown: f64,
}

/// Aggregate statistics returned by [`Service::shutdown`].
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    pub completed: u64,
    /// Jobs removed by [`Service::kill`] before completing (§5.2.2
    /// bookkeeping — their completion channels never fire).
    pub killed: u64,
    /// Kill requests that found no pending job (unknown id, already
    /// completed, already killed) — benign races, but recorded.
    pub kills_rejected: u64,
    /// Kill requests for a *pending* job that the discipline's
    /// `cancel` refused.  Either a §5.2.2 bookkeeping gap (a
    /// composed/custom scheduler silently dropping a kill) or a
    /// *designed* rejection: the nonpreemptive disciplines (`spt`,
    /// `sjf`) refuse to kill a job once it has started service — it
    /// runs to completion and its channel still fires.
    pub kills_unsupported: u64,
    pub mean_latency_s: f64,
    /// Streaming (P²) latency percentiles — no per-job retention.
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub mean_slowdown: f64,
    pub max_slowdown: f64,
    pub wall_s: f64,
    /// Fault-side counters from the discipline itself, captured at
    /// shutdown — `Some` when the policy runs the faulty/speculative
    /// cluster path (e.g. a `speculate(...)` spec), `None` for the
    /// plain disciplines.
    pub fault_stats: Option<crate::coordinator::faults::FaultStats>,
}

impl ServiceStats {
    pub fn throughput(&self) -> f64 {
        self.completed as f64 / self.wall_s.max(1e-9)
    }
}

enum Msg {
    Submit { size: f64, est: f64, weight: f64, done_tx: Sender<CompletionInfo> },
    /// Kill a pending job; `ack` receives whether it was still pending.
    Kill { id: u32, ack: Sender<bool> },
    Shutdown,
}

/// Handle to a running scheduling service.
pub struct Service {
    tx: Sender<Msg>,
    join: JoinHandle<ServiceStats>,
}

impl Service {
    /// Start the leader thread.
    pub fn start(cfg: ServiceConfig) -> Service {
        let (tx, rx) = channel();
        let join = std::thread::Builder::new()
            .name("psbs-leader".into())
            .spawn(move || leader_loop(cfg, rx))
            .expect("spawn leader");
        Service { tx, join }
    }

    /// Submit a job; the returned channel yields its completion.
    pub fn submit(&self, size: f64, est: f64, weight: f64) -> Receiver<CompletionInfo> {
        let (done_tx, done_rx) = channel();
        self.tx
            .send(Msg::Submit { size, est, weight, done_tx })
            .expect("leader thread alive");
        done_rx
    }

    /// Kill a submitted job.  Returns `true` if it was still pending
    /// (its completion channel will never fire); `false` if it had
    /// already completed or the policy does not support cancellation.
    /// Job ids are assigned in submission order starting from 0.
    pub fn kill(&self, id: u32) -> bool {
        let (ack_tx, ack_rx) = channel();
        if self.tx.send(Msg::Kill { id, ack: ack_tx }).is_err() {
            return false;
        }
        ack_rx.recv().unwrap_or(false)
    }

    /// Drain remaining work, stop the leader, return statistics.
    pub fn shutdown(self) -> ServiceStats {
        let _ = self.tx.send(Msg::Shutdown);
        self.join.join().expect("leader thread panicked")
    }
}

struct Pending {
    done_tx: Sender<CompletionInfo>,
    submitted: Instant,
    size: f64,
}

fn leader_loop(cfg: ServiceConfig, rx: Receiver<Msg>) -> ServiceStats {
    let mut sched = cfg.policy.build();
    // The leader owns the job store: submissions append rows, kills and
    // completions settle them, and the retired prefix is reclaimed so a
    // long-lived service stays O(active) like the streaming engine.
    let mut store = JobStore::new();
    let t0 = Instant::now();
    let speed = cfg.speed;
    let sim_now = |t0: Instant| t0.elapsed().as_secs_f64() * speed;

    let mut pending: HashMap<u32, Pending> = HashMap::new();
    let mut next_id: u32 = 0;
    let mut last_sim = 0.0_f64;
    let mut done_buf: Vec<Completion> = Vec::new();
    let mut stats = ServiceStats::default();
    let mut lat_sum = 0.0_f64;
    let mut slow_sum = 0.0_f64;
    let mut p50 = crate::stats::P2Quantile::new(0.5);
    let mut p99 = crate::stats::P2Quantile::new(0.99);
    let mut draining = false;

    loop {
        // Advance the scheduler through every internal event up to the
        // current wall-clock instant.
        let now = sim_now(t0);
        advance_through(sched.as_mut(), &mut last_sim, now, &store, &mut done_buf);
        let settled = !done_buf.is_empty();
        for c in done_buf.drain(..) {
            store.mark_completed(c.id);
            if let Some(p) = pending.remove(&c.id) {
                let latency = p.submitted.elapsed();
                let service_time = p.size / speed;
                let info = CompletionInfo {
                    job_id: c.id,
                    size: p.size,
                    latency,
                    slowdown: latency.as_secs_f64() / service_time.max(1e-12),
                };
                stats.completed += 1;
                lat_sum += latency.as_secs_f64();
                p50.observe(latency.as_secs_f64());
                p99.observe(latency.as_secs_f64());
                slow_sum += info.slowdown;
                stats.max_slowdown = stats.max_slowdown.max(info.slowdown);
                let _ = p.done_tx.send(info);
            }
        }
        if settled {
            store.retire();
        }

        if draining && sched.active() == 0 {
            break;
        }

        // Sleep until the next internal event (or forever if idle).
        let timeout = match sched.next_event(last_sim) {
            Some(ev) => {
                let wall = (ev - last_sim).max(0.0) / speed;
                Duration::from_secs_f64(wall.min(0.050)) // re-check >= 20 Hz
            }
            None => Duration::from_millis(50),
        };
        if draining {
            std::thread::sleep(timeout.min(Duration::from_millis(1)));
            continue;
        }
        match rx.recv_timeout(timeout) {
            Ok(Msg::Submit { size, est, weight, done_tx }) => {
                let now = sim_now(t0);
                advance_through(sched.as_mut(), &mut last_sim, now, &store, &mut done_buf);
                let id = next_id;
                next_id += 1;
                let job = Job { id, arrival: now, size, est, weight };
                pending.insert(id, Pending { done_tx, submitted: Instant::now(), size });
                store.push(&job);
                sched.on_arrival(now, id, &store);
            }
            Ok(Msg::Kill { id, ack }) => {
                let now = sim_now(t0);
                advance_through(sched.as_mut(), &mut last_sim, now, &store, &mut done_buf);
                let was_pending = pending.contains_key(&id);
                let killed = was_pending && sched.cancel(last_sim, id);
                if killed {
                    pending.remove(&id);
                    store.mark_cancelled(id);
                    store.retire();
                    stats.killed += 1;
                } else if was_pending {
                    // The discipline refused a kill for a job it still
                    // holds — record the §5.2.2 bookkeeping gap instead
                    // of silently dropping it (the job will run to
                    // completion and its channel will still fire).
                    stats.kills_unsupported += 1;
                } else {
                    stats.kills_rejected += 1;
                }
                let _ = ack.send(killed);
            }
            Ok(Msg::Shutdown) => draining = true,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => draining = true,
        }
    }

    stats.wall_s = t0.elapsed().as_secs_f64();
    stats.fault_stats = sched.fault_stats();
    if stats.completed > 0 {
        stats.mean_latency_s = lat_sum / stats.completed as f64;
        stats.mean_slowdown = slow_sum / stats.completed as f64;
        stats.p50_latency_s = p50.value();
        stats.p99_latency_s = p99.value();
    }
    stats
}

/// Advance the scheduler from `*last` to `target`, stopping at every
/// internal event on the way (the scheduler contract forbids jumping
/// past `next_event`).
fn advance_through(
    sched: &mut dyn Scheduler,
    last: &mut f64,
    target: f64,
    store: &JobStore,
    done: &mut Vec<Completion>,
) {
    let target = target.max(*last);
    loop {
        match sched.next_event(*last) {
            Some(ev) if ev <= target => {
                sched.advance(*last, ev.max(*last), store, done);
                *last = ev.max(*last);
            }
            _ => break,
        }
    }
    sched.advance(*last, target, store, done);
    *last = target;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_and_completes_jobs() {
        let svc = Service::start(ServiceConfig { policy: "psbs".into(), speed: 10_000.0 });
        // 20 jobs of 10 units each: ~1ms apiece at this speed.
        let rxs: Vec<_> = (0..20).map(|_| svc.submit(10.0, 10.0, 1.0)).collect();
        let mut got = 0;
        for rx in rxs {
            let info = rx.recv_timeout(Duration::from_secs(5)).expect("completion");
            assert_eq!(info.size, 10.0);
            got += 1;
        }
        assert_eq!(got, 20);
        let stats = svc.shutdown();
        assert_eq!(stats.completed, 20);
        assert!(stats.mean_latency_s > 0.0);
    }

    #[test]
    fn weighted_job_finishes_before_equal_light_job() {
        // Submit two identical long jobs, one weight 8: under PSBS the
        // heavy one must complete first.
        let svc = Service::start(ServiceConfig { policy: "psbs".into(), speed: 2_000.0 });
        let light = svc.submit(100.0, 100.0, 1.0);
        let heavy = svc.submit(100.0, 100.0, 8.0);
        let l = light.recv_timeout(Duration::from_secs(5)).unwrap();
        let h = heavy.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(h.latency <= l.latency, "heavy {:?} vs light {:?}", h.latency, l.latency);
        svc.shutdown();
    }

    #[test]
    fn every_policy_runs_in_the_service() {
        for policy in crate::sched::ALL_POLICIES {
            let svc = Service::start(ServiceConfig {
                policy: (*policy).into(),
                speed: 50_000.0,
            });
            let rx = svc.submit(5.0, 5.0, 1.0);
            rx.recv_timeout(Duration::from_secs(5))
                .unwrap_or_else(|e| panic!("policy {policy}: {e}"));
            let stats = svc.shutdown();
            assert_eq!(stats.completed, 1, "policy {policy}");
        }
    }

    /// A speculative cluster policy runs in the service and surfaces
    /// its fault-side counters at shutdown; plain disciplines stay
    /// `None`.
    #[test]
    fn speculative_policy_reports_fault_stats() {
        let svc = Service::start(ServiceConfig {
            policy: "speculate(after=4,inner=cluster(k=2,dispatch=leastwork,inner=psbs))".into(),
            speed: 10_000.0,
        });
        let rxs: Vec<_> = (0..8).map(|_| svc.submit(10.0, 10.0, 1.0)).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).expect("completion");
        }
        let stats = svc.shutdown();
        assert_eq!(stats.completed, 8);
        let f = stats.fault_stats.expect("speculative cluster reports fault stats");
        assert_eq!(f.lost, 0, "no faults injected: nothing may be lost");

        let svc = Service::start(ServiceConfig { policy: "psbs".into(), speed: 10_000.0 });
        svc.submit(1.0, 1.0, 1.0).recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(svc.shutdown().fault_stats.is_none(), "plain discipline has no fault stats");
    }

    /// `Service::kill` works for EVERY entry in `ALL_POLICIES` — the
    /// §5.2.2 bookkeeping with no default-`false` gaps — and the
    /// accounting distinguishes kills from benign rejections.  The
    /// nonpreemptive disciplines kill *waiting* jobs; their started
    /// job rejects the kill by design (`kills_unsupported`).
    #[test]
    fn every_policy_supports_kill() {
        for policy in crate::sched::ALL_POLICIES {
            let svc = Service::start(ServiceConfig {
                policy: (*policy).into(),
                speed: 10_000.0,
            });
            if matches!(*policy, "spt" | "sjf") {
                // Occupy the server (~1 s of wall clock at this speed —
                // ample margin for the kill to land while it serves),
                // then kill the waiting victim behind it.
                let serving_rx = svc.submit(1e4, 1e4, 1.0);
                let victim_rx = svc.submit(1e9, 1e9, 1.0);
                assert!(svc.kill(1), "policy {policy}: waiting job must be killable");
                assert!(!svc.kill(1), "policy {policy}: double kill reports false");
                assert!(!svc.kill(0), "policy {policy}: started job rejects the kill");
                assert!(
                    victim_rx.recv_timeout(Duration::from_millis(50)).is_err(),
                    "policy {policy}: killed job's channel must never fire"
                );
                serving_rx
                    .recv_timeout(Duration::from_secs(10))
                    .expect("the unkillable started job runs to completion");
                let stats = svc.shutdown();
                assert_eq!(stats.completed, 1, "policy {policy}");
                assert_eq!(stats.killed, 1, "policy {policy}");
                assert_eq!(stats.kills_rejected, 1, "policy {policy} (the double kill)");
                assert_eq!(
                    stats.kills_unsupported, 1,
                    "policy {policy}: the started-job rejection is recorded"
                );
                continue;
            }
            // A job far too large to complete before the kill lands.
            let rx = svc.submit(1e9, 1e9, 1.0);
            assert!(svc.kill(0), "policy {policy}: kill must succeed");
            assert!(!svc.kill(0), "policy {policy}: double kill reports false");
            assert!(
                rx.recv_timeout(Duration::from_millis(50)).is_err(),
                "policy {policy}: killed job's channel must never fire"
            );
            let stats = svc.shutdown();
            assert_eq!(stats.completed, 0, "policy {policy}");
            assert_eq!(stats.killed, 1, "policy {policy}");
            assert_eq!(stats.kills_rejected, 1, "policy {policy} (the double kill)");
            assert_eq!(
                stats.kills_unsupported, 0,
                "policy {policy} silently dropped a kill"
            );
        }
    }
}
