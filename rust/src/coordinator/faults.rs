//! Deterministic fault injection for the cluster layer.
//!
//! Real deployments (the HFSP context of PAPER.md §2, the
//! data-intensive simulators of arXiv:1306.6023) lose machines mid-job,
//! run stragglers, and re-execute work.  This module provides the
//! *schedule* side of that story: a seeded, lazily generated
//! [`FaultPlan`] of per-server crash/recovery windows and degraded
//! (straggler) intervals, the [`RetryPolicy`] governing re-dispatch of
//! jobs lost to a crash, and the [`FaultStats`] ledger the metrics
//! layer reads (goodput, wasted work, restart counts).
//!
//! The *mechanism* side — cancelling a crashed server's jobs through
//! the PR-5 kill path, re-dispatching with lost attained work, backup
//! copies — lives in [`crate::coordinator::Cluster`], which consumes a
//! plan.  Everything here is pure bookkeeping over the deterministic
//! [`Rng`]: the same `(FaultConfig, k)` always yields the same
//! schedule, so fault runs are exactly reproducible (`--seed`
//! discipline, like every other experiment).

use crate::util::rng::Rng;

/// Stochastic shape of one server's failure process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Mean time between failures (exponential up-times).
    /// `<= 0` disables crashes *and* degraded intervals entirely.
    pub mtbf: f64,
    /// Mean time to repair (exponential down-times).
    pub mttr: f64,
    /// Speed multiplier inside degraded (straggler) windows, drawn
    /// from an independent stream with the same mtbf/mttr means;
    /// `1.0` disables them.
    pub slowdown: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec { mtbf: 0.0, mttr: 0.0, slowdown: 1.0 }
    }
}

/// Re-dispatch policy for jobs whose server crashed under them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total dispatch attempts a job may consume (the first dispatch
    /// counts).  A job crashed on its `max_attempts`-th attempt is
    /// accounted lost.
    pub max_attempts: u32,
    /// Exponential-backoff base: the `k`-th retry waits
    /// `backoff * 2^(k-1)` after the crash; `0` re-dispatches
    /// immediately.
    pub backoff: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, backoff: 0.0 }
    }
}

/// Everything needed to reproduce a fault run: the failure shape, the
/// retry policy, and the schedule seed.  This is what a scenario
/// `[faults]` section parses into.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultConfig {
    pub spec: FaultSpec,
    pub retry: RetryPolicy,
    pub seed: u64,
}

impl FaultConfig {
    /// An empty config injects nothing; the cluster must then stay
    /// bit-identical to a fault-free run (the standing oracle
    /// discipline — pinned by `empty_fault_plan_is_bit_identical`).
    pub fn is_empty(&self) -> bool {
        self.spec.mtbf <= 0.0
    }
}

/// A state change in one server's fault schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultEvent {
    /// The server dies: in-flight and queued jobs must be killed.
    Crash,
    /// The server comes back at full speed, empty.
    Recover,
    /// A degraded window opens (rate × `slowdown`).
    SlowStart,
    /// The degraded window closes.
    SlowEnd,
}

/// One server's lazily generated schedule: alternating
/// up (`Exp(mtbf)`) / down (`Exp(mttr)`) windows, plus an independent
/// stream of degraded windows with the same means.  Windows are
/// regenerated as they are consumed, so the schedule has no horizon —
/// it covers however long the run needs.
#[derive(Debug, Clone)]
pub struct ServerFaults {
    spec: FaultSpec,
    rng_crash: Rng,
    rng_slow: Rng,
    /// Pending crash window `(crash_at, recover_at)`.
    crash: Option<(f64, f64)>,
    /// Pending degraded window `(start, end)`.
    slow: Option<(f64, f64)>,
    /// Currently inside the crash window (server down)?
    pub down: bool,
    /// Currently inside a degraded window?
    pub slowed: bool,
}

impl ServerFaults {
    fn exp(rng: &mut Rng, mean: f64) -> f64 {
        -mean * rng.u01_open_left().ln()
    }

    fn new(cfg: &FaultConfig, server: u64) -> ServerFaults {
        let base = Rng::new(cfg.seed ^ 0xFA_0175);
        let mut sf = ServerFaults {
            spec: cfg.spec,
            rng_crash: base.substream(2 * server),
            rng_slow: base.substream(2 * server + 1),
            crash: None,
            slow: None,
            down: false,
            slowed: false,
        };
        sf.refill_crash(0.0);
        sf.refill_slow(0.0);
        sf
    }

    fn refill_crash(&mut self, from: f64) {
        if self.spec.mtbf > 0.0 {
            let at = from + Self::exp(&mut self.rng_crash, self.spec.mtbf);
            let until = at + Self::exp(&mut self.rng_crash, self.spec.mttr);
            self.crash = Some((at, until));
        }
    }

    fn refill_slow(&mut self, from: f64) {
        if self.spec.mtbf > 0.0 && self.spec.slowdown < 1.0 {
            let at = from + Self::exp(&mut self.rng_slow, self.spec.mtbf);
            let until = at + Self::exp(&mut self.rng_slow, self.spec.mttr);
            self.slow = Some((at, until));
        }
    }

    /// Earliest pending state change, if any.
    pub fn next_change(&self) -> Option<f64> {
        let c = self.crash.map(|(at, until)| if self.down { until } else { at });
        let s = self.slow.map(|(at, until)| if self.slowed { until } else { at });
        match (c, s) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Consume the next state change landing at or before `t` (callers
    /// loop until `None` to catch up).  Degraded-window transitions
    /// apply before crash transitions at equal instants — they kill
    /// nothing, so the order only needs to be deterministic.
    pub fn pop_change(&mut self, t: f64) -> Option<FaultEvent> {
        if let Some((at, until)) = self.slow {
            if !self.slowed && at <= t {
                self.slowed = true;
                return Some(FaultEvent::SlowStart);
            }
            if self.slowed && until <= t {
                self.slowed = false;
                self.refill_slow(until);
                return Some(FaultEvent::SlowEnd);
            }
        }
        if let Some((at, until)) = self.crash {
            if !self.down && at <= t {
                self.down = true;
                return Some(FaultEvent::Crash);
            }
            if self.down && until <= t {
                self.down = false;
                self.refill_crash(until);
                return Some(FaultEvent::Recover);
            }
        }
        None
    }

    /// The recovery instant, when the server is currently down.
    pub fn recover_at(&self) -> Option<f64> {
        match self.crash {
            Some((_, until)) if self.down => Some(until),
            _ => None,
        }
    }

    /// The fault-induced rate multiplier right now: 0 while down,
    /// `slowdown` inside a degraded window, 1 otherwise.
    pub fn rate(&self) -> f64 {
        if self.down {
            0.0
        } else if self.slowed {
            self.spec.slowdown
        } else {
            1.0
        }
    }
}

/// The per-server fault schedules of a `k`-server cluster.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub servers: Vec<ServerFaults>,
}

impl FaultPlan {
    pub fn new(cfg: &FaultConfig, k: usize) -> FaultPlan {
        FaultPlan { servers: (0..k).map(|s| ServerFaults::new(cfg, s as u64)).collect() }
    }
}

/// Fault-side accounting, surfaced through
/// [`crate::sim::Scheduler::fault_stats`] and aggregated into the
/// sweep counter tables.  Counts are exact (not sampled); the work
/// ledger is in size units (server busy time at unit local rate).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultStats {
    /// Server crash events that fired.
    pub crashes: u64,
    /// Job copies removed through the kill path (crash victims and
    /// speculation losers).
    pub killed: u64,
    /// Kill attempts the inner scheduler rejected (a placed copy it no
    /// longer knew) — always 0 unless a discipline's bookkeeping leaks.
    pub kills_rejected: u64,
    /// Kill attempts on disciplines without `cancel` support — always
    /// 0 since PR 5 made the whole zoo killable.
    pub kills_unsupported: u64,
    /// Re-dispatches of crash victims.
    pub restarts: u64,
    /// Backup copies launched by speculative execution.
    pub speculations: u64,
    /// Jobs that exhausted `max_attempts` and were dropped.
    pub lost: u64,
    /// Total server busy time (size units): everything served,
    /// including attained work later thrown away.
    pub work_done: f64,
    /// Sizes of the jobs that actually completed.
    pub useful_work: f64,
}

impl FaultStats {
    /// Fraction of served work that was thrown away (crash losses and
    /// speculation duplicates); 0 when nothing ran.
    pub fn wasted_fraction(&self) -> f64 {
        if self.work_done <= 0.0 {
            0.0
        } else {
            (self.work_done - self.useful_work).max(0.0) / self.work_done
        }
    }

    /// Element-wise sum (aggregation across repetitions / cells).
    pub fn absorb(&mut self, o: &FaultStats) {
        self.crashes += o.crashes;
        self.killed += o.killed;
        self.kills_rejected += o.kills_rejected;
        self.kills_unsupported += o.kills_unsupported;
        self.restarts += o.restarts;
        self.speculations += o.speculations;
        self.lost += o.lost;
        self.work_done += o.work_done;
        self.useful_work += o.useful_work;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mtbf: f64, mttr: f64, slowdown: f64, seed: u64) -> FaultConfig {
        FaultConfig {
            spec: FaultSpec { mtbf, mttr, slowdown },
            retry: RetryPolicy::default(),
            seed,
        }
    }

    #[test]
    fn empty_spec_yields_no_events() {
        let plan = FaultPlan::new(&cfg(0.0, 1.0, 0.5, 7), 4);
        for s in &plan.servers {
            assert_eq!(s.next_change(), None);
            assert_eq!(s.rate(), 1.0);
        }
        assert!(cfg(0.0, 1.0, 0.5, 7).is_empty());
        assert!(!cfg(10.0, 1.0, 1.0, 7).is_empty());
    }

    #[test]
    fn schedule_is_deterministic_and_alternates() {
        let mk = || FaultPlan::new(&cfg(10.0, 2.0, 1.0, 42), 2);
        let mut a = mk();
        let mut b = mk();
        for _ in 0..100 {
            for s in 0..2 {
                let ta = a.servers[s].next_change().unwrap();
                let tb = b.servers[s].next_change().unwrap();
                assert_eq!(ta, tb, "same seed must give the same schedule");
                let eva = a.servers[s].pop_change(ta).unwrap();
                let evb = b.servers[s].pop_change(tb).unwrap();
                assert_eq!(eva, evb);
                // No slowdown stream here: strict crash/recover alternation.
                match eva {
                    FaultEvent::Crash => assert!(a.servers[s].down),
                    FaultEvent::Recover => assert!(!a.servers[s].down),
                    _ => panic!("slowdown event from a slowdown-free spec"),
                }
            }
        }
    }

    #[test]
    fn changes_are_time_ordered_and_rates_match_state() {
        let mut plan = FaultPlan::new(&cfg(5.0, 1.0, 0.25, 3), 1);
        let s = &mut plan.servers[0];
        let mut last = 0.0;
        for _ in 0..200 {
            let t = s.next_change().unwrap();
            assert!(t >= last, "schedule must be monotone: {t} after {last}");
            s.pop_change(t).unwrap();
            last = t;
            let want = if s.down {
                0.0
            } else if s.slowed {
                0.25
            } else {
                1.0
            };
            assert_eq!(s.rate(), want);
            if s.down {
                assert_eq!(s.recover_at(), Some(s.crash.unwrap().1));
            }
        }
    }

    #[test]
    fn catch_up_consumes_everything_up_to_t() {
        let mut plan = FaultPlan::new(&cfg(1.0, 0.5, 0.5, 11), 1);
        let s = &mut plan.servers[0];
        while s.pop_change(100.0).is_some() {}
        assert!(s.next_change().unwrap() > 100.0);
    }

    #[test]
    fn distinct_servers_get_distinct_streams() {
        let plan = FaultPlan::new(&cfg(10.0, 1.0, 1.0, 0), 2);
        assert_ne!(plan.servers[0].next_change(), plan.servers[1].next_change());
    }

    #[test]
    fn wasted_fraction_and_absorb() {
        let mut a = FaultStats { work_done: 10.0, useful_work: 8.0, crashes: 1, ..Default::default() };
        assert!((a.wasted_fraction() - 0.2).abs() < 1e-12);
        assert_eq!(FaultStats::default().wasted_fraction(), 0.0);
        let b = FaultStats { work_done: 2.0, useful_work: 2.0, lost: 3, ..Default::default() };
        a.absorb(&b);
        assert_eq!(a.crashes, 1);
        assert_eq!(a.lost, 3);
        assert!((a.work_done - 12.0).abs() < 1e-12);
    }
}
