//! Multi-server deployment: a dispatcher routing jobs to `k`
//! independent single-server schedulers.
//!
//! The paper's §8 pitch is that PSBS can "guide the design of
//! schedulers in real, complex systems"; real systems (web farms,
//! Hadoop as in HFSP [15]) are multi-server with immediate dispatch.
//! This module composes the single-server disciplines into that shape:
//! each of `k` servers runs its own scheduler instance at unit rate;
//! an arriving job is routed once (no migration) by a [`Dispatch`]
//! policy.  The composite implements [`Scheduler`] itself, so the same
//! engine, metrics and figure harness apply unchanged.
//!
//! Dispatch policies:
//! * [`Dispatch::RoundRobin`] — the size-oblivious baseline;
//! * [`Dispatch::LeastWork`] — route to the server with the least
//!   outstanding *estimated* work (the size-based policy; with wrong
//!   estimates it inherits exactly the error-sensitivity questions the
//!   paper studies, now at the routing layer too);
//! * [`Dispatch::Random`] — seeded uniform (the mean-field reference).

use crate::scenario::PolicySpec;
use crate::sim::{Completion, Job, Scheduler};
use crate::util::rng::Rng;

/// Routing policy for new arrivals.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dispatch {
    RoundRobin,
    LeastWork,
    Random,
}

/// `k` single-server schedulers behind one dispatcher.
pub struct Cluster {
    servers: Vec<Box<dyn Scheduler>>,
    dispatch: Dispatch,
    /// Outstanding estimated work per server (LeastWork bookkeeping).
    est_backlog: Vec<f64>,
    /// `placement[id] = Some((server, estimate))` for completion-time
    /// bookkeeping.  Dense by job id — the same 0..n contract the
    /// engine asserts — so the per-arrival/per-completion touch is one
    /// array slot, not a hash probe.
    placement: Vec<Option<(usize, f64)>>,
    rr_next: usize,
    rng: Rng,
}

impl Cluster {
    /// Build `k` servers each running `policy` — a typed
    /// [`PolicySpec`], or any spec string via the `From<&str>`
    /// conversion (which panics on an invalid literal; parse user
    /// input with [`PolicySpec::parse`] first).
    ///
    /// Always `Some` since validation moved into the spec parser; the
    /// `Option` return is kept so the pre-spec call sites
    /// (`Cluster::new("psbs", ...).unwrap()`) stay source-compatible.
    /// New code should prefer [`Cluster::from_spec`].
    pub fn new(
        policy: impl Into<PolicySpec>,
        k: usize,
        dispatch: Dispatch,
        seed: u64,
    ) -> Option<Cluster> {
        Some(Cluster::from_spec(&policy.into(), k, dispatch, seed))
    }

    /// Spec-native constructor (what `PolicySpec::build_seeded` uses).
    pub fn from_spec(policy: &PolicySpec, k: usize, dispatch: Dispatch, seed: u64) -> Cluster {
        assert!(k >= 1);
        Cluster {
            servers: (0..k).map(|_| policy.build_seeded(seed)).collect(),
            dispatch,
            est_backlog: vec![0.0; k],
            placement: Vec::new(),
            rr_next: 0,
            rng: Rng::new(seed ^ 0xC105_7E2),
        }
    }

    /// Dense-slot accessor, growing the table to cover `id`.
    fn slot(&mut self, id: u32) -> &mut Option<(usize, f64)> {
        let i = id as usize;
        if i >= self.placement.len() {
            self.placement.resize(i + 1, None);
        }
        &mut self.placement[i]
    }

    /// Clear a slot and reclaim the trailing tail, keeping the table
    /// proportional to the live id span even under the online
    /// service's forever-growing job ids.  Amortized O(1).
    fn clear_slot(&mut self, id: u32) -> Option<(usize, f64)> {
        let taken = self.placement.get_mut(id as usize).and_then(|s| s.take());
        while self.placement.last() == Some(&None) {
            self.placement.pop();
        }
        taken
    }

    pub fn k(&self) -> usize {
        self.servers.len()
    }

    fn pick(&mut self) -> usize {
        match self.dispatch {
            Dispatch::RoundRobin => {
                let s = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.servers.len();
                s
            }
            Dispatch::Random => self.rng.below(self.servers.len() as u64) as usize,
            Dispatch::LeastWork => {
                let mut best = 0;
                for (i, &w) in self.est_backlog.iter().enumerate() {
                    if w < self.est_backlog[best] {
                        best = i;
                    }
                }
                best
            }
        }
    }
}

impl Scheduler for Cluster {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn on_arrival(&mut self, now: f64, job: &Job) {
        let s = self.pick();
        self.est_backlog[s] += job.est;
        *self.slot(job.id) = Some((s, job.est));
        self.servers[s].on_arrival(now, job);
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        self.servers
            .iter()
            .filter_map(|s| s.next_event(now))
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    fn advance(&mut self, now: f64, t: f64, done: &mut Vec<Completion>) {
        // Servers are independent; each advances through its own
        // internal events up to t (a composite step may cross several
        // per-server events, which the engine cannot see individually).
        for s in self.servers.iter_mut() {
            let mut local_now = now;
            loop {
                match s.next_event(local_now) {
                    Some(ev) if ev < t => {
                        s.advance(local_now, ev.max(local_now), done);
                        local_now = ev.max(local_now);
                    }
                    _ => break,
                }
            }
            s.advance(local_now, t, done);
        }
        for c in done.iter() {
            if let Some((srv, est)) = self.clear_slot(c.id) {
                self.est_backlog[srv] = (self.est_backlog[srv] - est).max(0.0);
            }
        }
    }

    fn active(&self) -> usize {
        self.servers.iter().map(|s| s.active()).sum()
    }

    fn cancel(&mut self, now: f64, id: u32) -> bool {
        let Some(&Some((srv, est))) = self.placement.get(id as usize) else { return false };
        if self.servers[srv].cancel(now, id) {
            self.est_backlog[srv] = (self.est_backlog[srv] - est).max(0.0);
            self.clear_slot(id);
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched;
    use crate::sim::run;
    use crate::workload::SynthConfig;

    #[test]
    fn single_server_cluster_equals_plain_scheduler() {
        let cfg = SynthConfig::default().with_njobs(500);
        let jobs = crate::workload::synthesize(&cfg, 3);
        for dispatch in [Dispatch::RoundRobin, Dispatch::LeastWork, Dispatch::Random] {
            let mut c = Cluster::new("psbs", 1, dispatch, 0).unwrap();
            let a = run(&mut c, &jobs).completion;
            let mut s = sched::by_name("psbs").unwrap();
            let b = run(s.as_mut(), &jobs).completion;
            assert_eq!(a, b, "k=1 must be transparent ({dispatch:?})");
        }
    }

    #[test]
    fn all_jobs_complete_on_k_servers() {
        let cfg = SynthConfig::default().with_njobs(2_000);
        let jobs = crate::workload::synthesize(&cfg, 4);
        for k in [2, 4, 8] {
            let mut c = Cluster::new("psbs", k, Dispatch::LeastWork, 1).unwrap();
            let r = run(&mut c, &jobs);
            assert!(r.completion.iter().all(|x| x.is_finite()), "k={k}");
            assert_eq!(c.active(), 0);
        }
    }

    #[test]
    fn more_servers_never_hurt_mst_much() {
        // With load 0.9 against ONE unit server, k servers are heavily
        // under-loaded: MST must drop toward the mean size.
        let cfg = SynthConfig::default().with_njobs(3_000);
        let jobs = crate::workload::synthesize(&cfg, 5);
        let mst = |k| {
            let mut c = Cluster::new("psbs", k, Dispatch::LeastWork, 2).unwrap();
            run(&mut c, &jobs).mst(&jobs)
        };
        let m1 = mst(1);
        let m4 = mst(4);
        assert!(m4 < m1, "k=4 ({m4}) should beat k=1 ({m1})");
    }

    #[test]
    fn least_work_beats_round_robin_on_skew() {
        // Heavy-tailed sizes + 4 servers at high per-server load:
        // size-aware routing balances elephants, round-robin collides
        // them. Scale arrivals so per-server load stays high.
        let cfg = SynthConfig::default().with_njobs(4_000).with_load(3.6); // ~0.9 per server
        let jobs = crate::workload::synthesize(&cfg, 6);
        let mst = |d| {
            let mut c = Cluster::new("psbs", 4, d, 3).unwrap();
            run(&mut c, &jobs).mst(&jobs)
        };
        let lw = mst(Dispatch::LeastWork);
        let rr = mst(Dispatch::RoundRobin);
        assert!(lw < rr, "least-work {lw} should beat round-robin {rr}");
    }

    #[test]
    fn cluster_cancellation_updates_backlog() {
        let mut c = Cluster::new("psbs", 2, Dispatch::LeastWork, 4).unwrap();
        c.on_arrival(0.0, &Job::exact(0, 0.0, 100.0)); // -> server 0
        c.on_arrival(0.0, &Job::exact(1, 0.0, 1.0)); // -> server 1 (least work)
        assert_eq!(c.active(), 2);
        assert!(c.cancel(0.0, 0));
        assert_eq!(c.active(), 1);
        // Next big job routes to the now-empty server 0.
        c.on_arrival(0.0, &Job::exact(2, 0.0, 50.0));
        assert!(c.est_backlog[0] >= 50.0 - 1e-9);
    }

    #[test]
    fn dispatch_is_deterministic_per_seed() {
        let cfg = SynthConfig::default().with_njobs(300);
        let jobs = crate::workload::synthesize(&cfg, 8);
        let run_once = || {
            let mut c = Cluster::new("psbs", 3, Dispatch::Random, 42).unwrap();
            run(&mut c, &jobs).completion
        };
        assert_eq!(run_once(), run_once());
    }
}
