//! Multi-server deployment: a dispatcher routing jobs to `k`
//! independent single-server schedulers.
//!
//! The paper's §8 pitch is that PSBS can "guide the design of
//! schedulers in real, complex systems"; real systems (web farms,
//! Hadoop as in HFSP [15]) are multi-server with immediate dispatch.
//! This module composes the single-server disciplines into that shape:
//! each of `k` servers runs its own scheduler instance; an arriving job
//! is routed once by a [`Dispatch`] policy.  The composite implements
//! [`Scheduler`] itself, so the same engine, metrics and figure harness
//! apply unchanged.
//!
//! Dispatch policies:
//! * [`Dispatch::RoundRobin`] — the size-oblivious baseline;
//! * [`Dispatch::LeastWork`] — route to the server with the least
//!   outstanding *estimated* work (the size-based policy; with wrong
//!   estimates it inherits exactly the error-sensitivity questions the
//!   paper studies, now at the routing layer too);
//! * [`Dispatch::Random`] — seeded uniform (the mean-field reference);
//! * [`Dispatch::Jsq`] — join-the-shortest-queue by job count;
//! * [`Dispatch::RandomD`] — power-of-d-choices: `d` uniform probes,
//!   least estimated work among them;
//! * [`Dispatch::LeastTime`] — least estimated *completion time*
//!   (`backlog / speed`), the speed-aware routing for heterogeneous
//!   clusters.
//!
//! Beyond dispatch, the cluster is where the robustness machinery
//! lives (see [`crate::coordinator::faults`] for the schedules):
//!
//! * **Heterogeneous speeds** — per-server static multipliers; each
//!   inner scheduler runs in its own *local* clock (work units), and
//!   the cluster translates times at the boundary.
//! * **Crashes** — at a fault-plan crash instant, every copy placed on
//!   the server is cancelled through the PR-5 kill path (attained work
//!   is lost), then re-dispatched under the [`RetryPolicy`]'s
//!   exponential backoff; a job crashed on its `max_attempts`-th
//!   attempt is accounted lost.  Recovered servers come back empty at
//!   full speed.
//! * **Degraded windows** — straggler intervals scale a server's rate
//!   by `slowdown` without killing anything.
//! * **Speculative execution** — with a `speculate(after=A,...)` spec,
//!   a job still unfinished `A * est` after dispatch launches a backup
//!   copy on the least-loaded *other* alive server; the first copy to
//!   finish wins and the loser is killed.  Each job completes at most
//!   once, whichever copy wins.
//!
//! All of that is gated: with unit speeds, no fault plan and no
//! speculation the cluster takes the original bit-exact code paths
//! (`plain` mode), so fault-free runs stay bit-identical to every
//! earlier PR — the standing oracle discipline.

use crate::coordinator::faults::{FaultConfig, FaultEvent, FaultPlan, FaultStats, RetryPolicy};
use crate::scenario::PolicySpec;
use crate::sched::MinHeap;
use crate::sim::{Completion, JobId, JobStore, Scheduler};
use crate::util::rng::Rng;

/// Routing policy for new arrivals.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dispatch {
    RoundRobin,
    LeastWork,
    Random,
    /// Join-the-shortest-queue: fewest active jobs (ties: lowest index).
    Jsq,
    /// Power-of-d-choices: `d` uniform probes, least estimated work
    /// among the probed servers.
    RandomD(u32),
    /// Least estimated completion time: `est_backlog / speed` (equals
    /// [`Dispatch::LeastWork`] on homogeneous clusters).
    LeastTime,
}

/// Where one job currently lives.  Job fields themselves (size, est,
/// weight) are NOT carried — retries and backups re-read them from the
/// engine's [`JobStore`], whose row stays live until the job really
/// completes or is lost.
#[derive(Debug, Clone)]
struct Placement {
    /// Primary copy's server.
    srv: usize,
    /// Estimate charged to the backlog (per copy).
    est: f64,
    /// Speculative backup copy's server, if launched.
    backup: Option<usize>,
    /// Dispatch attempts consumed (1 = first dispatch; 0 in plain mode).
    attempts: u32,
}

/// `k` single-server schedulers behind one dispatcher.
pub struct Cluster {
    servers: Vec<Box<dyn Scheduler>>,
    dispatch: Dispatch,
    /// Outstanding estimated work per server (dispatch bookkeeping).
    est_backlog: Vec<f64>,
    /// `placement[id]` for completion-time bookkeeping.  Dense by job
    /// id — the same 0..n contract the engine asserts — so the
    /// per-arrival/per-completion touch is one array slot, not a hash
    /// probe.
    placement: Vec<Option<Placement>>,
    rr_next: usize,
    rng: Rng,
    /// Static per-server speed multipliers (all 1.0 = homogeneous).
    speeds: Vec<f64>,
    /// The fault/speed/speculation layer is inert: run the original
    /// bit-exact paths.
    plain: bool,
    // ---- state below is only touched when `!plain` ----
    /// Per-server local clocks: the inner scheduler's time (work
    /// units).  `synced[s]` marks a clock that has always run at rate
    /// exactly 1.0, where local == global with no float arithmetic.
    local: Vec<f64>,
    synced: Vec<bool>,
    faults: Option<FaultPlan>,
    retry: RetryPolicy,
    /// Jobs waiting for re-dispatch: key = due time, seq = job id,
    /// payload = attempts already consumed (fields re-read from the
    /// store at dispatch time).
    pending: MinHeap<u32>,
    /// Speculation threshold: launch a backup when a job is still
    /// unfinished `after * est` past its dispatch.
    spec_after: Option<f64>,
    /// Armed speculation deadlines: key = deadline, seq = job id.
    spec_deadlines: MinHeap<()>,
    /// Jobs released and not yet completed or lost.
    live: usize,
    stats: FaultStats,
    /// Scratch buffer for per-server completion translation.
    buf: Vec<Completion>,
}

impl Cluster {
    /// Build `k` servers each running `policy` — a typed
    /// [`PolicySpec`], or any spec string via the `From<&str>`
    /// conversion (which panics on an invalid literal; parse user
    /// input with [`PolicySpec::parse`] first).
    ///
    /// Always `Some` since validation moved into the spec parser; the
    /// `Option` return is kept so the pre-spec call sites
    /// (`Cluster::new("psbs", ...).unwrap()`) stay source-compatible.
    /// New code should prefer [`Cluster::from_spec`].
    pub fn new(
        policy: impl Into<PolicySpec>,
        k: usize,
        dispatch: Dispatch,
        seed: u64,
    ) -> Option<Cluster> {
        Some(Cluster::from_spec(&policy.into(), k, dispatch, seed))
    }

    /// Spec-native constructor (what `PolicySpec::build_seeded` uses):
    /// homogeneous, fault-free, no speculation.
    pub fn from_spec(policy: &PolicySpec, k: usize, dispatch: Dispatch, seed: u64) -> Cluster {
        Cluster::from_spec_full(policy, k, dispatch, &[], seed, None, None)
    }

    /// Full constructor: per-server `speeds` (empty = all 1.0), an
    /// optional fault-injection config and an optional speculation
    /// threshold.  With unit speeds, an empty (or absent) config and no
    /// speculation, the cluster runs the original bit-exact paths.
    pub fn from_spec_full(
        policy: &PolicySpec,
        k: usize,
        dispatch: Dispatch,
        speeds: &[f64],
        seed: u64,
        faults: Option<&FaultConfig>,
        spec_after: Option<f64>,
    ) -> Cluster {
        assert!(k >= 1);
        let speeds: Vec<f64> = if speeds.is_empty() {
            vec![1.0; k]
        } else {
            assert_eq!(speeds.len(), k, "need one speed per server");
            speeds.to_vec()
        };
        assert!(speeds.iter().all(|&s| s > 0.0), "server speeds must be positive");
        let cfg = faults.filter(|c| !c.is_empty());
        let plain =
            cfg.is_none() && spec_after.is_none() && speeds.iter().all(|&s| s == 1.0);
        Cluster {
            servers: (0..k).map(|_| policy.build_seeded(seed)).collect(),
            dispatch,
            est_backlog: vec![0.0; k],
            placement: Vec::new(),
            rr_next: 0,
            rng: Rng::new(seed ^ 0xC105_7E2),
            local: vec![0.0; k],
            synced: vec![true; k],
            faults: cfg.map(|c| FaultPlan::new(c, k)),
            retry: cfg.map(|c| c.retry).unwrap_or_default(),
            pending: MinHeap::with_index(),
            spec_after,
            spec_deadlines: MinHeap::with_index(),
            live: 0,
            stats: FaultStats::default(),
            speeds,
            plain,
            buf: Vec::new(),
        }
    }

    /// Dense-slot accessor, growing the table to cover `id`.
    fn slot(&mut self, id: u32) -> &mut Option<Placement> {
        let i = id as usize;
        if i >= self.placement.len() {
            self.placement.resize(i + 1, None);
        }
        &mut self.placement[i]
    }

    /// Clear a slot and reclaim the trailing tail, keeping the table
    /// proportional to the live id span even under the online
    /// service's forever-growing job ids.  Amortized O(1).
    fn clear_slot(&mut self, id: u32) -> Option<Placement> {
        let taken = self.placement.get_mut(id as usize).and_then(|s| s.take());
        while matches!(self.placement.last(), Some(None)) {
            self.placement.pop();
        }
        taken
    }

    pub fn k(&self) -> usize {
        self.servers.len()
    }

    /// Server `s` is not currently crashed.
    fn is_up(&self, s: usize) -> bool {
        self.faults.as_ref().map_or(true, |f| !f.servers[s].down)
    }

    /// Current effective service rate of server `s` (global-time units
    /// of work per unit time): static speed × fault multiplier.
    fn rate(&self, s: usize) -> f64 {
        self.speeds[s] * self.faults.as_ref().map_or(1.0, |f| f.servers[s].rate())
    }

    /// Dispatch among all `k` servers (plain mode, and the faulty-mode
    /// fast path when every server is up — so fault-free prefixes of a
    /// run consume the identical random draws).
    fn pick(&mut self) -> usize {
        match self.dispatch {
            Dispatch::RoundRobin => {
                let s = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.servers.len();
                s
            }
            Dispatch::Random => self.rng.below(self.servers.len() as u64) as usize,
            Dispatch::LeastWork => {
                let mut best = 0;
                for (i, &w) in self.est_backlog.iter().enumerate() {
                    if w < self.est_backlog[best] {
                        best = i;
                    }
                }
                best
            }
            Dispatch::Jsq => {
                let mut best = 0;
                for i in 1..self.servers.len() {
                    if self.servers[i].active() < self.servers[best].active() {
                        best = i;
                    }
                }
                best
            }
            Dispatch::RandomD(d) => {
                let k = self.servers.len() as u64;
                let mut best = self.rng.below(k) as usize;
                for _ in 1..d {
                    let c = self.rng.below(k) as usize;
                    if self.est_backlog[c] < self.est_backlog[best] {
                        best = c;
                    }
                }
                best
            }
            Dispatch::LeastTime => {
                let mut best = 0;
                for i in 1..self.servers.len() {
                    if self.est_backlog[i] / self.speeds[i]
                        < self.est_backlog[best] / self.speeds[best]
                    {
                        best = i;
                    }
                }
                best
            }
        }
    }

    /// Dispatch among the servers that are up; `None` when the whole
    /// cluster is down (the caller parks the job until a recovery).
    fn pick_up(&mut self) -> Option<usize> {
        let k = self.servers.len();
        if (0..k).all(|s| self.is_up(s)) {
            return Some(self.pick());
        }
        let up: Vec<usize> = (0..k).filter(|&s| self.is_up(s)).collect();
        if up.is_empty() {
            return None;
        }
        let argmin = |cost: &dyn Fn(&Cluster, usize) -> f64| {
            let mut best = up[0];
            for &s in &up[1..] {
                if cost(self, s) < cost(self, best) {
                    best = s;
                }
            }
            best
        };
        Some(match self.dispatch {
            Dispatch::RoundRobin => {
                let mut s = self.rr_next % k;
                while !self.is_up(s) {
                    s = (s + 1) % k;
                }
                self.rr_next = (s + 1) % k;
                s
            }
            Dispatch::Random => up[self.rng.below(up.len() as u64) as usize],
            Dispatch::RandomD(d) => {
                let mut best = up[self.rng.below(up.len() as u64) as usize];
                for _ in 1..d {
                    let c = up[self.rng.below(up.len() as u64) as usize];
                    if self.est_backlog[c] < self.est_backlog[best] {
                        best = c;
                    }
                }
                best
            }
            Dispatch::LeastWork => argmin(&|c, s| c.est_backlog[s]),
            Dispatch::Jsq => argmin(&|c, s| c.servers[s].active() as f64),
            Dispatch::LeastTime => argmin(&|c, s| c.est_backlog[s] / c.speeds[s]),
        })
    }

    /// Place one copy of job `id` (attempt number `attempts`, counting
    /// the first dispatch as 1), or park it if the whole cluster is
    /// down.
    fn dispatch_copy(&mut self, now: f64, id: JobId, attempts: u32, store: &JobStore) {
        match self.pick_up() {
            Some(s) => {
                let est = store.est(id);
                self.est_backlog[s] += est;
                let lt = self.local[s];
                *self.slot(id) = Some(Placement { srv: s, est, backup: None, attempts });
                self.servers[s].on_arrival(lt, id, store);
                if attempts > 1 {
                    self.stats.restarts += 1;
                }
                if let Some(after) = self.spec_after {
                    self.spec_deadlines.push(now + after * est, id as u64, ());
                }
            }
            None => {
                // Every server is down: park until the earliest
                // recovery (one always exists while a server is down).
                let due = self.earliest_recovery().unwrap_or(now).max(now);
                self.pending.push(due, id as u64, attempts.saturating_sub(1));
            }
        }
    }

    fn earliest_recovery(&self) -> Option<f64> {
        self.faults
            .as_ref()?
            .servers
            .iter()
            .filter_map(|sf| sf.recover_at())
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Earliest pending control event (fault state change, retry due
    /// time, speculation deadline), if any.
    fn next_control_time(&self) -> Option<f64> {
        let mut t = f64::INFINITY;
        if let Some(f) = &self.faults {
            for sf in &f.servers {
                if let Some(c) = sf.next_change() {
                    t = t.min(c);
                }
            }
        }
        if let Some((k, _, _)) = self.pending.peek() {
            t = t.min(k);
        }
        if let Some((k, _, _)) = self.spec_deadlines.peek() {
            t = t.min(k);
        }
        t.is_finite().then_some(t)
    }

    /// Advance every server's inner scheduler from global `from` to
    /// global `to` (rates are constant on the window — control events
    /// bound it), translating completions back to global time and
    /// settling them immediately.
    fn step_servers(&mut self, from: f64, to: f64, store: &JobStore, done: &mut Vec<Completion>) {
        if to <= from {
            return;
        }
        for s in 0..self.servers.len() {
            let rate = self.rate(s);
            if rate <= 0.0 {
                // Crashed: the local clock freezes (and can never again
                // equal global time).
                self.synced[s] = false;
                continue;
            }
            let exact = self.synced[s] && rate == 1.0;
            if !exact {
                self.synced[s] = false;
            }
            let l0 = self.local[s];
            let l1 = if exact { to } else { l0 + (to - from) * rate };
            let mut lnow = l0;
            let mut out = std::mem::take(&mut self.buf);
            loop {
                let ev = match self.servers[s].next_event(lnow) {
                    Some(ev) if ev < l1 => ev.max(lnow),
                    _ => break,
                };
                if self.servers[s].active() > 0 {
                    self.stats.work_done += ev - lnow;
                }
                out.clear();
                self.servers[s].advance(lnow, ev, store, &mut out);
                self.settle(s, from, l0, rate, exact, &out, store, done);
                lnow = ev;
            }
            if self.servers[s].active() > 0 {
                self.stats.work_done += l1 - lnow;
            }
            out.clear();
            self.servers[s].advance(lnow, l1, store, &mut out);
            self.settle(s, from, l0, rate, exact, &out, store, done);
            self.buf = out;
            self.local[s] = l1;
        }
    }

    /// Record completions surfaced by server `s`: translate to global
    /// time, kill the losing twin of a speculated job, release the
    /// bookkeeping, and forward exactly one completion per job.
    fn settle(
        &mut self,
        s: usize,
        from: f64,
        l0: f64,
        rate: f64,
        exact: bool,
        out: &[Completion],
        store: &JobStore,
        done: &mut Vec<Completion>,
    ) {
        for c in out {
            let g = if exact { c.time } else { from + (c.time - l0) / rate };
            // A copy whose placement is already gone lost a same-window
            // race; its twin completed and this copy's kill was
            // rejected.  Dropping it here keeps exactly-once intact.
            let Some(Some(p)) = self.placement.get(c.id as usize).map(|x| x.clone()) else {
                continue;
            };
            let loser = if p.srv == s { p.backup } else { Some(p.srv) };
            if let Some(l) = loser {
                let lt = self.local[l];
                if self.servers[l].cancel(lt, c.id) {
                    self.stats.killed += 1;
                } else {
                    self.stats.kills_rejected += 1;
                }
                self.est_backlog[l] = (self.est_backlog[l] - p.est).max(0.0);
            }
            self.est_backlog[s] = (self.est_backlog[s] - p.est).max(0.0);
            self.clear_slot(c.id);
            self.spec_deadlines.remove_by_seq(c.id as u64);
            self.live -= 1;
            self.stats.useful_work += store.size(c.id);
            done.push(Completion { id: c.id, time: g });
        }
    }

    /// Apply every control event due at `tc` (servers are already
    /// advanced to `tc`): fault state changes first (so recoveries
    /// unblock same-instant retries), then crash victim handling, then
    /// due retries, then speculation deadlines.
    fn apply_control(&mut self, tc: f64, store: &JobStore) {
        let mut crashed: Vec<usize> = Vec::new();
        if let Some(f) = self.faults.as_mut() {
            for (s, sf) in f.servers.iter_mut().enumerate() {
                while let Some(ev) = sf.pop_change(tc) {
                    if ev == FaultEvent::Crash {
                        crashed.push(s);
                    }
                }
            }
        }
        for &s in &crashed {
            self.on_crash(tc, s);
        }
        while matches!(self.pending.peek(), Some((k, _, _)) if k <= tc) {
            let (_, id, made) = self.pending.pop().unwrap();
            self.dispatch_copy(tc, id as u32, made + 1, store);
        }
        while matches!(self.spec_deadlines.peek(), Some((k, _, _)) if k <= tc) {
            let (_, id, ()) = self.spec_deadlines.pop().unwrap();
            self.try_speculate(tc, id as u32, store);
        }
    }

    /// Server `s` crashed at `tc`: kill every copy placed on it through
    /// the PR-5 cancel path (attained work is lost), then re-dispatch
    /// sole copies under the retry policy — or account them lost once
    /// `max_attempts` is exhausted.  A speculated job whose twin
    /// survives elsewhere just loses the crashed copy.
    fn on_crash(&mut self, tc: f64, s: usize) {
        self.stats.crashes += 1;
        let victims: Vec<u32> = self
            .placement
            .iter()
            .enumerate()
            .filter_map(|(id, p)| {
                p.as_ref()
                    .filter(|p| p.srv == s || p.backup == Some(s))
                    .map(|_| id as u32)
            })
            .collect();
        for id in victims {
            let mut p = self.placement[id as usize].clone().expect("victim vanished");
            let lt = self.local[s];
            if self.servers[s].cancel(lt, id) {
                self.stats.killed += 1;
            } else {
                self.stats.kills_rejected += 1;
            }
            if p.srv == s && p.backup.is_some() {
                // The backup survives and becomes the sole copy.
                p.srv = p.backup.take().unwrap();
                self.placement[id as usize] = Some(p);
            } else if p.backup == Some(s) {
                p.backup = None;
                self.placement[id as usize] = Some(p);
            } else {
                self.clear_slot(id);
                self.spec_deadlines.remove_by_seq(id as u64);
                if p.attempts >= self.retry.max_attempts {
                    self.stats.lost += 1;
                    self.live -= 1;
                } else {
                    let delay =
                        self.retry.backoff * (1u64 << (p.attempts - 1).min(32)) as f64;
                    self.pending.push(tc + delay, id as u64, p.attempts);
                }
            }
        }
        // Everything on the server was killed with it.
        self.est_backlog[s] = 0.0;
    }

    /// A speculation deadline fired for `id`: if the job is still a
    /// running sole copy, launch a backup on the least-loaded *other*
    /// up server (none available: speculation is skipped).
    fn try_speculate(&mut self, _tc: f64, id: u32, store: &JobStore) {
        let Some(Some(p)) = self.placement.get(id as usize) else { return };
        if p.backup.is_some() {
            return;
        }
        let primary = p.srv;
        let est = p.est;
        let mut best: Option<usize> = None;
        for s in 0..self.servers.len() {
            if s == primary || !self.is_up(s) {
                continue;
            }
            if best.map_or(true, |b| {
                self.est_backlog[s] / self.speeds[s] < self.est_backlog[b] / self.speeds[b]
            }) {
                best = Some(s);
            }
        }
        let Some(b) = best else { return };
        self.est_backlog[b] += est;
        self.placement[id as usize].as_mut().unwrap().backup = Some(b);
        let lt = self.local[b];
        self.servers[b].on_arrival(lt, id, store);
        self.stats.speculations += 1;
    }

    /// Faulty-mode advance: chop `[now, t]` at every control event,
    /// stepping all servers to each boundary (so completions at a crash
    /// instant land *before* the crash) and applying the events in
    /// time order.
    fn advance_faulty(&mut self, now: f64, t: f64, store: &JobStore, done: &mut Vec<Completion>) {
        let mut cur = now;
        loop {
            match self.next_control_time() {
                Some(tc) if tc <= t => {
                    let tc = tc.max(cur);
                    self.step_servers(cur, tc, store, done);
                    cur = tc;
                    self.apply_control(tc, store);
                }
                _ => break,
            }
        }
        self.step_servers(cur, t, store, done);
    }
}

impl Scheduler for Cluster {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn on_arrival(&mut self, now: f64, id: JobId, store: &JobStore) {
        if self.plain {
            let s = self.pick();
            let est = store.est(id);
            self.est_backlog[s] += est;
            *self.slot(id) = Some(Placement { srv: s, est, backup: None, attempts: 0 });
            self.servers[s].on_arrival(now, id, store);
            return;
        }
        // Faulty mode: state was advanced to `now` by the engine (the
        // standard contract), so the fault plan is current here.
        self.live += 1;
        self.dispatch_copy(now, id, 1, store);
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        if self.plain {
            return self
                .servers
                .iter()
                .filter_map(|s| s.next_event(now))
                .min_by(|a, b| a.partial_cmp(b).unwrap());
        }
        if self.live == 0 {
            // Idle: suppress the (endless) fault schedule so drained
            // runs terminate; `advance` catches the plan up across the
            // gap before the next arrival is delivered.
            return None;
        }
        let mut t = f64::INFINITY;
        for (s, srv) in self.servers.iter().enumerate() {
            let rate = self.rate(s);
            if rate > 0.0 {
                if let Some(ev) = srv.next_event(self.local[s]) {
                    let g = if self.synced[s] && rate == 1.0 {
                        ev
                    } else {
                        now + (ev - self.local[s]) / rate
                    };
                    t = t.min(g);
                }
            }
        }
        if let Some(c) = self.next_control_time() {
            t = t.min(c);
        }
        t.is_finite().then(|| t.max(now))
    }

    fn advance(&mut self, now: f64, t: f64, store: &JobStore, done: &mut Vec<Completion>) {
        if !self.plain {
            self.advance_faulty(now, t, store, done);
            return;
        }
        // Servers are independent; each advances through its own
        // internal events up to t (a composite step may cross several
        // per-server events, which the engine cannot see individually).
        for s in self.servers.iter_mut() {
            let mut local_now = now;
            loop {
                match s.next_event(local_now) {
                    Some(ev) if ev < t => {
                        s.advance(local_now, ev.max(local_now), store, done);
                        local_now = ev.max(local_now);
                    }
                    _ => break,
                }
            }
            s.advance(local_now, t, store, done);
        }
        for c in done.iter() {
            if let Some(p) = self.placement.get_mut(c.id as usize).and_then(|s| s.take()) {
                self.est_backlog[p.srv] = (self.est_backlog[p.srv] - p.est).max(0.0);
            }
        }
        while matches!(self.placement.last(), Some(None)) {
            self.placement.pop();
        }
    }

    fn active(&self) -> usize {
        if self.plain {
            self.servers.iter().map(|s| s.active()).sum()
        } else {
            self.live
        }
    }

    fn cancel(&mut self, now: f64, id: u32) -> bool {
        if self.plain {
            let Some(Some(p)) = self.placement.get(id as usize) else { return false };
            let (srv, est) = (p.srv, p.est);
            if self.servers[srv].cancel(now, id) {
                self.est_backlog[srv] = (self.est_backlog[srv] - est).max(0.0);
                self.clear_slot(id);
                true
            } else {
                false
            }
        } else {
            if self.pending.remove_by_seq(id as u64).is_some() {
                self.live -= 1;
                return true;
            }
            let Some(p) = self.placement.get(id as usize).and_then(|x| x.clone()) else {
                return false;
            };
            for srv in std::iter::once(p.srv).chain(p.backup) {
                let lt = self.local[srv];
                self.servers[srv].cancel(lt, id);
                self.est_backlog[srv] = (self.est_backlog[srv] - p.est).max(0.0);
            }
            self.clear_slot(id);
            self.spec_deadlines.remove_by_seq(id as u64);
            self.live -= 1;
            true
        }
    }

    fn fault_stats(&self) -> Option<FaultStats> {
        if self.plain {
            None
        } else {
            Some(self.stats)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::faults::FaultSpec;
    use crate::sched;
    use crate::sim::{run, run_to_drain, Job};
    use crate::workload::SynthConfig;

    fn fault_cfg(mtbf: f64, mttr: f64, slowdown: f64, seed: u64) -> FaultConfig {
        FaultConfig {
            spec: FaultSpec { mtbf, mttr, slowdown },
            retry: RetryPolicy::default(),
            seed,
        }
    }

    #[test]
    fn single_server_cluster_equals_plain_scheduler() {
        let cfg = SynthConfig::default().with_njobs(500);
        let jobs = crate::workload::synthesize(&cfg, 3);
        for dispatch in [
            Dispatch::RoundRobin,
            Dispatch::LeastWork,
            Dispatch::Random,
            Dispatch::Jsq,
            Dispatch::RandomD(2),
            Dispatch::LeastTime,
        ] {
            let mut c = Cluster::new("psbs", 1, dispatch, 0).unwrap();
            let a = run(&mut c, &jobs).completion;
            let mut s = sched::by_name("psbs").unwrap();
            let b = run(s.as_mut(), &jobs).completion;
            assert_eq!(a, b, "k=1 must be transparent ({dispatch:?})");
        }
    }

    #[test]
    fn all_jobs_complete_on_k_servers() {
        let cfg = SynthConfig::default().with_njobs(2_000);
        let jobs = crate::workload::synthesize(&cfg, 4);
        for k in [2, 4, 8] {
            let mut c = Cluster::new("psbs", k, Dispatch::LeastWork, 1).unwrap();
            let r = run(&mut c, &jobs);
            assert!(r.completion.iter().all(|x| x.is_finite()), "k={k}");
            assert_eq!(c.active(), 0);
        }
    }

    #[test]
    fn more_servers_never_hurt_mst_much() {
        // With load 0.9 against ONE unit server, k servers are heavily
        // under-loaded: MST must drop toward the mean size.
        let cfg = SynthConfig::default().with_njobs(3_000);
        let jobs = crate::workload::synthesize(&cfg, 5);
        let mst = |k| {
            let mut c = Cluster::new("psbs", k, Dispatch::LeastWork, 2).unwrap();
            run(&mut c, &jobs).mst(&jobs)
        };
        let m1 = mst(1);
        let m4 = mst(4);
        assert!(m4 < m1, "k=4 ({m4}) should beat k=1 ({m1})");
    }

    #[test]
    fn least_work_beats_round_robin_on_skew() {
        // Heavy-tailed sizes + 4 servers at high per-server load:
        // size-aware routing balances elephants, round-robin collides
        // them. Scale arrivals so per-server load stays high.
        let cfg = SynthConfig::default().with_njobs(4_000).with_load(3.6); // ~0.9 per server
        let jobs = crate::workload::synthesize(&cfg, 6);
        let mst = |d| {
            let mut c = Cluster::new("psbs", 4, d, 3).unwrap();
            run(&mut c, &jobs).mst(&jobs)
        };
        let lw = mst(Dispatch::LeastWork);
        let rr = mst(Dispatch::RoundRobin);
        assert!(lw < rr, "least-work {lw} should beat round-robin {rr}");
    }

    #[test]
    fn power_of_d_beats_uniform_random_on_skew() {
        let cfg = SynthConfig::default().with_njobs(4_000).with_load(3.6);
        let jobs = crate::workload::synthesize(&cfg, 16);
        let mst = |d| {
            let mut c = Cluster::new("psbs", 4, d, 3).unwrap();
            run(&mut c, &jobs).mst(&jobs)
        };
        let two = mst(Dispatch::RandomD(2));
        let uni = mst(Dispatch::Random);
        assert!(two < uni, "2 choices ({two}) should beat uniform ({uni})");
    }

    #[test]
    fn cluster_cancellation_updates_backlog() {
        let mut c = Cluster::new("psbs", 2, Dispatch::LeastWork, 4).unwrap();
        let mut st = JobStore::new();
        st.deliver(&mut c, 0.0, &Job::exact(0, 0.0, 100.0)); // -> server 0
        st.deliver(&mut c, 0.0, &Job::exact(1, 0.0, 1.0)); // -> server 1 (least work)
        assert_eq!(c.active(), 2);
        assert!(c.cancel(0.0, 0));
        assert_eq!(c.active(), 1);
        // Next big job routes to the now-empty server 0.
        st.deliver(&mut c, 0.0, &Job::exact(2, 0.0, 50.0));
        assert!(c.est_backlog[0] >= 50.0 - 1e-9);
    }

    #[test]
    fn dispatch_is_deterministic_per_seed() {
        let cfg = SynthConfig::default().with_njobs(300);
        let jobs = crate::workload::synthesize(&cfg, 8);
        let run_once = || {
            let mut c = Cluster::new("psbs", 3, Dispatch::Random, 42).unwrap();
            run(&mut c, &jobs).completion
        };
        assert_eq!(run_once(), run_once());
    }

    /// The speed/fault/speculation layer at its identity point: unit
    /// speeds and an *empty* fault config must leave the cluster in
    /// plain mode, bit-identical to the original constructor.
    #[test]
    fn empty_fault_plan_is_bit_identical() {
        let cfg = SynthConfig::default().with_njobs(800);
        let jobs = crate::workload::synthesize(&cfg, 21);
        let spec: PolicySpec = "psbs".into();
        let empty = fault_cfg(0.0, 1.0, 1.0, 9);
        for dispatch in [Dispatch::LeastWork, Dispatch::Random, Dispatch::RandomD(3)] {
            let mut a = Cluster::from_spec(&spec, 3, dispatch, 7);
            let mut b = Cluster::from_spec_full(
                &spec,
                3,
                dispatch,
                &[1.0, 1.0, 1.0],
                7,
                Some(&empty),
                None,
            );
            assert!(b.fault_stats().is_none(), "empty plan must stay plain");
            let ra = run(&mut a, &jobs).completion;
            let rb = run(&mut b, &jobs).completion;
            assert_eq!(ra, rb, "{dispatch:?}");
        }
    }

    /// A k=1 "cluster" with speed 2 halves every sojourn of a serial
    /// batch (local clocks translate correctly).
    #[test]
    fn double_speed_halves_service_times() {
        let jobs = vec![Job::exact(0, 0.0, 2.0), Job::exact(1, 0.0, 4.0)];
        let spec: PolicySpec = "fifo".into();
        let mut c =
            Cluster::from_spec_full(&spec, 1, Dispatch::RoundRobin, &[2.0], 0, None, None);
        let r = run(&mut c, &jobs);
        assert!((r.completion[0] - 1.0).abs() < 1e-9, "got {}", r.completion[0]);
        assert!((r.completion[1] - 3.0).abs() < 1e-9, "got {}", r.completion[1]);
        assert_eq!(c.active(), 0);
    }

    /// Heterogeneous speeds with speed-aware dispatch: a fast+slow pair
    /// under least-time routing beats the same pair under round-robin.
    #[test]
    fn least_time_exploits_fast_server() {
        let cfg = SynthConfig::default().with_njobs(3_000).with_load(1.8);
        let jobs = crate::workload::synthesize(&cfg, 13);
        let spec: PolicySpec = "psbs".into();
        let mst = |d| {
            let mut c =
                Cluster::from_spec_full(&spec, 2, d, &[3.0, 1.0], 5, None, None);
            run(&mut c, &jobs).mst(&jobs)
        };
        let lt = mst(Dispatch::LeastTime);
        let rr = mst(Dispatch::RoundRobin);
        assert!(lt < rr, "least-time {lt} should beat round-robin {rr}");
    }

    /// Crash + retry end to end on a deterministic single server: the
    /// job's attained work is lost, it restarts after recovery, and the
    /// stats ledger records the crash, the kill and the restart.
    #[test]
    fn crash_loses_attained_work_and_retries() {
        // mtbf scale >> job sizes: find the first crash window, then
        // place one long job straddling it.
        let cfg = fault_cfg(50.0, 5.0, 1.0, 123);
        let mut probe = FaultPlan::new(&cfg, 1);
        let crash_at = probe.servers[0].next_change().unwrap();
        probe.servers[0].pop_change(crash_at);
        let recover_at = probe.servers[0].recover_at().unwrap();

        let size = crash_at * 0.5 + 1.0; // started at 0, unfinished at the crash
        let jobs = vec![Job::exact(0, 0.0, size)];
        let spec: PolicySpec = "fifo".into();
        let mut c = Cluster::from_spec_full(
            &spec,
            1,
            Dispatch::RoundRobin,
            &[],
            0,
            Some(&cfg),
            None,
        );
        let r = run_to_drain(&mut c, &jobs);
        let stats = c.fault_stats().unwrap();
        assert!(stats.crashes >= 1);
        assert!(stats.killed >= 1, "crash must kill through the cancel path");
        assert_eq!(stats.kills_rejected, 0);
        assert_eq!(stats.restarts, 1);
        assert_eq!(stats.lost, 0);
        assert_eq!(c.active(), 0);
        // Restarted from scratch after recovery: full size again.
        assert!(
            (r.completion[0] - (recover_at + size)).abs() < 1e-6,
            "completion {} vs recover {} + size {}",
            r.completion[0],
            recover_at,
            size
        );
        // Attained work before the crash was wasted.
        assert!(stats.wasted_fraction() > 0.0);
        assert!((stats.useful_work - size).abs() < 1e-9);
    }

    /// Exhausting max_attempts drops the job as lost — and the run
    /// still drains (the engine's drain mode tolerates the NaN).
    #[test]
    fn retry_exhaustion_accounts_lost() {
        // Tiny mtbf and huge job: it can never finish.
        let mut cfg = fault_cfg(1.0, 0.1, 1.0, 7);
        cfg.retry.max_attempts = 2;
        let jobs = vec![Job::exact(0, 0.0, 1e4)];
        let spec: PolicySpec = "fifo".into();
        let mut c = Cluster::from_spec_full(
            &spec,
            1,
            Dispatch::RoundRobin,
            &[],
            0,
            Some(&cfg),
            None,
        );
        let r = run_to_drain(&mut c, &jobs);
        let stats = c.fault_stats().unwrap();
        assert!(r.completion[0].is_nan(), "unfinishable job must be lost");
        assert_eq!(stats.lost, 1);
        assert_eq!(r.completed(), 0);
        assert_eq!(c.active(), 0, "lost jobs must drain from active()");
    }

    /// Speculative execution rescues a job stuck on a degraded server:
    /// the backup launches on the other server, wins, and the loser is
    /// killed — exactly one completion.
    #[test]
    fn speculation_rescues_straggler() {
        // Server 0 is 100x slower; round-robin sends job 0 there.
        let jobs = vec![Job::exact(0, 0.0, 1.0)];
        let spec: PolicySpec = "fifo".into();
        let mut c = Cluster::from_spec_full(
            &spec,
            2,
            Dispatch::RoundRobin,
            &[0.01, 1.0],
            0,
            None,
            Some(2.0), // backup after 2 * est = 2.0
        );
        let r = run_to_drain(&mut c, &jobs);
        let stats = c.fault_stats().unwrap();
        assert_eq!(stats.speculations, 1);
        assert_eq!(stats.killed, 1, "the straggling copy must be killed");
        // Backup launched at t=2, runs at speed 1: done by t=3 — far
        // sooner than the straggler's t=100.
        assert!(
            (r.completion[0] - 3.0).abs() < 1e-6,
            "backup should win at 3.0, got {}",
            r.completion[0]
        );
        assert_eq!(c.active(), 0);
        // Duplicate work shows up in the waste ledger.
        assert!(stats.wasted_fraction() > 0.0);
    }

    /// Churn conservation, cluster edition: random faults over a real
    /// workload — every job completes exactly once or is accounted
    /// lost, and active() drains to 0.
    #[test]
    fn fault_conservation_quickcheck() {
        let wl = SynthConfig::default().with_njobs(400);
        let jobs = crate::workload::synthesize(&wl, 30);
        let horizon = jobs.last().unwrap().arrival;
        for seed in 0..4u64 {
            let mut cfg = fault_cfg(horizon / 4.0, horizon / 40.0, 0.5, seed);
            cfg.retry.max_attempts = 2;
            let spec: PolicySpec = "psbs".into();
            let mut c = Cluster::from_spec_full(
                &spec,
                3,
                Dispatch::LeastWork,
                &[],
                seed,
                Some(&cfg),
                Some(4.0),
            );
            let r = run_to_drain(&mut c, &jobs);
            let stats = c.fault_stats().unwrap();
            assert_eq!(
                r.completed() + stats.lost as usize,
                jobs.len(),
                "seed {seed}: completions + lost must equal arrivals"
            );
            assert_eq!(c.active(), 0, "seed {seed}");
            assert_eq!(stats.kills_unsupported, 0);
        }
    }
}
