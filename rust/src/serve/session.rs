//! Session internals for [`crate::serve`]: the bounded ingress queue
//! the reader thread and the engine share, and the three engine-side
//! adapters that turn the streaming event loop into a live service —
//! [`LiveSource`] (arrivals off the wire), [`LiveClock`] (wall pacing,
//! interruptible waits, control verbs) and [`ServeSink`] (protocol
//! output + shared [`OnlineMetrics`]).
//!
//! Threading model: exactly two threads touch the session — the reader
//! (parses lines, pushes [`Request`]s) and the engine (everything
//! else).  They meet only at [`Shared`]: one mutex-protected FIFO with
//! two condvars.  `can_pop` wakes the engine when a request lands;
//! `can_push` wakes the reader when the engine frees a slot.  The
//! queue is bounded (`--queue`): when it fills, the *reader parks* —
//! backpressure propagates to the client through an unread socket /
//! pipe, and no request is ever dropped silently.
//!
//! Deadlock freedom: the reader only ever waits on `can_push` (queue
//! full) and the engine only ever waits on `can_pop` (queue empty or,
//! paced, on a timeout).  With capacity ≥ 1 the queue cannot be full
//! and empty at once, so one of the two always makes progress.
//!
//! Ordering: requests take effect strictly in protocol order.  A
//! control verb behind a submitted row is a *barrier* — it is applied
//! only after every earlier row has been admitted into the scheduler
//! (under pacing, that means after the row's arrival time has come
//! due).  This is what makes a served session deterministic and, at
//! `--speedup inf`, bit-identical to an offline replay of the same
//! rows.

use std::collections::VecDeque;
use std::io::{BufRead, Write};
use std::sync::{Condvar, Mutex};

use crate::error::Error;
use crate::metrics::OnlineMetrics;
use crate::sim::{
    Clock, Completion, CompletionSink, Job, JobSource, JobStore, Scheduler, Wait, WallClock,
};
use crate::workload::trace_file::{RowParser, TraceRow};

/// One parsed protocol request, queued in protocol order.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Request {
    /// A data row (`arrival,size[,weight][,estimate]`): run this job.
    Submit(TraceRow),
    /// `kill <id>` — cancel a pending job.
    Kill(u32),
    /// `update <id> <est>` — revise a pending job's size estimate
    /// (the live face of [`Scheduler::on_estimate_update`]).
    Update(u32, f64),
    /// `stats` — write a metrics snapshot line.
    Stats,
    /// `drain` (or end of input) — stop intake, finish what's in
    /// flight, then end the session gracefully.
    Drain,
    /// `shutdown` — end the session now, abandoning in-flight jobs.
    Shutdown,
}

/// The mutex-protected half of [`Shared`].
pub(crate) struct Ingress {
    pub queue: VecDeque<Request>,
    /// The reader is done (EOF, `drain` or `shutdown` seen): nothing
    /// will ever be pushed again.
    pub closed: bool,
    cap: usize,
}

/// Everything the reader thread and the engine share.
pub(crate) struct Shared {
    pub ing: Mutex<Ingress>,
    /// Signalled after every push and on close: the engine may have
    /// something to pop (or a reason to stop waiting).
    pub can_pop: Condvar,
    /// Signalled after every pop: the reader may have room to push.
    pub can_push: Condvar,
}

impl Shared {
    pub fn new(cap: usize) -> Shared {
        assert!(cap >= 1, "ingress queue capacity must be >= 1");
        Shared {
            ing: Mutex::new(Ingress { queue: VecDeque::new(), closed: false, cap }),
            can_pop: Condvar::new(),
            can_push: Condvar::new(),
        }
    }

    /// Blocking bounded push — the backpressure point.
    fn push(&self, req: Request) {
        let mut ing = self.ing.lock().unwrap();
        while ing.queue.len() >= ing.cap {
            ing = self.can_push.wait(ing).unwrap();
        }
        ing.queue.push_back(req);
        self.can_pop.notify_all();
    }

    /// Mark the request stream closed and wake the engine.
    fn close(&self) {
        let mut ing = self.ing.lock().unwrap();
        ing.closed = true;
        self.can_pop.notify_all();
    }
}

/// The reader loop: one protocol request per input line.
///
/// Control verbs are recognized by the line's first whitespace token
/// (`kill`, `update`, `stats`, `drain`, `shutdown` — data rows are
/// comma-separated, so the token space cannot collide); every other
/// non-empty line goes through the trace-file [`RowParser`] — same
/// grammar as on-disk traces, including the optional header, `#`
/// comments and the non-decreasing-arrival check.  Malformed lines
/// are answered with an `err line N: ...` line and the session
/// continues; `drain`/`shutdown`/EOF end the loop and close intake
/// (EOF is an implicit `drain`).
pub(crate) fn read_requests<R: BufRead, W: Write>(input: R, shared: &Shared, out: &Mutex<W>) {
    let mut parser = RowParser::new();
    let mut ln = 0usize;
    for line in input.lines() {
        ln += 1;
        let Ok(raw) = line else { break };
        let mut words = raw.split_whitespace();
        match words.next() {
            Some("kill") => match words.next().map(str::parse::<u32>) {
                Some(Ok(id)) if words.next().is_none() => shared.push(Request::Kill(id)),
                _ => {
                    let e = Error::protocol_line(
                        ln as u64,
                        format!("kill: expected one job id, got `{}`", raw.trim()),
                    );
                    let _ = writeln!(out.lock().unwrap(), "err {e}");
                }
            },
            Some("update") => {
                let id = words.next().map(str::parse::<u32>);
                let est = words.next().map(str::parse::<f64>);
                match (id, est) {
                    (Some(Ok(id)), Some(Ok(est))) if words.next().is_none() && est.is_finite() => {
                        shared.push(Request::Update(id, est))
                    }
                    _ => {
                        let e = Error::protocol_line(
                            ln as u64,
                            format!(
                                "update: expected job id and finite estimate, got `{}`",
                                raw.trim()
                            ),
                        );
                        let _ = writeln!(out.lock().unwrap(), "err {e}");
                    }
                }
            }
            Some("stats") => shared.push(Request::Stats),
            Some("drain") => {
                shared.push(Request::Drain);
                break;
            }
            Some("shutdown") => {
                shared.push(Request::Shutdown);
                break;
            }
            _ => match parser.line(ln, &raw) {
                Ok(Some(row)) => shared.push(Request::Submit(row)),
                Ok(None) => {} // blank, comment, or header
                Err(e) => {
                    let _ = writeln!(out.lock().unwrap(), "err {e}");
                }
            },
        }
    }
    shared.close();
}

/// Engine-facing job stream over the ingress queue.
///
/// `peek_arrival` exposes the front `Submit`'s arrival time; a control
/// request at the front is a barrier (`None` — the engine falls
/// through to `wait_idle`, comes back around, and [`LiveClock::on_step`]
/// applies it), which keeps requests strictly in protocol order.
///
/// Free-run mode (`--speedup inf`): an *empty, open* queue **blocks**
/// until the reader pushes or closes.  The engine then always knows
/// the next arrival before advancing — the event merge, and therefore
/// every completion time, is bit-identical to an offline replay of
/// the same rows.  Under finite pacing an empty queue just reads as
/// "nothing yet" (`None`) and the clock's timed waits take over.
///
/// Ids are assigned densely (0, 1, 2, ...) in submission order — the
/// ids `done`/`killed` protocol lines refer to.
pub(crate) struct LiveSource<'a> {
    shared: &'a Shared,
    free_run: bool,
    next_id: u32,
}

impl<'a> LiveSource<'a> {
    pub fn new(shared: &'a Shared, free_run: bool) -> LiveSource<'a> {
        LiveSource { shared, free_run, next_id: 0 }
    }
}

impl JobSource for LiveSource<'_> {
    fn peek_arrival(&mut self) -> Option<f64> {
        let mut ing = self.shared.ing.lock().unwrap();
        loop {
            match ing.queue.front() {
                Some(Request::Submit(row)) => return Some(row.arrival),
                Some(_) => return None, // control barrier
                None if ing.closed || !self.free_run => return None,
                None => ing = self.shared.can_pop.wait(ing).unwrap(),
            }
        }
    }

    fn next_job(&mut self) -> Option<Job> {
        let mut ing = self.shared.ing.lock().unwrap();
        if !matches!(ing.queue.front(), Some(Request::Submit(_))) {
            return None;
        }
        let Some(Request::Submit(row)) = ing.queue.pop_front() else { unreachable!() };
        self.shared.can_push.notify_all();
        let id = self.next_id;
        self.next_id += 1;
        Some(super::job_from_row(id, &row))
    }
}

/// The serve session clock: [`WallClock`] pacing plus interruptible
/// waits and the control-verb hook — the live half of the [`Clock`]
/// contract.
pub(crate) struct LiveClock<'a, W: Write> {
    shared: &'a Shared,
    pace: WallClock,
    out: &'a Mutex<W>,
    metrics: &'a Mutex<OnlineMetrics>,
    /// Jobs successfully cancelled via `kill`.
    pub killed: u64,
    /// The session ended by `shutdown` (vs a graceful drain).
    pub aborted: bool,
}

impl<'a, W: Write> LiveClock<'a, W> {
    pub fn new(
        shared: &'a Shared,
        pace: WallClock,
        out: &'a Mutex<W>,
        metrics: &'a Mutex<OnlineMetrics>,
    ) -> LiveClock<'a, W> {
        LiveClock { shared, pace, out, metrics, killed: 0, aborted: false }
    }

    /// The PR 5 kill path, live: route through [`Scheduler::cancel`]
    /// and the store's state ledger, ack with `killed <id>` or nack
    /// with a distinct `err kill <id>: ...` reason.
    fn kill(&mut self, now: f64, id: u32, sched: &mut dyn Scheduler, store: &mut JobStore) {
        if !store.is_active(id) {
            let why = if id >= store.next_id() { "unknown id" } else { "not pending" };
            let _ = writeln!(self.out.lock().unwrap(), "err kill {id}: {why}");
        } else if sched.cancel(now, id) {
            store.mark_cancelled(id);
            store.retire();
            self.metrics.lock().unwrap().discard(id);
            self.killed += 1;
            let _ = writeln!(self.out.lock().unwrap(), "killed {id}");
        } else {
            let _ = writeln!(
                self.out.lock().unwrap(),
                "err kill {id}: policy does not support cancellation"
            );
        }
    }

    /// The estimate-refinement path, live: write the (clamped) value
    /// into the store ledger first, then let the scheduler re-key via
    /// [`Scheduler::on_estimate_update`].  Acked with
    /// `updated <id> est=<stored>` — `stored` is the post-clamp value,
    /// so clients learn the effective estimate — and nacked with a
    /// distinct `err update <id>: ...` mirroring the kill nacks.  A
    /// scheduler that refuses (default path over an uncancellable job,
    /// e.g. the serving job of a nonpreemptive discipline) leaves the
    /// store's estimate revised but its own ordering untouched.
    fn update(
        &mut self,
        now: f64,
        id: u32,
        est: f64,
        sched: &mut dyn Scheduler,
        store: &mut JobStore,
    ) {
        if !store.is_active(id) {
            let why = if id >= store.next_id() { "unknown id" } else { "not pending" };
            let _ = writeln!(self.out.lock().unwrap(), "err update {id}: {why}");
        } else {
            let stored = store.update_est(id, est);
            if sched.on_estimate_update(now, id, store) {
                let _ = writeln!(self.out.lock().unwrap(), "updated {id} est={stored}");
            } else {
                let _ = writeln!(
                    self.out.lock().unwrap(),
                    "err update {id}: policy does not support estimate updates"
                );
            }
        }
    }
}

impl<W: Write> Clock for LiveClock<'_, W> {
    fn wait_until(&mut self, t: f64) -> Wait {
        let mut ing = self.shared.ing.lock().unwrap();
        loop {
            // A control verb at the front outranks the planned event:
            // re-plan so `on_step` applies it first.  (The front of a
            // non-empty queue is stable under us — pushes append, and
            // all pops happen on this thread.)
            if matches!(ing.queue.front(), Some(r) if !matches!(r, Request::Submit(_))) {
                return Wait::Interrupted;
            }
            let Some(dur) = self.pace.remaining(t) else { return Wait::Elapsed };
            let was_empty = ing.queue.is_empty();
            let (guard, timeout) = self.shared.can_pop.wait_timeout(ing, dur).unwrap();
            ing = guard;
            if was_empty && !ing.queue.is_empty() {
                // First request after an empty stretch: it may predate
                // the event we were sleeping toward — re-merge.
                return Wait::Interrupted;
            }
            if timeout.timed_out() {
                return Wait::Elapsed;
            }
        }
    }

    fn wait_idle(&mut self) -> bool {
        let mut ing = self.shared.ing.lock().unwrap();
        loop {
            if !ing.queue.is_empty() {
                return true;
            }
            if ing.closed {
                return false; // graceful drain: nothing left anywhere
            }
            ing = self.shared.can_pop.wait(ing).unwrap();
        }
    }

    fn live(&self) -> bool {
        true
    }

    fn on_step(&mut self, now: f64, sched: &mut dyn Scheduler, store: &mut JobStore) -> bool {
        loop {
            let req = {
                let mut ing = self.shared.ing.lock().unwrap();
                match ing.queue.front() {
                    // Submits belong to the source; an empty queue
                    // means nothing to apply.
                    Some(Request::Submit(_)) | None => return true,
                    Some(_) => {
                        let req = ing.queue.pop_front().unwrap();
                        self.shared.can_push.notify_all();
                        req
                    }
                }
            };
            match req {
                Request::Kill(id) => self.kill(now, id, sched, store),
                Request::Update(id, est) => self.update(now, id, est, sched, store),
                Request::Stats => {
                    let snap = self.metrics.lock().unwrap().snapshot();
                    let _ = writeln!(self.out.lock().unwrap(), "stats {snap}");
                }
                // Intake is already closed (the reader pushed Drain as
                // its last act); the engine drains naturally.
                Request::Drain => {}
                Request::Shutdown => {
                    self.aborted = true;
                    return false;
                }
                Request::Submit(_) => unreachable!("matched above"),
            }
        }
    }
}

/// Protocol-side completion sink: one `done` line per completion and a
/// `stats` line every `stats_every` completions (0 = off).  All metric
/// state lives in the shared [`OnlineMetrics`] so the `stats` verb
/// (answered by the clock) and the cadence lines report from the same
/// accumulator.
pub(crate) struct ServeSink<'a, W: Write> {
    out: &'a Mutex<W>,
    metrics: &'a Mutex<OnlineMetrics>,
    stats_every: u64,
}

impl<'a, W: Write> ServeSink<'a, W> {
    pub fn new(
        out: &'a Mutex<W>,
        metrics: &'a Mutex<OnlineMetrics>,
        stats_every: u64,
    ) -> ServeSink<'a, W> {
        ServeSink { out, metrics, stats_every }
    }
}

impl<W: Write> CompletionSink for ServeSink<'_, W> {
    fn on_arrival(&mut self, now: f64, job: &Job) {
        self.metrics.lock().unwrap().on_arrival(now, job);
    }

    fn on_completion(&mut self, time: f64, c: &Completion) {
        let mut m = self.metrics.lock().unwrap();
        let (arrival, size) = m.in_flight(c.id).unwrap_or((f64::NAN, f64::NAN));
        m.on_completion(time, c);
        let sojourn = time - arrival;
        let mut out = self.out.lock().unwrap();
        let _ = writeln!(
            out,
            "done id={} t={} sojourn={} slowdown={}",
            c.id,
            time,
            sojourn,
            sojourn / size
        );
        if self.stats_every > 0 && m.count() % self.stats_every == 0 {
            let _ = writeln!(out, "stats {}", m.snapshot());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn drained(shared: &Shared) -> Vec<Request> {
        let mut ing = shared.ing.lock().unwrap();
        assert!(ing.closed, "reader must close intake");
        ing.queue.drain(..).collect()
    }

    #[test]
    fn reader_parses_verbs_rows_and_reports_errors() {
        let input = Cursor::new(
            "arrival,size,weight\n\
             # comment\n\
             0.5,2,1\n\
             kill 3\n\
             stats\n\
             update 1 7.5\n\
             0.5,oops,1\n\
             kill seven\n\
             update 1\n\
             update one 2\n\
             1.5,4,2\n\
             drain\n\
             9.9,9,9\n",
        );
        let shared = Shared::new(64);
        let out = Mutex::new(Vec::new());
        read_requests(input, &shared, &out);

        let reqs = drained(&shared);
        assert_eq!(reqs.len(), 6, "header/comment/bad lines produce no requests: {reqs:?}");
        assert!(matches!(reqs[0], Request::Submit(TraceRow { arrival, .. }) if arrival == 0.5));
        assert_eq!(reqs[1], Request::Kill(3));
        assert_eq!(reqs[2], Request::Stats);
        assert_eq!(reqs[3], Request::Update(1, 7.5));
        assert!(matches!(reqs[4], Request::Submit(TraceRow { weight, .. }) if weight == 2.0));
        // `drain` stops the reader: the trailing row is never read.
        assert_eq!(reqs[5], Request::Drain);

        let errs = String::from_utf8(out.into_inner().unwrap()).unwrap();
        let lines: Vec<&str> = errs.lines().collect();
        assert_eq!(lines.len(), 4, "one err line per bad input line: {lines:?}");
        assert!(lines[0].starts_with("err line 7: "), "{}", lines[0]);
        assert!(lines[0].contains("not a number"), "{}", lines[0]);
        assert_eq!(lines[1], "err line 8: kill: expected one job id, got `kill seven`");
        assert_eq!(
            lines[2],
            "err line 9: update: expected job id and finite estimate, got `update 1`"
        );
        assert_eq!(
            lines[3],
            "err line 10: update: expected job id and finite estimate, got `update one 2`"
        );
    }

    #[test]
    fn bounded_push_parks_the_reader_until_the_engine_pops() {
        let shared = Shared::new(1);
        let got = std::thread::scope(|s| {
            s.spawn(|| {
                for id in 0..3 {
                    shared.push(Request::Kill(id));
                }
                shared.close();
            });
            // Pop slowly; the pusher must park at the full queue each
            // time rather than dropping or reordering.
            let mut got = Vec::new();
            loop {
                let mut ing = shared.ing.lock().unwrap();
                while ing.queue.is_empty() && !ing.closed {
                    ing = shared.can_pop.wait(ing).unwrap();
                }
                assert!(ing.queue.len() <= 1, "capacity respected");
                match ing.queue.pop_front() {
                    Some(r) => {
                        shared.can_push.notify_all();
                        got.push(r);
                    }
                    None => break,
                }
            }
            got
        });
        assert_eq!(got, vec![Request::Kill(0), Request::Kill(1), Request::Kill(2)]);
    }

    /// A discipline that leaves [`Scheduler::cancel`] at its default
    /// (`false`): the kill path must nack with the "unsupported"
    /// reason, not pretend the job died.
    struct NoCancel {
        pending: Vec<u32>,
    }

    impl Scheduler for NoCancel {
        fn name(&self) -> &'static str {
            "nocancel"
        }
        fn on_arrival(&mut self, _now: f64, id: u32, _store: &JobStore) {
            self.pending.push(id);
        }
        fn next_event(&self, _now: f64) -> Option<f64> {
            None
        }
        fn advance(&mut self, _now: f64, _t: f64, _store: &JobStore, _done: &mut Vec<Completion>) {}
        fn active(&self) -> usize {
            self.pending.len()
        }
    }

    #[test]
    fn kill_nacks_are_distinct_per_reason() {
        let shared = Shared::new(8);
        let out = Mutex::new(Vec::new());
        let metrics = Mutex::new(OnlineMetrics::new());
        let mut clock = LiveClock::new(&shared, WallClock::new(1.0), &out, &metrics);
        let mut sched = NoCancel { pending: Vec::new() };
        let mut store = JobStore::new();
        let job = Job { id: 0, arrival: 0.0, size: 1.0, est: 1.0, weight: 1.0 };
        store.deliver(&mut sched, 0.0, &job);

        clock.kill(0.0, 7, &mut sched, &mut store); // never submitted
        clock.kill(0.0, 0, &mut sched, &mut store); // pending, unsupported
        store.mark_cancelled(0);
        clock.kill(0.0, 0, &mut sched, &mut store); // no longer pending

        assert_eq!(clock.killed, 0);
        let text = String::from_utf8(out.into_inner().unwrap()).unwrap();
        assert_eq!(
            text.lines().collect::<Vec<_>>(),
            vec![
                "err kill 7: unknown id",
                "err kill 0: policy does not support cancellation",
                "err kill 0: not pending",
            ]
        );
    }

    /// The update nacks mirror the kill nacks reason-for-reason: the
    /// same NoCancel stand-in keeps the trait-default
    /// `on_estimate_update` (cancel + re-admit), whose cancel refusal
    /// surfaces as the "unsupported" nack — while the store's estimate
    /// ledger is still revised (the contract: store first, scheduler
    /// second).
    #[test]
    fn update_nacks_are_distinct_per_reason() {
        let shared = Shared::new(8);
        let out = Mutex::new(Vec::new());
        let metrics = Mutex::new(OnlineMetrics::new());
        let mut clock = LiveClock::new(&shared, WallClock::new(1.0), &out, &metrics);
        let mut sched = NoCancel { pending: Vec::new() };
        let mut store = JobStore::new();
        let job = Job { id: 0, arrival: 0.0, size: 1.0, est: 1.0, weight: 1.0 };
        store.deliver(&mut sched, 0.0, &job);

        clock.update(0.0, 7, 5.0, &mut sched, &mut store); // never submitted
        clock.update(0.0, 0, 5.0, &mut sched, &mut store); // pending, unsupported
        assert_eq!(store.est(0), 5.0, "the ledger is revised even on scheduler refusal");
        store.mark_cancelled(0);
        clock.update(0.0, 0, 9.0, &mut sched, &mut store); // no longer pending

        let text = String::from_utf8(out.into_inner().unwrap()).unwrap();
        assert_eq!(
            text.lines().collect::<Vec<_>>(),
            vec![
                "err update 7: unknown id",
                "err update 0: policy does not support estimate updates",
                "err update 0: not pending",
            ]
        );
    }
}
