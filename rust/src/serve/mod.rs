//! `psbs serve` — the scheduler as a live service.
//!
//! The same streaming engine that replays million-job traces
//! ([`crate::sim::engine`]) runs here against the wall clock: jobs
//! arrive over a line protocol (stdin or one TCP connection), are
//! dispatched in real time by any policy from the zoo, and metrics
//! stream back as they happen.  Nothing is simulated twice — the
//! engine is identical, only the [`crate::sim::Clock`] differs.
//!
//! # Protocol
//!
//! One request per input line:
//!
//! * **data rows** — `arrival,size[,weight][,estimate]`, exactly the
//!   trace-file grammar ([`crate::workload::trace_file::RowParser`]):
//!   optional header, `#` comments, blank lines ignored, arrivals
//!   non-decreasing.  Each accepted row becomes job `0, 1, 2, ...` in
//!   submission order.
//! * **`kill <id>`** — cancel a pending job (the PR 5
//!   [`crate::sim::Scheduler::cancel`] path).  Acked with
//!   `killed <id>`, nacked with a distinct `err kill <id>: ...`.
//! * **`update <id> <est>`** — revise a pending job's size estimate
//!   (the live face of
//!   [`crate::sim::Scheduler::on_estimate_update`]): the store ledger
//!   clamps and records the value, then the scheduler re-keys.  Acked
//!   with `updated <id> est=<stored>` (the post-clamp value), nacked
//!   with a distinct `err update <id>: ...` mirroring the kill nacks.
//! * **`stats`** — write a `stats completed=.. active=.. mst=..
//!   mean_slowdown=..` snapshot line on demand.
//! * **`drain`** — stop intake, let everything in flight finish, then
//!   end the session (end-of-input is an implicit `drain`).
//! * **`shutdown`** — end the session immediately, abandoning
//!   in-flight jobs.
//!
//! Responses: `ok ...` greeting, `done id=.. t=.. sojourn=..
//! slowdown=..` per completion, `stats ...` (on demand and every
//! `stats_every` completions), `killed <id>` / `updated <id> est=..` /
//! `err ...`, and a final
//! `stats ...` + `bye delivered=.. completed=.. killed=.. aborted=..`
//! pair when the session ends.  Floats use Rust's shortest-roundtrip
//! `{}` rendering, so clients can parse them back bit-exactly.
//!
//! # Pacing
//!
//! `speedup` maps simulation seconds onto wall seconds (10 = run the
//! trace ten times faster than its timestamps; `f64::INFINITY` =
//! free-run, no pacing).  At `--speedup inf` a served session is
//! **bit-identical** to an offline replay of the same rows — pinned by
//! `rust/tests/serve.rs` — because the session adapters only reorder
//! *when* the engine waits, never *what* it computes.
//!
//! # Backpressure
//!
//! The ingress queue is bounded (`queue` requests).  When it fills,
//! the reader thread parks until the engine admits work — the client
//! sees an unread pipe/socket; no request is ever dropped silently.

mod session;

use std::io::{BufRead, Write};
use std::sync::Mutex;

use crate::error::Error;
use crate::metrics::{OnlineMetrics, StatsSnapshot};
use crate::scenario::PolicySpec;
use crate::sim::{run_streaming_clocked, Job, WallClock};
use crate::workload::trace_file::TraceRow;

use session::{read_requests, LiveClock, LiveSource, ServeSink, Shared};

/// Knobs of one serve session — CLI flags map onto this 1:1.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Policy spec (anything [`PolicySpec::parse`] accepts:
    /// `psbs`, `cluster(k=4,dispatch=leastwork,inner=psbs)`, ...).
    pub policy: String,
    /// Simulated seconds per wall second; `f64::INFINITY` = free-run.
    pub speedup: f64,
    /// Ingress queue capacity in requests (≥ 1).
    pub queue: usize,
    /// Emit a `stats` line every this many completions (0 = only on
    /// demand).
    pub stats_every: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { policy: "psbs".to_string(), speedup: 1.0, queue: 1024, stats_every: 0 }
    }
}

/// What a finished session did — the programmatic counterpart of the
/// final `stats` + `bye` protocol lines.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SessionSummary {
    /// Jobs admitted into the scheduler.
    pub delivered: u64,
    /// Jobs that ran to completion.
    pub completed: u64,
    /// Jobs cancelled via `kill`.
    pub killed: u64,
    /// The session ended by `shutdown` rather than a graceful drain.
    pub aborted: bool,
    /// Final metrics snapshot.
    pub snapshot: StatsSnapshot,
}

/// A protocol row as a schedulable [`Job`]: 1:1 field mapping, no
/// load/speed rescaling (unlike trace *replay*, which rescales sizes
/// to hit a target load — a live client means its numbers literally).
/// A row without an estimate gets a perfect one (`est = size`).
pub fn job_from_row(id: u32, row: &TraceRow) -> Job {
    Job {
        id,
        arrival: row.arrival,
        size: row.size,
        est: row.est.unwrap_or(row.size),
        weight: row.weight,
    }
}

/// Run one serve session over arbitrary line-oriented transports:
/// requests in from `input` (read on a dedicated thread), responses
/// out through `output` (shared, line-buffered under a mutex).
/// Returns when the session drains or is shut down.
///
/// This is the in-process entry point the round-trip tests drive with
/// `Cursor`/`Vec<u8>`; [`serve_stdin`] and [`serve_listen`] are thin
/// transport frontends over it.
pub fn serve_session<R, W>(input: R, output: W, cfg: &ServeConfig) -> Result<SessionSummary, Error>
where
    R: BufRead + Send,
    W: Write + Send,
{
    let spec = PolicySpec::parse(&cfg.policy).map_err(Error::msg)?;
    if !(cfg.speedup > 0.0) {
        return Err(Error::msg(format!("--speedup must be positive, got {}", cfg.speedup)));
    }
    if cfg.queue == 0 {
        return Err(Error::msg("--queue must be >= 1"));
    }
    let mut sched = spec.build();

    let shared = Shared::new(cfg.queue);
    let out = Mutex::new(output);
    let metrics = Mutex::new(OnlineMetrics::new());
    let _ = writeln!(
        out.lock().unwrap(),
        "ok psbs serve policy={} speedup={} queue={}",
        cfg.policy,
        cfg.speedup,
        cfg.queue
    );

    // Two threads: the scoped reader parses lines into the shared
    // queue; this thread runs the engine.  The scope joins the reader
    // before returning — every session end state (drain, EOF,
    // shutdown) implies the reader already broke out of its loop.
    let (stats, killed, aborted) = std::thread::scope(|s| {
        s.spawn(|| read_requests(input, &shared, &out));
        let mut source = LiveSource::new(&shared, !cfg.speedup.is_finite());
        let mut clock = LiveClock::new(&shared, WallClock::new(cfg.speedup), &out, &metrics);
        let mut sink = ServeSink::new(&out, &metrics, cfg.stats_every);
        let stats = run_streaming_clocked(sched.as_mut(), &mut source, &mut sink, &mut clock, false);
        (stats, clock.killed, clock.aborted)
    });

    let snapshot = metrics.into_inner().unwrap().snapshot();
    let mut w = out.into_inner().unwrap();
    let _ = writeln!(w, "stats {snapshot}");
    let _ = writeln!(
        w,
        "bye delivered={} completed={} killed={} aborted={}",
        stats.delivered, stats.completed, killed, aborted
    );
    let _ = w.flush();
    Ok(SessionSummary {
        delivered: stats.delivered,
        completed: stats.completed,
        killed,
        aborted,
        snapshot,
    })
}

/// Serve one session over stdin/stdout (`psbs serve --stdin`).
pub fn serve_stdin(cfg: &ServeConfig) -> Result<SessionSummary, Error> {
    serve_session(std::io::BufReader::new(std::io::stdin()), std::io::stdout(), cfg)
}

/// Bind `addr` (e.g. `127.0.0.1:7070`), accept **one** connection,
/// serve it to completion, and return (`psbs serve --listen ADDR`).
/// One connection is one session is one scheduler — multi-tenant
/// serving is a matter of running more processes.
pub fn serve_listen(addr: &str, cfg: &ServeConfig) -> Result<SessionSummary, Error> {
    let listener = std::net::TcpListener::bind(addr)
        .map_err(|e| Error::msg(format!("binding {addr}: {e}")))?;
    if let Ok(local) = listener.local_addr() {
        eprintln!("psbs serve: listening on {local} (one connection)");
    }
    let (stream, peer) =
        listener.accept().map_err(|e| Error::msg(format!("accepting on {addr}: {e}")))?;
    eprintln!("psbs serve: client {peer}");
    let reader = std::io::BufReader::new(
        stream.try_clone().map_err(|e| Error::msg(format!("cloning connection: {e}")))?,
    );
    serve_session(reader, stream, cfg)
}
