//! Evaluation metrics (paper §6.2): mean sojourn time, per-job
//! slowdown ECDF, mean conditional slowdown, CCDF.
//!
//! Two implementations of the aggregation pipeline exist:
//! * this module — pure rust, exact, used by tests and as the fallback;
//! * the AOT `analytics` artifact ([`crate::runtime::Analytics`]) —
//!   the production path for large sweeps; `rust/tests/integration.rs`
//!   cross-checks the two on identical inputs.

use crate::sim::{Job, SimResult};

pub mod online;
pub use online::{OnlineMetrics, StatsSnapshot, WindowSnapshot};

/// Number of equal-count size classes for conditional slowdown (§7.5:
/// "binning them into 100 job classes having similar size and
/// containing the same number of jobs").
pub const COND_BINS: usize = 100;

/// Full metric bundle for one simulation run.
#[derive(Debug, Clone)]
pub struct Metrics {
    /// Mean sojourn time.
    pub mst: f64,
    /// Per-job slowdowns (aligned with job ids).
    pub slowdowns: Vec<f64>,
}

/// Compute the bundle from a finished run.
pub fn compute(jobs: &[Job], res: &SimResult) -> Metrics {
    Metrics { mst: res.mst(jobs), slowdowns: res.slowdowns(jobs) }
}

/// Mean conditional slowdown (Fig. 7): sort jobs by size, split into
/// `bins` equal-count classes, return (mean size, mean slowdown) per
/// class.
pub fn conditional_slowdown(jobs: &[Job], slowdowns: &[f64], bins: usize) -> Vec<(f64, f64)> {
    assert_eq!(jobs.len(), slowdowns.len());
    // Group through the same class assignment the analytics artifact
    // receives ([`bin_indices`]) so the two pipelines agree exactly.
    let idx = bin_indices(jobs, bins);
    let mut size_sum = vec![0.0; bins];
    let mut slow_sum = vec![0.0; bins];
    let mut count = vec![0usize; bins];
    for (i, &b) in idx.iter().enumerate() {
        size_sum[b as usize] += jobs[i].size;
        slow_sum[b as usize] += slowdowns[i];
        count[b as usize] += 1;
    }
    (0..bins)
        .filter(|&b| count[b] > 0)
        .map(|b| (size_sum[b] / count[b] as f64, slow_sum[b] / count[b] as f64))
        .collect()
}

/// Equal-count bin index per job (input to the analytics artifact):
/// jobs sorted by size, class = rank * bins / n.
pub fn bin_indices(jobs: &[Job], bins: usize) -> Vec<i32> {
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by(|&a, &b| jobs[a].size.partial_cmp(&jobs[b].size).unwrap());
    let mut idx = vec![0i32; jobs.len()];
    for (rank, &i) in order.iter().enumerate() {
        idx[i] = (rank * bins / jobs.len().max(1)) as i32;
    }
    idx
}

/// ECDF of slowdowns evaluated at `thresholds` (Figs. 4 and 8):
/// fraction of jobs with slowdown <= t.  `None` when there are no
/// samples — an all-zero "ECDF" from an empty population (e.g. every
/// job lost under faults) would be indistinguishable from a real one
/// and must be surfaced as absent, not as zeros.
pub fn slowdown_ecdf(slowdowns: &[f64], thresholds: &[f64]) -> Option<Vec<f64>> {
    if slowdowns.is_empty() {
        return None;
    }
    let mut sorted = slowdowns.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len() as f64;
    Some(
        thresholds
            .iter()
            .map(|&t| {
                let cnt = sorted.partition_point(|&s| s <= t);
                cnt as f64 / n
            })
            .collect(),
    )
}

/// Log-spaced threshold grid covering slowdown 1..10^`decades`
/// (matches the artifact's fixed 128-point input).
pub fn log_thresholds(points: usize, decades: f64) -> Vec<f64> {
    (0..points)
        .map(|i| 10f64.powf(i as f64 * decades / (points - 1).max(1) as f64))
        .collect()
}

/// Fraction of jobs with slowdown above `limit` (the paper's headline
/// fairness number: "jobs with slowdown larger than 100 are around 1%
/// for FSPE and around 8% for SRPTE").  `None` when there are no
/// samples: a silent `0.0` there would read as "no job was ever slow"
/// when in fact no job was ever *measured*.
pub fn frac_above(slowdowns: &[f64], limit: f64) -> Option<f64> {
    if slowdowns.is_empty() {
        return None;
    }
    Some(slowdowns.iter().filter(|&&s| s > limit).count() as f64 / slowdowns.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimResult;

    fn mk(jobs_sizes: &[(f64, f64)], completions: &[f64]) -> (Vec<Job>, SimResult) {
        let jobs: Vec<Job> = jobs_sizes
            .iter()
            .enumerate()
            .map(|(i, &(a, s))| Job::exact(i as u32, a, s))
            .collect();
        (jobs, SimResult { completion: completions.to_vec(), events: 0 })
    }

    #[test]
    fn mst_and_slowdowns() {
        let (jobs, res) = mk(&[(0.0, 1.0), (0.0, 2.0)], &[2.0, 4.0]);
        let m = compute(&jobs, &res);
        assert_eq!(m.mst, 3.0);
        assert_eq!(m.slowdowns, vec![2.0, 2.0]);
    }

    #[test]
    fn conditional_slowdown_bins_by_size() {
        // 4 jobs, 2 bins: small pair vs large pair.
        let (jobs, res) = mk(
            &[(0.0, 1.0), (0.0, 10.0), (0.0, 1.0), (0.0, 10.0)],
            &[2.0, 20.0, 2.0, 40.0],
        );
        let m = compute(&jobs, &res);
        let cs = conditional_slowdown(&jobs, &m.slowdowns, 2);
        assert_eq!(cs.len(), 2);
        assert_eq!(cs[0], (1.0, 2.0));
        assert_eq!(cs[1], (10.0, 3.0)); // (20/10 + 40/10)/2
    }

    #[test]
    fn bin_indices_are_equal_count() {
        let jobs: Vec<Job> =
            (0..1000).map(|i| Job::exact(i, 0.0, (i as f64 + 1.0) * 0.1)).collect();
        let idx = bin_indices(&jobs, 100);
        let mut counts = [0; 100];
        for &i in &idx {
            counts[i as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
        // Larger size => larger (or equal) bin.
        assert!(idx[999] == 99 && idx[0] == 0);
    }

    #[test]
    fn ecdf_basics() {
        let e = slowdown_ecdf(&[1.0, 2.0, 4.0, 8.0], &[1.0, 3.0, 10.0]).unwrap();
        assert_eq!(e, vec![0.25, 0.5, 1.0]);
    }

    /// Empty populations yield `None`, not a misleading all-zero row.
    #[test]
    fn ecdf_and_frac_above_reject_empty_input() {
        assert_eq!(slowdown_ecdf(&[], &[1.0, 3.0]), None);
        assert_eq!(frac_above(&[], 100.0), None);
    }

    #[test]
    fn log_thresholds_span() {
        let t = log_thresholds(128, 3.0);
        assert_eq!(t.len(), 128);
        assert!((t[0] - 1.0).abs() < 1e-12);
        assert!((t[127] - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn frac_above_counts_tail() {
        assert_eq!(frac_above(&[1.0, 50.0, 150.0, 200.0], 100.0), Some(0.5));
    }
}
