//! Streaming metrics accumulation — the O(active)-memory output side
//! of the million-job engine.
//!
//! [`OnlineMetrics`] is a [`crate::sim::CompletionSink`]: it watches
//! arrivals to learn each job's arrival time and true size (held only
//! while the job is in flight), and folds every completion into
//! - Neumaier-compensated sums for MST and mean slowdown (a naive f64
//!   sum drifts over 10⁷+ terms; see [`crate::stats::CompensatedSum`]),
//! - a tail counter (`slowdown > limit`, matching
//!   [`crate::metrics::frac_above`]'s strict comparison),
//! - one [`crate::stats::P2Quantile`] sketch per requested slowdown
//!   quantile (O(1) per observation, no sample retention),
//! - optional fixed-size windows of the sojourn/slowdown means
//!   ([`WindowSnapshot`]) for long-horizon drift plots.
//!
//! All read accessors return `Option`: an accumulator that saw zero
//! completions reports `None` rather than fabricating zeros — the same
//! empty-population discipline as `frac_above`/`slowdown_ecdf`.

use std::collections::HashMap;

use crate::sim::{Completion, CompletionSink, Job};
use crate::stats::{CompensatedSum, P2Quantile};

/// Default tail threshold — the paper's "slowdown larger than 100"
/// headline number.
pub const DEFAULT_TAIL_LIMIT: f64 = 100.0;

/// Means over one completed window of `window` jobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WindowSnapshot {
    /// Completion time of the window's last job.
    pub end_time: f64,
    /// Completions in the window (== the configured window size).
    pub jobs: u64,
    /// Mean sojourn over the window.
    pub mean_sojourn: f64,
    /// Mean slowdown over the window.
    pub mean_slowdown: f64,
}

/// Point-in-time digest of an [`OnlineMetrics`] accumulator — the
/// payload of the `psbs serve` `stats` protocol line.
///
/// The [`std::fmt::Display`] form is the wire format:
/// `completed=N active=N mst=X mean_slowdown=X`, with the floats in
/// Rust's shortest-roundtrip `{}` rendering so a client (or a test)
/// can parse them back bit-exactly.  Before the first completion the
/// means are `NaN` (which `f64::from_str` accepts back).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StatsSnapshot {
    /// Completions folded in so far.
    pub completed: u64,
    /// Jobs in flight (arrived, not yet completed or cancelled).
    pub active: u64,
    /// Mean sojourn time; `NaN` before the first completion.
    pub mst: f64,
    /// Mean slowdown; `NaN` before the first completion.
    pub mean_slowdown: f64,
}

impl std::fmt::Display for StatsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "completed={} active={} mst={} mean_slowdown={}",
            self.completed, self.active, self.mst, self.mean_slowdown
        )
    }
}

/// Streaming MST / slowdown accumulator with bounded memory:
/// O(active jobs) for the in-flight map plus O(1) per tracked
/// quantile, regardless of how many jobs flow through.
#[derive(Debug, Clone)]
pub struct OnlineMetrics {
    /// In-flight jobs: id -> (arrival, true size).
    active: HashMap<u32, (f64, f64)>,
    count: u64,
    sojourn: CompensatedSum,
    slowdown: CompensatedSum,
    tail_limit: f64,
    tail: u64,
    /// Tracked quantile levels, parallel to `sketches`.
    qs: Vec<f64>,
    sketches: Vec<P2Quantile>,
    /// Window size in completions; 0 disables windowing.
    window: u64,
    win_sojourn: CompensatedSum,
    win_slowdown: CompensatedSum,
    win_count: u64,
    snapshots: Vec<WindowSnapshot>,
}

impl Default for OnlineMetrics {
    fn default() -> Self {
        OnlineMetrics::new()
    }
}

impl OnlineMetrics {
    /// Accumulator with the default tail limit, no tracked quantiles
    /// and no windowing.
    pub fn new() -> Self {
        OnlineMetrics {
            active: HashMap::new(),
            count: 0,
            sojourn: CompensatedSum::new(),
            slowdown: CompensatedSum::new(),
            tail_limit: DEFAULT_TAIL_LIMIT,
            tail: 0,
            qs: Vec::new(),
            sketches: Vec::new(),
            window: 0,
            win_sojourn: CompensatedSum::new(),
            win_slowdown: CompensatedSum::new(),
            win_count: 0,
            snapshots: Vec::new(),
        }
    }

    /// Track the given slowdown quantiles (each in (0,1)) via P².
    pub fn with_quantiles(mut self, qs: &[f64]) -> Self {
        self.qs = qs.to_vec();
        self.sketches = qs.iter().map(|&q| P2Quantile::new(q)).collect();
        self
    }

    /// Override the tail threshold (default 100).
    pub fn with_tail_limit(mut self, limit: f64) -> Self {
        self.tail_limit = limit;
        self
    }

    /// Record a [`WindowSnapshot`] every `jobs` completions (0 = off).
    pub fn with_window(mut self, jobs: u64) -> Self {
        self.window = jobs;
        self
    }

    /// Completions folded in so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Jobs currently in flight (arrived, not yet completed) — the
    /// memory the accumulator is holding.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Mean sojourn time over completed jobs; `None` before the first
    /// completion.
    pub fn mst(&self) -> Option<f64> {
        (self.count > 0).then(|| self.sojourn.value() / self.count as f64)
    }

    /// Mean slowdown over completed jobs.
    pub fn mean_slowdown(&self) -> Option<f64> {
        (self.count > 0).then(|| self.slowdown.value() / self.count as f64)
    }

    /// Fraction of completed jobs with slowdown strictly above the
    /// tail limit (same comparison as [`crate::metrics::frac_above`]).
    pub fn frac_above(&self) -> Option<f64> {
        (self.count > 0).then(|| self.tail as f64 / self.count as f64)
    }

    /// The configured tail threshold.
    pub fn tail_limit(&self) -> f64 {
        self.tail_limit
    }

    /// Estimated slowdown quantile for a tracked level `q`; `None` if
    /// `q` was not requested or nothing completed yet.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let i = self.qs.iter().position(|&x| x == q)?;
        (self.count > 0).then(|| self.sketches[i].value())
    }

    /// Completed windows recorded so far (empty when windowing is off
    /// or fewer than `window` jobs completed).
    pub fn snapshots(&self) -> &[WindowSnapshot] {
        &self.snapshots
    }

    /// Arrival time and true size of an in-flight job, if any — what
    /// `psbs serve` needs to render a `done` line without keeping a
    /// second copy of the in-flight map.
    pub fn in_flight(&self, id: u32) -> Option<(f64, f64)> {
        self.active.get(&id).copied()
    }

    /// Forget an in-flight job without completing it (a cancelled /
    /// killed job): it stops counting as active and never contributes
    /// to the means.
    pub fn discard(&mut self, id: u32) {
        self.active.remove(&id);
    }

    /// Current [`StatsSnapshot`] — `NaN` means before the first
    /// completion, mirroring the `Option` accessors.
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            completed: self.count,
            active: self.active.len() as u64,
            mst: self.mst().unwrap_or(f64::NAN),
            mean_slowdown: self.mean_slowdown().unwrap_or(f64::NAN),
        }
    }
}

impl CompletionSink for OnlineMetrics {
    fn on_arrival(&mut self, _now: f64, job: &Job) {
        self.active.insert(job.id, (job.arrival, job.size));
    }

    fn on_completion(&mut self, time: f64, c: &Completion) {
        // A completion for a job this sink never saw arrive would make
        // every mean silently wrong — refuse it loudly in debug runs,
        // skip it in release (the engine's own contract makes this
        // unreachable when the sink is attached for the whole run).
        let Some((arrival, size)) = self.active.remove(&c.id) else {
            debug_assert!(false, "completion for unseen job {}", c.id);
            return;
        };
        let sojourn = time - arrival;
        let slow = sojourn / size;
        self.count += 1;
        self.sojourn.add(sojourn);
        self.slowdown.add(slow);
        if slow > self.tail_limit {
            self.tail += 1;
        }
        for s in &mut self.sketches {
            s.observe(slow);
        }
        if self.window > 0 {
            self.win_sojourn.add(sojourn);
            self.win_slowdown.add(slow);
            self.win_count += 1;
            if self.win_count == self.window {
                self.snapshots.push(WindowSnapshot {
                    end_time: time,
                    jobs: self.win_count,
                    mean_sojourn: self.win_sojourn.value() / self.win_count as f64,
                    mean_slowdown: self.win_slowdown.value() / self.win_count as f64,
                });
                self.win_sojourn.reset();
                self.win_slowdown.reset();
                self.win_count = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{self, SliceSource};
    use crate::workload::{synthesize, SynthConfig};

    fn stream_metrics(policy: &str, jobs: &[crate::sim::Job], m: &mut OnlineMetrics) {
        let mut sched = crate::sched::by_name(policy).unwrap();
        let mut src = SliceSource::new(jobs);
        sim::run_streaming(sched.as_mut(), &mut src, m);
    }

    #[test]
    fn empty_accumulator_reports_none() {
        let m = OnlineMetrics::new().with_quantiles(&[0.5]);
        assert_eq!(m.count(), 0);
        assert_eq!(m.mst(), None);
        assert_eq!(m.mean_slowdown(), None);
        assert_eq!(m.frac_above(), None);
        assert_eq!(m.quantile(0.5), None);
        assert_eq!(m.quantile(0.9), None, "untracked quantile");
    }

    #[test]
    fn matches_materialized_metrics() {
        let jobs = synthesize(&SynthConfig::default().with_njobs(2_000).with_sigma(0.5), 11);
        let mut sched = crate::sched::by_name("psbs").unwrap();
        let r = sim::run(sched.as_mut(), &jobs);
        let slows = r.slowdowns(&jobs);

        let mut m = OnlineMetrics::new().with_quantiles(&[0.5, 0.99]);
        stream_metrics("psbs", &jobs, &mut m);

        assert_eq!(m.count(), jobs.len() as u64);
        assert_eq!(m.active_len(), 0, "everything completed");
        // Summation order differs (completion order vs id order) but
        // the compensated sums agree to ~eps.
        let mst = m.mst().unwrap();
        assert!((mst - r.mst(&jobs)).abs() <= 1e-9 * mst.abs().max(1.0));
        // Tail fraction is an exact count — must match bitwise.
        assert_eq!(m.frac_above(), crate::metrics::frac_above(&slows, 100.0));
        // P2 sketches track the exact retained-sample quantiles.
        for q in [0.5, 0.99] {
            let exact = crate::stats::quantile(&slows, q);
            let est = m.quantile(q).unwrap();
            assert!(
                (est - exact).abs() / exact.abs().max(1e-9) < 0.15,
                "q={q}: sketch {est} exact {exact}"
            );
        }
    }

    #[test]
    fn windows_partition_the_run() {
        let jobs = synthesize(&SynthConfig::default().with_njobs(1_000), 3);
        let mut m = OnlineMetrics::new().with_window(100);
        stream_metrics("fifo", &jobs, &mut m);
        assert_eq!(m.snapshots().len(), 10);
        assert!(m.snapshots().iter().all(|w| w.jobs == 100));
        let mut last = f64::NEG_INFINITY;
        for w in m.snapshots() {
            assert!(w.end_time > last, "windows advance in time");
            assert!(w.mean_sojourn.is_finite() && w.mean_slowdown >= 1.0 - 1e-12);
            last = w.end_time;
        }
        // Window means recombine to the global mean.
        let total: f64 = m.snapshots().iter().map(|w| w.mean_sojourn * w.jobs as f64).sum();
        assert!((total / 1_000.0 - m.mst().unwrap()).abs() < 1e-9);
    }
}
