//! Typed policy specifications.
//!
//! [`PolicySpec`] is the parsed, composable form of the policy strings
//! the CLI, figure harness and coordinator accept.  Every bare name in
//! [`crate::sched::ALL_POLICIES`] is a [`BasePolicy`]; on top of those
//! the grammar composes parameterized deployments:
//!
//! ```text
//! psbs                                          bare discipline
//! mlfq(levels=12,q0=0.02)                       parameterized MLFQ
//! cluster(k=8,dispatch=leastwork,inner=psbs)    k-server dispatcher
//! cluster(k=3,dispatch=leasttime,speeds=4:2:1,inner=psbs)
//!                                               heterogeneous speeds
//! est(model=sampling,fraction=0.05,sigma0=0.5,inner=psbs)
//!                                               estimator-wrapped policy
//! est(model=online,sigma0=2,period=5,decay=0.9,inner=psbs)
//!                                               online estimate refinement
//! speculate(after=4,inner=cluster(k=8,inner=psbs))
//!                                               speculative execution
//! cluster(k=4,dispatch=random,inner=est(model=lognormal,sigma=2,inner=srpte))
//!                                               arbitrary nesting
//! ```
//!
//! Dispatch names: `leastwork`, `roundrobin`, `random`, `jsq`,
//! `random{d}` (power-of-d-choices, e.g. `random2`), `leasttime`
//! (speed-aware least estimated completion time).
//!
//! Arguments are `key=value`, comma-separated; `inner` may itself be a
//! composed spec (the splitter respects parenthesis depth).  `Display`
//! renders the canonical form and `parse` inverts it exactly
//! (round-trip property-tested in this module and in `figures`).
//!
//! [`crate::sched::by_name`] is a thin compatibility shim over
//! [`PolicySpec::parse`], so every call site that accepted a bare name
//! (simulate/replay/serve CLI, `Service`, `Cluster`, benches) now
//! accepts composed specs with no further change.

use crate::coordinator::{Cluster, Dispatch};
use crate::estimate::{self, Estimator};
use crate::sched;
use crate::sim::{Completion, Job, JobId, JobStore, Scheduler};
use crate::util::rng::Rng;
use std::fmt;

/// The eighteen single-server disciplines of the zoo, one variant per
/// name in [`crate::sched::ALL_POLICIES`] (aliases like `srpt`/`srpte`
/// stay distinct variants so parse/display round-trips exactly).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BasePolicy {
    Fifo,
    Ps,
    Dps,
    Las,
    Mlfq,
    Srpt,
    Srpte,
    SrptePs,
    SrpteLas,
    Fsp,
    Fspe,
    FspePs,
    FspeLas,
    Psbs,
    PsbsPaperlit,
    FspNaive,
    Spt,
    Sjf,
}

impl BasePolicy {
    /// The canonical CLI name (exactly the `ALL_POLICIES` spelling).
    pub fn name(self) -> &'static str {
        match self {
            BasePolicy::Fifo => "fifo",
            BasePolicy::Ps => "ps",
            BasePolicy::Dps => "dps",
            BasePolicy::Las => "las",
            BasePolicy::Mlfq => "mlfq",
            BasePolicy::Srpt => "srpt",
            BasePolicy::Srpte => "srpte",
            BasePolicy::SrptePs => "srpte+ps",
            BasePolicy::SrpteLas => "srpte+las",
            BasePolicy::Fsp => "fsp",
            BasePolicy::Fspe => "fspe",
            BasePolicy::FspePs => "fspe+ps",
            BasePolicy::FspeLas => "fspe+las",
            BasePolicy::Psbs => "psbs",
            BasePolicy::PsbsPaperlit => "psbs-paperlit",
            BasePolicy::FspNaive => "fsp-naive",
            BasePolicy::Spt => "spt",
            BasePolicy::Sjf => "sjf",
        }
    }

    /// Inverse of [`BasePolicy::name`].
    pub fn from_name(name: &str) -> Option<BasePolicy> {
        Some(match name {
            "fifo" => BasePolicy::Fifo,
            "ps" => BasePolicy::Ps,
            "dps" => BasePolicy::Dps,
            "las" => BasePolicy::Las,
            "mlfq" => BasePolicy::Mlfq,
            "srpt" => BasePolicy::Srpt,
            "srpte" => BasePolicy::Srpte,
            "srpte+ps" => BasePolicy::SrptePs,
            "srpte+las" => BasePolicy::SrpteLas,
            "fsp" => BasePolicy::Fsp,
            "fspe" => BasePolicy::Fspe,
            "fspe+ps" => BasePolicy::FspePs,
            "fspe+las" => BasePolicy::FspeLas,
            "psbs" => BasePolicy::Psbs,
            "psbs-paperlit" => BasePolicy::PsbsPaperlit,
            "fsp-naive" => BasePolicy::FspNaive,
            "spt" => BasePolicy::Spt,
            "sjf" => BasePolicy::Sjf,
            _ => return None,
        })
    }

    /// Construct the discipline (the former body of `sched::by_name`).
    pub fn build(self) -> Box<dyn Scheduler> {
        match self {
            BasePolicy::Fifo => Box::new(sched::fifo::Fifo::new()),
            BasePolicy::Ps => Box::new(sched::ps::Dps::ps()),
            BasePolicy::Dps => Box::new(sched::ps::Dps::new()),
            BasePolicy::Las => Box::new(sched::las::Las::new()),
            BasePolicy::Mlfq => Box::new(sched::mlfq::Mlfq::default_zoo()),
            BasePolicy::Srpt | BasePolicy::Srpte => Box::new(sched::srpt::Srpte::new()),
            BasePolicy::SrptePs => Box::new(sched::srpte_hybrid::SrpteHybrid::ps()),
            BasePolicy::SrpteLas => Box::new(sched::srpte_hybrid::SrpteHybrid::las()),
            BasePolicy::Fsp | BasePolicy::Fspe => Box::new(sched::fsp_family::FspFamily::fspe()),
            BasePolicy::FspePs => Box::new(sched::fsp_family::FspFamily::fspe_ps()),
            BasePolicy::FspeLas => Box::new(sched::fsp_family::FspFamily::fspe_las()),
            BasePolicy::Psbs => Box::new(sched::fsp_family::Psbs::new()),
            BasePolicy::PsbsPaperlit => {
                Box::new(sched::fsp_family::FspFamily::psbs_paper_literal())
            }
            BasePolicy::FspNaive => Box::new(sched::fsp_naive::FspNaive::new()),
            BasePolicy::Spt => Box::new(sched::nonpreemptive::NonPreemptive::spt()),
            BasePolicy::Sjf => Box::new(sched::nonpreemptive::NonPreemptive::sjf()),
        }
    }

    /// Relative per-event cost (sweep-planner chunking heuristic):
    /// fsp-naive pays an O(n) virtual update per event where everything
    /// else pays O(log n) — on Table-1 populations that is the ~100x
    /// the ROADMAP cites.
    pub fn cost_weight(self) -> f64 {
        match self {
            BasePolicy::FspNaive => 100.0,
            _ => 1.0,
        }
    }

    /// [`BasePolicy::build`] with the dense seq→slot heap index made
    /// opt-in: `indexed = false` builds the disciplines that maintain
    /// one (DPS, the FSP family, the SRPTE hybrids) without it.  The
    /// index only accelerates `cancel`; with no kill path in the
    /// deployment it is pure overhead, and dropping it cannot change
    /// results (`remove_by_seq` falls back to an O(n) scan — pinned
    /// bitwise by the per-discipline `unindexed_matches_indexed` tests).
    pub fn build_with(self, indexed: bool) -> Box<dyn Scheduler> {
        if indexed {
            return self.build();
        }
        match self {
            BasePolicy::Ps => Box::new(sched::ps::Dps::ps().unindexed()),
            BasePolicy::Dps => Box::new(sched::ps::Dps::new().unindexed()),
            BasePolicy::Fsp | BasePolicy::Fspe => {
                Box::new(sched::fsp_family::FspFamily::fspe().unindexed())
            }
            BasePolicy::FspePs => Box::new(sched::fsp_family::FspFamily::fspe_ps().unindexed()),
            BasePolicy::FspeLas => Box::new(sched::fsp_family::FspFamily::fspe_las().unindexed()),
            BasePolicy::Psbs => Box::new(sched::fsp_family::Psbs::new().unindexed()),
            BasePolicy::PsbsPaperlit => {
                Box::new(sched::fsp_family::FspFamily::psbs_paper_literal().unindexed())
            }
            BasePolicy::SrptePs => Box::new(sched::srpte_hybrid::SrpteHybrid::ps().unindexed()),
            BasePolicy::SrpteLas => Box::new(sched::srpte_hybrid::SrpteHybrid::las().unindexed()),
            BasePolicy::Spt => Box::new(sched::nonpreemptive::NonPreemptive::spt().unindexed()),
            BasePolicy::Sjf => Box::new(sched::nonpreemptive::NonPreemptive::sjf().unindexed()),
            other => other.build(),
        }
    }
}

/// A job-size estimator specification (paper §2.2), parse/display-able
/// so estimator-wrapped policies are first-class sweepable cells.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EstimatorSpec {
    /// Exact sizes.
    Oracle,
    /// Eq. 1: `s_hat = s * LogN(0, sigma^2)`.
    LogNormal { sigma: f64 },
    /// HFSP-style sampling: run `fraction`, extrapolate with rate noise
    /// `sigma0 * sqrt(0.01 / fraction)`.
    Sampling { fraction: f64, sigma0: f64 },
    /// Semi-clairvoyant size classes (log2 bucket midpoint).
    Class,
    /// Correlated proxy with multiplicative `bias` and dispersion.
    Proxy { bias: f64, sigma: f64 },
    /// Online refinement (arXiv:1403.5996): initial draw at `sigma0`
    /// (exactly the log-normal model), then every `period` time units
    /// each live job is re-estimated at `sigma0 * decay^k` (k = its
    /// refinement count), clamped ≥ attained service.  `period=inf`
    /// never refines — bit-identical to the static log-normal path.
    Online { sigma0: f64, period: f64, decay: f64 },
}

impl EstimatorSpec {
    /// The one-shot estimator behind this spec.  For `Online` this is
    /// the *initial-draw* model (log-normal at `sigma0`) — the
    /// refinement machinery lives in the scheduler layer
    /// ([`crate::estimate::OnlineRefiner`]), which the `PolicySpec`
    /// builders construct directly.
    pub fn build(&self) -> Box<dyn Estimator> {
        match *self {
            EstimatorSpec::Oracle => Box::new(estimate::OracleEstimator),
            EstimatorSpec::LogNormal { sigma } => Box::new(estimate::LogNormalNoise::new(sigma)),
            EstimatorSpec::Sampling { fraction, sigma0 } => {
                Box::new(estimate::SamplingEstimator::new(fraction, sigma0))
            }
            EstimatorSpec::Class => Box::new(estimate::ClassEstimator),
            EstimatorSpec::Proxy { bias, sigma } => {
                Box::new(estimate::ProxyEstimator::new(bias, sigma))
            }
            EstimatorSpec::Online { sigma0, .. } => Box::new(estimate::LogNormalNoise::new(sigma0)),
        }
    }

    fn model_name(&self) -> &'static str {
        match self {
            EstimatorSpec::Oracle => "oracle",
            EstimatorSpec::LogNormal { .. } => "lognormal",
            EstimatorSpec::Sampling { .. } => "sampling",
            EstimatorSpec::Class => "class",
            EstimatorSpec::Proxy { .. } => "proxy",
            EstimatorSpec::Online { .. } => "online",
        }
    }
}

/// A typed, composable policy specification.  See the module docs for
/// the grammar; `Display` is the canonical rendering and
/// [`PolicySpec::parse`] its exact inverse.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicySpec {
    /// A bare single-server discipline.
    Base(BasePolicy),
    /// Parameterized MLFQ (`mlfq(levels=N,q0=X)`); the bare name `mlfq`
    /// stays `Base(Mlfq)` (the calibrated default zoo member).
    Mlfq { levels: usize, q0: f64 },
    /// `k` servers behind a dispatcher, each running `inner`.
    Cluster {
        k: usize,
        dispatch: Dispatch,
        inner: Box<PolicySpec>,
        /// Extra seed folded into the runtime seed (0 = omitted in the
        /// canonical rendering).
        seed: u64,
        /// Per-server speed multipliers (`speeds=4:2:1`); empty is the
        /// canonical homogeneous form (all-1.0 parses normalize to it,
        /// and it is omitted in the rendering).
        speeds: Vec<f64>,
    },
    /// `inner` fed estimator-generated `est` values instead of the
    /// workload's own (the estimator sees only true sizes).
    Estimated { est: EstimatorSpec, inner: Box<PolicySpec>, seed: u64 },
    /// Speculative execution (`speculate(after=A,inner=...)`): a job
    /// still unfinished `A * est` after dispatch launches a backup copy
    /// on another server; first completion wins, the loser is killed.
    /// `inner` is normally a `cluster(...)`; any other inner is wrapped
    /// as a k=1 cluster (where speculation can never trigger).
    Speculate { after: f64, inner: Box<PolicySpec> },
}

impl PolicySpec {
    /// The headline scheduler (handy default).
    pub fn psbs() -> PolicySpec {
        PolicySpec::Base(BasePolicy::Psbs)
    }

    /// Parse a policy spec string.  Errors name the offending fragment.
    pub fn parse(s: &str) -> Result<PolicySpec, String> {
        let s = s.trim();
        if let Some(b) = BasePolicy::from_name(s) {
            return Ok(PolicySpec::Base(b));
        }
        let (head, args) = match s.find('(') {
            Some(i) if s.ends_with(')') => (&s[..i], &s[i + 1..s.len() - 1]),
            _ => return Err(format!("unknown policy: {s}")),
        };
        let kv = parse_kv(args)?;
        let get = |key: &str| -> Option<&str> {
            kv.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
        };
        let check_keys = |allowed: &[&str]| -> Result<(), String> {
            for (k, _) in &kv {
                if !allowed.contains(&k.as_str()) {
                    return Err(format!("{head}: unknown argument `{k}`"));
                }
            }
            Ok(())
        };
        match head {
            "mlfq" => {
                check_keys(&["levels", "q0"])?;
                let levels = parse_num::<usize>(get("levels"), "mlfq: levels", 8)?;
                let q0 = parse_num::<f64>(get("q0"), "mlfq: q0", 0.05)?;
                if levels < 1 || !(q0 > 0.0) {
                    return Err("mlfq: need levels >= 1 and q0 > 0".into());
                }
                Ok(PolicySpec::Mlfq { levels, q0 })
            }
            "cluster" => {
                check_keys(&["k", "dispatch", "inner", "seed", "speeds"])?;
                let k = parse_num::<usize>(get("k"), "cluster: k", 2)?;
                if k < 1 {
                    return Err("cluster: need k >= 1".into());
                }
                let dispatch = parse_dispatch(get("dispatch").unwrap_or("leastwork"))?;
                let speeds = match get("speeds") {
                    None => Vec::new(),
                    Some(v) => {
                        let mut out = Vec::new();
                        for part in v.split(':') {
                            let s: f64 = part
                                .trim()
                                .parse()
                                .map_err(|_| format!("cluster: bad speed `{part}`"))?;
                            if !(s > 0.0) {
                                return Err(format!("cluster: speed must be > 0, got {s}"));
                            }
                            out.push(s);
                        }
                        if out.len() != k {
                            return Err(format!(
                                "cluster: speeds lists {} values for k={k}",
                                out.len()
                            ));
                        }
                        // Canonical form: homogeneous = empty.
                        if out.iter().all(|&s| s == 1.0) {
                            Vec::new()
                        } else {
                            out
                        }
                    }
                };
                let inner = PolicySpec::parse(get("inner").unwrap_or("psbs"))?;
                let seed = parse_num::<u64>(get("seed"), "cluster: seed", 0)?;
                Ok(PolicySpec::Cluster { k, dispatch, inner: Box::new(inner), seed, speeds })
            }
            "speculate" => {
                check_keys(&["after", "inner"])?;
                let after = parse_num::<f64>(get("after"), "speculate: after", 2.0)?;
                if !(after > 0.0) {
                    return Err("speculate: need after > 0".into());
                }
                let inner = PolicySpec::parse(get("inner").unwrap_or("cluster(k=2)"))?;
                Ok(PolicySpec::Speculate { after, inner: Box::new(inner) })
            }
            "est" => {
                check_keys(&[
                    "model", "sigma", "fraction", "sigma0", "bias", "period", "decay", "inner",
                    "seed",
                ])?;
                let est = match get("model").unwrap_or("lognormal") {
                    "oracle" => EstimatorSpec::Oracle,
                    "lognormal" => EstimatorSpec::LogNormal {
                        sigma: parse_num::<f64>(get("sigma"), "est: sigma", 0.5)?,
                    },
                    "sampling" => EstimatorSpec::Sampling {
                        fraction: parse_num::<f64>(get("fraction"), "est: fraction", 0.01)?,
                        sigma0: parse_num::<f64>(get("sigma0"), "est: sigma0", 0.5)?,
                    },
                    "class" => EstimatorSpec::Class,
                    "proxy" => EstimatorSpec::Proxy {
                        bias: parse_num::<f64>(get("bias"), "est: bias", 1.0)?,
                        sigma: parse_num::<f64>(get("sigma"), "est: sigma", 0.5)?,
                    },
                    "online" => EstimatorSpec::Online {
                        sigma0: parse_num::<f64>(get("sigma0"), "est: sigma0", 0.5)?,
                        period: parse_num::<f64>(get("period"), "est: period", f64::INFINITY)?,
                        decay: parse_num::<f64>(get("decay"), "est: decay", 1.0)?,
                    },
                    other => return Err(format!("est: unknown model `{other}`")),
                };
                if let EstimatorSpec::Sampling { fraction, .. } = est {
                    if !(fraction > 0.0 && fraction <= 1.0) {
                        return Err("est: need 0 < fraction <= 1".into());
                    }
                }
                if let EstimatorSpec::Proxy { bias, .. } = est {
                    if !(bias > 0.0) {
                        return Err("est: need bias > 0".into());
                    }
                }
                if let EstimatorSpec::Online { sigma0, period, decay } = est {
                    if !(sigma0 >= 0.0) {
                        return Err("est: need sigma0 >= 0".into());
                    }
                    if !(period > 0.0) {
                        return Err("est: need period > 0".into());
                    }
                    if !(decay > 0.0 && decay <= 1.0) {
                        return Err("est: need 0 < decay <= 1".into());
                    }
                } else if get("period").is_some() || get("decay").is_some() {
                    return Err(format!(
                        "est: period/decay only apply to model=online, not model={}",
                        est.model_name()
                    ));
                }
                let inner = PolicySpec::parse(get("inner").unwrap_or("psbs"))?;
                let seed = parse_num::<u64>(get("seed"), "est: seed", 0)?;
                Ok(PolicySpec::Estimated { est, inner: Box::new(inner), seed })
            }
            other => Err(format!("unknown policy: {other}")),
        }
    }

    /// Construct the scheduler.  `seed` feeds the components that need
    /// randomness (cluster random dispatch, estimator noise); it is
    /// folded with the spec's own `seed=` argument, so the same spec
    /// under the same runtime seed is fully deterministic.  Base
    /// disciplines ignore it.
    pub fn build_seeded(&self, seed: u64) -> Box<dyn Scheduler> {
        match self {
            PolicySpec::Base(b) => b.build(),
            PolicySpec::Mlfq { levels, q0 } => Box::new(sched::mlfq::Mlfq::new(*levels, *q0)),
            PolicySpec::Cluster { k, dispatch, inner, seed: s0, speeds } => {
                if speeds.is_empty() {
                    // The historical constructor: bit-identical paths.
                    Box::new(Cluster::from_spec(inner, *k, *dispatch, seed.wrapping_add(*s0)))
                } else {
                    Box::new(Cluster::from_spec_full(
                        inner,
                        *k,
                        *dispatch,
                        speeds,
                        seed.wrapping_add(*s0),
                        None,
                        None,
                    ))
                }
            }
            PolicySpec::Estimated { est, inner, seed: s0 } => {
                wrap_estimated(est, inner.build_seeded(seed.wrapping_add(*s0)), seed.wrapping_add(*s0))
            }
            PolicySpec::Speculate { .. } => self.build_cluster_full(seed, None),
        }
    }

    /// Construct the scheduler with fault injection: like
    /// [`PolicySpec::build_seeded`] but threading `cfg` into the
    /// cluster layer.  Base/Mlfq specs are wrapped as a k=1 cluster so
    /// every policy in the zoo can run under a fault plan; `Estimated`
    /// wraps its faulty inner.  With an *empty* config this still
    /// resolves to plain-mode paths (and the bare-spec wrap is the k=1
    /// transparent cluster).
    pub fn build_faulty(
        &self,
        seed: u64,
        cfg: &crate::coordinator::FaultConfig,
    ) -> Box<dyn Scheduler> {
        match self {
            PolicySpec::Estimated { est, inner, seed: s0 } => wrap_estimated(
                est,
                inner.build_faulty(seed.wrapping_add(*s0), cfg),
                seed.wrapping_add(*s0),
            ),
            _ => self.build_cluster_full(seed, Some(cfg)),
        }
    }

    /// Shared lowering for the cluster-shaped builds: peels one
    /// optional `speculate` layer, then builds the cluster beneath it
    /// (wrapping non-cluster specs as k=1).
    fn build_cluster_full(
        &self,
        seed: u64,
        cfg: Option<&crate::coordinator::FaultConfig>,
    ) -> Box<dyn Scheduler> {
        let (after, spec) = match self {
            PolicySpec::Speculate { after, inner } => (Some(*after), inner.as_ref()),
            other => (None, other),
        };
        match spec {
            PolicySpec::Cluster { k, dispatch, inner, seed: s0, speeds } => {
                Box::new(Cluster::from_spec_full(
                    inner,
                    *k,
                    *dispatch,
                    speeds,
                    seed.wrapping_add(*s0),
                    cfg,
                    after,
                ))
            }
            other => Box::new(Cluster::from_spec_full(
                other,
                1,
                Dispatch::RoundRobin,
                &[],
                seed,
                cfg,
                after,
            )),
        }
    }

    /// [`PolicySpec::build_seeded`] at seed 0 — what the `by_name`
    /// compatibility shim uses.
    pub fn build(&self) -> Box<dyn Scheduler> {
        self.build_seeded(0)
    }

    /// Sweep-deployment build: like [`PolicySpec::build_seeded`] but
    /// with the dense seq→slot heap index left off wherever no kill
    /// path can reach it — bare disciplines and estimator inners.
    /// Cluster and speculate layers keep the index: their crash and
    /// backup-kill machinery cancels through it.  The index is a pure
    /// accelerator, so results are bit-identical either way.
    pub fn build_sweep(&self, seed: u64) -> Box<dyn Scheduler> {
        match self {
            PolicySpec::Base(b) => b.build_with(false),
            PolicySpec::Estimated { est, inner, seed: s0 } => {
                wrap_estimated(est, inner.build_sweep(seed.wrapping_add(*s0)), seed.wrapping_add(*s0))
            }
            _ => self.build_seeded(seed),
        }
    }

    /// Relative cost of simulating one workload under this policy —
    /// the planner's chunking weight (largest-first dispatch keeps a
    /// stray fsp-naive cell from serializing the tail of a sweep).
    pub fn cost_weight(&self) -> f64 {
        match self {
            PolicySpec::Base(b) => b.cost_weight(),
            PolicySpec::Mlfq { .. } => 1.0,
            PolicySpec::Cluster { k, inner, .. } => *k as f64 * inner.cost_weight(),
            PolicySpec::Estimated { inner, .. } => inner.cost_weight(),
            PolicySpec::Speculate { inner, .. } => inner.cost_weight(),
        }
    }
}

/// Parse a dispatch name (see the module docs for the list).
fn parse_dispatch(name: &str) -> Result<Dispatch, String> {
    Ok(match name {
        "leastwork" => Dispatch::LeastWork,
        "roundrobin" => Dispatch::RoundRobin,
        "random" => Dispatch::Random,
        "jsq" => Dispatch::Jsq,
        "leasttime" => Dispatch::LeastTime,
        other => match other.strip_prefix("random").and_then(|d| d.parse::<u32>().ok()) {
            Some(d) if d >= 2 => Dispatch::RandomD(d),
            _ => return Err(format!("cluster: unknown dispatch `{other}`")),
        },
    })
}

/// Canonical dispatch rendering (inverse of [`parse_dispatch`]).
fn dispatch_name(d: Dispatch) -> String {
    match d {
        Dispatch::LeastWork => "leastwork".into(),
        Dispatch::RoundRobin => "roundrobin".into(),
        Dispatch::Random => "random".into(),
        Dispatch::Jsq => "jsq".into(),
        Dispatch::LeastTime => "leasttime".into(),
        Dispatch::RandomD(d) => format!("random{d}"),
    }
}

impl fmt::Display for PolicySpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PolicySpec::Base(b) => f.write_str(b.name()),
            PolicySpec::Mlfq { levels, q0 } => write!(f, "mlfq(levels={levels},q0={q0})"),
            PolicySpec::Cluster { k, dispatch, inner, seed, speeds } => {
                write!(f, "cluster(k={k},dispatch={},inner={inner}", dispatch_name(*dispatch))?;
                if !speeds.is_empty() {
                    f.write_str(",speeds=")?;
                    for (i, s) in speeds.iter().enumerate() {
                        if i > 0 {
                            f.write_str(":")?;
                        }
                        write!(f, "{s}")?;
                    }
                }
                if *seed != 0 {
                    write!(f, ",seed={seed}")?;
                }
                f.write_str(")")
            }
            PolicySpec::Speculate { after, inner } => {
                write!(f, "speculate(after={after},inner={inner})")
            }
            PolicySpec::Estimated { est, inner, seed } => {
                write!(f, "est(model={}", est.model_name())?;
                match est {
                    EstimatorSpec::Oracle | EstimatorSpec::Class => {}
                    EstimatorSpec::LogNormal { sigma } => write!(f, ",sigma={sigma}")?,
                    EstimatorSpec::Sampling { fraction, sigma0 } => {
                        write!(f, ",fraction={fraction},sigma0={sigma0}")?
                    }
                    EstimatorSpec::Proxy { bias, sigma } => {
                        write!(f, ",bias={bias},sigma={sigma}")?
                    }
                    EstimatorSpec::Online { sigma0, period, decay } => {
                        write!(f, ",sigma0={sigma0},period={period},decay={decay}")?
                    }
                }
                write!(f, ",inner={inner}")?;
                if *seed != 0 {
                    write!(f, ",seed={seed}")?;
                }
                f.write_str(")")
            }
        }
    }
}

/// Literal conversion for the figure harness and examples (policy
/// literals are compile-time constants there).  Panics on an invalid
/// spec — use [`PolicySpec::parse`] for user input.
impl From<&str> for PolicySpec {
    fn from(s: &str) -> PolicySpec {
        PolicySpec::parse(s).unwrap_or_else(|e| panic!("bad policy spec: {e}"))
    }
}

impl From<String> for PolicySpec {
    fn from(s: String) -> PolicySpec {
        PolicySpec::from(s.as_str())
    }
}

impl From<BasePolicy> for PolicySpec {
    fn from(b: BasePolicy) -> PolicySpec {
        PolicySpec::Base(b)
    }
}

/// Split `args` on top-level commas (parenthesis-depth aware) and parse
/// `key=value` pairs.
fn parse_kv(args: &str) -> Result<Vec<(String, String)>, String> {
    let mut out = Vec::new();
    for part in split_top_level(args, ',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        // `=` inside a composed inner value must not split here: take
        // the first `=` outside parentheses.
        let mut depth = 0usize;
        let mut eq = None;
        for (i, c) in part.char_indices() {
            match c {
                '(' => depth += 1,
                ')' => depth = depth.saturating_sub(1),
                '=' if depth == 0 => {
                    eq = Some(i);
                    break;
                }
                _ => {}
            }
        }
        let Some(eq) = eq else {
            return Err(format!("expected key=value, got `{part}`"));
        };
        out.push((part[..eq].trim().to_string(), part[eq + 1..].trim().to_string()));
    }
    Ok(out)
}

/// Split on `sep` at parenthesis depth 0 (the list separator used by
/// `--policies` and by spec arguments, where values may themselves be
/// parenthesized specs).
pub fn split_top_level(s: &str, sep: char) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '(' => {
                depth += 1;
                cur.push(c);
            }
            ')' => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            c if c == sep && depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    if !cur.is_empty() || !out.is_empty() {
        out.push(cur);
    }
    out
}

fn parse_num<T: std::str::FromStr>(v: Option<&str>, what: &str, default: T) -> Result<T, String> {
    match v {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| format!("{what}: not a number: {v}")),
    }
}

/// Lower an `est(...)` layer onto a built inner scheduler.  The
/// `online` model gets the refinement-capable wrapper
/// ([`estimate::OnlineRefiner`]); every other model keeps the static
/// [`Estimated`] wrapper.  Both seed their rng identically
/// (`seed ^ 0xE57`) and draw identically per arrival, which is what
/// makes `model=online,period=inf` bit-identical to
/// `model=lognormal,sigma=sigma0` — the pin in
/// `rust/tests/online_est.rs`.
fn wrap_estimated(
    est: &EstimatorSpec,
    inner: Box<dyn Scheduler>,
    seed: u64,
) -> Box<dyn Scheduler> {
    match *est {
        EstimatorSpec::Online { sigma0, period, decay } => {
            Box::new(estimate::OnlineRefiner::new(sigma0, period, decay, inner, seed))
        }
        _ => Box::new(Estimated::new(est.build(), inner, seed)),
    }
}

/// Estimator-wrapping scheduler: replaces each arriving job's `est`
/// with the estimator's output (computed from the *true* size, like
/// `estimate::apply`, but online — one draw per arrival in arrival
/// order, so runs are deterministic per seed).
pub struct Estimated {
    est: Box<dyn Estimator>,
    inner: Box<dyn Scheduler>,
    rng: Rng,
    /// Shadow store with the estimator-rewritten `est` column: the
    /// inner discipline reads job fields from this overlay instead of
    /// the caller's store.  Sparse-overlay discipline (see the store
    /// module docs): rows are written by `upsert` and only completed
    /// prefixes retire, so crash re-dispatch re-arrivals stay legal.
    overlay: JobStore,
}

impl Estimated {
    pub fn new(est: Box<dyn Estimator>, inner: Box<dyn Scheduler>, seed: u64) -> Estimated {
        Estimated { est, inner, rng: Rng::new(seed ^ 0xE57), overlay: JobStore::new() }
    }
}

impl Scheduler for Estimated {
    fn name(&self) -> &'static str {
        "estimated"
    }

    fn on_arrival(&mut self, now: f64, id: JobId, store: &JobStore) {
        let est = self.est.estimate(store.size(id), &mut self.rng).max(1e-12);
        self.overlay.upsert(&Job { est, ..store.job(id) });
        self.inner.on_arrival(now, id, &self.overlay);
    }

    fn next_event(&self, now: f64) -> Option<f64> {
        self.inner.next_event(now)
    }

    fn advance(&mut self, now: f64, t: f64, _store: &JobStore, done: &mut Vec<Completion>) {
        let before = done.len();
        self.inner.advance(now, t, &self.overlay, done);
        if done.len() > before {
            for c in &done[before..] {
                self.overlay.mark_completed(c.id);
            }
            self.overlay.retire_completed();
        }
    }

    fn active(&self) -> usize {
        self.inner.active()
    }

    fn cancel(&mut self, now: f64, id: u32) -> bool {
        let ok = self.inner.cancel(now, id);
        if ok {
            self.overlay.mark_cancelled(id);
        }
        ok
    }

    /// An external estimate update (`psbs serve`'s `update` verb)
    /// passes the caller's refreshed value through the overlay verbatim
    /// — no estimator draw, so the arrival-order rng stream is not
    /// perturbed — and re-keys the inner discipline off it.
    fn on_estimate_update(&mut self, now: f64, id: JobId, store: &JobStore) -> bool {
        if !self.overlay.is_active(id) {
            return false;
        }
        self.overlay.update_est(id, store.est(id));
        self.inner.on_estimate_update(now, id, &self.overlay)
    }

    fn fault_stats(&self) -> Option<crate::coordinator::FaultStats> {
        self.inner.fault_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::ALL_POLICIES;
    use crate::sim::run;
    use crate::util::check::{property, Config};
    use crate::workload::SynthConfig;

    #[test]
    fn every_base_name_parses_and_round_trips() {
        for name in ALL_POLICIES {
            let spec = PolicySpec::parse(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(spec.to_string(), *name, "display must equal the canonical name");
            assert_eq!(PolicySpec::parse(&spec.to_string()).unwrap(), spec);
        }
    }

    #[test]
    fn composed_specs_round_trip() {
        for s in [
            "mlfq(levels=12,q0=0.02)",
            "cluster(k=8,dispatch=leastwork,inner=psbs)",
            "cluster(k=4,dispatch=random,inner=srpte+las,seed=9)",
            "est(model=lognormal,sigma=2,inner=psbs)",
            "est(model=sampling,fraction=0.05,sigma0=0.5,inner=fspe+ps)",
            "est(model=class,inner=srpte)",
            "cluster(k=2,dispatch=roundrobin,inner=est(model=oracle,inner=psbs))",
            "cluster(k=4,dispatch=jsq,inner=psbs)",
            "cluster(k=4,dispatch=random2,inner=las)",
            "cluster(k=3,dispatch=leasttime,inner=psbs,speeds=4:2:1)",
            "speculate(after=4,inner=cluster(k=8,dispatch=leastwork,inner=psbs))",
            "speculate(after=2.5,inner=cluster(k=2,dispatch=jsq,inner=srpte))",
            "est(model=online,sigma0=2,period=5,decay=0.9,inner=psbs)",
            "est(model=online,sigma0=0.5,period=inf,decay=1,inner=srpte)",
            "cluster(k=2,dispatch=jsq,inner=est(model=online,sigma0=1,period=10,decay=0.5,inner=spt))",
        ] {
            let spec = PolicySpec::parse(s).unwrap_or_else(|e| panic!("{s}: {e}"));
            let rendered = spec.to_string();
            let reparsed = PolicySpec::parse(&rendered).unwrap();
            assert_eq!(reparsed, spec, "`{s}` -> `{rendered}` must re-parse identically");
        }
    }

    /// Random composed specs round-trip through display/parse — the
    /// grammar and the renderer cannot drift apart.
    #[test]
    fn random_specs_round_trip_property() {
        fn gen_spec(rng: &mut crate::util::rng::Rng, depth: usize) -> PolicySpec {
            let pick = rng.below(if depth == 0 { 2 } else { 6 });
            match pick {
                0 => {
                    let names = ALL_POLICIES;
                    PolicySpec::parse(names[rng.below(names.len() as u64) as usize]).unwrap()
                }
                1 => PolicySpec::Mlfq {
                    levels: 1 + rng.below(16) as usize,
                    q0: 0.01 * (1 + rng.below(50)) as f64,
                },
                2 | 3 => {
                    let k = 1 + rng.below(8) as usize;
                    // Empty (canonical homogeneous) or a vector with at
                    // least one non-unit entry — all-1.0 non-empty
                    // would re-parse to the canonical empty form.
                    let speeds = if rng.below(2) == 0 {
                        Vec::new()
                    } else {
                        (0..k).map(|i| if i == 0 { 2.0 } else { 0.5 * (1 + rng.below(6)) as f64 }).collect()
                    };
                    PolicySpec::Cluster {
                        k,
                        dispatch: [
                            Dispatch::LeastWork,
                            Dispatch::RoundRobin,
                            Dispatch::Random,
                            Dispatch::Jsq,
                            Dispatch::RandomD(2 + rng.below(3) as u32),
                            Dispatch::LeastTime,
                        ][rng.below(6) as usize],
                        inner: Box::new(gen_spec(rng, depth - 1)),
                        seed: rng.below(3),
                        speeds,
                    }
                }
                4 => PolicySpec::Speculate {
                    after: 0.5 * (1 + rng.below(8)) as f64,
                    inner: Box::new(gen_spec(rng, depth - 1)),
                },
                _ => PolicySpec::Estimated {
                    est: match rng.below(6) {
                        0 => EstimatorSpec::Oracle,
                        1 => EstimatorSpec::LogNormal { sigma: 0.25 * (1 + rng.below(8)) as f64 },
                        2 => EstimatorSpec::Sampling {
                            fraction: 0.01 * (1 + rng.below(99)) as f64,
                            sigma0: 0.5,
                        },
                        3 => EstimatorSpec::Class,
                        4 => EstimatorSpec::Proxy {
                            bias: 0.5 * (1 + rng.below(4)) as f64,
                            sigma: 0.25 * (1 + rng.below(4)) as f64,
                        },
                        _ => EstimatorSpec::Online {
                            sigma0: 0.25 * (1 + rng.below(8)) as f64,
                            period: if rng.below(3) == 0 {
                                f64::INFINITY
                            } else {
                                0.5 * (1 + rng.below(16)) as f64
                            },
                            decay: 0.125 * (1 + rng.below(8)) as f64,
                        },
                    },
                    inner: Box::new(gen_spec(rng, depth - 1)),
                    seed: rng.below(2),
                },
            }
        }
        property(
            "policy spec round-trip",
            Config { cases: 64, max_size: 3, ..Default::default() },
            |rng, size| gen_spec(rng, size.min(3)),
            |spec| {
                let rendered = spec.to_string();
                match PolicySpec::parse(&rendered) {
                    Ok(p) if p == *spec => Ok(()),
                    Ok(p) => Err(format!("`{rendered}` re-parsed as `{p}`")),
                    Err(e) => Err(format!("`{rendered}` failed to parse: {e}")),
                }
            },
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in [
            "nope",
            "cluster(k=0,inner=psbs)",
            "cluster(k=2,dispatch=wat,inner=psbs)",
            "cluster(k=2,inner=nope)",
            "mlfq(levels=0)",
            "est(model=wat,inner=psbs)",
            "cluster(k=2,inner=psbs,bogus=1)",
            "cluster(k=2",
            "cluster(k=2,dispatch=random1,inner=psbs)",
            "cluster(k=2,speeds=1:2:3,inner=psbs)",
            "cluster(k=2,speeds=0:1,inner=psbs)",
            "cluster(k=2,speeds=fast:1,inner=psbs)",
            "speculate(after=0,inner=cluster(k=2))",
            "speculate(after=2,inner=psbs,bogus=1)",
            "est(model=online,period=0,inner=psbs)",
            "est(model=online,decay=0,inner=psbs)",
            "est(model=online,decay=1.5,inner=psbs)",
            "est(model=online,sigma0=-1,inner=psbs)",
            "est(model=online,rate=2,inner=psbs)",
            "est(model=lognormal,period=5,inner=psbs)",
        ] {
            assert!(PolicySpec::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn defaults_fill_in() {
        assert_eq!(
            PolicySpec::parse("cluster(k=4)").unwrap(),
            PolicySpec::Cluster {
                k: 4,
                dispatch: Dispatch::LeastWork,
                inner: Box::new(PolicySpec::psbs()),
                seed: 0,
                speeds: Vec::new(),
            }
        );
        // All-unit speeds normalize to the canonical empty form.
        assert_eq!(
            PolicySpec::parse("cluster(k=2,speeds=1:1)").unwrap(),
            PolicySpec::parse("cluster(k=2)").unwrap()
        );
        assert_eq!(PolicySpec::parse("mlfq(levels=8,q0=0.05)").unwrap().to_string(), "mlfq(levels=8,q0=0.05)");
    }

    #[test]
    fn built_cluster_spec_matches_direct_cluster() {
        let cfg = SynthConfig::default().with_njobs(800);
        let jobs = crate::workload::synthesize(&cfg, 12);
        let spec: PolicySpec = "cluster(k=4,dispatch=leastwork,inner=psbs)".into();
        let a = run(spec.build_seeded(7).as_mut(), &jobs).completion;
        let mut direct = Cluster::new("psbs", 4, Dispatch::LeastWork, 7).unwrap();
        let b = run(&mut direct, &jobs).completion;
        assert_eq!(a, b, "spec-built cluster must equal the direct constructor");
    }

    #[test]
    fn estimated_oracle_is_transparent_and_lognormal_is_not() {
        let cfg = SynthConfig::default().with_njobs(600).with_sigma(0.0);
        let jobs = crate::workload::synthesize(&cfg, 5);
        let oracle: PolicySpec = "est(model=oracle,inner=psbs)".into();
        let a = run(oracle.build().as_mut(), &jobs).completion;
        let b = run(PolicySpec::psbs().build().as_mut(), &jobs).completion;
        assert_eq!(a, b, "oracle wrapper must be transparent on exact workloads");

        let noisy: PolicySpec = "est(model=lognormal,sigma=4,inner=psbs)".into();
        let c = run(noisy.build().as_mut(), &jobs).completion;
        assert_ne!(a, c, "heavy noise must change the schedule");
        // Deterministic per seed.
        let c2 = run(noisy.build().as_mut(), &jobs).completion;
        assert_eq!(c, c2);
    }

    #[test]
    fn speculate_spec_builds_and_completes_everything() {
        let cfg = SynthConfig::default().with_njobs(400);
        let jobs = crate::workload::synthesize(&cfg, 17);
        let spec: PolicySpec = "speculate(after=3,inner=cluster(k=4,inner=psbs))".into();
        let mut s = spec.build_seeded(11);
        let r = run(s.as_mut(), &jobs);
        assert!(r.completion.iter().all(|x| x.is_finite()));
        assert!(s.fault_stats().is_some(), "speculation layer must report stats");
    }

    #[test]
    fn faulty_build_with_empty_config_stays_plain_for_every_policy() {
        let empty = crate::coordinator::FaultConfig::default();
        for name in ALL_POLICIES {
            let spec = PolicySpec::parse(name).unwrap();
            let s = spec.build_faulty(3, &empty);
            assert!(s.fault_stats().is_none(), "{name}: empty config must stay plain");
        }
    }

    #[test]
    fn cost_weights_rank_sensibly() {
        let cheap: PolicySpec = "psbs".into();
        let naive: PolicySpec = "fsp-naive".into();
        let cluster: PolicySpec = "cluster(k=8,inner=fsp-naive)".into();
        assert!(naive.cost_weight() > 10.0 * cheap.cost_weight());
        assert!(cluster.cost_weight() > naive.cost_weight());
    }

    #[test]
    fn split_top_level_respects_depth() {
        let parts = split_top_level("psbs,cluster(k=4,inner=ps),las", ',');
        assert_eq!(parts, vec!["psbs", "cluster(k=4,inner=ps)", "las"]);
        assert!(split_top_level("", ',').is_empty());
    }
}
