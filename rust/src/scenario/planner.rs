//! The shared-workload sweep planner.
//!
//! [`eval_cells`] is the single evaluation engine behind
//! [`super::Scenario::tables`], `figures::Ctx::eval_grid` and the
//! `psbs sweep --policies` CLI.  Given a flat [`SweepCell`] grid it:
//!
//! 1. **groups** cells by their [`WorkloadSpec`] (bitwise key — two
//!    cells share a group iff they would synthesize identical
//!    workloads, whether synthetic Table-1 configs or trace-replay
//!    stand-ins);
//! 2. **splits at repetition level**: the parallel work item is
//!    `(group, rep)`, not a whole cell, so even a single expensive
//!    cell's repetitions spread across workers (the `--converge` mode
//!    requirement — late repetitions are scheduled one wave at a time
//!    as cells individually fail their convergence test);
//! 3. inside each item, **synthesizes the workload once** and runs
//!    each required [`Reference`] **once**, then simulates every
//!    not-yet-converged policy of the group against them — the
//!    pre-refactor per-cell path repeated both per policy;
//! 4. orders each wave's items **largest-first** by the group's summed
//!    [`PolicySpec::cost_weight`] before handing them to
//!    [`pool::par_map`]'s self-balancing work queue, so a stray
//!    fsp-naive group cannot serialize the sweep's tail (LPT
//!    heuristic; results are scattered back to cell order, which the
//!    pool already guarantees per item).
//!
//! Sharing is numerically a no-op (same seed, same workload, same
//! reference MST, same accumulation order), so output is bit-identical
//! to [`SweepCell::eval`] per cell — the `share` flag exists precisely
//! so tests can assert that.

use super::{BasePolicy, FaultOutput, PolicySpec, Reference, SweepCell, SweepParams, WorkloadSpec};
use crate::coordinator::{FaultConfig, FaultStats};
use crate::metrics::OnlineMetrics;
use crate::sim::{self, Completion, CompletionSink, Job, JobSource};
use crate::stats::Repetitions;
use crate::util::pool;
use std::collections::HashMap;

/// MST of one policy spec over one workload (seed 0 build — base
/// disciplines ignore the seed entirely).
pub fn mst_of(spec: &PolicySpec, jobs: &[Job]) -> f64 {
    mst_of_seeded(spec, jobs, 0)
}

/// MST with an explicit build seed (cluster random dispatch, estimator
/// noise); the planner passes the cell's repetition seed.  Builds via
/// [`PolicySpec::build_sweep`]: sweep cells never cancel jobs, so the
/// dense heaps skip their seq→slot index (pure accelerator — results
/// are bit-identical to the indexed build, pinned per discipline).
pub fn mst_of_seeded(spec: &PolicySpec, jobs: &[Job], seed: u64) -> f64 {
    let mut s = spec.build_sweep(seed);
    sim::run(s.as_mut(), jobs).mst(jobs)
}

/// Per-job slowdowns of one policy spec over one workload (seed 0
/// build).
pub fn slowdowns_of(spec: &PolicySpec, jobs: &[Job]) -> Vec<f64> {
    slowdowns_of_seeded(spec, jobs, 0)
}

/// Slowdowns with an explicit build seed — the pooled-ECDF metric
/// passes the repetition seed, like [`mst_of_seeded`], so seeded specs
/// (cluster random dispatch, estimator noise) draw independent streams
/// per repetition.  Base disciplines ignore the seed.
pub fn slowdowns_of_seeded(spec: &PolicySpec, jobs: &[Job], seed: u64) -> Vec<f64> {
    let mut s = spec.build_sweep(seed);
    sim::run(s.as_mut(), jobs).slowdowns(jobs)
}

/// Stream one repetition through a shared [`OnlineMetrics`] sink: build
/// the policy with the repetition seed (like [`mst_of_seeded`]) and run
/// the streaming engine over `source` — no completion vector, no
/// slowdown vector, O(active jobs) memory.  The tail-quantile metric
/// calls this once per (policy, rep), reps in order, so the
/// order-sensitive P² sketches accumulate deterministically.
pub fn stream_rep_seeded(
    spec: &PolicySpec,
    source: &mut dyn JobSource,
    seed: u64,
    m: &mut OnlineMetrics,
) {
    let mut s = spec.build_sweep(seed);
    sim::run_streaming(s.as_mut(), source, m);
}

/// Sink behind [`stream_mst_seeded`]: folds arrivals and completions
/// into a per-id sojourn buffer, then sums it **in id order** — the
/// exact (plain left-to-right f64) fold `SimResult::mst` performs, so
/// the streamed value is bit-identical to the materialized one.  The
/// buffer is one f64 per job — the only O(n) state the streamed path
/// keeps (a materialized rep holds the jobs *and* a completion vector).
#[derive(Default)]
struct MstSink {
    /// arrival time until completion, then sojourn (c.time - arrival).
    sojourn: Vec<f64>,
}

impl CompletionSink for MstSink {
    fn on_arrival(&mut self, _now: f64, job: &Job) {
        debug_assert_eq!(job.id as usize, self.sojourn.len(), "stream sources yield dense ids");
        self.sojourn.push(job.arrival);
    }
    fn on_completion(&mut self, _time: f64, c: &Completion) {
        let i = c.id as usize;
        self.sojourn[i] = c.time - self.sojourn[i];
    }
}

impl MstSink {
    fn mst(&self) -> f64 {
        self.sojourn.iter().sum::<f64>() / self.sojourn.len().max(1) as f64
    }
}

/// Streaming counterpart of [`mst_of_seeded`]: arrivals flow straight
/// from the workload's stream source into the engine — the repetition's
/// job vector is never materialized.  Bit-identical to the
/// materialized path (same engine loop, same id-order summation);
/// `SweepCell::eval` uses it for fault-free synthetic mean cells.
///
/// Planner plumbing, not library surface (see [`crate::prelude`]):
/// hidden from docs, subject to change without notice.
#[doc(hidden)]
pub fn stream_mst_seeded(spec: &PolicySpec, w: &WorkloadSpec, seed: u64) -> f64 {
    stream_mst_seeded_at(spec, w, seed, seed)
}

/// Presents every job of a wrapped source with `est = size` — the
/// streaming analogue of [`super::exact_copy`], feeding clairvoyant
/// reference runs without materializing the copied workload.
struct ExactView<'a>(&'a mut dyn JobSource);

impl JobSource for ExactView<'_> {
    fn peek_arrival(&mut self) -> Option<f64> {
        self.0.peek_arrival()
    }
    fn next_job(&mut self) -> Option<Job> {
        self.0.next_job().map(|j| Job { est: j.size, ..j })
    }
}

/// Streamed reference MST (the denominator of ratio cells), built at
/// seed 0 exactly like [`Reference::mst`]: PS over the same arrival
/// stream, or clairvoyant SRPT over an `est = size` view of it.
pub fn stream_reference_mst(r: Reference, w: &WorkloadSpec, rep_seed: u64) -> f64 {
    match r {
        Reference::Ps => stream_mst_seeded_at(&PolicySpec::Base(BasePolicy::Ps), w, rep_seed, 0),
        Reference::OptSrpt => {
            let mut s = PolicySpec::Base(BasePolicy::Srpt).build_sweep(0);
            let mut src = w.stream_source(rep_seed);
            let mut exact = ExactView(src.as_mut());
            let mut sink = MstSink::default();
            sim::run_streaming(s.as_mut(), &mut exact, &mut sink);
            sink.mst()
        }
    }
}

/// [`stream_mst_seeded`] with the workload seed and the policy build
/// seed decoupled (references are always seed-0 builds).
fn stream_mst_seeded_at(spec: &PolicySpec, w: &WorkloadSpec, rep_seed: u64, build: u64) -> f64 {
    let mut s = spec.build_sweep(build);
    let mut src = w.stream_source(rep_seed);
    let mut sink = MstSink::default();
    sim::run_streaming(s.as_mut(), src.as_mut(), &mut sink);
    sink.mst()
}

/// One fault-injected repetition: build the policy through
/// [`PolicySpec::build_faulty`], run the drain-mode engine (lost jobs
/// never complete), and reduce to the requested scalar.  The
/// repetition seed is folded into the fault plan's own seed so every
/// repetition sees an independent (but fully deterministic) fault
/// schedule, mirroring how it feeds the policy build.
///
/// Planner plumbing, not library surface: hidden from docs.
#[doc(hidden)]
pub fn fault_value_seeded(
    spec: &PolicySpec,
    jobs: &[Job],
    seed: u64,
    cfg: &FaultConfig,
    output: Option<FaultOutput>,
) -> f64 {
    fault_rep_seeded(spec, jobs, seed, cfg, output).0
}

/// [`fault_value_seeded`] plus the run's raw [`FaultStats`] — the sweep
/// layer absorbs the stats into per-policy counter tables so non-zero
/// `kills_rejected`/`kills_unsupported` counts cannot vanish silently.
///
/// Planner plumbing, not library surface: hidden from docs.
#[doc(hidden)]
pub fn fault_rep_seeded(
    spec: &PolicySpec,
    jobs: &[Job],
    seed: u64,
    cfg: &FaultConfig,
    output: Option<FaultOutput>,
) -> (f64, FaultStats) {
    let rep_cfg = FaultConfig { seed: cfg.seed.wrapping_add(seed), ..*cfg };
    let mut s = spec.build_faulty(seed, &rep_cfg);
    let r = sim::run_to_drain(s.as_mut(), jobs);
    let stats = s.fault_stats().unwrap_or_default();
    let v = match output {
        // Mean metric under faults: MST over the surviving jobs.
        None => r.mst_completed(jobs),
        Some(FaultOutput::Goodput) => r.completed() as f64 / jobs.len().max(1) as f64,
        Some(FaultOutput::WastedWork) => stats.wasted_fraction(),
        Some(FaultOutput::Restarts) => stats.restarts as f64,
    };
    (v, stats)
}

/// Group cell indices by workload spec, in first-appearance order.
/// Exposed for tests: the "synthesize once per (workload, seed)"
/// guarantee is structural — `eval_group_rep` synthesizes once per
/// group item.
pub fn group_cells(cells: &[SweepCell]) -> Vec<(WorkloadSpec, Vec<usize>)> {
    let mut index: HashMap<[u64; 8], usize> = HashMap::new();
    let mut groups: Vec<(WorkloadSpec, Vec<usize>)> = Vec::new();
    for (ci, cell) in cells.iter().enumerate() {
        let gi = *index.entry(cell.workload.key()).or_insert_with(|| {
            groups.push((cell.workload.clone(), Vec::new()));
            groups.len() - 1
        });
        groups[gi].1.push(ci);
    }
    groups
}

/// One shared work item: synthesize the group's workload for rep `r`,
/// run each needed reference once, simulate every active policy.
/// Returns one value per entry of `active`, in order.
fn eval_group_rep(
    p: SweepParams,
    w: &WorkloadSpec,
    active: &[usize],
    cells: &[SweepCell],
    r: u64,
) -> Vec<f64> {
    let rep_seed = w.rep_seed(p.seed, r);
    let jobs = w.synthesize(rep_seed);
    let mut ps_mst: Option<f64> = None;
    let mut opt_mst: Option<f64> = None;
    active
        .iter()
        .map(|&ci| {
            let cell = &cells[ci];
            let a = cell.rep_value(&jobs, rep_seed);
            match cell.reference {
                None => a,
                Some(Reference::Ps) => {
                    a / *ps_mst.get_or_insert_with(|| Reference::Ps.mst(&jobs))
                }
                Some(Reference::OptSrpt) => {
                    a / *opt_mst.get_or_insert_with(|| Reference::OptSrpt.mst(&jobs))
                }
            }
        })
        .collect()
}

/// Evaluate a sweep grid; results in cell order.
///
/// * `share = true` — the planner: shared workloads/references,
///   repetition-level parallel split, cost-aware ordering.
/// * `share = false` — the legacy per-cell path of PR 1 (one work item
///   per cell, each re-synthesizing its own workloads); kept as the
///   reference the bit-identity tests compare against.
pub fn eval_cells(p: SweepParams, threads: usize, share: bool, cells: &[SweepCell]) -> Vec<f64> {
    if !share {
        return pool::par_map(threads, cells, move |c| c.eval(p));
    }

    let groups = group_cells(cells);
    let mut accs: Vec<Repetitions> = vec![Repetitions::default(); cells.len()];
    let mut stopped: Vec<bool> = vec![false; cells.len()];

    let max = if p.converge { p.reps * 10 } else { p.reps };
    let mut r0: u64 = 0;
    while r0 < max {
        // First wave: the full `--reps` budget at once (every cell
        // needs at least that many).  Later waves (converge mode only):
        // one repetition at a time, only for still-unconverged cells.
        let span = if r0 == 0 { p.reps.min(max) } else { 1 };

        // Active cells per group are fixed for the wave: the stop rule
        // cannot fire before rep `reps - 1`, the last rep of wave one.
        let active: Vec<Vec<usize>> = groups
            .iter()
            .map(|(_, cs)| cs.iter().copied().filter(|&ci| !stopped[ci]).collect())
            .collect();
        let mut items: Vec<(usize, u64)> = Vec::new();
        for (gi, act) in active.iter().enumerate() {
            if act.is_empty() {
                continue;
            }
            for r in r0..r0 + span {
                items.push((gi, r));
            }
        }
        if items.is_empty() {
            break;
        }

        // Largest-first (LPT) ordering by summed policy cost; stable on
        // the original order so equal-cost waves keep a deterministic
        // layout.  Results are reassembled per item, so ordering only
        // affects wall-clock, never values.
        let group_cost: Vec<f64> = active
            .iter()
            .map(|act| act.iter().map(|&ci| cells[ci].policy.cost_weight()).sum::<f64>() + 1.0)
            .collect();
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by(|&a, &b| {
            group_cost[items[b].0]
                .partial_cmp(&group_cost[items[a].0])
                .unwrap()
                .then(a.cmp(&b))
        });
        let ordered: Vec<(usize, u64)> = order.iter().map(|&i| items[i]).collect();

        let results = pool::par_map(threads, &ordered, |&(gi, r)| {
            eval_group_rep(p, &groups[gi].0, &active[gi], cells, r)
        });
        let mut by_item: HashMap<(usize, u64), Vec<f64>> = HashMap::with_capacity(ordered.len());
        for (key, vals) in ordered.into_iter().zip(results) {
            by_item.insert(key, vals);
        }

        // Sequential replay in repetition order: each cell accumulates
        // exactly the values (and applies exactly the stop rule) the
        // serial per-cell loop would.
        for r in r0..r0 + span {
            for (gi, act) in active.iter().enumerate() {
                if act.is_empty() {
                    continue;
                }
                let vals = by_item.remove(&(gi, r)).expect("planner item missing");
                for (&ci, v) in act.iter().zip(vals) {
                    if stopped[ci] {
                        continue;
                    }
                    accs[ci].push(v);
                    if r + 1 >= p.reps && (!p.converge || accs[ci].converged(p.reps as usize)) {
                        stopped[ci] = true;
                    }
                }
            }
        }
        r0 += span;
        if stopped.iter().all(|&s| s) {
            break;
        }
    }

    accs.iter().map(|a| a.mean()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::GRID;
    use crate::scenario::TraceSpec;
    use crate::workload::traces::TraceName;
    use crate::workload::SynthConfig;

    #[test]
    fn grouping_merges_identical_configs_only() {
        let base = SynthConfig::default().with_njobs(100);
        let cells = vec![
            SweepCell::ratio("psbs", Reference::OptSrpt, base),
            SweepCell::ratio("srpte", Reference::OptSrpt, base),
            SweepCell::ratio("ps", Reference::Ps, base),
            SweepCell::ratio("psbs", Reference::OptSrpt, base.with_sigma(2.0)),
        ];
        let groups = group_cells(&cells);
        assert_eq!(groups.len(), 2, "three same-config cells share one group");
        assert_eq!(groups[0].1, vec![0, 1, 2]);
        assert_eq!(groups[1].1, vec![3]);
    }

    #[test]
    fn grouping_keeps_trace_and_synth_apart() {
        let synth = SynthConfig::default().with_njobs(100);
        let trace =
            TraceSpec { source: TraceName::Facebook.into(), njobs: 100, load: 0.9, sigma: 0.5 };
        let cells = vec![
            SweepCell::ratio("psbs", Reference::OptSrpt, synth),
            SweepCell::ratio("psbs", Reference::OptSrpt, trace.clone()),
            SweepCell::ratio("ps", Reference::OptSrpt, trace.clone()),
            SweepCell::ratio(
                "ps",
                Reference::OptSrpt,
                TraceSpec { source: TraceName::Ircache.into(), ..trace },
            ),
        ];
        let groups = group_cells(&cells);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[1].1, vec![1, 2], "same trace spec shares a group");
    }

    /// File-backed traces group on the identity of their loaded row
    /// buffer: clones of one load (how a scenario fans out across
    /// cells) share a group; a separately loaded buffer — even with
    /// identical-looking contents — never merges, so two different row
    /// sets behind one path can never be conflated; and different
    /// knobs or a stand-in always split.
    #[test]
    fn grouping_keys_trace_files_by_row_identity() {
        use crate::scenario::TraceSource;
        use crate::workload::trace_file::{parse, TraceFile};
        use std::sync::Arc;
        let rows = Arc::new(parse("0,10\n1,20\n2,15\n").unwrap());
        let reload = Arc::new(parse("0,10\n1,20\n2,15\n").unwrap());
        let file = |rows: &Arc<Vec<_>>| {
            TraceSpec::new(TraceFile { path: "t.csv".into(), rows: rows.clone() })
        };
        let builtin = TraceSpec {
            source: TraceSource::Builtin(TraceName::Facebook),
            njobs: 3,
            load: 0.9,
            sigma: 0.5,
        };
        let cells = vec![
            SweepCell::ratio("psbs", Reference::OptSrpt, file(&rows)),
            SweepCell::ratio("ps", Reference::OptSrpt, file(&rows)),
            SweepCell::ratio("ps", Reference::OptSrpt, file(&reload)),
            SweepCell::ratio("ps", Reference::OptSrpt, TraceSpec { sigma: 2.0, ..file(&rows) }),
            SweepCell::ratio("ps", Reference::OptSrpt, builtin),
        ];
        let groups = group_cells(&cells);
        assert_eq!(groups.len(), 4);
        assert_eq!(groups[0].1, vec![0, 1], "clones of one load share a group");
        assert_eq!(groups[1].1, vec![2], "a separate load never merges");
    }

    #[test]
    fn planner_matches_per_cell_eval_bitwise() {
        let base = SynthConfig::default().with_njobs(180);
        let mut cells = Vec::new();
        for &sigma in &GRID[..3] {
            for policy in ["psbs", "srpte", "ps"] {
                cells.push(SweepCell::ratio(policy, Reference::OptSrpt, base.with_sigma(sigma)));
            }
            cells.push(SweepCell::mst("las", base.with_sigma(sigma)));
        }
        let p = SweepParams { reps: 3, seed: 23, converge: false };
        let per_cell: Vec<u64> =
            eval_cells(p, 1, false, &cells).into_iter().map(f64::to_bits).collect();
        for threads in [1usize, 2, 4] {
            let shared: Vec<u64> =
                eval_cells(p, threads, true, &cells).into_iter().map(f64::to_bits).collect();
            assert_eq!(per_cell, shared, "threads={threads}");
        }
    }

    #[test]
    fn converge_mode_replays_the_serial_stop_rule() {
        // Heavy-tailed ratios at 2 base reps rarely converge instantly,
        // so the wave loop actually exercises continuation waves.
        let base = SynthConfig::default().with_njobs(150);
        let cells = vec![
            SweepCell::ratio("psbs", Reference::OptSrpt, base),
            SweepCell::ratio("las", Reference::OptSrpt, base.with_sigma(2.0)),
        ];
        let p = SweepParams { reps: 2, seed: 3, converge: true };
        let per_cell: Vec<u64> =
            eval_cells(p, 1, false, &cells).into_iter().map(f64::to_bits).collect();
        let shared: Vec<u64> =
            eval_cells(p, 3, true, &cells).into_iter().map(f64::to_bits).collect();
        assert_eq!(per_cell, shared);
    }

    /// Fault-injected cells run through the same planner machinery:
    /// bit-identity across share x threads, and the per-rep fault
    /// schedule is deterministic.
    #[test]
    fn fault_cells_match_per_cell_eval_bitwise() {
        use crate::coordinator::{FaultConfig, FaultSpec, RetryPolicy};
        let base = SynthConfig::default().with_njobs(150);
        let cfg = FaultConfig {
            spec: FaultSpec { mtbf: 40.0, mttr: 4.0, slowdown: 0.5 },
            retry: RetryPolicy { max_attempts: 2, backoff: 0.1 },
            seed: 3,
        };
        let mut cells = Vec::new();
        for policy in ["psbs", "ps", "cluster(k=2,dispatch=jsq,inner=psbs)"] {
            for output in [FaultOutput::Goodput, FaultOutput::WastedWork, FaultOutput::Restarts]
            {
                cells.push(SweepCell {
                    policy: policy.into(),
                    workload: base.into(),
                    reference: None,
                    faults: Some(cfg),
                    output: Some(output),
                    counters: None,
                });
            }
            // Mean-under-faults (survivor MST), ratio vs clean PS.
            cells.push(SweepCell {
                policy: policy.into(),
                workload: base.into(),
                reference: Some(Reference::Ps),
                faults: Some(cfg),
                output: None,
                counters: None,
            });
        }
        // A fault-free cell in the same grid keeps its old path.
        cells.push(SweepCell::ratio("psbs", Reference::Ps, base));
        let p = SweepParams { reps: 2, seed: 19, converge: false };
        let per_cell: Vec<u64> =
            eval_cells(p, 1, false, &cells).into_iter().map(f64::to_bits).collect();
        assert!(per_cell.iter().all(|&b| f64::from_bits(b).is_finite()));
        for threads in [1usize, 3] {
            let shared: Vec<u64> =
                eval_cells(p, threads, true, &cells).into_iter().map(f64::to_bits).collect();
            assert_eq!(per_cell, shared, "threads={threads}");
        }
    }

    /// `stream_rep_seeded` reproduces the materialized run: same job
    /// count, MST within compensated-summation tolerance (completion
    /// order vs id order), built from the same repetition seed.
    #[test]
    fn streamed_rep_matches_materialized_run() {
        use crate::metrics::OnlineMetrics;
        let w: WorkloadSpec = SynthConfig::default().with_njobs(300).into();
        let spec: PolicySpec = "psbs".into();
        let seed = w.rep_seed(7, 0);
        let jobs = w.synthesize(seed);
        let want = mst_of_seeded(&spec, &jobs, seed);
        let mut m = OnlineMetrics::new();
        let mut src = w.stream_source(seed);
        stream_rep_seeded(&spec, src.as_mut(), seed, &mut m);
        assert_eq!(m.count(), jobs.len() as u64);
        let got = m.mst().unwrap();
        assert!((got - want).abs() <= 1e-9 * want.abs().max(1.0), "got {got} want {want}");
    }

    /// Satellite pin: the streamed mean path never materializes the
    /// repetition's jobs yet is **bit-identical** to the materialized
    /// one — across disciplines (including a seeded estimator overlay
    /// and a hybrid) and repetition seeds.
    #[test]
    fn streamed_mst_is_bit_identical_to_materialized() {
        let w: WorkloadSpec = SynthConfig::default().with_njobs(250).into();
        for policy in ["psbs", "srpte+ps", "fspe", "las", "mlfq", "est(sigma=0.7,inner=srpt)"] {
            let spec: PolicySpec = policy.into();
            for r in 0..3u64 {
                let seed = w.rep_seed(11, r);
                let jobs = w.synthesize(seed);
                let want = mst_of_seeded(&spec, &jobs, seed);
                let got = stream_mst_seeded(&spec, &w, seed);
                assert_eq!(want.to_bits(), got.to_bits(), "{policy} rep {r}");
            }
        }
    }

    /// The streamed references match [`Reference::mst`] bitwise: PS on
    /// the raw stream, clairvoyant SRPT on the `est = size` view.
    #[test]
    fn streamed_references_are_bit_identical() {
        let w: WorkloadSpec = SynthConfig::default().with_njobs(250).into();
        for r in 0..3u64 {
            let seed = w.rep_seed(5, r);
            let jobs = w.synthesize(seed);
            for reference in [Reference::Ps, Reference::OptSrpt] {
                let want = reference.mst(&jobs);
                let got = stream_reference_mst(reference, &w, seed);
                assert_eq!(want.to_bits(), got.to_bits(), "{reference:?} rep {r}");
            }
        }
    }

    #[test]
    fn empty_grid_and_zero_reps() {
        let p = SweepParams { reps: 0, seed: 1, converge: false };
        assert!(eval_cells(p, 2, true, &[]).is_empty());
        let cells = [SweepCell::mst("ps", SynthConfig::default().with_njobs(50))];
        assert_eq!(eval_cells(p, 2, true, &cells), vec![0.0]);
    }
}
