//! Declarative scenario layer: typed policy specs, scenario
//! descriptions, and the shared-workload sweep planner.
//!
//! The paper's evaluation (§6–7) — and everything the ROADMAP wants to
//! grow beyond it — is a grid of *scenarios*: policy x workload shape x
//! estimation error x weights, evaluated over seeded repetitions and
//! normalized against a reference discipline.  This module makes that
//! structure first-class:
//!
//! * [`PolicySpec`] (`spec`) — typed, parse/display-able policy
//!   specifications composing parameterized deployments
//!   (`cluster(k=8,dispatch=leastwork,inner=psbs)`,
//!   `est(model=sampling,fraction=0.05,inner=psbs)`,
//!   `mlfq(levels=12,q0=0.02)`) over the base disciplines.
//!   [`crate::sched::by_name`] is a compatibility shim over
//!   [`PolicySpec::parse`].
//! * [`Scenario`] — a declarative sweep description: base workload
//!   config x grid axes x policy set x optional [`Reference`]; one
//!   generic evaluator ([`Scenario::table`]) turns it into a figure
//!   table, so each `figures::figN` collapses to a ~10-line
//!   declaration.
//! * the **planner** (`planner`) — evaluates a flat [`SweepCell`] grid
//!   by grouping cells on their workload config, synthesizing each
//!   `(config, seed)` workload **once**, running each [`Reference`]
//!   **once per seed**, and fanning the per-policy simulations out
//!   through [`crate::util::pool`] with cost-aware largest-first
//!   ordering (an fsp-naive cell costs ~100x a psbs cell) and a
//!   repetition-level work split in `--converge` mode.
//!
//! **Bit-identity invariant.** Sharing is numerically a no-op: the same
//! seed produces the same workload, hence the same reference MST and
//! the same per-policy MST, and repetition means accumulate in the same
//! order — so planner output is bit-identical to the per-cell path of
//! PR 1 (and to the serial path, for every thread count).
//! `figures::tests` pins this for Figs. 4/6/9 across `share` x
//! `threads`.

pub mod planner;
pub mod spec;

pub use planner::{eval_cells, group_cells, mst_of, mst_of_seeded, slowdowns_of};
pub use spec::{BasePolicy, Estimated, EstimatorSpec, PolicySpec};

use crate::figures::tables::Table;
use crate::sim::Job;
use crate::workload::SynthConfig;

/// Scalar sweep parameters, detached from `figures::Ctx` so worker
/// threads never touch the (non-`Sync`) runtime handle.
#[derive(Debug, Clone, Copy)]
pub struct SweepParams {
    pub reps: u64,
    pub seed: u64,
    pub converge: bool,
}

/// Normalization baseline for MST ratios.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reference {
    /// PS on the same workload (Fig. 3, Fig. 15).
    Ps,
    /// Optimal MST: SRPT with *exact* sizes (Figs. 5, 6, 10, 12-14).
    OptSrpt,
}

impl Reference {
    pub fn mst(&self, jobs: &[Job]) -> f64 {
        match self {
            Reference::Ps => mst_of(&PolicySpec::Base(BasePolicy::Ps), jobs),
            Reference::OptSrpt => {
                mst_of(&PolicySpec::Base(BasePolicy::Srpt), &exact_copy(jobs))
            }
        }
    }
}

/// The same workload with perfect size information.
pub fn exact_copy(jobs: &[Job]) -> Vec<Job> {
    jobs.iter().map(|j| Job { est: j.size, ..*j }).collect()
}

/// One cell of a sweep grid: one (policy, workload-config) data point,
/// evaluated over seeded repetitions.  Figures and the CLI build flat
/// `Vec<SweepCell>` grids and hand them to [`eval_cells`] (shared
/// planner or the per-cell legacy path).
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub policy: PolicySpec,
    pub cfg: SynthConfig,
    /// `Some(r)` => mean of per-seed MST ratios against `r`;
    /// `None` => mean raw MST.
    pub reference: Option<Reference>,
}

impl SweepCell {
    /// A ratio cell (the common case).
    pub fn ratio(
        policy: impl Into<PolicySpec>,
        reference: Reference,
        cfg: SynthConfig,
    ) -> SweepCell {
        SweepCell { policy: policy.into(), cfg, reference: Some(reference) }
    }

    /// A raw-MST cell.
    pub fn mst(policy: impl Into<PolicySpec>, cfg: SynthConfig) -> SweepCell {
        SweepCell { policy: policy.into(), cfg, reference: None }
    }

    /// Evaluate this cell alone: a pure function of (cell, params),
    /// safe to run on any worker.  This is the legacy per-cell path the
    /// planner is checked against — it re-synthesizes the workload and
    /// re-runs the reference for every cell.
    pub fn eval(&self, p: SweepParams) -> f64 {
        let mut reps = crate::stats::Repetitions::default();
        let max = if p.converge { p.reps * 10 } else { p.reps };
        for r in 0..max {
            let rep_seed = p.seed.wrapping_add(r * 7919);
            let jobs = crate::workload::synthesize(&self.cfg, rep_seed);
            let a = mst_of_seeded(&self.policy, &jobs, rep_seed);
            reps.push(match self.reference {
                None => a,
                Some(reference) => a / reference.mst(&jobs),
            });
            if r + 1 >= p.reps && (!p.converge || reps.converged(p.reps as usize)) {
                break;
            }
        }
        reps.mean()
    }
}

/// Which [`SynthConfig`] knob a grid axis sweeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AxisParam {
    Shape,
    Sigma,
    Load,
    Timeshape,
    Njobs,
    Beta,
}

impl AxisParam {
    pub fn apply(self, cfg: SynthConfig, v: f64) -> SynthConfig {
        match self {
            AxisParam::Shape => cfg.with_shape(v),
            AxisParam::Sigma => cfg.with_sigma(v),
            AxisParam::Load => cfg.with_load(v),
            AxisParam::Timeshape => cfg.with_timeshape(v),
            AxisParam::Njobs => cfg.with_njobs(v as usize),
            AxisParam::Beta => cfg.with_beta(v),
        }
    }

    /// CLI name (the `--axis` argument of `psbs sweep`).
    pub fn parse(s: &str) -> Option<AxisParam> {
        Some(match s {
            "shape" => AxisParam::Shape,
            "sigma" => AxisParam::Sigma,
            "load" => AxisParam::Load,
            "timeshape" => AxisParam::Timeshape,
            "njobs" => AxisParam::Njobs,
            "beta" => AxisParam::Beta,
            _ => return None,
        })
    }
}

/// One grid axis: a labelled list of values for one config knob.
#[derive(Debug, Clone)]
pub struct Axis {
    pub label: String,
    pub param: AxisParam,
    pub values: Vec<f64>,
}

/// A declarative sweep scenario: `base` workload config, grid `axes`
/// (row-major cartesian product), a labelled `policies` set, and an
/// optional normalization [`Reference`].  [`Scenario::table`] is the
/// one generic executor every grid figure now goes through.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub base: SynthConfig,
    pub axes: Vec<Axis>,
    /// (column label, spec) — the label is usually `spec.to_string()`,
    /// but figures may override it (e.g. Fig. 15's `psbs_over_ps`).
    pub policies: Vec<(String, PolicySpec)>,
    pub reference: Option<Reference>,
}

impl Scenario {
    pub fn new(name: impl Into<String>, base: SynthConfig) -> Scenario {
        Scenario {
            name: name.into(),
            base,
            axes: Vec::new(),
            policies: Vec::new(),
            reference: None,
        }
    }

    /// Add a grid axis (outermost first).
    pub fn axis(mut self, label: impl Into<String>, param: AxisParam, values: &[f64]) -> Scenario {
        self.axes.push(Axis { label: label.into(), param, values: values.to_vec() });
        self
    }

    /// Add policies labelled by their canonical spec strings.
    pub fn policies(mut self, specs: &[&str]) -> Scenario {
        for s in specs {
            self.policies.push((s.to_string(), PolicySpec::from(*s)));
        }
        self
    }

    /// Add one policy under an explicit column label.
    pub fn policy_as(mut self, label: impl Into<String>, spec: impl Into<PolicySpec>) -> Scenario {
        self.policies.push((label.into(), spec.into()));
        self
    }

    /// Normalize against `r` (omit for raw MST columns).
    pub fn vs(mut self, r: Reference) -> Scenario {
        self.reference = Some(r);
        self
    }

    /// The flat cell grid (grid-point-major, policy-minor — the cell
    /// order every pre-refactor figure used).
    pub fn cells(&self) -> Vec<SweepCell> {
        let points = self.grid_points();
        let mut cells = Vec::with_capacity(points.len() * self.policies.len());
        for point in &points {
            let mut cfg = self.base;
            for (axis, &v) in self.axes.iter().zip(point) {
                cfg = axis.param.apply(cfg, v);
            }
            for (_, spec) in &self.policies {
                cells.push(SweepCell { policy: spec.clone(), cfg, reference: self.reference });
            }
        }
        cells
    }

    /// Row-major cartesian product of the axis values.
    fn grid_points(&self) -> Vec<Vec<f64>> {
        let mut points: Vec<Vec<f64>> = vec![Vec::new()];
        for axis in &self.axes {
            let mut next = Vec::with_capacity(points.len() * axis.values.len());
            for p in &points {
                for &v in &axis.values {
                    let mut q = p.clone();
                    q.push(v);
                    next.push(q);
                }
            }
            points = next;
        }
        points
    }

    /// Evaluate the scenario into a table: one row per grid point
    /// (axis value columns first), one column per policy.
    pub fn table(&self, p: SweepParams, threads: usize, share: bool) -> Table {
        let header: Vec<String> = self
            .axes
            .iter()
            .map(|a| a.label.clone())
            .chain(self.policies.iter().map(|(l, _)| l.clone()))
            .collect();
        let mut t = Table::new(self.name.clone(), header);
        let cells = self.cells();
        let vals = eval_cells(p, threads, share, &cells);
        let mut it = vals.into_iter();
        for point in self.grid_points() {
            let mut row = point;
            row.extend((&mut it).take(self.policies.len()));
            t.push(row);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::GRID;

    fn params() -> SweepParams {
        SweepParams { reps: 2, seed: 11, converge: false }
    }

    #[test]
    fn scenario_table_shape_matches_declaration() {
        let sc = Scenario::new("t", SynthConfig::default().with_njobs(150))
            .axis("shape", AxisParam::Shape, &[0.5, 2.0])
            .axis("sigma", AxisParam::Sigma, &[0.25, 1.0, 4.0])
            .policies(&["psbs", "ps"])
            .vs(Reference::OptSrpt);
        let t = sc.table(params(), 2, true);
        assert_eq!(t.header, vec!["shape", "sigma", "psbs", "ps"]);
        assert_eq!(t.rows.len(), 6);
        // Row-major: shape outer, sigma inner.
        assert_eq!((t.rows[0][0], t.rows[0][1]), (0.5, 0.25));
        assert_eq!((t.rows[4][0], t.rows[4][1]), (2.0, 1.0));
        for row in &t.rows {
            assert!(row[2..].iter().all(|v| v.is_finite() && *v > 0.0));
        }
    }

    #[test]
    fn shared_planner_is_bit_identical_to_per_cell_path() {
        let sc = Scenario::new("t", SynthConfig::default().with_njobs(200))
            .axis("sigma", AxisParam::Sigma, &GRID[..3])
            .policies(&["psbs", "srpte", "ps"])
            .vs(Reference::OptSrpt);
        let cells = sc.cells();
        for converge in [false, true] {
            let p = SweepParams { reps: 2, seed: 7, converge };
            let legacy = eval_cells(p, 1, false, &cells);
            for threads in [1usize, 3] {
                let shared = eval_cells(p, threads, true, &cells);
                let lb: Vec<u64> = legacy.iter().map(|v| v.to_bits()).collect();
                let sb: Vec<u64> = shared.iter().map(|v| v.to_bits()).collect();
                assert_eq!(lb, sb, "converge={converge} threads={threads}");
            }
        }
    }

    #[test]
    fn composed_cluster_cells_are_sweepable() {
        let sc = Scenario::new("t", SynthConfig::default().with_njobs(150).with_load(1.8))
            .axis("sigma", AxisParam::Sigma, &[0.5])
            .policies(&["cluster(k=2,dispatch=leastwork,inner=psbs)", "ps"])
            .vs(Reference::Ps);
        let t = sc.table(params(), 1, true);
        assert_eq!(t.rows.len(), 1);
        assert!(t.rows[0][1].is_finite());
        // PS against itself is exactly 1 on every seed.
        assert!((t.rows[0][2] - 1.0).abs() < 1e-12);
    }
}
