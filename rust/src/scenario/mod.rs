//! Declarative scenario layer: typed policy specs, scenario
//! descriptions, persistable scenario files, and the shared-workload
//! sweep planner.
//!
//! The paper's evaluation (§6–7) — and everything the ROADMAP wants to
//! grow beyond it — is a grid of *scenarios*: policy x workload shape x
//! estimation error x weights, evaluated over seeded repetitions and
//! normalized against a reference discipline.  This module makes that
//! structure first-class:
//!
//! * [`PolicySpec`] (`spec`) — typed, parse/display-able policy
//!   specifications composing parameterized deployments
//!   (`cluster(k=8,dispatch=leastwork,inner=psbs)`,
//!   `est(model=sampling,fraction=0.05,inner=psbs)`,
//!   `mlfq(levels=12,q0=0.02)`) over the base disciplines.
//!   [`crate::sched::by_name`] is a compatibility shim over
//!   [`PolicySpec::parse`].
//! * [`Scenario`] — a declarative sweep description: a
//!   [`WorkloadSpec`] (synthetic Table-1 model, trace-replay
//!   stand-in, or a user-supplied on-disk trace file via
//!   [`TraceSource::File`]) x grid axes (row axes become table
//!   columns, *split* axes fan out into one table per value) x policy
//!   set x [`Metric`] x optional [`Reference`], plus optional
//!   per-scenario `reps`/`converge` overrides; one generic evaluator
//!   ([`Scenario::tables`]) turns it into figure tables, so each
//!   scenario-shaped `figures::figN` collapses to a ~10-line
//!   declaration — including the pooled-slowdown ECDFs (Figs. 4/8),
//!   the conditional-slowdown fairness table (Fig. 7) and the trace
//!   replays (Figs. 12/13) that used to be bespoke work-item code.
//! * scenario **files** (`file`) — a dependency-free TOML-subset
//!   serialization of [`Scenario`] (`to_toml`/`parse_toml`,
//!   round-trip property-tested like `PolicySpec`), so experiment
//!   grids live *outside* the binary: `psbs sweep --scenario f.toml`
//!   runs one, `psbs scenario export` dumps the built-ins into
//!   `scenarios/`.
//! * the **planner** (`planner`) — evaluates a flat [`SweepCell`] grid
//!   by grouping cells on their workload spec, synthesizing each
//!   `(workload, seed)` workload **once**, running each [`Reference`]
//!   **once per seed**, and fanning the per-policy simulations out
//!   through [`crate::util::pool`] with cost-aware largest-first
//!   ordering (an fsp-naive cell costs ~100x a psbs cell) and a
//!   repetition-level work split in `--converge` mode.
//!
//! **Bit-identity invariant.** Sharing is numerically a no-op: the same
//! seed produces the same workload, hence the same reference MST and
//! the same per-policy MST, and repetition means accumulate in the same
//! order — so planner output is bit-identical to the per-cell path of
//! PR 1 (and to the serial path, for every thread count).
//! `figures::tests` pins this for Figs. 4/6/9 across `share` x
//! `threads`, and `tests` below for one pooled and one trace scenario.

pub mod file;
pub mod planner;
pub mod spec;

pub use planner::{
    eval_cells, fault_rep_seeded, fault_value_seeded, group_cells, mst_of, mst_of_seeded,
    slowdowns_of, slowdowns_of_seeded, stream_mst_seeded, stream_reference_mst,
    stream_rep_seeded,
};
pub use spec::{BasePolicy, Estimated, EstimatorSpec, PolicySpec};

use crate::coordinator::{FaultConfig, FaultStats};
use crate::figures::tables::Table;
use crate::metrics;
use crate::sim::Job;
use crate::util::pool;
use crate::workload::trace_file::TraceFile;
use crate::workload::traces::{self, TraceName};
use crate::workload::{SizeDist, SynthConfig};

/// Column order of the `{table}_fault_counters` companion table a
/// fault scenario emits next to each mean/fault table: after the
/// leading `policy` column (the 0-based index into the scenario's
/// policy declaration order) come these per-policy totals, summed over
/// every repetition of every grid cell.  All are exact `u64` counts, so
/// the table is bit-identical for any thread count or `share` setting.
pub const FAULT_COUNTER_COLUMNS: [&str; 7] = [
    "crashes",
    "restarts",
    "speculations",
    "lost",
    "killed",
    "kills_rejected",
    "kills_unsupported",
];

/// Scalar sweep parameters, detached from `figures::Ctx` so worker
/// threads never touch the (non-`Sync`) runtime handle.
#[derive(Debug, Clone, Copy)]
pub struct SweepParams {
    pub reps: u64,
    pub seed: u64,
    pub converge: bool,
}

/// Normalization baseline for MST ratios.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reference {
    /// PS on the same workload (Fig. 3, Fig. 15).
    Ps,
    /// Optimal MST: SRPT with *exact* sizes (Figs. 5, 6, 10, 12-14).
    OptSrpt,
}

impl Reference {
    pub fn mst(&self, jobs: &[Job]) -> f64 {
        match self {
            Reference::Ps => mst_of(&PolicySpec::Base(BasePolicy::Ps), jobs),
            Reference::OptSrpt => {
                mst_of(&PolicySpec::Base(BasePolicy::Srpt), &exact_copy(jobs))
            }
        }
    }

    /// Per-job slowdowns of the reference discipline on `jobs` — the
    /// [`Metric::DominanceVsRef`] pairing baseline, same policy
    /// choices as [`Reference::mst`].
    pub fn slowdowns(&self, jobs: &[Job]) -> Vec<f64> {
        match self {
            Reference::Ps => slowdowns_of(&PolicySpec::Base(BasePolicy::Ps), jobs),
            Reference::OptSrpt => {
                slowdowns_of(&PolicySpec::Base(BasePolicy::Srpt), &exact_copy(jobs))
            }
        }
    }

    /// Canonical short name (scenario files: `reference = "..."`).
    pub fn name(self) -> &'static str {
        match self {
            Reference::OptSrpt => "opt",
            Reference::Ps => "ps",
        }
    }
}

/// The same workload with perfect size information.
pub fn exact_copy(jobs: &[Job]) -> Vec<Job> {
    jobs.iter().map(|j| Job { est: j.size, ..*j }).collect()
}

/// Where a trace-replay's records come from: a published stand-in or a
/// user-supplied on-disk trace file
/// ([`crate::workload::trace_file`]'s `arrival,size[,weight][,estimate]`
/// format, loaded once and shared by `Arc` across every clone).
#[derive(Debug, Clone, PartialEq)]
pub enum TraceSource {
    /// Synthetic stand-in matched to published statistics, re-drawn
    /// per repetition seed (Figs. 12/13).
    Builtin(TraceName),
    /// Fixed on-disk records; only the size-estimation error varies
    /// per repetition.
    File(TraceFile),
}

impl From<TraceName> for TraceSource {
    fn from(n: TraceName) -> TraceSource {
        TraceSource::Builtin(n)
    }
}

impl From<TraceFile> for TraceSource {
    fn from(f: TraceFile) -> TraceSource {
        TraceSource::File(f)
    }
}

impl TraceSource {
    /// The most records this source can replay (the `njobs` default
    /// and cap): the published job count, or the file's row count.
    pub fn max_jobs(&self) -> usize {
        match self {
            TraceSource::Builtin(n) => n.stats().jobs,
            TraceSource::File(f) => f.rows.len(),
        }
    }
}

/// A trace-replay workload description (Figs. 12/13 and on-disk
/// replays): which record source, how many records to replay, the load
/// normalization and the size-estimation error level.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpec {
    pub source: TraceSource,
    /// Replay at most this many records (the full published traces are
    /// 24 443 / 206 914 jobs).
    pub njobs: usize,
    /// Offered-load normalization (paper §7.8: 0.9).
    pub load: f64,
    /// Log-normal estimation-error sigma.
    pub sigma: f64,
}

impl TraceSpec {
    /// A spec replaying the whole source at the paper's defaults
    /// (load 0.9, sigma 0.5).
    pub fn new(source: impl Into<TraceSource>) -> TraceSpec {
        let source = source.into();
        TraceSpec { njobs: source.max_jobs(), load: 0.9, sigma: 0.5, source }
    }
}

/// Where a sweep cell's jobs come from.  Everything a cell needs to
/// synthesize its workload for a repetition, in a cheaply-clonable,
/// hashable-by-bits form the planner can group on (file-backed traces
/// share their rows by `Arc` and key on the path).
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// The Table-1 synthetic model.
    Synth(SynthConfig),
    /// A trace replay: published stand-in or on-disk file.
    Trace(TraceSpec),
}

impl From<SynthConfig> for WorkloadSpec {
    fn from(c: SynthConfig) -> WorkloadSpec {
        WorkloadSpec::Synth(c)
    }
}

impl From<TraceSpec> for WorkloadSpec {
    fn from(t: TraceSpec) -> WorkloadSpec {
        WorkloadSpec::Trace(t)
    }
}

impl WorkloadSpec {
    /// Repetition seed schedule.  Kept distinct per source so every
    /// value is bit-identical to what the pre-refactor figure code
    /// produced (figures used `r * 7919` for synthetic sweeps and
    /// `r * 104_729` for trace replays).
    pub fn rep_seed(&self, base: u64, r: u64) -> u64 {
        match self {
            WorkloadSpec::Synth(_) => base.wrapping_add(r.wrapping_mul(7919)),
            WorkloadSpec::Trace(_) => base.wrapping_add(r.wrapping_mul(104_729)),
        }
    }

    /// Materialize the jobs for one repetition seed.
    pub fn synthesize(&self, rep_seed: u64) -> Vec<Job> {
        match self {
            WorkloadSpec::Synth(cfg) => crate::workload::synthesize(cfg, rep_seed),
            WorkloadSpec::Trace(t) => match &t.source {
                TraceSource::Builtin(name) => {
                    let mut recs = traces::synth_trace(name.stats(), rep_seed);
                    recs.truncate(t.njobs);
                    traces::to_jobs(&recs, t.load, t.sigma, rep_seed)
                }
                TraceSource::File(f) => f.to_jobs(t.njobs, t.load, t.sigma, rep_seed),
            },
        }
    }

    /// A streaming [`crate::sim::JobSource`] for one repetition seed.
    /// Synthetic configs stream through
    /// [`crate::workload::SynthSource`] — O(active)-memory job
    /// production, bit-identical to [`synthesize`].  Trace specs
    /// already hold their rows in memory (builtin stand-ins are
    /// bounded, file rows are `Arc`-shared), so they materialize once
    /// and wrap a [`crate::sim::VecSource`]; the out-of-core trace
    /// path is `TraceFile::stream_jobs` / the binary cache at the CLI
    /// replay layer.
    ///
    /// [`synthesize`]: WorkloadSpec::synthesize
    pub fn stream_source(&self, rep_seed: u64) -> Box<dyn crate::sim::JobSource> {
        match self {
            WorkloadSpec::Synth(cfg) => {
                Box::new(crate::workload::SynthSource::new(cfg, rep_seed))
            }
            WorkloadSpec::Trace(_) => {
                Box::new(crate::sim::VecSource::new(self.synthesize(rep_seed)))
            }
        }
    }

    /// Bitwise grouping key: two specs share a key iff [`synthesize`]
    /// would produce identical workloads for them at every seed.
    /// File-backed traces key on the *identity* of their loaded row
    /// buffer (the `Arc` pointer): clones of one load — how a scenario
    /// fans a trace out across axes and cells — share a group, while
    /// separately loaded buffers never merge, so two different row
    /// sets behind one path (an edited file re-loaded, in-memory
    /// traces with placeholder names) can never be conflated.  The
    /// key's value varies across runs, but results never depend on it:
    /// grouping order is first-appearance order and sharing is
    /// numerically a no-op.
    ///
    /// [`synthesize`]: WorkloadSpec::synthesize
    pub fn key(&self) -> [u64; 8] {
        match self {
            WorkloadSpec::Synth(c) => {
                let (tag, param) = match c.size_dist {
                    SizeDist::Weibull { shape } => (0u64, shape.to_bits()),
                    SizeDist::Pareto { alpha } => (1u64, alpha.to_bits()),
                };
                [
                    0,
                    tag,
                    param,
                    c.sigma.to_bits(),
                    c.timeshape.to_bits(),
                    c.load.to_bits(),
                    c.njobs as u64,
                    c.beta.to_bits(),
                ]
            }
            WorkloadSpec::Trace(t) => {
                let (tag, ident, extra) = match &t.source {
                    TraceSource::Builtin(n) => (0u64, *n as u64, 0u64),
                    TraceSource::File(f) => {
                        let ptr = std::sync::Arc::as_ptr(&f.rows) as usize as u64;
                        (1u64, ptr, f.rows.len() as u64)
                    }
                };
                [
                    1,
                    tag,
                    ident,
                    t.njobs as u64,
                    t.load.to_bits(),
                    t.sigma.to_bits(),
                    extra,
                    0,
                ]
            }
        }
    }
}

/// One cell of a sweep grid: one (policy, workload) data point,
/// evaluated over seeded repetitions.  Figures and the CLI build flat
/// `Vec<SweepCell>` grids and hand them to [`eval_cells`] (shared
/// planner or the per-cell legacy path).
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub policy: PolicySpec,
    pub workload: WorkloadSpec,
    /// `Some(r)` => mean of per-seed MST ratios against `r`;
    /// `None` => mean raw MST.
    pub reference: Option<Reference>,
    /// `Some(cfg)` => run under fault injection (`build_faulty` +
    /// drain-mode engine); the per-cell value is the survivor MST (or
    /// the `output` scalar).  `None` => today's exact fault-free path.
    pub faults: Option<FaultConfig>,
    /// Which fault-side scalar to report (requires `faults`); `None`
    /// keeps the MST semantics.
    pub output: Option<FaultOutput>,
    /// Shared sink for the fault-side counters of every repetition run
    /// through this cell (one sink per policy column, shared across the
    /// cells of a table).  Counter totals are pure `u64` sums, so they
    /// are deterministic for any thread count / work order.
    pub counters: Option<std::sync::Arc<std::sync::Mutex<FaultStats>>>,
}

impl SweepCell {
    /// A ratio cell (the common case).
    pub fn ratio(
        policy: impl Into<PolicySpec>,
        reference: Reference,
        workload: impl Into<WorkloadSpec>,
    ) -> SweepCell {
        SweepCell {
            policy: policy.into(),
            workload: workload.into(),
            reference: Some(reference),
            faults: None,
            output: None,
            counters: None,
        }
    }

    /// A raw-MST cell.
    pub fn mst(policy: impl Into<PolicySpec>, workload: impl Into<WorkloadSpec>) -> SweepCell {
        SweepCell {
            policy: policy.into(),
            workload: workload.into(),
            reference: None,
            faults: None,
            output: None,
            counters: None,
        }
    }

    /// The per-repetition value of this cell on one materialized
    /// workload — the one place the fault-injected and fault-free
    /// evaluation paths fork (shared by [`SweepCell::eval`] and the
    /// planner, so both stay bit-identical by construction).
    fn rep_value(&self, jobs: &[Job], rep_seed: u64) -> f64 {
        match &self.faults {
            None => mst_of_seeded(&self.policy, jobs, rep_seed),
            Some(cfg) => {
                let (v, stats) =
                    fault_rep_seeded(&self.policy, jobs, rep_seed, cfg, self.output);
                if let Some(sink) = &self.counters {
                    sink.lock().unwrap().absorb(&stats);
                }
                v
            }
        }
    }

    /// Evaluate this cell alone: a pure function of (cell, params),
    /// safe to run on any worker.  This is the legacy per-cell path the
    /// planner is checked against — it re-synthesizes the workload and
    /// re-runs the reference for every cell.
    pub fn eval(&self, p: SweepParams) -> f64 {
        let mut reps = crate::stats::Repetitions::default();
        let max = if p.converge { p.reps * 10 } else { p.reps };
        for r in 0..max {
            let rep_seed = self.workload.rep_seed(p.seed, r);
            let v = if self.streams() {
                // Fault-free synthetic mean cells never materialize
                // the repetition: arrivals flow from the workload's
                // stream source straight into the engine, for the
                // policy and the reference alike.  Bit-identical to
                // the materialized branch below (pinned in
                // `planner::tests`), so the planner's shared path can
                // keep materializing without the two paths drifting.
                let a = stream_mst_seeded(&self.policy, &self.workload, rep_seed);
                match self.reference {
                    None => a,
                    Some(reference) => {
                        a / stream_reference_mst(reference, &self.workload, rep_seed)
                    }
                }
            } else {
                let jobs = self.workload.synthesize(rep_seed);
                let a = self.rep_value(&jobs, rep_seed);
                match self.reference {
                    None => a,
                    Some(reference) => a / reference.mst(&jobs),
                }
            };
            reps.push(v);
            if r + 1 >= p.reps && (!p.converge || reps.converged(p.reps as usize)) {
                break;
            }
        }
        reps.mean()
    }

    /// Whether [`SweepCell::eval`] can use the streaming path: fault
    /// injection needs the drain-mode engine over a materialized
    /// workload (lost jobs keep NaN completions), and trace replays
    /// materialize their rows anyway — synthetic fault-free mean cells
    /// are the ones that pay for per-rep job vectors.
    fn streams(&self) -> bool {
        self.faults.is_none() && matches!(self.workload, WorkloadSpec::Synth(_))
    }
}

/// Which workload knob a grid axis sweeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AxisParam {
    Shape,
    Sigma,
    Load,
    Timeshape,
    Njobs,
    Beta,
    /// Pareto tail exponent: applying it switches the size
    /// distribution to `Pareto { alpha }` (Fig. 10).
    Alpha,
}

impl AxisParam {
    /// Apply the value to a workload spec.  Parameters with no meaning
    /// for the spec's kind (e.g. `shape` on a trace replay) leave it
    /// unchanged — [`Scenario::validate`] rejects such combinations up
    /// front, so the executor never reaches them.
    pub fn apply(self, w: WorkloadSpec, v: f64) -> WorkloadSpec {
        match (self, w) {
            (AxisParam::Shape, WorkloadSpec::Synth(c)) => c.with_shape(v).into(),
            (AxisParam::Sigma, WorkloadSpec::Synth(c)) => c.with_sigma(v).into(),
            (AxisParam::Load, WorkloadSpec::Synth(c)) => c.with_load(v).into(),
            (AxisParam::Timeshape, WorkloadSpec::Synth(c)) => c.with_timeshape(v).into(),
            (AxisParam::Njobs, WorkloadSpec::Synth(c)) => c.with_njobs(v as usize).into(),
            (AxisParam::Beta, WorkloadSpec::Synth(c)) => c.with_beta(v).into(),
            (AxisParam::Alpha, WorkloadSpec::Synth(c)) => {
                WorkloadSpec::Synth(SynthConfig { size_dist: SizeDist::Pareto { alpha: v }, ..c })
            }
            (AxisParam::Sigma, WorkloadSpec::Trace(t)) => TraceSpec { sigma: v, ..t }.into(),
            (AxisParam::Load, WorkloadSpec::Trace(t)) => TraceSpec { load: v, ..t }.into(),
            (AxisParam::Njobs, WorkloadSpec::Trace(t)) => {
                let njobs = (v as usize).min(t.source.max_jobs());
                TraceSpec { njobs, ..t }.into()
            }
            (_, w) => w,
        }
    }

    /// Does this parameter mean anything for the given workload kind?
    pub fn applies_to(self, w: &WorkloadSpec) -> bool {
        match w {
            WorkloadSpec::Synth(_) => true,
            WorkloadSpec::Trace(_) => {
                matches!(self, AxisParam::Sigma | AxisParam::Load | AxisParam::Njobs)
            }
        }
    }

    /// Canonical name (the `--axis` argument of `psbs sweep` and the
    /// `param` key of scenario files).
    pub fn name(self) -> &'static str {
        match self {
            AxisParam::Shape => "shape",
            AxisParam::Sigma => "sigma",
            AxisParam::Load => "load",
            AxisParam::Timeshape => "timeshape",
            AxisParam::Njobs => "njobs",
            AxisParam::Beta => "beta",
            AxisParam::Alpha => "alpha",
        }
    }

    /// Inverse of [`AxisParam::name`].
    pub fn parse(s: &str) -> Option<AxisParam> {
        Some(match s {
            "shape" => AxisParam::Shape,
            "sigma" => AxisParam::Sigma,
            "load" => AxisParam::Load,
            "timeshape" => AxisParam::Timeshape,
            "njobs" => AxisParam::Njobs,
            "beta" => AxisParam::Beta,
            "alpha" => AxisParam::Alpha,
            _ => return None,
        })
    }
}

/// One grid axis: a labelled list of values for one workload knob.
/// Row axes (the default) become leading table columns; *split* axes
/// fan the scenario out into one table per value, the table named
/// `{name}_{label}{value}` (Fig. 6's three per-shape tables, Fig. 10's
/// two per-alpha tables, Fig. 4's three per-shape ECDFs).
#[derive(Debug, Clone, PartialEq)]
pub struct Axis {
    pub label: String,
    pub param: AxisParam,
    pub values: Vec<f64>,
    pub split: bool,
}

/// What a scenario measures per grid point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Metric {
    /// One value per (grid point, policy): the mean over repetitions
    /// of the MST (or of the per-seed MST ratio against the
    /// [`Reference`]).  Evaluated through the shared-workload planner.
    Mean,
    /// The pooled per-job slowdown ECDF across repetitions
    /// (Figs. 4/8): rows are `points` log-spaced thresholds spanning
    /// `decades` decades, one column per policy.  With `tail_above =
    /// Some(t)`, a companion table records the pooled fraction of jobs
    /// with slowdown above `t` per policy.  Axes must be split axes
    /// (an ECDF table has no room for extra value columns), and no
    /// reference applies.  Always pools exactly `reps` repetitions:
    /// the §6.3 convergence stopping rule is a per-scalar-cell notion
    /// and does not apply to pooled populations (the pre-refactor
    /// figure code ignored `--converge` here too).
    PooledEcdf { points: usize, decades: f64, tail_above: Option<f64> },
    /// A fault-side scalar per (grid point, policy), mean over
    /// repetitions — requires a `[faults]` config on the scenario (the
    /// run is `build_faulty` + drain instead of the strict engine
    /// loop) and takes no reference.  Evaluated through the same
    /// planner as [`Metric::Mean`].
    Fault { output: FaultOutput },
    /// Mean conditional slowdown per equal-count size class (Fig. 7,
    /// the paper's per-size-class fairness lens): pool every
    /// repetition's (jobs, slowdowns) per policy, split the pooled
    /// population into `bins` classes of similar size and equal count,
    /// and report (mean class size, mean class slowdown) — rows are
    /// classes, first column the mean size, one further column per
    /// policy.  Like [`Metric::PooledEcdf`]: axes must be split axes,
    /// no reference applies, and exactly `reps` repetitions pool
    /// (`--converge` is a scalar-cell notion).  Workload sharing is
    /// structurally a no-op on this path too.
    CondSlowdown { bins: usize },
    /// One streamed slowdown quantile per policy — the million-job
    /// engine's bounded-memory tail lens.  Every repetition's
    /// completions feed one [`metrics::OnlineMetrics`] P² sketch per
    /// policy through [`crate::sim::run_streaming`]: no pooled
    /// slowdown population is ever materialized, so memory stays
    /// O(active jobs) per worker no matter how many jobs the
    /// repetitions total.  The table has exactly one row, `[p,
    /// value per policy...]`.  Structurally a pooled-population
    /// metric: split axes only, no reference, exactly `reps`
    /// repetitions (the sketch is order-sensitive, so reps run
    /// serially inside each policy — identical for any thread count).
    TailQuantile { p: f64 },
    /// Pooled SLO attainment — the fairness/SLO suite's deadline lens:
    /// the fraction of the pooled per-job slowdown population at or
    /// under `deadline`, one row per policy.  The table takes the
    /// shape of [`Metric::PooledEcdf`]'s `tail_above` companion
    /// (`policy_idx` + fraction columns), named
    /// `{name}_slo_within_{deadline}`.  Structurally a pooled metric:
    /// split axes only, no reference, exactly `reps` repetitions pool.
    SloAttainment { deadline: f64 },
    /// Pooled per-job dominance against the [`Reference`]: the
    /// fraction of pooled jobs whose slowdown is at most the reference
    /// discipline's slowdown *for the same job on the same workload*
    /// (the per-job pairing behind FSP-style dominance claims, turned
    /// into a scalar).  Unique among the pooled metrics in REQUIRING a
    /// reference — without the baseline there is nothing to pair
    /// against.  Split axes only, exactly `reps` repetitions pool;
    /// the table is the companion shape, named
    /// `{name}_dominance_vs_{ref}`.
    DominanceVsRef,
}

/// Which fault-side scalar a [`Metric::Fault`] scenario reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultOutput {
    /// Fraction of released jobs that completed (lost jobs are the
    /// complement): `completions / arrivals` per repetition.
    Goodput,
    /// Fraction of executed service time that was thrown away (crashed
    /// attempts, losing speculative copies):
    /// [`crate::coordinator::FaultStats::wasted_fraction`].
    WastedWork,
    /// Number of retry re-dispatches (attempts beyond each job's
    /// first).
    Restarts,
}

impl FaultOutput {
    /// Canonical scenario-file metric name.
    pub fn name(self) -> &'static str {
        match self {
            FaultOutput::Goodput => "goodput",
            FaultOutput::WastedWork => "wasted_work",
            FaultOutput::Restarts => "restarts",
        }
    }

    /// Inverse of [`FaultOutput::name`].
    pub fn parse(s: &str) -> Option<FaultOutput> {
        Some(match s {
            "goodput" => FaultOutput::Goodput,
            "wasted_work" => FaultOutput::WastedWork,
            "restarts" => FaultOutput::Restarts,
            _ => return None,
        })
    }
}

/// A declarative sweep scenario: workload source, grid `axes`
/// (row-major cartesian product; split axes fan out into separate
/// tables), a labelled `policies` set, a [`Metric`] and an optional
/// normalization [`Reference`].  [`Scenario::tables`] is the one
/// generic executor every scenario-shaped figure now goes through.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub workload: WorkloadSpec,
    pub axes: Vec<Axis>,
    /// (column label, spec) — the label is usually `spec.to_string()`,
    /// but figures may override it (e.g. Fig. 15's `psbs_over_ps`).
    pub policies: Vec<(String, PolicySpec)>,
    pub reference: Option<Reference>,
    pub metric: Metric,
    /// Per-scenario repetition-count override: a scenario file can pin
    /// how many repetitions it needs (`reps = 30`); an explicit CLI
    /// `--reps` still wins.  `None` = use the caller's default.
    pub reps: Option<u64>,
    /// Per-scenario §6.3 convergence-mode override, same precedence.
    pub converge: Option<bool>,
    /// Fault-injection config (`[faults]` section): every cell runs
    /// under the seeded fault plan.  `None` = today's exact fault-free
    /// paths.
    pub faults: Option<FaultConfig>,
}

impl Scenario {
    pub fn new(name: impl Into<String>, base: SynthConfig) -> Scenario {
        Scenario::with_workload(name, base)
    }

    /// A scenario over an arbitrary workload source (trace replays use
    /// this; [`Scenario::new`] is the synthetic shorthand).
    pub fn with_workload(name: impl Into<String>, w: impl Into<WorkloadSpec>) -> Scenario {
        Scenario {
            name: name.into(),
            workload: w.into(),
            axes: Vec::new(),
            policies: Vec::new(),
            reference: None,
            metric: Metric::Mean,
            reps: None,
            converge: None,
            faults: None,
        }
    }

    /// Add a row axis (outermost first).
    pub fn axis(mut self, label: impl Into<String>, param: AxisParam, values: &[f64]) -> Scenario {
        self.axes.push(Axis { label: label.into(), param, values: values.to_vec(), split: false });
        self
    }

    /// Add a split axis: one table per value instead of a row column.
    pub fn split_axis(
        mut self,
        label: impl Into<String>,
        param: AxisParam,
        values: &[f64],
    ) -> Scenario {
        self.axes.push(Axis { label: label.into(), param, values: values.to_vec(), split: true });
        self
    }

    /// Add policies labelled by their canonical spec strings.
    pub fn policies(mut self, specs: &[&str]) -> Scenario {
        for s in specs {
            self.policies.push((s.to_string(), PolicySpec::from(*s)));
        }
        self
    }

    /// Add one policy under an explicit column label.
    pub fn policy_as(mut self, label: impl Into<String>, spec: impl Into<PolicySpec>) -> Scenario {
        self.policies.push((label.into(), spec.into()));
        self
    }

    /// Normalize against `r` (omit for raw MST columns).
    pub fn vs(mut self, r: Reference) -> Scenario {
        self.reference = Some(r);
        self
    }

    /// Set the metric (default: [`Metric::Mean`]).
    pub fn metric(mut self, m: Metric) -> Scenario {
        self.metric = m;
        self
    }

    /// Pin the repetition count (scenario files: `reps = N`).
    pub fn reps_override(mut self, reps: u64) -> Scenario {
        self.reps = Some(reps);
        self
    }

    /// Pin §6.3 convergence mode (scenario files: `converge = true`).
    pub fn converge_override(mut self, converge: bool) -> Scenario {
        self.converge = Some(converge);
        self
    }

    /// Run every cell under a fault plan (scenario files: `[faults]`).
    pub fn with_faults(mut self, cfg: FaultConfig) -> Scenario {
        self.faults = Some(cfg);
        self
    }

    /// Apply this scenario's `reps`/`converge` overrides to a caller's
    /// defaults.  The caller stays responsible for letting explicit
    /// CLI flags win over the file (see `cmd_sweep`).
    pub fn sweep_params(&self, base: SweepParams) -> SweepParams {
        SweepParams {
            reps: self.reps.unwrap_or(base.reps),
            converge: self.converge.unwrap_or(base.converge),
            ..base
        }
    }

    /// Rescale the workload's job count (figures shrink scenarios for
    /// tests; `psbs sweep --scenario --njobs N` overrides files).
    /// `njobs` *axes* are clamped to `njobs * 10` per value — the same
    /// rule the built-in Fig. 15c grid applies — so rescaling a
    /// scenario whose grid sweeps njobs cannot silently keep running
    /// full-scale cells.
    pub fn with_njobs(mut self, njobs: usize) -> Scenario {
        self.workload = match self.workload.clone() {
            WorkloadSpec::Synth(c) => c.with_njobs(njobs).into(),
            WorkloadSpec::Trace(t) => {
                TraceSpec { njobs: njobs.min(t.source.max_jobs()), ..t }.into()
            }
        };
        for axis in self.axes.iter_mut().filter(|a| a.param == AxisParam::Njobs) {
            for v in axis.values.iter_mut() {
                *v = v.min((njobs * 10) as f64);
            }
        }
        self
    }

    /// Structural checks shared by the file parser and the executor:
    /// a scenario that passes evaluates without panicking.
    pub fn validate(&self) -> Result<(), String> {
        if self.policies.is_empty() {
            return Err(format!("scenario {}: no policies", self.name));
        }
        if self.reps == Some(0) {
            return Err(format!("scenario {}: reps override must be >= 1", self.name));
        }
        if let WorkloadSpec::Trace(t) = &self.workload {
            if t.njobs == 0 {
                return Err(format!("scenario {}: trace njobs must be >= 1", self.name));
            }
            if !(t.load > 0.0) {
                return Err(format!(
                    "scenario {}: trace load normalization needs load > 0, got {}",
                    self.name, t.load
                ));
            }
        }
        for (i, axis) in self.axes.iter().enumerate() {
            if axis.values.is_empty() {
                return Err(format!("scenario {}: axis {} has no values", self.name, axis.label));
            }
            if !axis.param.applies_to(&self.workload) {
                return Err(format!(
                    "scenario {}: axis param `{}` does not apply to a trace workload \
                     (use sigma, load or njobs)",
                    self.name,
                    axis.param.name()
                ));
            }
            // Two axes over one knob would make the later value win
            // silently while both still label the rows — exactly the
            // kind of quiet misreport the CLI's unknown-flag policy
            // exists to prevent.
            if self.axes[..i].iter().any(|b| b.param == axis.param) {
                return Err(format!(
                    "scenario {}: axis param `{}` appears more than once",
                    self.name,
                    axis.param.name()
                ));
            }
        }
        if let Some(cfg) = &self.faults {
            if !(cfg.spec.mtbf >= 0.0) {
                return Err(format!("scenario {}: [faults] mtbf must be >= 0", self.name));
            }
            if cfg.spec.mtbf > 0.0 && !(cfg.spec.mttr >= 0.0) {
                return Err(format!("scenario {}: [faults] mttr must be >= 0", self.name));
            }
            if !(cfg.spec.slowdown > 0.0 && cfg.spec.slowdown <= 1.0) {
                return Err(format!(
                    "scenario {}: [faults] slowdown must be in (0, 1], got {}",
                    self.name, cfg.spec.slowdown
                ));
            }
            if cfg.retry.max_attempts < 1 {
                return Err(format!(
                    "scenario {}: [faults] max_attempts must be >= 1",
                    self.name
                ));
            }
            if !(cfg.retry.backoff >= 0.0) {
                return Err(format!("scenario {}: [faults] backoff must be >= 0", self.name));
            }
            if !matches!(self.metric, Metric::Mean | Metric::Fault { .. }) {
                return Err(format!(
                    "scenario {}: [faults] applies only to the mean and fault metrics \
                     (pooled slowdown populations have no lost-job semantics)",
                    self.name
                ));
            }
        }
        if matches!(self.metric, Metric::Fault { .. }) {
            if self.faults.is_none() {
                return Err(format!(
                    "scenario {}: fault metrics require a [faults] section",
                    self.name
                ));
            }
            if self.reference.is_some() {
                return Err(format!(
                    "scenario {}: fault metrics take no reference",
                    self.name
                ));
            }
        }
        // The pooled-population metrics (ECDF, conditional slowdown)
        // share structural constraints: split axes only (their tables
        // have no room for extra value columns) and no reference.
        let pooled_kind = match self.metric {
            Metric::Mean | Metric::Fault { .. } => None,
            Metric::PooledEcdf { points, decades, .. } => {
                if points < 2 || !(decades > 0.0) {
                    return Err(format!(
                        "scenario {}: ecdf metric needs points >= 2 and decades > 0",
                        self.name
                    ));
                }
                Some("ecdf")
            }
            Metric::CondSlowdown { bins } => {
                if bins < 2 {
                    return Err(format!(
                        "scenario {}: cond_slowdown metric needs bins >= 2",
                        self.name
                    ));
                }
                Some("cond_slowdown")
            }
            Metric::TailQuantile { p } => {
                if !(p > 0.0 && p < 1.0) {
                    return Err(format!(
                        "scenario {}: tail_quantile metric needs p in (0, 1), got {p}",
                        self.name
                    ));
                }
                Some("tail_quantile")
            }
            Metric::SloAttainment { deadline } => {
                if !(deadline > 0.0) {
                    return Err(format!(
                        "scenario {}: slo metric needs deadline > 0, got {deadline}",
                        self.name
                    ));
                }
                Some("slo")
            }
            Metric::DominanceVsRef => Some("dominance"),
        };
        if let Some(kind) = pooled_kind {
            if self.axes.iter().any(|a| !a.split) {
                return Err(format!(
                    "scenario {}: {kind} metric requires all axes to be split axes",
                    self.name
                ));
            }
            // Dominance is the one pooled metric that REQUIRES a
            // reference: the per-job pairing against the baseline IS
            // the metric.  Every other pooled metric takes none.
            if matches!(self.metric, Metric::DominanceVsRef) {
                if self.reference.is_none() {
                    return Err(format!(
                        "scenario {}: dominance metric requires a reference (opt|ps)",
                        self.name
                    ));
                }
            } else if self.reference.is_some() {
                return Err(format!(
                    "scenario {}: {kind} metric takes no reference",
                    self.name
                ));
            }
            // Pooled populations always use exactly `reps` repetitions
            // (§6.3 convergence is a scalar-cell notion), so a file
            // pinning `converge = true` would be silently ignored —
            // reject it like any other key that cannot take effect.
            // An explicit `converge = false` states the actual
            // behavior and is allowed.
            if self.converge == Some(true) {
                return Err(format!(
                    "scenario {}: {kind} metric pools exactly `reps` repetitions; \
                     a `converge = true` override cannot take effect",
                    self.name
                ));
            }
        }
        Ok(())
    }

    /// Expand the split axes: (table base name, specialized workload)
    /// per split grid point, in row-major declaration order.
    fn split_expansions(&self) -> Vec<(String, WorkloadSpec)> {
        let mut out = vec![(self.name.clone(), self.workload.clone())];
        for axis in self.axes.iter().filter(|a| a.split) {
            let mut next = Vec::with_capacity(out.len() * axis.values.len());
            for (name, w) in &out {
                for &v in &axis.values {
                    let applied = axis.param.apply(w.clone(), v);
                    next.push((format!("{name}_{}{v}", axis.label), applied));
                }
            }
            out = next;
        }
        out
    }

    fn row_axes(&self) -> Vec<&Axis> {
        self.axes.iter().filter(|a| !a.split).collect()
    }

    /// The flat cell grid for one specialized workload (grid-point-
    /// major, policy-minor — the cell order every pre-refactor figure
    /// used).
    fn cells_for(&self, w: WorkloadSpec) -> Vec<SweepCell> {
        let axes = self.row_axes();
        let points = grid_points(&axes);
        let mut cells = Vec::with_capacity(points.len() * self.policies.len());
        for point in &points {
            let mut wl = w.clone();
            for (axis, &v) in axes.iter().zip(point) {
                wl = axis.param.apply(wl, v);
            }
            for (_, spec) in &self.policies {
                cells.push(SweepCell {
                    policy: spec.clone(),
                    workload: wl.clone(),
                    reference: self.reference,
                    faults: self.faults,
                    output: match self.metric {
                        Metric::Fault { output } => Some(output),
                        _ => None,
                    },
                    counters: None,
                });
            }
        }
        cells
    }

    /// All cells across every split expansion, in table order.
    pub fn cells(&self) -> Vec<SweepCell> {
        self.split_expansions()
            .into_iter()
            .flat_map(|(_, w)| self.cells_for(w))
            .collect()
    }

    /// Evaluate the scenario into its tables: one table per split grid
    /// point; within each, one row per row-axis grid point and one
    /// column per policy ([`Metric::Mean`]), one row per slowdown
    /// threshold ([`Metric::PooledEcdf`], plus the optional tail
    /// table), or one row per size class ([`Metric::CondSlowdown`]).
    pub fn tables(&self, p: SweepParams, threads: usize, share: bool) -> Vec<Table> {
        debug_assert!(self.validate().is_ok(), "{:?}", self.validate());
        let mut out = Vec::new();
        for (name, w) in self.split_expansions() {
            match self.metric {
                Metric::Mean | Metric::Fault { .. } => {
                    let (t, counters) = self.mean_table(name, w, p, threads, share);
                    out.push(t);
                    // Fault scenarios also emit a per-policy counter
                    // table — non-zero `kills_rejected` /
                    // `kills_unsupported` counts must not vanish.
                    out.extend(counters);
                }
                Metric::PooledEcdf { points, decades, tail_above } => {
                    self.ecdf_tables(&mut out, name, w, p, threads, points, decades, tail_above)
                }
                Metric::CondSlowdown { bins } => {
                    out.push(self.cond_table(name, w, p, threads, bins))
                }
                Metric::TailQuantile { p: q } => {
                    out.push(self.tail_quantile_table(name, w, p, threads, q))
                }
                Metric::SloAttainment { deadline } => {
                    out.push(self.slo_table(name, w, p, threads, deadline))
                }
                Metric::DominanceVsRef => {
                    out.push(self.dominance_table(name, w, p, threads))
                }
            }
        }
        out
    }

    /// Convenience for single-table scenarios (no split axes, Mean
    /// metric): the CLI custom sweep and several figures use this.
    pub fn table(&self, p: SweepParams, threads: usize, share: bool) -> Table {
        let mut ts = self.tables(p, threads, share);
        assert_eq!(ts.len(), 1, "scenario {} produces {} tables; use tables()", self.name, ts.len());
        ts.pop().unwrap()
    }

    fn mean_table(
        &self,
        name: String,
        w: WorkloadSpec,
        p: SweepParams,
        threads: usize,
        share: bool,
    ) -> (Table, Option<Table>) {
        let axes = self.row_axes();
        let header: Vec<String> = axes
            .iter()
            .map(|a| a.label.clone())
            .chain(self.policies.iter().map(|(l, _)| l.clone()))
            .collect();
        let mut t = Table::new(name.clone(), header);
        let mut cells = self.cells_for(w);
        // Fault scenarios: one counter sink per policy column, shared by
        // every cell of that column (cells_for is policy-minor).
        let sinks: Vec<std::sync::Arc<std::sync::Mutex<FaultStats>>> = if self.faults.is_some() {
            (0..self.policies.len()).map(|_| Default::default()).collect()
        } else {
            Vec::new()
        };
        if !sinks.is_empty() {
            for (i, cell) in cells.iter_mut().enumerate() {
                cell.counters = Some(sinks[i % self.policies.len()].clone());
            }
        }
        let vals = eval_cells(p, threads, share, &cells);
        let mut it = vals.into_iter();
        for point in grid_points(&axes) {
            let mut row = point;
            row.extend((&mut it).take(self.policies.len()));
            t.push(row);
        }
        let counters = (!sinks.is_empty()).then(|| {
            let header = std::iter::once("policy".to_string())
                .chain(FAULT_COUNTER_COLUMNS.iter().map(|s| s.to_string()))
                .collect();
            let mut ct = Table::new(format!("{name}_fault_counters"), header);
            for (i, sink) in sinks.iter().enumerate() {
                let s = sink.lock().unwrap();
                let mut row = vec![i as f64];
                row.extend(
                    [s.crashes, s.restarts, s.speculations, s.lost, s.killed, s.kills_rejected,
                     s.kills_unsupported]
                    .map(|c| c as f64),
                );
                ct.push(row);
            }
            ct
        });
        (t, counters)
    }

    /// The pooled-population path (Figs. 4/8): repetitions run in
    /// parallel, one policy at a time — rep order inside each policy
    /// matches the serial loop, so the pooled ECDFs are bit-identical
    /// to it, and peak memory stays at one policy's pooled population.
    /// The paper pools runs too.  Workload sharing does not apply
    /// (each (policy, rep) item synthesizes its own workload, exactly
    /// as the pre-refactor figure code did), so `share` is a no-op
    /// here by construction.
    #[allow(clippy::too_many_arguments)]
    fn ecdf_tables(
        &self,
        out: &mut Vec<Table>,
        name: String,
        w: WorkloadSpec,
        p: SweepParams,
        threads: usize,
        points: usize,
        decades: f64,
        tail_above: Option<f64>,
    ) {
        let thresholds = metrics::log_thresholds(points, decades);
        let rep_items: Vec<u64> = (0..p.reps).collect();
        let mut ecdfs: Vec<Vec<f64>> = Vec::new();
        let mut tails: Vec<f64> = Vec::new();
        for (_, spec) in &self.policies {
            let runs = pool::par_map(threads, &rep_items, |&r| {
                let rep_seed = w.rep_seed(p.seed, r);
                let jobs = w.synthesize(rep_seed);
                // The repetition seed also feeds the policy build (as in
                // the Mean path): base disciplines ignore it, seeded
                // specs draw independent streams per repetition.
                slowdowns_of_seeded(spec, &jobs, rep_seed)
            });
            let mut pooled = Vec::new();
            for slow in runs {
                pooled.extend(slow);
            }
            // `frac_above`/`slowdown_ecdf` return `None` on an empty
            // pooled population (reachable only at `reps = 0`): report
            // NaN explicitly rather than fabricated zeros.
            if let Some(t) = tail_above {
                tails.push(metrics::frac_above(&pooled, t).unwrap_or(f64::NAN));
            }
            ecdfs.push(
                metrics::slowdown_ecdf(&pooled, &thresholds)
                    .unwrap_or_else(|| vec![f64::NAN; thresholds.len()]),
            );
        }
        let header: Vec<String> = ["slowdown"]
            .iter()
            .map(|s| s.to_string())
            .chain(self.policies.iter().map(|(l, _)| l.clone()))
            .collect();
        let mut t = Table::new(name.clone(), header);
        for (i, &thr) in thresholds.iter().enumerate() {
            let mut row = vec![thr];
            row.extend(ecdfs.iter().map(|e| e[i]));
            t.push(row);
        }
        out.push(t);
        if let Some(thr) = tail_above {
            let mut tt = Table::new(
                format!("{name}_tail_above_{thr}"),
                vec!["policy_idx".to_string(), format!("frac_above_{thr}")],
            );
            for (pi, &frac) in tails.iter().enumerate() {
                tt.push(vec![pi as f64, frac]);
            }
            out.push(tt);
        }
    }

    /// The conditional-slowdown path (Fig. 7): repetitions run in
    /// parallel, one policy materialized at a time (the full pooled
    /// (jobs, slowdowns) population per policy is the peak-memory unit,
    /// exactly as in the deleted bespoke `figures::fig7` loop), pooled
    /// in repetition order and reduced through
    /// [`metrics::conditional_slowdown`].  The mean size per class is
    /// policy-independent (same pooled workloads), so the first column
    /// comes from the first policy's classes — all of it bit-identical
    /// to the bespoke path it replaces
    /// (`figures::tests::fig7_scenario_path_matches_bespoke_path_bitwise`).
    /// `share` is structurally a no-op here, like the ECDF path.
    fn cond_table(
        &self,
        name: String,
        w: WorkloadSpec,
        p: SweepParams,
        threads: usize,
        bins: usize,
    ) -> Table {
        let rep_items: Vec<u64> = (0..p.reps).collect();
        let mut per_policy: Vec<Vec<(f64, f64)>> = Vec::new();
        for (_, spec) in &self.policies {
            let runs = pool::par_map(threads, &rep_items, |&r| {
                let rep_seed = w.rep_seed(p.seed, r);
                let jobs = w.synthesize(rep_seed);
                let slow = slowdowns_of_seeded(spec, &jobs, rep_seed);
                (jobs, slow)
            });
            let mut jobs_all: Vec<Job> = Vec::new();
            let mut slow_all: Vec<f64> = Vec::new();
            for (jobs, slow) in runs {
                slow_all.extend(slow);
                jobs_all.extend(jobs);
            }
            per_policy.push(metrics::conditional_slowdown(&jobs_all, &slow_all, bins));
        }
        let header: Vec<String> = ["size"]
            .iter()
            .map(|s| s.to_string())
            .chain(self.policies.iter().map(|(l, _)| l.clone()))
            .collect();
        let mut t = Table::new(name, header);
        for b in 0..per_policy[0].len() {
            let mut row = vec![per_policy[0][b].0];
            for pp in &per_policy {
                row.push(pp.get(b).map(|x| x.1).unwrap_or(f64::NAN));
            }
            t.push(row);
        }
        t
    }

    /// The streamed-quantile path ([`Metric::TailQuantile`]): each
    /// policy runs its repetitions *serially*, feeding every
    /// completion through one [`metrics::OnlineMetrics`] P² sketch via
    /// [`crate::sim::run_streaming`] — the sketch's observation order
    /// is fixed, so the table is identical for any thread count, and
    /// no pooled slowdown population is ever materialized (memory is
    /// O(active jobs), not O(reps x njobs)).  Policies fan out across
    /// threads; `share` is structurally a no-op here like the other
    /// pooled paths.
    fn tail_quantile_table(
        &self,
        name: String,
        w: WorkloadSpec,
        p: SweepParams,
        threads: usize,
        q: f64,
    ) -> Table {
        let vals = pool::par_map(threads, &self.policies, |(_, spec)| {
            let mut m = metrics::OnlineMetrics::new().with_quantiles(&[q]);
            for r in 0..p.reps {
                let rep_seed = w.rep_seed(p.seed, r);
                let mut source = w.stream_source(rep_seed);
                planner::stream_rep_seeded(spec, source.as_mut(), rep_seed, &mut m);
            }
            m.quantile(q).unwrap_or(f64::NAN)
        });
        let header: Vec<String> = ["p"]
            .iter()
            .map(|s| s.to_string())
            .chain(self.policies.iter().map(|(l, _)| l.clone()))
            .collect();
        let mut t = Table::new(name, header);
        let mut row = vec![q];
        row.extend(vals);
        t.push(row);
        t
    }

    /// The SLO-attainment path ([`Metric::SloAttainment`]): pool
    /// per-job slowdowns per policy exactly like the ECDF path (same
    /// rep seeds, repetitions in parallel one policy at a time) and
    /// reduce each pool to one fraction — jobs with slowdown at most
    /// `deadline` over jobs total.  Counts are exact integers, so the
    /// table is bit-identical for any thread count; `share` is
    /// structurally a no-op like every pooled path.
    fn slo_table(
        &self,
        name: String,
        w: WorkloadSpec,
        p: SweepParams,
        threads: usize,
        deadline: f64,
    ) -> Table {
        let rep_items: Vec<u64> = (0..p.reps).collect();
        let mut t = Table::new(
            format!("{name}_slo_within_{deadline}"),
            vec!["policy_idx".to_string(), format!("frac_within_{deadline}")],
        );
        for (pi, (_, spec)) in self.policies.iter().enumerate() {
            let counts = pool::par_map(threads, &rep_items, |&r| {
                let rep_seed = w.rep_seed(p.seed, r);
                let jobs = w.synthesize(rep_seed);
                let slow = slowdowns_of_seeded(spec, &jobs, rep_seed);
                (slow.iter().filter(|&&s| s <= deadline).count(), slow.len())
            });
            let (mut within, mut total) = (0usize, 0usize);
            for (hit, n) in counts {
                within += hit;
                total += n;
            }
            // An empty pooled population (reps = 0) reports NaN, not a
            // fabricated zero — the ECDF path's convention.
            let frac = if total == 0 { f64::NAN } else { within as f64 / total as f64 };
            t.push(vec![pi as f64, frac]);
        }
        t
    }

    /// The per-job dominance path ([`Metric::DominanceVsRef`]): the
    /// reference baseline is policy-independent, so each repetition's
    /// reference slowdowns compute once up front (in parallel); each
    /// policy then pairs its own per-job slowdowns against the stored
    /// baseline index-by-index — both vectors come from the same
    /// synthesized workload, so index i is the same job — and the
    /// pooled dominant-job count reduces to one fraction per policy.
    /// Exact integer counts: bit-identical for any thread count,
    /// `share` structurally a no-op.
    fn dominance_table(
        &self,
        name: String,
        w: WorkloadSpec,
        p: SweepParams,
        threads: usize,
    ) -> Table {
        let r = self.reference.expect("validate(): dominance requires a reference");
        let rep_items: Vec<u64> = (0..p.reps).collect();
        let baseline: Vec<Vec<f64>> = pool::par_map(threads, &rep_items, |&rep| {
            let rep_seed = w.rep_seed(p.seed, rep);
            let jobs = w.synthesize(rep_seed);
            r.slowdowns(&jobs)
        });
        let mut t = Table::new(
            format!("{name}_dominance_vs_{}", r.name()),
            vec!["policy_idx".to_string(), "frac_dominant".to_string()],
        );
        for (pi, (_, spec)) in self.policies.iter().enumerate() {
            let counts = pool::par_map(threads, &rep_items, |&rep| {
                let rep_seed = w.rep_seed(p.seed, rep);
                let jobs = w.synthesize(rep_seed);
                let slow = slowdowns_of_seeded(spec, &jobs, rep_seed);
                let base = &baseline[rep as usize];
                assert_eq!(slow.len(), base.len(), "per-job pairing needs equal lengths");
                (slow.iter().zip(base).filter(|&(s, b)| s <= b).count(), slow.len())
            });
            let (mut dom, mut total) = (0usize, 0usize);
            for (hit, n) in counts {
                dom += hit;
                total += n;
            }
            let frac = if total == 0 { f64::NAN } else { dom as f64 / total as f64 };
            t.push(vec![pi as f64, frac]);
        }
        t
    }
}

/// Row-major cartesian product of the axis values.
fn grid_points(axes: &[&Axis]) -> Vec<Vec<f64>> {
    let mut points: Vec<Vec<f64>> = vec![Vec::new()];
    for axis in axes {
        let mut next = Vec::with_capacity(points.len() * axis.values.len());
        for p in &points {
            for &v in &axis.values {
                let mut q = p.clone();
                q.push(v);
                next.push(q);
            }
        }
        points = next;
    }
    points
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::GRID;

    fn params() -> SweepParams {
        SweepParams { reps: 2, seed: 11, converge: false }
    }

    #[test]
    fn scenario_table_shape_matches_declaration() {
        let sc = Scenario::new("t", SynthConfig::default().with_njobs(150))
            .axis("shape", AxisParam::Shape, &[0.5, 2.0])
            .axis("sigma", AxisParam::Sigma, &[0.25, 1.0, 4.0])
            .policies(&["psbs", "ps"])
            .vs(Reference::OptSrpt);
        let t = sc.table(params(), 2, true);
        assert_eq!(t.header, vec!["shape", "sigma", "psbs", "ps"]);
        assert_eq!(t.rows.len(), 6);
        // Row-major: shape outer, sigma inner.
        assert_eq!((t.rows[0][0], t.rows[0][1]), (0.5, 0.25));
        assert_eq!((t.rows[4][0], t.rows[4][1]), (2.0, 1.0));
        for row in &t.rows {
            assert!(row[2..].iter().all(|v| v.is_finite() && *v > 0.0));
        }
    }

    #[test]
    fn shared_planner_is_bit_identical_to_per_cell_path() {
        let sc = Scenario::new("t", SynthConfig::default().with_njobs(200))
            .axis("sigma", AxisParam::Sigma, &GRID[..3])
            .policies(&["psbs", "srpte", "ps"])
            .vs(Reference::OptSrpt);
        let cells = sc.cells();
        for converge in [false, true] {
            let p = SweepParams { reps: 2, seed: 7, converge };
            let legacy = eval_cells(p, 1, false, &cells);
            for threads in [1usize, 3] {
                let shared = eval_cells(p, threads, true, &cells);
                let lb: Vec<u64> = legacy.iter().map(|v| v.to_bits()).collect();
                let sb: Vec<u64> = shared.iter().map(|v| v.to_bits()).collect();
                assert_eq!(lb, sb, "converge={converge} threads={threads}");
            }
        }
    }

    #[test]
    fn composed_cluster_cells_are_sweepable() {
        let sc = Scenario::new("t", SynthConfig::default().with_njobs(150).with_load(1.8))
            .axis("sigma", AxisParam::Sigma, &[0.5])
            .policies(&["cluster(k=2,dispatch=leastwork,inner=psbs)", "ps"])
            .vs(Reference::Ps);
        let t = sc.table(params(), 1, true);
        assert_eq!(t.rows.len(), 1);
        assert!(t.rows[0][1].is_finite());
        // PS against itself is exactly 1 on every seed.
        assert!((t.rows[0][2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn split_axes_fan_out_into_named_tables() {
        let sc = Scenario::new("t", SynthConfig::default().with_njobs(120))
            .split_axis("shape", AxisParam::Shape, &[0.5, 2.0])
            .axis("sigma", AxisParam::Sigma, &[0.25, 1.0])
            .policies(&["psbs", "ps"])
            .vs(Reference::OptSrpt);
        let ts = sc.tables(SweepParams { reps: 1, seed: 5, converge: false }, 1, true);
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "t_shape0.5");
        assert_eq!(ts[1].name, "t_shape2");
        for t in &ts {
            assert_eq!(t.header, vec!["sigma", "psbs", "ps"]);
            assert_eq!(t.rows.len(), 2);
        }
    }

    /// The pooled-ECDF metric is bit-identical across threads and
    /// share modes (sharing is structurally a no-op on this path).
    #[test]
    fn pooled_ecdf_scenario_is_bit_identical_across_modes() {
        let sc = Scenario::new("t_ecdf", SynthConfig::default().with_njobs(150))
            .policies(&["ps", "psbs"])
            .metric(Metric::PooledEcdf { points: 16, decades: 2.0, tail_above: Some(10.0) });
        let p = SweepParams { reps: 2, seed: 9, converge: false };
        let bits = |share: bool, threads: usize| -> Vec<Vec<u64>> {
            sc.tables(p, threads, share)
                .iter()
                .map(|t| t.rows.iter().flatten().map(|v| v.to_bits()).collect())
                .collect()
        };
        let base = bits(false, 1);
        assert_eq!(base.len(), 2, "ecdf + tail table");
        for (share, threads) in [(true, 1), (true, 3), (false, 3)] {
            assert_eq!(base, bits(share, threads), "share={share} threads={threads}");
        }
        // ECDF columns are monotone in the threshold.
        let ecdf = &sc.tables(p, 1, true)[0];
        for c in 1..ecdf.header.len() {
            for w in ecdf.rows.windows(2) {
                assert!(w[1][c] >= w[0][c]);
            }
        }
    }

    /// Trace-replay cells group and share through the planner exactly
    /// like synthetic ones: bit-identity across share x threads.
    #[test]
    fn trace_scenario_is_bit_identical_across_modes() {
        use crate::workload::traces::TraceName;
        let sc = Scenario::with_workload(
            "t_trace",
            TraceSpec { source: TraceName::Facebook.into(), njobs: 150, load: 0.9, sigma: 0.5 },
        )
        .axis("sigma", AxisParam::Sigma, &[0.25, 1.0])
        .policies(&["psbs", "ps"])
        .vs(Reference::OptSrpt);
        let p = SweepParams { reps: 2, seed: 17, converge: false };
        let bits = |share: bool, threads: usize| -> Vec<u64> {
            sc.table(p, threads, share)
                .rows
                .iter()
                .flatten()
                .map(|v| v.to_bits())
                .collect()
        };
        let base = bits(false, 1);
        assert!(base.iter().any(|&b| f64::from_bits(b) > 0.0));
        for (share, threads) in [(true, 1), (true, 3), (false, 3)] {
            assert_eq!(base, bits(share, threads), "share={share} threads={threads}");
        }
    }

    #[test]
    fn with_njobs_rescales_base_and_njobs_axes() {
        let sc = Scenario::new("t", SynthConfig::default())
            .axis("njobs", AxisParam::Njobs, &[1_000.0, 100_000.0])
            .policies(&["ps"])
            .with_njobs(200);
        match sc.workload {
            WorkloadSpec::Synth(c) => assert_eq!(c.njobs, 200),
            _ => unreachable!(),
        }
        // Axis values clamp at njobs * 10 (the built-in Fig. 15c rule),
        // so a "quick look" rescale cannot run full-scale cells.
        assert_eq!(sc.axes[0].values, vec![1_000.0, 2_000.0]);
    }

    #[test]
    fn validate_rejects_inconsistent_scenarios() {
        let trace = TraceSpec {
            source: crate::workload::traces::TraceName::Ircache.into(),
            njobs: 100,
            load: 0.9,
            sigma: 0.5,
        };
        // Shape axis on a trace replay.
        let bad = Scenario::with_workload("t", trace.clone())
            .axis("shape", AxisParam::Shape, &[0.5])
            .policies(&["ps"]);
        assert!(bad.validate().is_err());
        // ECDF with a row axis.
        let bad = Scenario::new("t", SynthConfig::default())
            .axis("sigma", AxisParam::Sigma, &[0.5])
            .policies(&["ps"])
            .metric(Metric::PooledEcdf { points: 8, decades: 2.0, tail_above: None });
        assert!(bad.validate().is_err());
        // ECDF with a reference.
        let bad = Scenario::new("t", SynthConfig::default())
            .policies(&["ps"])
            .vs(Reference::Ps)
            .metric(Metric::PooledEcdf { points: 8, decades: 2.0, tail_above: None });
        assert!(bad.validate().is_err());
        // No policies.
        assert!(Scenario::new("t", SynthConfig::default()).validate().is_err());
        // The same knob on two axes (row, split — either way).
        let bad = Scenario::new("t", SynthConfig::default())
            .split_axis("s1", AxisParam::Sigma, &[0.25])
            .axis("s2", AxisParam::Sigma, &[0.5])
            .policies(&["ps"]);
        assert!(bad.validate().is_err());
        // Cond-slowdown with a row axis / a reference / silly bins.
        let cond = Metric::CondSlowdown { bins: 10 };
        let bad = Scenario::new("t", SynthConfig::default())
            .axis("sigma", AxisParam::Sigma, &[0.5])
            .policies(&["ps"])
            .metric(cond);
        assert!(bad.validate().is_err());
        let bad = Scenario::new("t", SynthConfig::default())
            .policies(&["ps"])
            .vs(Reference::Ps)
            .metric(cond);
        assert!(bad.validate().is_err());
        let bad = Scenario::new("t", SynthConfig::default())
            .policies(&["ps"])
            .metric(Metric::CondSlowdown { bins: 1 });
        assert!(bad.validate().is_err());
        // converge=true on a pooled metric would be silently ignored.
        let bad = Scenario::new("t", SynthConfig::default())
            .policies(&["ps"])
            .metric(cond)
            .converge_override(true);
        assert!(bad.validate().is_err());
        let ok = Scenario::new("t", SynthConfig::default())
            .policies(&["ps"])
            .metric(cond)
            .converge_override(false);
        assert!(ok.validate().is_ok());
        // Zero-rep override, degenerate trace knobs.
        let bad = Scenario::new("t", SynthConfig::default()).policies(&["ps"]).reps_override(0);
        assert!(bad.validate().is_err());
        let bad = Scenario::with_workload("t", TraceSpec { njobs: 0, ..trace.clone() })
            .policies(&["ps"]);
        assert!(bad.validate().is_err());
        let bad = Scenario::with_workload("t", TraceSpec { load: 0.0, ..trace.clone() })
            .policies(&["ps"]);
        assert!(bad.validate().is_err());
        // A good one.
        let ok = Scenario::with_workload("t", trace)
            .axis("sigma", AxisParam::Sigma, &[0.5])
            .policies(&["ps"])
            .vs(Reference::OptSrpt);
        assert!(ok.validate().is_ok());
    }

    /// A file-backed trace scenario runs through the same planner as
    /// the stand-ins: bit-identity across `share` x `threads`, with the
    /// sigma axis re-estimating per repetition.
    #[test]
    fn trace_file_scenario_is_bit_identical_across_modes() {
        use crate::workload::trace_file::{parse, TraceFile};
        use std::sync::Arc;
        let mut text = String::from("arrival,size,weight\n");
        for i in 0..120u32 {
            // Deterministic, mildly heavy-tailed sizes; strictly
            // increasing arrivals.
            let size = 1 + (i as u64 * 7919) % 97 + if i % 17 == 0 { 500 } else { 0 };
            text.push_str(&format!("{}.5,{size},{}\n", i, 1 + i % 3));
        }
        let tf = TraceFile { path: "mem.csv".into(), rows: Arc::new(parse(&text).unwrap()) };
        let sc = Scenario::with_workload("t_trace_file", TraceSpec::new(tf))
            .axis("sigma", AxisParam::Sigma, &[0.0, 0.5, 2.0])
            .policies(&["psbs", "srpte", "ps"])
            .vs(Reference::OptSrpt);
        assert!(sc.validate().is_ok());
        let p = SweepParams { reps: 2, seed: 31, converge: false };
        let bits = |share: bool, threads: usize| -> Vec<u64> {
            sc.table(p, threads, share).rows.iter().flatten().map(|v| v.to_bits()).collect()
        };
        let base = bits(false, 1);
        assert!(base.iter().any(|&b| f64::from_bits(b) > 0.0));
        for (share, threads) in [(true, 1), (true, 3), (false, 3)] {
            assert_eq!(base, bits(share, threads), "share={share} threads={threads}");
        }
        // sigma = 0 keeps jobs identical across reps; sigma > 0 varies
        // the estimates only — sizes/arrivals stay the trace's.
        let w: WorkloadSpec = TraceSpec {
            sigma: 2.0,
            ..match &sc.workload {
                WorkloadSpec::Trace(t) => t.clone(),
                _ => unreachable!(),
            }
        }
        .into();
        let a = w.synthesize(w.rep_seed(1, 0));
        let b = w.synthesize(w.rep_seed(1, 1));
        assert_ne!(a, b);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.size, y.size);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.weight, y.weight);
        }
    }

    /// The reps/converge file overrides: applied over caller defaults,
    /// field by field.
    #[test]
    fn sweep_params_applies_overrides() {
        let base = SweepParams { reps: 5, seed: 42, converge: false };
        let sc = Scenario::new("t", SynthConfig::default()).policies(&["ps"]);
        assert_eq!(sc.sweep_params(base).reps, 5);
        assert!(!sc.sweep_params(base).converge);
        let sc = sc.reps_override(30).converge_override(true);
        let p = sc.sweep_params(base);
        assert_eq!(p.reps, 30);
        assert!(p.converge);
        assert_eq!(p.seed, 42);
    }

    /// Metric::TailQuantile: one-row shape, determinism across
    /// threads/share (the sketch is fed serially per policy), sanity
    /// against the exact pooled quantile, and validation of the shared
    /// pooled-metric constraints plus the p-range check.
    #[test]
    fn tail_quantile_scenario_streams_deterministically() {
        let sc = Scenario::new("t_q", SynthConfig::default().with_njobs(400))
            .policies(&["ps", "psbs"])
            .metric(Metric::TailQuantile { p: 0.9 });
        assert!(sc.validate().is_ok());
        let p = SweepParams { reps: 2, seed: 5, converge: false };
        let ts = sc.tables(p, 1, true);
        assert_eq!(ts.len(), 1);
        let t = &ts[0];
        assert_eq!(t.header, vec!["p", "ps", "psbs"]);
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0][0], 0.9);
        let bits = |share: bool, threads: usize| -> Vec<u64> {
            sc.tables(p, threads, share)[0].rows[0].iter().map(|v| v.to_bits()).collect()
        };
        let base = bits(true, 1);
        for (share, threads) in [(true, 3), (false, 1), (false, 4)] {
            assert_eq!(base, bits(share, threads), "share={share} threads={threads}");
        }
        // The P2 estimate tracks the exact quantile of the pooled
        // population the sketch saw (~800 observations at q=0.9).
        let spec: PolicySpec = "psbs".into();
        let mut pooled = Vec::new();
        for r in 0..p.reps {
            let seed = sc.workload.rep_seed(p.seed, r);
            let jobs = sc.workload.synthesize(seed);
            pooled.extend(slowdowns_of_seeded(&spec, &jobs, seed));
        }
        let exact = crate::stats::quantile(&pooled, 0.9);
        let est = t.rows[0][2];
        assert!((est - exact).abs() / exact.abs().max(1e-9) < 0.25, "est {est} exact {exact}");
        // p outside (0, 1).
        for bad_p in [0.0, 1.0, -0.5, 1.5] {
            let bad = Scenario::new("t", SynthConfig::default())
                .policies(&["ps"])
                .metric(Metric::TailQuantile { p: bad_p });
            assert!(bad.validate().is_err(), "p={bad_p}");
        }
        // Row axis / reference / converge=true all rejected, like the
        // other pooled metrics.
        let bad = Scenario::new("t", SynthConfig::default())
            .axis("sigma", AxisParam::Sigma, &[0.5])
            .policies(&["ps"])
            .metric(Metric::TailQuantile { p: 0.5 });
        assert!(bad.validate().is_err());
        let bad = Scenario::new("t", SynthConfig::default())
            .policies(&["ps"])
            .vs(Reference::Ps)
            .metric(Metric::TailQuantile { p: 0.5 });
        assert!(bad.validate().is_err());
        let bad = Scenario::new("t", SynthConfig::default())
            .policies(&["ps"])
            .metric(Metric::TailQuantile { p: 0.5 })
            .converge_override(true);
        assert!(bad.validate().is_err());
    }

    /// Metric::CondSlowdown: table shape (size + one column per
    /// policy, one row per class) and bit-identity across modes.
    #[test]
    fn cond_slowdown_scenario_shape_and_determinism() {
        let sc = Scenario::new("t_cond", SynthConfig::default().with_njobs(200))
            .policies(&["ps", "psbs"])
            .metric(Metric::CondSlowdown { bins: 20 });
        let p = SweepParams { reps: 2, seed: 13, converge: false };
        let ts = sc.tables(p, 1, true);
        assert_eq!(ts.len(), 1);
        let t = &ts[0];
        assert_eq!(t.header, vec!["size", "ps", "psbs"]);
        assert_eq!(t.rows.len(), 20);
        // Classes are sorted by size; slowdowns are >= 1-ish (>0).
        for w in t.rows.windows(2) {
            assert!(w[1][0] >= w[0][0]);
        }
        for row in &t.rows {
            assert!(row[1] > 0.0 && row[2] > 0.0);
        }
        let bits = |share: bool, threads: usize| -> Vec<u64> {
            sc.tables(p, threads, share)[0]
                .rows
                .iter()
                .flatten()
                .map(|v| v.to_bits())
                .collect()
        };
        let base = bits(false, 1);
        for (share, threads) in [(true, 1), (true, 3), (false, 3)] {
            assert_eq!(base, bits(share, threads), "share={share} threads={threads}");
        }
    }

    /// Metric::SloAttainment: companion-table shape, fraction range,
    /// cross-check against the pooled population, bit-identity across
    /// modes, and the structural rejections shared with the other
    /// pooled metrics plus the deadline-range check.
    #[test]
    fn slo_attainment_scenario_shape_and_determinism() {
        let sc = Scenario::new("t_slo", SynthConfig::default().with_njobs(200))
            .policies(&["ps", "psbs"])
            .metric(Metric::SloAttainment { deadline: 5.0 });
        assert!(sc.validate().is_ok());
        let p = SweepParams { reps: 2, seed: 13, converge: false };
        let ts = sc.tables(p, 1, true);
        assert_eq!(ts.len(), 1);
        let t = &ts[0];
        assert_eq!(t.name, "t_slo_slo_within_5");
        assert_eq!(t.header, vec!["policy_idx", "frac_within_5"]);
        assert_eq!(t.rows.len(), 2);
        for (pi, row) in t.rows.iter().enumerate() {
            assert_eq!(row[0], pi as f64);
            assert!((0.0..=1.0).contains(&row[1]), "frac {}", row[1]);
        }
        // Cross-check policy 1 against the pooled population directly.
        let spec: PolicySpec = "psbs".into();
        let (mut within, mut total) = (0usize, 0usize);
        for r in 0..p.reps {
            let seed = sc.workload.rep_seed(p.seed, r);
            let jobs = sc.workload.synthesize(seed);
            let slow = slowdowns_of_seeded(&spec, &jobs, seed);
            within += slow.iter().filter(|&&s| s <= 5.0).count();
            total += slow.len();
        }
        assert_eq!(t.rows[1][1].to_bits(), (within as f64 / total as f64).to_bits());
        let bits = |share: bool, threads: usize| -> Vec<u64> {
            sc.tables(p, threads, share)[0].rows.iter().flatten().map(|v| v.to_bits()).collect()
        };
        let base = bits(false, 1);
        for (share, threads) in [(true, 1), (true, 3), (false, 3)] {
            assert_eq!(base, bits(share, threads), "share={share} threads={threads}");
        }
        // Nonpositive deadline / row axis / reference / converge=true.
        for bad_d in [0.0, -1.0] {
            let bad = Scenario::new("t", SynthConfig::default())
                .policies(&["ps"])
                .metric(Metric::SloAttainment { deadline: bad_d });
            assert!(bad.validate().is_err(), "deadline={bad_d}");
        }
        let slo = Metric::SloAttainment { deadline: 5.0 };
        let bad = Scenario::new("t", SynthConfig::default())
            .axis("sigma", AxisParam::Sigma, &[0.5])
            .policies(&["ps"])
            .metric(slo);
        assert!(bad.validate().is_err());
        let bad =
            Scenario::new("t", SynthConfig::default()).policies(&["ps"]).vs(Reference::Ps).metric(slo);
        assert!(bad.validate().is_err());
        let bad = Scenario::new("t", SynthConfig::default())
            .policies(&["ps"])
            .metric(slo)
            .converge_override(true);
        assert!(bad.validate().is_err());
    }

    /// Metric::DominanceVsRef: companion-table shape, the required
    /// reference (rejected when missing — unique among pooled
    /// metrics), self-dominance sanity (PS vs PS is exactly 1),
    /// cross-check against a direct per-job pairing, and bit-identity
    /// across modes.
    #[test]
    fn dominance_scenario_shape_and_determinism() {
        let sc = Scenario::new("t_dom", SynthConfig::default().with_njobs(200))
            .policies(&["ps", "psbs"])
            .vs(Reference::Ps)
            .metric(Metric::DominanceVsRef);
        assert!(sc.validate().is_ok());
        let p = SweepParams { reps: 2, seed: 13, converge: false };
        let ts = sc.tables(p, 1, true);
        assert_eq!(ts.len(), 1);
        let t = &ts[0];
        assert_eq!(t.name, "t_dom_dominance_vs_ps");
        assert_eq!(t.header, vec!["policy_idx", "frac_dominant"]);
        assert_eq!(t.rows.len(), 2);
        // PS paired against the PS reference dominates on every job.
        assert_eq!(t.rows[0][1], 1.0);
        assert!((0.0..=1.0).contains(&t.rows[1][1]));
        // Cross-check policy 1 against a direct per-job pairing.
        let spec: PolicySpec = "psbs".into();
        let (mut dom, mut total) = (0usize, 0usize);
        for r in 0..p.reps {
            let seed = sc.workload.rep_seed(p.seed, r);
            let jobs = sc.workload.synthesize(seed);
            let slow = slowdowns_of_seeded(&spec, &jobs, seed);
            let base = Reference::Ps.slowdowns(&jobs);
            dom += slow.iter().zip(&base).filter(|&(s, b)| s <= b).count();
            total += slow.len();
        }
        assert_eq!(t.rows[1][1].to_bits(), (dom as f64 / total as f64).to_bits());
        let bits = |share: bool, threads: usize| -> Vec<u64> {
            sc.tables(p, threads, share)[0].rows.iter().flatten().map(|v| v.to_bits()).collect()
        };
        let base = bits(false, 1);
        for (share, threads) in [(true, 1), (true, 3), (false, 3)] {
            assert_eq!(base, bits(share, threads), "share={share} threads={threads}");
        }
        // The opt reference names the table accordingly.
        let sc_opt = Scenario::new("t_dom", SynthConfig::default().with_njobs(120))
            .policies(&["psbs"])
            .vs(Reference::OptSrpt)
            .metric(Metric::DominanceVsRef);
        assert_eq!(sc_opt.tables(SweepParams { reps: 1, seed: 3, converge: false }, 1, true)[0]
            .name, "t_dom_dominance_vs_opt");
        // Missing reference / row axis / converge=true rejected.
        let bad = Scenario::new("t", SynthConfig::default())
            .policies(&["ps"])
            .metric(Metric::DominanceVsRef);
        assert!(bad.validate().is_err(), "dominance without a reference");
        let bad = Scenario::new("t", SynthConfig::default())
            .axis("sigma", AxisParam::Sigma, &[0.5])
            .policies(&["ps"])
            .vs(Reference::Ps)
            .metric(Metric::DominanceVsRef);
        assert!(bad.validate().is_err());
        let bad = Scenario::new("t", SynthConfig::default())
            .policies(&["ps"])
            .vs(Reference::Ps)
            .metric(Metric::DominanceVsRef)
            .converge_override(true);
        assert!(bad.validate().is_err());
    }
}
