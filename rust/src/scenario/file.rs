//! Persistable scenario files: a dependency-free TOML-subset
//! serialization of [`Scenario`], so experiment grids live *outside*
//! the binary (ROADMAP scenario-layer item).  `psbs sweep --scenario
//! path.toml` runs one; `psbs scenario export` dumps the built-in
//! figure scenarios into `scenarios/` (see `scenarios/README.md` for
//! the schema).
//!
//! ## Grammar (TOML subset)
//!
//! ```text
//! name = "fig6_mst_vs_sigma"      # top-level keys first
//! metric = "mean"                 # "mean" | "ecdf" | "cond_slowdown"
//!                                 # | "tail_quantile" | "slo" | "dominance"
//!                                 # | "goodput" | "wasted_work" | "restarts"
//! reps = 30                       # optional per-scenario overrides;
//! converge = true                 # an explicit CLI flag still wins
//! reference = "opt"               # "opt" | "ps" (omit for raw MST)
//!
//! [faults]                        # optional: run under fault injection
//! mtbf = 400                      # mean time between per-server crashes
//! mttr = 40                       # mean repair time
//! slowdown = 0.5                  # straggler-window rate multiplier, (0,1]
//! max_attempts = 3                # retry budget per job
//! backoff = 1                     # base retry delay (doubles per retry)
//! seed = 0                        # fault-schedule seed
//!
//! [workload]                      # exactly one
//! kind = "synthetic"              # "synthetic" | "trace"
//! shape = 0.25                    # or: alpha = 2  (Pareto sizes)
//! sigma = 0.5
//! timeshape = 1
//! load = 0.9
//! njobs = 10000
//! beta = 0
//!
//! # kind = "trace" instead names a built-in stand-in OR an on-disk
//! # trace file (arrival,size[,weight][,estimate] — see
//! # crate::workload::trace_file), mutually exclusive:
//! # trace = "facebook"            # "facebook" | "ircache"
//! # path = "my_trace.csv"         # resolved against the scenario
//! #                               # file's own directory
//!
//! [[axis]]                        # zero or more
//! param = "shape"                 # shape|sigma|load|timeshape|njobs|beta|alpha
//! split = true                    # one table per value (default: row axis)
//! values = [0.5, 0.25, 0.125]
//!
//! [[policy]]                      # one or more
//! spec = "psbs"                   # any PolicySpec string
//! label = "psbs_over_ps"          # optional column-label override
//! ```
//!
//! Supported values: double-quoted strings (no escapes), numbers,
//! `true`/`false`, and flat numeric arrays.  `#` starts a comment
//! (outside strings).  Unknown keys are hard errors, exactly like the
//! CLI's unknown-flag policy — a typo must not silently fall back to a
//! default in the middle of an experiment.
//!
//! [`Scenario::to_toml`] renders the canonical form (fixed key order,
//! shortest-round-trip float formatting, defaults omitted only for
//! `label`/`split`) and [`Scenario::parse_toml`] inverts it exactly;
//! `tests::random_scenarios_round_trip_property` pins the pair the
//! same way `PolicySpec`'s grammar is pinned.

use super::{
    Axis, AxisParam, FaultOutput, Metric, PolicySpec, Reference, Scenario, TraceSource,
    TraceSpec, WorkloadSpec,
};
use crate::coordinator::{FaultConfig, FaultSpec, RetryPolicy};
use crate::error::Error;
use crate::workload::trace_file::TraceFile;
use crate::workload::traces::TraceName;
use crate::workload::{SizeDist, SynthConfig};
use std::fmt;
use std::path::Path;

impl Scenario {
    /// Render the canonical scenario-file form.
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("name = \"{}\"\n", self.name));
        match self.metric {
            Metric::Mean => s.push_str("metric = \"mean\"\n"),
            Metric::PooledEcdf { points, decades, tail_above } => {
                s.push_str("metric = \"ecdf\"\n");
                s.push_str(&format!("points = {points}\n"));
                s.push_str(&format!("decades = {decades}\n"));
                if let Some(t) = tail_above {
                    s.push_str(&format!("tail_above = {t}\n"));
                }
            }
            Metric::CondSlowdown { bins } => {
                s.push_str("metric = \"cond_slowdown\"\n");
                s.push_str(&format!("bins = {bins}\n"));
            }
            Metric::TailQuantile { p } => {
                s.push_str("metric = \"tail_quantile\"\n");
                s.push_str(&format!("p = {p}\n"));
            }
            Metric::SloAttainment { deadline } => {
                s.push_str("metric = \"slo\"\n");
                s.push_str(&format!("deadline = {deadline}\n"));
            }
            Metric::DominanceVsRef => s.push_str("metric = \"dominance\"\n"),
            Metric::Fault { output } => {
                s.push_str(&format!("metric = \"{}\"\n", output.name()));
            }
        }
        if let Some(r) = self.reps {
            s.push_str(&format!("reps = {r}\n"));
        }
        if let Some(c) = self.converge {
            s.push_str(&format!("converge = {c}\n"));
        }
        if let Some(r) = self.reference {
            s.push_str(&format!("reference = \"{}\"\n", r.name()));
        }
        if let Some(cfg) = &self.faults {
            s.push_str("\n[faults]\n");
            s.push_str(&format!("mtbf = {}\n", cfg.spec.mtbf));
            s.push_str(&format!("mttr = {}\n", cfg.spec.mttr));
            s.push_str(&format!("slowdown = {}\n", cfg.spec.slowdown));
            s.push_str(&format!("max_attempts = {}\n", cfg.retry.max_attempts));
            s.push_str(&format!("backoff = {}\n", cfg.retry.backoff));
            s.push_str(&format!("seed = {}\n", cfg.seed));
        }
        s.push_str("\n[workload]\n");
        match &self.workload {
            WorkloadSpec::Synth(c) => {
                s.push_str("kind = \"synthetic\"\n");
                match c.size_dist {
                    SizeDist::Weibull { shape } => s.push_str(&format!("shape = {shape}\n")),
                    SizeDist::Pareto { alpha } => s.push_str(&format!("alpha = {alpha}\n")),
                }
                s.push_str(&format!("sigma = {}\n", c.sigma));
                s.push_str(&format!("timeshape = {}\n", c.timeshape));
                s.push_str(&format!("load = {}\n", c.load));
                s.push_str(&format!("njobs = {}\n", c.njobs));
                s.push_str(&format!("beta = {}\n", c.beta));
            }
            WorkloadSpec::Trace(t) => {
                s.push_str("kind = \"trace\"\n");
                match &t.source {
                    TraceSource::Builtin(n) => {
                        s.push_str(&format!("trace = \"{}\"\n", n.name()))
                    }
                    TraceSource::File(f) => s.push_str(&format!("path = \"{}\"\n", f.path)),
                }
                s.push_str(&format!("njobs = {}\n", t.njobs));
                s.push_str(&format!("load = {}\n", t.load));
                s.push_str(&format!("sigma = {}\n", t.sigma));
            }
        }
        for axis in &self.axes {
            s.push_str("\n[[axis]]\n");
            s.push_str(&format!("param = \"{}\"\n", axis.param.name()));
            if axis.label != axis.param.name() {
                s.push_str(&format!("label = \"{}\"\n", axis.label));
            }
            if axis.split {
                s.push_str("split = true\n");
            }
            let vals: Vec<String> = axis.values.iter().map(|v| format!("{v}")).collect();
            s.push_str(&format!("values = [{}]\n", vals.join(", ")));
        }
        for (label, spec) in &self.policies {
            s.push_str("\n[[policy]]\n");
            s.push_str(&format!("spec = \"{spec}\"\n"));
            if *label != spec.to_string() {
                s.push_str(&format!("label = \"{label}\"\n"));
            }
        }
        s
    }

    /// Parse a scenario file.  Errors carry the offending line number.
    /// Relative trace-file `path`s resolve against the working
    /// directory; use [`Scenario::parse_toml_in`] to anchor them.
    pub fn parse_toml(text: &str) -> Result<Scenario, Error> {
        Scenario::parse_toml_in(text, None)
    }

    /// Parse with relative trace-file `path`s resolved against `base`
    /// (the scenario file's own directory, for [`Scenario::load`] and
    /// `psbs scenario validate` — a committed scenario must work from
    /// any working directory).
    pub fn parse_toml_in(text: &str, base: Option<&Path>) -> Result<Scenario, Error> {
        let doc = Doc::parse(text).map_err(scenario_error)?;
        doc.into_scenario(base).map_err(scenario_error)
    }

    /// Load a scenario from a file path.
    pub fn load(path: &str) -> Result<Scenario, Error> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::scenario(format!("reading {path}: {e}")))?;
        let base = Path::new(path).parent().filter(|p| !p.as_os_str().is_empty());
        Scenario::parse_toml_in(&text, base).map_err(|e| e.with_path(path))
    }
}

/// Lift an internal parse-error string into [`Error::Scenario`],
/// extracting the `line {N}: ` prefix the section parser emits into
/// the structured payload (Display re-attaches it byte-identically).
fn scenario_error(e: String) -> Error {
    if let Some(rest) = e.strip_prefix("line ") {
        if let Some((num, msg)) = rest.split_once(": ") {
            if let Ok(ln) = num.parse::<u64>() {
                return Error::Scenario { path: None, line: Some(ln), msg: msg.to_string() };
            }
        }
    }
    Error::scenario(e)
}

/// The canonical rendering — `format!("{sc}")` is a scenario file.
impl fmt::Display for Scenario {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_toml())
    }
}

/// One parsed value.
#[derive(Debug, Clone, PartialEq)]
enum Val {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<f64>),
}

/// A flat key list for one section, with the line each key came from.
#[derive(Debug, Default)]
struct Section {
    keys: Vec<(String, Val, usize)>,
}

impl Section {
    fn get(&self, key: &str) -> Option<&Val> {
        self.keys.iter().find(|(k, _, _)| k == key).map(|(_, v, _)| v)
    }

    fn str(&self, key: &str) -> Result<Option<&str>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(Val::Str(s)) => Ok(Some(s)),
            Some(v) => Err(format!("`{key}` must be a string, got {v:?}")),
        }
    }

    fn num(&self, key: &str) -> Result<Option<f64>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(Val::Num(n)) => Ok(Some(*n)),
            Some(v) => Err(format!("`{key}` must be a number, got {v:?}")),
        }
    }

    fn usize(&self, key: &str) -> Result<Option<usize>, String> {
        match self.num(key)? {
            None => Ok(None),
            Some(n) if n >= 0.0 && n == n.trunc() => Ok(Some(n as usize)),
            Some(n) => Err(format!("`{key}` must be a non-negative integer, got {n}")),
        }
    }

    fn bool(&self, key: &str) -> Result<Option<bool>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(Val::Bool(b)) => Ok(Some(*b)),
            Some(v) => Err(format!("`{key}` must be true or false, got {v:?}")),
        }
    }

    fn arr(&self, key: &str) -> Result<Option<&[f64]>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(Val::Arr(a)) => Ok(Some(a)),
            Some(v) => Err(format!("`{key}` must be a numeric array, got {v:?}")),
        }
    }

    /// Hard-error on any key outside `allowed` (typos must not fall
    /// back to defaults).
    fn check_keys(&self, what: &str, allowed: &[&str]) -> Result<(), String> {
        for (k, _, line) in &self.keys {
            if !allowed.contains(&k.as_str()) {
                return Err(format!("line {line}: {what}: unknown key `{k}`"));
            }
        }
        Ok(())
    }
}

/// A parsed scenario document: top-level keys plus the three section
/// kinds the schema defines.
#[derive(Debug, Default)]
struct Doc {
    top: Section,
    workload: Option<Section>,
    faults: Option<Section>,
    axes: Vec<Section>,
    policies: Vec<Section>,
}

/// Which section subsequent `key = value` lines land in.
enum Cursor {
    Top,
    Workload,
    Faults,
    Axis,
    Policy,
}

impl Doc {
    fn parse(text: &str) -> Result<Doc, String> {
        let mut doc = Doc::default();
        let mut cursor = Cursor::Top;
        for (ln, raw) in text.lines().enumerate() {
            let ln = ln + 1;
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(header) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
                match header.trim() {
                    "axis" => {
                        doc.axes.push(Section::default());
                        cursor = Cursor::Axis;
                    }
                    "policy" => {
                        doc.policies.push(Section::default());
                        cursor = Cursor::Policy;
                    }
                    other => return Err(format!("line {ln}: unknown section [[{other}]]")),
                }
                continue;
            }
            if let Some(header) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                match header.trim() {
                    "workload" => {
                        if doc.workload.is_some() {
                            return Err(format!("line {ln}: duplicate [workload] section"));
                        }
                        doc.workload = Some(Section::default());
                        cursor = Cursor::Workload;
                    }
                    "faults" => {
                        if doc.faults.is_some() {
                            return Err(format!("line {ln}: duplicate [faults] section"));
                        }
                        doc.faults = Some(Section::default());
                        cursor = Cursor::Faults;
                    }
                    other => return Err(format!("line {ln}: unknown section [{other}]")),
                }
                continue;
            }
            let Some((key, rest)) = line.split_once('=') else {
                return Err(format!("line {ln}: expected `key = value`, got `{line}`"));
            };
            let key = key.trim().to_string();
            let val = parse_val(rest.trim()).map_err(|e| format!("line {ln}: {e}"))?;
            let section = match cursor {
                Cursor::Top => &mut doc.top,
                Cursor::Workload => doc.workload.as_mut().unwrap(),
                Cursor::Faults => doc.faults.as_mut().unwrap(),
                Cursor::Axis => doc.axes.last_mut().unwrap(),
                Cursor::Policy => doc.policies.last_mut().unwrap(),
            };
            if section.get(&key).is_some() {
                return Err(format!("line {ln}: duplicate key `{key}`"));
            }
            section.keys.push((key, val, ln));
        }
        Ok(doc)
    }

    fn into_scenario(self, base: Option<&Path>) -> Result<Scenario, String> {
        self.top.check_keys(
            "top level",
            &[
                "name", "metric", "points", "decades", "tail_above", "bins", "p", "deadline",
                "reps", "converge", "reference",
            ],
        )?;
        let name = self
            .top
            .str("name")?
            .ok_or("missing top-level `name`")?
            .to_string();
        // Each metric rejects the other metrics' parameter keys: a
        // stray `points` on a mean scenario is a typo, not a default.
        let reject = |keys: &[&str], metric: &str| -> Result<(), String> {
            for k in keys {
                if self.top.get(k).is_some() {
                    return Err(format!("`{k}` does not apply to metric = \"{metric}\""));
                }
            }
            Ok(())
        };
        let metric = match self.top.str("metric")?.unwrap_or("mean") {
            "mean" => {
                reject(&["points", "decades", "tail_above", "bins", "p", "deadline"], "mean")?;
                Metric::Mean
            }
            "ecdf" => {
                reject(&["bins", "p", "deadline"], "ecdf")?;
                Metric::PooledEcdf {
                    points: self.top.usize("points")?.unwrap_or(128),
                    decades: self.top.num("decades")?.unwrap_or(3.0),
                    tail_above: self.top.num("tail_above")?,
                }
            }
            "cond_slowdown" => {
                reject(&["points", "decades", "tail_above", "p", "deadline"], "cond_slowdown")?;
                Metric::CondSlowdown { bins: self.top.usize("bins")?.unwrap_or(100) }
            }
            "tail_quantile" => {
                reject(&["points", "decades", "tail_above", "bins", "deadline"], "tail_quantile")?;
                Metric::TailQuantile { p: self.top.num("p")?.unwrap_or(0.99) }
            }
            "slo" => {
                reject(&["points", "decades", "tail_above", "bins", "p"], "slo")?;
                Metric::SloAttainment { deadline: self.top.num("deadline")?.unwrap_or(10.0) }
            }
            "dominance" => {
                reject(&["points", "decades", "tail_above", "bins", "p", "deadline"], "dominance")?;
                Metric::DominanceVsRef
            }
            name @ ("goodput" | "wasted_work" | "restarts") => {
                reject(&["points", "decades", "tail_above", "bins", "p", "deadline"], name)?;
                Metric::Fault {
                    output: FaultOutput::parse(name)
                        .expect("arm pattern and FaultOutput::parse agree"),
                }
            }
            other => {
                return Err(format!(
                    "unknown metric `{other}` (mean|ecdf|cond_slowdown|tail_quantile|\
                     slo|dominance|goodput|wasted_work|restarts)"
                ))
            }
        };
        let reps = self.top.usize("reps")?.map(|r| r as u64);
        let converge = self.top.bool("converge")?;
        let reference = match self.top.str("reference")? {
            None | Some("none") => None,
            Some("opt") => Some(Reference::OptSrpt),
            Some("ps") => Some(Reference::Ps),
            Some(other) => return Err(format!("unknown reference `{other}` (opt|ps|none)")),
        };

        let faults = match self.faults.as_ref() {
            None => None,
            Some(f) => {
                f.check_keys(
                    "[faults]",
                    &["mtbf", "mttr", "slowdown", "max_attempts", "backoff", "seed"],
                )?;
                Some(FaultConfig {
                    spec: FaultSpec {
                        mtbf: f.num("mtbf")?.unwrap_or(0.0),
                        mttr: f.num("mttr")?.unwrap_or(0.0),
                        slowdown: f.num("slowdown")?.unwrap_or(1.0),
                    },
                    retry: RetryPolicy {
                        max_attempts: f.usize("max_attempts")?.unwrap_or(3) as u32,
                        backoff: f.num("backoff")?.unwrap_or(0.0),
                    },
                    seed: f.usize("seed")?.unwrap_or(0) as u64,
                })
            }
        };

        let w = self.workload.as_ref().ok_or("missing [workload] section")?;
        let workload = match w.str("kind")?.ok_or("[workload]: missing `kind`")? {
            "synthetic" => {
                w.check_keys(
                    "[workload]",
                    &["kind", "shape", "alpha", "sigma", "timeshape", "load", "njobs", "beta"],
                )?;
                let d = SynthConfig::default();
                let size_dist = match (w.num("shape")?, w.num("alpha")?) {
                    (Some(_), Some(_)) => {
                        return Err("[workload]: `shape` and `alpha` are mutually exclusive".into())
                    }
                    (None, Some(alpha)) => SizeDist::Pareto { alpha },
                    (shape, None) => SizeDist::Weibull {
                        shape: shape.unwrap_or(match d.size_dist {
                            SizeDist::Weibull { shape } => shape,
                            SizeDist::Pareto { .. } => unreachable!("default is Weibull"),
                        }),
                    },
                };
                WorkloadSpec::Synth(SynthConfig {
                    size_dist,
                    sigma: w.num("sigma")?.unwrap_or(d.sigma),
                    timeshape: w.num("timeshape")?.unwrap_or(d.timeshape),
                    load: w.num("load")?.unwrap_or(d.load),
                    njobs: w.usize("njobs")?.unwrap_or(d.njobs),
                    beta: w.num("beta")?.unwrap_or(d.beta),
                })
            }
            "trace" => {
                w.check_keys("[workload]", &["kind", "trace", "path", "njobs", "load", "sigma"])?;
                let source = match (w.str("trace")?, w.str("path")?) {
                    (Some(_), Some(_)) => {
                        return Err(
                            "[workload]: `trace` and `path` are mutually exclusive".into()
                        )
                    }
                    (None, None) => {
                        return Err(
                            "[workload]: trace needs `trace` (stand-in) or `path` (file)".into()
                        )
                    }
                    (Some(name), None) => TraceSource::Builtin(
                        TraceName::from_name(name)
                            .ok_or_else(|| format!("unknown trace `{name}` (facebook|ircache)"))?,
                    ),
                    // The file loads eagerly: a scenario naming a
                    // missing or malformed trace fails at parse time
                    // (what `psbs scenario validate` gates on), never
                    // mid-sweep on a worker.
                    (None, Some(path)) => {
                        TraceSource::File(TraceFile::load_relative(path, base)?)
                    }
                };
                WorkloadSpec::Trace(TraceSpec {
                    njobs: w.usize("njobs")?.unwrap_or(source.max_jobs()),
                    load: w.num("load")?.unwrap_or(0.9),
                    sigma: w.num("sigma")?.unwrap_or(0.5),
                    source,
                })
            }
            other => return Err(format!("unknown workload kind `{other}` (synthetic|trace)")),
        };

        let mut axes = Vec::new();
        for a in &self.axes {
            a.check_keys("[[axis]]", &["param", "label", "split", "values"])?;
            let pname = a.str("param")?.ok_or("[[axis]]: missing `param`")?;
            let param = AxisParam::parse(pname)
                .ok_or_else(|| format!("[[axis]]: unknown param `{pname}`"))?;
            axes.push(Axis {
                label: a.str("label")?.unwrap_or(pname).to_string(),
                param,
                values: a
                    .arr("values")?
                    .ok_or("[[axis]]: missing `values`")?
                    .to_vec(),
                split: a.bool("split")?.unwrap_or(false),
            });
        }

        let mut policies = Vec::new();
        for p in &self.policies {
            p.check_keys("[[policy]]", &["spec", "label"])?;
            let spec_str = p.str("spec")?.ok_or("[[policy]]: missing `spec`")?;
            let spec = PolicySpec::parse(spec_str)?;
            let label = p.str("label")?.map(str::to_string).unwrap_or_else(|| spec.to_string());
            policies.push((label, spec));
        }

        let sc =
            Scenario { name, workload, axes, policies, reference, metric, reps, converge, faults };
        sc.validate()?;
        Ok(sc)
    }
}

/// Strip a `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse one value: quoted string, numeric array, bool, or number.
fn parse_val(s: &str) -> Result<Val, String> {
    if let Some(body) = s.strip_prefix('"') {
        let Some(body) = body.strip_suffix('"') else {
            return Err(format!("unterminated string: {s}"));
        };
        if body.contains('"') {
            return Err(format!("strings cannot contain `\"`: {s}"));
        }
        return Ok(Val::Str(body.to_string()));
    }
    if let Some(body) = s.strip_prefix('[') {
        let Some(body) = body.strip_suffix(']') else {
            return Err(format!("unterminated array: {s}"));
        };
        if body.trim().is_empty() {
            return Ok(Val::Arr(Vec::new()));
        }
        let mut vals = Vec::new();
        for part in body.split(',') {
            let part = part.trim();
            if part.is_empty() {
                // `[0.5,,1]` or `[0.5,]` is a hand-editing slip, not a
                // value: dropping it silently would shrink the grid.
                return Err(format!("empty array element in {s}"));
            }
            vals.push(
                part.parse::<f64>()
                    .map_err(|_| format!("array element is not a number: {part}"))?,
            );
        }
        return Ok(Val::Arr(vals));
    }
    match s {
        "true" => Ok(Val::Bool(true)),
        "false" => Ok(Val::Bool(false)),
        _ => s
            .parse::<f64>()
            .map(Val::Num)
            .map_err(|_| format!("not a value (string/number/bool/array): {s}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{property, Config};
    use crate::util::rng::Rng;

    fn assert_round_trip(sc: &Scenario) {
        let rendered = sc.to_toml();
        let parsed = Scenario::parse_toml(&rendered)
            .unwrap_or_else(|e| panic!("rendered scenario failed to parse: {e}\n{rendered}"));
        assert_eq!(&parsed, sc, "parse(render(s)) != s\n{rendered}");
        assert_eq!(parsed.to_toml(), rendered, "render is not a fixpoint");
    }

    #[test]
    fn synthetic_mean_scenario_round_trips() {
        let sc = Scenario::new("fig6_like", SynthConfig::default().with_njobs(500))
            .split_axis("shape", AxisParam::Shape, &[0.5, 0.25, 0.125])
            .axis("sigma", AxisParam::Sigma, &[0.125, 0.25, 0.5, 1.0, 2.0, 4.0])
            .policies(&["psbs", "srpte", "fspe", "ps", "las"])
            .vs(Reference::OptSrpt);
        assert_round_trip(&sc);
    }

    #[test]
    fn trace_and_ecdf_scenarios_round_trip() {
        let tr = Scenario::with_workload(
            "fig12_like",
            TraceSpec {
                source: TraceName::Facebook.into(),
                njobs: 24_443,
                load: 0.9,
                sigma: 0.5,
            },
        )
        .axis("sigma", AxisParam::Sigma, &[0.125, 4.0])
        .policies(&["psbs", "ps"])
        .vs(Reference::OptSrpt);
        assert_round_trip(&tr);

        let ec = Scenario::new("fig8_like", SynthConfig::default())
            .policies(&["fifo", "srpte", "psbs"])
            .metric(Metric::PooledEcdf { points: 128, decades: 4.0, tail_above: Some(100.0) });
        assert_round_trip(&ec);
    }

    #[test]
    fn cond_slowdown_and_override_scenarios_round_trip() {
        let sc = Scenario::new("fig7_like", SynthConfig::default())
            .policies(&["fifo", "ps", "psbs"])
            .metric(Metric::CondSlowdown { bins: 100 });
        assert_round_trip(&sc);
        assert!(sc.to_toml().contains("metric = \"cond_slowdown\"\nbins = 100\n"));

        let sc = Scenario::new("tail_like", SynthConfig::default())
            .policies(&["psbs", "ps"])
            .metric(Metric::TailQuantile { p: 0.99 });
        assert_round_trip(&sc);
        assert!(sc.to_toml().contains("metric = \"tail_quantile\"\np = 0.99\n"));
        // `p` defaults to 0.99 when omitted.
        let text = "name = \"t\"\nmetric = \"tail_quantile\"\n\n[workload]\n\
                    kind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n";
        match Scenario::parse_toml(text).unwrap().metric {
            Metric::TailQuantile { p } => assert_eq!(p, 0.99),
            m => panic!("expected tail_quantile, got {m:?}"),
        }

        let sc = Scenario::new("pinned", SynthConfig::default())
            .axis("sigma", AxisParam::Sigma, &[0.5])
            .policies(&["psbs"])
            .vs(Reference::OptSrpt)
            .reps_override(30)
            .converge_override(true);
        assert_round_trip(&sc);
        assert!(sc.to_toml().contains("reps = 30\nconverge = true\n"));
    }

    #[test]
    fn slo_and_dominance_scenarios_round_trip() {
        let sc = Scenario::new("slo_like", SynthConfig::default())
            .policies(&["psbs", "srpte", "ps"])
            .metric(Metric::SloAttainment { deadline: 5.0 });
        assert_round_trip(&sc);
        assert!(sc.to_toml().contains("metric = \"slo\"\ndeadline = 5\n"));
        // `deadline` defaults to 10 when omitted.
        let text = "name = \"t\"\nmetric = \"slo\"\n\n[workload]\n\
                    kind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n";
        match Scenario::parse_toml(text).unwrap().metric {
            Metric::SloAttainment { deadline } => assert_eq!(deadline, 10.0),
            m => panic!("expected slo, got {m:?}"),
        }

        let sc = Scenario::new("dom_like", SynthConfig::default())
            .split_axis("sigma", AxisParam::Sigma, &[0.5, 2.0])
            .policies(&["psbs", "fspe"])
            .vs(Reference::Ps)
            .metric(Metric::DominanceVsRef);
        assert_round_trip(&sc);
        assert!(sc.to_toml().contains("metric = \"dominance\"\nreference = \"ps\"\n"));
    }

    /// `kind = "trace"` + `path = ...`: loads eagerly, resolves the
    /// path against `base`, renders the path back verbatim, and
    /// round-trips.
    #[test]
    fn trace_file_scenarios_round_trip_and_resolve_relative_paths() {
        let dir = std::env::temp_dir().join("psbs_scenario_trace_file_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t.csv"), "arrival,size\n0,10\n1,20\n2,5\n").unwrap();
        let text = "name = \"t\"\n\n[workload]\nkind = \"trace\"\npath = \"t.csv\"\n\n\
                    [[policy]]\nspec = \"psbs\"\n";
        // Without a base dir the relative path misses (unless the CWD
        // happens to hold a t.csv — use an absolute-base parse for the
        // positive case).
        let sc = Scenario::parse_toml_in(text, Some(dir.as_path())).unwrap();
        match &sc.workload {
            WorkloadSpec::Trace(t) => {
                assert_eq!(t.njobs, 3, "njobs defaults to the file's row count");
                match &t.source {
                    TraceSource::File(f) => {
                        assert_eq!(f.path, "t.csv", "path stored as written");
                        assert_eq!(f.rows.len(), 3);
                    }
                    _ => panic!("expected file source"),
                }
            }
            _ => panic!("expected trace workload"),
        }
        let rendered = sc.to_toml();
        assert!(rendered.contains("path = \"t.csv\"\n"));
        let back = Scenario::parse_toml_in(&rendered, Some(dir.as_path())).unwrap();
        assert_eq!(back, sc);
        assert_eq!(back.to_toml(), rendered, "render is not a fixpoint");
        // A missing trace file fails the scenario parse, eagerly.
        let err = Scenario::parse_toml_in(
            &rendered.replace("t.csv", "missing.csv"),
            Some(dir.as_path()),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("reading trace file"), "{err}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn fault_scenarios_round_trip() {
        let cfg = FaultConfig {
            spec: FaultSpec { mtbf: 40.0, mttr: 4.0, slowdown: 0.5 },
            retry: RetryPolicy { max_attempts: 2, backoff: 0.1 },
            seed: 7,
        };
        // Survivor-MST ratio against a clean reference.
        let sc = Scenario::new("faulty_mean", SynthConfig::default().with_njobs(300))
            .axis("sigma", AxisParam::Sigma, &[0.5, 1.0])
            .policies(&["psbs", "srpte", "cluster(k=3,dispatch=jsq,inner=psbs)"])
            .vs(Reference::Ps)
            .with_faults(cfg);
        assert_round_trip(&sc);
        assert!(sc.to_toml().contains(
            "\n[faults]\nmtbf = 40\nmttr = 4\nslowdown = 0.5\n\
             max_attempts = 2\nbackoff = 0.1\nseed = 7\n"
        ));

        // Each fault-output metric, over a speculating cluster.
        for output in [FaultOutput::Goodput, FaultOutput::WastedWork, FaultOutput::Restarts] {
            let sc = Scenario::new("faulty_out", SynthConfig::default().with_njobs(300))
                .policies(&[
                    "psbs",
                    "speculate(after=2,inner=cluster(k=2,dispatch=leastwork,inner=srpte))",
                ])
                .metric(Metric::Fault { output })
                .with_faults(cfg);
            assert_round_trip(&sc);
            assert!(sc.to_toml().contains(&format!("metric = \"{}\"\n", output.name())));
        }

        // Omitted [faults] keys fill their defaults.
        let text = "name = \"t\"\n\n[faults]\nmtbf = 10\n\n[workload]\nkind = \"synthetic\"\n\n\
                    [[policy]]\nspec = \"ps\"\n";
        let f = Scenario::parse_toml(text).unwrap().faults.unwrap();
        assert_eq!(f.spec.mttr, 0.0);
        assert_eq!(f.spec.slowdown, 1.0);
        assert_eq!(f.retry.max_attempts, 3);
        assert_eq!(f.retry.backoff, 0.0);
        assert_eq!(f.seed, 0);
    }

    #[test]
    fn labels_and_composed_specs_round_trip() {
        let sc = Scenario::new("labelled", SynthConfig::default())
            .axis("err", AxisParam::Sigma, &[0.5])
            .policy_as("psbs_over_ps", "psbs")
            .policy_as(
                "cluster4",
                "cluster(k=4,dispatch=leastwork,inner=est(model=lognormal,sigma=2,inner=psbs))",
            )
            .vs(Reference::Ps);
        assert_round_trip(&sc);
    }

    /// Random scenarios round-trip through render/parse — the schema
    /// and the renderer cannot drift apart (the `PolicySpec` treatment).
    #[test]
    fn random_scenarios_round_trip_property() {
        fn gen_values(rng: &mut Rng) -> Vec<f64> {
            (0..1 + rng.below(4)).map(|_| 0.125 * (1 + rng.below(40)) as f64).collect()
        }
        fn gen_faults(rng: &mut Rng) -> FaultConfig {
            FaultConfig {
                spec: FaultSpec {
                    mtbf: (1 + rng.below(100)) as f64,
                    mttr: 0.25 * (1 + rng.below(16)) as f64,
                    slowdown: 0.125 * (1 + rng.below(8)) as f64,
                },
                retry: RetryPolicy {
                    max_attempts: 1 + rng.below(5) as u32,
                    backoff: 0.25 * rng.below(8) as f64,
                },
                seed: rng.below(1000),
            }
        }
        fn gen_scenario(rng: &mut Rng) -> Scenario {
            let workload = if rng.below(4) == 0 {
                WorkloadSpec::Trace(TraceSpec {
                    source: if rng.below(2) == 0 {
                        TraceName::Facebook.into()
                    } else {
                        TraceName::Ircache.into()
                    },
                    njobs: 100 + rng.below(10_000) as usize,
                    load: 0.1 * (1 + rng.below(9)) as f64,
                    sigma: 0.25 * rng.below(8) as f64,
                })
            } else {
                let mut c = SynthConfig::default()
                    .with_sigma(0.25 * rng.below(8) as f64)
                    .with_load(0.1 * (1 + rng.below(9)) as f64)
                    .with_njobs(100 + rng.below(10_000) as usize)
                    .with_beta(rng.below(3) as f64)
                    .with_timeshape(0.25 * (1 + rng.below(8)) as f64);
                if rng.below(3) == 0 {
                    c.size_dist = SizeDist::Pareto { alpha: 0.5 * (1 + rng.below(4)) as f64 };
                } else {
                    c = c.with_shape(0.125 * (1 + rng.below(16)) as f64);
                }
                WorkloadSpec::Synth(c)
            };
            let is_trace = matches!(workload, WorkloadSpec::Trace(_));
            // Metric: 0 = ecdf, 1 = cond_slowdown, 2 = tail_quantile,
            // 3 = a fault output, 4 = slo, 5 = dominance, else mean.
            // The pooled metrics restrict axes to split axes.
            let metric_kind = rng.below(10);
            let pooled = matches!(metric_kind, 0..=2 | 4 | 5);
            let mut sc = Scenario::with_workload(format!("s{}", rng.below(1000)), workload);
            let axis_pool: &[AxisParam] = if is_trace {
                &[AxisParam::Sigma, AxisParam::Load, AxisParam::Njobs]
            } else {
                &[
                    AxisParam::Shape,
                    AxisParam::Sigma,
                    AxisParam::Load,
                    AxisParam::Timeshape,
                    AxisParam::Njobs,
                    AxisParam::Beta,
                    AxisParam::Alpha,
                ]
            };
            for _ in 0..rng.below(3) {
                let param = axis_pool[rng.below(axis_pool.len() as u64) as usize];
                let label = if rng.below(3) == 0 {
                    format!("x{}", rng.below(10))
                } else {
                    param.name().to_string()
                };
                let values = gen_values(rng);
                // Pooled-metric scenarios only carry split axes.
                if pooled || rng.below(2) == 0 {
                    sc = sc.split_axis(label, param, &values);
                } else {
                    sc = sc.axis(label, param, &values);
                }
            }
            let specs = ["psbs", "srpte", "ps", "las", "mlfq(levels=12,q0=0.02)",
                "cluster(k=2,dispatch=roundrobin,inner=psbs)"];
            for _ in 0..1 + rng.below(3) {
                let spec = specs[rng.below(specs.len() as u64) as usize];
                if rng.below(4) == 0 {
                    sc = sc.policy_as(format!("col{}", rng.below(10)), spec);
                } else {
                    sc = sc.policy_as(PolicySpec::from(spec).to_string(), spec);
                }
            }
            match metric_kind {
                0 => {
                    sc = sc.metric(Metric::PooledEcdf {
                        points: 8 + rng.below(120) as usize,
                        decades: 1.0 + rng.below(4) as f64,
                        tail_above: if rng.below(2) == 0 { Some(10.0) } else { None },
                    });
                }
                1 => {
                    sc = sc.metric(Metric::CondSlowdown { bins: 2 + rng.below(200) as usize });
                }
                2 => {
                    sc = sc.metric(Metric::TailQuantile {
                        p: 0.05 * (1 + rng.below(19)) as f64,
                    });
                }
                3 => {
                    let output = [
                        FaultOutput::Goodput,
                        FaultOutput::WastedWork,
                        FaultOutput::Restarts,
                    ][rng.below(3) as usize];
                    sc = sc.metric(Metric::Fault { output }).with_faults(gen_faults(rng));
                }
                4 => {
                    sc = sc.metric(Metric::SloAttainment {
                        deadline: 0.5 * (1 + rng.below(40)) as f64,
                    });
                }
                5 => {
                    // Dominance REQUIRES a reference.
                    sc = sc
                        .metric(Metric::DominanceVsRef)
                        .vs(if rng.below(2) == 0 { Reference::OptSrpt } else { Reference::Ps });
                }
                _ if rng.below(3) > 0 => {
                    sc = sc.vs(if rng.below(2) == 0 { Reference::OptSrpt } else { Reference::Ps });
                }
                _ => {}
            }
            // Mean scenarios (with or without a reference) may also run
            // under a fault plan: survivor MST, possibly as a ratio
            // against a clean baseline.
            if matches!(sc.metric, Metric::Mean) && rng.below(3) == 0 {
                sc = sc.with_faults(gen_faults(rng));
            }
            if rng.below(4) == 0 {
                sc = sc.reps_override(1 + rng.below(50));
            }
            if rng.below(4) == 0 {
                sc = sc.converge_override(rng.below(2) == 0);
            }
            sc
        }
        property(
            "scenario file round-trip",
            Config { cases: 64, max_size: 3, ..Default::default() },
            |rng, _| gen_scenario(rng),
            |sc| {
                if sc.validate().is_err() {
                    // The generator can pick the same axis param twice;
                    // validate() rejects those before they ever render.
                    return Ok(());
                }
                let rendered = sc.to_toml();
                match Scenario::parse_toml(&rendered) {
                    Ok(p) if p == *sc && p.to_toml() == rendered => Ok(()),
                    Ok(p) => Err(format!("round-trip drift:\n--- in ---\n{rendered}\n--- out ---\n{}", p.to_toml())),
                    Err(e) => Err(format!("`{rendered}` failed to parse: {e}")),
                }
            },
        );
    }

    #[test]
    fn comments_and_spacing_are_tolerated() {
        let text = r#"
            # a scenario with decorations
            name = "decorated"   # trailing comment
            metric = "mean"

            [workload]
            kind = "synthetic"
            njobs = 200          # small

            [[axis]]
            param = "sigma"
            values = [ 0.5 , 1 ]

            [[policy]]
            spec = "psbs"        # the "headline" is quoted elsewhere
        "#;
        let sc = Scenario::parse_toml(text).unwrap();
        assert_eq!(sc.name, "decorated");
        assert_eq!(sc.axes[0].values, vec![0.5, 1.0]);
        match sc.workload {
            WorkloadSpec::Synth(c) => assert_eq!(c.njobs, 200),
            _ => panic!("expected synthetic workload"),
        }
    }

    #[test]
    fn parse_rejects_malformed_files() {
        let base = "name = \"t\"\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n";
        assert!(Scenario::parse_toml(base).is_ok());
        for (what, text) in [
            ("missing name", "metric = \"mean\"\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("missing workload", "name = \"t\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("no policies", "name = \"t\"\n\n[workload]\nkind = \"synthetic\"\n"),
            ("unknown top key", &format!("typo = 1\n{base}")),
            ("unknown section", &format!("{base}\n[wat]\nx = 1\n")),
            ("unknown axis param", &format!("{base}\n[[axis]]\nparam = \"wat\"\nvalues = [1]\n")),
            ("axis without values", &format!("{base}\n[[axis]]\nparam = \"sigma\"\n")),
            ("bad policy spec", "name = \"t\"\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"nope\"\n"),
            ("shape and alpha", "name = \"t\"\n\n[workload]\nkind = \"synthetic\"\nshape = 0.5\nalpha = 2\n\n[[policy]]\nspec = \"ps\"\n"),
            ("trace with shape axis", "name = \"t\"\n\n[workload]\nkind = \"trace\"\ntrace = \"facebook\"\n\n[[axis]]\nparam = \"shape\"\nvalues = [1]\n\n[[policy]]\nspec = \"ps\"\n"),
            ("ecdf with reference", "name = \"t\"\nmetric = \"ecdf\"\nreference = \"ps\"\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("ecdf points on mean", &format!("points = 9\n{base}")),
            ("cond bins on mean", &format!("bins = 9\n{base}")),
            ("ecdf points on cond_slowdown", "name = \"t\"\nmetric = \"cond_slowdown\"\npoints = 9\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("cond bins below 2", "name = \"t\"\nmetric = \"cond_slowdown\"\nbins = 1\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("cond with row axis", "name = \"t\"\nmetric = \"cond_slowdown\"\n\n[workload]\nkind = \"synthetic\"\n\n[[axis]]\nparam = \"sigma\"\nvalues = [1]\n\n[[policy]]\nspec = \"ps\"\n"),
            ("tail_quantile p out of range", "name = \"t\"\nmetric = \"tail_quantile\"\np = 1\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("tail_quantile with reference", "name = \"t\"\nmetric = \"tail_quantile\"\nreference = \"ps\"\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("tail_quantile with row axis", "name = \"t\"\nmetric = \"tail_quantile\"\n\n[workload]\nkind = \"synthetic\"\n\n[[axis]]\nparam = \"sigma\"\nvalues = [1]\n\n[[policy]]\nspec = \"ps\"\n"),
            ("ecdf points on tail_quantile", "name = \"t\"\nmetric = \"tail_quantile\"\npoints = 9\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("quantile p on mean", &format!("p = 0.5\n{base}")),
            ("slo deadline on mean", &format!("deadline = 5\n{base}")),
            ("slo deadline on ecdf", "name = \"t\"\nmetric = \"ecdf\"\ndeadline = 5\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("slo nonpositive deadline", "name = \"t\"\nmetric = \"slo\"\ndeadline = 0\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("slo with reference", "name = \"t\"\nmetric = \"slo\"\nreference = \"ps\"\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("slo with row axis", "name = \"t\"\nmetric = \"slo\"\n\n[workload]\nkind = \"synthetic\"\n\n[[axis]]\nparam = \"sigma\"\nvalues = [1]\n\n[[policy]]\nspec = \"ps\"\n"),
            ("dominance without reference", "name = \"t\"\nmetric = \"dominance\"\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("dominance with deadline", "name = \"t\"\nmetric = \"dominance\"\ndeadline = 5\nreference = \"ps\"\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("dominance with row axis", "name = \"t\"\nmetric = \"dominance\"\nreference = \"ps\"\n\n[workload]\nkind = \"synthetic\"\n\n[[axis]]\nparam = \"sigma\"\nvalues = [1]\n\n[[policy]]\nspec = \"ps\"\n"),
            ("faults with slo metric", "name = \"t\"\nmetric = \"slo\"\n\n[faults]\nmtbf = 10\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("zero reps override", &format!("reps = 0\n{base}")),
            ("non-bool converge", &format!("converge = 3\n{base}")),
            ("trace with both trace and path", "name = \"t\"\n\n[workload]\nkind = \"trace\"\ntrace = \"facebook\"\npath = \"x.csv\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("trace with neither trace nor path", "name = \"t\"\n\n[workload]\nkind = \"trace\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("trace path missing on disk", "name = \"t\"\n\n[workload]\nkind = \"trace\"\npath = \"/nonexistent/psbs_missing.csv\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("fault metric without [faults]", "name = \"t\"\nmetric = \"goodput\"\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("fault metric with reference", "name = \"t\"\nmetric = \"restarts\"\nreference = \"ps\"\n\n[faults]\nmtbf = 10\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("faults with ecdf metric", "name = \"t\"\nmetric = \"ecdf\"\n\n[faults]\nmtbf = 10\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("unknown faults key", "name = \"t\"\n\n[faults]\nmtbf = 10\nwat = 1\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("faults slowdown above 1", "name = \"t\"\n\n[faults]\nmtbf = 10\nslowdown = 1.5\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("faults zero max_attempts", "name = \"t\"\n\n[faults]\nmtbf = 10\nmax_attempts = 0\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("duplicate faults section", "name = \"t\"\n\n[faults]\nmtbf = 10\n\n[faults]\nmtbf = 20\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("ecdf points on goodput", "name = \"t\"\nmetric = \"goodput\"\npoints = 9\n\n[faults]\nmtbf = 10\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("duplicate key", "name = \"t\"\nname = \"u\"\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
            ("garbage line", &format!("{base}\nwat\n")),
            ("empty array element", &format!("{base}\n[[axis]]\nparam = \"sigma\"\nvalues = [0.5,,1]\n")),
            ("trailing array comma", &format!("{base}\n[[axis]]\nparam = \"sigma\"\nvalues = [0.5,]\n")),
            ("unterminated string", "name = \"t\n\n[workload]\nkind = \"synthetic\"\n\n[[policy]]\nspec = \"ps\"\n"),
        ] {
            assert!(Scenario::parse_toml(text).is_err(), "{what} should not parse");
        }
    }

    #[test]
    fn trace_defaults_fill_in() {
        let text = "name = \"t\"\n\n[workload]\nkind = \"trace\"\ntrace = \"ircache\"\n\n[[policy]]\nspec = \"psbs\"\n";
        let sc = Scenario::parse_toml(text).unwrap();
        match sc.workload {
            WorkloadSpec::Trace(t) => {
                assert_eq!(t.source, TraceSource::Builtin(TraceName::Ircache));
                assert_eq!(t.njobs, 206_914);
                assert_eq!(t.load, 0.9);
                assert_eq!(t.sigma, 0.5);
            }
            _ => panic!("expected trace workload"),
        }
    }
}
