//! # PSBS: Practical Size-Based Scheduling — reproduction library
//!
//! Full reproduction of Dell'Amico, Carra & Michiardi, *"PSBS:
//! Practical Size-Based Scheduling"* (2014): the PSBS scheduler
//! (an O(log n), weight-aware, error-robust generalization of FSP),
//! the complete zoo of disciplines it is evaluated against, a fast
//! discrete-event simulator, workload synthesis and trace replay, an
//! online scheduling service, and a benchmark harness regenerating
//! every figure of the paper's evaluation.
//!
//! Architecture (three layers; see DESIGN.md):
//! * **rust coordinator** (this crate) — schedulers, simulator,
//!   service, figures;
//! * **JAX graphs / Pallas kernels** (`python/compile`) — workload
//!   synthesis and metric analytics, AOT-compiled to HLO text;
//! * **PJRT runtime** ([`runtime`]) — loads and executes the artifacts
//!   from the rust hot path. Python never runs at simulation time.
//!
//! Quick start:
//! ```no_run
//! use psbs::{sched, sim, workload};
//!
//! let cfg = workload::SynthConfig::default();          // Table 1 defaults
//! let jobs = workload::synthesize(&cfg, 42);           // seeded workload
//! let mut psbs = sched::psbs::Psbs::new();
//! let res = sim::run(&mut psbs, &jobs);
//! println!("MST = {}", res.mst(&jobs));
//! ```

pub mod coordinator;
pub mod error;
pub mod estimate;
pub mod figures;
pub mod metrics;
pub mod runtime;
pub mod scenario;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod stats;
pub mod util;
pub mod workload;

pub use error::Error;

/// The stable library surface — what `psbs serve` (and any embedder)
/// builds on.
///
/// The crate is organized as a scheduling *library* with two
/// frontends: the offline simulator (`psbs sweep`/`replay`) and the
/// live service (`psbs serve`).  Both drive the same engine through
/// the names re-exported here:
///
/// * [`Scheduler`](crate::sim::Scheduler) + the policy zoo behind
///   [`PolicySpec`](crate::scenario::PolicySpec) /
///   [`by_name`](crate::sched::by_name);
/// * [`JobSource`](crate::sim::JobSource) /
///   [`CompletionSink`](crate::sim::CompletionSink) feeding
///   [`run_streaming`](crate::sim::run_streaming) (virtual time) or
///   [`run_streaming_clocked`](crate::sim::run_streaming_clocked)
///   (any [`Clock`](crate::sim::Clock));
/// * [`OnlineMetrics`](crate::metrics::OnlineMetrics) for O(1)-memory
///   result aggregation.
///
/// **Bit-identity invariant:** the simulation entry points are pinned
/// bit-identical across refactors — `run_streaming` monomorphized
/// over [`VirtualClock`](crate::sim::VirtualClock) reproduces the
/// pre-clock engine exactly (`rust/tests/streaming.rs`, all 16
/// policies, fault churn included), so results obtained through this
/// prelude are reproducible across crate versions to the last ulp.
/// Anything *not* re-exported here (planner internals, figure
/// plumbing) is subject to change without notice.
pub mod prelude {
    pub use crate::error::Error;
    pub use crate::metrics::OnlineMetrics;
    pub use crate::scenario::PolicySpec;
    pub use crate::sched::by_name;
    pub use crate::sim::{
        run_streaming, run_streaming_clocked, run_streaming_to_drain, Clock, Completion,
        CompletionSink, Job, JobSource, JobStore, Scheduler, StreamStats, VirtualClock, WallClock,
    };
}
