//! # PSBS: Practical Size-Based Scheduling — reproduction library
//!
//! Full reproduction of Dell'Amico, Carra & Michiardi, *"PSBS:
//! Practical Size-Based Scheduling"* (2014): the PSBS scheduler
//! (an O(log n), weight-aware, error-robust generalization of FSP),
//! the complete zoo of disciplines it is evaluated against, a fast
//! discrete-event simulator, workload synthesis and trace replay, an
//! online scheduling service, and a benchmark harness regenerating
//! every figure of the paper's evaluation.
//!
//! Architecture (three layers; see DESIGN.md):
//! * **rust coordinator** (this crate) — schedulers, simulator,
//!   service, figures;
//! * **JAX graphs / Pallas kernels** (`python/compile`) — workload
//!   synthesis and metric analytics, AOT-compiled to HLO text;
//! * **PJRT runtime** ([`runtime`]) — loads and executes the artifacts
//!   from the rust hot path. Python never runs at simulation time.
//!
//! Quick start:
//! ```no_run
//! use psbs::{sched, sim, workload};
//!
//! let cfg = workload::SynthConfig::default();          // Table 1 defaults
//! let jobs = workload::synthesize(&cfg, 42);           // seeded workload
//! let mut psbs = sched::psbs::Psbs::new();
//! let res = sim::run(&mut psbs, &jobs);
//! println!("MST = {}", res.mst(&jobs));
//! ```

pub mod coordinator;
pub mod estimate;
pub mod figures;
pub mod metrics;
pub mod runtime;
pub mod scenario;
pub mod sched;
pub mod sim;
pub mod stats;
pub mod util;
pub mod workload;
