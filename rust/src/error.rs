//! One public error type for the library surface.
//!
//! Until PR 9 every fallible boundary in the crate returned
//! `Result<_, String>` — cheap to write, but callers could not tell a
//! malformed trace row from a corrupt binary cache from a scenario
//! typo without string-sniffing, and the CLI could only ever exit 1.
//! [`Error`] replaces that plumbing with one enum whose variants carry
//! structured context (path, 1-based line number) and whose
//! [`Display`](std::fmt::Display) impl reproduces the pre-enum message
//! text **byte-identically** — every test that pinned an error string
//! still passes against `err.to_string()`.
//!
//! Interop with the old plumbing is deliberate: `From<String>` /
//! `From<&str>` lift legacy errors into [`Error::Msg`] (so `?` keeps
//! working in code that still formats ad-hoc strings), and
//! `From<Error> for String` renders back down (so crate-internal
//! helpers that still pass `Result<_, String>` can call converted
//! APIs with `?` unchanged).
//!
//! The CLI maps variants to distinct exit codes via
//! [`Error::exit_code`]; exit 2 stays reserved for argument-parse /
//! usage errors (see `main.rs`).

use std::fmt;

/// The crate-wide error type.  See the module docs for the Display
/// and exit-code contracts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    /// A CSV trace could not be parsed ([`crate::workload::trace_file`]).
    Trace {
        /// Source path, when the trace came from a file (streamed
        /// chunked reads and in-memory `parse` leave it `None`).
        path: Option<String>,
        /// 1-based line number of the offending row, when known.
        line: Option<u64>,
        /// The message body (everything after the `path:`/`line N:`
        /// prefixes that `Display` re-attaches).
        msg: String,
    },
    /// A binary trace cache (`.psbt`) failed validation
    /// ([`crate::workload::cache`]).
    Cache {
        /// Cache path, when the message is path-prefixed.
        path: Option<String>,
        msg: String,
    },
    /// A scenario file failed to parse or validate
    /// ([`crate::scenario`]).
    Scenario {
        path: Option<String>,
        /// 1-based line number in the scenario TOML, when known.
        line: Option<u64>,
        msg: String,
    },
    /// A `psbs serve` wire-protocol request was malformed
    /// ([`crate::serve`]).
    Protocol {
        /// 1-based input line number on the session stream, when known.
        line: Option<u64>,
        msg: String,
    },
    /// Uncategorized error (legacy `String` plumbing lifts to this).
    Msg(String),
}

impl Error {
    /// Trace error with no location context.
    pub fn trace(msg: impl Into<String>) -> Error {
        Error::Trace { path: None, line: None, msg: msg.into() }
    }

    /// Trace error pinned to a 1-based line number.
    pub fn trace_line(line: u64, msg: impl Into<String>) -> Error {
        Error::Trace { path: None, line: Some(line), msg: msg.into() }
    }

    /// Cache error with no path context.
    pub fn cache(msg: impl Into<String>) -> Error {
        Error::Cache { path: None, msg: msg.into() }
    }

    /// Cache error prefixed with its path.
    pub fn cache_at(path: impl Into<String>, msg: impl Into<String>) -> Error {
        Error::Cache { path: Some(path.into()), msg: msg.into() }
    }

    /// Scenario error with no location context.
    pub fn scenario(msg: impl Into<String>) -> Error {
        Error::Scenario { path: None, line: None, msg: msg.into() }
    }

    /// Protocol error pinned to a 1-based session input line.
    pub fn protocol_line(line: u64, msg: impl Into<String>) -> Error {
        Error::Protocol { line: Some(line), msg: msg.into() }
    }

    /// Uncategorized error.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error::Msg(msg.into())
    }

    /// Attach a source path to an error that does not carry one yet.
    ///
    /// Structured variants whose `path` is `None` gain it (so Display
    /// grows the `"{path}: "` prefix the old `format!("{path}: {e}")`
    /// wraps produced); variants that already carry a path are
    /// returned unchanged (the old wraps double-prefixed here — not a
    /// pinned behavior, so the enum fixes it).  [`Error::Msg`] is
    /// prefixed textually, exactly like the legacy wrap.
    #[must_use]
    pub fn with_path(self, path: &str) -> Error {
        match self {
            Error::Trace { path: None, line, msg } => {
                Error::Trace { path: Some(path.to_string()), line, msg }
            }
            Error::Cache { path: None, msg } => Error::Cache { path: Some(path.to_string()), msg },
            Error::Scenario { path: None, line, msg } => {
                Error::Scenario { path: Some(path.to_string()), line, msg }
            }
            Error::Msg(m) => Error::Msg(format!("{path}: {m}")),
            other => other,
        }
    }

    /// Process exit code for the CLI: 1 for uncategorized errors, a
    /// distinct code per structured variant.  2 is *not* produced here
    /// — it stays reserved for argument-parse/usage errors.
    pub fn exit_code(&self) -> i32 {
        match self {
            Error::Msg(_) => 1,
            Error::Trace { .. } => 3,
            Error::Cache { .. } => 4,
            Error::Scenario { .. } => 5,
            Error::Protocol { .. } => 6,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Trace { path, line, msg } | Error::Scenario { path, line, msg } => {
                if let Some(p) = path {
                    write!(f, "{p}: ")?;
                }
                if let Some(ln) = line {
                    write!(f, "line {ln}: ")?;
                }
                f.write_str(msg)
            }
            Error::Cache { path, msg } => {
                if let Some(p) = path {
                    write!(f, "{p}: ")?;
                }
                f.write_str(msg)
            }
            Error::Protocol { line, msg } => {
                if let Some(ln) = line {
                    write!(f, "line {ln}: ")?;
                }
                f.write_str(msg)
            }
            Error::Msg(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error::Msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error::Msg(s.to_string())
    }
}

impl From<Error> for String {
    fn from(e: Error) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_reassembles_prefixes() {
        let e = Error::trace_line(4, "job size must be positive, got 0");
        assert_eq!(e.to_string(), "line 4: job size must be positive, got 0");
        let e = e.with_path("t.csv");
        assert_eq!(e.to_string(), "t.csv: line 4: job size must be positive, got 0");
        // A second with_path is a no-op on structured variants.
        assert_eq!(e.clone().with_path("other"), e);
    }

    #[test]
    fn msg_round_trips_through_string() {
        let e: Error = format!("ad hoc {}", 7).into();
        assert_eq!(e, Error::Msg("ad hoc 7".to_string()));
        let s: String = e.into();
        assert_eq!(s, "ad hoc 7");
    }

    #[test]
    fn with_path_on_msg_matches_legacy_wrap() {
        let e = Error::msg("trace replays zero rows").with_path("mem");
        assert_eq!(e.to_string(), "mem: trace replays zero rows");
    }

    #[test]
    fn exit_codes_are_distinct_and_skip_2() {
        let codes = [
            Error::msg("x").exit_code(),
            Error::trace("x").exit_code(),
            Error::cache("x").exit_code(),
            Error::scenario("x").exit_code(),
            Error::protocol_line(1, "x").exit_code(),
        ];
        for (i, a) in codes.iter().enumerate() {
            assert_ne!(*a, 2, "2 is reserved for usage errors");
            for b in &codes[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn cache_display_is_path_colon_msg() {
        let e = Error::cache_at("/tmp/x.psbt", "truncated trace cache: 10 records promised, 3 present");
        assert_eq!(e.to_string(), "/tmp/x.psbt: truncated trace cache: 10 records promised, 3 present");
    }
}
