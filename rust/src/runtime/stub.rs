//! Stub runtime for builds without the `xla` feature (the default in
//! the offline environment): same API surface as the PJRT-backed
//! implementation, but artifacts can never load — `try_default` is
//! always `None`, so every caller takes its pure-rust fallback path.
//! Method bodies are unreachable in practice (no constructor
//! succeeds); they return errors rather than panicking so misuse is
//! diagnosable.

use super::{rt_err, AnalyticsOut, Manifest, Result};
use crate::util::rng::Rng;
use std::path::{Path, PathBuf};

/// API-compatible stand-in for the PJRT runtime.
pub struct Runtime {
    pub manifest: Manifest,
}

const NO_XLA: &str =
    "built without the `xla` feature: PJRT artifacts cannot be loaded (pure-rust fallback applies)";

impl Runtime {
    /// Always fails: this build has no PJRT support.
    pub fn load(_dir: &Path) -> Result<Runtime> {
        Err(rt_err(NO_XLA))
    }

    /// Artifacts directory: `$PSBS_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        super::default_artifacts_dir()
    }

    /// `None`, always — with a notice when artifacts exist on disk but
    /// this build cannot execute them.
    pub fn try_default() -> Option<Runtime> {
        if Self::default_dir().join("manifest.txt").exists() {
            eprintln!("warning: artifacts present but {NO_XLA}");
        }
        None
    }

    pub fn gen_batch(
        &self,
        _u_size: &[f32],
        _u_a: &[f32],
        _u_b: &[f32],
        _params: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        Err(rt_err(NO_XLA))
    }

    pub fn gen_weibull_lognormal(
        &self,
        _rng: &mut Rng,
        _n: usize,
        _shape: f64,
        _scale: f64,
        _sigma: f64,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        Err(rt_err(NO_XLA))
    }

    pub fn gen_pareto_lognormal(
        &self,
        _rng: &mut Rng,
        _n: usize,
        _alpha: f64,
        _xm: f64,
        _sigma: f64,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        Err(rt_err(NO_XLA))
    }

    pub fn analyze(
        &self,
        _sizes: &[f64],
        _sojourns: &[f64],
        _bin_idx: &[i32],
        _thresholds: &[f64],
    ) -> Result<AnalyticsOut> {
        Err(rt_err(NO_XLA))
    }
}
