//! PJRT runtime: load and execute the AOT artifacts from rust.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client): HLO **text** →
//! `HloModuleProto::from_text_file` → compile → execute.  Python never
//! runs here — `make artifacts` produced the HLO once at build time
//! (see python/compile/aot.py and /opt/xla-example/README.md for why
//! text, not serialized protos, is the interchange format).
//!
//! Two typed façades cover the two artifacts:
//! * [`Runtime::gen_batch`] — the `workload` graph: uniforms →
//!   Weibull samples + log-normal error multipliers;
//! * [`Runtime::analyze`] — the `analytics` graph: per-job metrics →
//!   slowdowns, conditional-slowdown class sums, ECDF counts, MST.
//!
//! Populations larger than the AOT batch are chunked and the (linear)
//! aggregates summed — exactness of that aggregation is tested on the
//! python side (`test_analytics_graph_mst_and_chunk_linearity`) and
//! cross-checked against the pure-rust metrics in
//! `rust/tests/integration.rs`.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub batch: usize,
    pub num_params: usize,
    pub num_bins: usize,
    pub num_thresholds: usize,
    pub workload_file: String,
    pub analytics_file: String,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let kv: HashMap<&str, &str> = text
            .lines()
            .filter_map(|l| l.split_once('='))
            .collect();
        let get = |k: &str| kv.get(k).copied().ok_or_else(|| anyhow!("manifest missing key {k}"));
        Ok(Manifest {
            batch: get("batch")?.parse().context("batch")?,
            num_params: get("num_params")?.parse().context("num_params")?,
            num_bins: get("num_bins")?.parse().context("num_bins")?,
            num_thresholds: get("num_thresholds")?.parse().context("num_thresholds")?,
            workload_file: get("workload")?.to_string(),
            analytics_file: get("analytics")?.to_string(),
        })
    }
}

/// Aggregated outputs of the analytics artifact over a job population.
#[derive(Debug, Clone)]
pub struct AnalyticsOut {
    /// Per-job slowdowns (population order).
    pub slowdowns: Vec<f64>,
    /// Per-class slowdown sums (len = manifest.num_bins).
    pub bin_sums: Vec<f64>,
    /// Per-class job counts.
    pub bin_counts: Vec<f64>,
    /// ECDF counts per threshold.
    pub ecdf_counts: Vec<f64>,
    /// Σ sojourn and job count (MST = sojourn_sum / count).
    pub sojourn_sum: f64,
    pub count: f64,
}

impl AnalyticsOut {
    pub fn mst(&self) -> f64 {
        self.sojourn_sum / self.count.max(1.0)
    }

    /// Mean conditional slowdown per class (skipping empty classes).
    pub fn conditional_slowdown(&self) -> Vec<f64> {
        self.bin_sums
            .iter()
            .zip(&self.bin_counts)
            .filter(|(_, c)| **c > 0.0)
            .map(|(s, c)| s / c)
            .collect()
    }
}

/// Loaded PJRT executables + manifest.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    pub manifest: Manifest,
    workload: xla::PjRtLoadedExecutable,
    analytics: xla::PjRtLoadedExecutable,
}

impl Runtime {
    /// Load artifacts from `dir` (compiles the HLO on the CPU client).
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest_text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let manifest = Manifest::parse(&manifest_text)?;
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(wrap)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(wrap)
        };
        let workload = compile(&manifest.workload_file)?;
        let analytics = compile(&manifest.analytics_file)?;
        Ok(Runtime { client, manifest, workload, analytics })
    }

    /// Artifacts directory: `$PSBS_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("PSBS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load from the default directory; `None` if artifacts are absent
    /// (callers fall back to the pure-rust paths).
    pub fn try_default() -> Option<Runtime> {
        let dir = Self::default_dir();
        if dir.join("manifest.txt").exists() {
            match Self::load(&dir) {
                Ok(rt) => Some(rt),
                Err(e) => {
                    eprintln!("warning: artifacts present but unloadable: {e:#}");
                    None
                }
            }
        } else {
            None
        }
    }

    /// Execute the workload graph on one batch of uniforms.
    ///
    /// `params = [weibull_shape, weibull_scale, sigma, 0]` (the
    /// PARAMS_LAYOUT of python/compile/model.py). Returns
    /// (weibull samples, log-normal error multipliers).
    pub fn gen_batch(
        &self,
        u_size: &[f32],
        u_a: &[f32],
        u_b: &[f32],
        params: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let b = self.manifest.batch;
        anyhow::ensure!(
            u_size.len() == b && u_a.len() == b && u_b.len() == b,
            "uniform inputs must have the AOT batch length {b}"
        );
        anyhow::ensure!(params.len() == self.manifest.num_params, "params length");
        let ins = [
            xla::Literal::vec1(u_size),
            xla::Literal::vec1(u_a),
            xla::Literal::vec1(u_b),
            xla::Literal::vec1(params),
        ];
        let result = self.workload.execute::<xla::Literal>(&ins).map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        let outs = result.to_tuple().map_err(wrap)?;
        anyhow::ensure!(outs.len() == 2, "workload graph must return 2 outputs");
        let samples = outs[0].to_vec::<f32>().map_err(wrap)?;
        let mults = outs[1].to_vec::<f32>().map_err(wrap)?;
        Ok((samples, mults))
    }

    /// Generate `n` Weibull(shape, scale) samples and log-normal(sigma)
    /// multipliers, chunking over the AOT batch. The uniforms come from
    /// the caller's deterministic stream.
    pub fn gen_weibull_lognormal(
        &self,
        rng: &mut crate::util::rng::Rng,
        n: usize,
        shape: f64,
        scale: f64,
        sigma: f64,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let b = self.manifest.batch;
        let params = [shape as f32, scale as f32, sigma as f32, 0.0];
        let mut samples = Vec::with_capacity(n);
        let mut mults = Vec::with_capacity(n);
        let mut u1 = vec![0f32; b];
        let mut u2 = vec![0f32; b];
        let mut u3 = vec![0f32; b];
        let mut produced = 0;
        while produced < n {
            for i in 0..b {
                u1[i] = rng.u01() as f32;
                u2[i] = rng.u01() as f32;
                u3[i] = rng.u01() as f32;
            }
            let (s, m) = self.gen_batch(&u1, &u2, &u3, &params)?;
            let take = (n - produced).min(b);
            samples.extend(s[..take].iter().map(|&x| x as f64));
            mults.extend(m[..take].iter().map(|&x| x as f64));
            produced += take;
        }
        Ok((samples, mults))
    }

    /// Generate `n` Pareto(alpha, xm) samples (plus log-normal(sigma)
    /// multipliers) through the same artifact — `params[3] = 1` selects
    /// the Pareto inverse CDF (Fig. 10 workloads).
    pub fn gen_pareto_lognormal(
        &self,
        rng: &mut crate::util::rng::Rng,
        n: usize,
        alpha: f64,
        xm: f64,
        sigma: f64,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let b = self.manifest.batch;
        let params = [alpha as f32, xm as f32, sigma as f32, 1.0];
        let mut samples = Vec::with_capacity(n);
        let mut mults = Vec::with_capacity(n);
        let mut u1 = vec![0f32; b];
        let mut u2 = vec![0f32; b];
        let mut u3 = vec![0f32; b];
        let mut produced = 0;
        while produced < n {
            for i in 0..b {
                u1[i] = rng.u01() as f32;
                u2[i] = rng.u01() as f32;
                u3[i] = rng.u01() as f32;
            }
            let (s, m) = self.gen_batch(&u1, &u2, &u3, &params)?;
            let take = (n - produced).min(b);
            samples.extend(s[..take].iter().map(|&x| x as f64));
            mults.extend(m[..take].iter().map(|&x| x as f64));
            produced += take;
        }
        Ok((samples, mults))
    }

    /// Execute the analytics graph over a full population, chunking and
    /// summing the linear aggregates.
    ///
    /// `bin_idx` uses `manifest.num_bins` as the "no class" tag for any
    /// padding the chunking introduces.
    pub fn analyze(
        &self,
        sizes: &[f64],
        sojourns: &[f64],
        bin_idx: &[i32],
        thresholds: &[f64],
    ) -> Result<AnalyticsOut> {
        let n = sizes.len();
        anyhow::ensure!(sojourns.len() == n && bin_idx.len() == n, "input lengths");
        anyhow::ensure!(
            thresholds.len() == self.manifest.num_thresholds,
            "thresholds must have length {}",
            self.manifest.num_thresholds
        );
        let b = self.manifest.batch;
        let thr: Vec<f32> = thresholds.iter().map(|&t| t as f32).collect();

        let mut out = AnalyticsOut {
            slowdowns: Vec::with_capacity(n),
            bin_sums: vec![0.0; self.manifest.num_bins],
            bin_counts: vec![0.0; self.manifest.num_bins],
            ecdf_counts: vec![0.0; self.manifest.num_thresholds],
            sojourn_sum: 0.0,
            count: 0.0,
        };

        let mut szs = vec![0f32; b];
        let mut soj = vec![0f32; b];
        let mut mask = vec![0f32; b];
        let mut idx = vec![0i32; b];
        let mut start = 0;
        while start < n {
            let take = (n - start).min(b);
            for i in 0..b {
                if i < take {
                    szs[i] = sizes[start + i] as f32;
                    soj[i] = sojourns[start + i] as f32;
                    mask[i] = 1.0;
                    idx[i] = bin_idx[start + i];
                } else {
                    szs[i] = 0.0;
                    soj[i] = 0.0;
                    mask[i] = 0.0;
                    idx[i] = self.manifest.num_bins as i32;
                }
            }
            let ins = [
                xla::Literal::vec1(&szs[..]),
                xla::Literal::vec1(&soj[..]),
                xla::Literal::vec1(&mask[..]),
                xla::Literal::vec1(&idx[..]),
                xla::Literal::vec1(&thr[..]),
            ];
            let result = self.analytics.execute::<xla::Literal>(&ins).map_err(wrap)?[0][0]
                .to_literal_sync()
                .map_err(wrap)?;
            let outs = result.to_tuple().map_err(wrap)?;
            anyhow::ensure!(outs.len() == 6, "analytics graph must return 6 outputs");
            let slow = outs[0].to_vec::<f32>().map_err(wrap)?;
            out.slowdowns.extend(slow[..take].iter().map(|&x| x as f64));
            for (acc, v) in out.bin_sums.iter_mut().zip(outs[1].to_vec::<f32>().map_err(wrap)?) {
                *acc += v as f64;
            }
            for (acc, v) in out.bin_counts.iter_mut().zip(outs[2].to_vec::<f32>().map_err(wrap)?) {
                *acc += v as f64;
            }
            for (acc, v) in out.ecdf_counts.iter_mut().zip(outs[3].to_vec::<f32>().map_err(wrap)?) {
                *acc += v as f64;
            }
            out.sojourn_sum += outs[4].to_vec::<f32>().map_err(wrap)?[0] as f64;
            out.count += outs[5].to_vec::<f32>().map_err(wrap)?[0] as f64;
            start += take;
        }
        Ok(out)
    }
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("{e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(
            "batch=32768\nnum_params=4\nnum_bins=128\nnum_thresholds=128\n\
             workload=workload.hlo.txt\nanalytics=analytics.hlo.txt\n",
        )
        .unwrap();
        assert_eq!(m.batch, 32768);
        assert_eq!(m.num_bins, 128);
        assert_eq!(m.workload_file, "workload.hlo.txt");
    }

    #[test]
    fn manifest_missing_key_is_error() {
        assert!(Manifest::parse("batch=4\n").is_err());
    }
}
