//! PJRT runtime: load and execute the AOT artifacts from rust.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client): HLO **text** →
//! `HloModuleProto::from_text_file` → compile → execute.  Python never
//! runs here — `make artifacts` produced the HLO once at build time
//! (see python/compile/aot.py and /opt/xla-example/README.md for why
//! text, not serialized protos, is the interchange format).
//!
//! Two typed façades cover the two artifacts:
//! * [`Runtime::gen_batch`] — the `workload` graph: uniforms →
//!   Weibull samples + log-normal error multipliers;
//! * [`Runtime::analyze`] — the `analytics` graph: per-job metrics →
//!   slowdowns, conditional-slowdown class sums, ECDF counts, MST.
//!
//! Populations larger than the AOT batch are chunked and the (linear)
//! aggregates summed — exactness of that aggregation is tested on the
//! python side (`test_analytics_graph_mst_and_chunk_linearity`) and
//! cross-checked against the pure-rust metrics in
//! `rust/tests/integration.rs`.
//!
//! ## Feature gating
//!
//! The `xla` crate (and everything else beyond std) is unavailable in
//! the offline build environment, so the PJRT-backed implementation is
//! gated behind the `xla` cargo feature (see Cargo.toml).  Without it,
//! a stub [`Runtime`] with the identical API is compiled whose
//! `try_default` is always `None` — every caller (figures, benches,
//! integration tests, examples) then takes its pure-rust fallback
//! path, which is the behavior a fresh checkout had anyway when
//! `artifacts/` was absent.

use std::collections::HashMap;
use std::fmt;

/// Minimal error type for artifact loading/execution (replaces the
/// unavailable `anyhow`; DESIGN.md §4 Substitutions).
#[derive(Debug)]
pub struct RtError(pub String);

impl fmt::Display for RtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RtError {}

/// Result alias used across the runtime façade.
pub type Result<T> = std::result::Result<T, RtError>;

pub(crate) fn rt_err(msg: impl Into<String>) -> RtError {
    RtError(msg.into())
}

/// Artifacts directory: `$PSBS_ARTIFACTS` or `./artifacts` — shared
/// by the PJRT and stub builds so discovery can never drift between
/// them.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var("PSBS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub batch: usize,
    pub num_params: usize,
    pub num_bins: usize,
    pub num_thresholds: usize,
    pub workload_file: String,
    pub analytics_file: String,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let kv: HashMap<&str, &str> = text
            .lines()
            .filter_map(|l| l.split_once('='))
            .collect();
        let get = |k: &str| -> Result<&str> {
            kv.get(k).copied().ok_or_else(|| rt_err(format!("manifest missing key {k}")))
        };
        let num = |k: &str| -> Result<usize> {
            get(k)?.parse().map_err(|_| rt_err(format!("manifest key {k}: not an integer")))
        };
        Ok(Manifest {
            batch: num("batch")?,
            num_params: num("num_params")?,
            num_bins: num("num_bins")?,
            num_thresholds: num("num_thresholds")?,
            workload_file: get("workload")?.to_string(),
            analytics_file: get("analytics")?.to_string(),
        })
    }
}

/// Aggregated outputs of the analytics artifact over a job population.
#[derive(Debug, Clone)]
pub struct AnalyticsOut {
    /// Per-job slowdowns (population order).
    pub slowdowns: Vec<f64>,
    /// Per-class slowdown sums (len = manifest.num_bins).
    pub bin_sums: Vec<f64>,
    /// Per-class job counts.
    pub bin_counts: Vec<f64>,
    /// ECDF counts per threshold.
    pub ecdf_counts: Vec<f64>,
    /// Σ sojourn and job count (MST = sojourn_sum / count).
    pub sojourn_sum: f64,
    pub count: f64,
}

impl AnalyticsOut {
    pub fn mst(&self) -> f64 {
        self.sojourn_sum / self.count.max(1.0)
    }

    /// Mean conditional slowdown per class (skipping empty classes).
    pub fn conditional_slowdown(&self) -> Vec<f64> {
        self.bin_sums
            .iter()
            .zip(&self.bin_counts)
            .filter(|(_, c)| **c > 0.0)
            .map(|(s, c)| s / c)
            .collect()
    }
}

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::Runtime;

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::Runtime;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(
            "batch=32768\nnum_params=4\nnum_bins=128\nnum_thresholds=128\n\
             workload=workload.hlo.txt\nanalytics=analytics.hlo.txt\n",
        )
        .unwrap();
        assert_eq!(m.batch, 32768);
        assert_eq!(m.num_bins, 128);
        assert_eq!(m.workload_file, "workload.hlo.txt");
    }

    #[test]
    fn manifest_missing_key_is_error() {
        assert!(Manifest::parse("batch=4\n").is_err());
    }

    #[test]
    fn manifest_bad_number_is_error() {
        let e = Manifest::parse(
            "batch=many\nnum_params=4\nnum_bins=128\nnum_thresholds=128\n\
             workload=w\nanalytics=a\n",
        );
        assert!(e.is_err());
        assert!(format!("{:#}", e.unwrap_err()).contains("batch"));
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_never_loads() {
        // Without the xla feature the runtime must gracefully report
        // absence so callers use the pure-rust fallback.
        assert!(Runtime::load(std::path::Path::new("/nonexistent")).is_err());
    }
}
