//! PJRT-backed runtime (the real implementation), compiled only with
//! the `xla` cargo feature — it needs the vendored `xla` crate, which
//! the offline environment does not ship.  Error plumbing uses the
//! module-local [`RtError`](super::RtError) so no `anyhow` is needed.

use super::{rt_err, AnalyticsOut, Manifest, Result};
use std::path::{Path, PathBuf};

/// Loaded PJRT executables + manifest.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    pub manifest: Manifest,
    workload: xla::PjRtLoadedExecutable,
    analytics: xla::PjRtLoadedExecutable,
}

fn wrap(e: xla::Error) -> super::RtError {
    rt_err(format!("{e}"))
}

impl Runtime {
    /// Load artifacts from `dir` (compiles the HLO on the CPU client).
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest_text = std::fs::read_to_string(dir.join("manifest.txt"))
            .map_err(|e| rt_err(format!("reading manifest in {}: {e}", dir.display())))?;
        let manifest = Manifest::parse(&manifest_text)?;
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| rt_err("non-utf8 path"))?,
            )
            .map_err(wrap)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(wrap)
        };
        let workload = compile(&manifest.workload_file)?;
        let analytics = compile(&manifest.analytics_file)?;
        Ok(Runtime { client, manifest, workload, analytics })
    }

    /// Artifacts directory: `$PSBS_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        super::default_artifacts_dir()
    }

    /// Load from the default directory; `None` if artifacts are absent
    /// (callers fall back to the pure-rust paths).
    pub fn try_default() -> Option<Runtime> {
        let dir = Self::default_dir();
        if dir.join("manifest.txt").exists() {
            match Self::load(&dir) {
                Ok(rt) => Some(rt),
                Err(e) => {
                    eprintln!("warning: artifacts present but unloadable: {e:#}");
                    None
                }
            }
        } else {
            None
        }
    }

    /// Execute the workload graph on one batch of uniforms.
    ///
    /// `params = [weibull_shape, weibull_scale, sigma, 0]` (the
    /// PARAMS_LAYOUT of python/compile/model.py). Returns
    /// (weibull samples, log-normal error multipliers).
    pub fn gen_batch(
        &self,
        u_size: &[f32],
        u_a: &[f32],
        u_b: &[f32],
        params: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let b = self.manifest.batch;
        if !(u_size.len() == b && u_a.len() == b && u_b.len() == b) {
            return Err(rt_err(format!("uniform inputs must have the AOT batch length {b}")));
        }
        if params.len() != self.manifest.num_params {
            return Err(rt_err("params length"));
        }
        let ins = [
            xla::Literal::vec1(u_size),
            xla::Literal::vec1(u_a),
            xla::Literal::vec1(u_b),
            xla::Literal::vec1(params),
        ];
        let result = self.workload.execute::<xla::Literal>(&ins).map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        let outs = result.to_tuple().map_err(wrap)?;
        if outs.len() != 2 {
            return Err(rt_err("workload graph must return 2 outputs"));
        }
        let samples = outs[0].to_vec::<f32>().map_err(wrap)?;
        let mults = outs[1].to_vec::<f32>().map_err(wrap)?;
        Ok((samples, mults))
    }

    /// Generate `n` Weibull(shape, scale) samples and log-normal(sigma)
    /// multipliers, chunking over the AOT batch. The uniforms come from
    /// the caller's deterministic stream.
    pub fn gen_weibull_lognormal(
        &self,
        rng: &mut crate::util::rng::Rng,
        n: usize,
        shape: f64,
        scale: f64,
        sigma: f64,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let params = [shape as f32, scale as f32, sigma as f32, 0.0];
        self.gen_chunked(rng, n, params)
    }

    /// Generate `n` Pareto(alpha, xm) samples (plus log-normal(sigma)
    /// multipliers) through the same artifact — `params[3] = 1` selects
    /// the Pareto inverse CDF (Fig. 10 workloads).
    pub fn gen_pareto_lognormal(
        &self,
        rng: &mut crate::util::rng::Rng,
        n: usize,
        alpha: f64,
        xm: f64,
        sigma: f64,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let params = [alpha as f32, xm as f32, sigma as f32, 1.0];
        self.gen_chunked(rng, n, params)
    }

    /// Shared chunking loop of the two generators.
    fn gen_chunked(
        &self,
        rng: &mut crate::util::rng::Rng,
        n: usize,
        params: [f32; 4],
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let b = self.manifest.batch;
        let mut samples = Vec::with_capacity(n);
        let mut mults = Vec::with_capacity(n);
        let mut u1 = vec![0f32; b];
        let mut u2 = vec![0f32; b];
        let mut u3 = vec![0f32; b];
        let mut produced = 0;
        while produced < n {
            for i in 0..b {
                u1[i] = rng.u01() as f32;
                u2[i] = rng.u01() as f32;
                u3[i] = rng.u01() as f32;
            }
            let (s, m) = self.gen_batch(&u1, &u2, &u3, &params)?;
            let take = (n - produced).min(b);
            samples.extend(s[..take].iter().map(|&x| x as f64));
            mults.extend(m[..take].iter().map(|&x| x as f64));
            produced += take;
        }
        Ok((samples, mults))
    }

    /// Execute the analytics graph over a full population, chunking and
    /// summing the linear aggregates.
    ///
    /// `bin_idx` uses `manifest.num_bins` as the "no class" tag for any
    /// padding the chunking introduces.
    pub fn analyze(
        &self,
        sizes: &[f64],
        sojourns: &[f64],
        bin_idx: &[i32],
        thresholds: &[f64],
    ) -> Result<AnalyticsOut> {
        let n = sizes.len();
        if !(sojourns.len() == n && bin_idx.len() == n) {
            return Err(rt_err("input lengths"));
        }
        if thresholds.len() != self.manifest.num_thresholds {
            return Err(rt_err(format!(
                "thresholds must have length {}",
                self.manifest.num_thresholds
            )));
        }
        let b = self.manifest.batch;
        let thr: Vec<f32> = thresholds.iter().map(|&t| t as f32).collect();

        let mut out = AnalyticsOut {
            slowdowns: Vec::with_capacity(n),
            bin_sums: vec![0.0; self.manifest.num_bins],
            bin_counts: vec![0.0; self.manifest.num_bins],
            ecdf_counts: vec![0.0; self.manifest.num_thresholds],
            sojourn_sum: 0.0,
            count: 0.0,
        };

        let mut szs = vec![0f32; b];
        let mut soj = vec![0f32; b];
        let mut mask = vec![0f32; b];
        let mut idx = vec![0i32; b];
        let mut start = 0;
        while start < n {
            let take = (n - start).min(b);
            for i in 0..b {
                if i < take {
                    szs[i] = sizes[start + i] as f32;
                    soj[i] = sojourns[start + i] as f32;
                    mask[i] = 1.0;
                    idx[i] = bin_idx[start + i];
                } else {
                    szs[i] = 0.0;
                    soj[i] = 0.0;
                    mask[i] = 0.0;
                    idx[i] = self.manifest.num_bins as i32;
                }
            }
            let ins = [
                xla::Literal::vec1(&szs[..]),
                xla::Literal::vec1(&soj[..]),
                xla::Literal::vec1(&mask[..]),
                xla::Literal::vec1(&idx[..]),
                xla::Literal::vec1(&thr[..]),
            ];
            let result = self.analytics.execute::<xla::Literal>(&ins).map_err(wrap)?[0][0]
                .to_literal_sync()
                .map_err(wrap)?;
            let outs = result.to_tuple().map_err(wrap)?;
            if outs.len() != 6 {
                return Err(rt_err("analytics graph must return 6 outputs"));
            }
            let slow = outs[0].to_vec::<f32>().map_err(wrap)?;
            out.slowdowns.extend(slow[..take].iter().map(|&x| x as f64));
            for (acc, v) in out.bin_sums.iter_mut().zip(outs[1].to_vec::<f32>().map_err(wrap)?) {
                *acc += v as f64;
            }
            for (acc, v) in out.bin_counts.iter_mut().zip(outs[2].to_vec::<f32>().map_err(wrap)?) {
                *acc += v as f64;
            }
            for (acc, v) in out.ecdf_counts.iter_mut().zip(outs[3].to_vec::<f32>().map_err(wrap)?) {
                *acc += v as f64;
            }
            out.sojourn_sum += outs[4].to_vec::<f32>().map_err(wrap)?[0] as f64;
            out.count += outs[5].to_vec::<f32>().map_err(wrap)?[0] as f64;
            start += take;
        }
        Ok(out)
    }
}
