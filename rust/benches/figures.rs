//! One bench per paper table/figure: times a reduced-scale regeneration
//! of each figure so regressions in any part of the pipeline (workload
//! synthesis, scheduler, metrics) surface as figure-level slowdowns.
//! `psbs sweep` produces the full-scale CSVs; this harness is the
//! regression guard.
//!
//! Also measures the parallel sweep executor on a Fig. 6-style
//! shape×sigma ratio grid at 1/2/4 worker threads and records the
//! wall-clock speedups in `BENCH_sweeps.json` (`derived` section), so
//! the executor's scaling is tracked from PR to PR.  Filter with
//! `cargo bench --bench figures -- sweep/` for the scaling run alone.

use psbs::figures::{self, Ctx, Reference, SweepCell};
use psbs::util::bench::{self, Bench};
use psbs::workload::SynthConfig;

fn main() {
    let mut b = Bench::new();
    // Reduced scale: 1 rep x 500 jobs keeps every figure fast; the
    // pure-rust analytics fallback avoids timing PJRT compilation here
    // (runtime.rs benches the artifacts directly).
    for fig in figures::ALL_FIGS {
        b.bench(&format!("figure/fig{fig}"), move || {
            let ctx = Ctx { reps: 1, njobs: 500, seed: 7, runtime: None, ..Default::default() };
            let tables = figures::by_number(&ctx, fig).unwrap();
            std::hint::black_box(tables.len());
        });
    }

    // Parallel sweep executor scaling: the shape×sigma MST/opt ratio
    // grid (the Fig. 6 shape) as one flat cell list, at 1/2/4 threads.
    // Identical cells each time — only the thread count varies, so the
    // mean-time ratios are the executor's wall-clock speedups.
    let mut cells: Vec<SweepCell> = Vec::new();
    for &shape in &[0.5, 0.25, 0.125] {
        for &sigma in &figures::GRID {
            for p in ["psbs", "srpte", "fspe", "ps", "las"] {
                cells.push(SweepCell::ratio(
                    p,
                    Reference::OptSrpt,
                    SynthConfig::default().with_shape(shape).with_sigma(sigma).with_njobs(1_500),
                ));
            }
        }
    }
    for &threads in &[1usize, 2, 4] {
        let ctx = Ctx { reps: 1, njobs: 1_500, seed: 7, threads, ..Default::default() };
        let cells = cells.clone();
        b.bench_items(
            &format!("sweep/shape_sigma_grid/threads{threads}"),
            Some(cells.len() as u64),
            move || {
                std::hint::black_box(ctx.eval_grid(&cells).len());
            },
        );
    }

    // Derived speedups vs the 1-thread run (when all three ran — a
    // `cargo bench -- <filter>` may have skipped some).
    let mean_of = |suffix: &str| {
        b.samples.iter().find(|s| s.name.ends_with(suffix)).map(|s| s.mean_ns)
    };
    let mut derived: Vec<(String, f64)> = Vec::new();
    if let Some(t1) = mean_of("threads1") {
        for (suffix, label) in [("threads2", "sweep_speedup_2v1"), ("threads4", "sweep_speedup_4v1")] {
            if let Some(tn) = mean_of(suffix) {
                derived.push((label.to_string(), t1 / tn));
            }
        }
    }
    for (k, v) in &derived {
        println!("derived {k} = {v:.2}x");
    }

    let path = bench::out_path("BENCH_sweeps.json");
    bench::write_json(&path, "sweeps", &b.samples, &derived).expect("write BENCH_sweeps.json");
    println!("wrote {path}");
}
