//! One bench per paper table/figure: times a reduced-scale regeneration
//! of each figure so regressions in any part of the pipeline (workload
//! synthesis, scheduler, metrics) surface as figure-level slowdowns.
//! `psbs sweep` produces the full-scale CSVs; this harness is the
//! regression guard.
//!
//! Also measures the sweep executor on a Fig. 6-style shape×sigma
//! ratio grid at 1/2/4 worker threads, in BOTH evaluation modes:
//!
//! * `sweep/shape_sigma_grid/threadsN` — the per-cell legacy path of
//!   PR 1 (every cell re-synthesizes its workloads and re-runs its
//!   reference); names unchanged so the numbers stay comparable
//!   across PRs.
//! * `sweep/planner/shape_sigma_grid/threadsN` — the shared-workload
//!   planner (synthesize once per (config, seed), reference once per
//!   seed, repetition-level split, cost-aware ordering).
//!
//! The `derived` section of `BENCH_sweeps.json` records the thread
//! speedups of each mode plus `planner_speedup_t{1,4}` — the planner's
//! wall-clock win over the per-cell path at equal thread count (the
//! sweep-throughput number this PR is accountable for) — and
//! `fault_replay_overhead`, the cost of running a cluster under a
//! fault plan relative to the plain path (see BENCH README).  Filter
//! with `cargo bench --bench figures -- sweep/` for the scaling run
//! alone.

use psbs::coordinator::{FaultConfig, FaultSpec};
use psbs::figures::{self, Ctx, Reference, SweepCell};
use psbs::scenario::PolicySpec;
use psbs::util::bench::{self, Bench};
use psbs::workload::SynthConfig;

fn main() {
    let mut b = Bench::new();
    // Reduced scale: 1 rep x 500 jobs keeps every figure fast; all
    // figure metrics are pure rust (runtime.rs benches the PJRT
    // artifacts directly).  Figures run through the planner (the
    // production default).
    for fig in figures::ALL_FIGS {
        b.bench(&format!("figure/fig{fig}"), move || {
            let ctx = Ctx { reps: 1, njobs: 500, seed: 7, ..Default::default() };
            let tables = figures::by_number(&ctx, fig).unwrap();
            std::hint::black_box(tables.len());
        });
    }

    // Sweep executor scaling: the shape×sigma MST/opt ratio grid (the
    // Fig. 6 shape) as one flat cell list, at 1/2/4 threads, per-cell
    // vs planner-shared.  Identical cells each time — only the thread
    // count and sharing mode vary, so mean-time ratios are wall-clock
    // speedups (results themselves are bit-identical by construction).
    let mut cells: Vec<SweepCell> = Vec::new();
    for &shape in &[0.5, 0.25, 0.125] {
        for &sigma in &figures::GRID {
            for p in ["psbs", "srpte", "fspe", "ps", "las"] {
                cells.push(SweepCell::ratio(
                    p,
                    Reference::OptSrpt,
                    SynthConfig::default().with_shape(shape).with_sigma(sigma).with_njobs(1_500),
                ));
            }
        }
    }
    for share in [false, true] {
        for &threads in &[1usize, 2, 4] {
            let ctx =
                Ctx { reps: 1, njobs: 1_500, seed: 7, threads, share, ..Default::default() };
            let cells = cells.clone();
            let mode = if share { "sweep/planner" } else { "sweep" };
            b.bench_items(
                &format!("{mode}/shape_sigma_grid/threads{threads}"),
                Some(cells.len() as u64),
                move || {
                    std::hint::black_box(ctx.eval_grid(&cells).len());
                },
            );
        }
    }

    // Trace-ingestion throughput: parse a 50k-row CSV trace held in
    // memory (no disk IO in the timed region — the parser, not the
    // filesystem, is the tracked quantity).  Named under `sweep/` so
    // the tier-1 bench smoke (`cargo bench --bench figures -- sweep/`)
    // emits it into BENCH_sweeps.json from day one; the derived
    // `trace_parse_throughput` (rows/s) rides the bench-compare step.
    const TRACE_ROWS: usize = 50_000;
    let mut csv = String::with_capacity(TRACE_ROWS * 16);
    csv.push_str("arrival,size,weight\n");
    for i in 0..TRACE_ROWS {
        csv.push_str(&format!("{i}.5,{},{}\n", (i * 7919) % 997 + 1, 1 + i % 3));
    }
    b.bench_items("sweep/trace_parse/rows50k", Some(TRACE_ROWS as u64), move || {
        std::hint::black_box(psbs::workload::trace_file::parse(&csv).unwrap().len());
    });

    // Fault-replay cost: 10k jobs through a k=4 cluster, plain vs under
    // a fault plan (crash/recovery churn, degraded windows, retries).
    // Also named under `sweep/` for the tier-1 smoke; the derived
    // `fault_replay_overhead` (faulty/plain mean-time ratio) tracks what
    // the fault machinery costs relative to the bit-identical plain
    // path — informational in bench-compare, not gated.
    const FAULT_JOBS: usize = 10_000;
    let jobs = psbs::workload::synthesize(
        &SynthConfig::default().with_njobs(FAULT_JOBS),
        7,
    );
    let spec = PolicySpec::from("cluster(k=4,dispatch=leastwork,inner=psbs)");
    let cfg = FaultConfig {
        spec: FaultSpec { mtbf: 50.0, mttr: 5.0, slowdown: 0.5 },
        ..Default::default()
    };
    {
        let jobs = jobs.clone();
        let spec = spec.clone();
        b.bench_items("sweep/cluster/plain/n10k", Some(FAULT_JOBS as u64), move || {
            let mut s = spec.build_seeded(7);
            std::hint::black_box(psbs::sim::run_to_drain(s.as_mut(), &jobs).completed());
        });
    }
    b.bench_items("sweep/cluster/fault_replay/n10k", Some(FAULT_JOBS as u64), move || {
        let mut s = spec.build_faulty(7, &cfg);
        std::hint::black_box(psbs::sim::run_to_drain(s.as_mut(), &jobs).completed());
    });

    // Derived speedups (when the relevant samples ran — a
    // `cargo bench -- <filter>` may have skipped some).
    let mean_of = |name: &str| b.samples.iter().find(|s| s.name == name).map(|s| s.mean_ns);
    let mut derived: Vec<(String, f64)> = Vec::new();
    for (mode, tag) in [("sweep", "sweep_speedup"), ("sweep/planner", "planner_speedup")] {
        if let Some(t1) = mean_of(&format!("{mode}/shape_sigma_grid/threads1")) {
            for n in [2u32, 4] {
                if let Some(tn) = mean_of(&format!("{mode}/shape_sigma_grid/threads{n}")) {
                    derived.push((format!("{tag}_{n}v1"), t1 / tn));
                }
            }
        }
    }
    // The planner's win over the per-cell path at equal thread count.
    for n in [1u32, 4] {
        if let (Some(cell), Some(plan)) = (
            mean_of(&format!("sweep/shape_sigma_grid/threads{n}")),
            mean_of(&format!("sweep/planner/shape_sigma_grid/threads{n}")),
        ) {
            derived.push((format!("planner_speedup_t{n}"), cell / plan));
        }
    }
    if let Some(s) = b.samples.iter().find(|s| s.name == "sweep/trace_parse/rows50k") {
        derived.push(("trace_parse_throughput".to_string(), bench::ops_per_sec(s)));
    }
    if let (Some(plain), Some(faulty)) = (
        mean_of("sweep/cluster/plain/n10k"),
        mean_of("sweep/cluster/fault_replay/n10k"),
    ) {
        derived.push(("fault_replay_overhead".to_string(), faulty / plain));
    }
    for (k, v) in &derived {
        println!("derived {k} = {v:.2}x");
    }

    let path = bench::out_path("BENCH_sweeps.json");
    bench::write_json(&path, "sweeps", &b.samples, &derived).expect("write BENCH_sweeps.json");
    println!("wrote {path}");
}
