//! One bench per paper table/figure: times a reduced-scale regeneration
//! of each figure so regressions in any part of the pipeline (workload
//! synthesis, scheduler, metrics) surface as figure-level slowdowns.
//! `psbs sweep` produces the full-scale CSVs; this harness is the
//! regression guard.
//!
//! Also measures the sweep executor on a Fig. 6-style shape×sigma
//! ratio grid at 1/2/4 worker threads, in BOTH evaluation modes:
//!
//! * `sweep/shape_sigma_grid/threadsN` — the per-cell legacy path of
//!   PR 1 (every cell re-synthesizes its workloads and re-runs its
//!   reference); names unchanged so the numbers stay comparable
//!   across PRs.
//! * `sweep/planner/shape_sigma_grid/threadsN` — the shared-workload
//!   planner (synthesize once per (config, seed), reference once per
//!   seed, repetition-level split, cost-aware ordering).
//!
//! The `derived` section of `BENCH_sweeps.json` records the thread
//! speedups of each mode plus `planner_speedup_t{1,4}` — the planner's
//! wall-clock win over the per-cell path at equal thread count (the
//! sweep-throughput number this PR is accountable for) — and
//! `fault_replay_overhead`, the cost of running a cluster under a
//! fault plan relative to the plain path (see BENCH README).  PR 7
//! adds the streaming-engine keys: `stream_throughput_jobs_per_s`
//! (gated — jobs/s through SynthSource → engine → OnlineMetrics),
//! `stream_vs_vec_overhead` (dyn-dispatch streaming entry point vs the
//! monomorphized `run` adapter, informational) and
//! `trace_cache_speedup` (chunked CSV parse vs `.psbt` binary cache
//! decode for the same 50k rows, informational).  Filter with
//! `cargo bench --bench figures -- sweep/` for the scaling run
//! alone.

use psbs::coordinator::{FaultConfig, FaultSpec};
use psbs::figures::{self, Ctx, Reference, SweepCell};
use psbs::scenario::PolicySpec;
use psbs::util::bench::{self, Bench};
use psbs::workload::SynthConfig;

fn main() {
    let mut b = Bench::new();
    // Reduced scale: 1 rep x 500 jobs keeps every figure fast; all
    // figure metrics are pure rust (runtime.rs benches the PJRT
    // artifacts directly).  Figures run through the planner (the
    // production default).
    for fig in figures::ALL_FIGS {
        b.bench(&format!("figure/fig{fig}"), move || {
            let ctx = Ctx { reps: 1, njobs: 500, seed: 7, ..Default::default() };
            let tables = figures::by_number(&ctx, fig).unwrap();
            std::hint::black_box(tables.len());
        });
    }

    // Sweep executor scaling: the shape×sigma MST/opt ratio grid (the
    // Fig. 6 shape) as one flat cell list, at 1/2/4 threads, per-cell
    // vs planner-shared.  Identical cells each time — only the thread
    // count and sharing mode vary, so mean-time ratios are wall-clock
    // speedups (results themselves are bit-identical by construction).
    let mut cells: Vec<SweepCell> = Vec::new();
    for &shape in &[0.5, 0.25, 0.125] {
        for &sigma in &figures::GRID {
            for p in ["psbs", "srpte", "fspe", "ps", "las"] {
                cells.push(SweepCell::ratio(
                    p,
                    Reference::OptSrpt,
                    SynthConfig::default().with_shape(shape).with_sigma(sigma).with_njobs(1_500),
                ));
            }
        }
    }
    for share in [false, true] {
        for &threads in &[1usize, 2, 4] {
            let ctx =
                Ctx { reps: 1, njobs: 1_500, seed: 7, threads, share, ..Default::default() };
            let cells = cells.clone();
            let mode = if share { "sweep/planner" } else { "sweep" };
            b.bench_items(
                &format!("{mode}/shape_sigma_grid/threads{threads}"),
                Some(cells.len() as u64),
                move || {
                    std::hint::black_box(ctx.eval_grid(&cells).len());
                },
            );
        }
    }

    // Trace-ingestion throughput: parse a 50k-row CSV trace held in
    // memory (no disk IO in the timed region — the parser, not the
    // filesystem, is the tracked quantity).  Named under `sweep/` so
    // the tier-1 bench smoke (`cargo bench --bench figures -- sweep/`)
    // emits it into BENCH_sweeps.json from day one; the derived
    // `trace_parse_throughput` (rows/s) rides the bench-compare step.
    const TRACE_ROWS: usize = 50_000;
    let mut csv = String::with_capacity(TRACE_ROWS * 16);
    csv.push_str("arrival,size,weight\n");
    for i in 0..TRACE_ROWS {
        csv.push_str(&format!("{i}.5,{},{}\n", (i * 7919) % 997 + 1, 1 + i % 3));
    }
    b.bench_items("sweep/trace_parse/rows50k", Some(TRACE_ROWS as u64), move || {
        std::hint::black_box(psbs::workload::trace_file::parse(&csv).unwrap().len());
    });

    // Fault-replay cost: 10k jobs through a k=4 cluster, plain vs under
    // a fault plan (crash/recovery churn, degraded windows, retries).
    // Also named under `sweep/` for the tier-1 smoke; the derived
    // `fault_replay_overhead` (faulty/plain mean-time ratio) tracks what
    // the fault machinery costs relative to the bit-identical plain
    // path — informational in bench-compare, not gated.
    const FAULT_JOBS: usize = 10_000;
    let jobs = psbs::workload::synthesize(
        &SynthConfig::default().with_njobs(FAULT_JOBS),
        7,
    );
    let spec = PolicySpec::from("cluster(k=4,dispatch=leastwork,inner=psbs)");
    let cfg = FaultConfig {
        spec: FaultSpec { mtbf: 50.0, mttr: 5.0, slowdown: 0.5 },
        ..Default::default()
    };
    {
        let jobs = jobs.clone();
        let spec = spec.clone();
        b.bench_items("sweep/cluster/plain/n10k", Some(FAULT_JOBS as u64), move || {
            let mut s = spec.build_seeded(7);
            std::hint::black_box(psbs::sim::run_to_drain(s.as_mut(), &jobs).completed());
        });
    }
    b.bench_items("sweep/cluster/fault_replay/n10k", Some(FAULT_JOBS as u64), move || {
        let mut s = spec.build_faulty(7, &cfg);
        std::hint::black_box(psbs::sim::run_to_drain(s.as_mut(), &jobs).completed());
    });

    // Streaming engine vs the materialized path on an identical 50k-job
    // workload (jobs synthesized outside the timed region).  `run` is a
    // monomorphized adapter over the same inner loop, so the mean-time
    // ratio (`stream_vs_vec_overhead`) isolates what the public
    // dyn-dispatch streaming entry point costs — informational in
    // bench-compare, expected near 1.0.
    const STREAM_JOBS: usize = 50_000;
    let sjobs = psbs::workload::synthesize(
        &SynthConfig::default().with_njobs(STREAM_JOBS),
        7,
    );
    {
        let jobs = sjobs.clone();
        b.bench_items("sweep/stream/replay_vec/n50k", Some(STREAM_JOBS as u64), move || {
            let mut s = psbs::sched::by_name("psbs").unwrap();
            std::hint::black_box(psbs::sim::run(s.as_mut(), &jobs).events);
        });
    }
    {
        let jobs = sjobs.clone();
        b.bench_items("sweep/stream/replay_stream/n50k", Some(STREAM_JOBS as u64), move || {
            let mut s = psbs::sched::by_name("psbs").unwrap();
            let mut src = psbs::sim::SliceSource::new(&jobs);
            let mut sink = psbs::sim::NullSink;
            std::hint::black_box(psbs::sim::run_streaming(s.as_mut(), &mut src, &mut sink).events);
        });
    }

    // End-to-end streaming replay throughput: generate 50k jobs on the
    // fly (O(active) memory — no materialized Vec<Job> anywhere) and
    // fold them into the online accumulator with two P2 quantile
    // sketches, exactly the `psbs replay --format bin` hot path.  The
    // derived `stream_throughput_jobs_per_s` is the gated key: jobs/s
    // through scheduler + engine + online metrics.
    {
        let cfg = SynthConfig::default().with_njobs(STREAM_JOBS);
        b.bench_items("sweep/stream/synth_replay/n50k", Some(STREAM_JOBS as u64), move || {
            let mut s = psbs::sched::by_name("psbs").unwrap();
            let mut src = psbs::workload::SynthSource::new(&cfg, 7);
            let mut m = psbs::metrics::OnlineMetrics::new().with_quantiles(&[0.5, 0.99]);
            let stats = psbs::sim::run_streaming(s.as_mut(), &mut src, &mut m);
            std::hint::black_box((stats.completed, m.count()));
        });
    }

    // Trace-cache ingestion: stream 50k validated rows from the CSV
    // (chunked parser) vs the `.psbt` binary cache of the same rows.
    // Both files are written once outside the timed region; each
    // iteration reopens and drains the stream, so the ratio
    // (`trace_cache_speedup`, csv/bin mean time) is the real
    // cost-per-replay win of caching — parse + validate vs fixed-width
    // decode + checksummed header.
    {
        use psbs::workload::trace_file::RowStream;
        let dir = std::env::temp_dir().join("psbs_bench_cache");
        std::fs::create_dir_all(&dir).expect("bench temp dir");
        let csv_path = dir.join("rows50k.csv");
        let bin_path = dir.join("rows50k.psbt");
        let mut text = String::with_capacity(TRACE_ROWS * 16);
        text.push_str("arrival,size,weight\n");
        for i in 0..TRACE_ROWS {
            text.push_str(&format!("{i}.5,{},{}\n", (i * 7919) % 997 + 1, 1 + i % 3));
        }
        std::fs::write(&csv_path, &text).expect("write bench csv");
        let rows = psbs::workload::trace_file::parse(&text).unwrap();
        psbs::workload::cache::write_cache(bin_path.to_str().unwrap(), rows)
            .expect("write bench cache");
        fn drain(mut s: Box<dyn RowStream>) -> u64 {
            let mut n = 0u64;
            while s.next_row().unwrap().is_some() {
                n += 1;
            }
            n
        }
        {
            let p = csv_path.to_str().unwrap().to_string();
            b.bench_items("sweep/trace_cache/csv/rows50k", Some(TRACE_ROWS as u64), move || {
                let r = psbs::workload::trace_file::ChunkedCsvReader::open(&p).unwrap();
                std::hint::black_box(drain(Box::new(r)));
            });
        }
        {
            let p = bin_path.to_str().unwrap().to_string();
            b.bench_items("sweep/trace_cache/bin/rows50k", Some(TRACE_ROWS as u64), move || {
                let r = psbs::workload::cache::CacheReader::open(&p).unwrap();
                std::hint::black_box(drain(Box::new(r)));
            });
        }
    }

    // Derived speedups (when the relevant samples ran — a
    // `cargo bench -- <filter>` may have skipped some).
    let mean_of = |name: &str| b.samples.iter().find(|s| s.name == name).map(|s| s.mean_ns);
    let mut derived: Vec<(String, f64)> = Vec::new();
    for (mode, tag) in [("sweep", "sweep_speedup"), ("sweep/planner", "planner_speedup")] {
        if let Some(t1) = mean_of(&format!("{mode}/shape_sigma_grid/threads1")) {
            for n in [2u32, 4] {
                if let Some(tn) = mean_of(&format!("{mode}/shape_sigma_grid/threads{n}")) {
                    derived.push((format!("{tag}_{n}v1"), t1 / tn));
                }
            }
        }
    }
    // The planner's win over the per-cell path at equal thread count.
    for n in [1u32, 4] {
        if let (Some(cell), Some(plan)) = (
            mean_of(&format!("sweep/shape_sigma_grid/threads{n}")),
            mean_of(&format!("sweep/planner/shape_sigma_grid/threads{n}")),
        ) {
            derived.push((format!("planner_speedup_t{n}"), cell / plan));
        }
    }
    if let Some(s) = b.samples.iter().find(|s| s.name == "sweep/trace_parse/rows50k") {
        derived.push(("trace_parse_throughput".to_string(), bench::ops_per_sec(s)));
    }
    if let (Some(plain), Some(faulty)) = (
        mean_of("sweep/cluster/plain/n10k"),
        mean_of("sweep/cluster/fault_replay/n10k"),
    ) {
        derived.push(("fault_replay_overhead".to_string(), faulty / plain));
    }
    // Streaming-engine keys.  `stream_throughput_jobs_per_s` is the
    // gated one (bench-compare fails a >20% drop); the two ratios are
    // informational.
    if let Some(s) = b.samples.iter().find(|s| s.name == "sweep/stream/synth_replay/n50k") {
        derived.push(("stream_throughput_jobs_per_s".to_string(), bench::ops_per_sec(s)));
    }
    if let (Some(vec_t), Some(stream_t)) = (
        mean_of("sweep/stream/replay_vec/n50k"),
        mean_of("sweep/stream/replay_stream/n50k"),
    ) {
        derived.push(("stream_vs_vec_overhead".to_string(), stream_t / vec_t));
    }
    if let (Some(csv_t), Some(bin_t)) = (
        mean_of("sweep/trace_cache/csv/rows50k"),
        mean_of("sweep/trace_cache/bin/rows50k"),
    ) {
        derived.push(("trace_cache_speedup".to_string(), csv_t / bin_t));
    }
    for (k, v) in &derived {
        println!("derived {k} = {v:.2}x");
    }

    let path = bench::out_path("BENCH_sweeps.json");
    bench::write_json(&path, "sweeps", &b.samples, &derived).expect("write BENCH_sweeps.json");
    println!("wrote {path}");
}
