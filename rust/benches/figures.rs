//! One bench per paper table/figure: times a reduced-scale regeneration
//! of each figure so regressions in any part of the pipeline (workload
//! synthesis, scheduler, metrics) surface as figure-level slowdowns.
//! `psbs sweep` produces the full-scale CSVs; this harness is the
//! regression guard.

use psbs::figures::{self, Ctx};
use psbs::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    // Reduced scale: 1 rep x 500 jobs keeps every figure fast; the
    // pure-rust analytics fallback avoids timing PJRT compilation here
    // (runtime.rs benches the artifacts directly).
    for fig in figures::ALL_FIGS {
        b.bench(&format!("figure/fig{fig}"), move || {
            let ctx = Ctx { reps: 1, njobs: 500, seed: 7, runtime: None, ..Default::default() };
            let tables = figures::by_number(&ctx, fig).unwrap();
            std::hint::black_box(tables.len());
        });
    }
}
