//! End-to-end simulator throughput per discipline (paper §A.1: their
//! Python simulator runs 10k jobs in ~0.5 s; DESIGN.md §Perf targets
//! <5 ms for PS-class policies here).

use psbs::sched;
use psbs::sim;
use psbs::util::bench::Bench;
use psbs::workload::{self, SynthConfig};

fn main() {
    let mut b = Bench::new();

    let cfg = SynthConfig::default().with_njobs(10_000);
    let jobs = workload::synthesize(&cfg, 42);
    for policy in sched::ALL_POLICIES {
        // fsp-naive is O(n^2)-ish on 10k jobs; bench it at this size
        // anyway — it IS the comparison the paper's §5.2.2 makes.
        let jobs = jobs.clone();
        b.bench_items(&format!("sim/10k_default/{policy}"), Some(jobs.len() as u64), move || {
            let mut s = sched::by_name(policy).unwrap();
            let r = sim::run(s.as_mut(), &jobs);
            std::hint::black_box(r.events);
        });
    }

    // Scaling: PSBS at increasing n (the O(log n) claim end to end).
    for njobs in [1_000usize, 10_000, 100_000] {
        let cfg = SynthConfig::default().with_njobs(njobs);
        let jobs = workload::synthesize(&cfg, 43);
        b.bench_items(&format!("sim/psbs/n{njobs}"), Some(njobs as u64), move || {
            let mut s = sched::by_name("psbs").unwrap();
            let r = sim::run(s.as_mut(), &jobs);
            std::hint::black_box(r.events);
        });
    }

    // Workload synthesis itself.
    b.bench_items("workload/synthesize_10k", Some(10_000), || {
        let cfg = SynthConfig::default().with_njobs(10_000);
        std::hint::black_box(workload::synthesize(&cfg, 7).len());
    });
}
