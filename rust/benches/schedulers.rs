//! End-to-end simulator throughput per discipline (paper §A.1: their
//! Python simulator runs 10k jobs in ~0.5 s; DESIGN.md §Perf targets
//! <5 ms for PS-class policies here) plus per-event scheduler cost at
//! a standing 10k-job population (the §5.2.2 O(log n) vs O(n) numbers;
//! the full population curve lives in the psbs_ops bench).
//!
//! Results land in `BENCH_sched.json`.  Filter with
//! `cargo bench --bench schedulers -- event/` for a quick per-event
//! smoke (what scripts/tier1.sh runs).

use psbs::sched;
use psbs::sim::{self, Job, Scheduler};
use psbs::util::bench::{self, Bench};
use psbs::workload::{self, SynthConfig};

#[path = "common.rs"]
mod common;
use common::{preload, TINY};

fn main() {
    let mut b = Bench::new();

    let cfg = SynthConfig::default().with_njobs(10_000);
    let jobs = workload::synthesize(&cfg, 42);
    for policy in sched::ALL_POLICIES {
        // fsp-naive is O(n^2)-ish on 10k jobs; bench it at this size
        // anyway — it IS the comparison the paper's §5.2.2 makes.
        let jobs = jobs.clone();
        b.bench_items(&format!("sim/10k_default/{policy}"), Some(jobs.len() as u64), move || {
            let mut s = sched::by_name(policy).unwrap();
            let r = sim::run(s.as_mut(), &jobs);
            std::hint::black_box(r.events);
        });
    }

    // Scaling: PSBS at increasing n (the O(log n) claim end to end).
    for njobs in [1_000usize, 10_000, 100_000] {
        let cfg = SynthConfig::default().with_njobs(njobs);
        let jobs = workload::synthesize(&cfg, 43);
        b.bench_items(&format!("sim/psbs/n{njobs}"), Some(njobs as u64), move || {
            let mut s = sched::by_name("psbs").unwrap();
            let r = sim::run(s.as_mut(), &jobs);
            std::hint::black_box(r.events);
        });
    }

    // Per-event cost against a standing population of 10k jobs: one
    // tiny-job arrival + completion pair per iteration (methodology as
    // in the psbs_ops bench, which sweeps the population size).
    for policy in ["psbs", "fsp-naive"] {
        let n = 10_000usize;
        let mut s = preload(policy, n);
        let mut id = n as u32;
        let mut now = n as f64 * 1e-6;
        let mut done = Vec::with_capacity(1);
        let dt = TINY * 4.0 * (n as f64 + 2.0);
        b.bench(&format!("event/{policy}/n{n}"), move || {
            id += 1;
            s.on_arrival(now, &Job::exact(id, now, TINY));
            std::hint::black_box(s.next_event(now));
            done.clear();
            s.advance(now, now + dt, &mut done);
            debug_assert_eq!(done.len(), 1);
            now += dt;
            std::hint::black_box(done.len());
        });
    }

    // Workload synthesis itself.
    b.bench_items("workload/synthesize_10k", Some(10_000), || {
        let cfg = SynthConfig::default().with_njobs(10_000);
        std::hint::black_box(workload::synthesize(&cfg, 7).len());
    });

    let path = bench::out_path("BENCH_sched.json");
    bench::write_json(&path, "sched", &b.samples, &[]).expect("write BENCH_sched.json");
    println!("wrote {path}");
}
